//! "Computing Hessians for small neural nets has now become feasible"
//! (§4): the full layer-1 Hessian of a 10-layer ReLU MLP with softmax
//! cross-entropy, in all three of our modes plus the per-entry framework
//! baseline, with timings.
//!
//! Run: `cargo run --release --example neural_net_hessian`

use std::time::Instant;
use tensorcalc::baselines::PerEntryHessian;
use tensorcalc::eval::{eval, Plan};
use tensorcalc::problems::neural_net;
use tensorcalc::simplify::{dag_size, flop_estimate};
use tensorcalc::util::fmt_secs;

fn main() {
    let (width, layers, batch) = (16usize, 10usize, 32usize);
    println!(
        "neural net: {} layers of width {}, batch {} — Hessian of W1 ({}⁴ = {} entries)",
        layers,
        width,
        batch,
        width,
        width.pow(4)
    );

    // ours (reverse)
    let mut w = neural_net(width, layers, batch);
    let h = w.hessian();
    println!(
        "\nreverse-mode Hessian DAG: {} nodes, ~{:.2e} flops",
        dag_size(&w.g, h),
        flop_estimate(&w.g, h) as f64
    );
    let plan = Plan::new(&w.g, &[h]);
    let t0 = Instant::now();
    let h_rev = plan.run(&w.g, &w.env).pop().unwrap();
    let t_rev = t0.elapsed().as_secs_f64();
    println!("ours(reverse):        {}", fmt_secs(t_rev));

    // ours (cross-country)
    let mut w2 = neural_net(width, layers, batch);
    let hcc = w2.hessian_cross_country();
    let plan = Plan::new(&w2.g, &[hcc]);
    let t0 = Instant::now();
    let h_cc = plan.run(&w2.g, &w2.env).pop().unwrap();
    let t_cc = t0.elapsed().as_secs_f64();
    println!("ours(cross-country):  {}", fmt_secs(t_cc));

    // ours (compressed)
    let mut w3 = neural_net(width, layers, batch);
    let comp = w3.hessian_compressed();
    let plan = Plan::new(&w3.g, &[comp.eval_node()]);
    let t0 = Instant::now();
    let core = plan.run(&w3.g, &w3.env).pop().unwrap();
    let t_comp = t0.elapsed().as_secs_f64();
    println!(
        "ours(compressed):     {}   (core shape {:?}, compressed: {})",
        fmt_secs(t_comp),
        core.shape(),
        comp.is_compressed()
    );

    // framework baseline: one reverse sweep per entry of ∇
    let mut w4 = neural_net(width, layers, batch);
    let pe = PerEntryHessian::new(&mut w4.g, w4.loss, w4.wrt);
    let t0 = Instant::now();
    let h_pe = pe.eval(&w4.g, &w4.env);
    let t_pe = t0.elapsed().as_secs_f64();
    println!(
        "framework(per-entry): {}   ({} reverse sweeps — the TF/PyTorch strategy)",
        fmt_secs(t_pe),
        pe.sweeps()
    );
    println!(
        "\n→ ours(reverse) is {:.0}× faster than the framework strategy at width {}",
        t_pe / t_rev,
        width
    );

    // all modes agree
    assert!(h_rev.allclose(&h_cc, 1e-8, 1e-10), "cc disagrees");
    assert!(h_rev.allclose(&h_pe, 1e-8, 1e-10), "per-entry disagrees");
    let h_comp = comp.materialize(&core);
    assert!(h_rev.allclose(&h_comp, 1e-8, 1e-10), "compressed disagrees");
    println!("all four Hessians agree ✓");

    // the Hessian of a smooth(ish) loss is symmetric: H[i,j,k,l] = H[k,l,i,j]
    let n = width;
    let mut max_asym: f64 = 0.0;
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                for l in 0..n {
                    let a = h_rev.at(&[i, j, k, l]);
                    let b = h_rev.at(&[k, l, i, j]);
                    max_asym = max_asym.max((a - b).abs());
                }
            }
        }
    }
    println!("max |H[ijkl] − H[klij]| = {:.2e} (symmetry ✓)", max_asym);

    // loss value for the record
    let f = eval(&w.g, w.loss, &w.env);
    println!("loss at init: {:.4}", f.item());
}
