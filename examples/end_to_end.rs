//! End-to-end driver: proves all three layers compose on a real (small)
//! workload.
//!
//! 1. Symbolically derive gradient + Hessian for logistic regression
//!    (L3 engine, the paper's calculus).
//! 2. Cross-check the numbers against the AOT-compiled JAX/Pallas
//!    artifacts executed via PJRT (L2/L1, loaded by the Rust runtime).
//! 3. Serve gradient/Hessian requests through the coordinator and train
//!    to convergence with damped Newton, logging the loss curve.
//! 4. Report the Figure-3-style mode comparison at this size.
//!
//! Run: `cargo run --release --example end_to_end`
//! (requires `make artifacts`; skips the PJRT cross-check if absent)

use std::time::Instant;
use tensorcalc::baselines::PerEntryHessian;
use tensorcalc::coordinator::{Coordinator, EngineEntry};
use tensorcalc::exec::CompiledPlan;
use tensorcalc::ir::{Elem, Graph, NodeId};
use tensorcalc::prelude::*;
use tensorcalc::runtime::{artifacts_dir, Runtime};
use tensorcalc::solve::solve_spd;
use tensorcalc::tensor::{Tensor, XorShift};
use tensorcalc::util::fmt_secs;

/// AOT shapes (fixed in python/compile/aot.py)
const M: usize = 256;
const N: usize = 128;

fn build_logreg(g: &mut Graph) -> (NodeId, NodeId) {
    let x = g.var("X", &[M, N]);
    let y = g.var("y", &[M]);
    let w = g.var("w", &[N]);
    let xw = g.matvec(x, w);
    let yxw = g.hadamard(y, xw);
    let t = g.neg(yxw);
    let e = g.elem(Elem::Exp, t);
    let one = g.constant(1.0, &[M]);
    let s = g.add(e, one);
    let l = g.elem(Elem::Log, s);
    (g.sum_all(l), w)
}

fn main() {
    println!("=== end-to-end: L3 engine ⇄ L2/L1 PJRT artifacts ⇄ coordinator ===\n");

    // ---- 1. symbolic derivation ----
    let mut g = Graph::new();
    let (loss, w) = build_logreg(&mut g);
    let grad = reverse_gradient(&mut g, loss, w);
    let grad = simplify(&mut g, &[grad])[0];
    let hess = hessian(&mut g, loss, w);
    let hess = optimize_contractions(&mut g, hess);
    let hess = simplify(&mut g, &[hess])[0];
    println!("derived ∇f and H symbolically (H shape {:?})", g.shape(hess));

    // synthetic two-blob data
    let mut rng = XorShift::new(42);
    let mut xdata = Vec::with_capacity(M * N);
    let mut ydata = Vec::with_capacity(M);
    for i in 0..M {
        let label = if i % 2 == 0 { 1.0 } else { -1.0 };
        ydata.push(label);
        for j in 0..N {
            let (a, _) = rng.normal_pair();
            xdata.push(a + if j < 4 { 0.4 * label } else { 0.0 });
        }
    }
    let xv = Tensor::new(&[M, N], xdata);
    let yv = Tensor::new(&[M], ydata);
    let mut env = Env::new();
    env.insert("X", xv.clone());
    env.insert("y", yv.clone());
    env.insert("w", Tensor::zeros(&[N]));

    // ---- 2. cross-check against the PJRT artifacts ----
    match artifacts_dir() {
        Some(dir) => {
            let mut rt = Runtime::open(&dir).expect("runtime open");
            let wv = Tensor::randn(&[N], 9).scale(0.05);
            let mut e2 = env.clone();
            e2.insert("w", wv.clone());
            let engine = eval_many(&g, &[loss, grad, hess], &e2);
            let pj_g = rt
                .execute("logreg_val_grad", &[wv.clone(), xv.clone(), yv.clone()])
                .expect("pjrt grad");
            let pj_h = rt
                .execute("logreg_hess", &[wv.clone(), xv.clone(), yv.clone()])
                .expect("pjrt hess");
            let dg = engine[1].max_abs_diff(&pj_g[1]);
            let dh = engine[2].max_abs_diff(&pj_h[0]);
            println!(
                "cross-check vs JAX/Pallas artifacts: |Δgrad|∞ = {:.2e}, |ΔH|∞ = {:.2e} ✓",
                dg, dh
            );
            assert!(engine[1].allclose(&pj_g[1], 1e-3, 1e-3), "grad mismatch vs PJRT");
            assert!(engine[2].allclose(&pj_h[0], 1e-3, 1e-3), "hess mismatch vs PJRT");
        }
        None => println!("(artifacts missing — PJRT cross-check skipped; run `make artifacts`)"),
    }

    // ---- 3. Newton training through the coordinator ----
    let mut coord = Coordinator::new(64);
    coord.register_engine(
        "logreg_newton_state",
        EngineEntry::compiled(
            &g,
            &[loss, grad, hess],
            vec![
                ("X".into(), vec![M, N]),
                ("y".into(), vec![M]),
                ("w".into(), vec![N]),
            ],
        ),
    );
    let mut wcur = Tensor::zeros(&[N]);
    println!("\n{:>4} {:>14} {:>14} {:>10}", "iter", "loss", "‖grad‖", "latency");
    let mut converged = false;
    for it in 0..25 {
        let resp = coord
            .eval("logreg_newton_state", vec![xv.clone(), yv.clone(), wcur.clone()])
            .expect("coordinator eval");
        let f = resp.outputs[0].item();
        // materialise the zero-copy arena views before the lease drops
        let gv = resp.outputs[1].to_tensor();
        let mut hv = resp.outputs[2].to_tensor();
        println!("{:>4} {:>14.6} {:>14.3e} {:>10}", it, f, gv.norm(), fmt_secs(resp.latency));
        if gv.norm() < 1e-8 {
            println!("\nconverged in {} Newton steps ✓", it);
            converged = true;
            break;
        }
        // damping keeps H SPD on nearly-separable data
        for i in 0..N {
            hv.data_mut()[i * N + i] += 1e-6;
        }
        let step = solve_spd(&hv, &gv).expect("H must be SPD");
        wcur = wcur.sub(&step);
    }
    assert!(converged || wcur.norm().is_finite(), "training diverged");
    let snap = coord.metrics().snapshot();
    println!(
        "coordinator: {} requests, 0 errors = {}",
        snap.completed,
        snap.errors == 0
    );

    // ---- 4. Figure-3-style mode comparison at this size ----
    println!("\nHessian mode comparison at m={}, n={}:", M, N);
    let mut wl = tensorcalc::problems::logistic_regression(M, N);
    let h = wl.hessian();
    let plan = CompiledPlan::new(&wl.g, &[h]);
    let t0 = Instant::now();
    let _ = plan.run(&wl.env);
    let t_rev = t0.elapsed().as_secs_f64();

    let mut wl2 = tensorcalc::problems::logistic_regression(M, N);
    let hcc = wl2.hessian_cross_country();
    let plan = CompiledPlan::new(&wl2.g, &[hcc]);
    let t0 = Instant::now();
    let _ = plan.run(&wl2.env);
    let t_cc = t0.elapsed().as_secs_f64();

    let mut wl3 = tensorcalc::problems::logistic_regression(M, N);
    let pe = PerEntryHessian::new(&mut wl3.g, wl3.loss, wl3.wrt);
    let t0 = Instant::now();
    let _ = pe.eval(&wl3.g, &wl3.env);
    let t_pe = t0.elapsed().as_secs_f64();

    println!("  framework(per-entry×{}): {}", pe.sweeps(), fmt_secs(t_pe));
    println!("  ours(reverse):          {}  ({:.0}× faster)", fmt_secs(t_rev), t_pe / t_rev);
    println!("  ours(cross-country):    {}  ({:+.0}% vs reverse)", fmt_secs(t_cc), 100.0 * (t_cc - t_rev) / t_rev);
    println!("\n=== end-to-end complete ===");
}
