//! Newton's method for logistic regression, with gradient and Hessian
//! derived *symbolically* by the tensor calculus (nothing hand-coded),
//! on a synthetic two-Gaussian classification task.
//!
//! Run: `cargo run --release --example logreg_newton`

use tensorcalc::eval::Plan;
use tensorcalc::ir::{Elem, Graph};
use tensorcalc::prelude::*;
use tensorcalc::solve::solve_spd;
use tensorcalc::tensor::{Tensor, XorShift};

fn main() {
    let (m, n) = (400usize, 20usize);

    // synthetic data: two Gaussian blobs, labels ±1
    let mut rng = XorShift::new(7);
    let mut xdata = Vec::with_capacity(m * n);
    let mut ydata = Vec::with_capacity(m);
    for i in 0..m {
        let label = if i % 2 == 0 { 1.0 } else { -1.0 };
        ydata.push(label);
        for j in 0..n {
            let (a, _) = rng.normal_pair();
            let shift = if j < 3 { 0.9 * label } else { 0.0 };
            xdata.push(a + shift);
        }
    }

    // loss: Σ log(exp(−y⊙Xw) + 1) + λ‖w‖²
    let mut g = Graph::new();
    let x = g.var("X", &[m, n]);
    let y = g.var("y", &[m]);
    let w = g.var("w", &[n]);
    let xw = g.matvec(x, w);
    let yxw = g.hadamard(y, xw);
    let t = g.neg(yxw);
    let e = g.elem(Elem::Exp, t);
    let one = g.constant(1.0, &[m]);
    let s = g.add(e, one);
    let l = g.elem(Elem::Log, s);
    let data_loss = g.sum_all(l);
    let reg = g.norm2(w);
    let reg = g.scale(reg, 1e-3);
    let loss = g.add(data_loss, reg);

    // derive ∇f and H symbolically, once
    let grad = reverse_gradient(&mut g, loss, w);
    let grad = simplify(&mut g, &[grad])[0];
    let hess = hessian(&mut g, loss, w);
    let hess = optimize_contractions(&mut g, hess);
    let hess = simplify(&mut g, &[hess])[0];
    let plan = Plan::new(&g, &[loss, grad, hess]);

    let mut env = Env::new();
    env.insert("X", Tensor::new(&[m, n], xdata));
    env.insert("y", Tensor::new(&[m], ydata));
    env.insert("w", Tensor::zeros(&[n]));

    println!("{:>4} {:>14} {:>14}", "iter", "loss", "‖grad‖");
    for it in 0..20 {
        let vals = plan.run(&g, &env);
        let (f, gv, hv) = (vals[0].item(), vals[1].clone(), vals[2].clone());
        println!("{:>4} {:>14.6} {:>14.3e}", it, f, gv.norm());
        if gv.norm() < 1e-10 {
            println!("\nconverged in {} Newton steps ✓", it);
            break;
        }
        let step = solve_spd(&hv, &gv).expect("Hessian must be SPD (convex problem)");
        let w_new = env.get("w").unwrap().sub(&step);
        env.insert("w", w_new);
    }

    // sanity: training accuracy
    let xw_plan = Plan::new(&g, &[g.var_id("w").map(|_| loss).unwrap()]);
    let _ = xw_plan;
    let wv = env.get("w").unwrap();
    let xv = env.get("X").unwrap();
    let yv = env.get("y").unwrap();
    let mut correct = 0;
    for i in 0..m {
        let mut z = 0.0;
        for j in 0..n {
            z += xv.at(&[i, j]) * wv.data()[j];
        }
        if z.signum() == yv.data()[i] {
            correct += 1;
        }
    }
    println!("training accuracy: {:.1}%", 100.0 * correct as f64 / m as f64);
    assert!(correct as f64 / m as f64 > 0.8, "Newton on separated blobs must fit well");
}
