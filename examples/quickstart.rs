//! Quickstart: build a tensor expression, differentiate it symbolically,
//! simplify, and evaluate — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! The `optimizer: …` line below is the [`tensorcalc::opt::OptStats`]
//! report (DAG nodes and estimated flops before/after the graph
//! optimizer). To reproduce the paper's figures and the design
//! ablations, see the "Reproduce" section of the repository README:
//! `cargo bench --bench fig2_gradients | fig3_hessians | ablation_modes`,
//! and `scripts/bench_baseline.sh` to record `BENCH_exec.json`.

use tensorcalc::prelude::*;
use tensorcalc::simplify::dag_size;
use tensorcalc::tensor::Tensor;

fn main() {
    // f(w) = Σ log(exp(X·w) + 1)  — a softplus sum
    let (m, n) = (6usize, 4usize);
    let mut g = Graph::new();
    let x = g.var("X", &[m, n]);
    let w = g.var("w", &[n]);
    let xw = g.matvec(x, w);
    let e = g.elem(Elem::Exp, xw);
    let one = g.constant(1.0, &[m]);
    let s = g.add(e, one);
    let l = g.elem(Elem::Log, s);
    let f = g.sum_all(l);
    println!("f = {}", g.render(f));

    // reverse-mode gradient (Theorem 8) + simplification
    let grad = reverse_gradient(&mut g, f, w);
    let grad = simplify(&mut g, &[grad])[0];
    println!("\n∇f ({} nodes):\n{}", dag_size(&g, grad), g.program(&[grad]));

    // Hessian, with and without cross-country reordering
    let hess = hessian(&mut g, f, w);
    let hess_cc = optimize_contractions(&mut g, hess);
    println!("H shape: {:?}", g.shape(hess));

    // the graph optimizer (global CSE + contraction reassociation) runs
    // automatically inside eval_many; here is what it does to the joint
    // loss/gradient/Hessian DAG before compilation
    let stats = tensorcalc::opt::report(&g, &[f, grad, hess], OptLevel::Full);
    println!("optimizer: {}", stats);
    assert!(stats.nodes_after <= stats.nodes_before);
    assert!(stats.flops_after <= stats.flops_before);

    // evaluate everything on random data
    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[m, n], 1));
    env.insert("w", Tensor::randn(&[n], 2));
    let vals = eval_many(&g, &[f, grad, hess, hess_cc], &env);
    println!("\nf     = {:.6}", vals[0].item());
    println!("∇f    = {:?}", vals[1]);
    println!("H     = {:?}", vals[2]);
    assert!(vals[2].allclose(&vals[3], 1e-10, 1e-12), "modes must agree");
    println!("\nreverse and cross-country Hessians agree ✓");

    // forward mode gives the same Jacobians as reverse mode
    let jac_fwd = forward_derivative(&mut g, grad, w);
    let hf = eval(&g, jac_fwd, &env);
    assert!(hf.allclose(&vals[2], 1e-10, 1e-12));
    println!("forward-over-reverse agrees with reverse-over-reverse ✓");
}
