//! The §3.3 compression showcase: alternating Newton steps for matrix
//! factorization where the Hessian is solved in its *compressed*
//! representation — a k×k core instead of an (nk)×(nk) system.
//!
//! Run: `cargo run --release --example matrix_factorization`

use std::time::Instant;
use tensorcalc::eval::eval_many;
use tensorcalc::problems::{
    matrix_factorization, newton_step_compressed, newton_step_full,
};
use tensorcalc::util::fmt_secs;

fn main() {
    let (n, k) = (200usize, 10usize);
    let mut w = matrix_factorization(n, n, k, false);

    // symbolic gradient + compressed Hessian (derived once)
    let comp = w.hessian_compressed();
    assert!(comp.is_compressed(), "matfac Hessian must compress");
    println!(
        "Hessian compressed: {:?} core instead of {}⁴-ish tensor (ratio {:.2e})",
        w.g.shape(comp.eval_node()),
        n,
        comp.compression_ratio(&w.g)
    );
    let core_node = comp.eval_node();
    let grad_node = w.gradient();

    // one Newton step solves the quadratic subproblem in U exactly
    let vals = eval_many(&w.g, &[w.loss, core_node, grad_node], &w.env);
    let (loss0, core, grad) = (vals[0].item(), vals[1].clone(), vals[2].clone());
    println!("\ninitial loss: {:.4}", loss0);

    let t0 = Instant::now();
    let step_fast = newton_step_compressed(&core, &grad).expect("core SPD");
    let t_fast = t0.elapsed().as_secs_f64();

    let h_full = comp.materialize(&core);
    let t0 = Instant::now();
    let step_slow = newton_step_full(&h_full, &grad).expect("full solve");
    let t_slow = t0.elapsed().as_secs_f64();

    println!(
        "compressed Newton solve: {}   (O(k³ + nk²), k={})",
        fmt_secs(t_fast),
        k
    );
    println!("full Newton solve:       {}   (O((nk)³))", fmt_secs(t_slow));
    println!("speedup: {:.0}× — the paper's '10 µs vs 1 s' effect", t_slow / t_fast);
    assert!(
        step_fast.allclose(&step_slow, 1e-6, 1e-7),
        "both solves must agree, diff {}",
        step_fast.max_abs_diff(&step_slow)
    );

    // apply the step: U ← U − ΔU, loss must drop to the V-conditional optimum
    let u_new = w.env.get("U").unwrap().sub(&step_fast);
    w.env.insert("U", u_new);
    let vals = eval_many(&w.g, &[w.loss, grad_node], &w.env);
    println!(
        "\nafter one compressed Newton step: loss {:.4} → {:.4}, ‖grad_U‖ = {:.2e}",
        loss0,
        vals[0].item(),
        vals[1].norm()
    );
    assert!(vals[1].norm() < 1e-6, "quadratic-in-U objective solved exactly");
}
