//! Figure 2 reproduction: function value + gradient evaluation times on
//! the CPU for logistic regression, matrix factorization and the
//! 10-layer neural net. The paper's point for this figure is a *tie*:
//! every framework computes scalar-output gradients the same way, and so
//! do we — the series should be flat across modes and scale with the
//! problem size only.
//!
//! Run: `cargo bench --bench fig2_gradients [-- --sizes 16,32 --secs 0.1]`

use tensorcalc::figures::{fig2, print_table};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let sizes = parse_sizes(&args).unwrap_or_else(|| vec![16, 32, 64, 128, 256]);
    let secs = parse_secs(&args).unwrap_or(0.3);
    let rows = fig2(&["logreg", "matfac", "mlp"], &sizes, secs);
    print_table("Figure 2 — function value + gradient (CPU)", &rows);
}

fn parse_sizes(args: &[String]) -> Option<Vec<usize>> {
    let i = args.iter().position(|a| a == "--sizes")?;
    Some(args.get(i + 1)?.split(',').map(|s| s.parse().unwrap()).collect())
}

fn parse_secs(args: &[String]) -> Option<f64> {
    let i = args.iter().position(|a| a == "--secs")?;
    args.get(i + 1)?.parse().ok()
}
