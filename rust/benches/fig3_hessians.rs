//! Figure 3 reproduction (CPU row): Hessian evaluation times per mode —
//! the paper's headline result. Expected shape:
//!
//! * `framework(per-entry×N)` grows ~N× faster than `ours(reverse)`
//!   (the 2–3 orders-of-magnitude gap of the paper at its sizes),
//! * `ours(cross-country)` shaves ~30 % off logreg,
//! * `ours(compressed)` wins big on matfac (k×k core) and the MLP,
//! * the PJRT rows give the real-JAX comparator at the AOT shapes.
//!
//! The GPU row of Figure 3 is out of scope on this testbed (documented in
//! EXPERIMENTS.md).
//!
//! Run: `cargo bench --bench fig3_hessians [-- --sizes 8,16,32 --secs 0.2 --no-baseline]`

use tensorcalc::figures::{fig3, print_table, speedup};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let sizes = parse_sizes(&args).unwrap_or_else(|| vec![8, 16, 32, 64]);
    let secs = parse_secs(&args).unwrap_or(0.3);
    let with_baseline = !args.iter().any(|a| a == "--no-baseline");
    let rows = fig3(&["logreg", "matfac", "mlp"], &sizes, secs, with_baseline);
    print_table("Figure 3 — Hessian (CPU)", &rows);

    if with_baseline {
        println!("\nspeedup of ours(reverse) over framework(per-entry) — the Figure 3 gap:");
        for (p, n, s) in speedup(&rows, "framework", "ours(reverse)") {
            println!("  {:<8} n={:<5} {:>8.1}×", p, n, s);
        }
    }
    println!("\nspeedup of ours(cross-country) over ours(reverse):");
    for (p, n, s) in speedup(&rows, "ours(reverse)", "ours(cross-country)") {
        println!("  {:<8} n={:<5} {:>8.2}×", p, n, s);
    }
    println!("\nspeedup of ours(compressed) over ours(reverse):");
    for (p, n, s) in speedup(&rows, "ours(reverse)", "ours(compressed") {
        println!("  {:<8} n={:<5} {:>8.1}×", p, n, s);
    }
}

fn parse_sizes(args: &[String]) -> Option<Vec<usize>> {
    let i = args.iter().position(|a| a == "--sizes")?;
    Some(args.get(i + 1)?.split(',').map(|s| s.parse().unwrap()).collect())
}

fn parse_secs(args: &[String]) -> Option<f64> {
    let i = args.iter().position(|a| a == "--secs")?;
    args.get(i + 1)?.parse().ok()
}
