//! Open-loop load bench for the coordinator's dynamic-batching serving
//! path.
//!
//! Two kinds of cell:
//!
//! * `sweep` — a single submitter fires requests at a fixed *offered*
//!   rate against a logistic-regression gradient entry, twice per rate:
//!   once with the default dynamic batch cap and once with
//!   `max_batch = 1` (the ablation baseline, batching off).
//! * `overload` — the robustness row: offered rate far beyond capacity,
//!   a small queue under `ShedPolicy::ShedOldest`, and a per-request
//!   deadline. What matters here is *goodput* (achieved/s counts only
//!   requests answered `Ok`), the shed/expired split, and the p99 of
//!   the admitted-and-served requests.
//!
//! Latency is measured from each request's **scheduled** send time, not
//! from when `submit` returned — the open-loop discipline that makes
//! queueing delay under saturation visible instead of silently eliding
//! it (coordinated omission).
//!
//! Run: `cargo bench --bench serve_load`
//!
//! `BENCH_SECS=<secs>` sets the duration of each cell (default 0.3;
//! CI's bench-smoke job uses a small value) and `BENCH_JSON=<path>`
//! records every row — the hook `scripts/bench_serve.sh` uses to write
//! `BENCH_serve.json`.

use std::sync::mpsc::TryRecvError;
use std::time::{Duration, Instant};
use tensorcalc::coordinator::{
    Coordinator, EngineEntry, Request, ShedPolicy, DEFAULT_MAX_BATCH,
};
use tensorcalc::problems::logistic_regression;
use tensorcalc::tensor::Tensor;
use tensorcalc::util::fmt_secs;

struct LoadRow {
    cell: &'static str,
    max_batch: usize,
    offered_rps: f64,
    achieved_rps: f64,
    p50: f64,
    p99: f64,
    sent: usize,
    dropped: usize,
    shed: u64,
    expired: u64,
    /// per-request deadline budget; 0 = no deadline
    deadline_ms: u64,
}

/// One cell's knobs beyond (cap, rate): the robustness axis.
struct CellCfg {
    cell: &'static str,
    queue_cap: usize,
    policy: ShedPolicy,
    deadline_ms: u64,
}

impl CellCfg {
    fn sweep() -> Self {
        CellCfg { cell: "sweep", queue_cap: 4096, policy: ShedPolicy::Reject, deadline_ms: 0 }
    }

    fn overload() -> Self {
        CellCfg {
            cell: "overload",
            queue_cap: 256,
            policy: ShedPolicy::ShedOldest,
            deadline_ms: 50,
        }
    }
}

/// Nearest-rank percentile of an already-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn run_load(cfg: &CellCfg, max_batch: usize, offered_rps: f64, secs: f64) -> LoadRow {
    let (m, n) = (64usize, 16usize);
    let mut wl = logistic_regression(m, n);
    let grad = wl.gradient();
    let roots = [wl.loss, grad];
    let mut c = Coordinator::new(cfg.queue_cap);
    c.register_engine(
        "grad",
        EngineEntry::compiled(
            &wl.g,
            &roots,
            vec![
                ("X".into(), vec![m, n]),
                ("y".into(), vec![m]),
                ("w".into(), vec![n]),
            ],
        )
        .with_max_batch(max_batch)
        .with_shed_policy(cfg.policy),
    );

    let x = Tensor::randn(&[m, n], 11);
    let y = Tensor::randn(&[m], 12).map(f64::signum);
    let wv = Tensor::randn(&[n], 13).scale(0.1);

    let total = (offered_rps * secs).ceil() as usize;
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::with_capacity(total);
    let mut pending: Vec<(Instant, std::sync::mpsc::Receiver<_>)> = Vec::new();
    let mut sent = 0usize;
    let mut dropped = 0usize;
    for i in 0..total {
        let due = t0 + Duration::from_secs_f64(i as f64 / offered_rps);
        while Instant::now() < due {
            std::hint::spin_loop();
        }
        let inputs = vec![x.clone(), y.clone(), wv.clone()];
        let req = if cfg.deadline_ms > 0 {
            Request::new(inputs).with_deadline(Duration::from_millis(cfg.deadline_ms))
        } else {
            Request::new(inputs)
        };
        match c.submit_with("grad", req) {
            Ok(rx) => {
                sent += 1;
                pending.push((due, rx));
            }
            // backpressure (queue full / expired at admission): an
            // open-loop generator drops the request and keeps its
            // schedule
            Err(_) => dropped += 1,
        }
        // reap finished responses without blocking the send schedule;
        // only `Ok` answers count toward goodput and the latency sample
        pending.retain(|(due, rx)| match rx.try_recv() {
            Ok(Ok(_)) => {
                lat.push(due.elapsed().as_secs_f64());
                false
            }
            Ok(Err(_)) | Err(TryRecvError::Disconnected) => {
                dropped += 1;
                false
            }
            Err(TryRecvError::Empty) => true,
        });
    }
    for (due, rx) in pending {
        match rx.recv() {
            Ok(Ok(_)) => lat.push(due.elapsed().as_secs_f64()),
            _ => dropped += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    c.shutdown();
    let snap = c.metrics().snapshot();

    lat.sort_by(f64::total_cmp);
    LoadRow {
        cell: cfg.cell,
        max_batch,
        offered_rps,
        achieved_rps: lat.len() as f64 / wall,
        p50: percentile(&lat, 0.5),
        p99: percentile(&lat, 0.99),
        sent,
        dropped,
        shed: snap.shed,
        expired: snap.expired + snap.rejected_expired,
        deadline_ms: cfg.deadline_ms,
    }
}

fn rows_to_json(rows: &[LoadRow]) -> String {
    let mut out =
        String::from("{\n  \"schema\": \"tensorcalc-serve-load/v2\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"entry\": \"logreg_grad\", \"cell\": \"{}\", \"max_batch\": {}, \
             \"offered_rps\": {}, \"achieved_rps\": {:.1}, \"p50_secs\": {:e}, \
             \"p99_secs\": {:e}, \"sent\": {}, \"dropped\": {}, \"shed\": {}, \
             \"expired\": {}, \"deadline_ms\": {}}}{}\n",
            r.cell,
            r.max_batch,
            r.offered_rps,
            r.achieved_rps,
            r.p50,
            r.p99,
            r.sent,
            r.dropped,
            r.shed,
            r.expired,
            r.deadline_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let secs: f64 = std::env::var("BENCH_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.3);

    let mut rows = Vec::new();
    let sweep = CellCfg::sweep();
    for &rate in &[1000.0f64, 4000.0, 16000.0] {
        for &cap in &[DEFAULT_MAX_BATCH, 1] {
            rows.push(run_load(&sweep, cap, rate, secs));
        }
    }
    // the robustness row: offered load far beyond capacity, small queue,
    // shed-oldest, 50ms deadlines — goodput + shed/expired split
    rows.push(run_load(&CellCfg::overload(), DEFAULT_MAX_BATCH, 32000.0, secs));

    println!(
        "\n== serve_load — logreg grad (64×16), open loop, {}s per cell ==",
        secs
    );
    println!(
        "{:>9} {:>9} {:>10} {:>13} {:>10} {:>10} {:>7} {:>8} {:>6} {:>8}",
        "cell", "batch", "offered/s", "goodput/s", "p50", "p99", "sent", "dropped", "shed", "expired"
    );
    for r in &rows {
        println!(
            "{:>9} {:>9} {:>10.0} {:>13.0} {:>10} {:>10} {:>7} {:>8} {:>6} {:>8}",
            r.cell,
            if r.max_batch == 1 { "off".to_string() } else { format!("≤{}", r.max_batch) },
            r.offered_rps,
            r.achieved_rps,
            fmt_secs(r.p50).trim(),
            fmt_secs(r.p99).trim(),
            r.sent,
            r.dropped,
            r.shed,
            r.expired
        );
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        if !path.is_empty() {
            match std::fs::write(&path, rows_to_json(&rows)) {
                Ok(()) => println!("\nwrote {} serve-load rows to {}", rows.len(), path),
                Err(e) => eprintln!("BENCH_JSON: failed to write {}: {}", path, e),
            }
        }
    }
}
