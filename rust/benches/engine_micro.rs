//! Microbenchmarks of the evaluation engine hot paths (einsum → GEMM,
//! plus the compiled executor): used by the §Perf pass to find and
//! verify bottleneck fixes.
//!
//! Run: `cargo bench --bench engine_micro`

use tensorcalc::einsum::{einsum, gemm_into, gemm_into_flat, EinScratch, EinSpec, EinsumPlan};
use tensorcalc::exec::CompiledPlan;
use tensorcalc::figures::{print_table, Row};
use tensorcalc::problems::logistic_regression;
use tensorcalc::tensor::Tensor;
use tensorcalc::util::{fmt_secs, time_median};

fn main() {
    let secs = 0.3;
    let mut rows: Vec<Row> = Vec::new();

    // raw GEMM roofline probe: the tiled default kernel vs the flat
    // pre-tiling reference it replaced (one reused, re-zeroed output
    // buffer on both sides so only the kernels differ)
    for &n in &[128usize, 256, 512, 1024] {
        let a = Tensor::randn(&[n, n], 1);
        let b = Tensor::randn(&[n, n], 2);
        let mut c = vec![0.0; n * n];
        let (t, runs) = time_median(
            || {
                c.fill(0.0);
                gemm_into(a.data(), b.data(), &mut c, n, n, n);
                std::hint::black_box(&c);
            },
            3,
            secs,
        );
        let gflops = 2.0 * (n as f64).powi(3) / t / 1e9;
        println!("gemm(tiled) {0}×{0}×{0}: {1} ({2:.2} GFLOP/s)", n, fmt_secs(t), gflops);
        rows.push(Row { figure: "micro", problem: "gemm-tiled", n, mode: format!("{:.2} GFLOP/s", gflops), secs: t, runs });

        let (tf, runs_f) = time_median(
            || {
                c.fill(0.0);
                gemm_into_flat(a.data(), b.data(), &mut c, n, n, n);
                std::hint::black_box(&c);
            },
            3,
            secs,
        );
        let gflops_f = 2.0 * (n as f64).powi(3) / tf / 1e9;
        println!(
            "gemm(flat)  {0}×{0}×{0}: {1} ({2:.2} GFLOP/s, tiled is {3:+.0}%)",
            n,
            fmt_secs(tf),
            gflops_f,
            100.0 * (tf - t) / tf
        );
        rows.push(Row { figure: "micro", problem: "gemm-flat", n, mode: format!("{:.2} GFLOP/s", gflops_f), secs: tf, runs: runs_f });
    }

    // einsum shapes that dominate the derivative DAGs
    let cases: Vec<(&str, Vec<usize>, Vec<usize>)> = vec![
        ("ij,jk->ik", vec![256, 256], vec![256, 256]), // matmul
        ("ji,jk->ik", vec![512, 256], vec![512, 256]), // XᵀX-style
        ("ij,i->ij", vec![512, 256], vec![512]),       // diag-scale
        ("ij,j->i", vec![512, 512], vec![512]),        // matvec
        ("i,j->ij", vec![512], vec![512]),             // outer
        ("ij,ij->", vec![512, 512], vec![512, 512]),   // full contraction
        ("jl,ik->ijkl", vec![8, 8], vec![32, 32]),     // delta expansion
        ("aij,ajk->aik", vec![64, 16, 16], vec![64, 16, 16]), // batched
    ];
    for (sig, sa, sb) in cases {
        let spec = EinSpec::parse(sig);
        let a = Tensor::randn(&sa, 3);
        let b = Tensor::randn(&sb, 4);
        let (t, runs) = time_median(
            || {
                std::hint::black_box(einsum(&spec, &a, &b));
            },
            3,
            secs,
        );
        println!("einsum {:<14} {:?}×{:?}: {}", sig, sa, sb, fmt_secs(t));
        rows.push(Row { figure: "micro", problem: "einsum", n: sa.iter().product(), mode: sig.into(), secs: t, runs });

        // the write-into path: pre-compiled plan, reused scratch + output
        let plan = EinsumPlan::new(&spec, &sa, &sb);
        let mut scratch = EinScratch::default();
        let mut out = Tensor::zeros(plan.out_shape());
        let (t2, runs2) = time_median(
            || {
                plan.run(&a, &b, &mut out, &mut scratch);
                std::hint::black_box(&out);
            },
            3,
            secs,
        );
        println!(
            "  einsum_into {:<9} {:?}×{:?}: {}  ({:+.0}% vs interpreter)",
            sig,
            sa,
            sb,
            fmt_secs(t2),
            100.0 * (t2 - t) / t
        );
        rows.push(Row {
            figure: "micro",
            problem: "einsum_into",
            n: sa.iter().product(),
            mode: sig.into(),
            secs: t2,
            runs: runs2,
        });
    }

    // compiled executor on a whole derivative DAG: the repeated-request
    // hot path, with the fusion + work-stealing executor against the
    // PR 1-style unfused plan. After the warm-up run the buffer pool
    // must serve every intermediate (fresh allocations ≈ one root
    // buffer per run), and the fused plan must allocate strictly fewer
    // cold buffers.
    {
        let (m, n) = (256usize, 128usize);
        let mut w = logistic_regression(m, n);
        let grad = w.gradient();
        let fused = CompiledPlan::new(&w.g, &[w.loss, grad]);
        let unfused = CompiledPlan::with_fusion(&w.g, &[w.loss, grad], false);
        let mut stats: Vec<(u64, f64)> = Vec::new();
        for (label, plan) in [("fused", &fused), ("unfused (PR 1)", &unfused)] {
            let _ = plan.run(&w.env); // warm-up
            let cold = plan.pool_stats();
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&w.env));
                },
                5,
                secs,
            );
            let after = plan.pool_stats();
            println!(
                "\ncompiled logreg grad [{}] (m={}, n={}): {}  [{} instrs, {} levels, {} fused]",
                label,
                m,
                n,
                fmt_secs(t),
                plan.len(),
                plan.depth(),
                plan.fused_count()
            );
            println!(
                "  buffer pool: fresh {} → {} (+{} over {} runs ≈ roots only), reused {}",
                cold.fresh,
                after.fresh,
                after.fresh - cold.fresh,
                runs,
                after.reused
            );
            rows.push(Row {
                figure: "micro",
                problem: "compiled",
                n,
                mode: format!("logreg grad {}", label),
                secs: t,
                runs,
            });
            stats.push((cold.fresh, t));
        }
        println!(
            "\n  fused vs unfused: cold allocations {} vs {}, wall-clock {:+.1}%",
            stats[0].0,
            stats[1].0,
            100.0 * (stats[0].1 - stats[1].1) / stats[1].1
        );
    }

    print_table("engine microbenchmarks", &rows);
}
