//! Microbenchmarks of the evaluation engine hot paths (einsum → GEMM,
//! plus the compiled executor): used by the §Perf pass to find and
//! verify bottleneck fixes.
//!
//! Run: `cargo bench --bench engine_micro`

use tensorcalc::einsum::{einsum, gemm_into, gemm_into_flat, EinScratch, EinSpec, EinsumPlan};
use tensorcalc::exec::{BackendKind, CompiledPlan, EpilogueMode, ExecMemory};
use tensorcalc::figures::{print_table, Row};
use tensorcalc::problems::logistic_regression;
use tensorcalc::tensor::Tensor;
use tensorcalc::util::{fmt_secs, time_median};

fn main() {
    let secs = 0.3;
    let mut rows: Vec<Row> = Vec::new();

    // raw GEMM roofline probe: the tiled default kernel vs the flat
    // pre-tiling reference it replaced (one reused, re-zeroed output
    // buffer on both sides so only the kernels differ)
    for &n in &[128usize, 256, 512, 1024] {
        let a = Tensor::randn(&[n, n], 1);
        let b = Tensor::randn(&[n, n], 2);
        let mut c = vec![0.0; n * n];
        let (t, runs) = time_median(
            || {
                c.fill(0.0);
                gemm_into(a.data(), b.data(), &mut c, n, n, n);
                std::hint::black_box(&c);
            },
            3,
            secs,
        );
        let gflops = 2.0 * (n as f64).powi(3) / t / 1e9;
        println!("gemm(tiled) {0}×{0}×{0}: {1} ({2:.2} GFLOP/s)", n, fmt_secs(t), gflops);
        rows.push(Row { figure: "micro", problem: "gemm-tiled", n, mode: format!("{:.2} GFLOP/s", gflops), secs: t, runs });

        let (tf, runs_f) = time_median(
            || {
                c.fill(0.0);
                gemm_into_flat(a.data(), b.data(), &mut c, n, n, n);
                std::hint::black_box(&c);
            },
            3,
            secs,
        );
        let gflops_f = 2.0 * (n as f64).powi(3) / tf / 1e9;
        println!(
            "gemm(flat)  {0}×{0}×{0}: {1} ({2:.2} GFLOP/s, tiled is {3:+.0}%)",
            n,
            fmt_secs(tf),
            gflops_f,
            100.0 * (tf - t) / tf
        );
        rows.push(Row { figure: "micro", problem: "gemm-flat", n, mode: format!("{:.2} GFLOP/s", gflops_f), secs: tf, runs: runs_f });
    }

    // einsum shapes that dominate the derivative DAGs
    let cases: Vec<(&str, Vec<usize>, Vec<usize>)> = vec![
        ("ij,jk->ik", vec![256, 256], vec![256, 256]), // matmul
        ("ji,jk->ik", vec![512, 256], vec![512, 256]), // XᵀX-style
        ("ij,i->ij", vec![512, 256], vec![512]),       // diag-scale
        ("ij,j->i", vec![512, 512], vec![512]),        // matvec
        ("i,j->ij", vec![512], vec![512]),             // outer
        ("ij,ij->", vec![512, 512], vec![512, 512]),   // full contraction
        ("jl,ik->ijkl", vec![8, 8], vec![32, 32]),     // delta expansion
        ("aij,ajk->aik", vec![64, 16, 16], vec![64, 16, 16]), // batched
    ];
    for (sig, sa, sb) in cases {
        let spec = EinSpec::parse(sig);
        let a = Tensor::randn(&sa, 3);
        let b = Tensor::randn(&sb, 4);
        let (t, runs) = time_median(
            || {
                std::hint::black_box(einsum(&spec, &a, &b));
            },
            3,
            secs,
        );
        println!("einsum {:<14} {:?}×{:?}: {}", sig, sa, sb, fmt_secs(t));
        rows.push(Row { figure: "micro", problem: "einsum", n: sa.iter().product(), mode: sig.into(), secs: t, runs });

        // the write-into path: pre-compiled plan, reused scratch + output
        let plan = EinsumPlan::new(&spec, &sa, &sb);
        let mut scratch = EinScratch::default();
        let mut out = Tensor::zeros(plan.out_shape());
        let (t2, runs2) = time_median(
            || {
                plan.run(&a, &b, &mut out, &mut scratch);
                std::hint::black_box(&out);
            },
            3,
            secs,
        );
        println!(
            "  einsum_into {:<9} {:?}×{:?}: {}  ({:+.0}% vs interpreter)",
            sig,
            sa,
            sb,
            fmt_secs(t2),
            100.0 * (t2 - t) / t
        );
        rows.push(Row {
            figure: "micro",
            problem: "einsum_into",
            n: sa.iter().product(),
            mode: sig.into(),
            secs: t2,
            runs: runs2,
        });
    }

    // compiled executor on a whole derivative DAG: the repeated-request
    // hot path across the memory/backend ablation — the planned arena
    // (fixed offsets, persistent workers, zero steady-state allocation),
    // the PR 1 pooled mode, the pooled+unfused PR 1 lowering, and the
    // direct-threaded backend over the same planned arena.
    {
        let (m, n) = (256usize, 128usize);
        let mut w = logistic_regression(m, n);
        let grad = w.gradient();
        let modes: [(&str, ExecMemory, bool, BackendKind); 4] = [
            ("planned", ExecMemory::Planned, true, BackendKind::Cpu),
            ("pooled", ExecMemory::Pooled, true, BackendKind::Cpu),
            ("pooled unfused (PR 1)", ExecMemory::Pooled, false, BackendKind::Cpu),
            ("direct-threaded", ExecMemory::Planned, true, BackendKind::Direct),
        ];
        let mut timed: Vec<f64> = Vec::new();
        for (label, memory, fuse, backend) in modes {
            let plan = CompiledPlan::with_options(
                &w.g,
                &[w.loss, grad],
                fuse,
                EpilogueMode::default(),
                memory,
                backend,
                tensorcalc::obs::TraceMode::Off,
            );
            let _ = plan.run(&w.env); // warm-up
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&w.env));
                },
                5,
                secs,
            );
            println!(
                "\ncompiled logreg grad [{}] (m={}, n={}): {}  [{} instrs, {} levels, {} fused]",
                label,
                m,
                n,
                fmt_secs(t),
                plan.len(),
                plan.depth(),
                plan.fused_count()
            );
            println!("  memory: {}", plan.pool_stats());
            rows.push(Row {
                figure: "micro",
                problem: "compiled",
                n,
                mode: format!("logreg grad {}", label),
                secs: t,
                runs,
            });
            timed.push(t);
        }
        println!(
            "\n  planned vs pooled wall-clock {:+.1}%, fused vs unfused {:+.1}%, direct vs level-parallel {:+.1}%",
            100.0 * (timed[0] - timed[1]) / timed[1],
            100.0 * (timed[1] - timed[2]) / timed[2],
            100.0 * (timed[3] - timed[0]) / timed[0]
        );
    }

    print_table("engine microbenchmarks", &rows);
}
