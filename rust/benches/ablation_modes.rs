//! Ablation benches for the two §3.3 design choices:
//!
//! * **newton** — compressed vs full Newton system on matrix
//!   factorization (the paper's "10 µs vs 1 s at n=1000, k=10" claim,
//!   scaled to this testbed),
//! * **cc** — cross-country vs reverse association on the Example-7
//!   chain `B·diag(u)·diag(v)·A` in isolation,
//! * **compress** — evaluating the matfac Hessian core vs materialising
//!   the order-4 tensor,
//!
//! plus the exec-layer ablations:
//!
//! * **gemm** — the tiled/packed kernel vs the flat pre-tiling kernel on
//!   epilogue-free contractions (tiling must not regress these),
//! * **simd** — the runtime-dispatched SIMD register microkernel vs the
//!   forced-scalar tier (`TC_SIMD=off`) on the same tiled path: the
//!   two are bit-identical by contract, so the rows measure pure
//!   codegen speedup,
//! * **epilogue** — fused chains riding on a contraction:
//!   `EpilogueMode::InTile` (applied inside the GEMM tiles, no second
//!   output sweep) vs `EpilogueMode::TwoPass` vs the unfused executor,
//! * **memory** — `ExecMemory::Planned` (buffer lifetimes compiled to
//!   arena offsets, persistent workers, no per-instruction lock) vs
//!   `ExecMemory::Pooled` (the PR 1 mutex-guarded buffer pool),
//! * **backend** — `BackendKind::Cpu` (the work-stealing level-parallel
//!   executor) vs `BackendKind::Direct` (the direct-threaded closure
//!   chain) over the same lowered streams,
//! * **trace** — `TraceMode::Off` (the default; a dead branch per
//!   instruction) vs `TraceMode::Profile` (per-instruction spans into
//!   per-lane ring buffers): the price of the observability layer.
//!
//! Run: `cargo bench --bench ablation_modes`
//!
//! Set `BENCH_JSON=<path>` to also record every row as JSON — the
//! perf-trajectory hook `scripts/bench_baseline.sh` uses to write
//! `BENCH_exec.json` — and `BENCH_SECS=<secs>` to override the
//! per-measurement budget (CI's bench-smoke job uses a small value).

use tensorcalc::autodiff::cross_country::optimize_contractions;
use tensorcalc::einsum::{gemm_into, gemm_into_flat};
use tensorcalc::eval::Env;
use tensorcalc::exec::{BackendKind, CompiledPlan, EpilogueMode, ExecMemory};
use tensorcalc::figures::{maybe_write_bench_json, newton, print_table, Row};
use tensorcalc::ir::{Elem, Graph};
use tensorcalc::obs::TraceMode;
use tensorcalc::opt::{optimize, OptLevel};
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::tensor::Tensor;
use tensorcalc::util::time_median;

fn main() {
    let secs: f64 = std::env::var("BENCH_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.3);
    let mut all_rows: Vec<Row> = Vec::new();

    // ---- newton: §3.3 in-text claim ----
    let rows = newton(&[20, 50, 100, 200], 10, secs);
    print_table("§3.3 — compressed vs full Newton system (matfac, k=10)", &rows);
    all_rows.extend(rows.iter().cloned());
    for n in [20usize, 50, 100, 200] {
        let fast = rows.iter().find(|r| r.n == n && r.mode.starts_with("compressed"));
        let slow = rows.iter().find(|r| r.n == n && r.mode.starts_with("full"));
        if let (Some(f), Some(s)) = (fast, slow) {
            println!("  n={:<5} compressed is {:>10.0}× faster", n, s.secs / f.secs);
        }
    }

    // ---- cc: Example 7 chain ----
    let mut rows = Vec::new();
    for &n in &[64usize, 128, 256, 512] {
        let m = n;
        let build = |cc: bool| -> (Graph, tensorcalc::ir::NodeId, Env) {
            let mut g = Graph::new();
            let b = g.var("B", &[m, n]);
            let a = g.var("A", &[n, m]);
            let u = g.var("u", &[n]);
            let v = g.var("v", &[n]);
            // ((B·diag(u))·diag(v))·A — reverse-mode association
            let bu = g.coldiag(b, u);
            let buv = g.coldiag(bu, v);
            let full = g.matmul(buv, a);
            let expr = if cc { optimize_contractions(&mut g, full) } else { full };
            let mut env = Env::new();
            env.insert("B", Tensor::randn(&[m, n], 1));
            env.insert("A", Tensor::randn(&[n, m], 2));
            env.insert("u", Tensor::randn(&[n], 3));
            env.insert("v", Tensor::randn(&[n], 4));
            (g, expr, env)
        };
        for (label, cc) in [("reverse-order", false), ("cross-country", true)] {
            let (g, node, env) = build(cc);
            let plan = CompiledPlan::new(&g, &[node]);
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            rows.push(Row { figure: "cc", problem: "example7", n, mode: label.into(), secs: t, runs });
        }
    }
    print_table("Cross-country ablation — Example 7 chain B·diag(u)·diag(v)·A", &rows);
    all_rows.extend(rows.iter().cloned());

    // ---- gemm: tiled/packed kernel vs the flat pre-tiling kernel ----
    // epilogue-free contractions: tiling must win (or at least not
    // regress) without any fused chain riding on the output. Both sides
    // reuse one re-zeroed output buffer so only the kernels differ.
    let mut rows = Vec::new();
    for &n in &[128usize, 256, 512] {
        let a = Tensor::randn(&[n, n], 11);
        let b = Tensor::randn(&[n, n], 12);
        let mut c = vec![0.0; n * n];
        let (t, runs) = time_median(
            || {
                c.fill(0.0);
                gemm_into(a.data(), b.data(), &mut c, n, n, n);
                std::hint::black_box(&c);
            },
            3,
            secs,
        );
        rows.push(Row { figure: "gemm", problem: "matmul", n, mode: "tiled (default)".into(), secs: t, runs });
        let (t, runs) = time_median(
            || {
                c.fill(0.0);
                gemm_into_flat(a.data(), b.data(), &mut c, n, n, n);
                std::hint::black_box(&c);
            },
            3,
            secs,
        );
        rows.push(Row { figure: "gemm", problem: "matmul", n, mode: "flat (pre-tiling)".into(), secs: t, runs });
    }
    print_table("GEMM kernel ablation — tiled/packed vs flat (epilogue-free)", &rows);
    all_rows.extend(rows.iter().cloned());

    // ---- simd: dispatched microkernel vs forced scalar ----
    // same tiled/packed path, same blocking; only the register
    // microkernel (and the fused-interpreter codegen tier) differs.
    // Scalar and SIMD are bit-identical by contract, asserted here on
    // live data before timing.
    let native = tensorcalc::util::simd::active_isa();
    let mut rows = Vec::new();
    for &n in &[128usize, 256, 512] {
        let a = Tensor::randn(&[n, n], 21);
        let b = Tensor::randn(&[n, n], 22);
        let mut c = vec![0.0; n * n];
        let mut outs: Vec<Vec<f64>> = Vec::new();
        for (label, isa) in [
            (format!("dispatched ({})", native.name()), native),
            ("forced scalar".to_string(), tensorcalc::util::simd::Isa::Scalar),
        ] {
            let prev = tensorcalc::util::simd::set_isa(isa);
            c.fill(0.0);
            gemm_into(a.data(), b.data(), &mut c, n, n, n);
            outs.push(c.clone());
            let (t, runs) = time_median(
                || {
                    c.fill(0.0);
                    gemm_into(a.data(), b.data(), &mut c, n, n, n);
                    std::hint::black_box(&c);
                },
                3,
                secs,
            );
            tensorcalc::util::simd::set_isa(prev);
            rows.push(Row { figure: "simd", problem: "matmul", n, mode: label, secs: t, runs });
        }
        assert_eq!(outs[0], outs[1], "scalar and {} GEMM diverged at n={}", native.name(), n);
    }
    print_table("SIMD ablation — dispatched microkernel vs forced scalar", &rows);
    for &n in &[128usize, 256, 512] {
        let simd = rows.iter().find(|r| r.n == n && r.mode.starts_with("dispatched"));
        let scal = rows.iter().find(|r| r.n == n && r.mode.starts_with("forced"));
        if let (Some(v), Some(s)) = (simd, scal) {
            println!("  n={:<5} {} is {:>6.2}× vs scalar", n, native.name(), s.secs / v.secs);
        }
    }
    all_rows.extend(rows.iter().cloned());

    // ---- epilogue: in-tile vs two-pass vs unfused on a GEMM-fed chain ----
    // tanh(X·W)+1 ⊙ (X·W): the chain melts into a contraction epilogue;
    // InTile applies it inside the GEMM tiles (no second output sweep),
    // TwoPass sweeps the finished output once more, unfused materialises
    // every chain node.
    let mut rows = Vec::new();
    for &n in &[256usize, 512, 1024] {
        let mut g = Graph::new();
        let x = g.var("X", &[n, n]);
        let w = g.var("W", &[n, n]);
        let xw = g.matmul(x, w);
        let t = g.elem(Elem::Tanh, xw);
        let one = g.constant(1.0, &[n, n]);
        let s = g.add(t, one);
        let y = g.hadamard(s, xw);
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[n, n], 13));
        env.insert("W", Tensor::randn(&[n, n], 14));
        for (label, fuse, mode) in [
            ("in-tile epilogue", true, EpilogueMode::InTile),
            ("two-pass epilogue", true, EpilogueMode::TwoPass),
            ("unfused", false, EpilogueMode::InTile),
        ] {
            let plan = CompiledPlan::with_options(
                &g,
                &[y],
                fuse,
                mode,
                ExecMemory::default(),
                BackendKind::default(),
                TraceMode::Off,
            );
            let _ = plan.run(&env); // warm-up
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            rows.push(Row { figure: "epilogue", problem: "gemm-chain", n, mode: label.into(), secs: t, runs });
        }
    }
    print_table("Epilogue ablation — fused chain on a contraction", &rows);
    for &n in &[256usize, 512, 1024] {
        let it = rows.iter().find(|r| r.n == n && r.mode.starts_with("in-tile"));
        let tp = rows.iter().find(|r| r.n == n && r.mode.starts_with("two-pass"));
        if let (Some(i), Some(t)) = (it, tp) {
            println!("  n={:<5} in-tile saves {:>6.1}% of the two-pass wall-clock", n, 100.0 * (t.secs - i.secs) / t.secs);
        }
    }
    all_rows.extend(rows.iter().cloned());

    // ---- fusion: element-wise chains fused vs one buffer per node ----
    let mut rows = Vec::new();
    for &n in &[1usize << 12, 1 << 16, 1 << 20] {
        let mut g = Graph::new();
        let x = g.var("x", &[n]);
        let mut v = g.elem(Elem::Tanh, x);
        for _ in 0..7 {
            v = g.elem(Elem::Sigmoid, v);
            v = g.elem(Elem::Tanh, v);
        }
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[n], 5));
        for (label, fuse) in [("fused single pass", true), ("per-node buffers", false)] {
            let plan = CompiledPlan::with_fusion(&g, &[v], fuse);
            let _ = plan.run(&env); // warm-up
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            rows.push(Row {
                figure: "fusion",
                problem: "elem-chain-15",
                n,
                mode: label.into(),
                secs: t,
                runs,
            });
        }
    }
    print_table("Fusion ablation — 15-deep element-wise chain", &rows);
    all_rows.extend(rows.iter().cloned());

    // ---- memory: planned arena vs PR 1 pooled buffers ----
    // the coordinator-shaped steady state: one compiled plan run
    // repeatedly. Planned compiles lifetimes to arena offsets (no
    // per-instruction mutex, no allocation after warm-up, persistent
    // workers); Pooled is the PR 1 bucket pool behind a mutex.
    const MEMORY_WORKLOADS: [(&str, usize); 3] =
        [("logreg-grad", 128), ("logreg-grad", 256), ("matfac-hess", 32)];
    let mut rows = Vec::new();
    for (p, n) in MEMORY_WORKLOADS {
        let (g, roots, env) = match p {
            "logreg-grad" => {
                let mut w = logistic_regression(2 * n, n);
                let grad = w.gradient();
                (w.g.clone(), vec![w.loss, grad], w.env.clone())
            }
            _ => {
                let mut w = matrix_factorization(n, n, 5, false);
                let h = w.hessian();
                (w.g.clone(), vec![h], w.env.clone())
            }
        };
        let mut g2 = g.clone();
        let o = optimize(&mut g2, &roots, OptLevel::Full);
        for (label, memory) in [
            ("planned arena", ExecMemory::Planned),
            ("pooled (PR 1)", ExecMemory::Pooled),
        ] {
            let plan = CompiledPlan::with_options(
                &g2,
                &o.roots,
                true,
                EpilogueMode::default(),
                memory,
                BackendKind::default(),
                TraceMode::Off,
            );
            let _ = plan.run(&env); // warm-up
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            println!("  memory[{:<14}] {:<12} n={:<4} {}", label, p, n, plan.pool_stats());
            rows.push(Row { figure: "memory", problem: p, n, mode: label.into(), secs: t, runs });
        }
    }
    print_table("Memory ablation — planned arena vs pooled buffers", &rows);
    all_rows.extend(rows.iter().cloned());
    for (p, n) in MEMORY_WORKLOADS {
        let pl = rows.iter().find(|r| r.problem == p && r.n == n && r.mode.starts_with("planned"));
        let po = rows.iter().find(|r| r.problem == p && r.n == n && r.mode.starts_with("pooled"));
        if let (Some(a), Some(b)) = (pl, po) {
            println!(
                "  {:<12} n={:<4} planned saves {:>6.1}% of the pooled wall-clock",
                p,
                n,
                100.0 * (b.secs - a.secs) / b.secs
            );
        }
    }

    // ---- backend: level-parallel work stealing vs direct-threaded ----
    // same lowered instruction streams, same planned arena; only the
    // executor differs. Cpu schedules each DAG level across the
    // persistent worker pool, Direct runs one pre-monomorphized closure
    // chain sequentially — the win is scheduling overhead on small/deep
    // graphs, the loss is level parallelism on wide ones. Backends are
    // bit-identical by contract (asserted here on live data).
    const BACKEND_WORKLOADS: [(&str, usize); 3] =
        [("logreg-grad", 128), ("logreg-grad", 256), ("matfac-hess", 32)];
    let mut rows = Vec::new();
    for (p, n) in BACKEND_WORKLOADS {
        let (g, roots, env) = match p {
            "logreg-grad" => {
                let mut w = logistic_regression(2 * n, n);
                let grad = w.gradient();
                (w.g.clone(), vec![w.loss, grad], w.env.clone())
            }
            _ => {
                let mut w = matrix_factorization(n, n, 5, false);
                let h = w.hessian();
                (w.g.clone(), vec![h], w.env.clone())
            }
        };
        let mut g2 = g.clone();
        let o = optimize(&mut g2, &roots, OptLevel::Full);
        let mut outs: Vec<Vec<Tensor>> = Vec::new();
        for (label, backend) in [
            ("cpu (level-parallel)", BackendKind::Cpu),
            ("direct-threaded", BackendKind::Direct),
        ] {
            let plan = CompiledPlan::with_options(
                &g2,
                &o.roots,
                true,
                EpilogueMode::default(),
                ExecMemory::default(),
                backend,
                TraceMode::Off,
            );
            outs.push(plan.run(&env)); // warm-up, kept for the identity check
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            rows.push(Row { figure: "backend", problem: p, n, mode: label.into(), secs: t, runs });
        }
        for (a, b) in outs[0].iter().zip(outs[1].iter()) {
            assert_eq!(a.data(), b.data(), "backends diverged on {} n={}", p, n);
        }
    }
    print_table("Backend ablation — work-stealing levels vs direct-threaded", &rows);
    all_rows.extend(rows.iter().cloned());
    for (p, n) in BACKEND_WORKLOADS {
        let cpu = rows.iter().find(|r| r.problem == p && r.n == n && r.mode.starts_with("cpu"));
        let dir = rows.iter().find(|r| r.problem == p && r.n == n && r.mode.starts_with("direct"));
        if let (Some(c), Some(d)) = (cpu, dir) {
            println!(
                "  {:<12} n={:<4} direct-threaded is {:+6.1}% vs level-parallel",
                p,
                n,
                100.0 * (d.secs - c.secs) / c.secs
            );
        }
    }

    // ---- opt: graph-optimizer ablation on the fig3 Hessian workloads ----
    // none = the raw Theorem-8/simplify output, cse = global CSE only,
    // cse+reassoc = the full pipeline eval_many/plan-cache run.
    let mut rows = Vec::new();
    for &(p, n) in &[("logreg", 32usize), ("logreg", 64), ("matfac", 32), ("mlp", 16)] {
        let mut w = match p {
            "logreg" => logistic_regression(2 * n, n),
            "matfac" => matrix_factorization(n, n, 5, false),
            _ => neural_net(n, 10, 2 * n),
        };
        let h = w.hessian();
        for (label, level) in [
            ("OptLevel::None", OptLevel::None),
            ("cse", OptLevel::Cse),
            ("cse+reassoc", OptLevel::Full),
        ] {
            let mut g2 = w.g.clone();
            let o = optimize(&mut g2, &[h], level);
            let plan = CompiledPlan::new(&g2, &o.roots);
            let _ = plan.run(&w.env); // warm-up
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&w.env));
                },
                3,
                secs,
            );
            println!("  opt[{:<15}] {:<8} n={:<4} {}", label, p, n, o.stats);
            rows.push(Row { figure: "opt", problem: p, n, mode: label.into(), secs: t, runs });
        }
    }
    print_table("Optimizer ablation — Hessians, none vs CSE vs CSE+reassoc", &rows);
    all_rows.extend(rows.iter().cloned());
    for &(p, n) in &[("logreg", 32usize), ("logreg", 64), ("matfac", 32), ("mlp", 16)] {
        let base = rows
            .iter()
            .find(|r| r.problem == p && r.n == n && r.mode.starts_with("OptLevel::None"));
        let full = rows
            .iter()
            .find(|r| r.problem == p && r.n == n && r.mode == "cse+reassoc");
        if let (Some(b), Some(f)) = (base, full) {
            println!("  {:<8} n={:<4} cse+reassoc is {:>6.2}× vs OptLevel::None", p, n, b.secs / f.secs);
        }
    }

    // ---- trace: observability overhead, Off vs Profile ----
    // same plan options either side, only TraceMode differs. Off must
    // cost nothing beyond a dead branch (it is the steady-state serving
    // configuration); Profile quantifies what `derive --trace` pays.
    // Outputs are asserted bit-identical — tracing is read-only.
    const TRACE_WORKLOADS: [(&str, usize); 2] = [("logreg-grad", 128), ("matfac-hess", 32)];
    let mut rows = Vec::new();
    for (p, n) in TRACE_WORKLOADS {
        let (g, roots, env) = match p {
            "logreg-grad" => {
                let mut w = logistic_regression(2 * n, n);
                let grad = w.gradient();
                (w.g.clone(), vec![w.loss, grad], w.env.clone())
            }
            _ => {
                let mut w = matrix_factorization(n, n, 5, false);
                let h = w.hessian();
                (w.g.clone(), vec![h], w.env.clone())
            }
        };
        let mut g2 = g.clone();
        let o = optimize(&mut g2, &roots, OptLevel::Full);
        let mut outs: Vec<Vec<Tensor>> = Vec::new();
        for (label, trace) in [("off (default)", TraceMode::Off), ("profile", TraceMode::Profile)]
        {
            let plan = CompiledPlan::with_options(
                &g2,
                &o.roots,
                true,
                EpilogueMode::default(),
                ExecMemory::default(),
                BackendKind::default(),
                trace,
            );
            outs.push(plan.run(&env)); // warm-up, kept for the identity check
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            rows.push(Row { figure: "trace", problem: p, n, mode: label.into(), secs: t, runs });
        }
        for (a, b) in outs[0].iter().zip(outs[1].iter()) {
            assert_eq!(a.data(), b.data(), "tracing perturbed outputs on {} n={}", p, n);
        }
    }
    print_table("Trace ablation — TraceMode::Off vs Profile", &rows);
    all_rows.extend(rows.iter().cloned());
    for (p, n) in TRACE_WORKLOADS {
        let off = rows.iter().find(|r| r.problem == p && r.n == n && r.mode.starts_with("off"));
        let pr = rows.iter().find(|r| r.problem == p && r.n == n && r.mode == "profile");
        if let (Some(o), Some(t)) = (off, pr) {
            println!(
                "  {:<12} n={:<4} profiling costs {:+6.1}% over untraced",
                p,
                n,
                100.0 * (t.secs - o.secs) / o.secs
            );
        }
    }

    // ---- compress: core vs materialised matfac Hessian ----
    let mut rows = Vec::new();
    for &n in &[32usize, 64, 128] {
        let mut w = matrix_factorization(n, n, 5, false);
        let comp = w.hessian_compressed();
        assert!(comp.is_compressed());
        let core = comp.eval_node();
        let plan = CompiledPlan::new(&w.g, &[core]);
        let (t, runs) = time_median(
            || {
                std::hint::black_box(plan.run(&w.env));
            },
            3,
            secs,
        );
        rows.push(Row {
            figure: "compress",
            problem: "matfac",
            n,
            mode: "compressed core (k×k)".into(),
            secs: t,
            runs,
        });

        let mut w2 = matrix_factorization(n, n, 5, false);
        let h = w2.hessian();
        let plan = CompiledPlan::new(&w2.g, &[h]);
        let (t, runs) = time_median(
            || {
                std::hint::black_box(plan.run(&w2.env));
            },
            3,
            secs,
        );
        rows.push(Row {
            figure: "compress",
            problem: "matfac",
            n,
            mode: "materialised order-4".into(),
            secs: t,
            runs,
        });
    }
    print_table("Compression ablation — matfac Hessian (k=5)", &rows);
    all_rows.extend(rows.iter().cloned());

    maybe_write_bench_json(&all_rows);
}
