//! Ablation benches for the two §3.3 design choices:
//!
//! * **newton** — compressed vs full Newton system on matrix
//!   factorization (the paper's "10 µs vs 1 s at n=1000, k=10" claim,
//!   scaled to this testbed),
//! * **cc** — cross-country vs reverse association on the Example-7
//!   chain `B·diag(u)·diag(v)·A` in isolation,
//! * **compress** — evaluating the matfac Hessian core vs materialising
//!   the order-4 tensor.
//!
//! Run: `cargo bench --bench ablation_modes`

use tensorcalc::autodiff::cross_country::optimize_contractions;
use tensorcalc::eval::Env;
use tensorcalc::exec::CompiledPlan;
use tensorcalc::figures::{newton, print_table, Row};
use tensorcalc::ir::{Elem, Graph};
use tensorcalc::opt::{optimize, OptLevel};
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::tensor::Tensor;
use tensorcalc::util::time_median;

fn main() {
    let secs = 0.3;

    // ---- newton: §3.3 in-text claim ----
    let rows = newton(&[20, 50, 100, 200], 10, secs);
    print_table("§3.3 — compressed vs full Newton system (matfac, k=10)", &rows);
    for n in [20usize, 50, 100, 200] {
        let fast = rows.iter().find(|r| r.n == n && r.mode.starts_with("compressed"));
        let slow = rows.iter().find(|r| r.n == n && r.mode.starts_with("full"));
        if let (Some(f), Some(s)) = (fast, slow) {
            println!("  n={:<5} compressed is {:>10.0}× faster", n, s.secs / f.secs);
        }
    }

    // ---- cc: Example 7 chain ----
    let mut rows = Vec::new();
    for &n in &[64usize, 128, 256, 512] {
        let m = n;
        let build = |cc: bool| -> (Graph, tensorcalc::ir::NodeId, Env) {
            let mut g = Graph::new();
            let b = g.var("B", &[m, n]);
            let a = g.var("A", &[n, m]);
            let u = g.var("u", &[n]);
            let v = g.var("v", &[n]);
            // ((B·diag(u))·diag(v))·A — reverse-mode association
            let bu = g.coldiag(b, u);
            let buv = g.coldiag(bu, v);
            let full = g.matmul(buv, a);
            let expr = if cc { optimize_contractions(&mut g, full) } else { full };
            let mut env = Env::new();
            env.insert("B", Tensor::randn(&[m, n], 1));
            env.insert("A", Tensor::randn(&[n, m], 2));
            env.insert("u", Tensor::randn(&[n], 3));
            env.insert("v", Tensor::randn(&[n], 4));
            (g, expr, env)
        };
        for (label, cc) in [("reverse-order", false), ("cross-country", true)] {
            let (g, node, env) = build(cc);
            let plan = CompiledPlan::new(&g, &[node]);
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            rows.push(Row { figure: "cc", problem: "example7", n, mode: label.into(), secs: t, runs });
        }
    }
    print_table("Cross-country ablation — Example 7 chain B·diag(u)·diag(v)·A", &rows);

    // ---- fusion: element-wise chains fused vs one buffer per node ----
    let mut rows = Vec::new();
    for &n in &[1usize << 12, 1 << 16, 1 << 20] {
        let mut g = Graph::new();
        let x = g.var("x", &[n]);
        let mut v = g.elem(Elem::Tanh, x);
        for _ in 0..7 {
            v = g.elem(Elem::Sigmoid, v);
            v = g.elem(Elem::Tanh, v);
        }
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[n], 5));
        for (label, fuse) in [("fused single pass", true), ("per-node buffers", false)] {
            let plan = CompiledPlan::with_fusion(&g, &[v], fuse);
            let _ = plan.run(&env); // warm-up
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&env));
                },
                3,
                secs,
            );
            rows.push(Row {
                figure: "fusion",
                problem: "elem-chain-15",
                n,
                mode: label.into(),
                secs: t,
                runs,
            });
        }
    }
    print_table("Fusion ablation — 15-deep element-wise chain", &rows);

    // ---- opt: graph-optimizer ablation on the fig3 Hessian workloads ----
    // none = the raw Theorem-8/simplify output, cse = global CSE only,
    // cse+reassoc = the full pipeline eval_many/plan-cache run.
    let mut rows = Vec::new();
    for &(p, n) in &[("logreg", 32usize), ("logreg", 64), ("matfac", 32), ("mlp", 16)] {
        let mut w = match p {
            "logreg" => logistic_regression(2 * n, n),
            "matfac" => matrix_factorization(n, n, 5, false),
            _ => neural_net(n, 10, 2 * n),
        };
        let h = w.hessian();
        for (label, level) in [
            ("OptLevel::None", OptLevel::None),
            ("cse", OptLevel::Cse),
            ("cse+reassoc", OptLevel::Full),
        ] {
            let mut g2 = w.g.clone();
            let o = optimize(&mut g2, &[h], level);
            let plan = CompiledPlan::new(&g2, &o.roots);
            let _ = plan.run(&w.env); // warm-up
            let (t, runs) = time_median(
                || {
                    std::hint::black_box(plan.run(&w.env));
                },
                3,
                secs,
            );
            println!("  opt[{:<15}] {:<8} n={:<4} {}", label, p, n, o.stats);
            rows.push(Row { figure: "opt", problem: p, n, mode: label.into(), secs: t, runs });
        }
    }
    print_table("Optimizer ablation — Hessians, none vs CSE vs CSE+reassoc", &rows);
    for &(p, n) in &[("logreg", 32usize), ("logreg", 64), ("matfac", 32), ("mlp", 16)] {
        let base = rows
            .iter()
            .find(|r| r.problem == p && r.n == n && r.mode.starts_with("OptLevel::None"));
        let full = rows
            .iter()
            .find(|r| r.problem == p && r.n == n && r.mode == "cse+reassoc");
        if let (Some(b), Some(f)) = (base, full) {
            println!("  {:<8} n={:<4} cse+reassoc is {:>6.2}× vs OptLevel::None", p, n, b.secs / f.secs);
        }
    }

    // ---- compress: core vs materialised matfac Hessian ----
    let mut rows = Vec::new();
    for &n in &[32usize, 64, 128] {
        let mut w = matrix_factorization(n, n, 5, false);
        let comp = w.hessian_compressed();
        assert!(comp.is_compressed());
        let core = comp.eval_node();
        let plan = CompiledPlan::new(&w.g, &[core]);
        let (t, runs) = time_median(
            || {
                std::hint::black_box(plan.run(&w.env));
            },
            3,
            secs,
        );
        rows.push(Row {
            figure: "compress",
            problem: "matfac",
            n,
            mode: "compressed core (k×k)".into(),
            secs: t,
            runs,
        });

        let mut w2 = matrix_factorization(n, n, 5, false);
        let h = w2.hessian();
        let plan = CompiledPlan::new(&w2.g, &[h]);
        let (t, runs) = time_median(
            || {
                std::hint::black_box(plan.run(&w2.env));
            },
            3,
            secs,
        );
        rows.push(Row {
            figure: "compress",
            problem: "matfac",
            n,
            mode: "materialised order-4".into(),
            secs: t,
            runs,
        });
    }
    print_table("Compression ablation — matfac Hessian (k=5)", &rows);
}
