//! The paper's three benchmark workloads (§4): logistic regression,
//! matrix factorization and a small fully-connected neural net — each
//! built on the public IR API with synthetic dense data, exactly as in
//! the paper ("we generated dense, random data for each experiment").

mod logreg;
mod matfac;
mod neural_net;

pub use logreg::{logistic_regression, logistic_regression_paper};
pub use matfac::{matrix_factorization, newton_step_compressed, newton_step_full};
pub use neural_net::neural_net;

use crate::autodiff::compress::{compress_derivative, CompressedDerivative};
use crate::autodiff::cross_country::optimize_contractions;
use crate::autodiff::hessian::jacobian;
use crate::autodiff::reverse::reverse_gradient;
use crate::eval::Env;
use crate::ir::{Graph, NodeId};
use crate::simplify::simplify_one;

/// A benchmark workload: a scalar loss over synthetic data, with one
/// distinguished variable to differentiate.
pub struct Workload {
    pub name: &'static str,
    pub g: Graph,
    pub loss: NodeId,
    pub wrt: NodeId,
    pub env: Env,
}

impl Workload {
    /// Simplified reverse-mode gradient.
    pub fn gradient(&mut self) -> NodeId {
        let gr = reverse_gradient(&mut self.g, self.loss, self.wrt);
        simplify_one(&mut self.g, gr)
    }

    /// Simplified reverse-mode Hessian (the mode equivalent to Laue et
    /// al. [6] — the paper's "ours (reverse)" series).
    pub fn hessian(&mut self) -> NodeId {
        let gr = self.gradient();
        jacobian(&mut self.g, gr, self.wrt)
    }

    /// Hessian with the cross-country re-association applied — the
    /// paper's "ours (cross-country)" series.
    pub fn hessian_cross_country(&mut self) -> NodeId {
        let h = self.hessian();
        let h = optimize_contractions(&mut self.g, h);
        simplify_one(&mut self.g, h)
    }

    /// Hessian in compressed representation — the paper's "ours
    /// (compressed)" series.
    pub fn hessian_compressed(&mut self) -> CompressedDerivative {
        let h = self.hessian_cross_country();
        compress_derivative(&mut self.g, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval, fd_gradient, fd_jacobian};

    #[test]
    fn all_workloads_gradients_match_fd() {
        for mut w in [
            logistic_regression(6, 3),
            matrix_factorization(5, 5, 2, false),
            matrix_factorization(5, 4, 2, true),
            neural_net(4, 3, 5),
        ] {
            let grad = w.gradient();
            let name = w.name;
            let wrt_name = match w.g.op(w.wrt) {
                crate::ir::Op::Var(n) => n.clone(),
                _ => unreachable!(),
            };
            let gv = eval(&w.g, grad, &w.env);
            let want = fd_gradient(&w.g, w.loss, &wrt_name, &w.env, 1e-6);
            assert!(
                gv.allclose(&want, 1e-4, 1e-6),
                "{}: gradient mismatch, diff {}",
                name,
                gv.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn all_workloads_hessians_match_fd_of_gradient() {
        for mut w in [
            logistic_regression(6, 3),
            matrix_factorization(5, 5, 2, false),
            neural_net(4, 2, 5),
        ] {
            let grad = w.gradient();
            let h = w.hessian();
            let name = w.name;
            let wrt_name = match w.g.op(w.wrt) {
                crate::ir::Op::Var(n) => n.clone(),
                _ => unreachable!(),
            };
            let hv = eval(&w.g, h, &w.env);
            let want = fd_jacobian(&w.g, grad, &wrt_name, &w.env, 1e-5);
            assert!(
                hv.allclose(&want, 1e-3, 1e-5),
                "{}: hessian mismatch, diff {}",
                name,
                hv.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn hessian_modes_agree_numerically() {
        for mut w in [
            logistic_regression(8, 4),
            matrix_factorization(6, 6, 2, false),
            neural_net(4, 3, 6),
        ] {
            let h = w.hessian();
            let hcc = w.hessian_cross_country();
            let name = w.name;
            let a = eval(&w.g, h, &w.env);
            let b = eval(&w.g, hcc, &w.env);
            assert!(
                a.allclose(&b, 1e-8, 1e-10),
                "{}: cross-country changed the Hessian, diff {}",
                name,
                a.max_abs_diff(&b)
            );
            let comp = w.hessian_compressed();
            let cv = eval(&w.g, comp.eval_node(), &w.env);
            let mat = comp.materialize(&cv);
            assert!(
                mat.allclose(&a, 1e-8, 1e-10),
                "{}: compressed Hessian disagrees, diff {}",
                name,
                mat.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn matfac_hessian_is_compressed() {
        let mut w = matrix_factorization(8, 8, 3, false);
        let comp = w.hessian_compressed();
        assert!(comp.is_compressed(), "plain matfac Hessian must compress");
        let ratio = comp.compression_ratio(&w.g);
        assert!(ratio <= 1.0 / 60.0, "ratio {} not small enough", ratio);
    }
}
