//! A small fully-connected neural net (§4): `layers` dense layers of
//! width `n` with ReLU activations and a softmax cross-entropy output,
//! differentiated with respect to the *first* layer's weights (the paper
//! reports Hessian times for the first layer).

use super::Workload;
use crate::eval::Env;
use crate::ir::{Elem, GenFn, Graph};
use crate::tensor::{Tensor, XorShift};

/// Build the neural-net workload: batch `m`, width `n`, `layers` weight
/// matrices `W1..WL` (all n×n). Loss = Σ_i [logsumexp(z_i) − y_iᵀ z_i]
/// — softmax cross-entropy against one-hot labels.
pub fn neural_net(n: usize, layers: usize, m: usize) -> Workload {
    assert!(layers >= 1);
    let mut g = Graph::new();
    let x = g.var("X", &[m, n]);
    let mut h = x;
    let mut w1 = None;
    for l in 1..=layers {
        let w = g.var(&format!("W{}", l), &[n, n]);
        if l == 1 {
            w1 = Some(w);
        }
        let z = g.matmul(h, w);
        h = if l < layers {
            g.elem(Elem::Relu, z)
        } else {
            z // logits
        };
    }
    let z = h;
    let lse = g.gen_unary(GenFn::LogSumExp, z); // [m]
    let total_lse = g.sum_all(lse);
    let y = g.var("Y", &[m, n]);
    let yz = g.hadamard(y, z);
    let fit = g.sum_all(yz);
    let neg_fit = g.neg(fit);
    let loss = g.add(total_lse, neg_fit);

    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[m, n], 800));
    let mut rng = XorShift::new(900);
    let mut yv = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let c = rng.below(n);
        yv.data_mut()[i * n + c] = 1.0;
    }
    env.insert("Y", yv);
    for l in 1..=layers {
        // small weights keep ReLU pre-activations well spread
        env.insert(
            &format!("W{}", l),
            Tensor::randn(&[n, n], 1000 + l as u64).scale(1.0 / (n as f64).sqrt()),
        );
    }

    Workload { name: "neural_net", g, loss, wrt: w1.unwrap(), env }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval, fd_gradient};

    #[test]
    fn loss_is_cross_entropy_like() {
        let w = neural_net(4, 2, 6);
        let v = eval(&w.g, w.loss, &w.env).item();
        // cross-entropy of m samples over n classes is ≥ 0
        assert!(v.is_finite() && v > 0.0, "loss {}", v);
    }

    #[test]
    fn single_layer_gradient_matches_fd() {
        let mut w = neural_net(3, 1, 4);
        let grad = w.gradient();
        let gv = eval(&w.g, grad, &w.env);
        let want = fd_gradient(&w.g, w.loss, "W1", &w.env, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn deep_net_gradient_matches_fd() {
        let mut w = neural_net(3, 4, 4);
        let grad = w.gradient();
        let gv = eval(&w.g, grad, &w.env);
        let want = fd_gradient(&w.g, w.loss, "W1", &w.env, 1e-6);
        assert!(gv.allclose(&want, 1e-4, 1e-6), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn hessian_shape_is_order4() {
        let mut w = neural_net(3, 2, 4);
        let h = w.hessian();
        assert_eq!(w.g.shape(h), &[3, 3, 3, 3]);
    }

    #[test]
    fn softmax_probabilities_embedded_in_gradient() {
        // For a 1-layer net, ∇_{W} loss = Xᵀ(softmax(XW) − Y)
        let mut w = neural_net(3, 1, 5);
        let grad = w.gradient();
        let gv = eval(&w.g, grad, &w.env);
        let xv = w.env.get("X").unwrap().clone();
        let wv = w.env.get("W1").unwrap().clone();
        let yv = w.env.get("Y").unwrap().clone();
        let z = crate::einsum::einsum(&crate::einsum::EinSpec::parse("ij,jk->ik"), &xv, &wv);
        let p = crate::ir::GenFn::Softmax.eval(&z);
        let pm = p.sub(&yv);
        let want = crate::einsum::einsum(&crate::einsum::EinSpec::parse("ji,jk->ik"), &xv, &pm);
        assert!(gv.allclose(&want, 1e-9, 1e-11), "diff {}", gv.max_abs_diff(&want));
    }
}
