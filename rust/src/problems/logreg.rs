//! Logistic regression (§4): `f(w) = Σ_i log(exp(−y⁽ⁱ⁾·(X⁽ⁱ⁾w)) + 1)`
//! with dense random data, `m = 2n` as in the paper's sweep.

use super::Workload;
use crate::eval::Env;
use crate::ir::{Elem, Graph};
use crate::tensor::{Tensor, XorShift};

/// Build the logistic-regression workload with `m` data points in `n`
/// dimensions, differentiated with respect to the weight vector `w`.
pub fn logistic_regression(m: usize, n: usize) -> Workload {
    let mut g = Graph::new();
    let x = g.var("X", &[m, n]);
    let y = g.var("y", &[m]);
    let w = g.var("w", &[n]);
    let xw = g.matvec(x, w);
    let yxw = g.hadamard(y, xw);
    let neg = g.neg(yxw);
    let e = g.elem(Elem::Exp, neg);
    let one = g.constant(1.0, &[m]);
    let s = g.add(e, one);
    let l = g.elem(Elem::Log, s);
    let loss = g.sum_all(l);

    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[m, n], 100));
    let mut rng = XorShift::new(200);
    let labels: Vec<f64> = (0..m)
        .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
        .collect();
    env.insert("y", Tensor::new(&[m], labels));
    env.insert("w", Tensor::randn(&[n], 300).scale(0.1));

    Workload { name: "logreg", g, loss, wrt: w, env }
}

/// The paper's sweep sizes use `m = 2n`.
pub fn logistic_regression_paper(n: usize) -> Workload {
    logistic_regression(2 * n, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval;

    #[test]
    fn loss_is_positive_and_finite() {
        let w = logistic_regression(10, 5);
        let v = eval(&w.g, w.loss, &w.env).item();
        assert!(v.is_finite() && v > 0.0, "loss {}", v);
    }

    #[test]
    fn loss_matches_manual_computation() {
        let w = logistic_regression(7, 3);
        let xv = w.env.get("X").unwrap();
        let yv = w.env.get("y").unwrap();
        let wv = w.env.get("w").unwrap();
        let mut want = 0.0;
        for i in 0..7 {
            let mut z = 0.0;
            for j in 0..3 {
                z += xv.at(&[i, j]) * wv.data()[j];
            }
            want += ((-yv.data()[i] * z).exp() + 1.0).ln();
        }
        let got = eval(&w.g, w.loss, &w.env).item();
        assert!((got - want).abs() < 1e-10, "{} vs {}", got, want);
    }

    #[test]
    fn hessian_shape_and_symmetry() {
        let mut w = logistic_regression(8, 4);
        let h = w.hessian();
        assert_eq!(w.g.shape(h), &[4, 4]);
        let hv = eval(&w.g, h, &w.env);
        assert!(hv.allclose(&hv.t(), 1e-10, 1e-12));
    }

    #[test]
    fn hessian_is_positive_semidefinite() {
        // logistic loss is convex ⇒ H ⪰ 0; with random dense X it is PD
        use crate::solve::cholesky;
        let mut w = logistic_regression(20, 6);
        let h = w.hessian();
        let mut hv = eval(&w.g, h, &w.env);
        // tiny jitter for numerical safety
        for i in 0..6 {
            hv.data_mut()[i * 6 + i] += 1e-10;
        }
        assert!(cholesky(&hv).is_some(), "logreg Hessian must be PSD");
    }
}
