//! Matrix factorization (§4): `min_U ‖T − U Vᵀ‖²_Ω`, gradient and Hessian
//! with respect to `U`. Without the mask Ω the Hessian is the paper's
//! flagship compression example `2(VᵀV) ⊗ 𝕀`; the §3.3 Newton-system
//! comparison (O(k³) vs O((nk)³)) is implemented below.

use super::Workload;
use crate::eval::Env;
use crate::ir::Graph;
use crate::solve::{cholesky, solve_lower, solve_lower_t, solve_spd};
use crate::tensor::{Tensor, XorShift};

/// Build the matrix-factorization workload: `T ∈ R^{m×n}`,
/// `U ∈ R^{m×k}`, `V ∈ R^{n×k}`. If `with_mask` an indicator Ω masks the
/// known entries (the paper's general form).
pub fn matrix_factorization(m: usize, n: usize, k: usize, with_mask: bool) -> Workload {
    let mut g = Graph::new();
    let t = g.var("T", &[m, n]);
    let u = g.var("U", &[m, k]);
    let v = g.var("V", &[n, k]);
    let uvt = g.matmul_t(u, v); // U Vᵀ : [m, n]
    let d = g.sub(t, uvt);
    let loss = if with_mask {
        let om = g.var("Omega", &[m, n]);
        let masked = g.hadamard(d, om);
        g.norm2(masked)
    } else {
        g.norm2(d)
    };

    let mut env = Env::new();
    env.insert("T", Tensor::randn(&[m, n], 400));
    env.insert("U", Tensor::randn(&[m, k], 500));
    env.insert("V", Tensor::randn(&[n, k], 600));
    if with_mask {
        let mut rng = XorShift::new(700);
        let om: Vec<f64> = (0..m * n)
            .map(|_| if rng.next_f64() < 0.8 { 1.0 } else { 0.0 })
            .collect();
        env.insert("Omega", Tensor::new(&[m, n], om));
    }

    Workload {
        name: if with_mask { "matfac_masked" } else { "matfac" },
        g,
        loss,
        wrt: u,
        env,
    }
}

/// Solve the Newton system `H·D = G` with the *compressed* Hessian
/// `H[i,j,k,l] = M[j,l]·δ_{ik}` (core `M = 2VᵀV`, k×k): one Cholesky of
/// `M` plus one triangular solve per row of `G` — O(k³ + m·k²).
pub fn newton_step_compressed(core: &Tensor, grad: &Tensor) -> Option<Tensor> {
    let k = core.shape()[0];
    assert_eq!(core.shape(), &[k, k]);
    let m = grad.shape()[0];
    assert_eq!(grad.shape(), &[m, k]);
    let l = cholesky(core)?;
    let mut out = Tensor::zeros(&[m, k]);
    for i in 0..m {
        let gi = &grad.data()[i * k..(i + 1) * k];
        let y = solve_lower(&l, gi);
        let x = solve_lower_t(&l, &y);
        out.data_mut()[i * k..(i + 1) * k].copy_from_slice(&x);
    }
    Some(out)
}

/// Solve the same system with the *materialised* order-4 Hessian,
/// flattened to (mk)×(mk) — the O((mk)³) baseline of §3.3.
pub fn newton_step_full(h: &Tensor, grad: &Tensor) -> Option<Tensor> {
    let (m, k) = (grad.shape()[0], grad.shape()[1]);
    assert_eq!(h.shape(), &[m, k, m, k]);
    let nk = m * k;
    let h2 = h.reshape(&[nk, nk]);
    let g2 = grad.reshape(&[nk]);
    let sol = solve_spd(&h2, &g2).or_else(|| crate::solve::solve(&h2, &g2))?;
    Some(sol.reshape(&[m, k]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval;

    #[test]
    fn loss_zero_at_exact_factorization() {
        let mut w = matrix_factorization(5, 4, 2, false);
        // set T = U Vᵀ exactly
        let uv = {
            let u = w.env.get("U").unwrap();
            let v = w.env.get("V").unwrap();
            crate::einsum::einsum(&crate::einsum::EinSpec::parse("ik,jk->ij"), u, v)
        };
        w.env.insert("T", uv);
        let v = eval(&w.g, w.loss, &w.env).item();
        assert!(v.abs() < 1e-18, "loss {}", v);
    }

    #[test]
    fn compressed_and_full_newton_agree() {
        let mut w = matrix_factorization(10, 10, 3, false);
        let comp = w.hessian_compressed();
        assert!(comp.is_compressed());
        let core = eval(&w.g, comp.eval_node(), &w.env);
        let h = comp.materialize(&core);
        let grad_node = w.gradient();
        let grad = eval(&w.g, grad_node, &w.env);

        let fast = newton_step_compressed(&core, &grad).expect("core must be SPD");
        let slow = newton_step_full(&h, &grad).expect("full solve failed");
        assert!(
            fast.allclose(&slow, 1e-7, 1e-8),
            "newton steps diverge, diff {}",
            fast.max_abs_diff(&slow)
        );
    }

    #[test]
    fn newton_step_solves_the_quadratic_exactly() {
        // f is quadratic in U, so one full Newton step lands on the
        // global minimum of the (convex in U) objective: grad becomes 0.
        let mut w = matrix_factorization(8, 8, 2, false);
        let comp = w.hessian_compressed();
        let core = eval(&w.g, comp.eval_node(), &w.env);
        let grad_node = w.gradient();
        let grad = eval(&w.g, grad_node, &w.env);
        let step = newton_step_compressed(&core, &grad).unwrap();
        // U ← U − step
        let u_new = w.env.get("U").unwrap().sub(&step);
        w.env.insert("U", u_new);
        let g_after = eval(&w.g, grad_node, &w.env);
        assert!(
            g_after.norm() < 1e-8 * grad.norm().max(1.0),
            "gradient after Newton step: {}",
            g_after.norm()
        );
    }

    #[test]
    fn masked_hessian_compresses_to_third_order_core() {
        // with the Ω mask the Hessian is H[i,j,k,l] = C[j,l,i]·δ_{ik}
        // (C = 2 Σ_b Ω_ib V_bj V_bl): the δ still factors out, with a
        // per-row k×k core — ratio 1/m
        let (m, n, k) = (8, 6, 2);
        let mut w = matrix_factorization(m, n, k, true);
        let comp = w.hessian_compressed();
        assert!(comp.is_compressed(), "masked matfac Hessian must compress");
        let core_elems: usize = w.g.shape(comp.eval_node()).iter().product();
        assert_eq!(core_elems, k * k * m);
        let ratio = comp.compression_ratio(&w.g);
        assert!((ratio - 1.0 / m as f64).abs() < 1e-12, "ratio {}", ratio);
        // numerics: materialised compressed == full Hessian
        use crate::eval::eval;
        let core = eval(&w.g, comp.eval_node(), &w.env);
        let mat = comp.materialize(&core);
        let full = w.hessian();
        let fv = eval(&w.g, full, &w.env);
        assert!(mat.allclose(&fv, 1e-9, 1e-11), "diff {}", mat.max_abs_diff(&fv));
    }

    #[test]
    fn masked_variant_uses_omega() {
        let mut w = matrix_factorization(6, 5, 2, true);
        let base = eval(&w.g, w.loss, &w.env).item();
        // zeroing Ω must zero the loss
        w.env.insert("Omega", Tensor::zeros(&[6, 5]));
        let z = eval(&w.g, w.loss, &w.env).item();
        assert!(z.abs() < 1e-18 && base > 0.0);
    }
}
