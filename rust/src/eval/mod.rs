//! DAG evaluation: a memoizing interpreter plus reusable evaluation
//! [`Plan`]s (precomputed topological order + buffer lifetimes).
//!
//! Two executors coexist deliberately:
//!
//! * [`Plan`] (here) — the allocating *interpreter*: the reference
//!   semantics, validated against brute-force einsum and
//!   finite-difference oracles, and itself the oracle the compiled
//!   executor is differentially tested against. It deliberately stays
//!   **un-fused** (one tensor per node) so the compiled executor's
//!   fusion pass always has an independent baseline.
//! * [`crate::exec::CompiledPlan`] — the pooled-buffer *hot path*:
//!   element-wise chains fused into single-pass kernels/epilogues and
//!   levels scheduled with work stealing. [`eval_many`] (and therefore
//!   [`eval`]) first run the [`crate::opt`] graph optimizer (global CSE
//!   + contraction reassociation) and then route through it; the FD
//!   helpers below stay on the raw interpreter on purpose.

use crate::einsum::einsum;
use crate::ir::{Graph, NodeId, Op};
use crate::opt::OptLevel;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Variable bindings for evaluation.
#[derive(Default, Clone)]
pub struct Env {
    map: HashMap<String, Tensor>,
}

impl Env {
    pub fn new() -> Self {
        Env::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }
}

/// Evaluate a single root.
pub fn eval(g: &Graph, root: NodeId, env: &Env) -> Tensor {
    eval_many(g, &[root], env).pop().unwrap()
}

/// Evaluate several roots sharing intermediate results. Runs the
/// [`crate::opt`] pipeline (global CSE + contraction reassociation, on a
/// clone of the graph) and routes through the compiled executor; use
/// [`eval_many_with`] + [`OptLevel::None`] for the unoptimized lowering
/// and [`Plan`] directly for the interpreter.
pub fn eval_many(g: &Graph, roots: &[NodeId], env: &Env) -> Vec<Tensor> {
    eval_many_with(g, roots, env, OptLevel::default())
}

/// [`eval_many`] with an explicit optimizer level. `OptLevel::None` is
/// the escape hatch that compiles the graph exactly as given (the
/// ablation baseline alongside `CompiledPlan::with_fusion(.., false)`).
pub fn eval_many_with(g: &Graph, roots: &[NodeId], env: &Env, level: OptLevel) -> Vec<Tensor> {
    eval_many_opts(
        g,
        roots,
        env,
        level,
        crate::exec::ExecMemory::default(),
        crate::obs::TraceMode::default(),
    )
}

/// [`eval_many_with`] with the executor's memory discipline and trace
/// mode explicit: [`ExecMemory::Planned`](crate::exec::ExecMemory)
/// compiles buffer lifetimes to arena offsets (the default),
/// [`ExecMemory::Pooled`](crate::exec::ExecMemory) keeps the PR 1
/// mutex-guarded buffer pool as the ablation baseline, and any
/// `trace != Off` compiles an instrumented plan (see [`crate::obs`] —
/// use `CompiledPlan::run_traced` to actually read the spans back;
/// this convenience entry point discards them).
pub fn eval_many_opts(
    g: &Graph,
    roots: &[NodeId],
    env: &Env,
    level: OptLevel,
    memory: crate::exec::ExecMemory,
    trace: crate::obs::TraceMode,
) -> Vec<Tensor> {
    use crate::exec::{BackendKind, CompiledPlan, EpilogueMode};
    if level == OptLevel::None {
        return CompiledPlan::with_options(
            g,
            roots,
            true,
            EpilogueMode::default(),
            memory,
            BackendKind::default(),
            trace,
        )
        .run(env);
    }
    let mut g2 = g.clone();
    let o = crate::opt::optimize(&mut g2, roots, level);
    CompiledPlan::with_options(
        &g2,
        &o.roots,
        true,
        EpilogueMode::default(),
        memory,
        BackendKind::default(),
        trace,
    )
    .run(env)
}

/// A reusable evaluation plan: topological order restricted to the
/// reachable sub-DAG plus last-use positions so intermediate buffers are
/// dropped as early as possible (the interpreter allocates nothing per
/// step beyond the result tensors themselves).
pub struct Plan {
    order: Vec<NodeId>,
    /// for each position in `order`, the node ids whose value dies there
    frees: Vec<Vec<NodeId>>,
    roots: Vec<NodeId>,
}

impl Plan {
    pub fn new(g: &Graph, roots: &[NodeId]) -> Self {
        let order = g.topo(roots);
        let mut last_use: HashMap<NodeId, usize> = HashMap::new();
        for (pos, &id) in order.iter().enumerate() {
            for c in g.children(id) {
                last_use.insert(c, pos);
            }
        }
        // roots must survive to the end
        for r in roots {
            last_use.remove(r);
        }
        let mut frees: Vec<Vec<NodeId>> = vec![Vec::new(); order.len()];
        for (id, pos) in last_use {
            frees[pos].push(id);
        }
        Plan { order, frees, roots: roots.to_vec() }
    }

    /// Number of nodes the plan evaluates.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Execute the plan.
    pub fn run(&self, g: &Graph, env: &Env) -> Vec<Tensor> {
        let mut values: HashMap<NodeId, Tensor> = HashMap::with_capacity(self.order.len());
        for (pos, &id) in self.order.iter().enumerate() {
            let v = match g.op(id) {
                Op::Var(name) => {
                    let t = env
                        .get(name)
                        .unwrap_or_else(|| panic!("unbound variable {}", name))
                        .clone();
                    assert_eq!(
                        t.shape(),
                        g.shape(id),
                        "variable {} bound with wrong shape",
                        name
                    );
                    t
                }
                Op::Const(bits) => Tensor::fill(g.shape(id), f64::from_bits(*bits)),
                Op::Delta { dims } => Tensor::delta(dims),
                Op::Add(a, b) => values[a].add(&values[b]),
                Op::Mul(a, b, spec) => einsum(spec, &values[a], &values[b]),
                Op::Elem(f, a) => f.eval(&values[a]),
                Op::GenUnary(f, a) => f.eval(&values[a]),
            };
            values.insert(id, v);
            for dead in &self.frees[pos] {
                values.remove(dead);
            }
        }
        self.roots
            .iter()
            .map(|r| values.get(r).cloned().expect("root not computed"))
            .collect()
    }
}

/// Central finite-difference gradient of a *scalar* root with respect to
/// one variable — the numerical oracle used throughout the test suite.
pub fn fd_gradient(g: &Graph, root: NodeId, var: &str, env: &Env, eps: f64) -> Tensor {
    assert!(g.shape(root).is_empty(), "fd_gradient needs a scalar root");
    let x0 = env.get(var).expect("variable not bound").clone();
    let mut grad = Tensor::zeros(x0.shape());
    let plan = Plan::new(g, &[root]);
    for i in 0..x0.len() {
        let mut ep = env.clone();
        ep.get_mut(var).unwrap().data_mut()[i] += eps;
        let fp = plan.run(g, &ep)[0].item();
        let mut em = env.clone();
        em.get_mut(var).unwrap().data_mut()[i] -= eps;
        let fm = plan.run(g, &em)[0].item();
        grad.data_mut()[i] = (fp - fm) / (2.0 * eps);
    }
    grad
}

/// Finite-difference Jacobian of a tensor-valued root w.r.t. one variable:
/// shape = root shape ++ var shape (Definition 4's derivative layout).
pub fn fd_jacobian(g: &Graph, root: NodeId, var: &str, env: &Env, eps: f64) -> Tensor {
    let x0 = env.get(var).expect("variable not bound").clone();
    let out_shape: Vec<usize> =
        g.shape(root).iter().chain(x0.shape()).copied().collect();
    let m: usize = g.shape(root).iter().product();
    let n = x0.len();
    let mut jac = Tensor::zeros(&out_shape);
    let plan = Plan::new(g, &[root]);
    for j in 0..n {
        let mut ep = env.clone();
        ep.get_mut(var).unwrap().data_mut()[j] += eps;
        let fp = plan.run(g, &ep).pop().unwrap();
        let mut em = env.clone();
        em.get_mut(var).unwrap().data_mut()[j] -= eps;
        let fm = plan.run(g, &em).pop().unwrap();
        for i in 0..m {
            jac.data_mut()[i * n + j] = (fp.data()[i] - fm.data()[i]) / (2.0 * eps);
        }
    }
    jac
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Elem;

    #[test]
    fn eval_expression_1_matches_manual() {
        // Xᵀ((exp(Xw)+1)⁻¹ ⊙ exp(Xw)) — paper Expression (1)
        let mut g = Graph::new();
        let x = g.var("X", &[4, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[4]);
        let e1 = g.add(e, one);
        let inv = g.elem(Elem::Recip, e1);
        let prod = g.hadamard(inv, e);
        let y = g.tmatvec(x, prod);

        let xv = Tensor::randn(&[4, 3], 1);
        let wv = Tensor::randn(&[3], 2);
        let mut env = Env::new();
        env.insert("X", xv.clone());
        env.insert("w", wv.clone());
        let got = eval(&g, y, &env);

        // manual computation
        let mut want = vec![0.0; 3];
        for i in 0..4 {
            let mut z = 0.0;
            for j in 0..3 {
                z += xv.at(&[i, j]) * wv.data()[j];
            }
            let s = z.exp() / (z.exp() + 1.0);
            for j in 0..3 {
                want[j] += xv.at(&[i, j]) * s;
            }
        }
        for j in 0..3 {
            assert!((got.data()[j] - want[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn eval_many_shares_work() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let e = g.elem(Elem::Exp, x);
        let a = g.sum_all(e);
        let b = g.hadamard(e, e);
        let mut env = Env::new();
        env.insert("x", Tensor::new(&[3], vec![0.0, 1.0, 2.0]));
        let vals = eval_many(&g, &[a, b], &env);
        assert_eq!(vals.len(), 2);
        assert!((vals[0].item() - (1.0 + 1f64.exp() + 2f64.exp())).abs() < 1e-12);
    }

    #[test]
    fn plan_reuse_is_consistent() {
        let mut g = Graph::new();
        let x = g.var("x", &[2]);
        let y = g.norm2(x);
        let plan = Plan::new(&g, &[y]);
        for seed in 0..3 {
            let mut env = Env::new();
            let xv = Tensor::randn(&[2], seed);
            env.insert("x", xv.clone());
            let got = plan.run(&g, &env)[0].item();
            assert!((got - xv.norm().powi(2)).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "unbound variable")]
    fn unbound_variable_panics() {
        let mut g = Graph::new();
        let x = g.var("x", &[2]);
        eval(&g, x, &Env::new());
    }

    #[test]
    fn fd_jacobian_of_linear_map_is_matrix() {
        // y = A x ⇒ dy/dx = A
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let x = g.var("x", &[4]);
        let y = g.matvec(a, x);
        let av = Tensor::randn(&[3, 4], 5);
        let mut env = Env::new();
        env.insert("A", av.clone());
        env.insert("x", Tensor::randn(&[4], 6));
        let j = fd_jacobian(&g, y, "x", &env, 1e-6);
        assert!(j.allclose(&av, 1e-5, 1e-7), "diff {}", j.max_abs_diff(&av));
    }
}
