//! Blocked, packed, tiled GEMM — the inner kernel every contraction
//! reduces to — with an in-tile epilogue hook and a runtime-dispatched
//! SIMD register microkernel.
//!
//! `C[m,n] += Σ_k A[m,k] · B[k,n]` over row-major contiguous buffers.
//!
//! The tiled path is the classic three-level blocking: an `MR×NR`
//! register microkernel accumulates into registers, an `MC×KC` block of
//! A is packed into microkernel order (L2-resident, per-thread scratch
//! sized to the call), and B is packed **once per GEMM** into `KC×NC`
//! chunks ([`pack_b_all`]) that the microkernel streams through — on
//! the parallel path all row bands share the one packed B read-only.
//! Packing pads partial tiles with zeros so the microkernel always runs
//! full constant-trip loops; the store loop masks the padding back off.
//! Large GEMMs parallelise over row bands with scoped threads, exactly
//! like the flat kernel.
//!
//! The blocking geometry and the microkernel are no longer compile-time
//! choices: [`gemm_into_epi`] resolves a [`crate::util::simd::GemmCfg`]
//! at entry — the process-wide [`crate::util::simd::Blocking`] (from
//! `TC_GEMM_BLOCKING` or the startup autotuner; defaults [`GEMM_MR`] ×
//! [`GEMM_NR`] tiles in [`GEMM_MC`]/[`GEMM_KC`]/[`GEMM_NC`] blocks) plus
//! the microkernel dispatched for the active ISA (`TC_SIMD`, see
//! [`crate::util::simd`]). Scalar and SIMD kernels accumulate each
//! output element in the same IEEE order (separate mul/add, no FMA), so
//! the dispatch choice never changes results bitwise.
//!
//! **In-tile epilogue** ([`TileEpilogue`]): callers can pass a per-tile
//! post-processing hook that is applied to every output element exactly
//! once, immediately after its *final* k-accumulation, while the tile is
//! still cache-hot. The compiled executor uses this to run fused
//! element-wise chains riding on a contraction without a second sweep
//! over the output buffer (the memory pass that
//! `EinsumPlan::run_with_epilogue` — kept as the two-pass reference —
//! still performs). Epilogue offsets are *global* flat indices so
//! broadcast/sliced operands of the fused chain resolve correctly from
//! inside row bands and batch slices.
//!
//! The pre-tiling flat kernel survives as [`gemm_into_flat`]: it is the
//! differential baseline for the tiled path, the small-shape fast path
//! (below [`GEMM_TILED_MIN_FLOP`] packing would dominate) and the
//! tiled-vs-flat ablation dimension in `benches/`.

use crate::util::simd::{self, Blocking, GemmCfg, MicroKernel};
use crate::util::{
    num_threads, par_band_zip, with_pack_scratch, GEMM_KC, GEMM_MC, GEMM_MR, GEMM_NC, GEMM_NR,
    GEMM_TILED_MIN_FLOP, PAR_GEMM_MIN_FLOP,
};

/// Flat-kernel cache block along the contraction dimension.
const KC_FLAT: usize = 256;
/// Flat-kernel cache block along the output columns.
const NC_FLAT: usize = 512;

/// A per-tile output post-processing hook: `apply(base, seg)` must
/// transform every element of `seg` exactly once, where `seg[j]` holds
/// the *final* accumulated value of global flat output index `base + j`.
/// The kernel guarantees each output element is handed to the epilogue
/// exactly once, after its last k-block accumulation, in disjoint
/// segments (so `Sync` suffices for the parallel row-band path).
///
/// The hook is called from inside the tile loop while the thread's
/// packing scratch is checked out: it must be element-wise work only and
/// must not re-enter a GEMM on the same thread.
pub trait TileEpilogue: Sync {
    fn apply(&self, base: usize, seg: &mut [f64]);
}

/// The no-op epilogue: `gemm_into` instantiates the tiled kernel with
/// it, and the optimizer erases the calls entirely.
pub struct NoEpilogue;

impl TileEpilogue for NoEpilogue {
    #[inline(always)]
    fn apply(&self, _base: usize, _seg: &mut [f64]) {}
}

/// Adapter running any `Fn(usize, &mut [f64]) + Sync` closure as a
/// [`TileEpilogue`]. (A direct blanket impl over `F: Fn` would collide
/// with the [`NoEpilogue`] impl under coherence, hence the newtype.)
pub struct EpiFn<F>(pub F);

impl<F: Fn(usize, &mut [f64]) + Sync> TileEpilogue for EpiFn<F> {
    #[inline]
    fn apply(&self, base: usize, seg: &mut [f64]) {
        (self.0)(base, seg)
    }
}

/// `C = A · B` into a fresh buffer. `a` is `m×k` row-major, `b` is `k×n`.
pub fn gemm(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    gemm_into(a, b, &mut c, m, k, n);
    c
}

/// `C += A · B` (accumulating) into an existing `m×n` buffer.
pub fn gemm_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    gemm_into_epi(a, b, c, m, k, n, 0, &NoEpilogue);
}

/// `C += A · B`, then `epi` applied exactly once to every element of `C`
/// after its final accumulation — inside the tile loop while the tile is
/// cache-hot on the tiled path, as a trailing sweep on the small-shape
/// and matvec fast paths (where `C` is tiny or freshly written anyway).
///
/// `c_base` is the global flat index of `c[0]` in the logical output
/// buffer; the epilogue sees global offsets (batched callers pass the
/// slice offset).
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_epi<E: TileEpilogue>(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    c_base: usize,
    epi: &E,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // the empty contraction adds nothing, but the epilogue still
        // owes every element exactly one application
        epi.apply(c_base, c);
        return;
    }
    // Resolve blocking + microkernel *before* borrowing this thread's
    // pack scratch: a first-call autotune runs probe GEMMs that use the
    // scratch themselves, which must not observe an open borrow.
    let cfg = simd::gemm_cfg();
    // Matvec (n == 1 < NR), small, or skinny shapes: the packed/tiled
    // path cannot pay for itself — run the flat reference kernel (which
    // has its own matvec fast path) and sweep the output once. For
    // every shape in this class the output is tiny relative to the
    // operand reads, so the extra sweep is noise.
    if m < cfg.blk.mr || n < cfg.blk.nr || m * n * k < GEMM_TILED_MIN_FLOP {
        gemm_into_flat(a, b, c, m, k, n);
        epi.apply(c_base, c);
        return;
    }

    // The `num_threads() > 1` gate guarantees par_band_zip really forks
    // (units = m ≥ 2): bands then run on fresh scoped threads with their
    // own pack scratch, so holding this thread's scratch open for the
    // shared packed B below can never be re-entered.
    if m * n * k >= PAR_GEMM_MIN_FLOP && m > 1 && num_threads() > 1 {
        with_pack_scratch(|pack| {
            // B is packed once into this thread's reusable scratch and
            // shared read-only by the row bands — packing it inside
            // each band would multiply that memory traffic by the
            // thread count. Each band packs only its own A blocks.
            pack_b_all(b, &mut pack.b, k, n, cfg.blk);
            let bpack: &[f64] = &pack.b;
            par_band_zip(c, n, a, k, |off, cb, ab| {
                let rows = cb.len() / n;
                with_pack_scratch(|wpack| {
                    tiled_body(
                        ab,
                        bpack,
                        cb,
                        rows,
                        k,
                        n,
                        c_base + off * n,
                        epi,
                        &mut wpack.a,
                        &cfg,
                    )
                });
            });
        });
    } else {
        with_pack_scratch(|pack| {
            pack_b_all(b, &mut pack.b, k, n, cfg.blk);
            tiled_body(a, &pack.b, c, m, k, n, c_base, epi, &mut pack.a, &cfg)
        });
    }
}

/// Padded width (in f64 columns) of the packed B panel starting at
/// column `jc`: the panel covers `min(nc, n - jc)` live columns, rounded
/// up to whole `nr`-wide microtiles. The **single source of truth** for
/// the panel geometry — the pre-pass that sizes the pack buffer, the
/// packing loop and the consuming tile loop all call this, so the three
/// can never disagree about where a ragged edge panel ends.
pub(crate) fn b_panel_width(n: usize, jc: usize, nc: usize, nr: usize) -> usize {
    nc.min(n - jc).div_ceil(nr) * nr
}

/// Pack every `(jc, pc)` block of B once, in the exact `(jc outer, pc
/// inner)` order [`tiled_body`] consumes chunks — so B is packed once
/// per GEMM, not once per row band. The scratch only ever grows (no
/// clear-and-zero: [`pack_b`] overwrites every element of its chunk,
/// padding included, and readers use the same chunk offsets).
fn pack_b_all(b: &[f64], bpack: &mut Vec<f64>, k: usize, n: usize, blk: Blocking) {
    let Blocking { nr, kc: kc_blk, nc: nc_blk, .. } = blk;
    let padded_n: usize =
        (0..n).step_by(nc_blk).map(|jc| b_panel_width(n, jc, nc_blk, nr)).sum();
    if bpack.len() < padded_n * k {
        bpack.resize(padded_n * k, 0.0);
    }
    let mut off = 0usize;
    for jc in (0..n).step_by(nc_blk) {
        let nc = nc_blk.min(n - jc);
        for pc in (0..k).step_by(kc_blk) {
            let kc = kc_blk.min(k - pc);
            let len = b_panel_width(n, jc, nc_blk, nr) * kc;
            pack_b(b, &mut bpack[off..off + len], pc, kc, jc, nc, n, nr);
            off += len;
        }
    }
}

/// The blocked/packed serial core: loops `jc` (NC column blocks) → `pc`
/// (KC k-blocks) → `ic` (MC row blocks), reading pre-packed B chunks
/// (see [`pack_b_all`]) and packing A once per `(ic, pc)` into `apack`
/// (grown to the call's actual block size, then reused), then sweeps
/// the dispatched microkernel over the packed panels. On the *last*
/// k-block each finished `mc×nc` output block gets the epilogue applied
/// row by row, while it is cache-hot.
#[allow(clippy::too_many_arguments)]
fn tiled_body<E: TileEpilogue>(
    a: &[f64],
    bpack: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    c_base: usize,
    epi: &E,
    apack: &mut Vec<f64>,
    cfg: &GemmCfg,
) {
    let Blocking { mr: mr_blk, nr: nr_blk, mc: mc_blk, kc: kc_blk, nc: nc_blk } = cfg.blk;
    let ukr = cfg.ukr;
    let a_need = mc_blk.min(m).div_ceil(mr_blk) * mr_blk * kc_blk.min(k);
    if apack.len() < a_need {
        apack.resize(a_need, 0.0);
    }
    let mut b_off = 0usize;
    for jc in (0..n).step_by(nc_blk) {
        let nc = nc_blk.min(n - jc);
        for pc in (0..k).step_by(kc_blk) {
            let kc = kc_blk.min(k - pc);
            let last_k = pc + kc == k;
            let bchunk = &bpack[b_off..b_off + b_panel_width(n, jc, nc_blk, nr_blk) * kc];
            b_off += bchunk.len();
            for ic in (0..m).step_by(mc_blk) {
                let mc = mc_blk.min(m - ic);
                pack_a(a, apack, ic, mc, pc, kc, k, mr_blk);
                for jr in (0..nc).step_by(nr_blk) {
                    let nr = nr_blk.min(nc - jr);
                    let bp = &bchunk[(jr / nr_blk) * kc * nr_blk..][..kc * nr_blk];
                    for ir in (0..mc).step_by(mr_blk) {
                        let mr = mr_blk.min(mc - ir);
                        let ap = &apack[(ir / mr_blk) * kc * mr_blk..][..kc * mr_blk];
                        ukr(ap, bp, c, n, ic + ir, jc + jr, mr, nr, kc);
                    }
                }
                if last_k {
                    for i in ic..ic + mc {
                        let row = &mut c[i * n + jc..i * n + jc + nc];
                        epi.apply(c_base + i * n + jc, row);
                    }
                }
            }
        }
    }
}

/// Pack `A[ic..ic+mc, pc..pc+kc]` (row stride `lda`) into panels of
/// `mr_blk` rows: `ap[panel][kk][r]`, zero-padded to full panels.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &[f64],
    ap: &mut [f64],
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
    lda: usize,
    mr_blk: usize,
) {
    let mut dst = 0usize;
    for ir in (0..mc).step_by(mr_blk) {
        let mr = mr_blk.min(mc - ir);
        for kk in 0..kc {
            for r in 0..mr_blk {
                ap[dst] = if r < mr { a[(ic + ir + r) * lda + pc + kk] } else { 0.0 };
                dst += 1;
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` (row stride `ldb`) into panels of
/// `nr_blk` columns: `bp[panel][kk][j]`, zero-padded to full panels.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &[f64],
    bp: &mut [f64],
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    ldb: usize,
    nr_blk: usize,
) {
    let mut dst = 0usize;
    for jr in (0..nc).step_by(nr_blk) {
        let nr = nr_blk.min(nc - jr);
        for kk in 0..kc {
            let src = (pc + kk) * ldb + jc + jr;
            for j in 0..nr_blk {
                bp[dst] = if j < nr { b[src + j] } else { 0.0 };
                dst += 1;
            }
        }
    }
}

/// Time one `(blocking, microkernel)` candidate on a fixed `m×k×n`
/// probe GEMM: pack B, run [`tiled_body`], take the best of two reps.
/// Called by the startup autotuner in [`crate::util::simd`] — it drives
/// [`tiled_body`] directly with an explicit config (never `gemm_into`,
/// which would re-enter the blocking `OnceLock` mid-initialization).
pub(crate) fn tune_probe(blk: Blocking, ukr: MicroKernel, m: usize, k: usize, n: usize) -> f64 {
    let a: Vec<f64> = (0..m * k).map(|i| ((i % 13) as f64) * 0.125 - 0.75).collect();
    let b: Vec<f64> = (0..k * n).map(|i| ((i % 7) as f64) * 0.25 - 0.875).collect();
    let mut c = vec![0.0f64; m * n];
    let cfg = GemmCfg { blk, ukr };
    let mut best = f64::INFINITY;
    with_pack_scratch(|pack| {
        for _ in 0..2 {
            let t0 = std::time::Instant::now();
            pack_b_all(&b, &mut pack.b, k, n, blk);
            tiled_body(&a, &pack.b, &mut c, m, k, n, 0, &NoEpilogue, &mut pack.a, &cfg);
            best = best.min(t0.elapsed().as_secs_f64());
        }
    });
    std::hint::black_box(&c);
    best
}

/// The pre-tiling flat kernel (k-blocked, column-blocked, row-parallel,
/// auto-vectorised over contiguous output rows). Kept as the reference
/// baseline the tiled path is differentially pinned against, as the
/// small-shape fast path, and as the "flat" ablation mode in the benches.
pub fn gemm_into_flat(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if n == 1 && k > 1 {
        // C[m] += A[m,k] · b[k]
        let matvec_row = |ci: &mut f64, arow: &[f64]| {
            let mut acc = 0.0;
            for (av, bv) in arow.iter().zip(b.iter()) {
                acc += av * bv;
            }
            *ci += acc;
        };
        if m * k >= PAR_GEMM_MIN_FLOP {
            par_band_zip(c, 1, a, k, |_, cb, ab| {
                for (ci, arow) in cb.iter_mut().zip(ab.chunks(k)) {
                    matvec_row(ci, arow);
                }
            });
        } else {
            for (ci, arow) in c.iter_mut().zip(a.chunks(k)) {
                matvec_row(ci, arow);
            }
        }
        return;
    }

    let body = |c_block: &mut [f64], a_block: &[f64]| {
        let rows = c_block.len() / n;
        for k0 in (0..k).step_by(KC_FLAT) {
            let kend = (k0 + KC_FLAT).min(k);
            // column blocking keeps the active B panel resident in L2
            // across the i loop
            for j0 in (0..n).step_by(NC_FLAT) {
                let jend = (j0 + NC_FLAT).min(n);
                for i in 0..rows {
                    let arow = &a_block[i * k..(i + 1) * k];
                    let crow = &mut c_block[i * n + j0..i * n + jend];
                    for kk in k0..kend {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * n + j0..kk * n + jend];
                        // contiguous fused multiply-add over the output
                        // row — auto-vectorised
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                }
            }
        }
    };

    if m * n * k >= PAR_GEMM_MIN_FLOP && m > 1 {
        par_band_zip(c, n, a, k, |_, cb, ab| body(cb, ab));
    } else {
        body(c, a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::XorShift;
    use crate::util::simd::{kernel_for, supported_isas, Isa, SUPPORTED_TILES, TUNE_CANDIDATES};

    fn naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut r = XorShift::new(seed);
        (0..n).map(|_| r.next_f64() - 0.5).collect()
    }

    fn check(m: usize, k: usize, n: usize) {
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let want = naive(&a, &b, m, k, n);
        let got = gemm(&a, &b, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{} vs {} ({m}x{k}x{n})", g, w);
        }
        // the flat reference kernel must agree with the tiled default
        let mut flat = vec![0.0; m * n];
        gemm_into_flat(&a, &b, &mut flat, m, k, n);
        for (g, w) in flat.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "flat {} vs {} ({m}x{k}x{n})", g, w);
        }
    }

    #[test]
    fn small_shapes() {
        check(1, 1, 1);
        check(2, 3, 4);
        check(5, 1, 7);
        check(1, 9, 1);
        check(7, 7, 7);
    }

    #[test]
    fn blocked_shapes() {
        check(33, 300, 17); // crosses KC and MC boundaries
        check(64, 64, 64);
        check(100, 513, 3);
        check(65, 257, 513); // one past every tiled block boundary
        check(4, 512, 8); // minimal tile dims, exactly at the flop threshold
        check(32, 64, 32); // serial tiled path (below the parallel gate)
    }

    #[test]
    fn parallel_path() {
        check(200, 200, 200); // above PAR_GEMM_MIN_FLOP
    }

    #[test]
    fn matvec_path() {
        check(100, 700, 1);
    }

    #[test]
    fn accumulation_semantics() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut c = vec![10.0];
        gemm_into(&a, &b, &mut c, 1, 2, 1);
        assert_eq!(c, vec![10.0 + 3.0 + 8.0]);
    }

    /// The in-tile epilogue must touch every element exactly once, after
    /// its final accumulation, with the right global offset.
    fn check_epilogue(m: usize, k: usize, n: usize, c_base: usize) {
        let a = rand_vec(m * k, 3);
        let b = rand_vec(k * n, 4);
        // reference: full GEMM, then one sweep applying the epilogue
        let mut want = naive(&a, &b, m, k, n);
        for (j, w) in want.iter_mut().enumerate() {
            *w = w.tanh() + (c_base + j) as f64;
        }
        let mut got = vec![0.0; m * n];
        let epi = EpiFn(|base: usize, seg: &mut [f64]| {
            for (j, v) in seg.iter_mut().enumerate() {
                *v = v.tanh() + (base + j) as f64;
            }
        });
        gemm_into_epi(&a, &b, &mut got, m, k, n, c_base, &epi);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-9, "epi {} vs {} at {} ({m}x{k}x{n})", g, w, i);
        }
    }

    #[test]
    fn epilogue_small_flat_path() {
        check_epilogue(3, 5, 4, 0);
        check_epilogue(3, 5, 4, 17);
        check_epilogue(7, 1, 9, 2); // k == 1
    }

    #[test]
    fn epilogue_tiled_path() {
        check_epilogue(32, 64, 32, 0); // serial tiled (below the parallel gate)
        check_epilogue(32, 64, 32, 1000);
        check_epilogue(4, 512, 8, 7); // minimal tile dims
    }

    #[test]
    fn epilogue_parallel_and_matvec_paths() {
        check_epilogue(200, 200, 200, 5); // parallel row bands
        check_epilogue(65, 257, 130, 0); // parallel + every block boundary
        check_epilogue(100, 700, 1, 3); // matvec fast path
    }

    #[test]
    fn epilogue_empty_k_still_applies() {
        let mut c = vec![1.0, 2.0, 3.0, 4.0];
        let epi = EpiFn(|_base: usize, seg: &mut [f64]| {
            for v in seg.iter_mut() {
                *v += 10.0;
            }
        });
        gemm_into_epi(&[], &[], &mut c, 2, 0, 2, 0, &epi);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }

    /// The hoisted [`b_panel_width`] helper and the pack/consume loops
    /// must agree on ragged edge panels: every live B element lands at
    /// the offset the consumer computes, and padding is exactly zero.
    #[test]
    fn panel_geometry_ragged_edges() {
        // spot-check the helper against hand-computed widths
        assert_eq!(b_panel_width(17, 0, 512, 8), 24); // 17 live → 3 tiles
        assert_eq!(b_panel_width(512, 0, 512, 8), 512); // exact block
        assert_eq!(b_panel_width(513, 512, 512, 8), 8); // 1 live col
        assert_eq!(b_panel_width(1030, 1024, 512, 8), 8); // 6 live cols
        assert_eq!(b_panel_width(1030, 512, 512, 8), 512); // interior block
        assert_eq!(b_panel_width(1, 0, 512, 4), 4);

        let blk = Blocking::DEFAULT;
        for (k, n) in [(1usize, 1usize), (3, 17), (300, 1030), (257, 513)] {
            let b: Vec<f64> = (0..k * n).map(|i| (i as f64) * 0.5 + 1.0).collect();
            let mut bpack = Vec::new();
            pack_b_all(&b, &mut bpack, k, n, blk);
            // walk the chunks exactly as tiled_body does
            let mut off = 0usize;
            for jc in (0..n).step_by(blk.nc) {
                let nc = blk.nc.min(n - jc);
                for pc in (0..k).step_by(blk.kc) {
                    let kc = blk.kc.min(k - pc);
                    let width = b_panel_width(n, jc, blk.nc, blk.nr);
                    let chunk = &bpack[off..off + width * kc];
                    off += chunk.len();
                    for jr in (0..nc).step_by(blk.nr) {
                        let live = blk.nr.min(nc - jr);
                        let panel = &chunk[(jr / blk.nr) * kc * blk.nr..][..kc * blk.nr];
                        for kk in 0..kc {
                            for j in 0..blk.nr {
                                let got = panel[kk * blk.nr + j];
                                let want = if j < live {
                                    b[(pc + kk) * n + jc + jr + j]
                                } else {
                                    0.0
                                };
                                assert_eq!(
                                    got, want,
                                    "k={k} n={n} jc={jc} pc={pc} jr={jr} kk={kk} j={j}"
                                );
                            }
                        }
                    }
                }
            }
            // a fresh pack buffer is sized exactly by the pre-pass, so
            // the consumer walk must end exactly at its end
            assert_eq!(off, bpack.len(), "k={k} n={n}: consumer walk != packed size");
        }
    }

    /// Every autotune candidate geometry, driven through the real packed
    /// tiled path with every supported ISA's microkernel, must match the
    /// naive reference — and all ISAs must agree bitwise with scalar.
    #[test]
    fn every_tune_candidate_matches_naive() {
        let (m, k, n) = (37usize, 300usize, 29usize);
        let a = rand_vec(m * k, 11);
        let b = rand_vec(k * n, 12);
        let want = naive(&a, &b, m, k, n);
        for cand in TUNE_CANDIDATES {
            let mut scalar_out: Option<Vec<f64>> = None;
            for isa in supported_isas() {
                let ukr = kernel_for(isa, cand.mr, cand.nr).unwrap();
                let cfg = GemmCfg { blk: cand, ukr };
                let mut c = vec![0.0f64; m * n];
                let mut apack = Vec::new();
                let mut bpack = Vec::new();
                pack_b_all(&b, &mut bpack, k, n, cand);
                tiled_body(&a, &bpack, &mut c, m, k, n, 0, &NoEpilogue, &mut apack, &cfg);
                for (g, w) in c.iter().zip(&want) {
                    assert!(
                        (g - w).abs() < 1e-9,
                        "{cand:?} {} diverged from naive: {g} vs {w}",
                        isa.name()
                    );
                }
                match &scalar_out {
                    None => {
                        assert_eq!(isa, Isa::Scalar, "supported_isas must lead with scalar");
                        scalar_out = Some(c);
                    }
                    Some(sc) => assert_eq!(
                        &c,
                        sc,
                        "{cand:?}: {} not bit-identical to scalar",
                        isa.name()
                    ),
                }
            }
        }
        // sanity: the candidate tile set stays inside the kernel tables
        for cand in TUNE_CANDIDATES {
            assert!(SUPPORTED_TILES.contains(&(cand.mr, cand.nr)));
        }
    }
}
