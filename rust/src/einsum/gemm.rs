//! Blocked dense GEMM — the inner kernel every contraction reduces to.
//!
//! `C[m,n] += Σ_k A[m,k] · B[k,n]` over row-major contiguous buffers.
//! The kernel is cache-blocked over `k` and parallelised over row bands
//! with scoped threads; the innermost `j` loop is written so LLVM
//! auto-vectorises it (contiguous FMA over the output row).

use crate::util::{par_band_zip, PAR_GEMM_MIN_FLOP};

/// Cache block along the contraction dimension (fits a few rows of B in L1/L2).
const KC: usize = 256;
/// Cache block along the output columns (B panel = KC·NC·8 bytes ≤ L2).
const NC: usize = 512;

/// `C = A · B` into a fresh buffer. `a` is `m×k` row-major, `b` is `k×n`.
pub fn gemm(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    gemm_into(a, b, &mut c, m, k, n);
    c
}

/// `C += A · B` (accumulating) into an existing `m×n` buffer.
pub fn gemm_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Degenerate shapes: dot products and outer products have cheaper forms.
    if n == 1 && k > 1 {
        // C[m] += A[m,k] · b[k]
        let matvec_row = |ci: &mut f64, arow: &[f64]| {
            let mut acc = 0.0;
            for (av, bv) in arow.iter().zip(b.iter()) {
                acc += av * bv;
            }
            *ci += acc;
        };
        if m * k >= PAR_GEMM_MIN_FLOP {
            par_band_zip(c, 1, a, k, |_, cb, ab| {
                for (ci, arow) in cb.iter_mut().zip(ab.chunks(k)) {
                    matvec_row(ci, arow);
                }
            });
        } else {
            for (ci, arow) in c.iter_mut().zip(a.chunks(k)) {
                matvec_row(ci, arow);
            }
        }
        return;
    }

    let body = |c_block: &mut [f64], a_block: &[f64]| {
        let rows = c_block.len() / n;
        for k0 in (0..k).step_by(KC) {
            let kend = (k0 + KC).min(k);
            // column blocking keeps the active B panel (KC×NC doubles)
            // resident in L2 across the i loop
            for j0 in (0..n).step_by(NC) {
                let jend = (j0 + NC).min(n);
                for i in 0..rows {
                    let arow = &a_block[i * k..(i + 1) * k];
                    let crow = &mut c_block[i * n + j0..i * n + jend];
                    for kk in k0..kend {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * n + j0..kk * n + jend];
                        // contiguous fused multiply-add over the output
                        // row — auto-vectorised
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += av * bv;
                        }
                    }
                }
            }
        }
    };

    if m * n * k >= PAR_GEMM_MIN_FLOP && m > 1 {
        par_band_zip(c, n, a, k, |_, cb, ab| body(cb, ab));
    } else {
        body(c, a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::XorShift;

    fn naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut r = XorShift::new(seed);
        (0..n).map(|_| r.next_f64() - 0.5).collect()
    }

    fn check(m: usize, k: usize, n: usize) {
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let got = gemm(&a, &b, m, k, n);
        let want = naive(&a, &b, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10, "{} vs {} ({m}x{k}x{n})", g, w);
        }
    }

    #[test]
    fn small_shapes() {
        check(1, 1, 1);
        check(2, 3, 4);
        check(5, 1, 7);
        check(1, 9, 1);
        check(7, 7, 7);
    }

    #[test]
    fn blocked_shapes() {
        check(33, 300, 17); // crosses KC and MC boundaries
        check(64, 64, 64);
        check(100, 513, 3);
    }

    #[test]
    fn parallel_path() {
        check(200, 200, 200); // above PAR_GEMM_MIN_FLOP
    }

    #[test]
    fn matvec_path() {
        check(100, 700, 1);
    }

    #[test]
    fn accumulation_semantics() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut c = vec![10.0];
        gemm_into(&a, &b, &mut c, 1, 2, 1);
        assert_eq!(c, vec![10.0 + 3.0 + 8.0]);
    }
}
