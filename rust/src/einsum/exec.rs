//! Dense evaluation of the generic multiplication `C = A *_(s1,s2,s3) B`.
//!
//! Strategy (the classical einsum-to-GEMM reduction, as in `np.einsum` /
//! `opt_einsum`):
//!
//! 1. *Diagonalize*: repeated labels within one operand become a strided
//!    diagonal view that is materialised compactly.
//! 2. *Pre-reduce*: labels private to one operand and absent from the
//!    output are summed out immediately.
//! 3. *Classify* the remaining labels into **batch** (in A, B and out),
//!    **M** (A and out), **N** (B and out) and **K** (A and B, summed).
//! 4. Permute to `A[batch, M, K]`, `B[batch, K, N]`, run the blocked GEMM
//!    per batch slice (scoped threads over batches when the slices are
//!    small — thresholds in [`crate::util`]), and permute the
//!    `[batch, M, N]` result to the requested output order.
//!
//! This is the *interpreter* path: every step materialises a fresh
//! tensor. The write-into twin in [`super::plan`] shares the same GEMM
//! core ([`super::plan::batched_gemm`]) but resolves all staging at
//! compile time — this file stays allocating-and-simple on purpose, as
//! the reference oracle.

use super::plan::batched_gemm;
use super::spec::{EinSpec, Label};
use crate::tensor::{row_major_strides, Tensor};

/// Sum a tensor over the given (distinct) axes.
pub fn reduce_sum(t: &Tensor, axes: &[usize]) -> Tensor {
    if axes.is_empty() {
        return t.clone();
    }
    let keep: Vec<usize> = (0..t.order()).filter(|ax| !axes.contains(ax)).collect();
    let mut perm = keep.clone();
    perm.extend_from_slice(axes);
    let moved = t.permute(&perm);
    let keep_shape: Vec<usize> = keep.iter().map(|&ax| t.shape()[ax]).collect();
    let chunk: usize = axes.iter().map(|&ax| t.shape()[ax]).product();
    let out: Vec<f64> = moved
        .data()
        .chunks(chunk.max(1))
        .map(|c| c.iter().sum())
        .collect();
    Tensor::new(&keep_shape, out)
}

/// Materialise the diagonal view of an operand with repeated labels:
/// returns the tensor restricted to distinct labels (first-occurrence
/// order) together with those labels.
fn dedup(t: &Tensor, labels: &[Label]) -> (Tensor, Vec<Label>) {
    let mut distinct: Vec<Label> = Vec::new();
    for &l in labels {
        if !distinct.contains(&l) {
            distinct.push(l);
        }
    }
    if distinct.len() == labels.len() {
        return (t.clone(), distinct);
    }
    let strides_in = row_major_strides(t.shape());
    // combined stride and dim per distinct label
    let mut dims = Vec::with_capacity(distinct.len());
    let mut strides = Vec::with_capacity(distinct.len());
    for &l in &distinct {
        let mut s = 0usize;
        let mut d = 0usize;
        for (pos, &ll) in labels.iter().enumerate() {
            if ll == l {
                s += strides_in[pos];
                d = t.shape()[pos];
            }
        }
        dims.push(d);
        strides.push(s);
    }
    let n: usize = dims.iter().product();
    let mut out = vec![0.0; n];
    let rank = dims.len();
    let mut idx = vec![0usize; rank];
    let mut src = 0usize;
    for slot in out.iter_mut() {
        *slot = t.data()[src];
        for ax in (0..rank).rev() {
            idx[ax] += 1;
            src += strides[ax];
            if idx[ax] < dims[ax] {
                break;
            }
            src -= strides[ax] * dims[ax];
            idx[ax] = 0;
        }
        if rank == 0 {
            break;
        }
    }
    (Tensor::new(&dims, out), distinct)
}

/// Sum out labels private to this operand that are not in the output.
fn presum(t: Tensor, labels: Vec<Label>, other: &[Label], out: &[Label]) -> (Tensor, Vec<Label>) {
    let dead: Vec<usize> = labels
        .iter()
        .enumerate()
        .filter(|(_, l)| !other.contains(l) && !out.contains(l))
        .map(|(ax, _)| ax)
        .collect();
    if dead.is_empty() {
        return (t, labels);
    }
    let kept: Vec<Label> = labels
        .iter()
        .enumerate()
        .filter(|(ax, _)| !dead.contains(ax))
        .map(|(_, &l)| l)
        .collect();
    (reduce_sum(&t, &dead), kept)
}

/// Permute `t` (with `labels`) into the axis order given by `target`.
fn to_order(t: &Tensor, labels: &[Label], target: &[Label]) -> Tensor {
    debug_assert_eq!(labels.len(), target.len());
    let perm: Vec<usize> = target
        .iter()
        .map(|l| labels.iter().position(|ll| ll == l).expect("label missing in to_order"))
        .collect();
    if perm.iter().enumerate().all(|(i, &p)| i == p) {
        t.clone()
    } else {
        t.permute(&perm)
    }
}

/// Evaluate `A *_(s1,s2,s3) B` on dense tensors.
pub fn einsum(spec: &EinSpec, a: &Tensor, b: &Tensor) -> Tensor {
    let out_shape = spec
        .output_shape(a.shape(), b.shape())
        .unwrap_or_else(|e| panic!("einsum shape error: {}", e));

    // Fast path: aligned element-wise multiplication (`s1 == s2 == s3`,
    // distinct labels — the ⊙ rows of Table 1).
    if spec.is_elementwise() && has_distinct(&spec.s1) {
        return a.mul_elem(b);
    }

    let (a_t, a_l) = dedup(a, &spec.s1);
    let (b_t, b_l) = dedup(b, &spec.s2);
    let (a_t, a_l) = presum(a_t, a_l, &b_l, &spec.s3);
    let (b_t, b_l) = presum(b_t, b_l, &a_l, &spec.s3);

    // Scalar operand → pure scale of the other side.
    if b_l.is_empty() {
        let m_labels: Vec<Label> = spec.s3.clone();
        let scaled = a_t.scale(b_t.item());
        return to_order(&scaled, &a_l, &m_labels);
    }
    if a_l.is_empty() {
        let n_labels: Vec<Label> = spec.s3.clone();
        let scaled = b_t.scale(a_t.item());
        return to_order(&scaled, &b_l, &n_labels);
    }

    // Classify surviving labels.
    let batch: Vec<Label> = spec
        .s3
        .iter()
        .filter(|l| a_l.contains(l) && b_l.contains(l))
        .copied()
        .collect();
    let m_labels: Vec<Label> = a_l
        .iter()
        .filter(|l| spec.s3.contains(l) && !b_l.contains(l))
        .copied()
        .collect();
    let n_labels: Vec<Label> = b_l
        .iter()
        .filter(|l| spec.s3.contains(l) && !a_l.contains(l))
        .copied()
        .collect();
    let k_labels: Vec<Label> = a_l
        .iter()
        .filter(|l| b_l.contains(l) && !spec.s3.contains(l))
        .copied()
        .collect();

    let dim_of = |l: Label| -> usize {
        a_l.iter()
            .position(|&ll| ll == l)
            .map(|p| a_t.shape()[p])
            .or_else(|| b_l.iter().position(|&ll| ll == l).map(|p| b_t.shape()[p]))
            .unwrap()
    };

    let mut a_order = batch.clone();
    a_order.extend(&m_labels);
    a_order.extend(&k_labels);
    let mut b_order = batch.clone();
    b_order.extend(&k_labels);
    b_order.extend(&n_labels);
    let a_g = to_order(&a_t, &a_l, &a_order);
    let b_g = to_order(&b_t, &b_l, &b_order);

    let bsz: usize = batch.iter().map(|&l| dim_of(l)).product();
    let m: usize = m_labels.iter().map(|&l| dim_of(l)).product();
    let k: usize = k_labels.iter().map(|&l| dim_of(l)).product();
    let n: usize = n_labels.iter().map(|&l| dim_of(l)).product();

    let mut c = vec![0.0; bsz * m * n];
    batched_gemm(
        a_g.data(),
        b_g.data(),
        &mut c,
        bsz,
        m,
        k,
        n,
        k_labels.is_empty(),
    );

    let mut res_labels = batch;
    res_labels.extend(&m_labels);
    res_labels.extend(&n_labels);
    let res_shape: Vec<usize> = res_labels.iter().map(|&l| dim_of(l)).collect();
    let res = Tensor::new(&res_shape, c);
    let out = to_order(&res, &res_labels, &spec.s3);
    debug_assert_eq!(out.shape(), &out_shape[..]);
    out
}

/// True if no label repeats within `ls`.
pub(super) fn has_distinct(ls: &[Label]) -> bool {
    ls.iter().enumerate().all(|(i, l)| !ls[i + 1..].contains(l))
}

/// Brute-force reference: iterate every (output ∪ summed) index tuple.
/// Exponential in the label count — this is the *oracle* the differential
/// test suites (`tests/exec_equivalence.rs`, `tests/property.rs`) pin
/// both the interpreter and the compiled executor against.
pub fn einsum_naive(spec: &EinSpec, a: &Tensor, b: &Tensor) -> Tensor {
    let out_shape = spec.output_shape(a.shape(), b.shape()).unwrap();
    // label -> dim
    let mut labels: Vec<Label> = Vec::new();
    let mut dims: Vec<usize> = Vec::new();
    for (&l, &d) in spec.s1.iter().zip(a.shape()).chain(spec.s2.iter().zip(b.shape())) {
        if !labels.contains(&l) {
            labels.push(l);
            dims.push(d);
        }
    }
    let total: usize = dims.iter().product::<usize>().max(1);
    let mut out = Tensor::zeros(&out_shape);
    let pos = |l: Label| labels.iter().position(|&x| x == l).unwrap();
    for flat in 0..total {
        // decode assignment
        let mut assign = vec![0usize; labels.len()];
        let mut rem = flat;
        for i in (0..labels.len()).rev() {
            assign[i] = rem % dims[i];
            rem /= dims[i];
        }
        let ai: Vec<usize> = spec.s1.iter().map(|&l| assign[pos(l)]).collect();
        let bi: Vec<usize> = spec.s2.iter().map(|&l| assign[pos(l)]).collect();
        let oi: Vec<usize> = spec.s3.iter().map(|&l| assign[pos(l)]).collect();
        let mut oflat = 0usize;
        for (x, &d) in oi.iter().zip(&out_shape) {
            oflat = oflat * d + x;
        }
        out.data_mut()[oflat] += a.at(&ai) * b.at(&bi);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(sig: &str, a_shape: &[usize], b_shape: &[usize]) {
        let spec = EinSpec::parse(sig);
        let a = Tensor::randn(a_shape, 11);
        let b = Tensor::randn(b_shape, 22);
        let fast = einsum(&spec, &a, &b);
        let slow = einsum_naive(&spec, &a, &b);
        assert!(
            fast.allclose(&slow, 1e-9, 1e-9),
            "{} mismatch: max diff {}",
            sig,
            fast.max_abs_diff(&slow)
        );
    }

    #[test]
    fn matmul_family() {
        check("ij,jk->ik", &[4, 5], &[5, 6]);
        check("ji,jk->ik", &[5, 4], &[5, 6]); // AᵀB
        check("ij,kj->ik", &[4, 5], &[6, 5]); // ABᵀ
        check("ij,j->i", &[4, 5], &[5]); // matvec
        check("i,ij->j", &[4], &[4, 5]); // vecmat
        check("i,i->", &[7], &[7]); // dot
    }

    #[test]
    fn outer_and_elementwise() {
        check("i,j->ij", &[3], &[4]);
        check("i,i->i", &[5], &[5]);
        check("ij,ij->ij", &[3, 4], &[3, 4]);
        check("ij,i->ij", &[3, 4], &[3]); // diag-scale rows
        check("ij,j->ij", &[3, 4], &[4]); // diag-scale cols
    }

    #[test]
    fn reductions() {
        check("ij,->i", &[3, 4], &[]); // row sums via scalar 1
        check("ij,->", &[3, 4], &[]); // total sum
        check("ijk,->ik", &[2, 3, 4], &[]);
        check("ij,ij->", &[3, 4], &[3, 4]); // full contraction
        check("ij,ij->i", &[3, 4], &[3, 4]); // row-wise dot
    }

    #[test]
    fn higher_order() {
        check("ijk,kl->ijl", &[2, 3, 4], &[4, 5]);
        check("ijkl,kl->ij", &[2, 3, 4, 5], &[4, 5]);
        check("ijkl,jl->ik", &[2, 3, 4, 3], &[3, 3]);
        check("ij,kl->ijkl", &[2, 3], &[4, 5]); // big outer
        check("abc,cd->abd", &[3, 2, 4], &[4, 2]);
        check("aij,ajk->aik", &[3, 2, 4], &[3, 4, 2]); // batched matmul
    }

    #[test]
    fn diagonal_specs() {
        check("ii,->i", &[4, 4], &[]); // diag extraction
        check("ii,->", &[4, 4], &[]); // trace
        check("ij,ii->j", &[4, 4], &[4, 4]);
        check("iji,j->ij", &[3, 4, 3], &[4]);
    }

    #[test]
    fn private_label_presum() {
        check("ij,k->i", &[3, 4], &[5]); // j and k summed privately
        check("ijk,l->ik", &[2, 3, 4], &[5]);
    }

    #[test]
    fn permuted_outputs() {
        check("ij,jk->ki", &[3, 4], &[4, 5]);
        check("ijk,->kji", &[2, 3, 4], &[]);
        check("ij,kl->ljki", &[2, 3], &[4, 5]);
    }

    #[test]
    fn scalar_operands() {
        check(",->", &[], &[]);
        check("ij,->ij", &[3, 4], &[]);
        check(",ij->ij", &[], &[3, 4]);
    }

    #[test]
    fn parallel_batched_path() {
        // bsz large, small per-batch gemms → exercises the parallel batch path
        check("aij,ajk->aik", &[300, 4, 4], &[300, 4, 4]);
    }

    #[test]
    fn delta_contraction_numeric() {
        // A[i,j] δ[j,k] summed over j must equal relabeling j→k.
        let a = Tensor::randn(&[3, 4], 5);
        let d = Tensor::delta(&[4]);
        let spec = EinSpec::parse("ij,jk->ik");
        let out = einsum(&spec, &a, &d);
        assert!(out.allclose(&a, 1e-12, 1e-12));
    }

    #[test]
    fn matfac_compression_identity() {
        // H[i,j,k,l] = M[j,l]·δ[i,k]: materialised vs compressed semantics.
        let m = Tensor::randn(&[3, 3], 8);
        let d = Tensor::delta(&[5]);
        let spec = EinSpec::parse("jl,ik->ijkl");
        let h = einsum(&spec, &m, &d);
        assert_eq!(h.shape(), &[5, 3, 5, 3]);
        for i in 0..5 {
            for j in 0..3 {
                for k in 0..5 {
                    for l in 0..3 {
                        let want = if i == k { m.at(&[j, l]) } else { 0.0 };
                        assert!((h.at(&[i, j, k, l]) - want).abs() < 1e-12);
                    }
                }
            }
        }
    }
}
