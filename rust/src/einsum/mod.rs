//! The generic Einstein-notation tensor multiplication `C = A *_(s1,s2,s3) B`
//! (Section 2 of the paper) and its dense evaluation engine.
//!
//! The semantics is
//!
//! ```text
//! C[s3] = Σ_{(s1 ∪ s2) \ s3}  A[s1] · B[s2]        with  s3 ⊆ s1 ∪ s2
//! ```
//!
//! which is exactly NumPy/TF/PyTorch `einsum` restricted to two operands.
//! [`EinSpec`] carries the three ordered label lists; [`einsum`] evaluates
//! a spec on dense tensors by reduction to batched GEMM with fast paths
//! for element-wise, scale/reduce and broadcast shapes.
//!
//! Two evaluation paths share the GEMM core:
//!
//! * [`einsum`] — the allocating *interpreter* path (one fresh tensor per
//!   step); simple, independently tested, and kept as the reference
//!   oracle for the compiled executor.
//! * [`einsum_into`] / [`EinsumPlan`] — the *write-into* path used by
//!   [`crate::exec`]: gathers, pre-sums and permutations are fused into
//!   strided passes over reused [`EinScratch`] buffers and the result is
//!   written into a caller-provided (typically pooled) buffer.

mod exec;
mod gemm;
mod plan;
mod spec;

pub use exec::{einsum, einsum_naive, reduce_sum};
pub use gemm::{gemm, gemm_into};
pub use plan::{einsum_into, EinScratch, EinsumPlan};
pub use spec::{EinSpec, Label};
