//! The generic Einstein-notation tensor multiplication `C = A *_(s1,s2,s3) B`
//! (Section 2 of the paper) and its dense evaluation engine.
//!
//! The semantics is
//!
//! ```text
//! C[s3] = Σ_{(s1 ∪ s2) \ s3}  A[s1] · B[s2]        with  s3 ⊆ s1 ∪ s2
//! ```
//!
//! which is exactly NumPy/TF/PyTorch `einsum` restricted to two operands.
//! [`EinSpec`] carries the three ordered label lists; [`einsum`] evaluates
//! a spec on dense tensors by reduction to batched GEMM with fast paths
//! for element-wise, scale/reduce and broadcast shapes.

mod exec;
mod gemm;
mod spec;

pub use exec::{einsum, reduce_sum};
pub use gemm::{gemm, gemm_into};
pub use spec::{EinSpec, Label};
