//! The generic Einstein-notation tensor multiplication `C = A *_(s1,s2,s3) B`
//! (Section 2 of the paper) and its dense evaluation engine.
//!
//! The semantics is
//!
//! ```text
//! C[s3] = Σ_{(s1 ∪ s2) \ s3}  A[s1] · B[s2]        with  s3 ⊆ s1 ∪ s2
//! ```
//!
//! which is exactly NumPy/TF/PyTorch `einsum` restricted to two operands.
//! [`EinSpec`] carries the three ordered label lists; [`einsum`] evaluates
//! a spec on dense tensors by reduction to batched GEMM with fast paths
//! for element-wise, scale/reduce and broadcast shapes.
//!
//! Two evaluation paths share the GEMM core:
//!
//! * [`einsum`] — the allocating *interpreter* path (one fresh tensor per
//!   step); simple, independently tested, and kept as the reference
//!   oracle for the compiled executor.
//! * [`einsum_into`] / [`EinsumPlan`] — the *write-into* path used by
//!   [`crate::exec`]: gathers, pre-sums and permutations are fused into
//!   strided passes over reused [`EinScratch`] buffers and the result is
//!   written into a caller-provided (typically pooled) buffer.
//!
//! Both bottom out in the tiled GEMM kernel ([`gemm_into`]): register
//! microkernel, packed cache-blocked panels, scoped-thread row bands,
//! and a per-tile epilogue hook ([`TileEpilogue`]) that lets fused
//! element-wise chains run on each output tile right after its final
//! k-accumulation, while the tile is cache-hot. The pre-tiling flat
//! kernel survives as [`gemm_into_flat`], the differential/ablation
//! baseline.
//!
//! # Example
//!
//! ```
//! use tensorcalc::einsum::{einsum, EinSpec};
//! use tensorcalc::tensor::Tensor;
//!
//! // matrix product: C[i,k] = Σ_j A[i,j] · B[j,k]
//! let spec = EinSpec::parse("ij,jk->ik");
//! let a = Tensor::randn(&[2, 3], 1);
//! let b = Tensor::randn(&[3, 4], 2);
//! let c = einsum(&spec, &a, &b);
//! assert_eq!(c.shape(), &[2, 4]);
//!
//! // the same spec also covers traces, diagonals and broadcasts:
//! // tr(M) via "ii,->"
//! let m = Tensor::randn(&[5, 5], 3);
//! let tr = einsum(&EinSpec::parse("ii,->"), &m, &Tensor::scalar(1.0));
//! let want: f64 = (0..5).map(|i| m.at(&[i, i])).sum();
//! assert!((tr.item() - want).abs() < 1e-12);
//! ```

mod exec;
mod gemm;
mod plan;
mod spec;

pub use exec::{einsum, einsum_naive, reduce_sum};
pub use gemm::{gemm, gemm_into, gemm_into_epi, gemm_into_flat, EpiFn, NoEpilogue, TileEpilogue};
pub(crate) use gemm::tune_probe;
pub use plan::{einsum_into, EinScratch, EinsumPlan, ScratchSizes};
pub use spec::{EinSpec, Label};
