//! Write-into einsum: a contraction compiled once per `(spec, shapes)`
//! pair and then executed into caller-provided buffers — the
//! allocation-free core of the compiled executor ([`crate::exec`]).
//!
//! Where the interpreter path ([`super::exec::einsum`]) materialises a
//! fresh tensor for every `dedup` / `presum` / `to_order` step, an
//! [`EinsumPlan`] resolves all of that at *compile* time into three
//! strided passes over reused scratch:
//!
//! 1. **gather** each operand (diagonal extraction via combined strides,
//!    private-label pre-summation, and permutation to GEMM order fused
//!    into one strided sweep),
//! 2. **batched GEMM** into scratch (or straight into the output buffer
//!    when no final permutation is needed),
//! 3. **permute** the `[batch, M, N]` product into the requested output
//!    order with one strided read / contiguous write.
//!
//! After warm-up no step allocates: scratch buffers grow to their peak
//! size once and are reused on every subsequent execution. (The tiled
//! GEMM's packing scratch is thread-local and follows the same
//! grow-once pattern on long-lived threads; scoped row-band workers are
//! born per call and re-grow theirs — bounded by one A block each.)
//!
//! Fused element-wise chains riding on a contraction enter here through
//! two doors: [`EinsumPlan::run_with_epilogue`] (the two-pass reference
//! — contract, then sweep the output once more) and
//! [`EinsumPlan::run_with_epilogue_in_tile`] (the hot path — the
//! epilogue runs inside the GEMM tile loop, right after each tile's
//! final k-accumulation, erasing the second memory pass).

use super::exec::has_distinct;
use super::gemm::{gemm_into_epi, NoEpilogue, TileEpilogue};
use super::spec::{EinSpec, Label};
use crate::tensor::{row_major_strides, Tensor};
use crate::util::simd::{mul_into, mul_scalar_into, scale_assign};
use crate::util::{par_band_zip2, PAR_BATCH_SLICE_MAX_FLOP, PAR_BATCH_TOTAL_MIN_FLOP};

/// Reusable scratch for [`einsum_into`] / [`EinsumPlan::run`]: two
/// operand staging buffers, the pre-permutation product buffer, and the
/// odometer index vector. All grow monotonically and are reused across
/// calls, so a warmed-up scratch never allocates.
///
/// The compiled executor's planned-memory mode does not use this type at
/// all: the `a`/`b`/`c` regions are assigned fixed offsets in the plan's
/// arena at compile time (their sizes are known via
/// [`EinsumPlan::scratch_sizes`]) and handed to
/// [`EinsumPlan::run_planned`] as slices.
#[derive(Default)]
pub struct EinScratch {
    a: Vec<f64>,
    b: Vec<f64>,
    c: Vec<f64>,
    idx: Vec<usize>,
}

/// Compile-time element counts of the scratch regions one execution of a
/// plan needs: gather staging for each operand (`a`, `b`) and the
/// pre-permutation product buffer (`c`). All zero for the non-GEMM kinds,
/// for operands already in GEMM order, and for contractions that write
/// straight into the output.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchSizes {
    pub a: usize,
    pub b: usize,
    pub c: usize,
}

/// Grow `v` to at least `n` elements (zero-filling only the new tail);
/// never shrinks, so warmed-up scratch stays allocation-free.
fn ensure_len(v: &mut Vec<f64>, n: usize) {
    if v.len() < n {
        v.resize(n, 0.0);
    }
}

/// One fused gather: reads a strided (possibly diagonal) view of the
/// source operand, sums out the private ("dead") axes, and writes the
/// surviving axes in target order. Every destination slot is assigned
/// (never accumulated into), so destination buffers need no pre-zeroing.
struct Gather {
    /// destination shape (target order)
    out_dims: Vec<usize>,
    /// source stride per destination axis (diagonal repeats pre-summed)
    out_strides: Vec<usize>,
    /// summed-out axes: dims and source strides
    dead_dims: Vec<usize>,
    dead_strides: Vec<usize>,
    /// Π dead_dims (1 for the empty product; 0 if any dead axis is
    /// empty, in which case the sum is the empty sum, 0.0)
    dead_total: usize,
    /// Π out_dims — the destination length
    n_out: usize,
}

impl Gather {
    fn new(op: &Operand, target: &[usize]) -> Gather {
        let out_dims: Vec<usize> = target.iter().map(|&i| op.dims[i]).collect();
        let out_strides: Vec<usize> = target.iter().map(|&i| op.strides[i]).collect();
        let dead_dims: Vec<usize> = op.dead.iter().map(|&i| op.dims[i]).collect();
        let dead_strides: Vec<usize> = op.dead.iter().map(|&i| op.strides[i]).collect();
        let dead_total = dead_dims.iter().product::<usize>();
        let n_out = out_dims.iter().product();
        Gather { out_dims, out_strides, dead_dims, dead_strides, dead_total, n_out }
    }

    /// `dst[target multi-index] = Σ_{dead} src[strided index]`. `dst`
    /// must hold exactly `n_out` elements; `idx` is odometer scratch.
    fn run(&self, src: &[f64], dst: &mut [f64], idx: &mut Vec<usize>) {
        debug_assert_eq!(dst.len(), self.n_out);
        if self.n_out == 0 {
            return;
        }
        let rank = self.out_dims.len();
        let drank = self.dead_dims.len();
        idx.clear();
        idx.resize(rank + drank, 0);
        let (oidx, didx) = idx.split_at_mut(rank);
        let mut base = 0usize;
        for slot in dst.iter_mut() {
            let mut s = 0.0;
            if drank == 0 {
                s = src[base];
            } else {
                // odometer over the dead axes with a running offset; a
                // full sweep wraps didx back to all zeros and off to 0
                let mut off = 0usize;
                for _ in 0..self.dead_total {
                    s += src[base + off];
                    for ax in (0..drank).rev() {
                        didx[ax] += 1;
                        off += self.dead_strides[ax];
                        if didx[ax] < self.dead_dims[ax] {
                            break;
                        }
                        off -= self.dead_strides[ax] * self.dead_dims[ax];
                        didx[ax] = 0;
                    }
                }
            }
            *slot = s;
            // advance the destination odometer, tracking the source base
            for ax in (0..rank).rev() {
                oidx[ax] += 1;
                base += self.out_strides[ax];
                if oidx[ax] < self.out_dims[ax] {
                    break;
                }
                base -= self.out_strides[ax] * self.out_dims[ax];
                oidx[ax] = 0;
            }
        }
    }
}

/// Compile-time analysis of one operand: distinct labels with their dims
/// and combined (diagonal) strides, split into surviving and pre-summed
/// axes.
struct Operand {
    /// distinct labels, first-occurrence order
    labels: Vec<Label>,
    dims: Vec<usize>,
    /// source stride per distinct label (repeats summed → diagonal view)
    strides: Vec<usize>,
    /// indices (into `labels`) of axes that survive the pre-sum
    kept: Vec<usize>,
    /// indices of axes private to this operand and absent from the output
    dead: Vec<usize>,
    /// the operand had no repeated labels (no diagonal extraction)
    no_repeats: bool,
}

impl Operand {
    fn analyze(labels: &[Label], shape: &[usize], other: &[Label], out: &[Label]) -> Operand {
        let strides_in = row_major_strides(shape);
        let mut distinct: Vec<Label> = Vec::new();
        for &l in labels {
            if !distinct.contains(&l) {
                distinct.push(l);
            }
        }
        let no_repeats = distinct.len() == labels.len();
        let mut dims = Vec::with_capacity(distinct.len());
        let mut strides = Vec::with_capacity(distinct.len());
        for &l in &distinct {
            let mut s = 0usize;
            let mut d = 0usize;
            for (pos, &ll) in labels.iter().enumerate() {
                if ll == l {
                    s += strides_in[pos];
                    d = shape[pos];
                }
            }
            dims.push(d);
            strides.push(s);
        }
        let mut kept = Vec::new();
        let mut dead = Vec::new();
        for (i, &l) in distinct.iter().enumerate() {
            if other.contains(&l) || out.contains(&l) {
                kept.push(i);
            } else {
                dead.push(i);
            }
        }
        Operand { labels: distinct, dims, strides, kept, dead, no_repeats }
    }

    /// Position of `l` among the distinct labels (must exist).
    fn pos(&self, l: Label) -> usize {
        self.labels.iter().position(|&x| x == l).expect("label not in operand")
    }
}

enum Kind {
    /// `s1 == s2 == s3` with distinct labels: `out = a ⊙ b`.
    Elementwise,
    /// The right operand reduces to a scalar: `out = gather(a) · Σ(b)`.
    ScaleA { a_gather: Gather, b_sum: Gather },
    /// The left operand reduces to a scalar: `out = gather(b) · Σ(a)`.
    ScaleB { b_gather: Gather, a_sum: Gather },
    /// The general case: gather to `[batch, M, K]` × `[batch, K, N]`,
    /// batched GEMM, permute to the requested output order.
    Gemm {
        /// `None` when the operand is already in GEMM order (borrowed).
        a_gather: Option<Gather>,
        b_gather: Option<Gather>,
        bsz: usize,
        m: usize,
        k: usize,
        n: usize,
        /// no label is contracted (outer/broadcast shapes)
        k_empty: bool,
        /// source strides into the `[batch, M, N]` product per output
        /// axis; `None` when the product order already matches `s3`
        /// (GEMM then writes straight into the output buffer).
        out_read: Option<Vec<usize>>,
    },
}

/// A contraction compiled for fixed operand shapes: run it any number of
/// times against tensors of those shapes with [`EinsumPlan::run`].
pub struct EinsumPlan {
    out_shape: Vec<usize>,
    /// Π over all distinct label dims — the iteration-space flop proxy.
    iter_space: usize,
    kind: Kind,
}

impl EinsumPlan {
    /// Compile `spec` for the given operand shapes. Panics on rank or
    /// dimension mismatches (same contract as [`super::einsum`]).
    pub fn new(spec: &EinSpec, a_shape: &[usize], b_shape: &[usize]) -> EinsumPlan {
        let out_shape = spec
            .output_shape(a_shape, b_shape)
            .unwrap_or_else(|e| panic!("einsum shape error: {}", e));

        // flop proxy: product of every distinct label's dimension
        let mut seen: Vec<Label> = Vec::new();
        let mut iter_space = 1usize;
        for (&l, &d) in spec.s1.iter().zip(a_shape).chain(spec.s2.iter().zip(b_shape)) {
            if !seen.contains(&l) {
                seen.push(l);
                iter_space = iter_space.saturating_mul(d);
            }
        }

        if spec.is_elementwise() && has_distinct(&spec.s1) {
            return EinsumPlan { out_shape, iter_space, kind: Kind::Elementwise };
        }

        let a_op = Operand::analyze(&spec.s1, a_shape, &spec.s2, &spec.s3);
        let b_op = Operand::analyze(&spec.s2, b_shape, &spec.s1, &spec.s3);
        let a_kept: Vec<Label> = a_op.kept.iter().map(|&i| a_op.labels[i]).collect();
        let b_kept: Vec<Label> = b_op.kept.iter().map(|&i| b_op.labels[i]).collect();

        // A scalar operand turns the contraction into a gather + scale.
        // (When one side keeps no labels, every output label lives on the
        // other side — see the presum invariants in super::exec.)
        if b_kept.is_empty() {
            let target: Vec<usize> = spec.s3.iter().map(|&l| a_op.pos(l)).collect();
            let kind = Kind::ScaleA {
                a_gather: Gather::new(&a_op, &target),
                b_sum: Gather::new(&b_op, &[]),
            };
            return EinsumPlan { out_shape, iter_space, kind };
        }
        if a_kept.is_empty() {
            let target: Vec<usize> = spec.s3.iter().map(|&l| b_op.pos(l)).collect();
            let kind = Kind::ScaleB {
                b_gather: Gather::new(&b_op, &target),
                a_sum: Gather::new(&a_op, &[]),
            };
            return EinsumPlan { out_shape, iter_space, kind };
        }

        // Classify surviving labels exactly as the interpreter does.
        let batch: Vec<Label> = spec
            .s3
            .iter()
            .filter(|l| a_kept.contains(l) && b_kept.contains(l))
            .copied()
            .collect();
        let m_labels: Vec<Label> = a_kept
            .iter()
            .filter(|l| spec.s3.contains(l) && !b_kept.contains(l))
            .copied()
            .collect();
        let n_labels: Vec<Label> = b_kept
            .iter()
            .filter(|l| spec.s3.contains(l) && !a_kept.contains(l))
            .copied()
            .collect();
        let k_labels: Vec<Label> = a_kept
            .iter()
            .filter(|l| b_kept.contains(l) && !spec.s3.contains(l))
            .copied()
            .collect();

        let dim_of = |l: Label| -> usize {
            a_op.labels
                .iter()
                .position(|&ll| ll == l)
                .map(|p| a_op.dims[p])
                .unwrap_or_else(|| b_op.dims[b_op.pos(l)])
        };

        let mut a_order: Vec<Label> = batch.clone();
        a_order.extend(&m_labels);
        a_order.extend(&k_labels);
        let mut b_order: Vec<Label> = batch.clone();
        b_order.extend(&k_labels);
        b_order.extend(&n_labels);
        let a_target: Vec<usize> = a_order.iter().map(|&l| a_op.pos(l)).collect();
        let b_target: Vec<usize> = b_order.iter().map(|&l| b_op.pos(l)).collect();

        let identity =
            |op: &Operand, target: &[usize]| -> bool {
                op.no_repeats
                    && op.dead.is_empty()
                    && target.iter().enumerate().all(|(i, &t)| i == t)
            };
        let a_gather =
            if identity(&a_op, &a_target) { None } else { Some(Gather::new(&a_op, &a_target)) };
        let b_gather =
            if identity(&b_op, &b_target) { None } else { Some(Gather::new(&b_op, &b_target)) };

        let bsz: usize = batch.iter().map(|&l| dim_of(l)).product();
        let m: usize = m_labels.iter().map(|&l| dim_of(l)).product();
        let k: usize = k_labels.iter().map(|&l| dim_of(l)).product();
        let n: usize = n_labels.iter().map(|&l| dim_of(l)).product();

        let mut res_labels: Vec<Label> = batch;
        res_labels.extend(&m_labels);
        res_labels.extend(&n_labels);
        let out_read = if res_labels == spec.s3 {
            None
        } else {
            let res_dims: Vec<usize> = res_labels.iter().map(|&l| dim_of(l)).collect();
            let res_strides = row_major_strides(&res_dims);
            let strides: Vec<usize> = spec
                .s3
                .iter()
                .map(|l| {
                    let p = res_labels.iter().position(|ll| ll == l).expect("output label");
                    res_strides[p]
                })
                .collect();
            Some(strides)
        };

        let kind = Kind::Gemm {
            a_gather,
            b_gather,
            bsz,
            m,
            k,
            n,
            k_empty: k_labels.is_empty(),
            out_read,
        };
        EinsumPlan { out_shape, iter_space, kind }
    }

    /// The output shape this plan produces.
    pub fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }

    /// Product of all distinct label dims — a cheap flop estimate used
    /// by the executor's parallelism gate.
    pub fn iteration_space(&self) -> usize {
        self.iter_space
    }

    /// Execute the contraction into `out` (shape-checked), reusing
    /// `scratch`. Every element of `out` is written.
    pub fn run(&self, a: &Tensor, b: &Tensor, out: &mut Tensor, scratch: &mut EinScratch) {
        self.run_epi(a, b, out, scratch, &NoEpilogue);
    }

    /// Execute the contraction into `out`, then apply `epilogue` to the
    /// freshly written output data — the **two-pass reference** hook the
    /// compiled executor uses to fuse trailing element-wise chains onto
    /// a contraction without a separate buffer (and its
    /// `EpilogueMode::TwoPass` ablation baseline). The epilogue here is
    /// always a second full sweep over `out`; see
    /// [`EinsumPlan::run_with_epilogue_in_tile`] for the in-tile form
    /// that erases that memory pass.
    pub fn run_with_epilogue<F: FnOnce(&mut [f64])>(
        &self,
        a: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
        scratch: &mut EinScratch,
        epilogue: F,
    ) {
        self.run(a, b, out, scratch);
        epilogue(out.data_mut());
    }

    /// Execute the contraction with `epi` pushed into the GEMM tile
    /// loop: every output element receives exactly one `epi` application
    /// immediately after its final k-accumulation, while the tile is
    /// still cache-hot — no second sweep over the output buffer.
    ///
    /// Plans whose GEMM result needs a final permutation (`out_read`)
    /// and the non-GEMM kinds fall back to op-then-sweep, which is
    /// semantically identical (the two-pass reference
    /// [`EinsumPlan::run_with_epilogue`] and this method agree
    /// bit-for-bit on every plan kind).
    pub fn run_with_epilogue_in_tile<E: TileEpilogue>(
        &self,
        a: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
        scratch: &mut EinScratch,
        epi: &E,
    ) {
        self.run_epi(a, b, out, scratch, epi);
    }

    /// Element counts of the scratch regions one execution needs. The
    /// compiled executor's memory planner uses this to reserve fixed
    /// arena offsets for them at compile time.
    pub fn scratch_sizes(&self) -> ScratchSizes {
        match &self.kind {
            Kind::Gemm { a_gather, b_gather, bsz, m, n, out_read, .. } => ScratchSizes {
                a: a_gather.as_ref().map_or(0, |g| g.n_out),
                b: b_gather.as_ref().map_or(0, |g| g.n_out),
                c: if out_read.is_some() { bsz * m * n } else { 0 },
            },
            _ => ScratchSizes::default(),
        }
    }

    /// Shape-checking wrapper over [`EinsumPlan::run_core`] that stages
    /// the scratch regions in a (growing, reused) [`EinScratch`]. `run`
    /// instantiates the epilogue with [`NoEpilogue`], which the optimizer
    /// erases.
    fn run_epi<E: TileEpilogue>(
        &self,
        a: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
        scratch: &mut EinScratch,
        epi: &E,
    ) {
        assert_eq!(
            out.shape(),
            &self.out_shape[..],
            "einsum_into: output buffer has the wrong shape"
        );
        let ss = self.scratch_sizes();
        ensure_len(&mut scratch.a, ss.a);
        ensure_len(&mut scratch.b, ss.b);
        ensure_len(&mut scratch.c, ss.c);
        let EinScratch { a: sa, b: sb, c: sc, idx } = scratch;
        self.run_core(
            a.data(),
            b.data(),
            out.data_mut(),
            &mut sa[..ss.a],
            &mut sb[..ss.b],
            &mut sc[..ss.c],
            idx,
            epi,
        );
    }

    /// Execute the contraction over raw slices with caller-provided
    /// scratch — the planned-arena entry point of the compiled executor:
    /// `sa`/`sb`/`sc` are fixed arena regions sized exactly by
    /// [`EinsumPlan::scratch_sizes`], so the call performs no allocation
    /// and takes no lock. Semantically identical to [`EinsumPlan::run`] /
    /// [`EinsumPlan::run_with_epilogue_in_tile`] (bit-for-bit: same core).
    #[allow(clippy::too_many_arguments)]
    pub fn run_planned<E: TileEpilogue>(
        &self,
        a: &[f64],
        b: &[f64],
        out: &mut [f64],
        sa: &mut [f64],
        sb: &mut [f64],
        sc: &mut [f64],
        idx: &mut Vec<usize>,
        epi: &E,
    ) {
        self.run_core(a, b, out, sa, sb, sc, idx, epi);
    }

    /// Shared execution core over raw slices: `sa`/`sb`/`sc` must be
    /// exactly [`EinsumPlan::scratch_sizes`] long (the planned executor
    /// hands arena slices, the pooled path resized [`EinScratch`]
    /// vectors). The epilogue is applied exactly once to every output
    /// element — in-tile on the straight-to-output GEMM path, as a
    /// trailing sweep everywhere else.
    #[allow(clippy::too_many_arguments)]
    fn run_core<E: TileEpilogue>(
        &self,
        a: &[f64],
        b: &[f64],
        out_data: &mut [f64],
        sa: &mut [f64],
        sb: &mut [f64],
        sc: &mut [f64],
        idx: &mut Vec<usize>,
        epi: &E,
    ) {
        debug_assert_eq!(out_data.len(), self.out_shape.iter().product::<usize>());
        match &self.kind {
            Kind::Elementwise => {
                mul_into(out_data, a, b);
                epi.apply(0, out_data);
            }
            Kind::ScaleA { a_gather, b_sum } => {
                a_gather.run(a, out_data, idx);
                let mut s = [0.0f64];
                b_sum.run(b, &mut s, idx);
                if s[0] != 1.0 {
                    scale_assign(out_data, s[0]);
                }
                epi.apply(0, out_data);
            }
            Kind::ScaleB { b_gather, a_sum } => {
                b_gather.run(b, out_data, idx);
                let mut s = [0.0f64];
                a_sum.run(a, &mut s, idx);
                if s[0] != 1.0 {
                    scale_assign(out_data, s[0]);
                }
                epi.apply(0, out_data);
            }
            Kind::Gemm { a_gather, b_gather, bsz, m, k, n, k_empty, out_read } => {
                let (bsz, m, k, n) = (*bsz, *m, *k, *n);
                let a_data: &[f64] = match a_gather {
                    None => a,
                    Some(gth) => {
                        gth.run(a, sa, idx);
                        sa
                    }
                };
                let b_data: &[f64] = match b_gather {
                    None => b,
                    Some(gth) => {
                        gth.run(b, sb, idx);
                        sb
                    }
                };
                match out_read {
                    None => {
                        // GEMM order already matches the output order:
                        // global flat indices in the product equal output
                        // indices, so the epilogue rides inside the tiles
                        out_data.fill(0.0);
                        batched_gemm_epi(a_data, b_data, out_data, bsz, m, k, n, *k_empty, epi);
                    }
                    Some(strides) => {
                        // the permutation re-orders elements, so the
                        // epilogue can only run on the permuted output
                        sc.fill(0.0);
                        batched_gemm(a_data, b_data, sc, bsz, m, k, n, *k_empty);
                        permute_read(sc, out_data, &self.out_shape, strides, idx);
                        epi.apply(0, out_data);
                    }
                }
            }
        }
    }
}

/// Evaluate `A *_(s1,s2,s3) B` into `out`, reusing `scratch` buffers.
/// Compiles the spec on the fly — callers on a hot path should hold an
/// [`EinsumPlan`] instead (the compiled executor does).
pub fn einsum_into(spec: &EinSpec, a: &Tensor, b: &Tensor, out: &mut Tensor, scratch: &mut EinScratch) {
    EinsumPlan::new(spec, a.shape(), b.shape()).run(a, b, out, scratch)
}

/// `dst[i] = src[strided(i)]`: one strided read / contiguous write pass
/// (the write-into analogue of `Tensor::permute`).
fn permute_read(src: &[f64], dst: &mut [f64], dims: &[usize], strides: &[usize], idx: &mut Vec<usize>) {
    let rank = dims.len();
    debug_assert_eq!(rank, strides.len());
    idx.clear();
    idx.resize(rank, 0);
    let mut off = 0usize;
    for slot in dst.iter_mut() {
        *slot = src[off];
        for ax in (0..rank).rev() {
            idx[ax] += 1;
            off += strides[ax];
            if idx[ax] < dims[ax] {
                break;
            }
            off -= strides[ax] * dims[ax];
            idx[ax] = 0;
        }
    }
}

/// Whole-`chunk` slices of `s` — named to avoid shadowing the unstable
/// `slice::as_chunks` (which an earlier private helper collided with).
pub(super) fn chunks_of(s: &[f64], chunk: usize) -> std::slice::Chunks<'_, f64> {
    s.chunks(chunk.max(1))
}

/// `C[b] = A[b] · B[b]` over `bsz` row-major batch slices, with the
/// degenerate-shape fast paths and the small-slice parallel split shared
/// by the interpreter and compiled einsum paths. `c` must be zeroed; all
/// zero-size shapes leave it untouched.
#[allow(clippy::too_many_arguments)]
pub(super) fn batched_gemm(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    bsz: usize,
    m: usize,
    k: usize,
    n: usize,
    k_empty: bool,
) {
    batched_gemm_epi(a, b, c, bsz, m, k, n, k_empty, &NoEpilogue);
}

/// Block size for epilogue application on the element-wise fast paths:
/// compute a block, post-process it while it is still in L1/L2, move on.
const EPI_BLOCK: usize = 4096;

/// [`batched_gemm`] with a [`TileEpilogue`] applied exactly once to
/// every element of `c` after its final accumulation — inside the GEMM
/// tiles on the general path, per freshly written block on the
/// element-wise fast paths. Epilogue offsets are global flat indices
/// into `c`.
#[allow(clippy::too_many_arguments)]
pub(super) fn batched_gemm_epi<E: TileEpilogue>(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    bsz: usize,
    m: usize,
    k: usize,
    n: usize,
    k_empty: bool,
    epi: &E,
) {
    if bsz == 0 || m == 0 || n == 0 || k == 0 {
        // empty contraction — c stays zero, but the epilogue still owes
        // every (if any) element one application
        if !c.is_empty() {
            epi.apply(0, c);
        }
        return;
    }
    if k_empty && m == 1 && n == 1 {
        // pure batched element-wise product, post-processed per block
        let mut off = 0usize;
        while off < c.len() {
            let end = (off + EPI_BLOCK).min(c.len());
            let cb = &mut c[off..end];
            mul_into(cb, &a[off..end], &b[off..end]);
            epi.apply(off, cb);
            off = end;
        }
    } else if k_empty && n == 1 {
        // row broadcast: C[b, m] = A[b, m] · B[b]
        for bi in 0..bsz {
            let bv = b[bi];
            let arow = &a[bi * m..(bi + 1) * m];
            let crow = &mut c[bi * m..(bi + 1) * m];
            mul_scalar_into(crow, arow, bv);
            epi.apply(bi * m, crow);
        }
    } else {
        // batched GEMM (with k_empty, k == 1 and GEMM degrades gracefully
        // to a batched outer product)
        let per = m * k * n;
        if bsz > 1 && per < PAR_BATCH_SLICE_MAX_FLOP && bsz * per > PAR_BATCH_TOTAL_MIN_FLOP {
            par_band_zip2(c, m * n, a, m * k, b, k * n, |off, cc, aa, bb| {
                for (si, ((cs, as_), bs)) in cc
                    .chunks_mut(m * n)
                    .zip(chunks_of(aa, m * k))
                    .zip(chunks_of(bb, k * n))
                    .enumerate()
                {
                    gemm_into_epi(as_, bs, cs, m, k, n, (off + si) * m * n, epi);
                }
            });
        } else {
            for bi in 0..bsz {
                gemm_into_epi(
                    &a[bi * m * k..(bi + 1) * m * k],
                    &b[bi * k * n..(bi + 1) * k * n],
                    &mut c[bi * m * n..(bi + 1) * m * n],
                    m,
                    k,
                    n,
                    bi * m * n,
                    epi,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::exec::{einsum, einsum_naive};
    use super::super::gemm::EpiFn;
    use super::*;

    fn check_into(sig: &str, a_shape: &[usize], b_shape: &[usize]) {
        let spec = EinSpec::parse(sig);
        let a = Tensor::randn(a_shape, 31);
        let b = Tensor::randn(b_shape, 32);
        let want = einsum(&spec, &a, &b);
        let naive = einsum_naive(&spec, &a, &b);

        let mut scratch = EinScratch::default();
        let plan = EinsumPlan::new(&spec, a_shape, b_shape);
        // poisoned output buffer: every slot must be overwritten
        let mut out = Tensor::fill(plan.out_shape(), f64::NAN);
        plan.run(&a, &b, &mut out, &mut scratch);
        assert!(
            out.allclose(&want, 1e-12, 1e-12),
            "{}: into vs einsum diff {}",
            sig,
            out.max_abs_diff(&want)
        );
        assert!(
            out.allclose(&naive, 1e-9, 1e-9),
            "{}: into vs naive diff {}",
            sig,
            out.max_abs_diff(&naive)
        );
        // second run with the warmed scratch must agree bit-for-bit
        let mut out2 = Tensor::fill(plan.out_shape(), f64::NAN);
        plan.run(&a, &b, &mut out2, &mut scratch);
        assert_eq!(out.data(), out2.data(), "{}: scratch reuse changed the result", sig);
    }

    #[test]
    fn matmul_family_into() {
        check_into("ij,jk->ik", &[4, 5], &[5, 6]);
        check_into("ji,jk->ik", &[5, 4], &[5, 6]);
        check_into("ij,kj->ik", &[4, 5], &[6, 5]);
        check_into("ij,j->i", &[4, 5], &[5]);
        check_into("i,i->", &[7], &[7]);
    }

    #[test]
    fn elementwise_outer_diag_into() {
        check_into("i,j->ij", &[3], &[4]);
        check_into("ij,ij->ij", &[3, 4], &[3, 4]);
        check_into("ij,i->ij", &[3, 4], &[3]);
        check_into("ii,->i", &[4, 4], &[]);
        check_into("ii,->", &[4, 4], &[]);
        check_into("iji,j->ij", &[3, 4, 3], &[4]);
    }

    #[test]
    fn presum_scalar_permuted_into() {
        check_into("ij,k->i", &[3, 4], &[5]);
        check_into("ij,->ij", &[3, 4], &[]);
        check_into(",ij->ij", &[], &[3, 4]);
        check_into(",->", &[], &[]);
        check_into("ij,jk->ki", &[3, 4], &[4, 5]);
        check_into("ijk,->kji", &[2, 3, 4], &[]);
        check_into("ij,kl->ljki", &[2, 3], &[4, 5]);
        check_into("aij,ajk->aik", &[3, 2, 4], &[3, 4, 2]);
    }

    #[test]
    fn parallel_batched_into() {
        check_into("aij,ajk->aik", &[300, 4, 4], &[300, 4, 4]);
    }

    #[test]
    fn einsum_into_free_function() {
        let spec = EinSpec::parse("ij,jk->ik");
        let a = Tensor::randn(&[3, 4], 1);
        let b = Tensor::randn(&[4, 5], 2);
        let mut out = Tensor::zeros(&[3, 5]);
        let mut scratch = EinScratch::default();
        einsum_into(&spec, &a, &b, &mut out, &mut scratch);
        assert!(out.allclose(&einsum(&spec, &a, &b), 1e-12, 1e-12));
    }

    #[test]
    #[should_panic(expected = "wrong shape")]
    fn wrong_out_shape_panics() {
        let spec = EinSpec::parse("ij,jk->ik");
        let a = Tensor::randn(&[3, 4], 1);
        let b = Tensor::randn(&[4, 5], 2);
        let mut out = Tensor::zeros(&[5, 3]);
        einsum_into(&spec, &a, &b, &mut out, &mut EinScratch::default());
    }

    #[test]
    fn in_tile_epilogue_matches_two_pass() {
        // every plan kind: tiled GEMM, permuted fallback, parallel
        // batch, elementwise, scale, outer (k_empty)
        let cases: Vec<(&str, Vec<usize>, Vec<usize>)> = vec![
            ("ij,jk->ik", vec![65, 257], vec![257, 130]),
            ("ij,jk->ki", vec![9, 8], vec![8, 7]),
            ("aij,ajk->aik", vec![300, 4, 4], vec![300, 4, 4]),
            ("ij,ij->ij", vec![33, 5], vec![33, 5]),
            ("ij,k->i", vec![3, 4], vec![5]),
            ("i,j->ij", vec![64], vec![64]),
        ];
        for (sig, sa, sb) in cases {
            let spec = EinSpec::parse(sig);
            let a = Tensor::randn(&sa, 41);
            let b = Tensor::randn(&sb, 42);
            let plan = EinsumPlan::new(&spec, &sa, &sb);
            let mut scratch = EinScratch::default();
            let mut two_pass = Tensor::fill(plan.out_shape(), f64::NAN);
            plan.run_with_epilogue(&a, &b, &mut two_pass, &mut scratch, |data| {
                for (i, v) in data.iter_mut().enumerate() {
                    *v = v.tanh() + i as f64 * 0.01;
                }
            });
            let mut in_tile = Tensor::fill(plan.out_shape(), f64::NAN);
            let epi = EpiFn(|base: usize, seg: &mut [f64]| {
                for (j, v) in seg.iter_mut().enumerate() {
                    *v = v.tanh() + (base + j) as f64 * 0.01;
                }
            });
            plan.run_with_epilogue_in_tile(&a, &b, &mut in_tile, &mut scratch, &epi);
            assert_eq!(
                two_pass.data(),
                in_tile.data(),
                "{}: in-tile epilogue diverged from the two-pass reference",
                sig
            );
        }
    }

    #[test]
    fn run_planned_matches_run_on_all_kinds() {
        // planned-arena entry (caller-provided scratch slices) must be
        // bit-identical to the EinScratch path on every plan kind
        let cases: Vec<(&str, Vec<usize>, Vec<usize>)> = vec![
            ("ij,jk->ik", vec![9, 17], vec![17, 13]),
            ("ij,jk->ki", vec![9, 8], vec![8, 7]),
            ("ji,jk->ik", vec![5, 4], vec![5, 6]),
            ("aij,ajk->aik", vec![6, 4, 4], vec![6, 4, 4]),
            ("ij,ij->ij", vec![33, 5], vec![33, 5]),
            ("ij,k->i", vec![3, 4], vec![5]),
            ("i,j->ij", vec![16], vec![16]),
            ("ii,->i", vec![4, 4], vec![]),
        ];
        for (sig, sa_shape, sb_shape) in cases {
            let spec = EinSpec::parse(sig);
            let a = Tensor::randn(&sa_shape, 71);
            let b = Tensor::randn(&sb_shape, 72);
            let plan = EinsumPlan::new(&spec, &sa_shape, &sb_shape);
            let mut want = Tensor::fill(plan.out_shape(), f64::NAN);
            plan.run(&a, &b, &mut want, &mut EinScratch::default());

            let ss = plan.scratch_sizes();
            let mut sa = vec![f64::NAN; ss.a];
            let mut sb = vec![f64::NAN; ss.b];
            let mut sc = vec![f64::NAN; ss.c];
            let mut idx = Vec::new();
            let out_len: usize = plan.out_shape().iter().product();
            let mut out = vec![f64::NAN; out_len];
            plan.run_planned(
                a.data(),
                b.data(),
                &mut out,
                &mut sa,
                &mut sb,
                &mut sc,
                &mut idx,
                &NoEpilogue,
            );
            assert_eq!(out.as_slice(), want.data(), "{}: planned path diverged", sig);
        }
    }

    #[test]
    fn iteration_space_estimates() {
        let p = EinsumPlan::new(&EinSpec::parse("ij,jk->ik"), &[4, 5], &[5, 6]);
        assert_eq!(p.iteration_space(), 4 * 5 * 6);
        let p = EinsumPlan::new(&EinSpec::parse("i,i->i"), &[7], &[7]);
        assert_eq!(p.iteration_space(), 7);
    }
}
