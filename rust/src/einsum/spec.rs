//! Index-set specifications for the generic tensor multiplication.

use std::fmt;

/// An index label. Labels are *local to one [`EinSpec`]* — they name axes
/// of the two operands and the result, exactly like the letters in an
/// einsum string `"ij,jk->ik"`.
pub type Label = u32;

/// The `(s1, s2, s3)` triple of the paper's generic multiplication
/// `C = A *_(s1,s2,s3) B`:
///
/// * `s1` labels the axes of the left operand (in order),
/// * `s2` labels the axes of the right operand,
/// * `s3` labels the axes of the result; every label summed over is the
///   one *missing* from `s3` (the paper's explicit-output convention).
///
/// Invariants (checked by [`EinSpec::validate`]):
/// * `s3 ⊆ s1 ∪ s2`,
/// * `s3` has no repeated labels (operands may repeat labels — that is a
///   diagonal extraction, e.g. `diag(A) = A *_(ii,∅,i) 1`).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct EinSpec {
    pub s1: Vec<Label>,
    pub s2: Vec<Label>,
    pub s3: Vec<Label>,
}

impl EinSpec {
    pub fn new(s1: Vec<Label>, s2: Vec<Label>, s3: Vec<Label>) -> Self {
        let spec = EinSpec { s1, s2, s3 };
        spec.validate().expect("invalid EinSpec");
        spec
    }

    /// Parse an einsum-style string, e.g. `"ij,jk->ik"` or `"i,->i"`.
    /// Each ASCII letter becomes one label.
    pub fn parse(s: &str) -> Self {
        let (ins, out) = s.split_once("->").expect("spec needs ->");
        let (a, b) = ins.split_once(',').expect("spec needs two operands");
        let lab = |c: char| c as Label;
        EinSpec::new(
            a.chars().map(lab).collect(),
            b.chars().map(lab).collect(),
            out.chars().map(lab).collect(),
        )
    }

    /// Check the structural invariants (labels only — dimension consistency
    /// is checked against concrete shapes in [`EinSpec::output_shape`]).
    pub fn validate(&self) -> Result<(), String> {
        for (i, l) in self.s3.iter().enumerate() {
            if self.s3[i + 1..].contains(l) {
                return Err(format!("repeated output label {} in {}", l, self));
            }
            if !self.s1.contains(l) && !self.s2.contains(l) {
                return Err(format!("output label {} not in s1 ∪ s2 ({})", l, self));
            }
        }
        Ok(())
    }

    /// Labels that are summed over: `(s1 ∪ s2) \ s3`.
    pub fn summed_labels(&self) -> Vec<Label> {
        let mut out = Vec::new();
        for &l in self.s1.iter().chain(&self.s2) {
            if !self.s3.contains(&l) && !out.contains(&l) {
                out.push(l);
            }
        }
        out
    }

    /// True if this is a pure element-wise multiplication (`s1 == s2 == s3`).
    pub fn is_elementwise(&self) -> bool {
        self.s1 == self.s2 && self.s2 == self.s3
    }

    /// True if no label is summed over.
    pub fn is_sum_free(&self) -> bool {
        self.summed_labels().is_empty()
    }

    /// Infer the result shape given operand shapes; checks rank and
    /// dimension consistency of shared labels.
    pub fn output_shape(
        &self,
        a_shape: &[usize],
        b_shape: &[usize],
    ) -> Result<Vec<usize>, String> {
        if a_shape.len() != self.s1.len() {
            return Err(format!(
                "left operand rank {} != |s1| {} in {}",
                a_shape.len(),
                self.s1.len(),
                self
            ));
        }
        if b_shape.len() != self.s2.len() {
            return Err(format!(
                "right operand rank {} != |s2| {} in {}",
                b_shape.len(),
                self.s2.len(),
                self
            ));
        }
        let mut dims: Vec<(Label, usize)> = Vec::new();
        let mut bind = |l: Label, d: usize| -> Result<(), String> {
            match dims.iter().find(|(ll, _)| *ll == l) {
                Some(&(_, d0)) if d0 != d => {
                    Err(format!("label {} bound to both {} and {} in {}", l, d0, d, self))
                }
                Some(_) => Ok(()),
                None => {
                    dims.push((l, d));
                    Ok(())
                }
            }
        };
        for (&l, &d) in self.s1.iter().zip(a_shape) {
            bind(l, d)?;
        }
        for (&l, &d) in self.s2.iter().zip(b_shape) {
            bind(l, d)?;
        }
        Ok(self
            .s3
            .iter()
            .map(|l| dims.iter().find(|(ll, _)| ll == l).unwrap().1)
            .collect())
    }

    /// Swap the operands (Lemma 2, commutativity): `A *_(s1,s2,s3) B =
    /// B *_(s2,s1,s3) A`.
    pub fn swapped(&self) -> EinSpec {
        EinSpec { s1: self.s2.clone(), s2: self.s1.clone(), s3: self.s3.clone() }
    }

    /// Relabel every label through `f` (used when splicing specs into a
    /// larger label space, e.g. in the derivative constructions).
    pub fn relabel(&self, f: impl Fn(Label) -> Label) -> EinSpec {
        EinSpec {
            s1: self.s1.iter().map(|&l| f(l)).collect(),
            s2: self.s2.iter().map(|&l| f(l)).collect(),
            s3: self.s3.iter().map(|&l| f(l)).collect(),
        }
    }

    /// Largest label value used (for fresh-label generation).
    pub fn max_label(&self) -> Label {
        self.s1
            .iter()
            .chain(&self.s2)
            .chain(&self.s3)
            .copied()
            .max()
            .unwrap_or(0)
    }
}

fn fmt_labels(ls: &[Label], f: &mut fmt::Formatter<'_>) -> fmt::Result {
    for &l in ls {
        // print letters when in ASCII range, otherwise `#n`
        if (97..=122).contains(&l) || (65..=90).contains(&l) {
            write!(f, "{}", char::from_u32(l).unwrap())?;
        } else {
            write!(f, "#{} ", l)?;
        }
    }
    Ok(())
}

impl fmt::Display for EinSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_labels(&self.s1, f)?;
        write!(f, ",")?;
        fmt_labels(&self.s2, f)?;
        write!(f, "->")?;
        fmt_labels(&self.s3, f)
    }
}

impl fmt::Debug for EinSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "EinSpec({})", self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let s = EinSpec::parse("ij,jk->ik");
        assert_eq!(s.to_string(), "ij,jk->ik");
        assert_eq!(s.summed_labels(), vec!['j' as Label]);
        assert!(!s.is_elementwise());
    }

    #[test]
    fn elementwise_detection() {
        assert!(EinSpec::parse("ij,ij->ij").is_elementwise());
        assert!(!EinSpec::parse("ij,ij->i").is_elementwise());
        assert!(EinSpec::parse("ij,ij->ij").is_sum_free());
        assert!(EinSpec::parse("ij,i->ij").is_sum_free());
    }

    #[test]
    fn output_shape_inference() {
        let s = EinSpec::parse("ij,jk->ik");
        assert_eq!(s.output_shape(&[2, 3], &[3, 4]).unwrap(), vec![2, 4]);
        assert!(s.output_shape(&[2, 3], &[5, 4]).is_err()); // j mismatch
        assert!(s.output_shape(&[2], &[3, 4]).is_err()); // rank mismatch
    }

    #[test]
    fn validate_rejects_bad_specs() {
        // repeated output label
        assert!(EinSpec { s1: vec![1], s2: vec![2], s3: vec![1, 1] }.validate().is_err());
        // output label not present in inputs
        assert!(EinSpec { s1: vec![1], s2: vec![2], s3: vec![3] }.validate().is_err());
    }

    #[test]
    fn diagonal_spec_allowed() {
        // diag extraction: s1 = ii
        let s = EinSpec::parse("ii,->i");
        assert_eq!(s.output_shape(&[3, 3], &[]).unwrap(), vec![3]);
    }

    #[test]
    fn swapped_is_commutativity() {
        let s = EinSpec::parse("ij,jk->ik");
        let t = s.swapped();
        assert_eq!(t.to_string(), "jk,ij->ik");
    }

    #[test]
    fn table1_specs_from_paper() {
        // The Einstein-notation column of Table 1, row by row.
        let outer = EinSpec::parse("i,j->ij"); // y xᵀ
        assert_eq!(outer.output_shape(&[2], &[3]).unwrap(), vec![2, 3]);
        let matvec = EinSpec::parse("ij,j->i"); // A x
        assert_eq!(matvec.output_shape(&[2, 3], &[3]).unwrap(), vec![2]);
        let dot = EinSpec::parse("i,i->"); // yᵀ x
        assert_eq!(dot.output_shape(&[3], &[3]).unwrap(), Vec::<usize>::new());
        let matmul = EinSpec::parse("ij,jk->ik"); // A B
        assert_eq!(matmul.output_shape(&[2, 3], &[3, 4]).unwrap(), vec![2, 4]);
        let had_v = EinSpec::parse("i,i->i"); // y ⊙ x
        assert_eq!(had_v.output_shape(&[3], &[3]).unwrap(), vec![3]);
        let had_m = EinSpec::parse("ij,ij->ij"); // A ⊙ B
        assert_eq!(had_m.output_shape(&[2, 3], &[2, 3]).unwrap(), vec![2, 3]);
        let diag_scale = EinSpec::parse("ij,i->ij"); // A · diag(x)
        assert_eq!(diag_scale.output_shape(&[2, 3], &[2]).unwrap(), vec![2, 3]);
    }
}
