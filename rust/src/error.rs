//! Minimal `anyhow`-shaped error plumbing. The offline build carries no
//! external dependencies, so the handful of idioms the service layer
//! uses (`anyhow!`, `bail!`, `Context`, `Result`) are provided here with
//! the same spelling; swapping the real `anyhow` back in is a one-line
//! import change per module.

use std::fmt;

/// A message-carrying error (the `anyhow::Error` role).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (the `anyhow::Context` role).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", c, e)))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e)))
    }
}

/// Build an [`Error`] from a format string (the `anyhow::anyhow!` role).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::error::Error::msg(format!($($arg)*)) };
}

/// Early-return an [`Error`] (the `anyhow::bail!` role).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::error::Error::msg(format!($($arg)*))) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anyhow_macro_formats() {
        let e = crate::anyhow!("bad thing {}", 7);
        assert_eq!(e.to_string(), "bad thing 7");
    }

    #[test]
    fn bail_early_returns() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                crate::bail!("nope: {}", 42);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "nope: 42");
    }

    #[test]
    fn context_wraps_source_error() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert!(e.to_string().contains("reading x"));
        assert!(e.to_string().contains("gone"));
    }
}
