//! Dense linear-algebra substrate for the Newton examples: Cholesky and
//! LU factorizations with solves. Needed to demonstrate the §3.3 claim
//! that the compressed matrix-factorization Hessian turns an O((nk)³)
//! Newton solve into an O(k³) one.

use crate::tensor::Tensor;

/// Cholesky factor `L` (lower-triangular, `A = L·Lᵀ`) of a symmetric
/// positive-definite matrix. Returns `None` if a pivot is non-positive.
pub fn cholesky(a: &Tensor) -> Option<Tensor> {
    let n = a.shape()[0];
    assert_eq!(a.shape(), &[n, n], "cholesky needs a square matrix");
    let mut l = vec![0.0f64; n * n];
    let ad = a.data();
    for i in 0..n {
        for j in 0..=i {
            let mut s = ad[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(Tensor::new(&[n, n], l))
}

/// Solve `L·x = b` with `L` lower triangular.
pub fn solve_lower(l: &Tensor, b: &[f64]) -> Vec<f64> {
    let n = l.shape()[0];
    let ld = l.data();
    let mut x = b.to_vec();
    for i in 0..n {
        let mut s = x[i];
        for k in 0..i {
            s -= ld[i * n + k] * x[k];
        }
        x[i] = s / ld[i * n + i];
    }
    x
}

/// Solve `Lᵀ·x = b` with `L` lower triangular.
pub fn solve_lower_t(l: &Tensor, b: &[f64]) -> Vec<f64> {
    let n = l.shape()[0];
    let ld = l.data();
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in (i + 1)..n {
            s -= ld[k * n + i] * x[k];
        }
        x[i] = s / ld[i * n + i];
    }
    x
}

/// Solve the SPD system `A·x = b` via Cholesky.
pub fn solve_spd(a: &Tensor, b: &Tensor) -> Option<Tensor> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b.data());
    let x = solve_lower_t(&l, &y);
    Some(Tensor::new(b.shape(), x))
}

/// LU decomposition with partial pivoting: returns `(lu, perm)` where the
/// combined factors are stored in `lu` and `perm` is the row permutation.
pub fn lu_decompose(a: &Tensor) -> Option<(Tensor, Vec<usize>)> {
    let n = a.shape()[0];
    assert_eq!(a.shape(), &[n, n]);
    let mut lu = a.data().to_vec();
    let mut perm: Vec<usize> = (0..n).collect();
    for col in 0..n {
        // pivot
        let (mut piv, mut pmax) = (col, lu[col * n + col].abs());
        for r in (col + 1)..n {
            let v = lu[r * n + col].abs();
            if v > pmax {
                piv = r;
                pmax = v;
            }
        }
        if pmax < 1e-300 {
            return None; // singular
        }
        if piv != col {
            for c in 0..n {
                lu.swap(col * n + c, piv * n + c);
            }
            perm.swap(col, piv);
        }
        let d = lu[col * n + col];
        for r in (col + 1)..n {
            let f = lu[r * n + col] / d;
            lu[r * n + col] = f;
            for c in (col + 1)..n {
                lu[r * n + c] -= f * lu[col * n + c];
            }
        }
    }
    Some((Tensor::new(&[n, n], lu), perm))
}

/// Solve `A·x = b` from a precomputed LU decomposition.
pub fn lu_solve(lu: &Tensor, perm: &[usize], b: &[f64]) -> Vec<f64> {
    let n = lu.shape()[0];
    let d = lu.data();
    // apply permutation
    let mut x: Vec<f64> = perm.iter().map(|&p| b[p]).collect();
    // forward (unit lower)
    for i in 0..n {
        for k in 0..i {
            x[i] -= d[i * n + k] * x[k];
        }
    }
    // back (upper)
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            x[i] -= d[i * n + k] * x[k];
        }
        x[i] /= d[i * n + i];
    }
    x
}

/// Solve the general square system `A·x = b`.
pub fn solve(a: &Tensor, b: &Tensor) -> Option<Tensor> {
    let (lu, perm) = lu_decompose(a)?;
    Some(Tensor::new(b.shape(), lu_solve(&lu, &perm, b.data())))
}

/// Matrix inverse via LU.
pub fn inverse(a: &Tensor) -> Option<Tensor> {
    let n = a.shape()[0];
    let (lu, perm) = lu_decompose(a)?;
    let mut inv = vec![0.0; n * n];
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = lu_solve(&lu, &perm, &e);
        for i in 0..n {
            inv[i * n + j] = col[i];
        }
        e[j] = 0.0;
    }
    Some(Tensor::new(&[n, n], inv))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::{einsum, EinSpec};

    fn spd(n: usize, seed: u64) -> Tensor {
        // AᵀA + n·I is SPD
        let a = Tensor::randn(&[n, n], seed);
        let mut m = einsum(&EinSpec::parse("ki,kj->ij"), &a, &a);
        for i in 0..n {
            m.data_mut()[i * n + i] += n as f64;
        }
        m
    }

    #[test]
    fn cholesky_roundtrip() {
        let a = spd(8, 1);
        let l = cholesky(&a).unwrap();
        let llt = einsum(&EinSpec::parse("ik,jk->ij"), &l, &l);
        assert!(llt.allclose(&a, 1e-9, 1e-9), "diff {}", llt.max_abs_diff(&a));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, −1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_residual_small() {
        let a = spd(10, 2);
        let b = Tensor::randn(&[10], 3);
        let x = solve_spd(&a, &b).unwrap();
        let ax = einsum(&EinSpec::parse("ij,j->i"), &a, &x);
        assert!(ax.allclose(&b, 1e-8, 1e-8), "residual {}", ax.max_abs_diff(&b));
    }

    #[test]
    fn lu_solve_general_matrix() {
        let a = Tensor::randn(&[12, 12], 4);
        let b = Tensor::randn(&[12], 5);
        let x = solve(&a, &b).unwrap();
        let ax = einsum(&EinSpec::parse("ij,j->i"), &a, &x);
        assert!(ax.allclose(&b, 1e-8, 1e-8));
    }

    #[test]
    fn lu_needs_pivoting() {
        // zero on the diagonal forces a row swap
        let a = Tensor::new(&[2, 2], vec![0.0, 1.0, 1.0, 0.0]);
        let b = Tensor::new(&[2], vec![3.0, 7.0]);
        let x = solve(&a, &b).unwrap();
        assert!(x.allclose(&Tensor::new(&[2], vec![7.0, 3.0]), 1e-12, 1e-12));
    }

    #[test]
    fn singular_matrix_detected() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 2.0, 4.0]);
        assert!(solve(&a, &Tensor::new(&[2], vec![1.0, 1.0])).is_none());
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let a = Tensor::randn(&[6, 6], 6);
        let inv = inverse(&a).unwrap();
        let prod = einsum(&EinSpec::parse("ij,jk->ik"), &a, &inv);
        assert!(prod.allclose(&Tensor::eye(6), 1e-8, 1e-8));
    }
}
