//! The tensor calculus itself (Section 3 of the paper): forward mode
//! (Theorems 5–7), reverse mode (Theorems 8–10), the cross-country
//! product reordering and the higher-order-derivative compression of
//! Section 3.3.
//!
//! All modes are *symbolic*: they extend the expression DAG with nodes
//! for the derivative, which is then simplified ([`crate::simplify`]) and
//! evaluated ([`crate::eval`]). This mirrors the paper's implementation
//! (and MatrixCalculus.org), where the derivative of a tensor expression
//! is again a tensor expression in Einstein notation.

pub mod compress;
pub mod cross_country;
pub mod forward;
pub mod hessian;
pub mod reverse;

use crate::einsum::{EinSpec, Label};

/// Relabel the distinct labels of `spec` injectively to `base, base+1, …`
/// so it can be spliced into a larger label space (e.g. next to the fresh
/// `s4` output/input block of the derivative constructions).
pub(crate) fn relabel_from(spec: &EinSpec, base: Label) -> EinSpec {
    let mut distinct: Vec<Label> = Vec::new();
    for &l in spec.s1.iter().chain(&spec.s2).chain(&spec.s3) {
        if !distinct.contains(&l) {
            distinct.push(l);
        }
    }
    spec.relabel(|l| base + distinct.iter().position(|&d| d == l).unwrap() as Label)
}

/// `0, 1, …, n-1` shifted by `base`.
pub(crate) fn fresh_block(n: usize, base: Label) -> Vec<Label> {
    (base..base + n as Label).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relabel_preserves_structure() {
        let s = EinSpec::parse("ij,jk->ik");
        let r = relabel_from(&s, 100);
        assert_eq!(r.s1, vec![100, 101]);
        assert_eq!(r.s2, vec![101, 102]);
        assert_eq!(r.s3, vec![100, 102]);
    }

    #[test]
    fn relabel_keeps_shared_labels_shared() {
        let s = EinSpec::parse("ii,i->i");
        let r = relabel_from(&s, 7);
        assert_eq!(r.s1, vec![7, 7]);
        assert_eq!(r.s2, vec![7]);
        assert_eq!(r.s3, vec![7]);
    }
}
