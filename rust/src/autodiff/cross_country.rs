//! Cross-country mode (§3.3): reorder chains of generic multiplications.
//!
//! Forward and reverse mode multiply the partial derivatives in opposite,
//! fixed orders; neither is optimal for non-scalar derivatives. The paper's
//! strategy — multiply tensors in order of increasing tensor order
//! (vectors first, then matrices, …) — is implemented here as its natural
//! generalization: multiplication chains in the derivative DAG are
//! flattened into n-ary contractions and re-associated greedily by
//! contraction cost (with tensor order as tie-break). On the
//! `B·diag(u)·diag(v)·A` chains that dominate Hessians (Example 7) this
//! reproduces exactly the paper's ordering: the element-wise vector
//! factors merge first.
//!
//! Re-association is justified by Lemmas 1–3; the flattened n-ary view
//! makes the validity condition automatic (labels are unified globally,
//! summed labels stay internal).

use crate::einsum::{EinSpec, Label};
use crate::ir::{Graph, NodeId, Op};
use std::collections::HashMap;

type GLabel = u64;

/// Re-associate all multiplication chains below `root`; returns the new
/// root. Semantics are preserved exactly (tested against the untouched
/// DAG); only the association order of `*` changes.
pub fn optimize_contractions(g: &mut Graph, root: NodeId) -> NodeId {
    let uses = g.use_counts(&[root]);
    let mut opt = Opt { uses, memo: HashMap::new(), counter: 0 };
    opt.rewrite(g, root)
}

struct Opt {
    uses: Vec<u32>,
    memo: HashMap<NodeId, NodeId>,
    counter: GLabel,
}

/// One operand of a flattened n-ary contraction: the (original-graph)
/// node plus the global labels of its axes.
struct Term {
    node: NodeId,
    labels: Vec<GLabel>,
}

impl Opt {
    fn fresh(&mut self) -> GLabel {
        self.counter += 1;
        self.counter
    }

    fn rewrite(&mut self, g: &mut Graph, id: NodeId) -> NodeId {
        if let Some(&m) = self.memo.get(&id) {
            return m;
        }
        let res = match g.op(id).clone() {
            Op::Mul(..) => {
                // flatten the chain rooted here
                let out: Vec<GLabel> = (0..g.order(id)).map(|_| self.fresh()).collect();
                let mut terms: Vec<Term> = Vec::new();
                let mut dims: HashMap<GLabel, usize> = HashMap::new();
                for (gl, &d) in out.iter().zip(g.shape(id)) {
                    dims.insert(*gl, d);
                }
                self.flatten(g, id, &out, true, &mut terms, &mut dims);
                // rewrite the atomic operands themselves
                for t in &mut terms {
                    t.node = self.rewrite(g, t.node);
                }
                contract_greedy(g, terms, &out, &dims)
            }
            Op::Add(a, b) => {
                let a = self.rewrite(g, a);
                let b = self.rewrite(g, b);
                g.add(a, b)
            }
            Op::Elem(f, a) => {
                let a = self.rewrite(g, a);
                g.elem(f, a)
            }
            Op::GenUnary(f, a) => {
                let a = self.rewrite(g, a);
                g.gen_unary(f, a)
            }
            _ => id,
        };
        self.memo.insert(id, res);
        res
    }

    /// Collect the operands of the multiplication tree at `id`, whose
    /// axes carry the global labels `labels`. Only exclusively-owned Mul
    /// children are inlined — shared subexpressions stay atomic so no
    /// work is duplicated.
    fn flatten(
        &mut self,
        g: &Graph,
        id: NodeId,
        labels: &[GLabel],
        is_root: bool,
        terms: &mut Vec<Term>,
        dims: &mut HashMap<GLabel, usize>,
    ) {
        let inline = is_root || self.uses[id.index()] <= 1;
        if let Op::Mul(a, b, spec) = g.op(id).clone() {
            if inline {
                // map the spec's local labels to global ones: output labels
                // through `labels`, summed labels fresh
                let mut map: HashMap<Label, GLabel> = HashMap::new();
                for (l, &gl) in spec.s3.iter().zip(labels) {
                    map.insert(*l, gl);
                }
                let bind = |this: &mut Self,
                            map: &mut HashMap<Label, GLabel>,
                            ls: &[Label],
                            shape: &[usize],
                            dims: &mut HashMap<GLabel, usize>|
                 -> Vec<GLabel> {
                    ls.iter()
                        .zip(shape)
                        .map(|(l, &d)| {
                            let gl = *map.entry(*l).or_insert_with(|| this.fresh());
                            dims.insert(gl, d);
                            gl
                        })
                        .collect()
                };
                let la = bind(self, &mut map, &spec.s1, g.shape(a), dims);
                let lb = bind(self, &mut map, &spec.s2, g.shape(b), dims);
                self.flatten(g, a, &la, false, terms, dims);
                self.flatten(g, b, &lb, false, terms, dims);
                return;
            }
        }
        terms.push(Term { node: id, labels: labels.to_vec() });
    }
}

/// Greedily contract the flattened terms pairwise: cheapest contraction
/// first (iteration-space size; ties broken by the *order* of the result
/// tensor — the paper's vectors-before-matrices rule).
fn contract_greedy(
    g: &mut Graph,
    mut terms: Vec<Term>,
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> NodeId {
    assert!(!terms.is_empty());
    while terms.len() > 1 {
        let mut best: Option<(usize, usize, u128, usize)> = None; // (i, j, cost, result order)
        for i in 0..terms.len() {
            for j in (i + 1)..terms.len() {
                let (cost, res) = pair_result(&terms, i, j, out, dims);
                let order = res.len();
                let better = match best {
                    None => true,
                    Some((_, _, bc, bo)) => cost < bc || (cost == bc && order < bo),
                };
                if better {
                    best = Some((i, j, cost, order));
                }
            }
        }
        let (i, j, _, _) = best.unwrap();
        let (_, mut res_labels) = pair_result(&terms, i, j, out, dims);
        if terms.len() == 2 {
            // final contraction: emit directly in the requested output order
            res_labels = out.to_vec();
        }
        let merged = build_mul(g, &terms[i], &terms[j], &res_labels);
        terms[i] = Term { node: merged, labels: res_labels };
        terms.remove(j);
    }
    let last = terms.pop().unwrap();
    // final axis order must match `out`
    if last.labels == out {
        last.node
    } else {
        let perm: Vec<usize> = out
            .iter()
            .map(|gl| last.labels.iter().position(|x| x == gl).unwrap())
            .collect();
        g.transpose(last.node, &perm)
    }
}

/// Cost (iteration-space size) and surviving labels of contracting the
/// pair `(i, j)`: a label survives if some other term or the output still
/// needs it.
fn pair_result(
    terms: &[Term],
    i: usize,
    j: usize,
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> (u128, Vec<GLabel>) {
    let mut union: Vec<GLabel> = Vec::new();
    for &l in terms[i].labels.iter().chain(&terms[j].labels) {
        if !union.contains(&l) {
            union.push(l);
        }
    }
    let cost: u128 = union.iter().map(|l| dims[l] as u128).product();
    let needed = |l: &GLabel| {
        out.contains(l)
            || terms
                .iter()
                .enumerate()
                .any(|(t, term)| t != i && t != j && term.labels.contains(l))
    };
    let res: Vec<GLabel> = union.into_iter().filter(needed).collect();
    (cost, res)
}

/// Emit the binary Mul node for one greedy step, relabelling the global
/// labels into a compact local space.
fn build_mul(g: &mut Graph, a: &Term, b: &Term, res: &[GLabel]) -> NodeId {
    let mut local: HashMap<GLabel, Label> = HashMap::new();
    let mut next: Label = 0;
    let mut conv = |gl: GLabel, local: &mut HashMap<GLabel, Label>| -> Label {
        *local.entry(gl).or_insert_with(|| {
            let l = next;
            next += 1;
            l
        })
    };
    let s1: Vec<Label> = a.labels.iter().map(|&gl| conv(gl, &mut local)).collect();
    let s2: Vec<Label> = b.labels.iter().map(|&gl| conv(gl, &mut local)).collect();
    let s3: Vec<Label> = res.iter().map(|&gl| conv(gl, &mut local)).collect();
    g.mul(a.node, b.node, EinSpec::new(s1, s2, s3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval, Env};
    use crate::ir::Elem;
    use crate::simplify::{flop_estimate, simplify_one};
    use crate::tensor::Tensor;

    #[test]
    fn example7_orders_vectors_first() {
        // Example 7 of the paper: d = B·diag(u)·diag(v)·A-chain. Reverse
        // association multiplies matrix×matrix first; cross-country must
        // merge the two vectors first, reducing the flop estimate.
        let (m, n) = (12, 16);
        let mut g = Graph::new();
        let bmat = g.var("B", &[m, n]);
        let amat = g.var("A", &[n, m]);
        let u = g.var("u", &[n]);
        let v = g.var("v", &[n]);
        // left-to-right (reverse-mode-like) association:
        // ((B·diag(u))·diag(v))·A
        let bu = g.coldiag(bmat, u);
        let buv = g.coldiag(bu, v);
        let full = g.matmul(buv, amat);
        let before = flop_estimate(&g, full);
        let opt = optimize_contractions(&mut g, full);
        let after = flop_estimate(&g, opt);
        assert!(after < before, "cross-country should reduce flops: {} vs {}", after, before);

        let mut env = Env::new();
        env.insert("B", Tensor::randn(&[m, n], 1));
        env.insert("A", Tensor::randn(&[n, m], 2));
        env.insert("u", Tensor::randn(&[n], 3));
        env.insert("v", Tensor::randn(&[n], 4));
        let x = eval(&g, full, &env);
        let y = eval(&g, opt, &env);
        assert!(x.allclose(&y, 1e-9, 1e-11), "diff {}", x.max_abs_diff(&y));
    }

    #[test]
    fn chain_of_matrices_orders_by_cost() {
        // (A·B)·x is worse than A·(B·x): matrix-vector first
        let mut g = Graph::new();
        let a = g.var("A", &[20, 20]);
        let b = g.var("B", &[20, 20]);
        let x = g.var("x", &[20]);
        let ab = g.matmul(a, b);
        let y = g.matvec(ab, x);
        let opt = optimize_contractions(&mut g, y);
        assert!(flop_estimate(&g, opt) < flop_estimate(&g, y));
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[20, 20], 1));
        env.insert("B", Tensor::randn(&[20, 20], 2));
        env.insert("x", Tensor::randn(&[20], 3));
        let want = eval(&g, y, &env);
        let got = eval(&g, opt, &env);
        assert!(got.allclose(&want, 1e-9, 1e-11));
    }

    #[test]
    fn shared_subexpressions_stay_atomic() {
        // e = exp(Ax) is used twice; flattening must not duplicate it
        let mut g = Graph::new();
        let a = g.var("A", &[6, 6]);
        let x = g.var("x", &[6]);
        let ax = g.matvec(a, x);
        let e = g.elem(Elem::Exp, ax);
        let h = g.hadamard(e, e); // e used twice
        let y = g.tmatvec(a, h);
        let opt = optimize_contractions(&mut g, y);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[6, 6], 4));
        env.insert("x", Tensor::randn(&[6], 5));
        let want = eval(&g, y, &env);
        let got = eval(&g, opt, &env);
        assert!(got.allclose(&want, 1e-9, 1e-11));
        // exp must still appear exactly once in the optimized DAG
        let exp_count = g
            .topo(&[opt])
            .iter()
            .filter(|&&n| matches!(g.op(n), Op::Elem(Elem::Exp, _)))
            .count();
        assert_eq!(exp_count, 1);
    }

    #[test]
    fn preserves_permuted_outputs() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.mul(a, b, EinSpec::parse("ij,jk->ki"));
        let opt = optimize_contractions(&mut g, c);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 1));
        env.insert("B", Tensor::randn(&[4, 5], 2));
        let want = eval(&g, c, &env);
        let got = eval(&g, opt, &env);
        assert!(got.allclose(&want, 1e-10, 1e-12));
    }

    #[test]
    fn hessian_cross_country_matches_plain() {
        // end-to-end: logistic-regression-style Hessian, optimized vs not
        use crate::autodiff::hessian::hessian;
        let mut g = Graph::new();
        let x = g.var("X", &[8, 4]);
        let w = g.var("w", &[4]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[8]);
        let s = g.add(e, one);
        let l = g.elem(Elem::Log, s);
        let f = g.sum_all(l);
        let h = hessian(&mut g, f, w);
        let h_cc = optimize_contractions(&mut g, h);
        let h_cc = simplify_one(&mut g, h_cc);
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[8, 4], 1));
        env.insert("w", Tensor::randn(&[4], 2));
        let a = eval(&g, h, &env);
        let b = eval(&g, h_cc, &env);
        assert!(a.allclose(&b, 1e-9, 1e-11), "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn single_mul_is_untouched_semantically() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.matmul(a, b);
        let opt = optimize_contractions(&mut g, c);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 6));
        env.insert("B", Tensor::randn(&[4, 5], 7));
        assert!(eval(&g, opt, &env).allclose(&eval(&g, c, &env), 1e-12, 1e-12));
    }
}
