//! Cross-country mode (§3.3): reorder chains of generic multiplications.
//!
//! Forward and reverse mode multiply the partial derivatives in opposite,
//! fixed orders; neither is optimal for non-scalar derivatives. The paper's
//! strategy — multiply tensors in order of increasing tensor order
//! (vectors first, then matrices, …) — is implemented as its natural
//! generalization: multiplication chains in the derivative DAG are
//! flattened into n-ary contractions and re-associated greedily by
//! contraction cost (with tensor order as tie-break). On the
//! `B·diag(u)·diag(v)·A` chains that dominate Hessians (Example 7) this
//! reproduces exactly the paper's ordering: the element-wise vector
//! factors merge first.
//!
//! Since PR 3 the machinery lives in [`crate::opt::reassoc`], where the
//! optimizer pipeline runs it jointly over whole root sets (with a cost
//! guard); this entry point is the single-root historical API used by
//! the `ours(cross-country)` mode and the compression pipeline.
//!
//! Re-association is justified by Lemmas 1–3; the flattened n-ary view
//! makes the validity condition automatic (labels are unified globally,
//! summed labels stay internal).

use crate::ir::{Graph, NodeId};

/// Re-associate all multiplication chains below `root`; returns the new
/// root. Semantics are preserved exactly (tested against the untouched
/// DAG); only the association order of `*` changes.
pub fn optimize_contractions(g: &mut Graph, root: NodeId) -> NodeId {
    crate::opt::reassoc::reassociate(g, &[root]).0[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::EinSpec;
    use crate::eval::{eval, Env};
    use crate::ir::{Elem, Op};
    use crate::simplify::{flop_estimate, simplify_one};
    use crate::tensor::Tensor;

    #[test]
    fn example7_orders_vectors_first() {
        // Example 7 of the paper: d = B·diag(u)·diag(v)·A-chain. Reverse
        // association multiplies matrix×matrix first; cross-country must
        // merge the two vectors first, reducing the flop estimate.
        let (m, n) = (12, 16);
        let mut g = Graph::new();
        let bmat = g.var("B", &[m, n]);
        let amat = g.var("A", &[n, m]);
        let u = g.var("u", &[n]);
        let v = g.var("v", &[n]);
        // left-to-right (reverse-mode-like) association:
        // ((B·diag(u))·diag(v))·A
        let bu = g.coldiag(bmat, u);
        let buv = g.coldiag(bu, v);
        let full = g.matmul(buv, amat);
        let before = flop_estimate(&g, full);
        let opt = optimize_contractions(&mut g, full);
        let after = flop_estimate(&g, opt);
        assert!(after < before, "cross-country should reduce flops: {} vs {}", after, before);

        let mut env = Env::new();
        env.insert("B", Tensor::randn(&[m, n], 1));
        env.insert("A", Tensor::randn(&[n, m], 2));
        env.insert("u", Tensor::randn(&[n], 3));
        env.insert("v", Tensor::randn(&[n], 4));
        let x = eval(&g, full, &env);
        let y = eval(&g, opt, &env);
        assert!(x.allclose(&y, 1e-9, 1e-11), "diff {}", x.max_abs_diff(&y));
    }

    #[test]
    fn chain_of_matrices_orders_by_cost() {
        // (A·B)·x is worse than A·(B·x): matrix-vector first
        let mut g = Graph::new();
        let a = g.var("A", &[20, 20]);
        let b = g.var("B", &[20, 20]);
        let x = g.var("x", &[20]);
        let ab = g.matmul(a, b);
        let y = g.matvec(ab, x);
        let opt = optimize_contractions(&mut g, y);
        assert!(flop_estimate(&g, opt) < flop_estimate(&g, y));
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[20, 20], 1));
        env.insert("B", Tensor::randn(&[20, 20], 2));
        env.insert("x", Tensor::randn(&[20], 3));
        let want = eval(&g, y, &env);
        let got = eval(&g, opt, &env);
        assert!(got.allclose(&want, 1e-9, 1e-11));
    }

    #[test]
    fn shared_subexpressions_stay_atomic() {
        // e = exp(Ax) is used twice; flattening must not duplicate it
        let mut g = Graph::new();
        let a = g.var("A", &[6, 6]);
        let x = g.var("x", &[6]);
        let ax = g.matvec(a, x);
        let e = g.elem(Elem::Exp, ax);
        let h = g.hadamard(e, e); // e used twice
        let y = g.tmatvec(a, h);
        let opt = optimize_contractions(&mut g, y);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[6, 6], 4));
        env.insert("x", Tensor::randn(&[6], 5));
        let want = eval(&g, y, &env);
        let got = eval(&g, opt, &env);
        assert!(got.allclose(&want, 1e-9, 1e-11));
        // exp must still appear exactly once in the optimized DAG
        let exp_count = g
            .topo(&[opt])
            .iter()
            .filter(|&&n| matches!(g.op(n), Op::Elem(Elem::Exp, _)))
            .count();
        assert_eq!(exp_count, 1);
    }

    #[test]
    fn preserves_permuted_outputs() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.mul(a, b, EinSpec::parse("ij,jk->ki"));
        let opt = optimize_contractions(&mut g, c);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 1));
        env.insert("B", Tensor::randn(&[4, 5], 2));
        let want = eval(&g, c, &env);
        let got = eval(&g, opt, &env);
        assert!(got.allclose(&want, 1e-10, 1e-12));
    }

    #[test]
    fn hessian_cross_country_matches_plain() {
        // end-to-end: logistic-regression-style Hessian, optimized vs not
        use crate::autodiff::hessian::hessian;
        let mut g = Graph::new();
        let x = g.var("X", &[8, 4]);
        let w = g.var("w", &[4]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[8]);
        let s = g.add(e, one);
        let l = g.elem(Elem::Log, s);
        let f = g.sum_all(l);
        let h = hessian(&mut g, f, w);
        let h_cc = optimize_contractions(&mut g, h);
        let h_cc = simplify_one(&mut g, h_cc);
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[8, 4], 1));
        env.insert("w", Tensor::randn(&[4], 2));
        let a = eval(&g, h, &env);
        let b = eval(&g, h_cc, &env);
        assert!(a.allclose(&b, 1e-9, 1e-11), "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn single_mul_is_untouched_semantically() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.matmul(a, b);
        let opt = optimize_contractions(&mut g, c);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 6));
        env.insert("B", Tensor::randn(&[4, 5], 7));
        assert!(eval(&g, opt, &env).allclose(&eval(&g, c, &env), 1e-12, 1e-12));
    }
}
