//! Forward mode automatic differentiation in Einstein notation
//! (Section 3.1, Theorems 5–7).
//!
//! Each node `v` receives a *pushforward* `v̇ = ∂v/∂x`, a tensor with
//! index set `s_v ++ s4` where `s4` is the input variable's index set.
//! The seed at the input is the unit tensor δ.

use super::{fresh_block, relabel_from};
use crate::einsum::{EinSpec, Label};
use crate::ir::{Graph, NodeId, Op};
use std::collections::HashMap;

/// Forward-mode derivative of `y` with respect to `x`. Note the layout:
/// forward mode produces `shape(y) ++ shape(x)` just like reverse mode,
/// so the two are directly comparable (and interchangeable in the
/// cross-country combinations of Section 3.3).
pub fn forward_derivative(g: &mut Graph, y: NodeId, x: NodeId) -> NodeId {
    let s4_shape = g.shape(x).to_vec();
    let r4 = s4_shape.len();
    let seed = if r4 == 0 { g.scalar(1.0) } else { g.delta(&s4_shape) };

    let order = g.topo(&[y]);
    // pushforward per node; absent = does not depend on x (zero)
    let mut dot: HashMap<NodeId, NodeId> = HashMap::new();
    dot.insert(x, seed);

    for &id in &order {
        if id == x || dot.contains_key(&id) {
            continue;
        }
        let pushed = match g.op(id).clone() {
            Op::Add(a, b) => match (dot.get(&a).copied(), dot.get(&b).copied()) {
                (Some(da), Some(db)) => Some(g.add(da, db)),
                (Some(da), None) => Some(da),
                (None, Some(db)) => Some(db),
                (None, None) => None,
            },
            Op::Mul(a, b, spec) => {
                let da = dot.get(&a).copied();
                let db = dot.get(&b).copied();
                if da.is_none() && db.is_none() {
                    None
                } else {
                    let sp = relabel_from(&spec, 0);
                    let s4 = fresh_block(r4, sp.max_label() + 1);
                    // Theorem 5: Ċ = B *_(s2, s1 s4, s3 s4) Ȧ
                    //              + A *_(s1, s2 s4, s3 s4) Ḃ
                    let s3s4: Vec<Label> = sp.s3.iter().chain(&s4).copied().collect();
                    let term_a = da.map(|da| {
                        let s1s4: Vec<Label> = sp.s1.iter().chain(&s4).copied().collect();
                        g.mul(b, da, EinSpec::new(sp.s2.clone(), s1s4, s3s4.clone()))
                    });
                    let term_b = db.map(|db| {
                        let s2s4: Vec<Label> = sp.s2.iter().chain(&s4).copied().collect();
                        g.mul(a, db, EinSpec::new(sp.s1.clone(), s2s4, s3s4.clone()))
                    });
                    match (term_a, term_b) {
                        (Some(ta), Some(tb)) => Some(g.add(ta, tb)),
                        (Some(ta), None) => Some(ta),
                        (None, Some(tb)) => Some(tb),
                        (None, None) => unreachable!(),
                    }
                }
            }
            Op::Elem(f, a) => dot.get(&a).copied().map(|da| {
                // Theorem 7: Ċ = f'(A) *_(s1, s1 s4, s1 s4) Ȧ
                let r1 = g.order(a);
                let s1 = fresh_block(r1, 0);
                let s4 = fresh_block(r4, r1 as Label);
                let fp = f.derivative(g, a);
                let s14: Vec<Label> = s1.iter().chain(&s4).copied().collect();
                g.mul(fp, da, EinSpec::new(s1, s14.clone(), s14))
            }),
            Op::GenUnary(f, a) => dot.get(&a).copied().map(|da| {
                // Theorem 6: Ċ = f'(A) *_(s2 s1, s1 s4, s2 s4) Ȧ
                let r1 = g.order(a);
                let r2 = g.order(id);
                let s2 = fresh_block(r2, 0);
                let s1 = fresh_block(r1, r2 as Label);
                let s4 = fresh_block(r4, (r2 + r1) as Label);
                let fp = f.derivative(g, a);
                let s21: Vec<Label> = s2.iter().chain(&s1).copied().collect();
                let s14: Vec<Label> = s1.iter().chain(&s4).copied().collect();
                let s24: Vec<Label> = s2.iter().chain(&s4).copied().collect();
                g.mul(fp, da, EinSpec::new(s21, s14, s24))
            }),
            Op::Var(_) | Op::Const(_) | Op::Delta { .. } => None,
        };
        if let Some(p) = pushed {
            dot.insert(id, p);
        }
    }

    dot.get(&y).copied().unwrap_or_else(|| {
        let shape: Vec<usize> = g.shape(y).iter().chain(&s4_shape).copied().collect();
        g.constant(0.0, &shape)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::reverse::reverse_derivative;
    use crate::eval::{eval, fd_jacobian, Env};
    use crate::ir::Elem;
    use crate::tensor::Tensor;

    fn env_of(pairs: &[(&str, Tensor)]) -> Env {
        let mut env = Env::new();
        for (n, t) in pairs {
            env.insert(n, t.clone());
        }
        env
    }

    #[test]
    fn forward_matches_fd_on_vector_function() {
        // y = exp(Ax)
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let y = g.elem(Elem::Exp, ax);
        let jac = forward_derivative(&mut g, y, x);
        assert_eq!(g.shape(jac), &[3, 4]);
        let env = env_of(&[("A", Tensor::randn(&[3, 4], 1)), ("x", Tensor::randn(&[4], 2))]);
        let jv = eval(&g, jac, &env);
        let want = fd_jacobian(&g, y, "x", &env, 1e-6);
        assert!(jv.allclose(&want, 1e-5, 1e-7), "diff {}", jv.max_abs_diff(&want));
    }

    #[test]
    fn forward_equals_reverse_jacobian() {
        // The two modes must produce identical tensors (they multiply the
        // same partials in opposite order — Section 3.3).
        let mut g = Graph::new();
        let a = g.var("A", &[4, 3]);
        let x = g.var("x", &[3]);
        let ax = g.matvec(a, x);
        let s = g.elem(Elem::Sigmoid, ax);
        let y = g.tmatvec(a, s);
        let jf = forward_derivative(&mut g, y, x);
        let jr = reverse_derivative(&mut g, y, &[x])[0];
        let env = env_of(&[("A", Tensor::randn(&[4, 3], 3)), ("x", Tensor::randn(&[3], 4))]);
        let f = eval(&g, jf, &env);
        let r = eval(&g, jr, &env);
        assert!(f.allclose(&r, 1e-10, 1e-12), "diff {}", f.max_abs_diff(&r));
    }

    #[test]
    fn forward_wrt_matrix_variable() {
        // Y = AB, derivative wrt A has shape [2,4,2,3]
        let mut g = Graph::new();
        let a = g.var("A", &[2, 3]);
        let b = g.var("B", &[3, 4]);
        let y = g.matmul(a, b);
        let j = forward_derivative(&mut g, y, a);
        assert_eq!(g.shape(j), &[2, 4, 2, 3]);
        let env = env_of(&[("A", Tensor::randn(&[2, 3], 5)), ("B", Tensor::randn(&[3, 4], 6))]);
        let jv = eval(&g, j, &env);
        let want = fd_jacobian(&g, y, "A", &env, 1e-6);
        assert!(jv.allclose(&want, 1e-4, 1e-6), "diff {}", jv.max_abs_diff(&want));
    }

    #[test]
    fn forward_scalar_input() {
        // y = exp(t · c) with scalar t
        let mut g = Graph::new();
        let t = g.var("t", &[]);
        let c = g.var("c", &[3]);
        let tc = g.mul(c, t, EinSpec::parse("i,->i"));
        let y = g.elem(Elem::Exp, tc);
        let j = forward_derivative(&mut g, y, t);
        assert_eq!(g.shape(j), &[3]);
        let env = env_of(&[("t", Tensor::scalar(0.7)), ("c", Tensor::randn(&[3], 7))]);
        let jv = eval(&g, j, &env);
        let want = fd_jacobian(&g, y, "t", &env, 1e-6).reshape(&[3]);
        assert!(jv.allclose(&want, 1e-5, 1e-7));
    }

    #[test]
    fn forward_zero_when_independent() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let z = g.var("z", &[2]);
        let f = g.norm2(x);
        let j = forward_derivative(&mut g, f, z);
        let env = env_of(&[("x", Tensor::randn(&[3], 1)), ("z", Tensor::randn(&[2], 2))]);
        assert_eq!(eval(&g, j, &env), Tensor::zeros(&[2]));
    }

    #[test]
    fn forward_through_general_unary() {
        let mut g = Graph::new();
        let x = g.var("x", &[5]);
        let s = g.gen_unary(crate::ir::GenFn::Softmax, x);
        let j = forward_derivative(&mut g, s, x);
        assert_eq!(g.shape(j), &[5, 5]);
        let env = env_of(&[("x", Tensor::randn(&[5], 9))]);
        let jv = eval(&g, j, &env);
        let want = fd_jacobian(&g, s, "x", &env, 1e-6);
        assert!(jv.allclose(&want, 1e-5, 1e-7), "diff {}", jv.max_abs_diff(&want));
    }
}
