//! Compression of higher-order derivatives (§3.3).
//!
//! In both modes the first partial derivative is a unit tensor; with the
//! cross-country ordering it is multiplied last, where it either cancels
//! (handled by [`crate::simplify`]) or survives as a *pure expansion*:
//! a multiplication `core *_(…) δ` with no summed labels. Such a root is
//! stored compressed — only `core` is ever evaluated. The flagship
//! example is the matrix-factorization Hessian
//! `H = 2(VᵀV) *_(jl,ik,ijkl) 𝕀 ∈ R^{n×k×n×k}`, compressed to the k×k
//! matrix `2(VᵀV)`.

use crate::einsum::{einsum, EinSpec};
use crate::ir::{Graph, NodeId, Op};
use crate::tensor::Tensor;

/// A derivative in (possibly) compressed representation.
#[derive(Clone, Debug)]
pub enum CompressedDerivative {
    /// No compressible structure found: the plain expression.
    Full(NodeId),
    /// `H[spec.s3] = core[spec.s1] · δ[spec.s2]` with no summation —
    /// only `core` needs to be evaluated.
    DeltaFactored {
        core: NodeId,
        delta_dims: Vec<usize>,
        spec: EinSpec,
        /// shape of the uncompressed derivative
        full_shape: Vec<usize>,
    },
}

impl CompressedDerivative {
    /// The node to evaluate (core for compressed, the expression itself
    /// otherwise).
    pub fn eval_node(&self) -> NodeId {
        match self {
            CompressedDerivative::Full(n) => *n,
            CompressedDerivative::DeltaFactored { core, .. } => *core,
        }
    }

    pub fn is_compressed(&self) -> bool {
        matches!(self, CompressedDerivative::DeltaFactored { .. })
    }

    /// Element count of what actually gets evaluated vs the full tensor —
    /// the compression ratio reported in the benchmarks.
    pub fn compression_ratio(&self, g: &Graph) -> f64 {
        match self {
            CompressedDerivative::Full(_) => 1.0,
            CompressedDerivative::DeltaFactored { core, full_shape, .. } => {
                let full: usize = full_shape.iter().product();
                let small: usize = g.shape(*core).iter().product();
                small as f64 / full as f64
            }
        }
    }

    /// Materialise the full derivative tensor from an evaluated core —
    /// used by tests and by consumers that genuinely need the dense form.
    pub fn materialize(&self, core_value: &Tensor) -> Tensor {
        match self {
            CompressedDerivative::Full(_) => core_value.clone(),
            CompressedDerivative::DeltaFactored { delta_dims, spec, .. } => {
                let d = Tensor::delta(delta_dims);
                einsum(spec, core_value, &d)
            }
        }
    }
}

/// Detect the compressible `core · δ` structure at the root of a
/// derivative expression (run [`crate::simplify`] first — it leaves the
/// delta factored at the root precisely when it cannot be contracted).
/// Scalar scaling wrappers around the product are pushed into the core.
pub fn compress_derivative(g: &mut Graph, h: NodeId) -> CompressedDerivative {
    // peel `x *_(s,∅,s) c` scalar-scale wrappers, collecting the factor
    let mut node = h;
    let mut scale = 1.0f64;
    loop {
        match g.op(node).clone() {
            Op::Mul(x, k, spec)
                if spec.s2.is_empty()
                    && spec.s3 == spec.s1
                    && g.const_value(k).is_some() =>
            {
                scale *= g.const_value(k).unwrap();
                node = x;
            }
            _ => break,
        }
    }

    let (a, b, spec) = match g.op(node).clone() {
        Op::Mul(a, b, spec) => (a, b, spec),
        _ => return CompressedDerivative::Full(h),
    };
    // normalize delta to the right
    let (core, delta, spec) = match (g.op(a).clone(), g.op(b).clone()) {
        (_, Op::Delta { dims }) => (a, dims, spec),
        (Op::Delta { dims }, _) => (b, dims, spec.swapped()),
        _ => return CompressedDerivative::Full(h),
    };
    // pure expansion: nothing summed. Delta labels may be shared with the
    // core — the paper's neural-net Hessian `A *_(ijl,ik,ijkl) 𝕀` shares
    // `i` — because materialization is then a broadcast/mask, and the
    // core still carries all the information.
    if !spec.is_sum_free() {
        return CompressedDerivative::Full(h);
    }
    let full_shape = g.shape(node).to_vec();
    let core = if scale == 1.0 {
        core
    } else {
        g.scale(core, scale)
    };
    CompressedDerivative::DeltaFactored { core, delta_dims: delta, spec, full_shape }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::hessian::{hessian, hessian_compressed};
    use crate::eval::{eval, Env};
    use crate::simplify::simplify_one;

    #[test]
    fn matfac_hessian_compresses_to_k_by_k() {
        // f = ‖T − U Vᵀ‖², Hessian w.r.t. U is 2(VᵀV) ⊗ 𝕀 — the paper's
        // flagship compression example
        let (n, k) = (6, 2);
        let mut g = Graph::new();
        let t = g.var("T", &[n, n]);
        let u = g.var("U", &[n, k]);
        let v = g.var("V", &[n, k]);
        let uvt = g.matmul_t(u, v);
        let d = g.sub(t, uvt);
        let f = g.norm2(d);
        let comp = hessian_compressed(&mut g, f, u);
        assert!(comp.is_compressed(), "matfac Hessian must compress");
        let core = comp.eval_node();
        assert_eq!(g.shape(core), &[k, k], "core must be k×k, got {:?}", g.shape(core));
        // ratio (k·k)/(n·k·n·k) = 1/n²
        let ratio = comp.compression_ratio(&g);
        assert!((ratio - 1.0 / (n * n) as f64).abs() < 1e-12, "ratio {}", ratio);

        // numerics: materialized compressed == full Hessian
        let mut env = Env::new();
        env.insert("T", Tensor::randn(&[n, n], 1));
        env.insert("U", Tensor::randn(&[n, k], 2));
        env.insert("V", Tensor::randn(&[n, k], 3));
        let core_v = eval(&g, core, &env);
        let mat = comp.materialize(&core_v);
        let h_full = hessian(&mut g, f, u);
        let full_v = eval(&g, h_full, &env);
        assert!(
            mat.allclose(&full_v, 1e-9, 1e-11),
            "diff {}",
            mat.max_abs_diff(&full_v)
        );
        // and the core is 2·VᵀV
        let vt_v = {
            let v = env.get("V").unwrap();
            let spec = EinSpec::parse("ij,ik->jk");
            einsum(&spec, v, v).scale(2.0)
        };
        assert!(core_v.allclose(&vt_v, 1e-9, 1e-11));
    }

    #[test]
    fn non_compressible_hessian_returns_full() {
        // logistic-regression Hessian Xᵀdiag(v)X has no free delta factor
        let mut g = Graph::new();
        let x = g.var("X", &[5, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(crate::ir::Elem::Exp, xw);
        let one = g.constant(1.0, &[5]);
        let s = g.add(e, one);
        let l = g.elem(crate::ir::Elem::Log, s);
        let f = g.sum_all(l);
        let comp = hessian_compressed(&mut g, f, w);
        assert!(!comp.is_compressed());
    }

    #[test]
    fn manual_delta_factored_root_detected() {
        // H[i,j,k,l] = M[j,l]·δ[i,k], possibly scaled
        let mut g = Graph::new();
        let m = g.var("M", &[3, 3]);
        let d = g.delta(&[5]);
        let h = g.mul(m, d, EinSpec::parse("jl,ik->ijkl"));
        let h2 = g.scale(h, 2.0);
        let h2 = simplify_one(&mut g, h2);
        let comp = compress_derivative(&mut g, h2);
        assert!(comp.is_compressed());
        assert_eq!(g.shape(comp.eval_node()), &[3, 3]);
        // materialization semantics
        let mut env = Env::new();
        env.insert("M", Tensor::randn(&[3, 3], 4));
        let cv = eval(&g, comp.eval_node(), &env);
        let full = comp.materialize(&cv);
        assert_eq!(full.shape(), &[5, 3, 5, 3]);
        let mval = env.get("M").unwrap();
        for i in 0..5 {
            for j in 0..3 {
                let want = 2.0 * mval.at(&[j, j]);
                let _ = want;
                for k in 0..5 {
                    for l in 0..3 {
                        let want = if i == k { 2.0 * mval.at(&[j, l]) } else { 0.0 };
                        assert!((full.at(&[i, j, k, l]) - want).abs() < 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn summed_delta_is_not_compressible() {
        let mut g = Graph::new();
        let m = g.var("M", &[3, 4]);
        let d = g.delta(&[4]);
        // Σ_j M[i,j] δ[j,k] — contraction, not expansion (simplify would
        // remove it; compress alone must refuse)
        let h = g.mul(m, d, EinSpec::parse("ij,jk->ik"));
        let comp = compress_derivative(&mut g, h);
        assert!(!comp.is_compressed());
    }
}
