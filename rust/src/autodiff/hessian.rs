//! Higher-order derivatives: Jacobians and Hessians, computed by applying
//! the (non-scalar-seeded) reverse mode to the derivative expression —
//! the construction whose reverse-mode instance the paper proves
//! equivalent to Laue et al. [6].

use super::compress::{compress_derivative, CompressedDerivative};
use super::reverse::{reverse_derivative, reverse_gradient};
use crate::ir::{Graph, NodeId};
use crate::simplify::simplify_one;

/// Jacobian of a (possibly tensor-valued) expression `y` with respect to
/// `x`: shape `shape(y) ++ shape(x)`. Simplified.
pub fn jacobian(g: &mut Graph, y: NodeId, x: NodeId) -> NodeId {
    let j = reverse_derivative(g, y, &[x])[0];
    simplify_one(g, j)
}

/// Hessian of a scalar expression `f` with respect to `x`: shape
/// `shape(x) ++ shape(x)`. Computed as the Jacobian of the simplified
/// gradient expression.
pub fn hessian(g: &mut Graph, f: NodeId, x: NodeId) -> NodeId {
    assert!(g.shape(f).is_empty(), "hessian needs a scalar function");
    let grad = reverse_gradient(g, f, x);
    let grad = simplify_one(g, grad);
    jacobian(g, grad, x)
}

/// Hessian in compressed form (§3.3): unit-tensor factors that survive
/// simplification are split off symbolically instead of being
/// materialised, e.g. the matrix-factorization Hessian
/// `2(VᵀV) ⊗ 𝕀` is returned as the k×k core `2(VᵀV)`.
///
/// As in the paper, "our compression scheme builds on the re-ordering
/// scheme (cross-country mode)": the greedy cheapest-first contraction
/// order naturally pushes the (most expensive) unit tensor to the last
/// multiplication, where [`compress_derivative`] splits it off.
pub fn hessian_compressed(g: &mut Graph, f: NodeId, x: NodeId) -> CompressedDerivative {
    let h = hessian(g, f, x);
    let h = crate::autodiff::cross_country::optimize_contractions(g, h);
    let h = crate::simplify::simplify_one(g, h);
    compress_derivative(g, h)
}

/// Gradient *and* Hessian sharing one simplified gradient DAG.
pub fn grad_and_hessian(g: &mut Graph, f: NodeId, x: NodeId) -> (NodeId, NodeId) {
    let grad = reverse_gradient(g, f, x);
    let grad = simplify_one(g, grad);
    let h = jacobian(g, grad, x);
    (grad, h)
}

/// Hessian–vector product `H·v` *without materialising H* — the
/// Pearlmutter [10] construction the paper discusses in Related Work:
/// differentiate `⟨∇f, v⟩` with respect to `x`, where `v` is a fresh
/// input variable named `v_name`. Cost: one extra reverse sweep, O(n)
/// memory — the right tool when only products are needed (CG/Newton-CG),
/// complementary to the full compressed Hessians of §3.3.
pub fn hessian_vector_product(
    g: &mut Graph,
    f: NodeId,
    x: NodeId,
    v_name: &str,
) -> NodeId {
    assert!(g.shape(f).is_empty(), "hvp needs a scalar function");
    let grad = reverse_gradient(g, f, x);
    let grad = simplify_one(g, grad);
    let shape = g.shape(x).to_vec();
    let v = g.var(v_name, &shape);
    let p = g.hadamard(grad, v);
    let gv = g.sum_all(p);
    let hvp = reverse_gradient(g, gv, x);
    simplify_one(g, hvp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval, fd_jacobian, Env};
    use crate::ir::Elem;
    use crate::tensor::Tensor;

    fn env_of(pairs: &[(&str, Tensor)]) -> Env {
        let mut env = Env::new();
        for (n, t) in pairs {
            env.insert(n, t.clone());
        }
        env
    }

    #[test]
    fn hessian_of_quadratic_is_constant() {
        // f = ½ xᵀAx with symmetric A ⇒ H = ½(A + Aᵀ)
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let q = g.dot(x, ax);
        let f = g.scale(q, 0.5);
        let h = hessian(&mut g, f, x);
        assert_eq!(g.shape(h), &[4, 4]);
        let av = Tensor::randn(&[4, 4], 1);
        let env = env_of(&[("A", av.clone()), ("x", Tensor::randn(&[4], 2))]);
        let hv = eval(&g, h, &env);
        let want = av.add(&av.t()).scale(0.5);
        assert!(hv.allclose(&want, 1e-10, 1e-12), "diff {}", hv.max_abs_diff(&want));
    }

    #[test]
    fn hessian_of_logistic_term_matches_fd() {
        // f = Σ log(exp(Xw)+1)
        let mut g = Graph::new();
        let x = g.var("X", &[6, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[6]);
        let s = g.add(e, one);
        let l = g.elem(Elem::Log, s);
        let f = g.sum_all(l);
        let (grad, h) = grad_and_hessian(&mut g, f, w);
        let env = env_of(&[("X", Tensor::randn(&[6, 3], 3)), ("w", Tensor::randn(&[3], 4))]);
        let hv = eval(&g, h, &env);
        let want = fd_jacobian(&g, grad, "w", &env, 1e-5);
        assert!(hv.allclose(&want, 1e-4, 1e-6), "diff {}", hv.max_abs_diff(&want));
        // Hessian of a smooth function is symmetric
        assert!(hv.allclose(&hv.t(), 1e-9, 1e-11));
    }

    #[test]
    fn hessian_wrt_matrix_variable_is_order4() {
        // f = ‖T − U Uᵀ‖² (symmetric factorization flavour)
        let mut g = Graph::new();
        let t = g.var("T", &[3, 3]);
        let u = g.var("U", &[3, 2]);
        let uut = g.matmul_t(u, u);
        let d = g.sub(t, uut);
        let f = g.norm2(d);
        let h = hessian(&mut g, f, u);
        assert_eq!(g.shape(h), &[3, 2, 3, 2]);
        let grad = {
            let gr = reverse_gradient(&mut g, f, u);
            simplify_one(&mut g, gr)
        };
        let env = env_of(&[("T", Tensor::randn(&[3, 3], 5)), ("U", Tensor::randn(&[3, 2], 6))]);
        let hv = eval(&g, h, &env);
        let want = fd_jacobian(&g, grad, "U", &env, 1e-5);
        assert!(hv.allclose(&want, 1e-4, 1e-5), "diff {}", hv.max_abs_diff(&want));
    }

    #[test]
    fn third_derivative_by_iterating() {
        // f = Σ x³ (via x ⊙ x ⊙ x): ∂³f/∂x³ is diag₃(6)
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let x2 = g.hadamard(x, x);
        let x3 = g.hadamard(x2, x);
        let f = g.sum_all(x3);
        let g1 = jacobian(&mut g, f, x);
        let g2 = jacobian(&mut g, g1, x);
        let g3 = jacobian(&mut g, g2, x);
        assert_eq!(g.shape(g3), &[3, 3, 3]);
        let env = env_of(&[("x", Tensor::randn(&[3], 7))]);
        let t3 = eval(&g, g3, &env);
        for i in 0..3 {
            for j in 0..3 {
                for k in 0..3 {
                    let want = if i == j && j == k { 6.0 } else { 0.0 };
                    assert!((t3.at(&[i, j, k]) - want).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn hvp_matches_explicit_hessian_product() {
        use super::hessian_vector_product;
        use crate::einsum::{einsum, EinSpec};
        let mut g = Graph::new();
        let a = g.var("A", &[5, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let s = g.elem(Elem::Sigmoid, ax);
        let f = g.norm2(s);
        let h = hessian(&mut g, f, x);
        let hvp = hessian_vector_product(&mut g, f, x, "v");
        let env = env_of(&[
            ("A", Tensor::randn(&[5, 4], 1)),
            ("x", Tensor::randn(&[4], 2)),
            ("v", Tensor::randn(&[4], 3)),
        ]);
        let hv = eval(&g, h, &env);
        let want = einsum(&EinSpec::parse("ij,j->i"), &hv, env.get("v").unwrap());
        let got = eval(&g, hvp, &env);
        assert!(got.allclose(&want, 1e-9, 1e-11), "diff {}", got.max_abs_diff(&want));
        // and the HVP DAG must be materialisation-free: no node of order ≥ 2
        // beyond the inputs' natural shapes at n=4 is required — check the
        // biggest intermediate is O(matrix), not O(Hessian) at larger n
        let mut g2 = Graph::new();
        let a2 = g2.var("A", &[64, 64]);
        let x2 = g2.var("x", &[64]);
        let ax2 = g2.matvec(a2, x2);
        let s2 = g2.elem(Elem::Sigmoid, ax2);
        let f2 = g2.norm2(s2);
        let hvp2 = hessian_vector_product(&mut g2, f2, x2, "v");
        assert_eq!(g2.shape(hvp2), &[64]);
    }

    #[test]
    fn forward_over_reverse_matches_reverse_over_reverse() {
        use crate::autodiff::forward::forward_derivative;
        let mut g = Graph::new();
        let a = g.var("A", &[4, 3]);
        let x = g.var("x", &[3]);
        let ax = g.matvec(a, x);
        let s = g.elem(Elem::Tanh, ax);
        let f = g.norm2(s);
        let grad = reverse_gradient(&mut g, f, x);
        let grad = simplify_one(&mut g, grad);
        let h_rr = jacobian(&mut g, grad, x);
        let h_fr = forward_derivative(&mut g, grad, x);
        let env = env_of(&[("A", Tensor::randn(&[4, 3], 8)), ("x", Tensor::randn(&[3], 9))]);
        let rr = eval(&g, h_rr, &env);
        let fr = eval(&g, h_fr, &env);
        assert!(rr.allclose(&fr, 1e-9, 1e-11), "diff {}", rr.max_abs_diff(&fr));
    }
}
