//! Reverse mode automatic differentiation in Einstein notation
//! (Section 3.2, Theorems 8–10).
//!
//! Each node `v` of the expression DAG receives a *pullback*
//! `v̄ = ∂y/∂v`, a tensor with index set `s4 ++ s_v` where `s4` is the
//! output's index set. The seed at the output is the unit tensor
//! (a scalar `1` when `y` is scalar — in which case the pullback rules
//! coincide exactly with what TF/PyTorch implement, as the paper notes
//! after Theorem 8).

use super::{fresh_block, relabel_from};
use crate::einsum::{EinSpec, Label};
use crate::ir::{Graph, NodeId, Op};
use std::collections::HashMap;

/// Reverse-mode derivative of `y` with respect to each variable in `xs`.
/// The derivative w.r.t. `x` has shape `shape(y) ++ shape(x)`
/// (Definition 4). One single sweep computes all of them — the property
/// that makes reverse mode the default in deep-learning frameworks.
pub fn reverse_derivative(g: &mut Graph, y: NodeId, xs: &[NodeId]) -> Vec<NodeId> {
    let s4_shape = g.shape(y).to_vec();
    let r4 = s4_shape.len();
    // Seed: ∂y/∂y — scalar 1 for scalar outputs, the unit tensor otherwise.
    let seed = if r4 == 0 { g.scalar(1.0) } else { g.delta(&s4_shape) };

    let order = g.topo(&[y]);
    // contributions to each node's pullback
    let mut contrib: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
    contrib.insert(y, vec![seed]);

    let total = |g: &mut Graph, parts: &[NodeId]| -> NodeId {
        let mut it = parts.iter();
        let first = *it.next().unwrap();
        it.fold(first, |acc, &p| g.add(acc, p))
    };

    for &id in order.iter().rev() {
        let parts = match contrib.get(&id) {
            Some(p) if !p.is_empty() => p.clone(),
            _ => continue, // node does not influence y
        };
        let vbar = total(g, &parts);
        contrib.insert(id, vec![vbar]);

        match g.op(id).clone() {
            Op::Add(a, b) => {
                // the contribution of an addition node to both arguments
                // is simply C̄
                contrib.entry(a).or_default().push(vbar);
                contrib.entry(b).or_default().push(vbar);
            }
            Op::Mul(a, b, spec) => {
                assert_distinct_operand_labels(&spec);
                let s4 = fresh_block(r4, 0);
                let sp = relabel_from(&spec, r4 as Label);
                let s4s3: Vec<Label> = s4.iter().chain(&sp.s3).copied().collect();
                // Theorem 8: contribution to Ā is C̄ *_(s4 s3, s2, s4 s1) B
                let to_a = {
                    let out: Vec<Label> = s4.iter().chain(&sp.s1).copied().collect();
                    pullback_term(g, vbar, b, &s4s3, &sp.s2, &out, &sp.s1, g.shape(a).to_vec())
                };
                contrib.entry(a).or_default().push(to_a);
                // and to B̄ it is C̄ *_(s4 s3, s1, s4 s2) A
                let to_b = {
                    let out: Vec<Label> = s4.iter().chain(&sp.s2).copied().collect();
                    pullback_term(g, vbar, a, &s4s3, &sp.s1, &out, &sp.s2, g.shape(b).to_vec())
                };
                contrib.entry(b).or_default().push(to_b);
            }
            Op::Elem(f, a) => {
                // Theorem 10: contribution is C̄ *_(s4 s1, s1, s4 s1) f'(A)
                let r1 = g.order(a);
                let s4 = fresh_block(r4, 0);
                let s1 = fresh_block(r1, r4 as Label);
                let fp = f.derivative(g, a);
                let s41: Vec<Label> = s4.iter().chain(&s1).copied().collect();
                let to_a = g.mul(vbar, fp, EinSpec::new(s41.clone(), s1, s41));
                contrib.entry(a).or_default().push(to_a);
            }
            Op::GenUnary(f, a) => {
                // Theorem 9: contribution is C̄ *_(s4 s2, s2 s1, s4 s1) f'(A)
                let r2 = g.order(id); // range
                let r1 = g.order(a); // domain
                let s4 = fresh_block(r4, 0);
                let s2 = fresh_block(r2, r4 as Label);
                let s1 = fresh_block(r1, (r4 + r2) as Label);
                let fp = f.derivative(g, a);
                let s42: Vec<Label> = s4.iter().chain(&s2).copied().collect();
                let s21: Vec<Label> = s2.iter().chain(&s1).copied().collect();
                let s41: Vec<Label> = s4.iter().chain(&s1).copied().collect();
                let to_a = g.mul(vbar, fp, EinSpec::new(s42, s21, s41));
                contrib.entry(a).or_default().push(to_a);
            }
            Op::Var(_) | Op::Const(_) | Op::Delta { .. } => {}
        }
    }

    xs.iter()
        .map(|&x| match contrib.get(&x) {
            Some(parts) if !parts.is_empty() => total(g, parts),
            _ => {
                // y does not depend on x: zero tensor of shape s4 ++ s_x
                let shape: Vec<usize> =
                    s4_shape.iter().chain(g.shape(x)).copied().collect();
                g.constant(0.0, &shape)
            }
        })
        .collect()
}

/// Gradient of a scalar-valued expression with respect to one variable.
pub fn reverse_gradient(g: &mut Graph, y: NodeId, x: NodeId) -> NodeId {
    assert!(g.shape(y).is_empty(), "reverse_gradient needs a scalar output");
    reverse_derivative(g, y, &[x])[0]
}

/// Build one Theorem-8 pullback contribution `C̄ *_(s4s3, other, out)
/// Other`, augmenting `Other` with a broadcast ones-tensor when `out`
/// contains labels present in neither input. That happens exactly when
/// the forward multiplication summed an axis the other operand does not
/// carry (e.g. `Σ_ij A[ij]·1`): the pullback then *broadcasts* back over
/// that axis.
#[allow(clippy::too_many_arguments)]
fn pullback_term(
    g: &mut Graph,
    vbar: NodeId,
    other: NodeId,
    s4s3: &[Label],
    other_labels: &[Label],
    out: &[Label],
    own_labels: &[Label],
    own_shape: Vec<usize>,
) -> NodeId {
    let mut missing: Vec<Label> = Vec::new();
    let mut missing_dims: Vec<usize> = Vec::new();
    for &l in out {
        if !s4s3.contains(&l) && !other_labels.contains(&l) && !missing.contains(&l) {
            let pos = own_labels.iter().position(|&x| x == l).expect("label origin");
            missing.push(l);
            missing_dims.push(own_shape[pos]);
        }
    }
    if missing.is_empty() {
        return g.mul(
            vbar,
            other,
            EinSpec::new(s4s3.to_vec(), other_labels.to_vec(), out.to_vec()),
        );
    }
    // outer-extend the other operand with ones over the missing axes
    let ones = g.constant(1.0, &missing_dims);
    let ext: Vec<Label> = other_labels.iter().chain(&missing).copied().collect();
    let aug = g.mul(
        other,
        ones,
        EinSpec::new(other_labels.to_vec(), missing.clone(), ext.clone()),
    );
    g.mul(vbar, aug, EinSpec::new(s4s3.to_vec(), ext, out.to_vec()))
}

fn assert_distinct_operand_labels(spec: &EinSpec) {
    for ls in [&spec.s1, &spec.s2] {
        for (i, l) in ls.iter().enumerate() {
            assert!(
                !ls[i + 1..].contains(l),
                "repeated operand label in {} — rewrite the diagonal with an \
                 explicit δ factor (see Graph::diag_of) to keep the node \
                 differentiable",
                spec
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval, fd_gradient, fd_jacobian, Env};
    use crate::ir::Elem;
    use crate::tensor::Tensor;

    fn env_of(pairs: &[(&str, Tensor)]) -> Env {
        let mut env = Env::new();
        for (n, t) in pairs {
            env.insert(n, t.clone());
        }
        env
    }

    #[test]
    fn gradient_of_quadratic_form() {
        // f = xᵀAx  ⇒  ∇f = (A + Aᵀ)x — the paper's motivating example
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let f = g.dot(x, ax);
        let grad = reverse_gradient(&mut g, f, x);
        let av = Tensor::randn(&[4, 4], 1);
        let xv = Tensor::randn(&[4], 2);
        let env = env_of(&[("A", av.clone()), ("x", xv.clone())]);
        let gv = eval(&g, grad, &env);
        let want = fd_gradient(&g, f, "x", &env, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn gradient_wrt_matrix() {
        // f = xᵀAx ⇒ ∂f/∂A = x xᵀ
        let mut g = Graph::new();
        let a = g.var("A", &[3, 3]);
        let x = g.var("x", &[3]);
        let ax = g.matvec(a, x);
        let f = g.dot(x, ax);
        let grad = reverse_gradient(&mut g, f, a);
        assert_eq!(g.shape(grad), &[3, 3]);
        let env = env_of(&[("A", Tensor::randn(&[3, 3], 3)), ("x", Tensor::randn(&[3], 4))]);
        let gv = eval(&g, grad, &env);
        let want = fd_gradient(&g, f, "A", &env, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7));
    }

    #[test]
    fn gradient_through_elementwise_chain() {
        // f = Σ log(exp(Xw) + 1)
        let mut g = Graph::new();
        let x = g.var("X", &[5, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[5]);
        let s = g.add(e, one);
        let l = g.elem(Elem::Log, s);
        let f = g.sum_all(l);
        let grad = reverse_gradient(&mut g, f, w);
        let env = env_of(&[("X", Tensor::randn(&[5, 3], 5)), ("w", Tensor::randn(&[3], 6))]);
        let gv = eval(&g, grad, &env);
        let want = fd_gradient(&g, f, "w", &env, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn jacobian_of_vector_valued_function() {
        // y = exp(Ax) (vector) ⇒ J ∈ R^{3×4}, non-scalar seed (δ tensor)
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let y = g.elem(Elem::Exp, ax);
        let jac = reverse_derivative(&mut g, y, &[x])[0];
        assert_eq!(g.shape(jac), &[3, 4]);
        let env = env_of(&[("A", Tensor::randn(&[3, 4], 7)), ("x", Tensor::randn(&[4], 8))]);
        let jv = eval(&g, jac, &env);
        let want = fd_jacobian(&g, y, "x", &env, 1e-6);
        assert!(jv.allclose(&want, 1e-5, 1e-7), "diff {}", jv.max_abs_diff(&want));
    }

    #[test]
    fn jacobian_wrt_matrix_of_matrix_output() {
        // Y = A B ⇒ ∂Y/∂B ∈ R^{2×4×3×4}
        let mut g = Graph::new();
        let a = g.var("A", &[2, 3]);
        let b = g.var("B", &[3, 4]);
        let y = g.matmul(a, b);
        let jac = reverse_derivative(&mut g, y, &[b])[0];
        assert_eq!(g.shape(jac), &[2, 4, 3, 4]);
        let env = env_of(&[("A", Tensor::randn(&[2, 3], 9)), ("B", Tensor::randn(&[3, 4], 10))]);
        let jv = eval(&g, jac, &env);
        let want = fd_jacobian(&g, y, "B", &env, 1e-6);
        assert!(jv.allclose(&want, 1e-4, 1e-6), "diff {}", jv.max_abs_diff(&want));
    }

    #[test]
    fn derivative_wrt_independent_variable_is_zero() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let z = g.var("z", &[2]);
        let f = g.norm2(x);
        let dz = reverse_derivative(&mut g, f, &[z])[0];
        assert_eq!(g.shape(dz), &[2]);
        let env = env_of(&[("x", Tensor::randn(&[3], 1)), ("z", Tensor::randn(&[2], 2))]);
        assert_eq!(eval(&g, dz, &env), Tensor::zeros(&[2]));
    }

    #[test]
    fn multiple_variables_single_sweep() {
        // f = uᵀ v: one reverse sweep yields both gradients
        let mut g = Graph::new();
        let u = g.var("u", &[4]);
        let v = g.var("v", &[4]);
        let f = g.dot(u, v);
        let grads = reverse_derivative(&mut g, f, &[u, v]);
        let uv = Tensor::randn(&[4], 1);
        let vv = Tensor::randn(&[4], 2);
        let env = env_of(&[("u", uv.clone()), ("v", vv.clone())]);
        assert!(eval(&g, grads[0], &env).allclose(&vv, 1e-12, 1e-12));
        assert!(eval(&g, grads[1], &env).allclose(&uv, 1e-12, 1e-12));
    }

    #[test]
    fn shared_subexpression_accumulates() {
        // f = Σ (x ⊙ x): pullback must accumulate both uses of x
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let h = g.hadamard(x, x);
        let f = g.sum_all(h);
        let grad = reverse_gradient(&mut g, f, x);
        let xv = Tensor::new(&[3], vec![1.0, 2.0, 3.0]);
        let env = env_of(&[("x", xv.clone())]);
        let gv = eval(&g, grad, &env);
        assert!(gv.allclose(&xv.scale(2.0), 1e-12, 1e-12), "{:?}", gv);
    }

    #[test]
    fn gradient_through_general_unary_softmax() {
        // f = Σ (softmax(x) ⊙ c) — Theorem 9 path
        let mut g = Graph::new();
        let x = g.var("x", &[4]);
        let c = g.var("c", &[4]);
        let s = g.gen_unary(crate::ir::GenFn::Softmax, x);
        let p = g.hadamard(s, c);
        let f = g.sum_all(p);
        let grad = reverse_gradient(&mut g, f, x);
        let env = env_of(&[("x", Tensor::randn(&[4], 3)), ("c", Tensor::randn(&[4], 4))]);
        let gv = eval(&g, grad, &env);
        let want = fd_gradient(&g, f, "x", &env, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn gradient_through_batched_softmax() {
        // batched softmax exercises the δ-over-batch structure of f'
        let mut g = Graph::new();
        let x = g.var("X", &[3, 4]);
        let c = g.var("C", &[3, 4]);
        let s = g.gen_unary(crate::ir::GenFn::Softmax, x);
        let p = g.hadamard(s, c);
        let f = g.sum_all(p);
        let grad = reverse_gradient(&mut g, f, x);
        let env = env_of(&[("X", Tensor::randn(&[3, 4], 5)), ("C", Tensor::randn(&[3, 4], 6))]);
        let gv = eval(&g, grad, &env);
        let want = fd_gradient(&g, f, "X", &env, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn gradient_through_logsumexp() {
        let mut g = Graph::new();
        let x = g.var("X", &[3, 4]);
        let l = g.gen_unary(crate::ir::GenFn::LogSumExp, x);
        let f = g.sum_all(l);
        let grad = reverse_gradient(&mut g, f, x);
        let env = env_of(&[("X", Tensor::randn(&[3, 4], 7))]);
        let gv = eval(&g, grad, &env);
        let want = fd_gradient(&g, f, "X", &env, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn relu_subgradient_matches_where_differentiable() {
        let mut g = Graph::new();
        let x = g.var("x", &[4]);
        let r = g.elem(Elem::Relu, x);
        let f = g.sum_all(r);
        let grad = reverse_gradient(&mut g, f, x);
        let xv = Tensor::new(&[4], vec![-2.0, -0.5, 0.5, 2.0]);
        let env = env_of(&[("x", xv)]);
        let gv = eval(&g, grad, &env);
        assert_eq!(gv.data(), &[0.0, 0.0, 1.0, 1.0]);
    }
}
