//! Regeneration of the paper's evaluation artifacts (Figures 2 and 3,
//! plus the §3.3 Newton-system comparison): shared by the bench harnesses
//! (`cargo bench`) and the CLI (`tensorcalc bench …`).
//!
//! Modes per the paper:
//! * `framework(per-entry)` — the TF/PyTorch/autograd/JAX strategy: one
//!   reverse sweep per gradient entry ([`crate::baselines`]).
//! * `ours(reverse)` — Theorem-8 reverse mode on the whole tensor
//!   expression (equivalent to Laue et al. [6]).
//! * `ours(cross-country)` — plus the §3.3 re-association.
//! * `ours(compressed)` — plus unit-tensor compression (evaluates only
//!   the core).
//! * `jax(pjrt)` — the real JAX, AOT-lowered and executed via PJRT from
//!   Rust (fixed AOT shapes only).

use crate::baselines::PerEntryHessian;
use crate::exec::CompiledPlan;
use crate::ir::{Graph, NodeId};
use crate::opt::{self, OptLevel};
use crate::problems::{
    logistic_regression, matrix_factorization, neural_net, newton_step_compressed,
    newton_step_full, Workload,
};
use crate::tensor::Tensor;
use crate::util::{fmt_secs, time_median};

/// Compile roots through the graph optimizer and report what it did.
/// fig2 uses the production default ([`OptLevel::Full`]); the fig3 mode
/// rows use [`OptLevel::Cse`] — CSE is association-preserving, so the
/// reverse vs cross-country comparison the figure exists to report
/// still measures the §3.3 reordering, not the optimizer's own
/// reassociation pass.
fn compile_opt(g: &Graph, roots: &[NodeId], level: OptLevel) -> (CompiledPlan, opt::OptStats) {
    let mut g2 = g.clone();
    let o = opt::optimize(&mut g2, roots, level);
    // default executor options (planned arena, in-tile epilogues); the
    // `memory` dimension of `benches/ablation_modes.rs` is where the
    // ExecMemory ablation is actually measured
    (CompiledPlan::new(&g2, &o.roots), o.stats)
}

/// One measurement row.
#[derive(Clone, Debug)]
pub struct Row {
    pub figure: &'static str,
    pub problem: &'static str,
    pub n: usize,
    pub mode: String,
    pub secs: f64,
    pub runs: usize,
}

/// Render rows as the paper-style series table.
pub fn print_table(title: &str, rows: &[Row]) {
    println!("\n== {} ==", title);
    println!("{:<12} {:>6}  {:<24} {:>12} {:>6}", "problem", "n", "mode", "median", "runs");
    for r in rows {
        println!(
            "{:<12} {:>6}  {:<24} {:>12} {:>6}",
            r.problem,
            r.n,
            r.mode,
            fmt_secs(r.secs),
            r.runs
        );
    }
}

fn workloads_for(problem: &'static str, n: usize) -> Workload {
    match problem {
        "logreg" => logistic_regression(2 * n, n),
        "matfac" => matrix_factorization(n, n, 5, false),
        "mlp" => neural_net(n, 10, 2 * n),
        _ => panic!("unknown problem {}", problem),
    }
}

/// Figure 2: function value + gradient evaluation times. All frameworks
/// coincide on gradients (scalar-output reverse mode); we report the
/// engine and, where an AOT artifact matches, the PJRT/JAX path.
pub fn fig2(problems: &[&'static str], sizes: &[usize], min_secs: f64) -> Vec<Row> {
    let mut rows = Vec::new();
    for &p in problems {
        for &n in sizes {
            let mut w = workloads_for(p, n);
            let grad = w.gradient();
            let (plan, _) = compile_opt(&w.g, &[w.loss, grad], OptLevel::Full);
            let env = w.env.clone();
            let (secs, runs) = time_median(
                || {
                    let out = plan.run(&env);
                    std::hint::black_box(out);
                },
                5,
                min_secs,
            );
            rows.push(Row {
                figure: "fig2",
                problem: p,
                n,
                mode: "ours(reverse)".into(),
                secs,
                runs,
            });
        }
    }
    rows.extend(fig2_pjrt(min_secs));
    rows
}

/// The PJRT/JAX gradient path at the fixed AOT shapes.
fn fig2_pjrt(min_secs: f64) -> Vec<Row> {
    let mut rows = Vec::new();
    let Some(dir) = crate::runtime::artifacts_dir() else {
        return rows;
    };
    let Ok(mut rt) = crate::runtime::Runtime::open(&dir) else {
        return rows;
    };
    // logreg_val_grad at n=128, m=256
    let x = Tensor::randn(&[256, 128], 1);
    let y = Tensor::randn(&[256], 2).map(f64::signum);
    let w = Tensor::randn(&[128], 3).scale(0.1);
    if rt.artifact("logreg_val_grad").is_ok() {
        let (secs, runs) = time_median(
            || {
                let out = rt.execute("logreg_val_grad", &[w.clone(), x.clone(), y.clone()]);
                std::hint::black_box(out.unwrap());
            },
            5,
            min_secs,
        );
        rows.push(Row {
            figure: "fig2",
            problem: "logreg",
            n: 128,
            mode: "jax(pjrt,aot)".into(),
            secs,
            runs,
        });
    }
    rows
}

/// Figure 3 (CPU row): Hessian evaluation times per mode.
/// `with_baseline` controls whether the (slow) per-entry framework
/// emulation runs at every size.
pub fn fig3(
    problems: &[&'static str],
    sizes: &[usize],
    min_secs: f64,
    with_baseline: bool,
) -> Vec<Row> {
    let mut rows = Vec::new();
    for &p in problems {
        for &n in sizes {
            // The MLP Hessian materialises order-4 intermediates of
            // ~batch·n⁴ doubles; above width ~32 that exceeds the
            // testbed's memory (the paper saw the same wall: JAX "did
            // not finish computations but raised memory errors").
            if p == "mlp" && n > 32 {
                continue;
            }
            // ours (reverse)
            {
                let mut w = workloads_for(p, n);
                let h = w.hessian();
                let (plan, stats) = compile_opt(&w.g, &[h], OptLevel::Cse);
                println!("  [opt] fig3 {:<8} n={:<5} ours(reverse): {}", p, n, stats);
                println!("  [mem] fig3 {:<8} n={:<5} {}", p, n, plan.pool_stats());
                let (secs, runs) = time_median(
                    || {
                        std::hint::black_box(plan.run(&w.env));
                    },
                    3,
                    min_secs,
                );
                rows.push(Row { figure: "fig3", problem: p, n, mode: "ours(reverse)".into(), secs, runs });
            }
            // ours (cross-country)
            {
                let mut w = workloads_for(p, n);
                let h = w.hessian_cross_country();
                let (plan, _) = compile_opt(&w.g, &[h], OptLevel::Cse);
                let (secs, runs) = time_median(
                    || {
                        std::hint::black_box(plan.run(&w.env));
                    },
                    3,
                    min_secs,
                );
                rows.push(Row {
                    figure: "fig3",
                    problem: p,
                    n,
                    mode: "ours(cross-country)".into(),
                    secs,
                    runs,
                });
            }
            // ours (compressed) — evaluates only the core
            {
                let mut w = workloads_for(p, n);
                let comp = w.hessian_compressed();
                let mode = if comp.is_compressed() {
                    format!("ours(compressed,{:.0e})", comp.compression_ratio(&w.g))
                } else {
                    "ours(compressed=n/a)".into()
                };
                let node = comp.eval_node();
                let (plan, _) = compile_opt(&w.g, &[node], OptLevel::Cse);
                let (secs, runs) = time_median(
                    || {
                        std::hint::black_box(plan.run(&w.env));
                    },
                    3,
                    min_secs,
                );
                rows.push(Row { figure: "fig3", problem: p, n, mode, secs, runs });
            }
            // framework baseline: per-entry reverse sweeps. Above ~2k
            // sweeps a single cell takes minutes on this testbed — the
            // gap is already unambiguous, so larger cells are skipped
            // (exactly like the paper's frameworks time out / OOM at the
            // top of its sweeps).
            let sweeps: usize = {
                let w = workloads_for(p, n);
                let g = &w.g;
                g.shape(w.wrt).iter().product()
            };
            if with_baseline && sweeps <= 2048 {
                let mut w = workloads_for(p, n);
                let pe = PerEntryHessian::new(&mut w.g, w.loss, w.wrt);
                let (secs, runs) = time_median(
                    || {
                        std::hint::black_box(pe.eval(&w.g, &w.env));
                    },
                    2,
                    min_secs,
                );
                rows.push(Row {
                    figure: "fig3",
                    problem: p,
                    n,
                    mode: format!("framework(per-entry×{})", pe.sweeps()),
                    secs,
                    runs,
                });
            }
        }
    }
    rows.extend(fig3_pjrt(min_secs));
    rows
}

/// Hessians via PJRT at the fixed AOT shapes: our compressed formula and
/// the real `jax.hessian` comparator.
fn fig3_pjrt(min_secs: f64) -> Vec<Row> {
    let mut rows = Vec::new();
    let Some(dir) = crate::runtime::artifacts_dir() else {
        return rows;
    };
    let Ok(mut rt) = crate::runtime::Runtime::open(&dir) else {
        return rows;
    };
    let x = Tensor::randn(&[256, 128], 1);
    let y = Tensor::randn(&[256], 2).map(f64::signum);
    let w = Tensor::randn(&[128], 3).scale(0.1);
    for (name, mode) in [
        ("logreg_hess", "ours(pallas,pjrt,aot)"),
        ("logreg_hess_jax", "jax.hessian(pjrt,aot)"),
    ] {
        if rt.artifact(name).is_ok() {
            let (secs, runs) = time_median(
                || {
                    let out = rt.execute(name, &[w.clone(), x.clone(), y.clone()]);
                    std::hint::black_box(out.unwrap());
                },
                3,
                min_secs,
            );
            rows.push(Row {
                figure: "fig3",
                problem: "logreg",
                n: 128,
                mode: mode.into(),
                secs,
                runs,
            });
        }
    }
    rows
}

/// §3.3 Newton-system comparison: solve `H·D = G` with the compressed
/// k×k core vs the materialised (nk)×(nk) system.
pub fn newton(sizes: &[usize], k: usize, min_secs: f64) -> Vec<Row> {
    let mut rows = Vec::new();
    for &n in sizes {
        let mut w = matrix_factorization(n, n, k, false);
        let comp = w.hessian_compressed();
        assert!(comp.is_compressed(), "matfac must compress");
        let core_node = comp.eval_node();
        let grad_node = w.gradient();
        let vals = crate::eval::eval_many(&w.g, &[core_node, grad_node], &w.env);
        let (core, grad) = (vals[0].clone(), vals[1].clone());

        let (secs, runs) = time_median(
            || {
                std::hint::black_box(newton_step_compressed(&core, &grad).unwrap());
            },
            3,
            min_secs,
        );
        rows.push(Row {
            figure: "newton",
            problem: "matfac",
            n,
            mode: format!("compressed O(k³+nk²), k={}", k),
            secs,
            runs,
        });

        let h = comp.materialize(&core);
        let (secs, runs) = time_median(
            || {
                std::hint::black_box(newton_step_full(&h, &grad).unwrap());
            },
            1,
            min_secs.min(0.5),
        );
        rows.push(Row {
            figure: "newton",
            problem: "matfac",
            n,
            mode: "full O((nk)³)".into(),
            secs,
            runs,
        });
    }
    rows
}

/// Serialize measurement rows as the perf-trajectory JSON that
/// `scripts/bench_baseline.sh` records into `BENCH_exec.json` at the
/// repository root. Hand-rolled — the crate is dependency-free.
pub fn rows_to_json(rows: &[Row]) -> String {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let mut out = String::from("{\n  \"schema\": \"tensorcalc-bench-rows/v1\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"figure\": \"{}\", \"problem\": \"{}\", \"n\": {}, \"mode\": \"{}\", \"median_secs\": {:e}, \"runs\": {}}}{}\n",
            esc(r.figure),
            esc(r.problem),
            r.n,
            esc(&r.mode),
            r.secs,
            r.runs,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write `rows` to the file named by the `BENCH_JSON` environment
/// variable (the hook `scripts/bench_baseline.sh` uses); silent no-op
/// when the variable is unset or empty.
pub fn maybe_write_bench_json(rows: &[Row]) {
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) if !p.is_empty() => p,
        _ => return,
    };
    match std::fs::write(&path, rows_to_json(rows)) {
        Ok(()) => println!("\nwrote {} bench rows to {}", rows.len(), path),
        Err(e) => eprintln!("BENCH_JSON: failed to write {}: {}", path, e),
    }
}

/// Speedup summary used by EXPERIMENTS.md: for each (problem, n) compare
/// a mode's median against a reference mode.
pub fn speedup(rows: &[Row], reference: &str, mode: &str) -> Vec<(String, usize, f64)> {
    let mut out = Vec::new();
    for r in rows.iter().filter(|r| r.mode.starts_with(mode)) {
        if let Some(base) = rows
            .iter()
            .find(|b| b.problem == r.problem && b.n == r.n && b.mode.starts_with(reference))
        {
            out.push((r.problem.to_string(), r.n, base.secs / r.secs));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_produces_rows_for_all_problems() {
        let rows = fig2(&["logreg", "matfac"], &[8], 0.0);
        assert!(rows.iter().any(|r| r.problem == "logreg"));
        assert!(rows.iter().any(|r| r.problem == "matfac"));
        assert!(rows.iter().all(|r| r.secs > 0.0));
    }

    #[test]
    fn fig3_modes_present() {
        let rows = fig3(&["logreg"], &[6], 0.0, true);
        let modes: Vec<&str> = rows.iter().map(|r| r.mode.as_str()).collect();
        assert!(modes.iter().any(|m| m.starts_with("ours(reverse)")), "{:?}", modes);
        assert!(modes.iter().any(|m| m.starts_with("ours(cross-country)")));
        assert!(modes.iter().any(|m| m.starts_with("framework(per-entry")));
    }

    #[test]
    fn newton_compressed_beats_full() {
        let rows = newton(&[24], 3, 0.0);
        let fast = rows.iter().find(|r| r.mode.starts_with("compressed")).unwrap();
        let slow = rows.iter().find(|r| r.mode.starts_with("full")).unwrap();
        assert!(
            fast.secs < slow.secs,
            "compressed {} should beat full {}",
            fast.secs,
            slow.secs
        );
    }

    #[test]
    fn bench_json_is_well_formed() {
        let rows = vec![
            Row { figure: "f", problem: "p", n: 4, mode: "a \"q\"".into(), secs: 5e-4, runs: 7 },
            Row { figure: "f", problem: "p", n: 8, mode: "b".into(), secs: 1e-3, runs: 3 },
        ];
        let j = rows_to_json(&rows);
        assert!(j.contains("\"schema\": \"tensorcalc-bench-rows/v1\""));
        assert!(j.contains("\\\"q\\\""), "quotes must be escaped: {}", j);
        assert!(j.contains("e-4"), "secs must serialize in exponent form: {}", j);
        assert!(j.trim_start().starts_with('{') && j.trim_end().ends_with('}'));
        // exactly one separator comma between the two row objects
        assert_eq!(j.matches("},").count(), 1);
    }

    #[test]
    fn speedup_helper() {
        let rows = vec![
            Row { figure: "f", problem: "p", n: 4, mode: "a".into(), secs: 2.0, runs: 1 },
            Row { figure: "f", problem: "p", n: 4, mode: "b".into(), secs: 1.0, runs: 1 },
        ];
        let s = speedup(&rows, "a", "b");
        assert_eq!(s.len(), 1);
        assert!((s[0].2 - 2.0).abs() < 1e-12);
    }
}
