//! Element-wise tensor operations and permutations.

use super::{row_major_strides, Tensor};

impl Tensor {
    /// Element-wise binary map. Shapes must match exactly.
    pub fn zip_map(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        assert_eq!(self.shape(), other.shape(), "zip_map shape mismatch");
        let data = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| f(a, b))
            .collect();
        Tensor::new(self.shape(), data)
    }

    /// Element-wise unary map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Tensor {
        Tensor::new(self.shape(), self.data().iter().map(|&a| f(a)).collect())
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip_map(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip_map(other, |a, b| a - b)
    }

    pub fn mul_elem(&self, other: &Tensor) -> Tensor {
        self.zip_map(other, |a, b| a * b)
    }

    pub fn scale(&self, c: f64) -> Tensor {
        self.map(|a| a * c)
    }

    /// In-place `self += other`. Shapes must match.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape(), other.shape(), "add_assign shape mismatch");
        for (a, b) in self.data_mut().iter_mut().zip(other.data()) {
            *a += b;
        }
    }

    /// Axis permutation (generalized transpose). `perm[k]` gives the input
    /// axis that becomes output axis `k`.
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.order(), "permute rank mismatch");
        let in_shape = self.shape();
        let in_strides = row_major_strides(in_shape);
        let out_shape: Vec<usize> = perm.iter().map(|&p| in_shape[p]).collect();
        // stride (in the input buffer) per output axis
        let strides: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
        let n: usize = out_shape.iter().product();
        let mut out = vec![0.0; n];
        let rank = out_shape.len();
        if rank == 0 {
            return Tensor::scalar(self.item());
        }
        // odometer over the output shape
        let mut idx = vec![0usize; rank];
        let mut src = 0usize;
        for slot in out.iter_mut() {
            *slot = self.data()[src];
            // increment
            for ax in (0..rank).rev() {
                idx[ax] += 1;
                src += strides[ax];
                if idx[ax] < out_shape[ax] {
                    break;
                }
                src -= strides[ax] * out_shape[ax];
                idx[ax] = 0;
            }
        }
        Tensor::new(&out_shape, out)
    }

    /// Matrix transpose (order-2 only).
    pub fn t(&self) -> Tensor {
        assert_eq!(self.order(), 2, "t() on non-matrix");
        self.permute(&[1, 0])
    }

    /// Sum of all elements.
    pub fn sum_all(&self) -> f64 {
        self.data().iter().sum()
    }

    /// Dot product of two equally-shaped tensors viewed as flat vectors.
    pub fn flat_dot(&self, other: &Tensor) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data().iter().zip(other.data()).map(|(a, b)| a * b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permute_matrix_transpose() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = a.t();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.data(), &[1., 4., 2., 5., 3., 6.]);
        // double transpose is identity
        assert_eq!(t.t(), a);
    }

    #[test]
    fn permute_order3() {
        let a = Tensor::randn(&[2, 3, 4], 3);
        let p = a.permute(&[2, 0, 1]);
        assert_eq!(p.shape(), &[4, 2, 3]);
        for i in 0..2 {
            for j in 0..3 {
                for k in 0..4 {
                    assert_eq!(p.at(&[k, i, j]), a.at(&[i, j, k]));
                }
            }
        }
    }

    #[test]
    fn permute_identity() {
        let a = Tensor::randn(&[3, 5], 9);
        assert_eq!(a.permute(&[0, 1]), a);
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(&[3], vec![1., 2., 3.]);
        let b = Tensor::new(&[3], vec![4., 5., 6.]);
        assert_eq!(a.add(&b).data(), &[5., 7., 9.]);
        assert_eq!(a.sub(&b).data(), &[-3., -3., -3.]);
        assert_eq!(a.mul_elem(&b).data(), &[4., 10., 18.]);
        assert_eq!(a.scale(2.0).data(), &[2., 4., 6.]);
        assert_eq!(a.flat_dot(&b), 32.0);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut a = Tensor::zeros(&[2, 2]);
        a.add_assign(&Tensor::ones(&[2, 2]));
        a.add_assign(&Tensor::ones(&[2, 2]));
        assert_eq!(a, Tensor::fill(&[2, 2], 2.0));
    }
}
