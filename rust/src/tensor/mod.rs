//! Dense row-major tensors over `f64`.
//!
//! This is the storage substrate of the evaluation engine — the role NumPy
//! plays in the paper's experiments. Tensors are immutable-ish contiguous
//! buffers with shape metadata; all contraction logic lives in
//! [`crate::einsum`].

mod ops;

use std::fmt;

/// A dense, row-major (C-order), contiguous tensor of `f64` values.
///
/// An order-0 tensor (shape `[]`) is a scalar with one element.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f64>,
}

impl Tensor {
    /// Build a tensor from a flat row-major buffer. Panics if the buffer
    /// length does not match the shape product.
    pub fn new(shape: &[usize], data: Vec<f64>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(
            n,
            data.len(),
            "shape {:?} wants {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// A scalar (order-0) tensor.
    pub fn scalar(v: f64) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Constant-filled tensor.
    pub fn fill(shape: &[usize], v: f64) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// All zeros.
    pub fn zeros(shape: &[usize]) -> Self {
        Self::fill(shape, 0.0)
    }

    /// All ones.
    pub fn ones(shape: &[usize]) -> Self {
        Self::fill(shape, 1.0)
    }

    /// The `n × n` identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// The order-`2k` unit (delta) tensor with index structure
    /// `[d_0..d_{k-1}, d_0..d_{k-1}]`: entry 1 iff the m-th front index
    /// equals the m-th back index for all m. This is the tensor `𝕀` the
    /// paper's compression scheme eliminates.
    pub fn delta(dims: &[usize]) -> Self {
        let mut shape = dims.to_vec();
        shape.extend_from_slice(dims);
        let mut t = Self::zeros(&shape);
        let block: usize = dims.iter().product();
        // flat index of (i, i) = i * block + i
        for i in 0..block {
            t.data[i * block + i] = 1.0;
        }
        t
    }

    /// Deterministic pseudo-random standard-normal tensor (xorshift +
    /// Box–Muller); seeded so tests and benches are reproducible without
    /// an external RNG dependency.
    pub fn randn(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = XorShift::new(seed);
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            let (a, b) = rng.normal_pair();
            data.push(a);
            if data.len() < n {
                data.push(b);
            }
        }
        Tensor { shape: shape.to_vec(), data }
    }

    /// Uniform in `[lo, hi)`.
    pub fn rand_uniform(shape: &[usize], seed: u64, lo: f64, hi: f64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = XorShift::new(seed);
        let data = (0..n).map(|_| lo + (hi - lo) * rng.next_f64()).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Tensor order (number of axes).
    pub fn order(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Row-major strides of this tensor's shape.
    pub fn strides(&self) -> Vec<usize> {
        row_major_strides(&self.shape)
    }

    /// Value of a scalar tensor. Panics if more than one element.
    pub fn item(&self) -> f64 {
        assert_eq!(self.data.len(), 1, "item() on tensor of shape {:?}", self.shape);
        self.data[0]
    }

    /// Element access by multi-index.
    pub fn at(&self, idx: &[usize]) -> f64 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut flat = 0;
        for (i, (&ix, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < d, "index {} out of bounds at axis {} (dim {})", ix, i, d);
            flat = flat * d + ix;
        }
        self.data[flat]
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Frobenius / Euclidean norm (`‖A‖ = sqrt(Σ A[s]²)`, Definition 4).
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Largest absolute element-wise difference to `other`.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape, "shape mismatch in max_abs_diff");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// True if all elements match `other` within `atol + rtol·|other|`.
    pub fn allclose(&self, other: &Tensor, rtol: f64, atol: f64) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{:.4}, {:.4}, …, {:.4}]", self.data[0], self.data[1], self.data[self.data.len() - 1])
        }
    }
}

/// Row-major strides for a shape.
pub fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![0usize; shape.len()];
    let mut acc = 1usize;
    for i in (0..shape.len()).rev() {
        strides[i] = acc;
        acc *= shape[i];
    }
    strides
}

/// Minimal xorshift64* PRNG — keeps the crate dependency-free for
/// reproducible test/bench data.
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        XorShift { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Two independent standard normals (Box–Muller).
    pub fn normal_pair(&mut self) -> (f64, f64) {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        (r * th.cos(), r * th.sin())
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.item(), 3.5);
        assert_eq!(t.order(), 0);
    }

    #[test]
    fn fill_and_at() {
        let t = Tensor::new(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.strides(), vec![3, 1]);
    }

    #[test]
    fn eye_is_delta_of_one_dim() {
        assert_eq!(Tensor::eye(4), Tensor::delta(&[4]));
    }

    #[test]
    fn delta_order4() {
        // δ[i,j,k,l] = [i==k][j==l]
        let d = Tensor::delta(&[2, 3]);
        assert_eq!(d.shape(), &[2, 3, 2, 3]);
        for i in 0..2 {
            for j in 0..3 {
                for k in 0..2 {
                    for l in 0..3 {
                        let want = if i == k && j == l { 1.0 } else { 0.0 };
                        assert_eq!(d.at(&[i, j, k, l]), want);
                    }
                }
            }
        }
    }

    #[test]
    fn randn_reproducible_and_normalish() {
        let a = Tensor::randn(&[1000], 7);
        let b = Tensor::randn(&[1000], 7);
        assert_eq!(a, b);
        let mean = a.data().iter().sum::<f64>() / 1000.0;
        let var = a.data().iter().map(|v| (v - mean).powi(2)).sum::<f64>() / 1000.0;
        assert!(mean.abs() < 0.15, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.3, "var {}", var);
    }

    #[test]
    fn norm_matches_frobenius() {
        let t = Tensor::new(&[2, 2], vec![3., 4., 0., 0.]);
        assert!((t.norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]);
        let b = Tensor::new(&[2], vec![1.0 + 1e-9, 2.0 - 1e-9]);
        assert!(a.allclose(&b, 1e-6, 1e-8));
        let c = Tensor::new(&[2], vec![1.1, 2.0]);
        assert!(!a.allclose(&c, 1e-6, 1e-8));
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0]);
    }
}
