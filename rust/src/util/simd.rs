//! Runtime SIMD dispatch and the GEMM blocking autotuner.
//!
//! # Dispatch
//!
//! The GEMM register microkernel and the fused element-wise pipelines
//! come in one scalar and up to three explicit-SIMD flavours (AVX-512,
//! AVX2+FMA, NEON — `core::arch` f64 intrinsics). Which flavour runs is
//! decided **once per process** ([`active_isa`]): CPU feature detection
//! picks the widest supported tier, the `TC_SIMD` environment variable
//! (`off`/`scalar`/`avx2`/`avx512`/`neon`) pins it, and tests/benches
//! can flip it at runtime with [`set_isa`]. The decision is cached in an
//! atomic; per-call dispatch cost is one relaxed load plus a
//! function-pointer table lookup ([`kernel_for`]).
//!
//! # Bit-identity
//!
//! Every microkernel — scalar and SIMD alike — computes each output
//! element as the *same* IEEE-754 operation chain: the `MR×NR` register
//! tile accumulates `acc[r][j] += a[r] · b[j]` as a separate multiply
//! then add (**no FMA contraction**), in the same k order, with one add
//! into `C` per k-block. The SIMD kernels vectorize across the `NR`
//! column dimension, so each C element still owns an independent
//! per-lane accumulation chain; lane-wise `vmul`/`vadd` round exactly
//! like their scalar counterparts. Forced-scalar and every dispatched
//! ISA therefore produce **bit-identical** results under the same
//! [`Blocking`] — the repo's oracle contract survives the rewrite, and
//! `tests/simd_equivalence.rs` pins it.
//!
//! # Blocking autotuner
//!
//! The tile/cache-blocking geometry ([`Blocking`]) is no longer a set of
//! hard-coded constants: [`blocking`] resolves it once per process from
//! `TC_GEMM_BLOCKING="MR,NR,MC,KC,NC"` (validated loudly — divisibility
//! and supported-tile violations panic) or, absent the override, from a
//! small at-startup autotuner that times each [`TUNE_CANDIDATES`] entry
//! on a fixed probe GEMM and caches the winner ([`tune_count`] exposes
//! how many times tuning actually ran — once, however many plans warm
//! up afterwards). All candidates share the same `KC`, and `MR`/`NR`/
//! `MC`/`NC` never affect per-element accumulation order, so the
//! autotuner's pick changes speed but **never numerics**; only an
//! explicit `TC_GEMM_BLOCKING` with a different `KC` re-rounds.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

use super::{GEMM_KC, GEMM_MC, GEMM_MR, GEMM_NC, GEMM_NR};

/// An instruction-set tier of the dispatched kernels. `Scalar` is always
/// available and is the bit-identity reference; the SIMD tiers are only
/// activatable when [`Isa::supported`] confirms the CPU has them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernels (the reference path, `TC_SIMD=off`).
    Scalar,
    /// x86-64 AVX2 (+FMA presence checked, though the kernels use
    /// separate mul/add for bit-identity), 4 f64 lanes.
    Avx2,
    /// x86-64 AVX-512F, 8 f64 lanes.
    Avx512,
    /// AArch64 NEON (baseline on that architecture), 2 f64 lanes.
    Neon,
}

impl Isa {
    /// The name used by `TC_SIMD`, the CLI `--simd` flag and the bench
    /// mode labels.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Parse a `TC_SIMD` / `--simd` value (`off` is an alias for
    /// `scalar`, matching the ablation convention of the other
    /// subsystem switches).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Whether this tier can run on the current CPU.
    pub fn supported(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx512 => is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 | Isa::Avx512 => false,
            #[cfg(not(target_arch = "aarch64"))]
            Isa::Neon => false,
        }
    }

    fn code(self) -> u8 {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 2,
            Isa::Avx512 => 3,
            Isa::Neon => 4,
        }
    }

    fn from_code(c: u8) -> Isa {
        match c {
            1 => Isa::Scalar,
            2 => Isa::Avx2,
            3 => Isa::Avx512,
            4 => Isa::Neon,
            _ => unreachable!("bad ISA code {c}"),
        }
    }
}

/// Every ISA this build could dispatch to on the current CPU, scalar
/// first — the iteration axis of the differential test wall.
pub fn supported_isas() -> Vec<Isa> {
    [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon]
        .into_iter()
        .filter(|isa| isa.supported())
        .collect()
}

/// The widest SIMD tier the current CPU supports.
pub fn detect_isa() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx512f") {
            Isa::Avx512
        } else if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            Isa::Avx2
        } else {
            Isa::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Isa::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Isa::Scalar
    }
}

/// `u8::MAX` = not yet initialized; otherwise an [`Isa::code`].
static ACTIVE_ISA: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_isa_from_env() -> Isa {
    match std::env::var("TC_SIMD") {
        Ok(s) => {
            let isa = Isa::parse(&s).unwrap_or_else(|| {
                panic!("invalid TC_SIMD value {s:?}: expected off|scalar|avx2|avx512|neon")
            });
            assert!(
                isa.supported(),
                "TC_SIMD={s} requests ISA `{}`, which this CPU does not support",
                isa.name()
            );
            isa
        }
        Err(_) => detect_isa(),
    }
}

/// The ISA every dispatched kernel currently runs on. Initialized once
/// from `TC_SIMD` (or CPU detection); a relaxed atomic load afterwards.
pub fn active_isa() -> Isa {
    let c = ACTIVE_ISA.load(Ordering::Relaxed);
    if c != u8::MAX {
        return Isa::from_code(c);
    }
    let isa = init_isa_from_env();
    ACTIVE_ISA.store(isa.code(), Ordering::Relaxed);
    isa
}

/// Force the dispatched ISA at runtime (tests, benches, the CLI
/// `--simd` flag) and return the previous one. Panics on a tier the CPU
/// does not support — a silent scalar fallback would turn a differential
/// test into a tautology. Callers that flip this concurrently with
/// running plans must serialize themselves; each GEMM/fused-kernel call
/// reads the ISA once at entry and stays internally consistent.
pub fn set_isa(isa: Isa) -> Isa {
    assert!(isa.supported(), "cannot force unsupported ISA `{}`", isa.name());
    let prev = active_isa();
    ACTIVE_ISA.store(isa.code(), Ordering::Relaxed);
    prev
}

// ---------------------------------------------------------------------------
// Blocking geometry
// ---------------------------------------------------------------------------

/// The `(MR, NR)` register tiles that have microkernels in every ISA
/// table — [`Blocking::validate`] rejects anything else.
pub const SUPPORTED_TILES: &[(usize, usize)] = &[(4, 4), (4, 8), (6, 8), (8, 8)];

/// The tile/cache-blocking geometry of the tiled GEMM: an `mr×nr`
/// register microkernel inside `mc×kc` packed A blocks and `kc×nc`
/// packed B panels. Resolved once per process by [`blocking`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocking {
    /// Microkernel tile rows (accumulator rows held in registers).
    pub mr: usize,
    /// Microkernel tile columns (one or more SIMD vectors of f64).
    pub nr: usize,
    /// Cache block of output rows; must be a multiple of `mr`.
    pub mc: usize,
    /// Cache block along the contraction dimension. The one parameter
    /// that affects rounding order (the register tile is flushed to C
    /// once per k-block) — every [`TUNE_CANDIDATES`] entry shares it.
    pub kc: usize,
    /// Cache block of output columns; must be a multiple of `nr`.
    pub nc: usize,
}

impl Blocking {
    /// The pre-autotuner geometry (the `GEMM_*` constants in
    /// `util`), kept as the documented baseline and test pin.
    pub const DEFAULT: Blocking =
        Blocking { mr: GEMM_MR, nr: GEMM_NR, mc: GEMM_MC, kc: GEMM_KC, nc: GEMM_NC };

    /// Check the packing invariants the tiled kernel relies on:
    /// a supported `(MR, NR)` tile, `MC % MR == 0`, `NC % NR == 0`,
    /// and nothing zero.
    pub fn validate(&self) -> Result<(), String> {
        let Blocking { mr, nr, mc, kc, nc } = *self;
        if !SUPPORTED_TILES.contains(&(mr, nr)) {
            return Err(format!(
                "unsupported microkernel tile {mr}x{nr}; supported (MR,NR) pairs: {SUPPORTED_TILES:?}"
            ));
        }
        if kc == 0 {
            return Err("KC must be non-zero".to_string());
        }
        if mc == 0 || mc % mr != 0 {
            return Err(format!("MC ({mc}) must be a non-zero multiple of MR ({mr})"));
        }
        if nc == 0 || nc % nr != 0 {
            return Err(format!("NC ({nc}) must be a non-zero multiple of NR ({nr})"));
        }
        Ok(())
    }

    /// Parse a `TC_GEMM_BLOCKING` override: five comma-separated
    /// integers `"MR,NR,MC,KC,NC"`, validated with [`Blocking::validate`].
    pub fn parse(s: &str) -> Result<Blocking, String> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        if parts.len() != 5 {
            return Err(format!("expected \"MR,NR,MC,KC,NC\", got {s:?}"));
        }
        let mut v = [0usize; 5];
        for (slot, p) in v.iter_mut().zip(&parts) {
            *slot = p.parse().map_err(|_| format!("bad integer {p:?} in {s:?}"))?;
        }
        let blk = Blocking { mr: v[0], nr: v[1], mc: v[2], kc: v[3], nc: v[4] };
        blk.validate()?;
        Ok(blk)
    }
}

/// The autotuner's candidate set. Every entry validates, and every
/// entry shares `KC = 256` so the tuner's pick can never change
/// per-element accumulation order — tuning is a pure-performance
/// decision.
pub const TUNE_CANDIDATES: [Blocking; 5] = [
    Blocking { mr: 4, nr: 8, mc: 64, kc: 256, nc: 512 },
    Blocking { mr: 8, nr: 8, mc: 64, kc: 256, nc: 512 },
    Blocking { mr: 6, nr: 8, mc: 96, kc: 256, nc: 512 },
    Blocking { mr: 4, nr: 8, mc: 128, kc: 256, nc: 1024 },
    Blocking { mr: 4, nr: 4, mc: 64, kc: 256, nc: 512 },
];

static BLOCKING: OnceLock<Blocking> = OnceLock::new();
static TUNE_COUNT: AtomicU64 = AtomicU64::new(0);

/// How many times the autotuner has actually run in this process —
/// at most once, regardless of how many plans compile or warm up
/// (zero under a `TC_GEMM_BLOCKING` pin). The tune-once tests assert
/// on this counter.
pub fn tune_count() -> u64 {
    TUNE_COUNT.load(Ordering::Relaxed)
}

fn autotune() -> Blocking {
    TUNE_COUNT.fetch_add(1, Ordering::Relaxed);
    // Probe shape: big enough that packing + tile traversal dominate,
    // small enough that five candidates cost a few ms at startup.
    let (m, k, n) = (64, 256, 128);
    let isa = active_isa();
    let mut best = Blocking::DEFAULT;
    let mut best_t = f64::INFINITY;
    for cand in TUNE_CANDIDATES {
        debug_assert!(cand.validate().is_ok());
        let ukr = kernel_for(isa, cand.mr, cand.nr)
            .expect("every tune candidate has a kernel in every ISA table");
        let t = crate::einsum::tune_probe(cand, ukr, m, k, n);
        if t < best_t {
            best_t = t;
            best = cand;
        }
    }
    best
}

/// The process-wide blocking geometry: `TC_GEMM_BLOCKING` if set
/// (invalid values panic — a typo must not silently fall back), else
/// the autotuner's pick. Cached in a `OnceLock`; the steady-state cost
/// is one initialized-check per GEMM call.
pub fn blocking() -> Blocking {
    *BLOCKING.get_or_init(|| match std::env::var("TC_GEMM_BLOCKING") {
        Ok(s) => Blocking::parse(&s)
            .unwrap_or_else(|e| panic!("invalid TC_GEMM_BLOCKING {s:?}: {e}")),
        Err(_) => autotune(),
    })
}

// ---------------------------------------------------------------------------
// The microkernel function-pointer table
// ---------------------------------------------------------------------------

/// One register microkernel: accumulate a full `MR×NR` tile over `kc`
/// packed k-steps from an A micro-panel (`kc×MR`, row-padded) and a B
/// micro-panel (`kc×NR`, column-padded), then add the valid `mr×nr`
/// part into `C` at `(row0, col0)` with row stride `ldc`. The argument
/// order is `(ap, bp, c, ldc, row0, col0, mr, nr, kc)`.
pub type MicroKernel = fn(&[f64], &[f64], &mut [f64], usize, usize, usize, usize, usize, usize);

/// The per-call GEMM configuration the tiled kernel threads through its
/// loop nest: the resolved [`Blocking`] plus the microkernel dispatched
/// for `(active ISA, MR, NR)`.
#[derive(Clone, Copy)]
pub struct GemmCfg {
    /// The process-wide blocking geometry.
    pub blk: Blocking,
    /// The dispatched register microkernel.
    pub ukr: MicroKernel,
}

/// Resolve the blocking and kernel for one GEMM call. Called at
/// `gemm_into_epi` entry, *before* any packing scratch is borrowed, so
/// a first-call autotune can itself run probe GEMMs.
pub fn gemm_cfg() -> GemmCfg {
    let blk = blocking();
    let ukr = kernel_for(active_isa(), blk.mr, blk.nr)
        .expect("validated blocking always has a microkernel for the active ISA");
    GemmCfg { blk, ukr }
}

/// Look up the microkernel for `(isa, mr, nr)`. Total over
/// [`SUPPORTED_TILES`] for every ISA the build includes; `None` for
/// unsupported tiles (and for SIMD tiers on foreign architectures).
pub fn kernel_for(isa: Isa, mr: usize, nr: usize) -> Option<MicroKernel> {
    match isa {
        Isa::Scalar => scalar_kernel(mr, nr),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::avx2_kernel(mr, nr),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => x86::avx512_kernel(mr, nr),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::neon_kernel(mr, nr),
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 | Isa::Avx512 => None,
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => None,
    }
}

/// Shared tail of every microkernel: add the valid `mr×nr` part of the
/// accumulator tile into `C`. One add per element, in row-major order —
/// identical across scalar and SIMD kernels, so the store never breaks
/// bit-identity (partial tiles included).
#[inline(always)]
fn store_tile<const MR: usize, const NR: usize>(
    acc: &[[f64; NR]; MR],
    c: &mut [f64],
    ldc: usize,
    row0: usize,
    col0: usize,
    mr: usize,
    nr: usize,
) {
    for r in 0..mr {
        let off = (row0 + r) * ldc + col0;
        let crow = &mut c[off..off + nr];
        for (cv, av) in crow.iter_mut().zip(acc[r][..nr].iter()) {
            *cv += av;
        }
    }
}

macro_rules! scalar_ukr {
    ($name:ident, $mr:literal, $nr:literal) => {
        #[allow(clippy::too_many_arguments)]
        fn $name(
            ap: &[f64],
            bp: &[f64],
            c: &mut [f64],
            ldc: usize,
            row0: usize,
            col0: usize,
            mr: usize,
            nr: usize,
            kc: usize,
        ) {
            let mut acc = [[0.0f64; $nr]; $mr];
            for kk in 0..kc {
                let av = &ap[kk * $mr..kk * $mr + $mr];
                let bv = &bp[kk * $nr..kk * $nr + $nr];
                for r in 0..$mr {
                    let ar = av[r];
                    for j in 0..$nr {
                        acc[r][j] += ar * bv[j];
                    }
                }
            }
            store_tile::<$mr, $nr>(&acc, c, ldc, row0, col0, mr, nr);
        }
    };
}

scalar_ukr!(ukr_scalar_4x4, 4, 4);
scalar_ukr!(ukr_scalar_4x8, 4, 8);
scalar_ukr!(ukr_scalar_6x8, 6, 8);
scalar_ukr!(ukr_scalar_8x8, 8, 8);

fn scalar_kernel(mr: usize, nr: usize) -> Option<MicroKernel> {
    Some(match (mr, nr) {
        (4, 4) => ukr_scalar_4x4,
        (4, 8) => ukr_scalar_4x8,
        (6, 8) => ukr_scalar_6x8,
        (8, 8) => ukr_scalar_8x8,
        _ => return None,
    })
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 (4 f64 lanes) and AVX-512F (8 f64 lanes) microkernels. Both
    //! vectorize across the NR column dimension and use separate
    //! `vmulpd`/`vaddpd` (never FMA), so each lane rounds exactly like
    //! the scalar kernel's `acc[r][j] += a[r] * b[j]`.

    use super::{store_tile, MicroKernel};
    use core::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_setzero_pd,
        _mm256_storeu_pd, _mm512_add_pd, _mm512_loadu_pd, _mm512_mul_pd, _mm512_set1_pd,
        _mm512_setzero_pd, _mm512_storeu_pd,
    };

    macro_rules! avx2_ukr {
        ($inner:ident, $outer:ident, $mr:literal, $nr:literal) => {
            /// # Safety
            /// Requires AVX2; only reachable through `avx2_kernel` /
            /// `avx512_kernel`, whose ISAs are gated on detection.
            #[allow(clippy::too_many_arguments)]
            #[target_feature(enable = "avx2")]
            unsafe fn $inner(
                ap: &[f64],
                bp: &[f64],
                c: &mut [f64],
                ldc: usize,
                row0: usize,
                col0: usize,
                mr: usize,
                nr: usize,
                kc: usize,
            ) {
                debug_assert!(ap.len() >= kc * $mr && bp.len() >= kc * $nr);
                let mut acc = [[_mm256_setzero_pd(); $nr / 4]; $mr];
                for kk in 0..kc {
                    let bbase = bp.as_ptr().add(kk * $nr);
                    let mut bv = [_mm256_setzero_pd(); $nr / 4];
                    for (v, slot) in bv.iter_mut().enumerate() {
                        *slot = _mm256_loadu_pd(bbase.add(4 * v));
                    }
                    let abase = ap.as_ptr().add(kk * $mr);
                    for (r, arow) in acc.iter_mut().enumerate() {
                        let ar = _mm256_set1_pd(*abase.add(r));
                        for (slot, &b) in arow.iter_mut().zip(bv.iter()) {
                            // separate mul then add — no FMA contraction
                            *slot = _mm256_add_pd(*slot, _mm256_mul_pd(ar, b));
                        }
                    }
                }
                let mut spill = [[0.0f64; $nr]; $mr];
                for (srow, arow) in spill.iter_mut().zip(acc.iter()) {
                    for (v, &lane) in arow.iter().enumerate() {
                        _mm256_storeu_pd(srow.as_mut_ptr().add(4 * v), lane);
                    }
                }
                store_tile::<$mr, $nr>(&spill, c, ldc, row0, col0, mr, nr);
            }

            #[allow(clippy::too_many_arguments)]
            fn $outer(
                ap: &[f64],
                bp: &[f64],
                c: &mut [f64],
                ldc: usize,
                row0: usize,
                col0: usize,
                mr: usize,
                nr: usize,
                kc: usize,
            ) {
                // SAFETY: this wrapper only enters the dispatch table for
                // Avx2/Avx512, which `Isa::supported` gates on detection.
                unsafe { $inner(ap, bp, c, ldc, row0, col0, mr, nr, kc) }
            }
        };
    }

    macro_rules! avx512_ukr {
        ($inner:ident, $outer:ident, $mr:literal) => {
            /// # Safety
            /// Requires AVX-512F; only reachable through `avx512_kernel`.
            #[allow(clippy::too_many_arguments)]
            #[target_feature(enable = "avx512f")]
            unsafe fn $inner(
                ap: &[f64],
                bp: &[f64],
                c: &mut [f64],
                ldc: usize,
                row0: usize,
                col0: usize,
                mr: usize,
                nr: usize,
                kc: usize,
            ) {
                debug_assert!(ap.len() >= kc * $mr && bp.len() >= kc * 8);
                let mut acc = [_mm512_setzero_pd(); $mr];
                for kk in 0..kc {
                    let bv = _mm512_loadu_pd(bp.as_ptr().add(kk * 8));
                    let abase = ap.as_ptr().add(kk * $mr);
                    for (r, slot) in acc.iter_mut().enumerate() {
                        let ar = _mm512_set1_pd(*abase.add(r));
                        // separate mul then add — no FMA contraction
                        *slot = _mm512_add_pd(*slot, _mm512_mul_pd(ar, bv));
                    }
                }
                let mut spill = [[0.0f64; 8]; $mr];
                for (srow, &lane) in spill.iter_mut().zip(acc.iter()) {
                    _mm512_storeu_pd(srow.as_mut_ptr(), lane);
                }
                store_tile::<$mr, 8>(&spill, c, ldc, row0, col0, mr, nr);
            }

            #[allow(clippy::too_many_arguments)]
            fn $outer(
                ap: &[f64],
                bp: &[f64],
                c: &mut [f64],
                ldc: usize,
                row0: usize,
                col0: usize,
                mr: usize,
                nr: usize,
                kc: usize,
            ) {
                // SAFETY: only dispatched for Avx512, gated on detection.
                unsafe { $inner(ap, bp, c, ldc, row0, col0, mr, nr, kc) }
            }
        };
    }

    avx2_ukr!(ukr_avx2_4x4_tf, ukr_avx2_4x4, 4, 4);
    avx2_ukr!(ukr_avx2_4x8_tf, ukr_avx2_4x8, 4, 8);
    avx2_ukr!(ukr_avx2_6x8_tf, ukr_avx2_6x8, 6, 8);
    avx2_ukr!(ukr_avx2_8x8_tf, ukr_avx2_8x8, 8, 8);

    avx512_ukr!(ukr_avx512_4x8_tf, ukr_avx512_4x8, 4);
    avx512_ukr!(ukr_avx512_6x8_tf, ukr_avx512_6x8, 6);
    avx512_ukr!(ukr_avx512_8x8_tf, ukr_avx512_8x8, 8);

    pub(super) fn avx2_kernel(mr: usize, nr: usize) -> Option<MicroKernel> {
        Some(match (mr, nr) {
            (4, 4) => ukr_avx2_4x4,
            (4, 8) => ukr_avx2_4x8,
            (6, 8) => ukr_avx2_6x8,
            (8, 8) => ukr_avx2_8x8,
            _ => return None,
        })
    }

    pub(super) fn avx512_kernel(mr: usize, nr: usize) -> Option<MicroKernel> {
        Some(match (mr, nr) {
            // NR = 4 tiles run the AVX2 kernel (identical rounding;
            // AVX-512F hardware always has AVX2)
            (4, 4) => ukr_avx2_4x4,
            (4, 8) => ukr_avx512_4x8,
            (6, 8) => ukr_avx512_6x8,
            (8, 8) => ukr_avx512_8x8,
            _ => return None,
        })
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON microkernels (2 f64 lanes), vectorized across NR with
    //! separate `vmulq`/`vaddq` — no FMA contraction.

    use super::{store_tile, MicroKernel};
    use core::arch::aarch64::{vaddq_f64, vdupq_n_f64, vld1q_f64, vmulq_f64, vst1q_f64};

    macro_rules! neon_ukr {
        ($inner:ident, $outer:ident, $mr:literal, $nr:literal) => {
            /// # Safety
            /// Requires NEON, which is baseline on aarch64.
            #[allow(clippy::too_many_arguments)]
            #[target_feature(enable = "neon")]
            unsafe fn $inner(
                ap: &[f64],
                bp: &[f64],
                c: &mut [f64],
                ldc: usize,
                row0: usize,
                col0: usize,
                mr: usize,
                nr: usize,
                kc: usize,
            ) {
                debug_assert!(ap.len() >= kc * $mr && bp.len() >= kc * $nr);
                let mut acc = [[vdupq_n_f64(0.0); $nr / 2]; $mr];
                for kk in 0..kc {
                    let bbase = bp.as_ptr().add(kk * $nr);
                    let mut bv = [vdupq_n_f64(0.0); $nr / 2];
                    for (v, slot) in bv.iter_mut().enumerate() {
                        *slot = vld1q_f64(bbase.add(2 * v));
                    }
                    let abase = ap.as_ptr().add(kk * $mr);
                    for (r, arow) in acc.iter_mut().enumerate() {
                        let ar = vdupq_n_f64(*abase.add(r));
                        for (slot, &b) in arow.iter_mut().zip(bv.iter()) {
                            // separate mul then add — no FMA contraction
                            *slot = vaddq_f64(*slot, vmulq_f64(ar, b));
                        }
                    }
                }
                let mut spill = [[0.0f64; $nr]; $mr];
                for (srow, arow) in spill.iter_mut().zip(acc.iter()) {
                    for (v, &lane) in arow.iter().enumerate() {
                        vst1q_f64(srow.as_mut_ptr().add(2 * v), lane);
                    }
                }
                store_tile::<$mr, $nr>(&spill, c, ldc, row0, col0, mr, nr);
            }

            #[allow(clippy::too_many_arguments)]
            fn $outer(
                ap: &[f64],
                bp: &[f64],
                c: &mut [f64],
                ldc: usize,
                row0: usize,
                col0: usize,
                mr: usize,
                nr: usize,
                kc: usize,
            ) {
                // SAFETY: NEON is baseline on every aarch64 target.
                unsafe { $inner(ap, bp, c, ldc, row0, col0, mr, nr, kc) }
            }
        };
    }

    neon_ukr!(ukr_neon_4x4_tf, ukr_neon_4x4, 4, 4);
    neon_ukr!(ukr_neon_4x8_tf, ukr_neon_4x8, 4, 8);
    neon_ukr!(ukr_neon_6x8_tf, ukr_neon_6x8, 6, 8);
    neon_ukr!(ukr_neon_8x8_tf, ukr_neon_8x8, 8, 8);

    pub(super) fn neon_kernel(mr: usize, nr: usize) -> Option<MicroKernel> {
        Some(match (mr, nr) {
            (4, 4) => ukr_neon_4x4,
            (4, 8) => ukr_neon_4x8,
            (6, 8) => ukr_neon_6x8,
            (8, 8) => ukr_neon_8x8,
            _ => return None,
        })
    }
}

// ---------------------------------------------------------------------------
// Dispatched element-wise helpers
//
// The compiled executor's non-contraction sweeps (tensor adds, the
// einsum element-wise fast paths) are lane-independent maps, so an
// AVX2-compiled clone of the same loop is bit-identical to the baseline
// build — `#[target_feature]` only widens the vectors LLVM may use.
// ---------------------------------------------------------------------------

macro_rules! ew_op {
    ($(#[$doc:meta])* $name:ident, $avx:ident, ($($arg:ident: $ty:ty),*), $body:block) => {
        #[cfg(target_arch = "x86_64")]
        #[target_feature(enable = "avx2")]
        unsafe fn $avx($($arg: $ty),*) $body

        $(#[$doc])*
        #[inline]
        pub fn $name($($arg: $ty),*) {
            #[cfg(target_arch = "x86_64")]
            if matches!(active_isa(), Isa::Avx2 | Isa::Avx512) {
                // SAFETY: the dispatch tier guarantees AVX2 is present.
                unsafe { $avx($($arg),*) };
                return;
            }
            $body
        }
    };
}

ew_op!(
    /// `out[i] = a[i] + b[i]` (dispatched; bit-identical across ISAs).
    add_into,
    add_into_avx2,
    (out: &mut [f64], a: &[f64], b: &[f64]),
    {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x + y;
        }
    }
);

ew_op!(
    /// `out[i] += a[i]` (dispatched; bit-identical across ISAs).
    add_assign,
    add_assign_avx2,
    (out: &mut [f64], a: &[f64]),
    {
        for (o, &x) in out.iter_mut().zip(a) {
            *o += x;
        }
    }
);

ew_op!(
    /// `out[i] = a[i] * b[i]` (dispatched; bit-identical across ISAs).
    mul_into,
    mul_into_avx2,
    (out: &mut [f64], a: &[f64], b: &[f64]),
    {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x * y;
        }
    }
);

ew_op!(
    /// `out[i] = a[i] * s` (dispatched; bit-identical across ISAs).
    mul_scalar_into,
    mul_scalar_into_avx2,
    (out: &mut [f64], a: &[f64], s: f64),
    {
        for (o, &x) in out.iter_mut().zip(a) {
            *o = x * s;
        }
    }
);

ew_op!(
    /// `out[i] *= s` (dispatched; bit-identical across ISAs).
    scale_assign,
    scale_assign_avx2,
    (out: &mut [f64], s: f64),
    {
        for o in out.iter_mut() {
            *o *= s;
        }
    }
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_parse_named_forms() {
        assert_eq!(Isa::parse("off"), Some(Isa::Scalar));
        assert_eq!(Isa::parse("scalar"), Some(Isa::Scalar));
        assert_eq!(Isa::parse("AVX2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse(" avx512 "), Some(Isa::Avx512));
        assert_eq!(Isa::parse("neon"), Some(Isa::Neon));
        assert_eq!(Isa::parse("sse9"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn detection_is_coherent() {
        let best = detect_isa();
        assert!(best.supported());
        let all = supported_isas();
        assert!(all.contains(&Isa::Scalar));
        assert!(all.contains(&best));
    }

    #[test]
    fn parse_blocking_accepts_valid() {
        let blk = Blocking::parse("4,8,64,256,512").unwrap();
        assert_eq!(blk, Blocking::DEFAULT);
        let blk = Blocking::parse(" 8 , 8 , 64 , 128 , 512 ").unwrap();
        assert_eq!(blk, Blocking { mr: 8, nr: 8, mc: 64, kc: 128, nc: 512 });
    }

    #[test]
    fn parse_blocking_rejects_loudly() {
        // MC % MR != 0
        let e = Blocking::parse("4,8,65,256,512").unwrap_err();
        assert!(e.contains("MC"), "{e}");
        // NC % NR != 0
        let e = Blocking::parse("4,8,64,256,513").unwrap_err();
        assert!(e.contains("NC"), "{e}");
        // unsupported register tile
        let e = Blocking::parse("5,8,65,256,512").unwrap_err();
        assert!(e.contains("unsupported"), "{e}");
        // wrong arity and garbage integers
        assert!(Blocking::parse("4,8,64,256").is_err());
        assert!(Blocking::parse("4,8,64,256,512,9").is_err());
        assert!(Blocking::parse("4,8,sixty,256,512").is_err());
        // zeros
        assert!(Blocking::parse("4,8,64,0,512").is_err());
        assert!(Blocking::parse("4,8,0,256,512").is_err());
    }

    #[test]
    fn default_and_candidates_validate() {
        assert!(Blocking::DEFAULT.validate().is_ok());
        for cand in TUNE_CANDIDATES {
            assert!(cand.validate().is_ok(), "{cand:?}");
            // the pick must never change numerics: same KC everywhere
            assert_eq!(cand.kc, Blocking::DEFAULT.kc, "{cand:?} breaks KC invariance");
        }
    }

    #[test]
    fn blocking_is_cached_and_tunes_at_most_once() {
        let b1 = blocking();
        let t1 = tune_count();
        let b2 = blocking();
        let t2 = tune_count();
        assert_eq!(b1, b2, "blocking must be stable within a process");
        assert_eq!(t1, t2, "a warm blocking() call re-ran the tuner");
        assert!(t1 <= 1, "the tuner ran {t1} times");
        assert!(b1.validate().is_ok());
    }

    #[test]
    fn every_isa_table_is_total_over_supported_tiles() {
        for isa in supported_isas() {
            for &(mr, nr) in SUPPORTED_TILES {
                assert!(
                    kernel_for(isa, mr, nr).is_some(),
                    "no {mr}x{nr} kernel for {}",
                    isa.name()
                );
            }
        }
        // unsupported tiles answer None instead of panicking
        assert!(kernel_for(Isa::Scalar, 5, 8).is_none());
        assert!(kernel_for(Isa::Scalar, 4, 6).is_none());
    }

    /// Kernel-level bit-identity: every dispatched ISA microkernel must
    /// reproduce the scalar kernel exactly — full tiles, partial tiles
    /// and padded panels alike.
    #[test]
    fn microkernels_bit_identical_to_scalar() {
        for &(mr_t, nr_t) in SUPPORTED_TILES {
            for kc in [1usize, 3, 17, 64] {
                // deterministic packed panels, zero-padded rows/cols
                let ap: Vec<f64> =
                    (0..kc * mr_t).map(|i| ((i * 37 % 101) as f64) * 0.013 - 0.5).collect();
                let bp: Vec<f64> =
                    (0..kc * nr_t).map(|i| ((i * 53 % 97) as f64) * 0.021 - 0.7).collect();
                let ldc = nr_t + 3;
                for (mr, nr) in [(mr_t, nr_t), (mr_t - 1, nr_t - 1), (1, 1)] {
                    let mr = mr.max(1);
                    let nr = nr.max(1);
                    let mut want = vec![0.25f64; mr_t * ldc];
                    let scalar = kernel_for(Isa::Scalar, mr_t, nr_t).unwrap();
                    scalar(&ap, &bp, &mut want, ldc, 0, 1, mr, nr, kc);
                    for isa in supported_isas() {
                        let ukr = kernel_for(isa, mr_t, nr_t).unwrap();
                        let mut got = vec![0.25f64; mr_t * ldc];
                        ukr(&ap, &bp, &mut got, ldc, 0, 1, mr, nr, kc);
                        assert_eq!(
                            got,
                            want,
                            "{}x{} tile (valid {mr}x{nr}, kc {kc}) diverged on {}",
                            mr_t,
                            nr_t,
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn elementwise_helpers_bit_identical_across_dispatch() {
        let a: Vec<f64> = (0..103).map(|i| (i as f64) * 0.37 - 19.0).collect();
        let b: Vec<f64> = (0..103).map(|i| (i as f64) * -0.11 + 3.0).collect();
        let mut plain = vec![0.0; 103];
        for ((o, &x), &y) in plain.iter_mut().zip(&a).zip(&b) {
            *o = x + y;
        }
        let mut got = vec![0.0; 103];
        add_into(&mut got, &a, &b);
        assert_eq!(got, plain);
        mul_into(&mut got, &a, &b);
        for ((o, &x), &y) in plain.iter_mut().zip(&a).zip(&b) {
            *o = x * y;
        }
        assert_eq!(got, plain);
        add_assign(&mut got, &a);
        for (o, &x) in plain.iter_mut().zip(&a) {
            *o += x;
        }
        assert_eq!(got, plain);
        mul_scalar_into(&mut got, &b, 1.37);
        for (o, &y) in plain.iter_mut().zip(&b) {
            *o = y * 1.37;
        }
        assert_eq!(got, plain);
        scale_assign(&mut got, -0.5);
        for o in plain.iter_mut() {
            *o *= -0.5;
        }
        assert_eq!(got, plain);
    }
}
