//! Small utilities: the persistent [`WorkerPool`], scoped-thread data
//! parallelism (the offline build has no rayon), the shared
//! parallelism/blocking constants, per-thread GEMM packing scratch,
//! runtime SIMD dispatch and the blocking autotuner ([`simd`]), and
//! wall-clock helpers for the bench harnesses.

pub mod simd;

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Parallelism thresholds, shared by the GEMM kernel (`crate::einsum::gemm`),
// the batched einsum paths (`crate::einsum`) and the compiled executor
// (`crate::exec`). All counts are in flops ≈ multiply-adds; the values were
// chosen so the scoped-thread fork cost (~10 µs on this testbed) stays well
// under 10 % of the forked work.
// ---------------------------------------------------------------------------

/// Below this many flops a single GEMM runs serially — the fork overhead
/// would dominate.
pub const PAR_GEMM_MIN_FLOP: usize = 1 << 17;

/// Batched contractions parallelise over *batch slices* only when each
/// slice is smaller than this (bigger slices parallelise internally via
/// the GEMM row bands instead).
pub const PAR_BATCH_SLICE_MAX_FLOP: usize = 1 << 16;

/// … and only when the whole batch is at least this big; otherwise the
/// batch loop runs serially.
pub const PAR_BATCH_TOTAL_MIN_FLOP: usize = 1 << 16;

/// A DAG level of the compiled executor forks worker threads only when
/// the level's estimated flops exceed this.
pub const PAR_LEVEL_MIN_FLOP: usize = 1 << 17;

/// The work-stealing level scheduler in `crate::exec` carves each
/// parallel level into roughly this many chunks *per worker thread*
/// (at least one node per chunk): small enough that one oversized node
/// strands at most the chunk that claimed it, large enough that the
/// shared cursor is not hit once per node.
pub const STEAL_CHUNKS_PER_THREAD: usize = 4;

// ---------------------------------------------------------------------------
// Default blocking parameters of the tiled GEMM kernel
// (`crate::einsum::gemm`). The register microkernel computes an MR×NR
// tile of C in local accumulators; cache blocking packs an MC×KC panel
// of A (L2-resident) and a KC×NC panel of B (streamed through L2/L3)
// around it. Sizes are in f64 elements: the default A panel is
// MC·KC·8 = 128 KiB and the active B sub-panel KC·NR·8 = 16 KiB,
// comfortable for common L2/L1 sizes.
//
// Since the SIMD/autotuner rework these constants are *defaults*, not
// the live geometry: [`simd::blocking`] resolves the per-process
// [`simd::Blocking`] from `TC_GEMM_BLOCKING` or the startup autotuner,
// seeded by these values ([`simd::Blocking::DEFAULT`]).
// ---------------------------------------------------------------------------

/// Default microkernel tile rows — accumulator rows held in registers.
pub const GEMM_MR: usize = 4;

/// Default microkernel tile columns — one or two SIMD vectors of f64.
pub const GEMM_NR: usize = 8;

/// Default cache block of output rows (a multiple of [`GEMM_MR`]).
pub const GEMM_MC: usize = 64;

/// Cache block along the contraction dimension. Shared by every
/// autotune candidate — KC is the one blocking parameter that affects
/// accumulation order, so pinning it keeps the tuner numerics-neutral.
pub const GEMM_KC: usize = 256;

/// Default cache block of output columns (a multiple of [`GEMM_NR`]).
pub const GEMM_NC: usize = 512;

/// Below this many flops (m·n·k) a GEMM skips tiling/packing and runs
/// the flat reference kernel — the packing sweep would dominate.
pub const GEMM_TILED_MIN_FLOP: usize = 1 << 14;

/// Packing scratch of the tiled GEMM, laid out in microkernel panel
/// order with zero padding to full [`GEMM_MR`]/[`GEMM_NR`] tiles: `a`
/// holds one A block (≤ `GEMM_MC·GEMM_KC` elements, sized to the
/// call's actual blocks), `b` holds the serial path's packed copy of
/// the whole B operand (the parallel path shares one packed B across
/// its row bands instead). Both grow monotonically and are reused.
#[derive(Default)]
pub struct PackBuf {
    pub a: Vec<f64>,
    pub b: Vec<f64>,
}

thread_local! {
    /// Per-thread packing scratch. Long-lived threads (the main thread,
    /// the coordinator workers) warm it once and never allocate again;
    /// short-lived scoped GEMM band workers pay one allocation per fork,
    /// which the `PAR_GEMM_MIN_FLOP` gate already amortises.
    static PACK_SCRATCH: RefCell<PackBuf> = RefCell::new(PackBuf::default());
}

/// Run `f` with this thread's GEMM packing scratch.
pub fn with_pack_scratch<R>(f: impl FnOnce(&mut PackBuf) -> R) -> R {
    PACK_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Number of worker threads (overridable with `TENSORCALC_THREADS`).
pub fn num_threads() -> usize {
    static CACHE: AtomicUsize = AtomicUsize::new(0);
    let c = CACHE.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("TENSORCALC_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .max(1);
    CACHE.store(n, Ordering::Relaxed);
    n
}

// ---------------------------------------------------------------------------
// Persistent worker pool
//
// `std::thread::scope` pays a clone/spawn/join round trip per fork (~10 µs
// plus a cold stack and cold thread-locals). The compiled executor forks on
// *every parallel level of every run*, which on the coordinator's
// steady-state hot path means thousands of spawns per second — all for
// workers that execute the same shape of work each time. `WorkerPool` keeps
// the workers alive instead: they park on a condvar, wake to run one
// scope's closure, and go back to sleep warm (thread-local GEMM packing
// scratch and einsum scratch survive between scopes).
// ---------------------------------------------------------------------------

/// A unit of work handed to a parked worker: a raw pointer to the scope's
/// closure plus the participant index it should run as. The pointer is only
/// dereferenced while [`WorkerPool::scope`] is still blocked waiting on the
/// job's latch, so the borrow it erases is always live.
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    idx: usize,
    done: Arc<ScopeLatch>,
}

// SAFETY: the closure behind `f` is `Sync` (shared by reference across the
// scope's participants) and outlives the job — `WorkerPool::scope` does not
// return, and therefore does not release the borrow, until every job has
// counted down the latch.
unsafe impl Send for Job {}

/// Completion latch of one `scope` call: counts outstanding jobs and holds
/// the first panic payload so the caller can resume the unwind.
struct ScopeLatch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl ScopeLatch {
    fn new(count: usize) -> Self {
        ScopeLatch {
            state: Mutex::new(LatchState { remaining: count, panic: None }),
            cv: Condvar::new(),
        }
    }

    fn count_down(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<Box<dyn std::any::Any + Send>> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panic.take()
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    /// workers spawned so far (grown lazily up to `num_threads() - 1`)
    spawned: AtomicUsize,
}

thread_local! {
    /// Set while a pool worker is running jobs: a nested `scope` from
    /// inside a job degrades to serial execution instead of deadlocking
    /// on workers that are all busy waiting for each other.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A persistent pool of parked worker threads executing fork-join scopes.
///
/// [`WorkerPool::scope`]`(n, f)` runs `f(0) … f(n-1)` concurrently — `f(0)`
/// on the calling thread, the rest on pool workers — and returns when all
/// participants have finished, exactly like `std::thread::scope` with `n`
/// spawns, but without creating or joining a single thread on the hot
/// path. Workers are spawned lazily (at most `num_threads() - 1`, shared
/// process-wide via [`worker_pool`]) and live for the rest of the process,
/// so their thread-local scratch (GEMM packing buffers, einsum odometers)
/// stays warm across scopes, plans and coordinator entries.
///
/// Panics inside any participant are caught, forwarded, and re-raised on
/// the calling thread after the scope has fully drained (no job is left
/// holding the closure borrow).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl WorkerPool {
    pub fn new() -> Self {
        WorkerPool {
            shared: Arc::new(PoolShared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                spawned: AtomicUsize::new(0),
            }),
        }
    }

    /// Ensure at least `want` workers exist (capped at `num_threads()-1`).
    fn ensure_workers(&self, want: usize) {
        let cap = num_threads().saturating_sub(1);
        let want = want.min(cap);
        loop {
            let cur = self.shared.spawned.load(Ordering::Relaxed);
            if cur >= want {
                return;
            }
            if self
                .shared
                .spawned
                .compare_exchange(cur, cur + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name(format!("tensorcalc-worker-{}", cur))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn pool worker");
        }
    }

    /// Run `f(0) … f(n-1)` concurrently; blocks until every participant
    /// has finished. `f(0)` runs on the calling thread. With `n <= 1`, or
    /// when called from inside a pool worker (a nested fork would risk
    /// waiting on ourselves), every index runs serially on the caller.
    pub fn scope<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n <= 1 || num_threads() <= 1 || IN_POOL_WORKER.with(|w| w.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }
        self.ensure_workers(n - 1);
        let done = Arc::new(ScopeLatch::new(n - 1));
        {
            let f_ref: &(dyn Fn(usize) + Sync) = &f;
            // SAFETY: erase the borrow lifetime to store the pointer in
            // the queue (`*const dyn Trait` defaults to `'static`, which
            // a plain cast cannot produce from a scoped borrow); `scope`
            // blocks on the latch until every job has finished, so the
            // closure strictly outlives all uses of the pointer.
            #[allow(clippy::useless_transmute)]
            let fp = unsafe {
                std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(
                    f_ref,
                )
            };
            let mut q = self.shared.queue.lock().unwrap();
            for idx in 1..n {
                q.push_back(Job { f: fp, idx, done: done.clone() });
            }
        }
        self.shared.cv.notify_all();
        // The caller participates as index 0. Its panic must still wait
        // for the latch — workers hold a pointer into this stack frame.
        let caller_panic =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0))).err();
        // Help-first join: under concurrent scopes the shared workers may
        // be busy draining another scope's jobs — instead of idling on
        // the latch behind them, the caller runs its *own* still-queued
        // jobs itself. After this loop only jobs a worker has already
        // claimed (i.e. is actively running) remain outstanding.
        loop {
            let job = {
                let mut q = self.shared.queue.lock().unwrap();
                match q.iter().position(|j| Arc::ptr_eq(&j.done, &done)) {
                    Some(pos) => q.remove(pos),
                    None => None,
                }
            };
            let Some(job) = job else { break };
            // SAFETY: same contract as worker_loop — we are still inside
            // `scope`, so the closure is alive.
            let jf = unsafe { &*job.f };
            let panic =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| jf(job.idx))).err();
            job.done.count_down(panic);
        }
        let worker_panic = done.wait();
        if let Some(p) = caller_panic.or(worker_panic) {
            std::panic::resume_unwind(p);
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    IN_POOL_WORKER.with(|w| w.set(true));
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // SAFETY: the scope that enqueued this job blocks on its latch
        // until we count down below, so the closure is still alive.
        let f = unsafe { &*job.f };
        let panic =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(job.idx))).err();
        job.done.count_down(panic);
    }
}

/// The process-wide worker pool: shared by every compiled plan and by the
/// coordinator's entry workers across `eval_many` calls, so the whole
/// process keeps one set of warm, parked threads.
pub fn worker_pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(WorkerPool::new)
}

/// Split `out` into up to `num_threads` contiguous bands of whole
/// `out_chunk`-sized units (paired with the corresponding `inp` bands of
/// `in_chunk`-sized units) and run `f(band_index_offset, out_band,
/// in_band)` on each band in parallel.
pub fn par_band_zip<F>(out: &mut [f64], out_chunk: usize, inp: &[f64], in_chunk: usize, f: F)
where
    F: Fn(usize, &mut [f64], &[f64]) + Sync,
{
    let units = out.len() / out_chunk.max(1);
    debug_assert_eq!(inp.len() / in_chunk.max(1), units);
    let nt = num_threads().min(units.max(1));
    if nt <= 1 {
        f(0, out, inp);
        return;
    }
    let per = units.div_ceil(nt);
    std::thread::scope(|s| {
        let mut out_rest = out;
        let mut in_rest = inp;
        let mut off = 0usize;
        for _ in 0..nt {
            if out_rest.is_empty() {
                break;
            }
            let take = per.min(out_rest.len() / out_chunk);
            let (ob, ot) = out_rest.split_at_mut(take * out_chunk);
            let (ib, it) = in_rest.split_at(take * in_chunk);
            let fr = &f;
            let this_off = off;
            s.spawn(move || fr(this_off, ob, ib));
            out_rest = ot;
            in_rest = it;
            off += take;
        }
    });
}

/// Like [`par_band_zip`] but with two read-only inputs (for batched GEMM:
/// C bands zipped with A and B bands).
pub fn par_band_zip2<F>(
    out: &mut [f64],
    out_chunk: usize,
    a: &[f64],
    a_chunk: usize,
    b: &[f64],
    b_chunk: usize,
    f: F,
) where
    F: Fn(usize, &mut [f64], &[f64], &[f64]) + Sync,
{
    let units = out.len() / out_chunk.max(1);
    let nt = num_threads().min(units.max(1));
    if nt <= 1 {
        f(0, out, a, b);
        return;
    }
    let per = units.div_ceil(nt);
    std::thread::scope(|s| {
        let mut out_rest = out;
        let mut a_rest = a;
        let mut b_rest = b;
        let mut off = 0usize;
        for _ in 0..nt {
            if out_rest.is_empty() {
                break;
            }
            let take = per.min(out_rest.len() / out_chunk);
            let (ob, ot) = out_rest.split_at_mut(take * out_chunk);
            let (ab, at) = a_rest.split_at(take * a_chunk);
            let (bb, bt) = b_rest.split_at(take * b_chunk);
            let fr = &f;
            let this_off = off;
            s.spawn(move || fr(this_off, ob, ab, bb));
            out_rest = ot;
            a_rest = at;
            b_rest = bt;
            off += take;
        }
    });
}

/// Median-of-runs timing helper for the hand-rolled bench harnesses.
/// Runs `f` for at least `min_runs` times and at least `min_secs`
/// seconds; returns (median_secs, runs).
pub fn time_median<F: FnMut()>(mut f: F, min_runs: usize, min_secs: f64) -> (f64, usize) {
    let mut times = Vec::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() >= min_runs && start.elapsed().as_secs_f64() >= min_secs {
            break;
        }
        if times.len() >= 10_000 {
            break;
        }
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (times[times.len() / 2], times.len())
}

/// Pretty seconds for bench tables.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2} ms", s * 1e3)
    } else {
        format!("{:8.3} s ", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_band_zip_covers_everything() {
        let mut out = vec![0.0; 64];
        let inp: Vec<f64> = (0..64).map(|i| i as f64).collect();
        par_band_zip(&mut out, 4, &inp, 4, |off, ob, ib| {
            for (k, (o, i)) in ob.iter_mut().zip(ib).enumerate() {
                *o = i * 2.0 + (off * 4 + k) as f64 * 0.0;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f64 * 2.0);
        }
    }

    #[test]
    fn par_band_zip2_offsets_are_consistent() {
        let mut out = vec![0.0; 30];
        let a: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..30).map(|i| 100.0 + i as f64).collect();
        par_band_zip2(&mut out, 3, &a, 3, &b, 3, |off, ob, ab, bb| {
            for k in 0..ob.len() {
                ob[k] = ab[k] + bb[k] + (off * 3 + k) as f64 * 0.0;
            }
        });
        for i in 0..30 {
            assert_eq!(out[i], a[i] + b[i]);
        }
    }

    #[test]
    fn time_median_returns_positive() {
        let (t, runs) = time_median(
            || {
                std::hint::black_box(1 + 1);
            },
            3,
            0.0,
        );
        assert!(t >= 0.0 && runs >= 3);
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn worker_pool_scope_runs_every_index_once() {
        use std::sync::atomic::AtomicU64;
        let pool = WorkerPool::new();
        for round in 0..8 {
            let n = 1 + (round % 5);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.scope(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {} round {}", i, round);
            }
        }
    }

    #[test]
    fn worker_pool_propagates_worker_panics() {
        let pool = worker_pool();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope(4, |i| {
                if i == 3 {
                    panic!("boom from participant");
                }
            });
        }));
        assert!(res.is_err(), "a participant panic must surface on the caller");
        // the pool must stay usable after a panicked scope
        let count = AtomicUsize::new(0);
        pool.scope(4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn worker_pool_nested_scope_degrades_to_serial() {
        let pool = worker_pool();
        let count = AtomicUsize::new(0);
        pool.scope(3, |_| {
            // nested fork from inside a job: must complete (serially on
            // workers, in parallel on the caller) rather than deadlock
            pool.scope(2, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn worker_pool_concurrent_scopes_interleave() {
        let pool = worker_pool();
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..25 {
                        pool.scope(3, |_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 25 * 3);
    }
}
