//! PJRT runtime bridge: loads the AOT-compiled HLO-text artifacts
//! produced by `python/compile/aot.py` and executes them on the XLA CPU
//! client — the request path never touches Python.
//!
//! Interchange format is HLO **text** (see aot.py for why), parsed with
//! `HloModuleProto::from_text_file`, compiled once per artifact and then
//! executed with `f32` literals converted from/to the engine's `f64`
//! [`Tensor`]s.
//!
//! The whole XLA binding is gated behind the `pjrt` cargo feature: it
//! needs a vendored `xla` crate, which the offline build does not ship.
//! Without the feature a stub [`Runtime`] with the same signature is
//! compiled that reports artifacts as unavailable, so every
//! artifact-gated test and CLI path degrades to a clean skip.

use crate::error::{Context, Result};
use crate::tensor::Tensor;
use std::path::{Path, PathBuf};

// The real PJRT binding needs the `xla` crate, which must be vendored
// (it is not on the offline registry). Fail the build with an actionable
// message instead of a wall of E0433s when the feature is enabled bare.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires a vendored `xla` crate: add it under \
     [dependencies] in rust/Cargo.toml and remove this guard (see the \
     exec-layer notes in ROADMAP.md)"
);

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;
    use crate::{anyhow, bail};
    use std::collections::HashMap;

    /// One compiled artifact: the loaded executable plus its signature from
    /// the manifest.
    pub struct Artifact {
        pub name: String,
        pub input_shapes: Vec<Vec<usize>>,
        pub output_names: Vec<String>,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The artifact registry: a PJRT CPU client plus every entry of
    /// `artifacts/manifest.txt`, compiled lazily on first use.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        specs: Vec<(String, String, Vec<Vec<usize>>, Vec<String>)>,
        compiled: HashMap<String, Artifact>,
    }

    impl Runtime {
        /// Open the artifact directory (reads `manifest.txt`; does not
        /// compile anything yet).
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {:?} — run `make artifacts` first", manifest))?;
            let mut specs = Vec::new();
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                let parts: Vec<&str> = line.split('\t').collect();
                if parts.len() != 4 {
                    bail!("malformed manifest line: {}", line);
                }
                let shapes: Vec<Vec<usize>> = parts[2]
                    .split(';')
                    .map(|s| {
                        if s.is_empty() {
                            Ok(vec![])
                        } else {
                            s.split(',')
                                .map(|d| d.parse::<usize>().map_err(|e| anyhow!("{}", e)))
                                .collect()
                        }
                    })
                    .collect::<Result<_>>()?;
                let outs: Vec<String> = parts[3].split(',').map(|s| s.to_string()).collect();
                specs.push((parts[0].to_string(), parts[1].to_string(), shapes, outs));
            }
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {:?}", e))?;
            Ok(Runtime { client, dir, specs, compiled: HashMap::new() })
        }

        /// Default artifact location (`artifacts/`, overridable with
        /// `TENSORCALC_ARTIFACTS`).
        pub fn open_default() -> Result<Self> {
            let dir = std::env::var("TENSORCALC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Self::open(dir)
        }

        /// Names of all artifacts in the manifest.
        pub fn names(&self) -> Vec<String> {
            self.specs.iter().map(|(n, ..)| n.clone()).collect()
        }

        /// Compile (once) and return the artifact.
        pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
            if !self.compiled.contains_key(name) {
                let (n, file, shapes, outs) = self
                    .specs
                    .iter()
                    .find(|(n, ..)| n == name)
                    .ok_or_else(|| anyhow!("unknown artifact {}", name))?
                    .clone();
                let path = self.dir.join(&file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parsing {:?}: {:?}", path, e))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {:?}", name, e))?;
                self.compiled.insert(
                    name.to_string(),
                    Artifact { name: n, input_shapes: shapes, output_names: outs, exe },
                );
            }
            Ok(&self.compiled[name])
        }

        /// Execute an artifact on `f64` tensors (converted to the
        /// artifact's `f32` signature and back).
        pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let art = self.artifact(name)?;
            art.run(inputs)
        }
    }

    impl Artifact {
        /// Execute with shape checking.
        pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            if inputs.len() != self.input_shapes.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.name,
                    self.input_shapes.len(),
                    inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (t, want) in inputs.iter().zip(&self.input_shapes) {
                if t.shape() != &want[..] {
                    bail!("{}: input shape {:?}, expected {:?}", self.name, t.shape(), want);
                }
                let data: Vec<f32> = t.data().iter().map(|&v| v as f32).collect();
                let lit = xla::Literal::vec1(&data);
                let dims: Vec<i64> = want.iter().map(|&d| d as i64).collect();
                let lit = lit.reshape(&dims).map_err(|e| anyhow!("reshape: {:?}", e))?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {}: {:?}", self.name, e))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {:?}", e))?;
            // aot.py lowers with return_tuple=True — always a tuple
            let parts = lit.to_tuple().map_err(|e| anyhow!("to_tuple: {:?}", e))?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                let shape = p.shape().map_err(|e| anyhow!("shape: {:?}", e))?;
                let dims: Vec<usize> = match &shape {
                    xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                    _ => bail!("{}: non-array output", self.name),
                };
                let v: Vec<f32> = p.to_vec().map_err(|e| anyhow!("to_vec: {:?}", e))?;
                out.push(Tensor::new(&dims, v.into_iter().map(|x| x as f64).collect()));
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use super::*;
    use crate::bail;

    /// Stub artifact handle compiled when the `pjrt` feature is off.
    pub struct Artifact {
        pub name: String,
        pub input_shapes: Vec<Vec<usize>>,
        pub output_names: Vec<String>,
    }

    impl Artifact {
        pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!(
                "tensorcalc was built without the `pjrt` feature — artifact {} cannot run",
                self.name
            );
        }
    }

    /// Stub runtime compiled when the `pjrt` feature is off: opening it
    /// always fails with a clear message, so artifact-gated callers
    /// (tests, `tensorcalc serve`, figures) degrade to a skip.
    pub struct Runtime {
        _dir: PathBuf,
    }

    impl Runtime {
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let _ = dir.as_ref();
            bail!(
                "tensorcalc was built without the `pjrt` feature — \
                 PJRT artifacts are unavailable (vendor the `xla` crate and \
                 build with `--features pjrt`)"
            );
        }

        pub fn open_default() -> Result<Self> {
            Self::open("artifacts")
        }

        pub fn names(&self) -> Vec<String> {
            Vec::new()
        }

        pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
            bail!("unknown artifact {} (built without the `pjrt` feature)", name);
        }

        pub fn execute(&mut self, name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("cannot execute {} (built without the `pjrt` feature)", name);
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Artifact, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::{Artifact, Runtime};

/// Read a raw little-endian `f32` file (the check bundles written by
/// aot.py) into an `f64` tensor of the given shape.
pub fn read_f32_raw(path: impl AsRef<Path>, shape: &[usize]) -> Result<Tensor> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    let n: usize = shape.iter().product();
    if bytes.len() != n * 4 {
        crate::bail!("{:?}: {} bytes, expected {}", path.as_ref(), bytes.len(), n * 4);
    }
    let data: Vec<f64> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
        .collect();
    Ok(Tensor::new(shape, data))
}

/// Locate the artifacts directory for tests/benches: `$TENSORCALC_ARTIFACTS`
/// or `<manifest dir>/artifacts`.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(d) = std::env::var("TENSORCALC_ARTIFACTS") {
        let d = PathBuf::from(d);
        return d.join("manifest.txt").exists().then_some(d);
    }
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.txt").exists().then_some(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        // only the stub build may skip here — with `pjrt` enabled an
        // open failure is a real bug (malformed manifest, client init)
        let rt = Runtime::open(&dir);
        if cfg!(not(feature = "pjrt")) && rt.is_err() {
            eprintln!("skipping: runtime unavailable (pjrt feature off)");
            return;
        }
        let rt = rt.unwrap();
        let names = rt.names();
        assert!(names.contains(&"logreg_val_grad".to_string()), "{:?}", names);
        assert!(names.contains(&"matfac_hess_core".to_string()));
    }

    #[test]
    fn logreg_artifact_matches_check_bundle() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let rt = Runtime::open(&dir);
        if cfg!(not(feature = "pjrt")) && rt.is_err() {
            eprintln!("skipping: runtime unavailable (pjrt feature off)");
            return;
        }
        let mut rt = rt.unwrap();
        let (m, n) = (256, 128);
        let x = read_f32_raw(dir.join("check/logreg_X.f32"), &[m, n]).unwrap();
        let y = read_f32_raw(dir.join("check/logreg_y.f32"), &[m]).unwrap();
        let w = read_f32_raw(dir.join("check/logreg_w.f32"), &[n]).unwrap();
        let loss = read_f32_raw(dir.join("check/logreg_loss.f32"), &[]).unwrap();
        let grad = read_f32_raw(dir.join("check/logreg_grad.f32"), &[n]).unwrap();
        let hess = read_f32_raw(dir.join("check/logreg_hess.f32"), &[n, n]).unwrap();

        let out = rt.execute("logreg_val_grad", &[w.clone(), x.clone(), y.clone()]).unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0].item() - loss.item()).abs() < 1e-2 * loss.item().abs());
        assert!(out[1].allclose(&grad, 1e-4, 1e-4), "grad diff {}", out[1].max_abs_diff(&grad));

        let h = rt.execute("logreg_hess", &[w, x, y]).unwrap();
        assert!(h[0].allclose(&hess, 1e-4, 1e-4), "hess diff {}", h[0].max_abs_diff(&hess));
    }

    #[test]
    fn engine_matches_pjrt_artifact() {
        // the cross-layer test: Rust symbolic engine vs the JAX-lowered
        // artifact on identical data
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        use crate::eval::{eval, Env};
        use crate::ir::{Elem, Graph};
        let rt = Runtime::open(&dir);
        if cfg!(not(feature = "pjrt")) && rt.is_err() {
            eprintln!("skipping: runtime unavailable (pjrt feature off)");
            return;
        }
        let mut rt = rt.unwrap();
        let (m, n) = (256usize, 128usize);
        let x = read_f32_raw(dir.join("check/logreg_X.f32"), &[m, n]).unwrap();
        let y = read_f32_raw(dir.join("check/logreg_y.f32"), &[m]).unwrap();
        let w = read_f32_raw(dir.join("check/logreg_w.f32"), &[n]).unwrap();

        // engine-side logistic loss gradient
        let mut g = Graph::new();
        let xv = g.var("X", &[m, n]);
        let yv = g.var("y", &[m]);
        let wv = g.var("w", &[n]);
        let xw = g.matvec(xv, wv);
        let yxw = g.hadamard(yv, xw);
        let t = g.neg(yxw);
        let e = g.elem(Elem::Exp, t);
        let one = g.constant(1.0, &[m]);
        let s = g.add(e, one);
        let l = g.elem(Elem::Log, s);
        let loss = g.sum_all(l);
        let grad = crate::autodiff::reverse::reverse_gradient(&mut g, loss, wv);
        let grad = crate::simplify::simplify_one(&mut g, grad);
        let mut env = Env::new();
        env.insert("X", x.clone());
        env.insert("y", y.clone());
        env.insert("w", w.clone());
        let engine_grad = eval(&g, grad, &env);

        let out = rt.execute("logreg_val_grad", &[w, x, y]).unwrap();
        assert!(
            engine_grad.allclose(&out[1], 1e-3, 1e-3),
            "engine vs PJRT grad diff {}",
            engine_grad.max_abs_diff(&out[1])
        );
    }

    #[test]
    fn read_f32_raw_rejects_bad_size() {
        let tmp = std::env::temp_dir().join("tc_raw_test.f32");
        std::fs::write(&tmp, [0u8; 8]).unwrap();
        assert!(read_f32_raw(&tmp, &[3]).is_err());
        assert!(read_f32_raw(&tmp, &[2]).is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::open("nonexistent").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{}", err);
    }
}
