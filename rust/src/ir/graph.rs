//! The hash-consed expression DAG.

use crate::einsum::EinSpec;
use crate::ir::elem::{Elem, GenFn};
use std::collections::HashMap;

/// Handle to a node in a [`Graph`]. Node ids are topologically ordered:
/// children always have smaller ids than their parents.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct NodeId(pub u32);

impl NodeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Node operation. `Mul` carries the `(s1,s2,s3)` spec whose labels are
/// local to that node (like letters in one einsum string).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Op {
    /// Named input tensor.
    Var(String),
    /// Constant-filled tensor (`value` in every entry). A scalar constant
    /// has shape `[]`. Zero and one tensors are this with value 0 / 1.
    Const(u64 /* f64 bits */),
    /// Order-`2k` unit tensor `δ[u₁..u_k, v₁..v_k] = Π [u_m = v_m]`,
    /// where `dims` are the k paired dimensions (shape = dims ++ dims).
    Delta { dims: Vec<usize> },
    /// Tensor addition; operands must have identical shapes.
    Add(NodeId, NodeId),
    /// The generic multiplication `a *_(s1,s2,s3) b`.
    Mul(NodeId, NodeId, EinSpec),
    /// Element-wise unary function.
    Elem(Elem, NodeId),
    /// General (non-element-wise) unary function, Theorem 6/9 territory.
    GenUnary(GenFn, NodeId),
}

/// A node: operation plus the shape of its value.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Node {
    pub op: Op,
    pub shape: Vec<usize>,
}

/// The expression DAG. Nodes are hash-consed: structurally identical
/// subexpressions share a node (free CSE), which the paper relies on when
/// it reuses `exp(X·w)` twice in Expression (1).
#[derive(Default, Clone)]
pub struct Graph {
    nodes: Vec<Node>,
    intern: HashMap<Node, NodeId>,
    vars: HashMap<String, NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    /// Number of nodes in the DAG.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }

    pub fn op(&self, id: NodeId) -> &Op {
        &self.nodes[id.index()].op
    }

    pub fn shape(&self, id: NodeId) -> &[usize] {
        &self.nodes[id.index()].shape
    }

    /// Tensor order (rank) of a node's value.
    pub fn order(&self, id: NodeId) -> usize {
        self.shape(id).len()
    }

    /// All nodes, in id (= topological) order.
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Look up a declared variable by name.
    pub fn var_id(&self, name: &str) -> Option<NodeId> {
        self.vars.get(name).copied()
    }

    /// All declared variables in declaration order.
    pub fn var_names(&self) -> Vec<String> {
        let mut v: Vec<(NodeId, String)> =
            self.vars.iter().map(|(n, &id)| (id, n.clone())).collect();
        v.sort();
        v.into_iter().map(|(_, n)| n).collect()
    }

    fn push(&mut self, node: Node) -> NodeId {
        if let Some(&id) = self.intern.get(&node) {
            return id;
        }
        let id = NodeId(self.nodes.len() as u32);
        self.intern.insert(node.clone(), id);
        self.nodes.push(node);
        id
    }

    /// Declare (or fetch) an input variable with the given shape.
    pub fn var(&mut self, name: &str, shape: &[usize]) -> NodeId {
        if let Some(&id) = self.vars.get(name) {
            assert_eq!(
                self.shape(id),
                shape,
                "variable {} redeclared with different shape",
                name
            );
            return id;
        }
        let id = self.push(Node { op: Op::Var(name.to_string()), shape: shape.to_vec() });
        self.vars.insert(name.to_string(), id);
        id
    }

    /// Constant-filled tensor.
    pub fn constant(&mut self, value: f64, shape: &[usize]) -> NodeId {
        self.push(Node { op: Op::Const(value.to_bits()), shape: shape.to_vec() })
    }

    /// Scalar constant.
    pub fn scalar(&mut self, value: f64) -> NodeId {
        self.constant(value, &[])
    }

    /// The order-`2k` unit tensor over the given paired dims.
    pub fn delta(&mut self, dims: &[usize]) -> NodeId {
        let mut shape = dims.to_vec();
        shape.extend_from_slice(dims);
        self.push(Node { op: Op::Delta { dims: dims.to_vec() }, shape })
    }

    /// `a + b`; shapes must match exactly (axis order included — use
    /// [`Graph::transpose`] first when they differ).
    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        assert_eq!(
            self.shape(a),
            self.shape(b),
            "add shape mismatch: {:?} vs {:?}",
            self.shape(a),
            self.shape(b)
        );
        let shape = self.shape(a).to_vec();
        // canonical operand order for better CSE
        let (a, b) = if a <= b { (a, b) } else { (b, a) };
        self.push(Node { op: Op::Add(a, b), shape })
    }

    /// The generic multiplication `a *_(s1,s2,s3) b`.
    pub fn mul(&mut self, a: NodeId, b: NodeId, spec: EinSpec) -> NodeId {
        let shape = spec
            .output_shape(self.shape(a), self.shape(b))
            .unwrap_or_else(|e| panic!("mul: {}", e));
        self.push(Node { op: Op::Mul(a, b, spec), shape })
    }

    /// Element-wise unary application.
    pub fn elem(&mut self, f: Elem, a: NodeId) -> NodeId {
        let shape = self.shape(a).to_vec();
        self.push(Node { op: Op::Elem(f, a), shape })
    }

    /// General unary application (range shape determined by the function).
    pub fn gen_unary(&mut self, f: GenFn, a: NodeId) -> NodeId {
        let shape = f.range_shape(self.shape(a));
        self.push(Node { op: Op::GenUnary(f, a), shape })
    }

    /// Direct children of a node.
    pub fn children(&self, id: NodeId) -> Vec<NodeId> {
        match self.op(id) {
            Op::Add(a, b) | Op::Mul(a, b, _) => vec![*a, *b],
            Op::Elem(_, a) | Op::GenUnary(_, a) => vec![*a],
            _ => vec![],
        }
    }

    /// Topological order of the sub-DAG reachable from `roots`
    /// (children before parents).
    pub fn topo(&self, roots: &[NodeId]) -> Vec<NodeId> {
        let mut seen = vec![false; self.nodes.len()];
        let mut out = Vec::new();
        // ids are already topologically sorted; mark reachable then scan
        let mut stack: Vec<NodeId> = roots.to_vec();
        while let Some(id) = stack.pop() {
            if seen[id.index()] {
                continue;
            }
            seen[id.index()] = true;
            stack.extend(self.children(id));
        }
        for (i, s) in seen.iter().enumerate() {
            if *s {
                out.push(NodeId(i as u32));
            }
        }
        out
    }

    /// True if `x` is reachable from `root` (i.e. `root` depends on `x`).
    pub fn depends_on(&self, root: NodeId, x: NodeId) -> bool {
        self.topo(&[root]).contains(&x)
    }

    /// Number of uses of each node within the sub-DAG reachable from `roots`.
    pub fn use_counts(&self, roots: &[NodeId]) -> Vec<u32> {
        let mut counts = vec![0u32; self.nodes.len()];
        for id in self.topo(roots) {
            for c in self.children(id) {
                counts[c.index()] += 1;
            }
        }
        for r in roots {
            counts[r.index()] += 1;
        }
        counts
    }

    /// Is this node the scalar/filled constant `value`?
    pub fn is_const_value(&self, id: NodeId, value: f64) -> bool {
        matches!(self.op(id), Op::Const(bits) if *bits == value.to_bits())
    }

    pub fn const_value(&self, id: NodeId) -> Option<f64> {
        match self.op(id) {
            Op::Const(bits) => Some(f64::from_bits(*bits)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_consing_dedups() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let a = g.elem(Elem::Exp, x);
        let b = g.elem(Elem::Exp, x);
        assert_eq!(a, b);
        let s = g.add(a, x);
        let t = g.add(x, a); // canonical order ⇒ same node
        assert_eq!(s, t);
    }

    #[test]
    fn shapes_inferred_through_mul() {
        let mut g = Graph::new();
        let a = g.var("A", &[2, 3]);
        let b = g.var("B", &[3, 4]);
        let c = g.mul(a, b, EinSpec::parse("ij,jk->ik"));
        assert_eq!(g.shape(c), &[2, 4]);
        assert_eq!(g.order(c), 2);
    }

    #[test]
    #[should_panic]
    fn add_rejects_shape_mismatch() {
        let mut g = Graph::new();
        let a = g.var("A", &[2, 3]);
        let b = g.var("B", &[3, 2]);
        g.add(a, b);
    }

    #[test]
    fn topo_is_child_first() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let e = g.elem(Elem::Exp, x);
        let y = g.add(e, x);
        let order = g.topo(&[y]);
        let pos = |id: NodeId| order.iter().position(|&n| n == id).unwrap();
        assert!(pos(x) < pos(e));
        assert!(pos(e) < pos(y));
    }

    #[test]
    fn depends_on_works() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let y = g.var("y", &[3]);
        let e = g.elem(Elem::Exp, x);
        assert!(g.depends_on(e, x));
        assert!(!g.depends_on(e, y));
    }

    #[test]
    fn delta_shape() {
        let mut g = Graph::new();
        let d = g.delta(&[2, 5]);
        assert_eq!(g.shape(d), &[2, 5, 2, 5]);
    }

    #[test]
    fn var_redeclaration_same_shape_ok() {
        let mut g = Graph::new();
        let a = g.var("x", &[3]);
        let b = g.var("x", &[3]);
        assert_eq!(a, b);
    }
}
