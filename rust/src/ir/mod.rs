//! The tensor-expression IR: a hash-consed expression DAG whose only
//! multiplication primitive is the paper's generic Einstein-notation
//! product `A *_(s1,s2,s3) B`.
//!
//! Node kinds (Section 3.1 of the paper distinguishes exactly these):
//!
//! * variables and constants (input nodes),
//! * **multiplication nodes** `Mul(a, b, spec)`,
//! * **addition nodes** `Add(a, b)`,
//! * **element-wise unary** functions `Elem(f, a)`,
//! * **general unary** functions `GenUnary(f, a)` (e.g. softmax),
//! * **unit (delta) tensors** — the `δ`/`𝕀` tensors produced as
//!   derivative seeds and eliminated by simplification/compression.

mod build;
mod display;
mod elem;
mod graph;

pub use elem::{Elem, GenFn};
pub use graph::{Graph, Node, NodeId, Op};
