//! Element-wise and general unary functions, together with the symbolic
//! derivative `f'` each contributes to the pushforward/pullback rules
//! (Theorems 6, 7, 9, 10).

use crate::einsum::EinSpec;
use crate::ir::graph::{Graph, NodeId};
use crate::tensor::Tensor;

/// Element-wise unary functions (applied entry by entry).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Elem {
    Exp,
    Log,
    /// `max(0, x)` — the ReLU of the paper's neural-net experiment.
    Relu,
    /// Heaviside step `1[x > 0]` — ReLU's (sub)derivative.
    Step,
    Sigmoid,
    Tanh,
    Sqrt,
    /// `-x`
    Neg,
    /// `1/x` — the paper's element-wise multiplicative inverse `·⁻¹`.
    Recip,
    /// `x²`
    Square,
    /// Sign function (subderivative of |x|).
    Sign,
    Abs,
}

impl Elem {
    pub fn name(self) -> &'static str {
        match self {
            Elem::Exp => "exp",
            Elem::Log => "log",
            Elem::Relu => "relu",
            Elem::Step => "step",
            Elem::Sigmoid => "sigmoid",
            Elem::Tanh => "tanh",
            Elem::Sqrt => "sqrt",
            Elem::Neg => "neg",
            Elem::Recip => "recip",
            Elem::Square => "square",
            Elem::Sign => "sign",
            Elem::Abs => "abs",
        }
    }

    /// Scalar evaluation. `#[inline]` because the compiled executor's
    /// fused kernels call this once per element inside their hot loop.
    #[inline]
    pub fn apply(self, x: f64) -> f64 {
        match self {
            Elem::Exp => x.exp(),
            Elem::Log => x.ln(),
            Elem::Relu => x.max(0.0),
            Elem::Step => {
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Elem::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Elem::Tanh => x.tanh(),
            Elem::Sqrt => x.sqrt(),
            Elem::Neg => -x,
            Elem::Recip => 1.0 / x,
            Elem::Square => x * x,
            Elem::Sign => {
                if x > 0.0 {
                    1.0
                } else if x < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            }
            Elem::Abs => x.abs(),
        }
    }

    /// Tensor evaluation.
    pub fn eval(self, t: &Tensor) -> Tensor {
        t.map(|x| self.apply(x))
    }

    /// Build the expression `f'(a)` (same shape as `a`) in the graph —
    /// the `f'(A)` factor of Theorems 6/7/9/10.
    pub fn derivative(self, g: &mut Graph, a: NodeId) -> NodeId {
        let shape = g.shape(a).to_vec();
        // elementwise spec over the argument shape
        let labels: Vec<u32> = (0..shape.len() as u32).collect();
        let ew = EinSpec::new(labels.clone(), labels.clone(), labels.clone());
        match self {
            Elem::Exp => g.elem(Elem::Exp, a),
            Elem::Log => g.elem(Elem::Recip, a),
            Elem::Relu => g.elem(Elem::Step, a),
            Elem::Step => g.constant(0.0, &shape),
            Elem::Sigmoid => {
                // σ' = σ (1 − σ)
                let s = g.elem(Elem::Sigmoid, a);
                let one = g.constant(1.0, &shape);
                let neg_s = g.elem(Elem::Neg, s);
                let om = g.add(one, neg_s);
                g.mul(s, om, ew)
            }
            Elem::Tanh => {
                // tanh' = 1 − tanh²
                let t = g.elem(Elem::Tanh, a);
                let t2 = g.elem(Elem::Square, t);
                let one = g.constant(1.0, &shape);
                let neg = g.elem(Elem::Neg, t2);
                g.add(one, neg)
            }
            Elem::Sqrt => {
                // (√x)' = 1 / (2 √x)
                let s = g.elem(Elem::Sqrt, a);
                let half = g.scalar(0.5);
                let r = g.elem(Elem::Recip, s);
                let sc = EinSpec::new(labels.clone(), vec![], labels.clone());
                g.mul(r, half, sc)
            }
            Elem::Neg => g.constant(-1.0, &shape),
            Elem::Recip => {
                // (1/x)' = −1/x²
                let x2 = g.elem(Elem::Square, a);
                let r = g.elem(Elem::Recip, x2);
                g.elem(Elem::Neg, r)
            }
            Elem::Square => {
                // (x²)' = 2x
                let two = g.scalar(2.0);
                let sc = EinSpec::new(labels.clone(), vec![], labels.clone());
                g.mul(a, two, sc)
            }
            Elem::Sign => g.constant(0.0, &shape),
            Elem::Abs => g.elem(Elem::Sign, a),
        }
    }
}

/// General (non-element-wise) unary tensor functions — the `f` of
/// Theorems 6 and 9, whose derivative `f'` is a tensor of order
/// `|range| + |domain|`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum GenFn {
    /// Row-wise softmax over the last axis.
    Softmax,
    /// Row-wise log-sum-exp over the last axis (removes the last axis).
    LogSumExp,
}

impl GenFn {
    pub fn name(self) -> &'static str {
        match self {
            GenFn::Softmax => "softmax",
            GenFn::LogSumExp => "logsumexp",
        }
    }

    /// Shape of `f(A)` given the shape of `A`.
    pub fn range_shape(self, domain: &[usize]) -> Vec<usize> {
        match self {
            GenFn::Softmax => domain.to_vec(),
            GenFn::LogSumExp => domain[..domain.len() - 1].to_vec(),
        }
    }

    /// Numeric evaluation.
    pub fn eval(self, t: &Tensor) -> Tensor {
        let n = *t.shape().last().expect("GenFn needs rank ≥ 1");
        match self {
            GenFn::Softmax => {
                let mut out = t.clone();
                for row in out.data_mut().chunks_mut(n) {
                    let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    let mut z = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - m).exp();
                        z += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= z;
                    }
                }
                out
            }
            GenFn::LogSumExp => {
                let out_shape = self.range_shape(t.shape());
                let data = t
                    .data()
                    .chunks(n)
                    .map(|row| {
                        let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                        m + row.iter().map(|v| (v - m).exp()).sum::<f64>().ln()
                    })
                    .collect();
                Tensor::new(&out_shape, data)
            }
        }
    }

    /// Build `f'(A)` symbolically: a node of shape `range ++ domain`
    /// (index set `s2 s1` in the paper's statement of Theorem 6/9).
    pub fn derivative(self, g: &mut Graph, a: NodeId) -> NodeId {
        let dom = g.shape(a).to_vec();
        let r = dom.len();
        let n = dom[r - 1];
        let batch = &dom[..r - 1];
        match self {
            GenFn::Softmax => {
                // f'[b, j, b', j'] = δ_{bb'} (δ_{jj'} s_{bj} − s_{bj} s_{bj'})
                // with batch indices b and the softmax axis j.
                let s = g.gen_unary(GenFn::Softmax, a);
                // labels: batch = 0..r-1 (b), j = r-1, b' = r..2r-2, j' = 2r-2
                let b_l: Vec<u32> = (0..(r as u32 - 1)).collect();
                let j = r as u32 - 1;
                let bp_l: Vec<u32> = (r as u32..(2 * r as u32 - 1)).collect();
                let jp = 2 * r as u32 - 1;

                // term1[b, j, j'] = δ_{jj'} s_{bj}:  s *_( bj, j j', b j j' ) δ_n
                let dn = g.delta(&[n]);
                let mut s1: Vec<u32> = b_l.clone();
                s1.push(j);
                let s2 = vec![j, jp];
                let mut s3: Vec<u32> = b_l.clone();
                s3.push(j);
                s3.push(jp);
                let term1 = g.mul(s, dn, EinSpec::new(s1.clone(), s2, s3.clone()));

                // term2[b, j, j'] = s_{bj} s_{bj'}
                let mut s2b: Vec<u32> = b_l.clone();
                s2b.push(jp);
                let term2 = g.mul(s, s, EinSpec::new(s1.clone(), s2b, s3.clone()));
                let nt2 = g.elem(Elem::Neg, term2);
                let core = g.add(term1, nt2); // [batch, j, j']

                // expand with δ over the batch block: out[b, j, b', j'] =
                // core[b, j, j'] · δ_{b b'}
                if batch.is_empty() {
                    // domain is a vector: f' is already [j, j']
                    return core;
                }
                let db = g.delta(batch);
                // core labels: b j jp ; delta labels: b bp
                let mut cl: Vec<u32> = b_l.clone();
                cl.push(j);
                cl.push(jp);
                let mut dl: Vec<u32> = b_l.clone();
                dl.extend(&bp_l);
                // out: b j bp jp   (range ++ domain order)
                let mut ol: Vec<u32> = b_l.clone();
                ol.push(j);
                ol.extend(&bp_l);
                ol.push(jp);
                g.mul(core, db, EinSpec::new(cl, dl, ol))
            }
            GenFn::LogSumExp => {
                // f'[b, b', j'] = δ_{bb'} softmax(a)[b', j']
                let s = g.gen_unary(GenFn::Softmax, a);
                if batch.is_empty() {
                    // range is scalar: f' = softmax(a) of shape [j']
                    return s;
                }
                let db = g.delta(batch);
                let b_l: Vec<u32> = (0..(r as u32 - 1)).collect();
                let bp_l: Vec<u32> = (r as u32..(2 * r as u32 - 1)).collect();
                let jp = 2 * r as u32 - 1;
                let mut sl: Vec<u32> = bp_l.clone();
                sl.push(jp);
                let mut dl: Vec<u32> = b_l.clone();
                dl.extend(&bp_l);
                let mut ol: Vec<u32> = b_l.clone();
                ol.extend(&bp_l);
                ol.push(jp);
                g.mul(s, db, EinSpec::new(sl, dl, ol))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_scalar_values() {
        assert_eq!(Elem::Relu.apply(-2.0), 0.0);
        assert_eq!(Elem::Relu.apply(3.0), 3.0);
        assert_eq!(Elem::Step.apply(0.5), 1.0);
        assert_eq!(Elem::Step.apply(0.0), 0.0);
        assert!((Elem::Sigmoid.apply(0.0) - 0.5).abs() < 1e-15);
        assert_eq!(Elem::Neg.apply(4.0), -4.0);
        assert_eq!(Elem::Square.apply(3.0), 9.0);
        assert_eq!(Elem::Recip.apply(4.0), 0.25);
        assert_eq!(Elem::Sign.apply(-3.0), -1.0);
        assert_eq!(Elem::Abs.apply(-3.0), 3.0);
    }

    #[test]
    fn elem_derivative_numeric_fd() {
        // finite-difference check of every f' through the symbolic builder
        use crate::eval::{eval, Env};
        for f in [
            Elem::Exp,
            Elem::Log,
            Elem::Sigmoid,
            Elem::Tanh,
            Elem::Sqrt,
            Elem::Neg,
            Elem::Recip,
            Elem::Square,
        ] {
            let mut g = Graph::new();
            let x = g.var("x", &[4]);
            let d = f.derivative(&mut g, x);
            let xv = Tensor::new(&[4], vec![0.3, 0.7, 1.2, 2.5]); // positive domain
            let mut env = Env::new();
            env.insert("x", xv.clone());
            let dv = eval(&g, d, &env);
            let h = 1e-6;
            for i in 0..4 {
                let fd = (f.apply(xv.data()[i] + h) - f.apply(xv.data()[i] - h)) / (2.0 * h);
                assert!(
                    (dv.data()[i] - fd).abs() < 1e-5,
                    "{}' mismatch at {}: {} vs {}",
                    f.name(),
                    xv.data()[i],
                    dv.data()[i],
                    fd
                );
            }
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::randn(&[3, 5], 4);
        let s = GenFn::Softmax.eval(&t);
        for row in s.data().chunks(5) {
            let sum: f64 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
            assert!(row.iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn logsumexp_matches_naive() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., -1., 0., 1.]);
        let l = GenFn::LogSumExp.eval(&t);
        assert_eq!(l.shape(), &[2]);
        let naive0 = (1f64.exp() + 2f64.exp() + 3f64.exp()).ln();
        assert!((l.data()[0] - naive0).abs() < 1e-12);
    }

    #[test]
    fn logsumexp_stable_for_large_inputs() {
        let t = Tensor::new(&[1, 2], vec![1000.0, 1000.0]);
        let l = GenFn::LogSumExp.eval(&t);
        assert!((l.data()[0] - (1000.0 + 2f64.ln())).abs() < 1e-9);
    }

    #[test]
    fn range_shapes() {
        assert_eq!(GenFn::Softmax.range_shape(&[4, 7]), vec![4, 7]);
        assert_eq!(GenFn::LogSumExp.range_shape(&[4, 7]), vec![4]);
    }
}
