//! Pretty-printing of expression DAGs: infix rendering, program listings
//! and graphviz dumps (the paper's Figures 1, 4, 5 are such dumps).

use crate::ir::graph::{Graph, NodeId, Op};
use std::fmt::Write;

impl Graph {
    /// Render a node as an infix expression string (shared subexpressions
    /// are inlined — use [`Graph::program`] for the DAG view).
    pub fn render(&self, id: NodeId) -> String {
        match self.op(id) {
            Op::Var(name) => name.clone(),
            Op::Const(bits) => {
                let v = f64::from_bits(*bits);
                if self.shape(id).is_empty() {
                    format!("{}", v)
                } else {
                    format!("{}⟨{:?}⟩", v, self.shape(id))
                }
            }
            Op::Delta { dims } => format!("δ{:?}", dims),
            Op::Add(a, b) => format!("({} + {})", self.render(*a), self.render(*b)),
            Op::Mul(a, b, spec) => {
                format!("({} *[{}] {})", self.render(*a), spec, self.render(*b))
            }
            Op::Elem(f, a) => format!("{}({})", f.name(), self.render(*a)),
            Op::GenUnary(f, a) => format!("{}({})", f.name(), self.render(*a)),
        }
    }

    /// A three-address program listing of the sub-DAG below `roots` —
    /// one line per node, in evaluation order.
    pub fn program(&self, roots: &[NodeId]) -> String {
        let mut out = String::new();
        for id in self.topo(roots) {
            let rhs = match self.op(id) {
                Op::Var(name) => format!("var {}", name),
                Op::Const(bits) => format!("const {}", f64::from_bits(*bits)),
                Op::Delta { dims } => format!("delta {:?}", dims),
                Op::Add(a, b) => format!("add %{} %{}", a.0, b.0),
                Op::Mul(a, b, spec) => format!("mul[{}] %{} %{}", spec, a.0, b.0),
                Op::Elem(f, a) => format!("{} %{}", f.name(), a.0),
                Op::GenUnary(f, a) => format!("{} %{}", f.name(), a.0),
            };
            let _ = writeln!(out, "%{:<4} : {:<14} = {}", id.0, format!("{:?}", self.shape(id)), rhs);
        }
        out
    }

    /// Graphviz dot output for the sub-DAG below `roots`. Nodes whose value
    /// is an order ≥ 4 tensor are highlighted red, mirroring the paper's
    /// appendix figures.
    pub fn to_dot(&self, roots: &[NodeId]) -> String {
        let mut out = String::from("digraph expr {\n  rankdir=BT;\n");
        for id in self.topo(roots) {
            let label = match self.op(id) {
                Op::Var(name) => name.clone(),
                Op::Const(bits) => format!("{}", f64::from_bits(*bits)),
                Op::Delta { dims } => format!("δ{:?}", dims),
                Op::Add(..) => "+".into(),
                Op::Mul(_, _, spec) => format!("*[{}]", spec),
                Op::Elem(f, _) => f.name().into(),
                Op::GenUnary(f, _) => f.name().into(),
            };
            let color = if self.order(id) >= 4 { ", color=red, fontcolor=red" } else { "" };
            let _ = writeln!(
                out,
                "  n{} [label=\"{}\\n{:?}\"{}];",
                id.0,
                label.replace('"', "'"),
                self.shape(id),
                color
            );
            for c in self.children(id) {
                let _ = writeln!(out, "  n{} -> n{};", c.0, id.0);
            }
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Elem;

    /// Expression (1) from the paper:
    /// Xᵀ((exp(X·w)+1)⁻¹ ⊙ exp(X·w))
    fn paper_expr1(g: &mut Graph) -> NodeId {
        let x = g.var("X", &[4, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[4]);
        let e1 = g.add(e, one);
        let inv = g.elem(Elem::Recip, e1);
        let prod = g.hadamard(inv, e);
        g.tmatvec(x, prod)
    }

    #[test]
    fn render_expression_1() {
        let mut g = Graph::new();
        let y = paper_expr1(&mut g);
        let s = g.render(y);
        assert!(s.contains("exp"), "{}", s);
        assert!(s.contains("recip"), "{}", s);
        assert!(s.contains("X"), "{}", s);
    }

    #[test]
    fn program_lists_all_nodes_once() {
        let mut g = Graph::new();
        let y = paper_expr1(&mut g);
        let p = g.program(&[y]);
        // exp(X·w) is shared (CSE) — must appear exactly once
        let exp_lines = p.lines().filter(|l| l.contains("exp %")).count();
        assert_eq!(exp_lines, 1, "{}", p);
    }

    #[test]
    fn dot_marks_high_order_nodes() {
        let mut g = Graph::new();
        let d = g.delta(&[2, 3]); // order-4 tensor
        let dot = g.to_dot(&[d]);
        assert!(dot.contains("color=red"), "{}", dot);
    }
}
