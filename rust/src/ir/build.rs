//! Convenience builders for common linear-algebra shapes on top of the
//! generic multiplication — the vectorized column of Table 1.

use crate::einsum::{EinSpec, Label};
use crate::ir::elem::Elem;
use crate::ir::graph::{Graph, NodeId};

impl Graph {
    fn labels(&self, n: usize, base: Label) -> Vec<Label> {
        (base..base + n as Label).collect()
    }

    /// Matrix product `A·B` (`ij,jk->ik`).
    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.mul(a, b, EinSpec::parse("ij,jk->ik"))
    }

    /// Matrix–vector product `A·x` (`ij,j->i`).
    pub fn matvec(&mut self, a: NodeId, x: NodeId) -> NodeId {
        self.mul(a, x, EinSpec::parse("ij,j->i"))
    }

    /// Inner product `yᵀx` (`i,i->`).
    pub fn dot(&mut self, y: NodeId, x: NodeId) -> NodeId {
        self.mul(y, x, EinSpec::parse("i,i->"))
    }

    /// Outer product `y xᵀ` (`i,j->ij`).
    pub fn outer(&mut self, y: NodeId, x: NodeId) -> NodeId {
        self.mul(y, x, EinSpec::parse("i,j->ij"))
    }

    /// Element-wise (Hadamard) product of equally-shaped tensors.
    pub fn hadamard(&mut self, a: NodeId, b: NodeId) -> NodeId {
        assert_eq!(self.shape(a), self.shape(b), "hadamard shape mismatch");
        let l = self.labels(self.order(a), 0);
        self.mul(a, b, EinSpec::new(l.clone(), l.clone(), l))
    }

    /// `AᵀB` (`ji,jk->ik`).
    pub fn tmatmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.mul(a, b, EinSpec::parse("ji,jk->ik"))
    }

    /// `ABᵀ` (`ij,kj->ik`).
    pub fn matmul_t(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.mul(a, b, EinSpec::parse("ij,kj->ik"))
    }

    /// `Aᵀx` (`ji,j->i`).
    pub fn tmatvec(&mut self, a: NodeId, x: NodeId) -> NodeId {
        self.mul(a, x, EinSpec::parse("ji,j->i"))
    }

    /// Axis permutation expressed as `A *_(s1, ∅, perm(s1)) 1`.
    pub fn transpose(&mut self, a: NodeId, perm: &[usize]) -> NodeId {
        let l = self.labels(self.order(a), 0);
        let out: Vec<Label> = perm.iter().map(|&p| l[p]).collect();
        let one = self.scalar(1.0);
        self.mul(a, one, EinSpec::new(l, vec![], out))
    }

    /// Sum over all axes → scalar (`A *_(s1, ∅, ∅) 1`).
    pub fn sum_all(&mut self, a: NodeId) -> NodeId {
        let l = self.labels(self.order(a), 0);
        let one = self.scalar(1.0);
        self.mul(a, one, EinSpec::new(l, vec![], vec![]))
    }

    /// Sum over the given axes.
    pub fn sum_axes(&mut self, a: NodeId, axes: &[usize]) -> NodeId {
        let l = self.labels(self.order(a), 0);
        let keep: Vec<Label> = (0..self.order(a))
            .filter(|ax| !axes.contains(ax))
            .map(|ax| l[ax])
            .collect();
        let one = self.scalar(1.0);
        self.mul(a, one, EinSpec::new(l, vec![], keep))
    }

    /// Scale by a compile-time scalar constant.
    pub fn scale(&mut self, a: NodeId, c: f64) -> NodeId {
        let l = self.labels(self.order(a), 0);
        let k = self.scalar(c);
        self.mul(a, k, EinSpec::new(l.clone(), vec![], l))
    }

    /// `-a`.
    pub fn neg(&mut self, a: NodeId) -> NodeId {
        self.elem(Elem::Neg, a)
    }

    /// `a - b`.
    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let nb = self.neg(b);
        self.add(a, nb)
    }

    /// `A · diag(x)` — scale the columns of `A` by `x` (`ij,j->ij`).
    pub fn coldiag(&mut self, a: NodeId, x: NodeId) -> NodeId {
        self.mul(a, x, EinSpec::parse("ij,j->ij"))
    }

    /// `diag(x) · A` — scale the rows of `A` by `x` (`ij,i->ij`).
    pub fn rowdiag(&mut self, a: NodeId, x: NodeId) -> NodeId {
        self.mul(a, x, EinSpec::parse("ij,i->ij"))
    }

    /// Extract the main diagonal of a square matrix. Written with an
    /// explicit delta factor (`A *_(ij,ij,i) δ`) rather than a repeated
    /// operand label so the node stays differentiable under Theorem 8.
    pub fn diag_of(&mut self, a: NodeId) -> NodeId {
        let n = self.shape(a)[0];
        assert_eq!(self.shape(a), &[n, n], "diag_of needs a square matrix");
        let d = self.delta(&[n]);
        self.mul(a, d, EinSpec::parse("ij,ij->i"))
    }

    /// Squared Frobenius/Euclidean norm `‖A‖²`.
    pub fn norm2(&mut self, a: NodeId) -> NodeId {
        let sq = self.elem(Elem::Square, a);
        self.sum_all(sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval, Env};
    use crate::tensor::Tensor;

    fn env2() -> (Env, Tensor, Tensor) {
        let a = Tensor::randn(&[3, 4], 1);
        let b = Tensor::randn(&[4, 5], 2);
        let mut env = Env::new();
        env.insert("A", a.clone());
        env.insert("B", b.clone());
        (env, a, b)
    }

    #[test]
    fn matmul_builder() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.matmul(a, b);
        let (env, av, bv) = env2();
        let cv = eval(&g, c, &env);
        // spot check one entry
        let want: f64 = (0..4).map(|k| av.at(&[1, k]) * bv.at(&[k, 2])).sum();
        assert!((cv.at(&[1, 2]) - want).abs() < 1e-12);
    }

    #[test]
    fn transpose_builder() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let t = g.transpose(a, &[1, 0]);
        assert_eq!(g.shape(t), &[4, 3]);
        let (env, av, _) = env2();
        let tv = eval(&g, t, &env);
        assert_eq!(tv, av.t());
    }

    #[test]
    fn sum_builders() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let s = g.sum_all(a);
        let rows = g.sum_axes(a, &[1]);
        assert_eq!(g.shape(s), &[] as &[usize]);
        assert_eq!(g.shape(rows), &[3]);
        let (env, av, _) = env2();
        assert!((eval(&g, s, &env).item() - av.sum_all()).abs() < 1e-12);
    }

    #[test]
    fn diag_of_square() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 3]);
        let d = g.diag_of(a);
        let mut env = Env::new();
        let av = Tensor::randn(&[3, 3], 3);
        env.insert("A", av.clone());
        let dv = eval(&g, d, &env);
        for i in 0..3 {
            assert_eq!(dv.data()[i], av.at(&[i, i]));
        }
    }

    #[test]
    fn norm2_matches_tensor_norm() {
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let n = g.norm2(a);
        let mut env = Env::new();
        let av = Tensor::randn(&[4, 4], 9);
        env.insert("A", av.clone());
        assert!((eval(&g, n, &env).item() - av.norm().powi(2)).abs() < 1e-10);
    }

    #[test]
    fn sub_and_scale() {
        let mut g = Graph::new();
        let a = g.var("A", &[2]);
        let b = g.var("B", &[2]);
        let d = g.sub(a, b);
        let s = g.scale(d, 3.0);
        let mut env = Env::new();
        env.insert("A", Tensor::new(&[2], vec![5.0, 1.0]));
        env.insert("B", Tensor::new(&[2], vec![2.0, 4.0]));
        assert_eq!(eval(&g, s, &env).data(), &[9.0, -9.0]);
    }
}
