//! The comparator the paper benchmarks against: standard deep-learning
//! frameworks (TensorFlow, PyTorch, autograd, JAX) compute the derivative
//! of a non-scalar function "for each entry of the output function
//! separately" (§1, §4 — the Pearlmutter [10] strategy). For Hessians
//! this means one full reverse sweep per gradient entry, which is the
//! source of the 2–3 orders-of-magnitude gap in Figure 3.

use crate::autodiff::reverse::reverse_gradient;
use crate::eval::Env;
use crate::exec::CompiledPlan;
use crate::ir::{Graph, NodeId, Op};
use crate::simplify::simplify_one;
use crate::tensor::Tensor;

/// A prepared per-entry Hessian evaluator: one scalar-seeded reverse-mode
/// row expression, evaluated once per gradient entry with a basis vector
/// bound — exactly the framework strategy. The row runs on the same
/// compiled executor as the "ours" modes, so the Figure-3 gap measures
/// the *algorithmic* difference (N sweeps vs one), not executor overhead.
pub struct PerEntryHessian {
    row_plan: CompiledPlan,
    row_node: NodeId,
    basis_name: String,
    x_shape: Vec<usize>,
}

impl PerEntryHessian {
    /// Build the row expression `∂(eᵀ·grad)/∂x` for a scalar loss.
    pub fn new(g: &mut Graph, loss: NodeId, x: NodeId) -> Self {
        assert!(g.shape(loss).is_empty());
        let x_shape = g.shape(x).to_vec();
        let grad = reverse_gradient(g, loss, x);
        let grad = simplify_one(g, grad);
        // scalar projection against a (runtime) basis tensor
        let basis_name = "__basis".to_string();
        let e = g.var(&basis_name, &x_shape);
        let p = g.hadamard(grad, e);
        let gi = g.sum_all(p);
        let row = reverse_gradient(g, gi, x);
        let row = simplify_one(g, row);
        let row_plan = CompiledPlan::new(g, &[row]);
        PerEntryHessian { row_plan, row_node: row, basis_name, x_shape }
    }

    /// Evaluate the full Hessian: `Π shape(x)` reverse sweeps. The graph
    /// argument is kept for API stability; the compiled row plan is
    /// self-contained.
    pub fn eval(&self, _g: &Graph, env: &Env) -> Tensor {
        let n: usize = self.x_shape.iter().product();
        let mut h_shape = self.x_shape.clone();
        h_shape.extend(&self.x_shape);
        let mut h = Tensor::zeros(&h_shape);
        let mut env = env.clone();
        let mut basis = Tensor::zeros(&self.x_shape);
        for i in 0..n {
            basis.data_mut()[i] = 1.0;
            env.insert(&self.basis_name, basis.clone());
            let row = self.row_plan.run(&env).pop().unwrap();
            h.data_mut()[i * n..(i + 1) * n].copy_from_slice(row.data());
            basis.data_mut()[i] = 0.0;
        }
        h
    }

    /// Number of reverse sweeps one Hessian evaluation costs.
    pub fn sweeps(&self) -> usize {
        self.x_shape.iter().product()
    }

    pub fn row_node(&self) -> NodeId {
        self.row_node
    }
}

/// Left-to-right (pure reverse-mode-order) evaluation baseline for the
/// cross-country ablation: the Hessian expression *without* the
/// re-association pass, i.e. exactly what `Workload::hessian` returns.
/// Provided as a named function for the bench tables.
pub fn reverse_mode_hessian(g: &mut Graph, loss: NodeId, x: NodeId) -> NodeId {
    crate::autodiff::hessian::hessian(g, loss, x)
}

/// Count framework-visible "ops" (nodes) of a DAG — used in reports to
/// contrast expression sizes between modes.
pub fn op_count(g: &Graph, root: NodeId) -> (usize, usize) {
    let nodes = g.topo(&[root]);
    let muls = nodes
        .iter()
        .filter(|&&n| matches!(g.op(n), Op::Mul(..)))
        .count();
    (nodes.len(), muls)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval;
    use crate::problems::logistic_regression;

    #[test]
    fn per_entry_hessian_matches_symbolic() {
        let mut w = logistic_regression(10, 4);
        let h_node = w.hessian();
        let want = eval(&w.g, h_node, &w.env);
        let pe = PerEntryHessian::new(&mut w.g, w.loss, w.wrt);
        assert_eq!(pe.sweeps(), 4);
        let got = pe.eval(&w.g, &w.env);
        assert!(
            got.allclose(&want, 1e-8, 1e-10),
            "per-entry disagrees, diff {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn per_entry_on_matrix_variable() {
        use crate::problems::matrix_factorization;
        let mut w = matrix_factorization(5, 5, 2, false);
        let h_node = w.hessian();
        let want = eval(&w.g, h_node, &w.env);
        let pe = PerEntryHessian::new(&mut w.g, w.loss, w.wrt);
        assert_eq!(pe.sweeps(), 10);
        let got = pe.eval(&w.g, &w.env);
        assert!(got.allclose(&want, 1e-8, 1e-10));
    }

    #[test]
    fn op_count_reports() {
        let mut w = logistic_regression(6, 3);
        let h = w.hessian();
        let (nodes, muls) = op_count(&w.g, h);
        assert!(nodes > 0 && muls > 0 && muls < nodes);
    }
}
