//! The derivative-evaluation service: a request router + per-entry
//! worker with bounded queues (backpressure), serving two backends —
//! the symbolic engine (expression DAG + [`CompiledPlan`]) and the PJRT
//! executables loaded by [`crate::runtime`].
//!
//! The paper's contribution is the calculus itself, so this layer is a
//! thin-but-real coordinator: the end-to-end example and `tensorcalc
//! serve` drive batched gradient/Hessian requests through it and report
//! throughput/latency.
//!
//! ## Dynamic request batching
//!
//! An engine worker drains everything already queued for its entry and
//! runs the drained eval jobs as *one* batched execution: inputs are
//! stacked along a new leading batch axis and a batched variant of the
//! plan — derived by [`crate::exec::batch_graph`] from the same
//! canonical graph, compiled lazily per batch bucket through the global
//! [`PlanCache`](crate::exec::PlanCache) — runs once. Batch sizes are
//! bucketed to powers of two (capped by
//! [`EngineEntry::with_max_batch`]); a partial bucket is padded with
//! copies of the first request, whose slots are computed and discarded —
//! the batch axis is never contracted, so pad slots cannot perturb live
//! ones. Root outputs come back as [`PlanOutput`] views into the leased
//! run arena (zero-copy; see [`CompiledPlan::run_leased`]) and are split
//! per request by pointer arithmetic on the shared lease.
//!
//! The rewrite is bit-identity-preserving: slice `b` of a batched run is
//! computed by the same floating-point operations, in the same order, as
//! request `b` run alone (pinned in `tests/serve_batch.rs` and the
//! module tests below). `with_max_batch(1)` turns batching off and is
//! kept as the ablation axis for `benches/serve_load.rs`.
//!
//! ## Serving robustness
//!
//! The request path is specified end to end (see ARCHITECTURE.md,
//! "Serving robustness"):
//!
//! * **Admission** — [`Coordinator::submit_with`] takes a [`Request`]
//!   with an optional deadline and returns a typed [`SubmitError`]
//!   (`QueueFull` is the only retryable variant). Already-expired
//!   deadlines are rejected before touching the queue. Full queues obey
//!   the entry's [`ShedPolicy`]: reject, evict-oldest (the victim is
//!   answered [`ServeError::Shed`]), or block with a timeout.
//! * **Drain** — the worker answers expired jobs `Err(Expired)` before
//!   any compile/exec work, orders the remainder nearest-deadline-first
//!   (stable, so undeadlined traffic stays FIFO), and re-checks expiry
//!   between chunks of one drain.
//! * **Degradation** — a per-worker [`DegradeLadder`] watches drain
//!   sizes; under sustained overload it first restricts chunks to
//!   already-compiled exact-fit buckets (no padding, no serving-path
//!   compiles), then to the base plan. Degraded outputs are
//!   bit-identical to normal ones — the ladder changes scheduling,
//!   never numerics.
//! * **Accounting** — every shed, expiry, rejection and degraded run is
//!   counted ([`Metrics`], exported via Prometheus), and the balance
//!   `submitted == completed + errors + shed + expired` holds under
//!   every fault mix — pinned by `tests/chaos.rs` against the seeded
//!   [`FaultPlan`] injector (env `TC_FAULT`).
//! * **Shutdown** — [`JobQueue::close`] is the deterministic signal:
//!   it cannot be lost to a full queue (the old `try_send(Shutdown)`
//!   nudge could), and jobs accepted before the close are still drained
//!   and answered.

mod degrade;
mod fault;
mod metrics;
mod queue;

pub use degrade::{DegradeLadder, MAX_DEGRADE_LEVEL};
pub use fault::{FaultPlan, FaultSite};
pub use metrics::{Metrics, Outcome, Snapshot};
pub use queue::ShedPolicy;

use crate::anyhow;
use crate::error::Result;
use crate::eval::Env;
use crate::exec::{batch_graph, global_plan_cache, BackendKind, CompiledPlan, ExecMemory, PlanOutput};
use crate::ir::{Graph, NodeId};
use crate::obs::TraceMode;
use crate::opt::{OptLevel, OptStats};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use queue::{JobQueue, PushOutcome};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Largest micro-batch an entry fuses into one run unless overridden:
/// high enough to amortise per-request dispatch under load, low enough
/// that a power-of-two bucket pads at most one doubling.
pub const DEFAULT_MAX_BATCH: usize = 8;

/// Why [`Coordinator::submit`] / [`Coordinator::submit_with`] refused a
/// request at admission. Typed so callers can tell retryable congestion
/// from permanent conditions — the old stringly
/// `anyhow!("queue full / closed for {}")` conflated all four.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The entry's queue is at capacity under [`ShedPolicy::Reject`]
    /// (or a [`ShedPolicy::Block`] timed out). Retryable: back off and
    /// resubmit.
    QueueFull { entry: String },
    /// No entry registered under this name.
    UnknownEntry { entry: String },
    /// The entry's worker is shutting down; its queue takes no new work.
    Closed { entry: String },
    /// The request's deadline had already passed at submit time —
    /// refused before it could waste queue space.
    Expired { entry: String },
}

impl SubmitError {
    /// Whether resubmitting the same request can succeed. Only
    /// [`SubmitError::QueueFull`] is transient.
    pub fn is_retryable(&self) -> bool {
        matches!(self, SubmitError::QueueFull { .. })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { entry } => write!(f, "queue full for {}", entry),
            SubmitError::UnknownEntry { entry } => write!(f, "unknown entry {}", entry),
            SubmitError::Closed { entry } => write!(f, "entry {} is shutting down", entry),
            SubmitError::Expired { entry } => {
                write!(f, "deadline already expired at submit for {}", entry)
            }
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<SubmitError> for crate::error::Error {
    fn from(e: SubmitError) -> Self {
        crate::error::Error::msg(e.to_string())
    }
}

/// Why an *admitted* request was answered with an error. This is the
/// `Err` side of the reply channel ([`ServeResult`]); admission-time
/// refusals are [`SubmitError`] instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The deadline passed while the request waited in the queue or
    /// between chunks of a drain — answered before any exec work.
    Expired,
    /// Evicted by a newer request under [`ShedPolicy::ShedOldest`].
    Shed,
    /// Plan execution panicked (caught; the worker survives).
    Panic(String),
    /// The request failed input validation (arity/shape mismatch).
    Invalid(String),
    /// The backend reported an execution error.
    Exec(String),
}

impl ServeError {
    /// Whether resubmitting the same request can succeed. Sheds and
    /// transient execution failures are retryable; an expired deadline
    /// or malformed request is not.
    pub fn is_retryable(&self) -> bool {
        matches!(self, ServeError::Shed | ServeError::Panic(_) | ServeError::Exec(_))
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Expired => write!(f, "deadline expired before execution"),
            ServeError::Shed => write!(f, "shed under overload (oldest-first eviction)"),
            ServeError::Panic(m) => write!(f, "plan execution panicked: {}", m),
            ServeError::Invalid(m) => write!(f, "invalid request: {}", m),
            ServeError::Exec(m) => write!(f, "execution failed: {}", m),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<ServeError> for crate::error::Error {
    fn from(e: ServeError) -> Self {
        crate::error::Error::msg(e.to_string())
    }
}

/// What a reply channel carries: the response, or a typed serving
/// error.
pub type ServeResult = std::result::Result<Response, ServeError>;

/// One submission: inputs plus an optional deadline. Deadlines are
/// monotonic [`Instant`]s, never wall clock — a host clock step cannot
/// expire (or resurrect) queued work.
#[derive(Debug)]
pub struct Request {
    pub inputs: Vec<Tensor>,
    pub deadline: Option<Instant>,
}

impl Request {
    pub fn new(inputs: Vec<Tensor>) -> Self {
        Request { inputs, deadline: None }
    }

    /// Deadline as a budget from now.
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(Instant::now() + budget);
        self
    }

    /// Deadline as an absolute instant (for callers propagating an
    /// upstream deadline).
    pub fn with_deadline_at(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }
}

/// An engine-backed entry: a *compiled* plan (planned arena, level-
/// parallel execution — see [`crate::exec`]) plus a fixed input
/// signature. The entry retains the canonical (optimized + compacted)
/// graph it was compiled from, so batched variants can be derived from
/// the exact structure the base plan executes — that is what makes the
/// batched path bit-identical per slice. All plans come from the global
/// plan cache: re-registering the same graph (the repeated-request hot
/// path) reuses the compiled artifact and its warm run states.
pub struct EngineEntry {
    pub plan: Arc<CompiledPlan>,
    /// variable names in submission order, with expected shapes
    pub inputs: Vec<(String, Vec<usize>)>,
    /// the graph `plan` was compiled from (canonical unless the entry
    /// was built at `OptLevel::None`), retained for batched variants
    graph: Graph,
    roots: Vec<NodeId>,
    memory: ExecMemory,
    /// which executor serves this entry (per-entry backend choice)
    backend: BackendKind,
    /// largest micro-batch fused into one run; 1 = batching off (the
    /// ablation baseline)
    max_batch: usize,
    /// lazily compiled batched variants, one per batch bucket
    batched: HashMap<usize, Arc<CompiledPlan>>,
    /// batch-bucket plans compiled on the serving path (i.e. *not*
    /// prewarmed) — [`EngineEntry::with_prewarm`] exists to pin this at
    /// zero in steady state
    lazy_compiles: Arc<AtomicU64>,
    /// batch-bucket plans compiled at registration time by
    /// [`EngineEntry::with_prewarm`]
    prewarm_compiles: Arc<AtomicU64>,
    /// what the optimizer did to this entry's graph before it was
    /// frozen (None when built at `OptLevel::None`); surfaced through
    /// [`Coordinator::stats`]
    opt_stats: Option<OptStats>,
    /// what `submit` does when this entry's queue is full
    policy: ShedPolicy,
    /// pin the degradation ladder at a fixed level (test / ops API);
    /// None = let the ladder drive
    forced_degrade: Option<u8>,
    /// the worker's current ladder level, exported as the
    /// `tensorcalc_degrade_level` gauge
    degrade_level: Arc<AtomicU64>,
}

impl EngineEntry {
    /// Compile `roots` of `graph` (through the global plan cache) into a
    /// servable entry at the default optimizer level and memory
    /// discipline (planned arena).
    pub fn compiled(
        graph: &Graph,
        roots: &[NodeId],
        inputs: Vec<(String, Vec<usize>)>,
    ) -> Self {
        Self::compiled_with(
            graph,
            roots,
            inputs,
            OptLevel::default(),
            ExecMemory::default(),
            BackendKind::default(),
        )
    }

    /// [`EngineEntry::compiled`] with the optimizer level, executor
    /// memory discipline and execution backend explicit — the
    /// coordinator-side end of the `ExecMemory` / `BackendKind`
    /// ablations. All entries share the process-wide persistent worker
    /// pool regardless of mode, so the level scheduler of repeated
    /// request bursts spawns no threads.
    pub fn compiled_with(
        graph: &Graph,
        roots: &[NodeId],
        inputs: Vec<(String, Vec<usize>)>,
        level: OptLevel,
        memory: ExecMemory,
        backend: BackendKind,
    ) -> Self {
        // canonicalise once here, then compile at OptLevel::None: the
        // cache keys `None` by the fingerprint of the graph as given,
        // which for the canonical graph is exactly the key the ordinary
        // optimized path uses — same key, same shared Arc. Batched
        // variants then derive from this frozen structure instead of
        // re-running the optimizer (whose cost model could reassociate
        // the batched contractions differently and break bit-identity).
        let (graph, roots, opt_stats) = if level == OptLevel::None {
            (graph.clone(), roots.to_vec(), None)
        } else {
            let mut g2 = graph.clone();
            let o = crate::opt::optimize(&mut g2, roots, level);
            let (gc, croots) = crate::opt::compact(&g2, &o.roots);
            (gc, croots, Some(o.stats))
        };
        let plan = global_plan_cache().get_or_compile_opts(
            &graph,
            &roots,
            OptLevel::None,
            memory,
            backend,
            TraceMode::Off,
        );
        EngineEntry {
            plan,
            inputs,
            graph,
            roots,
            memory,
            backend,
            max_batch: DEFAULT_MAX_BATCH,
            batched: HashMap::new(),
            lazy_compiles: Arc::new(AtomicU64::new(0)),
            prewarm_compiles: Arc::new(AtomicU64::new(0)),
            opt_stats,
            policy: ShedPolicy::default(),
            forced_degrade: None,
            degrade_level: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Cap the dynamic batch size (1 disables batching — the ablation
    /// baseline served next to the batched entry in `serve_load`).
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Set the full-queue policy for this entry's submissions
    /// (default: [`ShedPolicy::Reject`]).
    pub fn with_shed_policy(mut self, policy: ShedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// The full-queue policy in force for this entry.
    pub fn shed_policy(&self) -> ShedPolicy {
        self.policy
    }

    /// Pin the degradation ladder at a fixed level (clamped to
    /// [`MAX_DEGRADE_LEVEL`]) instead of letting drain pressure drive
    /// it — the test/ops hook that makes the degraded paths
    /// deterministically reachable.
    pub fn with_forced_degrade_level(mut self, level: u8) -> Self {
        self.forced_degrade = Some(level.min(MAX_DEGRADE_LEVEL));
        self
    }

    /// Eagerly compile every batch-bucket variant this entry can reach
    /// (the power-of-two buckets up to `max_batch` — exactly the set
    /// [`run_chunk`] computes), so the serving path never compiles: the
    /// first burst after registration pays zero compile latency, and
    /// [`EngineEntry::lazy_compile_counter`] stays at zero. Apply
    /// *after* [`EngineEntry::with_max_batch`] — prewarming covers the
    /// bucket set of the cap in force when it runs.
    pub fn with_prewarm(mut self, prewarm: bool) -> Self {
        if prewarm {
            for n in 2..=self.max_batch {
                let bucket = n.next_power_of_two().min(self.max_batch).max(n);
                if !self.batched.contains_key(&bucket) {
                    let (bg, broots) = batch_graph(&self.graph, &self.roots, bucket);
                    let plan = global_plan_cache().get_or_compile_opts(
                        &bg,
                        &broots,
                        OptLevel::None,
                        self.memory,
                        self.backend,
                        TraceMode::Off,
                    );
                    self.prewarm_compiles.fetch_add(1, Ordering::Relaxed);
                    self.batched.insert(bucket, plan);
                }
            }
        }
        self
    }

    /// Handle on the lazy-compile counter: how many batch-bucket plans
    /// were compiled on the serving path instead of at registration.
    /// With [`EngineEntry::with_prewarm`] this must stay zero in steady
    /// state (asserted in the module tests). The handle survives the
    /// entry moving into its worker thread.
    pub fn lazy_compile_counter(&self) -> Arc<AtomicU64> {
        self.lazy_compiles.clone()
    }

    /// Handle on the prewarm-compile counter: how many batch-bucket
    /// plans [`EngineEntry::with_prewarm`] compiled at registration.
    pub fn prewarm_compile_counter(&self) -> Arc<AtomicU64> {
        self.prewarm_compiles.clone()
    }

    /// What the optimizer did to this entry's graph before compilation
    /// (None when the entry was built at `OptLevel::None`).
    pub fn opt_stats(&self) -> Option<OptStats> {
        self.opt_stats
    }

    /// The batch buckets with a compiled plan right now, ascending.
    pub fn compiled_buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.batched.keys().copied().collect();
        b.sort_unstable();
        b
    }

    /// The plan for one batch bucket, compiled on first use through the
    /// global cache (key: fingerprint of the batched graph, which covers
    /// the bucket size via the variables' leading axis).
    fn batched_plan(&mut self, bucket: usize) -> Arc<CompiledPlan> {
        if bucket <= 1 {
            return self.plan.clone();
        }
        if let Some(p) = self.batched.get(&bucket) {
            return p.clone();
        }
        self.lazy_compiles.fetch_add(1, Ordering::Relaxed);
        let (bg, broots) = batch_graph(&self.graph, &self.roots, bucket);
        let plan = global_plan_cache().get_or_compile_opts(
            &bg,
            &broots,
            OptLevel::None,
            self.memory,
            self.backend,
            TraceMode::Off,
        );
        self.batched.insert(bucket, plan.clone());
        plan
    }

    /// Chunk size under degradation. Level ≥ 2 serves the base plan
    /// only; level 1 snaps to the largest *already-compiled* bucket
    /// that fits exactly (no pad slots computed, no serving-path
    /// compiles), falling back to the base plan when none fits.
    fn degraded_chunk(&self, pending: usize, level: u8) -> usize {
        if level >= 2 {
            return 1;
        }
        let cap = pending.min(self.max_batch.max(1));
        let mut best = 1;
        for &b in self.batched.keys() {
            if b <= cap && b > best {
                best = b;
            }
        }
        best
    }
}

/// One accepted request as it sits in an entry's [`JobQueue`].
struct QueuedJob {
    inputs: Vec<Tensor>,
    reply: SyncSender<ServeResult>,
    /// stamped in [`Coordinator::submit_with`]: queue wait is measured
    /// from here to the worker's drain, so `Response.latency` is the
    /// end-to-end time the caller experienced, not just the plan
    /// execution
    enqueued: Instant,
    deadline: Option<Instant>,
}

impl QueuedJob {
    fn expired_at(&self, now: Instant) -> bool {
        self.deadline.map(|d| d <= now).unwrap_or(false)
    }
}

/// A completed evaluation. `outputs` are [`PlanOutput`]s: for engine
/// entries they are zero-copy views into the plan's leased run arena
/// (the arena returns to its pool when the last view drops); call
/// [`PlanOutput::to_tensor`] to materialise an owned copy.
#[derive(Debug)]
pub struct Response {
    pub outputs: Vec<PlanOutput>,
    /// end-to-end latency the caller experienced:
    /// `queue_secs + service_secs`
    pub latency: f64,
    /// time the request waited in the worker queue (enqueue → drain)
    pub queue_secs: f64,
    /// time the (possibly batched) plan execution took (drain → reply)
    pub service_secs: f64,
    /// how many requests the worker drained in the same batch
    pub batch_size: usize,
}

struct Worker {
    queue: Arc<JobQueue<QueuedJob>>,
    policy: ShedPolicy,
    handle: Option<JoinHandle<()>>,
}

/// Compile-time facts about one registered engine entry, kept on the
/// coordinator after the entry itself moves into its worker thread.
struct EntryInfo {
    opt_stats: Option<OptStats>,
    max_batch: usize,
    prewarmed_buckets: Vec<usize>,
    lazy_compiles: Arc<AtomicU64>,
    prewarm_compiles: Arc<AtomicU64>,
}

/// One entry's row in [`Coordinator::stats`]: the optimizer report its
/// graph was compiled under plus the batched-plan compile counters.
#[derive(Debug, Clone)]
pub struct EntryStats {
    pub name: String,
    /// what the optimizer did before the graph was frozen (None for
    /// entries built at `OptLevel::None`)
    pub opt_stats: Option<OptStats>,
    pub max_batch: usize,
    /// batch buckets compiled at registration by `with_prewarm`
    pub prewarmed_buckets: Vec<usize>,
    /// batch-bucket plans compiled lazily on the serving path
    pub lazy_compiles: u64,
    /// batch-bucket plans compiled eagerly at registration
    pub prewarm_compiles: u64,
}

/// The coordinator: one worker thread per registered entry, bounded
/// queues, shared metrics, one process-wide fault plan (off by default,
/// seeded via `TC_FAULT` or [`Coordinator::with_faults`]).
pub struct Coordinator {
    workers: HashMap<String, Worker>,
    infos: HashMap<String, EntryInfo>,
    metrics: Arc<Metrics>,
    queue_cap: usize,
    faults: Arc<FaultPlan>,
}

impl Coordinator {
    pub fn new(queue_cap: usize) -> Self {
        Self::with_faults(queue_cap, FaultPlan::from_env().unwrap_or_else(FaultPlan::none))
    }

    /// A coordinator with an explicit fault plan — the chaos-test entry
    /// point ([`FaultPlan::none`] for production behavior).
    pub fn with_faults(queue_cap: usize, faults: FaultPlan) -> Self {
        Coordinator {
            workers: HashMap::new(),
            infos: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            queue_cap,
            faults: Arc::new(faults),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Register an engine-backed entry (symbolic expression evaluation).
    /// Re-registering a name replaces the entry: the old worker is shut
    /// down and joined before this returns, so every job it had already
    /// accepted is answered and its thread is reaped (not leaked).
    ///
    /// Registration also wires the entry's compile counters, its
    /// plan's run-state recycling, and its current degradation level
    /// into the metrics gauge surface, so `Metrics::render_prometheus`
    /// exposes them without the worker's involvement.
    pub fn register_engine(&mut self, name: &str, entry: EngineEntry) {
        let info = EntryInfo {
            opt_stats: entry.opt_stats,
            max_batch: entry.max_batch,
            prewarmed_buckets: entry.compiled_buckets(),
            lazy_compiles: entry.lazy_compiles.clone(),
            prewarm_compiles: entry.prewarm_compiles.clone(),
        };
        let labels = format!("entry=\"{}\"", name);
        let lazy = info.lazy_compiles.clone();
        self.metrics.register_gauge("tensorcalc_lazy_compiles", &labels, move || {
            lazy.load(Ordering::Relaxed) as f64
        });
        let prewarmed = info.prewarm_compiles.clone();
        self.metrics.register_gauge("tensorcalc_prewarm_compiles", &labels, move || {
            prewarmed.load(Ordering::Relaxed) as f64
        });
        let plan = entry.plan.clone();
        self.metrics.register_gauge("tensorcalc_lease_state_reuse", &labels, move || {
            plan.pool_stats().state_reuse as f64
        });
        let dlevel = entry.degrade_level.clone();
        self.metrics.register_gauge("tensorcalc_degrade_level", &labels, move || {
            dlevel.load(Ordering::Relaxed) as f64
        });
        self.infos.insert(name.to_string(), info);
        let policy = entry.policy;
        let queue = Arc::new(JobQueue::new(self.queue_cap));
        let metrics = self.metrics.clone();
        let faults = self.faults.clone();
        let ename = name.to_string();
        let q2 = queue.clone();
        let handle = std::thread::spawn(move || {
            engine_worker(ename, entry, q2, metrics, faults);
        });
        self.insert_worker(name.to_string(), Worker { queue, policy, handle: Some(handle) });
    }

    /// Per-entry compile/optimizer statistics, sorted by entry name.
    /// Covers engine entries only (PJRT entries have no optimizer run
    /// or batched variants to report).
    pub fn stats(&self) -> Vec<EntryStats> {
        let mut v: Vec<EntryStats> = self
            .infos
            .iter()
            .map(|(name, i)| EntryStats {
                name: name.clone(),
                opt_stats: i.opt_stats,
                max_batch: i.max_batch,
                prewarmed_buckets: i.prewarmed_buckets.clone(),
                lazy_compiles: i.lazy_compiles.load(Ordering::Relaxed),
                prewarm_compiles: i.prewarm_compiles.load(Ordering::Relaxed),
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Install a worker under `name`, shutting down and joining any
    /// worker previously registered there (the duplicate-registration
    /// leak fix: dropping the old `Worker` silently detached its
    /// thread — handle never joined, in-flight work unobservable).
    fn insert_worker(&mut self, name: String, worker: Worker) {
        if let Some(old) = self.workers.insert(name, worker) {
            Self::stop_worker(old);
        }
    }

    /// Shut down one worker and join its thread. [`JobQueue::close`] is
    /// the deterministic signal: it wakes the worker unconditionally
    /// (a full queue cannot swallow it, unlike the old best-effort
    /// `try_send(Job::Shutdown)` nudge), and the worker still drains
    /// and answers every job accepted before the close.
    fn stop_worker(mut w: Worker) {
        w.queue.close();
        if let Some(h) = w.handle.take() {
            let _ = h.join();
        }
    }

    /// Register every listed artifact under `dir` as a PJRT-backed
    /// entry. PJRT handles are not `Send`, so the backend worker thread
    /// opens the [`Runtime`] itself and routes jobs by entry name; an
    /// open failure is reported back through this call.
    pub fn register_runtime(
        &mut self,
        dir: std::path::PathBuf,
        names: &[String],
    ) -> Result<()> {
        let (tx, rx) = sync_channel::<(String, QueuedJob)>(self.queue_cap);
        let metrics = self.metrics.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let backend = std::thread::spawn(move || {
            let runtime = match Runtime::open(&dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            pjrt_worker(runtime, rx, metrics);
        });
        ready_rx.recv().map_err(|_| anyhow!("pjrt backend died"))??;
        for name in names {
            let fq = Arc::new(JobQueue::<QueuedJob>::new(self.queue_cap));
            let fq2 = fq.clone();
            let tx2 = tx.clone();
            let n2 = name.clone();
            let fmetrics = self.metrics.clone();
            let fh = std::thread::spawn(move || loop {
                let (jobs, closed) = fq2.drain_wait();
                let mut jobs = jobs.into_iter();
                let mut backend_gone = false;
                for job in &mut jobs {
                    if let Err(e) = tx2.send((n2.clone(), job)) {
                        let (_, job) = e.0;
                        answer_backend_gone(&fmetrics, &n2, job);
                        backend_gone = true;
                        break;
                    }
                }
                if backend_gone {
                    for job in jobs {
                        answer_backend_gone(&fmetrics, &n2, job);
                    }
                    return;
                }
                if closed {
                    return;
                }
            });
            self.insert_worker(
                name.clone(),
                Worker { queue: fq, policy: ShedPolicy::Reject, handle: Some(fh) },
            );
        }
        // shutdown guard: when its queue closes it drops the last fan-in
        // sender, which (after every forwarder has exited and dropped
        // its clone) disconnects the backend's receiver and stops it
        let gq = Arc::new(JobQueue::<QueuedJob>::new(1));
        let gq2 = gq.clone();
        let gh = std::thread::spawn(move || {
            let _ = gq2.drain_wait();
            drop(tx);
            let _ = backend.join();
        });
        self.insert_worker(
            "__pjrt_backend".into(),
            Worker { queue: gq, policy: ShedPolicy::Reject, handle: Some(gh) },
        );
        Ok(())
    }

    /// Submit asynchronously with no deadline; returns a receiver for
    /// the [`ServeResult`]. See [`Coordinator::submit_with`].
    pub fn submit(
        &self,
        entry: &str,
        inputs: Vec<Tensor>,
    ) -> std::result::Result<Receiver<ServeResult>, SubmitError> {
        self.submit_with(entry, Request::new(inputs))
    }

    /// Admission control: refuse unknown entries, already-expired
    /// deadlines, and (per the entry's [`ShedPolicy`]) full queues —
    /// each with a typed [`SubmitError`]. Under
    /// [`ShedPolicy::ShedOldest`] the submission is accepted by
    /// evicting the oldest queued job, which is answered
    /// `Err(ServeError::Shed)` here, on the submitter's thread.
    pub fn submit_with(
        &self,
        entry: &str,
        req: Request,
    ) -> std::result::Result<Receiver<ServeResult>, SubmitError> {
        let w = self
            .workers
            .get(entry)
            .ok_or_else(|| SubmitError::UnknownEntry { entry: entry.to_string() })?;
        if let Some(d) = req.deadline {
            // monotonic: Instant::now() never runs backwards, so a
            // deadline observed expired here stays expired
            if d <= Instant::now() {
                self.metrics.rejected_expired();
                return Err(SubmitError::Expired { entry: entry.to_string() });
            }
        }
        if self.faults.fire(FaultSite::QueueFull) {
            self.metrics.rejected_queue_full();
            return Err(SubmitError::QueueFull { entry: entry.to_string() });
        }
        let (rtx, rrx) = sync_channel(1);
        let job = QueuedJob {
            inputs: req.inputs,
            reply: rtx,
            enqueued: Instant::now(),
            deadline: req.deadline,
        };
        match w.queue.push(job, w.policy) {
            PushOutcome::Accepted => {
                self.metrics.submitted();
                self.metrics.enqueued();
                Ok(rrx)
            }
            PushOutcome::AcceptedShed(victim) => {
                self.metrics.submitted();
                self.metrics.enqueued();
                // the victim was admitted earlier (counted then); close
                // out its accounting and answer it as shed
                self.metrics.dequeued();
                self.metrics.observe(
                    entry,
                    victim.enqueued.elapsed().as_secs_f64(),
                    0.0,
                    0,
                    Outcome::Shed,
                );
                let _ = victim.reply.send(Err(ServeError::Shed));
                Ok(rrx)
            }
            PushOutcome::Full => {
                self.metrics.rejected_queue_full();
                Err(SubmitError::QueueFull { entry: entry.to_string() })
            }
            PushOutcome::Closed => Err(SubmitError::Closed { entry: entry.to_string() }),
        }
    }

    /// Blocking evaluation.
    pub fn eval(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Response> {
        let rx = self.submit(entry, inputs)?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(e.into()),
            Err(_) => Err(anyhow!("worker dropped reply for {}", entry)),
        }
    }

    /// Registered entry names (excluding internal workers).
    pub fn entries(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .workers
            .keys()
            .filter(|k| !k.starts_with("__"))
            .cloned()
            .collect();
        v.sort();
        v
    }

    /// Stop all workers and wait for them.
    ///
    /// Every queue is closed *before* the first join: closing is the
    /// authoritative signal (deterministic — a full queue cannot
    /// swallow it) and workers drain and answer every job accepted
    /// before the close. Closing all queues first means fan-in
    /// topologies (the PJRT backend) cannot wedge on a sibling either:
    /// each forwarder exits on its own close, releasing its fan-in
    /// sender, and the guard stops the backend once its queue closes.
    pub fn shutdown(&mut self) {
        let workers: Vec<Worker> = self.workers.drain().map(|(_, w)| w).collect();
        for w in &workers {
            w.queue.close();
        }
        for mut w in workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Engine worker: drains the queue and serves the drained eval jobs in
/// micro-batches of up to `entry.max_batch` requests, each batch one
/// batched plan execution (see the module docs). Per drain it:
/// answers already-expired jobs `Err(Expired)` before any exec work,
/// rejects malformed jobs individually (they cannot poison the stacked
/// batch), orders the rest nearest-deadline-first, feeds the drain size
/// to the degradation ladder, and re-checks expiry between chunks. A
/// closed queue ([`JobQueue::close`]) is the shutdown signal; jobs
/// drained alongside the close are still answered before the worker
/// exits. A panic inside plan execution is caught, answered to every
/// affected caller as an `Err`, counted in the error metrics — and the
/// worker stays alive for the next request.
fn engine_worker(
    name: String,
    mut entry: EngineEntry,
    queue: Arc<JobQueue<QueuedJob>>,
    metrics: Arc<Metrics>,
    faults: Arc<FaultPlan>,
) {
    let mut ladder = DegradeLadder::new(queue.cap());
    loop {
        let (jobs, closed) = queue.drain_wait();
        if !jobs.is_empty() {
            let fill = jobs.len();
            let now = Instant::now();
            let mut valid = Vec::with_capacity(jobs.len());
            for job in jobs {
                metrics.dequeued();
                if job.expired_at(now) {
                    let queue_wait = now.duration_since(job.enqueued).as_secs_f64();
                    metrics.observe(&name, queue_wait, 0.0, 0, Outcome::Expired);
                    send_reply(&faults, job.reply, Err(ServeError::Expired));
                } else if let Err(msg) = validate_inputs(&entry, &job.inputs) {
                    let queue_wait = now.duration_since(job.enqueued).as_secs_f64();
                    metrics.observe(&name, queue_wait, 0.0, 1, Outcome::Error);
                    send_reply(&faults, job.reply, Err(ServeError::Invalid(msg)));
                } else {
                    valid.push(job);
                }
            }
            // nearest deadline first (stable: undeadlined FIFO intact),
            // so under pressure the jobs most at risk run soonest
            order_by_deadline(&mut valid);
            let batch = valid.len();
            let level = match entry.forced_degrade {
                Some(l) => l.min(MAX_DEGRADE_LEVEL),
                None => ladder.observe_drain(fill).0,
            };
            entry.degrade_level.store(level as u64, Ordering::Relaxed);
            while !valid.is_empty() {
                // re-check between chunks: earlier chunks of this drain
                // may have outlasted later jobs' deadlines
                let now = Instant::now();
                let mut i = 0;
                while i < valid.len() {
                    if valid[i].expired_at(now) {
                        let job = valid.remove(i);
                        let queue_wait = now.duration_since(job.enqueued).as_secs_f64();
                        metrics.observe(&name, queue_wait, 0.0, 0, Outcome::Expired);
                        send_reply(&faults, job.reply, Err(ServeError::Expired));
                    } else {
                        i += 1;
                    }
                }
                if valid.is_empty() {
                    break;
                }
                let take = if level == 0 {
                    valid.len().min(entry.max_batch.max(1))
                } else {
                    entry.degraded_chunk(valid.len(), level)
                };
                let chunk: Vec<QueuedJob> = valid.drain(..take).collect();
                run_chunk(&name, &mut entry, chunk, batch, level > 0, &metrics, &faults);
            }
        }
        if closed {
            return;
        }
    }
}

/// Run one micro-batch: a single request executes the base plan, a
/// larger one stacks inputs into the next power-of-two bucket (padding
/// with copies of request 0) and executes the bucket's batched plan
/// once. Degraded chunks arrive pre-sized to an exact-fit compiled
/// bucket, so the pad loop is empty and `batched_plan` is a cache hit.
/// Both paths return leased zero-copy outputs and run under
/// `catch_unwind`, so a panicking plan answers its callers instead of
/// killing the worker.
///
/// Timing: queue wait runs per request from its enqueue stamp to the
/// drain point here; the service clock starts after the drain and
/// covers stacking + execution, shared by every request in the chunk.
/// `Response.latency` is the sum.
fn run_chunk(
    name: &str,
    entry: &mut EngineEntry,
    chunk: Vec<QueuedJob>,
    batch: usize,
    degraded: bool,
    metrics: &Metrics,
    faults: &FaultPlan,
) {
    let n = chunk.len();
    let drained = Instant::now();
    let mut ins = Vec::with_capacity(n);
    let mut replies = Vec::with_capacity(n);
    let mut queue_waits = Vec::with_capacity(n);
    for job in chunk {
        queue_waits.push(drained.duration_since(job.enqueued).as_secs_f64());
        ins.push(job.inputs);
        replies.push(job.reply);
    }
    if degraded {
        metrics.degraded_run();
    }
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(move || -> Vec<Vec<PlanOutput>> {
        faults.maybe_delay();
        if faults.fire(FaultSite::ExecPanic) {
            panic!("injected fault: exec panic at entry {}", name);
        }
        if n == 1 {
            let mut env = Env::new();
            let req = ins.into_iter().next().expect("chunk of one");
            for ((vname, _), t) in entry.inputs.iter().zip(req) {
                env.insert(vname, t);
            }
            return vec![entry.plan.clone().run_leased(&env)];
        }
        let bucket = if degraded {
            // degraded_chunk already snapped n to a compiled bucket
            n
        } else {
            n.next_power_of_two().min(entry.max_batch).max(n)
        };
        let plan = entry.batched_plan(bucket);
        let mut env = Env::new();
        for (k, (vname, shape)) in entry.inputs.iter().enumerate() {
            let len: usize = shape.iter().product();
            let mut data = Vec::with_capacity(bucket * len);
            for req in &ins {
                data.extend_from_slice(req[k].data());
            }
            for _ in n..bucket {
                // pad slots are computed and thrown away; the batch axis
                // is never contracted, so they cannot affect live slots
                data.extend_from_slice(ins[0][k].data());
            }
            let mut bshape = vec![bucket];
            bshape.extend_from_slice(shape);
            env.insert(vname, Tensor::new(&bshape, data));
        }
        let outs = plan.run_leased(&env);
        (0..n)
            .map(|i| outs.iter().map(|o| o.batch_slice(i, bucket)).collect())
            .collect()
    }));
    let service = t0.elapsed().as_secs_f64();
    match outcome {
        Ok(per_req) => {
            for ((outputs, reply), queue) in per_req.into_iter().zip(replies).zip(queue_waits) {
                metrics.observe(name, queue, service, batch, Outcome::Ok);
                send_reply(
                    faults,
                    reply,
                    Ok(Response {
                        outputs,
                        latency: queue + service,
                        queue_secs: queue,
                        service_secs: service,
                        batch_size: batch,
                    }),
                );
            }
        }
        Err(payload) => {
            let msg = panic_message(payload.as_ref());
            for (reply, queue) in replies.into_iter().zip(queue_waits) {
                metrics.observe(name, queue, service, batch, Outcome::Error);
                send_reply(
                    faults,
                    reply,
                    Err(ServeError::Panic(format!("entry {}: {}", name, msg))),
                );
            }
        }
    }
}

/// Deliver a reply — or, when the [`FaultSite::ReplyDrop`] fault fires,
/// drop the channel unsent. Metrics are always recorded *before* this
/// point, so the balance invariant survives dropped replies (the caller
/// sees `RecvError`, never a hang).
fn send_reply(faults: &FaultPlan, reply: SyncSender<ServeResult>, result: ServeResult) {
    if faults.fire(FaultSite::ReplyDrop) {
        drop(reply);
        return;
    }
    let _ = reply.send(result);
}

/// Stable nearest-deadline-first order: deadlined jobs ascending by
/// deadline, then undeadlined jobs in arrival (FIFO) order.
fn order_by_deadline(jobs: &mut [QueuedJob]) {
    jobs.sort_by(|a, b| match (a.deadline, b.deadline) {
        (Some(x), Some(y)) => x.cmp(&y),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => std::cmp::Ordering::Equal,
    });
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn validate_inputs(entry: &EngineEntry, inputs: &[Tensor]) -> std::result::Result<(), String> {
    if inputs.len() != entry.inputs.len() {
        return Err(format!("expected {} inputs, got {}", entry.inputs.len(), inputs.len()));
    }
    for ((name, shape), t) in entry.inputs.iter().zip(inputs) {
        if t.shape() != &shape[..] {
            return Err(format!("input {} shape {:?}, expected {:?}", name, t.shape(), shape));
        }
    }
    Ok(())
}

/// Close out a job whose PJRT backend is gone: count it and answer the
/// caller instead of silently dropping the reply channel.
fn answer_backend_gone(metrics: &Metrics, name: &str, job: QueuedJob) {
    metrics.dequeued();
    metrics.observe(name, job.enqueued.elapsed().as_secs_f64(), 0.0, 0, Outcome::Error);
    let _ = job.reply.send(Err(ServeError::Exec("pjrt backend unavailable".into())));
}

/// PJRT worker: owns the runtime, routes jobs by artifact name, answers
/// expired jobs before touching the device.
fn pjrt_worker(mut runtime: Runtime, rx: Receiver<(String, QueuedJob)>, metrics: Arc<Metrics>) {
    while let Ok((name, job)) = rx.recv() {
        metrics.dequeued();
        let now = Instant::now();
        if job.expired_at(now) {
            let queue_wait = now.duration_since(job.enqueued).as_secs_f64();
            metrics.observe(&name, queue_wait, 0.0, 0, Outcome::Expired);
            let _ = job.reply.send(Err(ServeError::Expired));
            continue;
        }
        let queue = now.duration_since(job.enqueued).as_secs_f64();
        let t0 = Instant::now();
        let res = runtime.execute(&name, &job.inputs);
        let service = t0.elapsed().as_secs_f64();
        let outcome = if res.is_err() { Outcome::Error } else { Outcome::Ok };
        metrics.observe(&name, queue, service, 1, outcome);
        let res = res
            .map(|outputs| Response {
                outputs: outputs.into_iter().map(PlanOutput::from).collect(),
                latency: queue + service,
                queue_secs: queue,
                service_secs: service,
                batch_size: 1,
            })
            .map_err(|e| ServeError::Exec(e.to_string()));
        let _ = job.reply.send(res);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::reverse::reverse_gradient;
    use crate::simplify::simplify_one;

    /// The logreg value+gradient graph the serving tests revolve around.
    fn logreg_grad_graph(m: usize, n: usize) -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new();
        let x = g.var("X", &[m, n]);
        let y = g.var("y", &[m]);
        let w = g.var("w", &[n]);
        let xw = g.matvec(x, w);
        let yxw = g.hadamard(y, xw);
        let t = g.neg(yxw);
        let e = g.elem(crate::ir::Elem::Exp, t);
        let one = g.constant(1.0, &[m]);
        let s = g.add(e, one);
        let l = g.elem(crate::ir::Elem::Log, s);
        let loss = g.sum_all(l);
        let grad = reverse_gradient(&mut g, loss, w);
        let grad = simplify_one(&mut g, grad);
        (g, vec![loss, grad])
    }

    fn logreg_grad_entry(m: usize, n: usize) -> EngineEntry {
        logreg_grad_entry_mem(m, n, crate::exec::ExecMemory::default())
    }

    fn logreg_grad_entry_mem(
        m: usize,
        n: usize,
        memory: crate::exec::ExecMemory,
    ) -> EngineEntry {
        logreg_grad_entry_opts(m, n, memory, BackendKind::default())
    }

    fn logreg_grad_entry_opts(
        m: usize,
        n: usize,
        memory: crate::exec::ExecMemory,
        backend: BackendKind,
    ) -> EngineEntry {
        let (g, roots) = logreg_grad_graph(m, n);
        EngineEntry::compiled_with(
            &g,
            &roots,
            vec![
                ("X".into(), vec![m, n]),
                ("y".into(), vec![m]),
                ("w".into(), vec![n]),
            ],
            crate::opt::OptLevel::default(),
            memory,
            backend,
        )
    }

    fn logreg_inputs(m: usize, n: usize, seed: u64) -> Vec<Tensor> {
        vec![
            Tensor::randn(&[m, n], seed),
            Tensor::randn(&[m], seed + 1).map(f64::signum),
            Tensor::randn(&[n], seed + 2),
        ]
    }

    fn logreg_env(m: usize, n: usize, seed: u64) -> Env {
        let inputs = logreg_inputs(m, n, seed);
        let mut env = Env::new();
        for (name, t) in ["X", "y", "w"].into_iter().zip(inputs) {
            env.insert(name, t);
        }
        env
    }

    fn no_faults() -> Arc<FaultPlan> {
        Arc::new(FaultPlan::none())
    }

    /// Enqueue one job (stamped now, no deadline) for tests that drive
    /// `engine_worker` directly.
    fn push_job(
        q: &JobQueue<QueuedJob>,
        inputs: Vec<Tensor>,
        reply: SyncSender<ServeResult>,
    ) {
        let out = q.push(
            QueuedJob { inputs, reply, enqueued: Instant::now(), deadline: None },
            ShedPolicy::Reject,
        );
        assert!(matches!(out, PushOutcome::Accepted), "test queue must accept");
    }

    fn push_job_deadline(
        q: &JobQueue<QueuedJob>,
        inputs: Vec<Tensor>,
        reply: SyncSender<ServeResult>,
        deadline: Instant,
    ) {
        let out = q.push(
            QueuedJob { inputs, reply, enqueued: Instant::now(), deadline: Some(deadline) },
            ShedPolicy::Reject,
        );
        assert!(matches!(out, PushOutcome::Accepted), "test queue must accept");
    }

    #[test]
    fn engine_entry_roundtrip() {
        let mut c = Coordinator::new(16);
        c.register_engine("logreg_grad", logreg_grad_entry(8, 3));
        let resp = c.eval("logreg_grad", logreg_inputs(8, 3, 1)).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        assert_eq!(resp.outputs[1].shape(), &[3]);
        assert!(resp.latency >= 0.0);
    }

    #[test]
    fn latency_is_queue_wait_plus_service_time() {
        let mut c = Coordinator::new(16);
        c.register_engine("e", logreg_grad_entry(8, 3));
        let resp = c.eval("e", logreg_inputs(8, 3, 1)).unwrap();
        assert!(resp.queue_secs >= 0.0);
        assert!(resp.service_secs > 0.0, "plan execution takes nonzero time");
        let sum = resp.queue_secs + resp.service_secs;
        assert!(
            (resp.latency - sum).abs() < 1e-12,
            "latency {} must equal queue {} + service {}",
            resp.latency,
            resp.queue_secs,
            resp.service_secs
        );
    }

    #[test]
    fn stats_surface_reports_optimizer_and_compile_counters() {
        let mut c = Coordinator::new(16);
        c.register_engine("warm", logreg_grad_entry(8, 3).with_max_batch(8).with_prewarm(true));
        c.register_engine("cold", logreg_grad_entry(8, 3));
        let stats = c.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].name, "cold");
        assert_eq!(stats[1].name, "warm");
        let warm = &stats[1];
        // entries compile at the default (Full) level, so the optimizer
        // report must ride along
        let os = warm.opt_stats.expect("optimized entry must carry OptStats");
        assert!(os.nodes_before >= os.nodes_after);
        assert_eq!(warm.prewarmed_buckets, vec![2, 4, 8]);
        assert_eq!(warm.prewarm_compiles, 3);
        assert_eq!(warm.lazy_compiles, 0);
        assert_eq!(stats[0].prewarmed_buckets, Vec::<usize>::new());
        assert_eq!(stats[0].prewarm_compiles, 0);
        // the registration gauges surface the same counters per entry
        let prom = c.metrics().render_prometheus();
        assert!(prom.contains("tensorcalc_prewarm_compiles{entry=\"warm\"} 3"), "{prom}");
        assert!(prom.contains("tensorcalc_lazy_compiles{entry=\"cold\"} 0"), "{prom}");
        assert!(prom.contains("tensorcalc_degrade_level{entry=\"warm\"} 0"), "{prom}");
        c.shutdown();
    }

    #[test]
    fn planned_and_pooled_entries_agree() {
        use crate::exec::ExecMemory;
        let mut c = Coordinator::new(16);
        c.register_engine("planned", logreg_grad_entry_mem(8, 3, ExecMemory::Planned));
        c.register_engine("pooled", logreg_grad_entry_mem(8, 3, ExecMemory::Pooled));
        let inputs = logreg_inputs(8, 3, 1);
        let a = c.eval("planned", inputs.clone()).unwrap();
        let b = c.eval("pooled", inputs).unwrap();
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(ta.data(), tb.data(), "entry memory modes diverged");
        }
    }

    #[test]
    fn backend_entries_agree_bitwise() {
        // per-entry backend choice: a direct-threaded entry serves
        // bit-identical responses to the default cpu entry
        let mut c = Coordinator::new(16);
        c.register_engine(
            "cpu",
            logreg_grad_entry_opts(8, 3, ExecMemory::default(), BackendKind::Cpu),
        );
        c.register_engine(
            "direct",
            logreg_grad_entry_opts(8, 3, ExecMemory::default(), BackendKind::Direct),
        );
        let inputs = logreg_inputs(8, 3, 1);
        let a = c.eval("cpu", inputs.clone()).unwrap();
        let b = c.eval("direct", inputs).unwrap();
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(ta.data(), tb.data(), "entry backends diverged");
        }
    }

    #[test]
    fn prewarm_eliminates_serving_path_compiles() {
        // queue 5 requests before the worker starts so one drain forms a
        // multi-request bucket — the case that lazily compiles a batched
        // plan unless the entry was prewarmed
        let drive = |entry: EngineEntry| -> u64 {
            let counter = entry.lazy_compile_counter();
            let metrics = Arc::new(Metrics::new());
            let q = Arc::new(JobQueue::new(8));
            let mut replies = Vec::new();
            for i in 0..5u64 {
                let (rtx, rrx) = sync_channel(1);
                push_job(&q, logreg_inputs(8, 3, i), rtx);
                replies.push(rrx);
            }
            q.close();
            engine_worker("e".into(), entry, q, metrics, no_faults());
            for rrx in replies {
                rrx.recv().expect("reply dropped").unwrap();
            }
            counter.load(Ordering::Relaxed)
        };
        let cold = drive(logreg_grad_entry(8, 3));
        assert!(cold > 0, "an un-prewarmed entry must compile its bucket lazily");
        let warm = drive(logreg_grad_entry(8, 3).with_max_batch(8).with_prewarm(true));
        assert_eq!(warm, 0, "a prewarmed entry must never compile on the serving path");
    }

    #[test]
    fn unknown_entry_errors() {
        let c = Coordinator::new(4);
        let err = c.submit("nope", vec![]).err().expect("unknown entry must be refused");
        assert_eq!(err, SubmitError::UnknownEntry { entry: "nope".into() });
        assert!(!err.is_retryable());
    }

    #[test]
    fn wrong_shape_is_reported_not_panicking() {
        let mut c = Coordinator::new(4);
        c.register_engine("e", logreg_grad_entry(8, 3));
        let bad = vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[8]), Tensor::zeros(&[3])];
        let resp = c.eval("e", bad);
        assert!(resp.is_err());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let mut c = Coordinator::new(64);
        c.register_engine("e", logreg_grad_entry(16, 4));
        let mut rxs = Vec::new();
        for i in 0..32 {
            rxs.push(c.submit("e", logreg_inputs(16, 4, i)).unwrap());
        }
        let mut max_batch = 0;
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            max_batch = max_batch.max(r.batch_size);
        }
        assert!(max_batch >= 1);
        let stats = c.metrics().snapshot();
        assert_eq!(stats.completed, 32);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn backpressure_queue_full_is_typed_and_counted() {
        let mut c = Coordinator::new(1);
        c.register_engine("e", logreg_grad_entry(64, 16));
        let mut errs = 0;
        let mut oks = Vec::new();
        for i in 0..64 {
            match c.submit("e", logreg_inputs(64, 16, i)) {
                Ok(rx) => oks.push(rx),
                Err(e) => {
                    assert_eq!(e, SubmitError::QueueFull { entry: "e".into() });
                    assert!(e.is_retryable(), "QueueFull is the retryable variant");
                    errs += 1;
                }
            }
        }
        for rx in oks {
            let _ = rx.recv();
        }
        // with queue_cap=1 and 64 rapid submits, backpressure should trigger
        assert!(errs > 0, "expected backpressure with cap=1");
        let snap = c.metrics().snapshot();
        assert_eq!(snap.rejected_full, errs, "every QueueFull must be counted");
        assert_eq!(snap.submitted, 64 - errs, "rejected requests are not submitted");
    }

    #[test]
    fn shutdown_with_saturated_cap1_queue_terminates() {
        let mut c = Coordinator::new(1);
        c.register_engine("e", logreg_grad_entry(64, 16));
        // saturate the cap-1 queue so a lossy nudge-style signal would fail
        let mut accepted = Vec::new();
        for i in 0..16 {
            if let Ok(rx) = c.submit("e", logreg_inputs(64, 16, i)) {
                accepted.push(rx);
            }
        }
        let (done_tx, done_rx) = sync_channel::<()>(1);
        let h = std::thread::spawn(move || {
            c.shutdown();
            drop(c);
            let _ = done_tx.send(());
        });
        assert!(
            done_rx.recv_timeout(std::time::Duration::from_secs(60)).is_ok(),
            "Coordinator::shutdown deadlocked on a full queue"
        );
        h.join().unwrap();
        // every accepted job was answered before the worker exited
        for rx in accepted {
            let resp = rx.recv().expect("reply dropped on shutdown");
            assert!(resp.is_ok());
        }
    }

    #[test]
    fn close_with_queued_jobs_answers_all() {
        // the satellite-1 contract at the worker: close() does not
        // discard accepted jobs — the final drain serves them
        let entry = logreg_grad_entry(8, 3);
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(JobQueue::new(8));
        let (r1tx, r1rx) = sync_channel(1);
        let (r2tx, r2rx) = sync_channel(1);
        push_job(&q, logreg_inputs(8, 3, 1), r1tx);
        push_job(&q, logreg_inputs(8, 3, 10), r2tx);
        q.close();
        engine_worker("e".into(), entry, q, metrics.clone(), no_faults());
        let a = r1rx.recv().expect("first reply dropped").unwrap();
        let b = r2rx.recv().expect("job queued before close dropped").unwrap();
        assert_eq!(a.batch_size, 2);
        assert_eq!(b.batch_size, 2);
        assert_eq!(metrics.snapshot().completed, 2);
    }

    #[test]
    fn close_with_queued_jobs_answers_all_batched() {
        // the batched-path variant: enough jobs for a real
        // multi-request bucket, every one still answered after close
        let entry = logreg_grad_entry(8, 3);
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(JobQueue::new(16));
        let mut replies = Vec::new();
        for i in 0..5u64 {
            let (rtx, rrx) = sync_channel(1);
            push_job(&q, logreg_inputs(8, 3, 20 + i), rtx);
            replies.push(rrx);
        }
        q.close();
        engine_worker("e".into(), entry, q, metrics.clone(), no_faults());
        for rrx in replies {
            let resp = rrx.recv().expect("job queued before close dropped").unwrap();
            assert_eq!(resp.batch_size, 5);
        }
        assert_eq!(metrics.snapshot().completed, 5);
        assert_eq!(metrics.snapshot().errors, 0);
    }

    #[test]
    fn batched_run_bit_identical_to_sequential() {
        // Queue 5 requests before the worker starts: one drain, one
        // batched execution (bucket 8, so padding is exercised too).
        // Every slice must match a sequential base-plan run bitwise.
        let entry = logreg_grad_entry(8, 3);
        let base = entry.plan.clone();
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(JobQueue::new(8));
        let mut replies = Vec::new();
        for i in 0..5u64 {
            let (rtx, rrx) = sync_channel(1);
            push_job(&q, logreg_inputs(8, 3, i * 10), rtx);
            replies.push((i, rrx));
        }
        q.close();
        engine_worker("e".into(), entry, q, metrics.clone(), no_faults());
        for (i, rrx) in replies {
            let resp = rrx.recv().unwrap().unwrap();
            assert_eq!(resp.batch_size, 5);
            let want = base.run(&logreg_env(8, 3, i * 10));
            assert_eq!(resp.outputs.len(), want.len());
            for (o, w) in resp.outputs.iter().zip(&want) {
                assert_eq!(o.shape(), w.shape());
                assert_eq!(o.data(), w.data(), "batched slice diverged from sequential run");
            }
        }
        assert_eq!(metrics.snapshot().completed, 5);
        assert_eq!(metrics.snapshot().errors, 0);
    }

    #[test]
    fn batch_ablation_is_bit_identical() {
        // The ablation axis: a max_batch=1 entry must serve bit-identical
        // results to the batched entry for identical inputs.
        let mut c = Coordinator::new(64);
        c.register_engine("on", logreg_grad_entry(8, 3));
        c.register_engine("off", logreg_grad_entry(8, 3).with_max_batch(1));
        let mut pairs = Vec::new();
        for i in 0..12 {
            pairs.push((
                c.submit("on", logreg_inputs(8, 3, i)).unwrap(),
                c.submit("off", logreg_inputs(8, 3, i)).unwrap(),
            ));
        }
        for (a, b) in pairs {
            let ra = a.recv().unwrap().unwrap();
            let rb = b.recv().unwrap().unwrap();
            assert_eq!(ra.outputs.len(), rb.outputs.len());
            for (x, y) in ra.outputs.iter().zip(&rb.outputs) {
                assert_eq!(x.data(), y.data(), "batching ablation diverged");
            }
        }
    }

    #[test]
    fn concurrent_mixed_entries_match_direct_plans() {
        // Concurrent submitters across two entries with different shapes;
        // every response must be bit-identical to a direct base-plan run.
        let mut c = Coordinator::new(256);
        c.register_engine("small", logreg_grad_entry(8, 3));
        c.register_engine("big", logreg_grad_entry(16, 4));
        let plans =
            [logreg_grad_entry(8, 3).plan.clone(), logreg_grad_entry(16, 4).plan.clone()];
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let c = &c;
                let plans = &plans;
                s.spawn(move || {
                    for i in 0..8u64 {
                        let seed = t * 100 + i;
                        let which = ((t + i) % 2) as usize;
                        let (m, n) = [(8, 3), (16, 4)][which];
                        let name = ["small", "big"][which];
                        let resp = c.eval(name, logreg_inputs(m, n, seed)).unwrap();
                        let want = plans[which].run(&logreg_env(m, n, seed));
                        assert_eq!(resp.outputs.len(), want.len());
                        for (o, w) in resp.outputs.iter().zip(&want) {
                            assert_eq!(o.data(), w.data(), "served output diverged bitwise");
                        }
                    }
                });
            }
        });
        let stats = c.metrics().snapshot();
        assert_eq!(stats.completed, 32);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn panic_in_plan_is_isolated() {
        // An entry whose declared inputs omit a graph variable: the plan
        // panics ("unbound variable w") at run time. The worker must
        // answer with Err, count the error, and stay alive.
        let (g, roots) = logreg_grad_graph(8, 3);
        let entry = EngineEntry::compiled(
            &g,
            &roots,
            vec![("X".into(), vec![8, 3]), ("y".into(), vec![8])],
        );
        let mut c = Coordinator::new(8);
        c.register_engine("boom", entry);
        c.register_engine("ok", logreg_grad_entry(8, 3));
        let bad = vec![Tensor::randn(&[8, 3], 1), Tensor::randn(&[8], 2).map(f64::signum)];
        let r1 = c.eval("boom", bad.clone());
        assert!(r1.is_err(), "panicking plan must answer with Err");
        let r2 = c.eval("boom", bad);
        assert!(r2.is_err(), "worker must survive the panic and keep answering");
        // healthy entries in the same coordinator are unaffected
        let ok = c.eval("ok", logreg_inputs(8, 3, 5)).unwrap();
        assert_eq!(ok.outputs.len(), 2);
        let stats = c.metrics().snapshot();
        assert_eq!(stats.completed, 1, "completed counts successes only");
        assert_eq!(stats.errors, 2);
        c.shutdown();
    }

    #[test]
    fn re_registration_joins_replaced_worker() {
        let mut c = Coordinator::new(64);
        c.register_engine("e", logreg_grad_entry(64, 16));
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(c.submit("e", logreg_inputs(64, 16, i)).unwrap());
        }
        // replacing the entry must shut down and *join* the old worker:
        // by the time register_engine returns, every job it accepted has
        // been answered (pre-fix the old thread was silently detached)
        c.register_engine("e", logreg_grad_entry(8, 3));
        for rx in rxs {
            let resp = rx
                .try_recv()
                .expect("replaced worker must answer accepted jobs before registration returns");
            assert!(resp.is_ok());
        }
        // the new worker serves the new signature, and shutdown after
        // re-registration stays clean
        let resp = c.eval("e", logreg_inputs(8, 3, 99)).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        c.shutdown();
    }

    #[test]
    fn pjrt_backend_through_coordinator() {
        let Some(dir) = crate::runtime::artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let mut c = Coordinator::new(8);
        c.register_runtime(dir.clone(), &["logreg_val_grad".to_string()]).unwrap();
        let x = crate::runtime::read_f32_raw(dir.join("check/logreg_X.f32"), &[256, 128]).unwrap();
        let y = crate::runtime::read_f32_raw(dir.join("check/logreg_y.f32"), &[256]).unwrap();
        let w = crate::runtime::read_f32_raw(dir.join("check/logreg_w.f32"), &[128]).unwrap();
        let resp = c.eval("logreg_val_grad", vec![w, x, y]).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        let grad =
            crate::runtime::read_f32_raw(dir.join("check/logreg_grad.f32"), &[128]).unwrap();
        assert!(resp.outputs[1].allclose(&grad, 1e-4, 1e-4));
    }

    // ---- deadline / shed / degrade robustness tests ----

    #[test]
    fn submit_errors_classify_retryability() {
        let q = SubmitError::QueueFull { entry: "e".into() };
        assert!(q.is_retryable());
        assert!(q.to_string().contains("queue full"));
        assert!(!SubmitError::UnknownEntry { entry: "e".into() }.is_retryable());
        assert!(!SubmitError::Closed { entry: "e".into() }.is_retryable());
        assert!(!SubmitError::Expired { entry: "e".into() }.is_retryable());
        assert!(ServeError::Shed.is_retryable());
        assert!(ServeError::Panic("x".into()).is_retryable());
        assert!(ServeError::Exec("x".into()).is_retryable());
        assert!(!ServeError::Expired.is_retryable());
        assert!(!ServeError::Invalid("x".into()).is_retryable());
    }

    #[test]
    fn shed_policy_cli_spellings_parse() {
        assert_eq!(ShedPolicy::parse("reject"), Some(ShedPolicy::Reject));
        assert_eq!(ShedPolicy::parse("oldest"), Some(ShedPolicy::ShedOldest));
        assert_eq!(ShedPolicy::parse("shed-oldest"), Some(ShedPolicy::ShedOldest));
        assert_eq!(ShedPolicy::parse("block"), Some(ShedPolicy::Block(Duration::from_millis(100))));
        assert_eq!(
            ShedPolicy::parse("block:250"),
            Some(ShedPolicy::Block(Duration::from_millis(250)))
        );
        assert_eq!(ShedPolicy::parse("nope"), None);
        assert_eq!(ShedPolicy::Block(Duration::from_millis(250)).to_string(), "block:250");
    }

    #[test]
    fn zero_and_past_deadlines_are_rejected_at_admission() {
        // Deadlines are monotonic Instants: a zero budget stamps
        // `now + 0`, and by the time admission re-reads the clock the
        // deadline can only be <= now — never resurrected by a clock
        // step, because Instant never runs backwards.
        let t0 = Instant::now();
        let mut c = Coordinator::new(8);
        c.register_engine("e", logreg_grad_entry(8, 3));
        let err = c
            .submit_with("e", Request::new(logreg_inputs(8, 3, 1)).with_deadline(Duration::ZERO))
            .err()
            .expect("zero deadline must be rejected at admission");
        assert_eq!(err, SubmitError::Expired { entry: "e".into() });
        assert!(!err.is_retryable());
        // a deadline in the past (t0 predates register_engine's compile)
        let err = c
            .submit_with("e", Request::new(logreg_inputs(8, 3, 2)).with_deadline_at(t0))
            .err()
            .expect("past deadline must be rejected at admission");
        assert_eq!(err, SubmitError::Expired { entry: "e".into() });
        let snap = c.metrics().snapshot();
        assert_eq!(snap.rejected_expired, 2);
        assert_eq!(snap.submitted, 0, "rejected requests never count as submitted");
        // a generous deadline is admitted and served
        let rx = c
            .submit_with(
                "e",
                Request::new(logreg_inputs(8, 3, 3)).with_deadline(Duration::from_secs(60)),
            )
            .unwrap();
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn near_deadline_jobs_sort_first_and_fifo_is_stable() {
        let now = Instant::now();
        let mk = |deadline: Option<Instant>, tag: f64| -> QueuedJob {
            let (tx, _rx) = sync_channel(1);
            QueuedJob {
                inputs: vec![Tensor::new(&[1], vec![tag])],
                reply: tx,
                enqueued: now,
                deadline,
            }
        };
        let mut jobs = vec![
            mk(None, 0.0),
            mk(Some(now + Duration::from_secs(5)), 1.0),
            mk(Some(now + Duration::from_secs(1)), 2.0),
            mk(None, 3.0),
            mk(Some(now + Duration::from_secs(1)), 4.0),
        ];
        order_by_deadline(&mut jobs);
        let tags: Vec<f64> = jobs.iter().map(|j| j.inputs[0].data()[0]).collect();
        // nearest deadlines first (ties FIFO-stable), undeadlined last in
        // arrival order
        assert_eq!(tags, vec![2.0, 4.0, 1.0, 0.0, 3.0]);
    }

    #[test]
    fn expired_jobs_in_a_drain_are_answered_before_exec() {
        // One already-expired job drained alongside two live ones: the
        // expired job gets Err(Expired) with no exec work, the live jobs
        // form the fused batch and stay bit-identical to base-plan runs.
        let entry = logreg_grad_entry(8, 3).with_max_batch(8).with_prewarm(true);
        let base = entry.plan.clone();
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(JobQueue::new(8));
        let now = Instant::now();
        let (etx, erx) = sync_channel(1);
        push_job_deadline(&q, logreg_inputs(8, 3, 50), etx, now); // expires immediately
        let mut live = Vec::new();
        for i in 0..2u64 {
            let (rtx, rrx) = sync_channel(1);
            push_job_deadline(&q, logreg_inputs(8, 3, 60 + i), rtx, now + Duration::from_secs(60));
            live.push((60 + i, rrx));
        }
        q.close();
        engine_worker("e".into(), entry, q, metrics.clone(), no_faults());
        match erx.recv().expect("expired job must still get its one reply") {
            Err(ServeError::Expired) => {}
            other => panic!("expected Err(Expired), got {:?}", other),
        }
        for (seed, rrx) in live {
            let resp = rrx.recv().unwrap().unwrap();
            assert_eq!(resp.batch_size, 2, "batch counts live jobs only");
            let want = base.run(&logreg_env(8, 3, seed));
            for (o, w) in resp.outputs.iter().zip(&want) {
                assert_eq!(o.data(), w.data(), "live slice diverged with expired sibling");
            }
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.expired, 1);
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn deadline_expiry_mid_drain_answers_expired_before_exec() {
        // Two live jobs drained together, chunked one at a time
        // (max_batch 1); injected service latency (300ms, rate 1.0 —
        // fires without drawing, so fully deterministic) makes the first
        // chunk outlast the second job's 250ms deadline. The worker must
        // catch that between chunks and answer Err(Expired) pre-exec.
        // Deadline ordering runs the 100ms job first; both are live at
        // drain time (the drain starts within microseconds of the push).
        let faults = Arc::new(
            FaultPlan::seeded(1)
                .with_rate(FaultSite::ServiceLatency, 1.0)
                .with_latency(Duration::from_millis(300)),
        );
        let entry = logreg_grad_entry(8, 3).with_max_batch(1);
        let metrics = Arc::new(Metrics::new());
        let q = Arc::new(JobQueue::new(8));
        let now = Instant::now();
        let (r1tx, r1rx) = sync_channel(1);
        let (r2tx, r2rx) = sync_channel(1);
        push_job_deadline(&q, logreg_inputs(8, 3, 1), r1tx, now + Duration::from_millis(100));
        push_job_deadline(&q, logreg_inputs(8, 3, 2), r2tx, now + Duration::from_millis(250));
        q.close();
        engine_worker("e".into(), entry, q, metrics.clone(), faults);
        assert!(r1rx.recv().unwrap().is_ok(), "job inside its deadline at drain time runs");
        match r2rx.recv().expect("mid-drain-expired job must get its one reply") {
            Err(ServeError::Expired) => {}
            other => panic!("expected Err(Expired) after chunk overran deadline, got {:?}", other),
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.expired, 1);
    }

    #[test]
    fn shed_oldest_policy_answers_victims_with_shed() {
        // Injected 10ms service latency (rate 1.0) keeps the worker busy
        // while 32 submissions race a cap-2 queue: ShedOldest admits all
        // of them, evicting oldest-first. Exactly-one-reply and the
        // metrics balance must hold.
        let faults = FaultPlan::seeded(3)
            .with_rate(FaultSite::ServiceLatency, 1.0)
            .with_latency(Duration::from_millis(10));
        let mut c = Coordinator::with_faults(2, faults);
        c.register_engine(
            "e",
            logreg_grad_entry(8, 3).with_shed_policy(ShedPolicy::ShedOldest),
        );
        let mut rxs = Vec::new();
        for i in 0..32 {
            rxs.push(c.submit("e", logreg_inputs(8, 3, i)).expect("shed-oldest never rejects"));
        }
        let mut ok = 0u64;
        let mut shed = 0u64;
        for rx in rxs {
            match rx.recv().expect("every admitted request gets exactly one reply") {
                Ok(_) => ok += 1,
                Err(ServeError::Shed) => shed += 1,
                Err(e) => panic!("unexpected serve error: {:?}", e),
            }
        }
        assert_eq!(ok + shed, 32);
        assert!(shed > 0, "cap-2 queue under a busy worker must shed");
        let snap = c.metrics().snapshot();
        assert_eq!(snap.submitted, 32);
        assert_eq!(snap.completed, ok);
        assert_eq!(snap.shed, shed);
        assert_eq!(
            snap.submitted,
            snap.completed + snap.errors + snap.shed + snap.expired,
            "metrics balance must hold under shedding"
        );
    }

    #[test]
    fn forced_degrade_levels_serve_bit_identically() {
        // Level 1 (exact-fit compiled buckets, no pad, no compiles) and
        // level 2 (base plan only) must both serve bit-identical outputs
        // to the canonical plan — the ladder changes scheduling, never
        // numerics.
        for level in [1u8, 2] {
            let entry = logreg_grad_entry(8, 3)
                .with_max_batch(8)
                .with_prewarm(true)
                .with_forced_degrade_level(level);
            let lazy = entry.lazy_compile_counter();
            let base = entry.plan.clone();
            let metrics = Arc::new(Metrics::new());
            let q = Arc::new(JobQueue::new(8));
            let mut replies = Vec::new();
            for i in 0..5u64 {
                let (rtx, rrx) = sync_channel(1);
                push_job(&q, logreg_inputs(8, 3, i * 7), rtx);
                replies.push((i * 7, rrx));
            }
            q.close();
            engine_worker("e".into(), entry, q, metrics.clone(), no_faults());
            for (seed, rrx) in replies {
                let resp = rrx.recv().unwrap().unwrap();
                let want = base.run(&logreg_env(8, 3, seed));
                assert_eq!(resp.outputs.len(), want.len());
                for (o, w) in resp.outputs.iter().zip(&want) {
                    assert_eq!(o.data(), w.data(), "degrade level {} diverged bitwise", level);
                }
            }
            assert_eq!(
                lazy.load(Ordering::Relaxed),
                0,
                "degraded serving must never compile (level {})",
                level
            );
            let snap = metrics.snapshot();
            assert_eq!(snap.completed, 5);
            assert!(snap.degraded > 0, "degraded chunks must be counted (level {})", level);
        }
    }

    #[test]
    fn degraded_chunk_snaps_to_compiled_buckets() {
        let entry = logreg_grad_entry(8, 3).with_max_batch(8).with_prewarm(true);
        // prewarmed buckets: 2, 4, 8
        assert_eq!(entry.degraded_chunk(5, 1), 4, "largest compiled bucket <= 5");
        assert_eq!(entry.degraded_chunk(8, 1), 8);
        assert_eq!(entry.degraded_chunk(3, 1), 2);
        assert_eq!(entry.degraded_chunk(1, 1), 1, "no bucket fits: base plan");
        assert_eq!(entry.degraded_chunk(5, 2), 1, "level 2 is base-plan only");
        let cold = logreg_grad_entry(8, 3);
        assert_eq!(cold.degraded_chunk(5, 1), 1, "nothing compiled: base plan, no compiles");
    }
}
