//! The derivative-evaluation service: a request router + per-entry
//! worker with bounded queues (backpressure), serving two backends —
//! the symbolic engine (expression DAG + [`CompiledPlan`]) and the PJRT
//! executables loaded by [`crate::runtime`].
//!
//! The paper's contribution is the calculus itself, so this layer is a
//! thin-but-real coordinator: the end-to-end example and `tensorcalc
//! serve` drive batched gradient/Hessian requests through it and report
//! throughput/latency.
//!
//! ## Dynamic request batching
//!
//! An engine worker drains everything already queued for its entry and
//! runs the drained eval jobs as *one* batched execution: inputs are
//! stacked along a new leading batch axis and a batched variant of the
//! plan — derived by [`crate::exec::batch_graph`] from the same
//! canonical graph, compiled lazily per batch bucket through the global
//! [`PlanCache`](crate::exec::PlanCache) — runs once. Batch sizes are
//! bucketed to powers of two (capped by
//! [`EngineEntry::with_max_batch`]); a partial bucket is padded with
//! copies of the first request, whose slots are computed and discarded —
//! the batch axis is never contracted, so pad slots cannot perturb live
//! ones. Root outputs come back as [`PlanOutput`] views into the leased
//! run arena (zero-copy; see [`CompiledPlan::run_leased`]) and are split
//! per request by pointer arithmetic on the shared lease.
//!
//! The rewrite is bit-identity-preserving: slice `b` of a batched run is
//! computed by the same floating-point operations, in the same order, as
//! request `b` run alone (pinned in `tests/serve_batch.rs` and the
//! module tests below). `with_max_batch(1)` turns batching off and is
//! kept as the ablation axis for `benches/serve_load.rs`.

mod metrics;
pub use metrics::{Metrics, Snapshot};

use crate::error::Result;
use crate::eval::Env;
use crate::exec::{batch_graph, global_plan_cache, BackendKind, CompiledPlan, ExecMemory, PlanOutput};
use crate::ir::{Graph, NodeId};
use crate::obs::TraceMode;
use crate::opt::{OptLevel, OptStats};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Largest micro-batch an entry fuses into one run unless overridden:
/// high enough to amortise per-request dispatch under load, low enough
/// that a power-of-two bucket pads at most one doubling.
pub const DEFAULT_MAX_BATCH: usize = 8;

/// An engine-backed entry: a *compiled* plan (planned arena, level-
/// parallel execution — see [`crate::exec`]) plus a fixed input
/// signature. The entry retains the canonical (optimized + compacted)
/// graph it was compiled from, so batched variants can be derived from
/// the exact structure the base plan executes — that is what makes the
/// batched path bit-identical per slice. All plans come from the global
/// plan cache: re-registering the same graph (the repeated-request hot
/// path) reuses the compiled artifact and its warm run states.
pub struct EngineEntry {
    pub plan: Arc<CompiledPlan>,
    /// variable names in submission order, with expected shapes
    pub inputs: Vec<(String, Vec<usize>)>,
    /// the graph `plan` was compiled from (canonical unless the entry
    /// was built at `OptLevel::None`), retained for batched variants
    graph: Graph,
    roots: Vec<NodeId>,
    memory: ExecMemory,
    /// which executor serves this entry (per-entry backend choice)
    backend: BackendKind,
    /// largest micro-batch fused into one run; 1 = batching off (the
    /// ablation baseline)
    max_batch: usize,
    /// lazily compiled batched variants, one per batch bucket
    batched: HashMap<usize, Arc<CompiledPlan>>,
    /// batch-bucket plans compiled on the serving path (i.e. *not*
    /// prewarmed) — [`EngineEntry::with_prewarm`] exists to pin this at
    /// zero in steady state
    lazy_compiles: Arc<AtomicU64>,
    /// batch-bucket plans compiled at registration time by
    /// [`EngineEntry::with_prewarm`]
    prewarm_compiles: Arc<AtomicU64>,
    /// what the optimizer did to this entry's graph before it was
    /// frozen (None when built at `OptLevel::None`); surfaced through
    /// [`Coordinator::stats`]
    opt_stats: Option<OptStats>,
}

impl EngineEntry {
    /// Compile `roots` of `graph` (through the global plan cache) into a
    /// servable entry at the default optimizer level and memory
    /// discipline (planned arena).
    pub fn compiled(
        graph: &Graph,
        roots: &[NodeId],
        inputs: Vec<(String, Vec<usize>)>,
    ) -> Self {
        Self::compiled_with(
            graph,
            roots,
            inputs,
            OptLevel::default(),
            ExecMemory::default(),
            BackendKind::default(),
        )
    }

    /// [`EngineEntry::compiled`] with the optimizer level, executor
    /// memory discipline and execution backend explicit — the
    /// coordinator-side end of the `ExecMemory` / `BackendKind`
    /// ablations. All entries share the process-wide persistent worker
    /// pool regardless of mode, so the level scheduler of repeated
    /// request bursts spawns no threads.
    pub fn compiled_with(
        graph: &Graph,
        roots: &[NodeId],
        inputs: Vec<(String, Vec<usize>)>,
        level: OptLevel,
        memory: ExecMemory,
        backend: BackendKind,
    ) -> Self {
        // canonicalise once here, then compile at OptLevel::None: the
        // cache keys `None` by the fingerprint of the graph as given,
        // which for the canonical graph is exactly the key the ordinary
        // optimized path uses — same key, same shared Arc. Batched
        // variants then derive from this frozen structure instead of
        // re-running the optimizer (whose cost model could reassociate
        // the batched contractions differently and break bit-identity).
        let (graph, roots, opt_stats) = if level == OptLevel::None {
            (graph.clone(), roots.to_vec(), None)
        } else {
            let mut g2 = graph.clone();
            let o = crate::opt::optimize(&mut g2, roots, level);
            let (gc, croots) = crate::opt::compact(&g2, &o.roots);
            (gc, croots, Some(o.stats))
        };
        let plan = global_plan_cache().get_or_compile_opts(
            &graph,
            &roots,
            OptLevel::None,
            memory,
            backend,
            TraceMode::Off,
        );
        EngineEntry {
            plan,
            inputs,
            graph,
            roots,
            memory,
            backend,
            max_batch: DEFAULT_MAX_BATCH,
            batched: HashMap::new(),
            lazy_compiles: Arc::new(AtomicU64::new(0)),
            prewarm_compiles: Arc::new(AtomicU64::new(0)),
            opt_stats,
        }
    }

    /// Cap the dynamic batch size (1 disables batching — the ablation
    /// baseline served next to the batched entry in `serve_load`).
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Eagerly compile every batch-bucket variant this entry can reach
    /// (the power-of-two buckets up to `max_batch` — exactly the set
    /// [`run_chunk`] computes), so the serving path never compiles: the
    /// first burst after registration pays zero compile latency, and
    /// [`EngineEntry::lazy_compile_counter`] stays at zero. Apply
    /// *after* [`EngineEntry::with_max_batch`] — prewarming covers the
    /// bucket set of the cap in force when it runs.
    pub fn with_prewarm(mut self, prewarm: bool) -> Self {
        if prewarm {
            for n in 2..=self.max_batch {
                let bucket = n.next_power_of_two().min(self.max_batch).max(n);
                if !self.batched.contains_key(&bucket) {
                    let (bg, broots) = batch_graph(&self.graph, &self.roots, bucket);
                    let plan = global_plan_cache().get_or_compile_opts(
                        &bg,
                        &broots,
                        OptLevel::None,
                        self.memory,
                        self.backend,
                        TraceMode::Off,
                    );
                    self.prewarm_compiles.fetch_add(1, Ordering::Relaxed);
                    self.batched.insert(bucket, plan);
                }
            }
        }
        self
    }

    /// Handle on the lazy-compile counter: how many batch-bucket plans
    /// were compiled on the serving path instead of at registration.
    /// With [`EngineEntry::with_prewarm`] this must stay zero in steady
    /// state (asserted in the module tests). The handle survives the
    /// entry moving into its worker thread.
    pub fn lazy_compile_counter(&self) -> Arc<AtomicU64> {
        self.lazy_compiles.clone()
    }

    /// Handle on the prewarm-compile counter: how many batch-bucket
    /// plans [`EngineEntry::with_prewarm`] compiled at registration.
    pub fn prewarm_compile_counter(&self) -> Arc<AtomicU64> {
        self.prewarm_compiles.clone()
    }

    /// What the optimizer did to this entry's graph before compilation
    /// (None when the entry was built at `OptLevel::None`).
    pub fn opt_stats(&self) -> Option<OptStats> {
        self.opt_stats
    }

    /// The batch buckets with a compiled plan right now, ascending.
    pub fn compiled_buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.batched.keys().copied().collect();
        b.sort_unstable();
        b
    }

    /// The plan for one batch bucket, compiled on first use through the
    /// global cache (key: fingerprint of the batched graph, which covers
    /// the bucket size via the variables' leading axis).
    fn batched_plan(&mut self, bucket: usize) -> Arc<CompiledPlan> {
        if bucket <= 1 {
            return self.plan.clone();
        }
        if let Some(p) = self.batched.get(&bucket) {
            return p.clone();
        }
        self.lazy_compiles.fetch_add(1, Ordering::Relaxed);
        let (bg, broots) = batch_graph(&self.graph, &self.roots, bucket);
        let plan = global_plan_cache().get_or_compile_opts(
            &bg,
            &broots,
            OptLevel::None,
            self.memory,
            self.backend,
            TraceMode::Off,
        );
        self.batched.insert(bucket, plan.clone());
        plan
    }
}

enum Job {
    Eval {
        inputs: Vec<Tensor>,
        reply: SyncSender<Result<Response>>,
        /// stamped in [`Coordinator::submit`]: queue wait is measured
        /// from here to the worker's drain, so `Response.latency` is
        /// the end-to-end time the caller experienced, not just the
        /// plan execution
        enqueued: Instant,
    },
    Shutdown,
}

/// A completed evaluation. `outputs` are [`PlanOutput`]s: for engine
/// entries they are zero-copy views into the plan's leased run arena
/// (the arena returns to its pool when the last view drops); call
/// [`PlanOutput::to_tensor`] to materialise an owned copy.
#[derive(Debug)]
pub struct Response {
    pub outputs: Vec<PlanOutput>,
    /// end-to-end latency the caller experienced:
    /// `queue_secs + service_secs`
    pub latency: f64,
    /// time the request waited in the worker queue (enqueue → drain)
    pub queue_secs: f64,
    /// time the (possibly batched) plan execution took (drain → reply)
    pub service_secs: f64,
    /// how many requests the worker drained in the same batch
    pub batch_size: usize,
}

struct Worker {
    tx: SyncSender<Job>,
    handle: Option<JoinHandle<()>>,
}

/// Compile-time facts about one registered engine entry, kept on the
/// coordinator after the entry itself moves into its worker thread.
struct EntryInfo {
    opt_stats: Option<OptStats>,
    max_batch: usize,
    prewarmed_buckets: Vec<usize>,
    lazy_compiles: Arc<AtomicU64>,
    prewarm_compiles: Arc<AtomicU64>,
}

/// One entry's row in [`Coordinator::stats`]: the optimizer report its
/// graph was compiled under plus the batched-plan compile counters.
#[derive(Debug, Clone)]
pub struct EntryStats {
    pub name: String,
    /// what the optimizer did before the graph was frozen (None for
    /// entries built at `OptLevel::None`)
    pub opt_stats: Option<OptStats>,
    pub max_batch: usize,
    /// batch buckets compiled at registration by `with_prewarm`
    pub prewarmed_buckets: Vec<usize>,
    /// batch-bucket plans compiled lazily on the serving path
    pub lazy_compiles: u64,
    /// batch-bucket plans compiled eagerly at registration
    pub prewarm_compiles: u64,
}

/// The coordinator: one worker thread per registered entry, bounded
/// queues, shared metrics.
pub struct Coordinator {
    workers: HashMap<String, Worker>,
    infos: HashMap<String, EntryInfo>,
    metrics: Arc<Metrics>,
    queue_cap: usize,
}

impl Coordinator {
    pub fn new(queue_cap: usize) -> Self {
        Coordinator {
            workers: HashMap::new(),
            infos: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            queue_cap,
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Register an engine-backed entry (symbolic expression evaluation).
    /// Re-registering a name replaces the entry: the old worker is shut
    /// down and joined before this returns, so every job it had already
    /// accepted is answered and its thread is reaped (not leaked).
    ///
    /// Registration also wires the entry's compile counters and its
    /// plan's run-state recycling into the metrics gauge surface, so
    /// `Metrics::render_prometheus` exposes them without the worker's
    /// involvement.
    pub fn register_engine(&mut self, name: &str, entry: EngineEntry) {
        let info = EntryInfo {
            opt_stats: entry.opt_stats,
            max_batch: entry.max_batch,
            prewarmed_buckets: entry.compiled_buckets(),
            lazy_compiles: entry.lazy_compiles.clone(),
            prewarm_compiles: entry.prewarm_compiles.clone(),
        };
        let labels = format!("entry=\"{}\"", name);
        let lazy = info.lazy_compiles.clone();
        self.metrics.register_gauge("tensorcalc_lazy_compiles", &labels, move || {
            lazy.load(Ordering::Relaxed) as f64
        });
        let prewarmed = info.prewarm_compiles.clone();
        self.metrics.register_gauge("tensorcalc_prewarm_compiles", &labels, move || {
            prewarmed.load(Ordering::Relaxed) as f64
        });
        let plan = entry.plan.clone();
        self.metrics.register_gauge("tensorcalc_lease_state_reuse", &labels, move || {
            plan.pool_stats().state_reuse as f64
        });
        self.infos.insert(name.to_string(), info);
        let (tx, rx) = sync_channel::<Job>(self.queue_cap);
        let metrics = self.metrics.clone();
        let ename = name.to_string();
        let handle = std::thread::spawn(move || {
            engine_worker(ename, entry, rx, metrics);
        });
        self.insert_worker(name.to_string(), Worker { tx, handle: Some(handle) });
    }

    /// Per-entry compile/optimizer statistics, sorted by entry name.
    /// Covers engine entries only (PJRT entries have no optimizer run
    /// or batched variants to report).
    pub fn stats(&self) -> Vec<EntryStats> {
        let mut v: Vec<EntryStats> = self
            .infos
            .iter()
            .map(|(name, i)| EntryStats {
                name: name.clone(),
                opt_stats: i.opt_stats,
                max_batch: i.max_batch,
                prewarmed_buckets: i.prewarmed_buckets.clone(),
                lazy_compiles: i.lazy_compiles.load(Ordering::Relaxed),
                prewarm_compiles: i.prewarm_compiles.load(Ordering::Relaxed),
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Install a worker under `name`, shutting down and joining any
    /// worker previously registered there (the duplicate-registration
    /// leak fix: dropping the old `Worker` silently detached its
    /// thread — handle never joined, in-flight work unobservable).
    fn insert_worker(&mut self, name: String, worker: Worker) {
        if let Some(old) = self.workers.insert(name, worker) {
            Self::stop_worker(old);
        }
    }

    /// Shut down one worker and join its thread. Mirrors the
    /// [`Coordinator::shutdown`] contract: the try_send is a best-effort
    /// nudge, the sender drop is the authoritative signal, and the join
    /// happens only after the drop so a full queue cannot deadlock.
    fn stop_worker(w: Worker) {
        let Worker { tx, handle } = w;
        let _ = tx.try_send(Job::Shutdown);
        drop(tx);
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Register every listed artifact under `dir` as a PJRT-backed
    /// entry. PJRT handles are not `Send`, so the backend worker thread
    /// opens the [`Runtime`] itself and routes jobs by entry name; an
    /// open failure is reported back through this call.
    pub fn register_runtime(
        &mut self,
        dir: std::path::PathBuf,
        names: &[String],
    ) -> Result<()> {
        let (tx, rx) = sync_channel::<(String, Job)>(self.queue_cap);
        let metrics = self.metrics.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let backend = std::thread::spawn(move || {
            let runtime = match Runtime::open(&dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            pjrt_worker(runtime, rx, metrics);
        });
        ready_rx.recv().map_err(|_| anyhow!("pjrt backend died"))??;
        for name in names {
            let (ftx, frx) = sync_channel::<Job>(self.queue_cap);
            let tx2 = tx.clone();
            let n2 = name.clone();
            let fh = std::thread::spawn(move || {
                while let Ok(job) = frx.recv() {
                    if matches!(job, Job::Shutdown) {
                        break;
                    }
                    if tx2.send((n2.clone(), job)).is_err() {
                        break;
                    }
                }
            });
            self.insert_worker(name.clone(), Worker { tx: ftx, handle: Some(fh) });
        }
        // shutdown guard: dropping the last fan-in sender stops the backend
        let (gtx, grx) = sync_channel::<Job>(1);
        let gh = std::thread::spawn(move || {
            let _ = grx.recv();
            drop(tx);
            let _ = backend.join();
        });
        self.insert_worker("__pjrt_backend".into(), Worker { tx: gtx, handle: Some(gh) });
        Ok(())
    }

    /// Submit asynchronously; returns a receiver for the response.
    /// Errors immediately if the entry is unknown or its queue is full
    /// (backpressure surfaces to the caller).
    pub fn submit(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Receiver<Result<Response>>> {
        let w = self
            .workers
            .get(entry)
            .ok_or_else(|| anyhow!("unknown entry {}", entry))?;
        let (rtx, rrx) = sync_channel(1);
        w.tx
            .try_send(Job::Eval { inputs, reply: rtx, enqueued: Instant::now() })
            .map_err(|e| anyhow!("queue full / closed for {}: {}", entry, e))?;
        self.metrics.submitted();
        self.metrics.enqueued();
        Ok(rrx)
    }

    /// Blocking evaluation.
    pub fn eval(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Response> {
        let rx = self.submit(entry, inputs)?;
        rx.recv().map_err(|_| anyhow!("worker dropped"))?
    }

    /// Registered entry names (excluding internal workers).
    pub fn entries(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .workers
            .keys()
            .filter(|k| !k.starts_with("__"))
            .cloned()
            .collect();
        v.sort();
        v
    }

    /// Stop all workers and wait for them.
    ///
    /// The authoritative shutdown signal is *dropping every sender
    /// before joining any worker*: a `try_send(Job::Shutdown)` alone
    /// fails silently when a queue is full, and joining while the
    /// sender is still alive would then deadlock (the worker blocks in
    /// `recv` forever). Workers treat channel closure as shutdown and
    /// still drain (and answer) every job buffered before the close.
    /// All senders drop before the first join so that fan-in topologies
    /// (the PJRT backend) cannot wedge on a sibling's queue either.
    pub fn shutdown(&mut self) {
        let mut handles = Vec::new();
        for (_, mut w) in self.workers.drain() {
            // best-effort nudge for an idle worker; the sender drop at
            // the end of this iteration is what guarantees progress
            let _ = w.tx.try_send(Job::Shutdown);
            if let Some(h) = w.handle.take() {
                handles.push(h);
            }
        }
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Engine worker: drains the queue and serves the drained eval jobs in
/// micro-batches of up to `entry.max_batch` requests, each batch one
/// batched plan execution (see the module docs). A `Shutdown` drained
/// mid-batch does not abort the batch: every eval job drained alongside
/// it is still answered before the worker exits, and `batch_size`
/// counts eval jobs only. Channel closure (all senders dropped) is
/// treated as shutdown too. A panic inside plan execution is caught,
/// answered to every affected caller as an `Err`, counted in the error
/// metrics — and the worker stays alive for the next request.
fn engine_worker(name: String, mut entry: EngineEntry, rx: Receiver<Job>, metrics: Arc<Metrics>) {
    while let Ok(first) = rx.recv() {
        let mut jobs = vec![first];
        while let Ok(j) = rx.try_recv() {
            jobs.push(j);
        }
        let mut shutdown = false;
        let mut evals = Vec::new();
        for job in jobs {
            match job {
                Job::Shutdown => shutdown = true,
                Job::Eval { inputs, reply, enqueued } => {
                    metrics.dequeued();
                    evals.push((inputs, reply, enqueued));
                }
            }
        }
        let batch = evals.len();
        // validate per request up front: a malformed request is answered
        // individually and cannot poison the stacked batch
        let mut valid = Vec::with_capacity(evals.len());
        for (inputs, reply, enqueued) in evals {
            match validate_inputs(&entry, &inputs) {
                Ok(()) => valid.push((inputs, reply, enqueued)),
                Err(e) => {
                    metrics.observe(&name, enqueued.elapsed().as_secs_f64(), 0.0, 1, true);
                    let _ = reply.send(Err(e));
                }
            }
        }
        while !valid.is_empty() {
            let take = valid.len().min(entry.max_batch.max(1));
            let chunk: Vec<_> = valid.drain(..take).collect();
            run_chunk(&name, &mut entry, chunk, batch, &metrics);
        }
        if shutdown {
            return;
        }
    }
}

/// Run one micro-batch: a single request executes the base plan, a
/// larger one stacks inputs into the next power-of-two bucket (padding
/// with copies of request 0) and executes the bucket's batched plan
/// once. Both paths return leased zero-copy outputs and run under
/// `catch_unwind`, so a panicking plan answers its callers instead of
/// killing the worker.
///
/// Timing: queue wait runs per request from its enqueue stamp to the
/// drain point here; the service clock starts after the drain and
/// covers stacking + execution, shared by every request in the chunk.
/// `Response.latency` is the sum — the pre-PR accounting started the
/// clock after the drain, silently excluding queue wait.
fn run_chunk(
    name: &str,
    entry: &mut EngineEntry,
    chunk: Vec<(Vec<Tensor>, SyncSender<Result<Response>>, Instant)>,
    batch: usize,
    metrics: &Metrics,
) {
    let n = chunk.len();
    let drained = Instant::now();
    let mut ins = Vec::with_capacity(n);
    let mut replies = Vec::with_capacity(n);
    let mut queue_waits = Vec::with_capacity(n);
    for (inputs, reply, enqueued) in chunk {
        queue_waits.push(drained.duration_since(enqueued).as_secs_f64());
        ins.push(inputs);
        replies.push(reply);
    }
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(move || -> Vec<Vec<PlanOutput>> {
        if n == 1 {
            let mut env = Env::new();
            let req = ins.into_iter().next().expect("chunk of one");
            for ((vname, _), t) in entry.inputs.iter().zip(req) {
                env.insert(vname, t);
            }
            return vec![entry.plan.clone().run_leased(&env)];
        }
        let bucket = n.next_power_of_two().min(entry.max_batch).max(n);
        let plan = entry.batched_plan(bucket);
        let mut env = Env::new();
        for (k, (vname, shape)) in entry.inputs.iter().enumerate() {
            let len: usize = shape.iter().product();
            let mut data = Vec::with_capacity(bucket * len);
            for req in &ins {
                data.extend_from_slice(req[k].data());
            }
            for _ in n..bucket {
                // pad slots are computed and thrown away; the batch axis
                // is never contracted, so they cannot affect live slots
                data.extend_from_slice(ins[0][k].data());
            }
            let mut bshape = vec![bucket];
            bshape.extend_from_slice(shape);
            env.insert(vname, Tensor::new(&bshape, data));
        }
        let outs = plan.run_leased(&env);
        (0..n)
            .map(|i| outs.iter().map(|o| o.batch_slice(i, bucket)).collect())
            .collect()
    }));
    let service = t0.elapsed().as_secs_f64();
    match outcome {
        Ok(per_req) => {
            for ((outputs, reply), queue) in per_req.into_iter().zip(replies).zip(queue_waits) {
                metrics.observe(name, queue, service, batch, false);
                let _ = reply.send(Ok(Response {
                    outputs,
                    latency: queue + service,
                    queue_secs: queue,
                    service_secs: service,
                    batch_size: batch,
                }));
            }
        }
        Err(payload) => {
            let msg = panic_message(payload.as_ref());
            for (reply, queue) in replies.into_iter().zip(queue_waits) {
                metrics.observe(name, queue, service, batch, true);
                let _ = reply
                    .send(Err(anyhow!("plan execution panicked for entry {}: {}", name, msg)));
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn validate_inputs(entry: &EngineEntry, inputs: &[Tensor]) -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        bail!("expected {} inputs, got {}", entry.inputs.len(), inputs.len());
    }
    for ((name, shape), t) in entry.inputs.iter().zip(inputs) {
        if t.shape() != &shape[..] {
            bail!("input {} shape {:?}, expected {:?}", name, t.shape(), shape);
        }
    }
    Ok(())
}

/// PJRT worker: owns the runtime, routes jobs by artifact name.
fn pjrt_worker(mut runtime: Runtime, rx: Receiver<(String, Job)>, metrics: Arc<Metrics>) {
    while let Ok((name, job)) = rx.recv() {
        match job {
            Job::Shutdown => return,
            Job::Eval { inputs, reply, enqueued } => {
                metrics.dequeued();
                let queue = enqueued.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let res = runtime.execute(&name, &inputs);
                let service = t0.elapsed().as_secs_f64();
                metrics.observe(&name, queue, service, 1, res.is_err());
                let res = res.map(|outputs| Response {
                    outputs: outputs.into_iter().map(PlanOutput::from).collect(),
                    latency: queue + service,
                    queue_secs: queue,
                    service_secs: service,
                    batch_size: 1,
                });
                let _ = reply.send(res);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::reverse::reverse_gradient;
    use crate::simplify::simplify_one;

    /// The logreg value+gradient graph the serving tests revolve around.
    fn logreg_grad_graph(m: usize, n: usize) -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new();
        let x = g.var("X", &[m, n]);
        let y = g.var("y", &[m]);
        let w = g.var("w", &[n]);
        let xw = g.matvec(x, w);
        let yxw = g.hadamard(y, xw);
        let t = g.neg(yxw);
        let e = g.elem(crate::ir::Elem::Exp, t);
        let one = g.constant(1.0, &[m]);
        let s = g.add(e, one);
        let l = g.elem(crate::ir::Elem::Log, s);
        let loss = g.sum_all(l);
        let grad = reverse_gradient(&mut g, loss, w);
        let grad = simplify_one(&mut g, grad);
        (g, vec![loss, grad])
    }

    fn logreg_grad_entry(m: usize, n: usize) -> EngineEntry {
        logreg_grad_entry_mem(m, n, crate::exec::ExecMemory::default())
    }

    fn logreg_grad_entry_mem(
        m: usize,
        n: usize,
        memory: crate::exec::ExecMemory,
    ) -> EngineEntry {
        logreg_grad_entry_opts(m, n, memory, BackendKind::default())
    }

    fn logreg_grad_entry_opts(
        m: usize,
        n: usize,
        memory: crate::exec::ExecMemory,
        backend: BackendKind,
    ) -> EngineEntry {
        let (g, roots) = logreg_grad_graph(m, n);
        EngineEntry::compiled_with(
            &g,
            &roots,
            vec![
                ("X".into(), vec![m, n]),
                ("y".into(), vec![m]),
                ("w".into(), vec![n]),
            ],
            crate::opt::OptLevel::default(),
            memory,
            backend,
        )
    }

    fn logreg_inputs(m: usize, n: usize, seed: u64) -> Vec<Tensor> {
        vec![
            Tensor::randn(&[m, n], seed),
            Tensor::randn(&[m], seed + 1).map(f64::signum),
            Tensor::randn(&[n], seed + 2),
        ]
    }

    fn logreg_env(m: usize, n: usize, seed: u64) -> Env {
        let inputs = logreg_inputs(m, n, seed);
        let mut env = Env::new();
        for (name, t) in ["X", "y", "w"].into_iter().zip(inputs) {
            env.insert(name, t);
        }
        env
    }

    /// A hand-built eval job for tests that drive `engine_worker`
    /// directly, stamped now (as `Coordinator::submit` would).
    fn eval_job(inputs: Vec<Tensor>, reply: SyncSender<Result<Response>>) -> Job {
        Job::Eval { inputs, reply, enqueued: Instant::now() }
    }

    #[test]
    fn engine_entry_roundtrip() {
        let mut c = Coordinator::new(16);
        c.register_engine("logreg_grad", logreg_grad_entry(8, 3));
        let resp = c.eval("logreg_grad", logreg_inputs(8, 3, 1)).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        assert_eq!(resp.outputs[1].shape(), &[3]);
        assert!(resp.latency >= 0.0);
    }

    #[test]
    fn latency_is_queue_wait_plus_service_time() {
        let mut c = Coordinator::new(16);
        c.register_engine("e", logreg_grad_entry(8, 3));
        let resp = c.eval("e", logreg_inputs(8, 3, 1)).unwrap();
        assert!(resp.queue_secs >= 0.0);
        assert!(resp.service_secs > 0.0, "plan execution takes nonzero time");
        let sum = resp.queue_secs + resp.service_secs;
        assert!(
            (resp.latency - sum).abs() < 1e-12,
            "latency {} must equal queue {} + service {}",
            resp.latency,
            resp.queue_secs,
            resp.service_secs
        );
    }

    #[test]
    fn stats_surface_reports_optimizer_and_compile_counters() {
        let mut c = Coordinator::new(16);
        c.register_engine("warm", logreg_grad_entry(8, 3).with_max_batch(8).with_prewarm(true));
        c.register_engine("cold", logreg_grad_entry(8, 3));
        let stats = c.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].name, "cold");
        assert_eq!(stats[1].name, "warm");
        let warm = &stats[1];
        // entries compile at the default (Full) level, so the optimizer
        // report must ride along
        let os = warm.opt_stats.expect("optimized entry must carry OptStats");
        assert!(os.nodes_before >= os.nodes_after);
        assert_eq!(warm.prewarmed_buckets, vec![2, 4, 8]);
        assert_eq!(warm.prewarm_compiles, 3);
        assert_eq!(warm.lazy_compiles, 0);
        assert_eq!(stats[0].prewarmed_buckets, Vec::<usize>::new());
        assert_eq!(stats[0].prewarm_compiles, 0);
        // the registration gauges surface the same counters per entry
        let prom = c.metrics().render_prometheus();
        assert!(prom.contains("tensorcalc_prewarm_compiles{entry=\"warm\"} 3"), "{prom}");
        assert!(prom.contains("tensorcalc_lazy_compiles{entry=\"cold\"} 0"), "{prom}");
        c.shutdown();
    }

    #[test]
    fn planned_and_pooled_entries_agree() {
        use crate::exec::ExecMemory;
        let mut c = Coordinator::new(16);
        c.register_engine("planned", logreg_grad_entry_mem(8, 3, ExecMemory::Planned));
        c.register_engine("pooled", logreg_grad_entry_mem(8, 3, ExecMemory::Pooled));
        let inputs = logreg_inputs(8, 3, 1);
        let a = c.eval("planned", inputs.clone()).unwrap();
        let b = c.eval("pooled", inputs).unwrap();
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(ta.data(), tb.data(), "entry memory modes diverged");
        }
    }

    #[test]
    fn backend_entries_agree_bitwise() {
        // per-entry backend choice: a direct-threaded entry serves
        // bit-identical responses to the default cpu entry
        let mut c = Coordinator::new(16);
        c.register_engine(
            "cpu",
            logreg_grad_entry_opts(8, 3, ExecMemory::default(), BackendKind::Cpu),
        );
        c.register_engine(
            "direct",
            logreg_grad_entry_opts(8, 3, ExecMemory::default(), BackendKind::Direct),
        );
        let inputs = logreg_inputs(8, 3, 1);
        let a = c.eval("cpu", inputs.clone()).unwrap();
        let b = c.eval("direct", inputs).unwrap();
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(ta.data(), tb.data(), "entry backends diverged");
        }
    }

    #[test]
    fn prewarm_eliminates_serving_path_compiles() {
        // queue 5 requests before the worker starts so one drain forms a
        // multi-request bucket — the case that lazily compiles a batched
        // plan unless the entry was prewarmed
        let drive = |entry: EngineEntry| -> u64 {
            let counter = entry.lazy_compile_counter();
            let metrics = Arc::new(Metrics::new());
            let (tx, rx) = sync_channel::<Job>(8);
            let mut replies = Vec::new();
            for i in 0..5u64 {
                let (rtx, rrx) = sync_channel(1);
                tx.send(eval_job(logreg_inputs(8, 3, i), rtx)).unwrap();
                replies.push(rrx);
            }
            drop(tx);
            engine_worker("e".into(), entry, rx, metrics);
            for rrx in replies {
                rrx.recv().expect("reply dropped").unwrap();
            }
            counter.load(Ordering::Relaxed)
        };
        let cold = drive(logreg_grad_entry(8, 3));
        assert!(cold > 0, "an un-prewarmed entry must compile its bucket lazily");
        let warm = drive(logreg_grad_entry(8, 3).with_max_batch(8).with_prewarm(true));
        assert_eq!(warm, 0, "a prewarmed entry must never compile on the serving path");
    }

    #[test]
    fn unknown_entry_errors() {
        let c = Coordinator::new(4);
        assert!(c.submit("nope", vec![]).is_err());
    }

    #[test]
    fn wrong_shape_is_reported_not_panicking() {
        let mut c = Coordinator::new(4);
        c.register_engine("e", logreg_grad_entry(8, 3));
        let bad = vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[8]), Tensor::zeros(&[3])];
        let resp = c.eval("e", bad);
        assert!(resp.is_err());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let mut c = Coordinator::new(64);
        c.register_engine("e", logreg_grad_entry(16, 4));
        let mut rxs = Vec::new();
        for i in 0..32 {
            rxs.push(c.submit("e", logreg_inputs(16, 4, i)).unwrap());
        }
        let mut max_batch = 0;
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            max_batch = max_batch.max(r.batch_size);
        }
        assert!(max_batch >= 1);
        let stats = c.metrics().snapshot();
        assert_eq!(stats.completed, 32);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn backpressure_queue_full() {
        let mut c = Coordinator::new(1);
        c.register_engine("e", logreg_grad_entry(64, 16));
        let mut errs = 0;
        let mut oks = Vec::new();
        for i in 0..64 {
            match c.submit("e", logreg_inputs(64, 16, i)) {
                Ok(rx) => oks.push(rx),
                Err(_) => errs += 1,
            }
        }
        for rx in oks {
            let _ = rx.recv();
        }
        // with queue_cap=1 and 64 rapid submits, backpressure should trigger
        assert!(errs > 0, "expected backpressure with cap=1");
    }

    #[test]
    fn shutdown_with_saturated_cap1_queue_terminates() {
        let mut c = Coordinator::new(1);
        c.register_engine("e", logreg_grad_entry(64, 16));
        // saturate the cap-1 queue so try_send(Shutdown) will fail
        let mut accepted = Vec::new();
        for i in 0..16 {
            if let Ok(rx) = c.submit("e", logreg_inputs(64, 16, i)) {
                accepted.push(rx);
            }
        }
        let (done_tx, done_rx) = sync_channel::<()>(1);
        let h = std::thread::spawn(move || {
            c.shutdown();
            drop(c);
            let _ = done_tx.send(());
        });
        assert!(
            done_rx.recv_timeout(std::time::Duration::from_secs(60)).is_ok(),
            "Coordinator::shutdown deadlocked on a full queue"
        );
        h.join().unwrap();
        // every accepted job was answered before the worker exited
        for rx in accepted {
            let resp = rx.recv().expect("reply dropped on shutdown");
            assert!(resp.is_ok());
        }
    }

    #[test]
    fn mid_batch_shutdown_answers_drained_jobs() {
        // Deterministic mid-batch shutdown: queue [Eval, Shutdown, Eval]
        // before the worker starts, so one drain sees all three.
        let entry = logreg_grad_entry(8, 3);
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Job>(8);
        let (r1tx, r1rx) = sync_channel(1);
        let (r2tx, r2rx) = sync_channel(1);
        tx.send(eval_job(logreg_inputs(8, 3, 1), r1tx)).unwrap();
        tx.send(Job::Shutdown).unwrap();
        tx.send(eval_job(logreg_inputs(8, 3, 10), r2tx)).unwrap();
        drop(tx);
        engine_worker("e".into(), entry, rx, metrics.clone());
        let a = r1rx.recv().expect("first reply dropped").unwrap();
        let b = r2rx.recv().expect("eval after mid-batch Shutdown dropped").unwrap();
        assert_eq!(a.batch_size, 2, "Shutdown must not count toward the eval batch");
        assert_eq!(b.batch_size, 2);
        assert_eq!(metrics.snapshot().completed, 2);
    }

    #[test]
    fn mid_batch_shutdown_answers_drained_jobs_batched() {
        // The batched-path variant: enough evals around the Shutdown to
        // force a real multi-request bucket, every one still answered.
        let entry = logreg_grad_entry(8, 3);
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Job>(16);
        let mut replies = Vec::new();
        for i in 0..2u64 {
            let (rtx, rrx) = sync_channel(1);
            tx.send(eval_job(logreg_inputs(8, 3, 20 + i), rtx)).unwrap();
            replies.push(rrx);
        }
        tx.send(Job::Shutdown).unwrap();
        for i in 2..5u64 {
            let (rtx, rrx) = sync_channel(1);
            tx.send(eval_job(logreg_inputs(8, 3, 20 + i), rtx)).unwrap();
            replies.push(rrx);
        }
        drop(tx);
        engine_worker("e".into(), entry, rx, metrics.clone());
        for rrx in replies {
            let resp = rrx.recv().expect("drained eval dropped on shutdown").unwrap();
            assert_eq!(resp.batch_size, 5);
        }
        assert_eq!(metrics.snapshot().completed, 5);
        assert_eq!(metrics.snapshot().errors, 0);
    }

    #[test]
    fn batched_run_bit_identical_to_sequential() {
        // Queue 5 requests before the worker starts: one drain, one
        // batched execution (bucket 8, so padding is exercised too).
        // Every slice must match a sequential base-plan run bitwise.
        let entry = logreg_grad_entry(8, 3);
        let base = entry.plan.clone();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Job>(8);
        let mut replies = Vec::new();
        for i in 0..5u64 {
            let (rtx, rrx) = sync_channel(1);
            tx.send(eval_job(logreg_inputs(8, 3, i * 10), rtx)).unwrap();
            replies.push((i, rrx));
        }
        drop(tx);
        engine_worker("e".into(), entry, rx, metrics.clone());
        for (i, rrx) in replies {
            let resp = rrx.recv().unwrap().unwrap();
            assert_eq!(resp.batch_size, 5);
            let want = base.run(&logreg_env(8, 3, i * 10));
            assert_eq!(resp.outputs.len(), want.len());
            for (o, w) in resp.outputs.iter().zip(&want) {
                assert_eq!(o.shape(), w.shape());
                assert_eq!(o.data(), w.data(), "batched slice diverged from sequential run");
            }
        }
        assert_eq!(metrics.snapshot().completed, 5);
        assert_eq!(metrics.snapshot().errors, 0);
    }

    #[test]
    fn batch_ablation_is_bit_identical() {
        // The ablation axis: a max_batch=1 entry must serve bit-identical
        // results to the batched entry for identical inputs.
        let mut c = Coordinator::new(64);
        c.register_engine("on", logreg_grad_entry(8, 3));
        c.register_engine("off", logreg_grad_entry(8, 3).with_max_batch(1));
        let mut pairs = Vec::new();
        for i in 0..12 {
            pairs.push((
                c.submit("on", logreg_inputs(8, 3, i)).unwrap(),
                c.submit("off", logreg_inputs(8, 3, i)).unwrap(),
            ));
        }
        for (a, b) in pairs {
            let ra = a.recv().unwrap().unwrap();
            let rb = b.recv().unwrap().unwrap();
            assert_eq!(ra.outputs.len(), rb.outputs.len());
            for (x, y) in ra.outputs.iter().zip(&rb.outputs) {
                assert_eq!(x.data(), y.data(), "batching ablation diverged");
            }
        }
    }

    #[test]
    fn concurrent_mixed_entries_match_direct_plans() {
        // Concurrent submitters across two entries with different shapes;
        // every response must be bit-identical to a direct base-plan run.
        let mut c = Coordinator::new(256);
        c.register_engine("small", logreg_grad_entry(8, 3));
        c.register_engine("big", logreg_grad_entry(16, 4));
        let plans =
            [logreg_grad_entry(8, 3).plan.clone(), logreg_grad_entry(16, 4).plan.clone()];
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let c = &c;
                let plans = &plans;
                s.spawn(move || {
                    for i in 0..8u64 {
                        let seed = t * 100 + i;
                        let which = ((t + i) % 2) as usize;
                        let (m, n) = [(8, 3), (16, 4)][which];
                        let name = ["small", "big"][which];
                        let resp = c.eval(name, logreg_inputs(m, n, seed)).unwrap();
                        let want = plans[which].run(&logreg_env(m, n, seed));
                        assert_eq!(resp.outputs.len(), want.len());
                        for (o, w) in resp.outputs.iter().zip(&want) {
                            assert_eq!(o.data(), w.data(), "served output diverged bitwise");
                        }
                    }
                });
            }
        });
        let stats = c.metrics().snapshot();
        assert_eq!(stats.completed, 32);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn panic_in_plan_is_isolated() {
        // An entry whose declared inputs omit a graph variable: the plan
        // panics ("unbound variable w") at run time. The worker must
        // answer with Err, count the error, and stay alive.
        let (g, roots) = logreg_grad_graph(8, 3);
        let entry = EngineEntry::compiled(
            &g,
            &roots,
            vec![("X".into(), vec![8, 3]), ("y".into(), vec![8])],
        );
        let mut c = Coordinator::new(8);
        c.register_engine("boom", entry);
        c.register_engine("ok", logreg_grad_entry(8, 3));
        let bad = vec![Tensor::randn(&[8, 3], 1), Tensor::randn(&[8], 2).map(f64::signum)];
        let r1 = c.eval("boom", bad.clone());
        assert!(r1.is_err(), "panicking plan must answer with Err");
        let r2 = c.eval("boom", bad);
        assert!(r2.is_err(), "worker must survive the panic and keep answering");
        // healthy entries in the same coordinator are unaffected
        let ok = c.eval("ok", logreg_inputs(8, 3, 5)).unwrap();
        assert_eq!(ok.outputs.len(), 2);
        let stats = c.metrics().snapshot();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.errors, 2);
        c.shutdown();
    }

    #[test]
    fn re_registration_joins_replaced_worker() {
        let mut c = Coordinator::new(64);
        c.register_engine("e", logreg_grad_entry(64, 16));
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(c.submit("e", logreg_inputs(64, 16, i)).unwrap());
        }
        // replacing the entry must shut down and *join* the old worker:
        // by the time register_engine returns, every job it accepted has
        // been answered (pre-fix the old thread was silently detached)
        c.register_engine("e", logreg_grad_entry(8, 3));
        for rx in rxs {
            let resp = rx
                .try_recv()
                .expect("replaced worker must answer accepted jobs before registration returns");
            assert!(resp.is_ok());
        }
        // the new worker serves the new signature, and shutdown after
        // re-registration stays clean
        let resp = c.eval("e", logreg_inputs(8, 3, 99)).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        c.shutdown();
    }

    #[test]
    fn pjrt_backend_through_coordinator() {
        let Some(dir) = crate::runtime::artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let mut c = Coordinator::new(8);
        c.register_runtime(dir.clone(), &["logreg_val_grad".to_string()]).unwrap();
        let x = crate::runtime::read_f32_raw(dir.join("check/logreg_X.f32"), &[256, 128]).unwrap();
        let y = crate::runtime::read_f32_raw(dir.join("check/logreg_y.f32"), &[256]).unwrap();
        let w = crate::runtime::read_f32_raw(dir.join("check/logreg_w.f32"), &[128]).unwrap();
        let resp = c.eval("logreg_val_grad", vec![w, x, y]).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        let grad =
            crate::runtime::read_f32_raw(dir.join("check/logreg_grad.f32"), &[128]).unwrap();
        assert!(resp.outputs[1].allclose(&grad, 1e-4, 1e-4));
    }
}
