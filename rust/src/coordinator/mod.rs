//! The derivative-evaluation service: a request router + per-entry
//! worker with bounded queues (backpressure), serving two backends —
//! the symbolic engine (expression DAG + [`CompiledPlan`]) and the PJRT
//! executables loaded by [`crate::runtime`].
//!
//! The paper's contribution is the calculus itself, so this layer is a
//! thin-but-real coordinator: the end-to-end example and `tensorcalc
//! serve` drive batched gradient/Hessian requests through it and report
//! throughput/latency.

mod metrics;
pub use metrics::{Metrics, Snapshot};

use crate::error::Result;
use crate::eval::Env;
use crate::exec::{global_plan_cache, CompiledPlan};
use crate::ir::{Graph, NodeId};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// An engine-backed entry: a *compiled* plan (pooled buffers,
/// level-parallel execution — see [`crate::exec`]) plus a fixed input
/// signature. The graph itself is not retained — the plan is
/// self-contained — and the plan comes from the global plan cache, so
/// re-registering the same graph (the repeated-request hot path) reuses
/// the compiled artifact and its warm buffer pool.
pub struct EngineEntry {
    pub plan: Arc<CompiledPlan>,
    /// variable names in submission order, with expected shapes
    pub inputs: Vec<(String, Vec<usize>)>,
}

impl EngineEntry {
    /// Compile `roots` of `graph` (through the global plan cache) into a
    /// servable entry at the default optimizer level and memory
    /// discipline (planned arena).
    pub fn compiled(
        graph: &Graph,
        roots: &[NodeId],
        inputs: Vec<(String, Vec<usize>)>,
    ) -> Self {
        let plan = global_plan_cache().get_or_compile(graph, roots);
        EngineEntry { plan, inputs }
    }

    /// [`EngineEntry::compiled`] with the optimizer level and executor
    /// memory discipline explicit — the coordinator-side end of the
    /// `ExecMemory` ablation. All entries share the process-wide
    /// persistent worker pool regardless of mode, so the level
    /// scheduler of repeated request bursts spawns no threads.
    pub fn compiled_with(
        graph: &Graph,
        roots: &[NodeId],
        inputs: Vec<(String, Vec<usize>)>,
        level: crate::opt::OptLevel,
        memory: crate::exec::ExecMemory,
    ) -> Self {
        let plan = global_plan_cache().get_or_compile_opts(graph, roots, level, memory);
        EngineEntry { plan, inputs }
    }
}

enum Job {
    Eval { inputs: Vec<Tensor>, reply: SyncSender<Result<Response>> },
    Shutdown,
}

/// A completed evaluation.
#[derive(Debug)]
pub struct Response {
    pub outputs: Vec<Tensor>,
    pub latency: f64,
    /// how many requests the worker drained in the same batch
    pub batch_size: usize,
}

struct Worker {
    tx: SyncSender<Job>,
    handle: Option<JoinHandle<()>>,
}

/// The coordinator: one worker thread per registered entry, bounded
/// queues, shared metrics.
pub struct Coordinator {
    workers: HashMap<String, Worker>,
    metrics: Arc<Metrics>,
    queue_cap: usize,
}

impl Coordinator {
    pub fn new(queue_cap: usize) -> Self {
        Coordinator { workers: HashMap::new(), metrics: Arc::new(Metrics::new()), queue_cap }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Register an engine-backed entry (symbolic expression evaluation).
    pub fn register_engine(&mut self, name: &str, entry: EngineEntry) {
        let (tx, rx) = sync_channel::<Job>(self.queue_cap);
        let metrics = self.metrics.clone();
        let ename = name.to_string();
        let handle = std::thread::spawn(move || {
            engine_worker(ename, entry, rx, metrics);
        });
        self.workers
            .insert(name.to_string(), Worker { tx, handle: Some(handle) });
    }

    /// Register every listed artifact under `dir` as a PJRT-backed
    /// entry. PJRT handles are not `Send`, so the backend worker thread
    /// opens the [`Runtime`] itself and routes jobs by entry name; an
    /// open failure is reported back through this call.
    pub fn register_runtime(
        &mut self,
        dir: std::path::PathBuf,
        names: &[String],
    ) -> Result<()> {
        let (tx, rx) = sync_channel::<(String, Job)>(self.queue_cap);
        let metrics = self.metrics.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let backend = std::thread::spawn(move || {
            let runtime = match Runtime::open(&dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            pjrt_worker(runtime, rx, metrics);
        });
        ready_rx.recv().map_err(|_| anyhow!("pjrt backend died"))??;
        for name in names {
            let (ftx, frx) = sync_channel::<Job>(self.queue_cap);
            let tx2 = tx.clone();
            let n2 = name.clone();
            let fh = std::thread::spawn(move || {
                while let Ok(job) = frx.recv() {
                    if matches!(job, Job::Shutdown) {
                        break;
                    }
                    if tx2.send((n2.clone(), job)).is_err() {
                        break;
                    }
                }
            });
            self.workers
                .insert(name.clone(), Worker { tx: ftx, handle: Some(fh) });
        }
        // shutdown guard: dropping the last fan-in sender stops the backend
        let (gtx, grx) = sync_channel::<Job>(1);
        let gh = std::thread::spawn(move || {
            let _ = grx.recv();
            drop(tx);
            let _ = backend.join();
        });
        self.workers
            .insert("__pjrt_backend".into(), Worker { tx: gtx, handle: Some(gh) });
        Ok(())
    }

    /// Submit asynchronously; returns a receiver for the response.
    /// Errors immediately if the entry is unknown or its queue is full
    /// (backpressure surfaces to the caller).
    pub fn submit(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Receiver<Result<Response>>> {
        let w = self
            .workers
            .get(entry)
            .ok_or_else(|| anyhow!("unknown entry {}", entry))?;
        let (rtx, rrx) = sync_channel(1);
        w.tx
            .try_send(Job::Eval { inputs, reply: rtx })
            .map_err(|e| anyhow!("queue full / closed for {}: {}", entry, e))?;
        self.metrics.submitted();
        Ok(rrx)
    }

    /// Blocking evaluation.
    pub fn eval(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Response> {
        let rx = self.submit(entry, inputs)?;
        rx.recv().map_err(|_| anyhow!("worker dropped"))?
    }

    /// Registered entry names (excluding internal workers).
    pub fn entries(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .workers
            .keys()
            .filter(|k| !k.starts_with("__"))
            .cloned()
            .collect();
        v.sort();
        v
    }

    /// Stop all workers and wait for them.
    ///
    /// The authoritative shutdown signal is *dropping every sender
    /// before joining any worker*: a `try_send(Job::Shutdown)` alone
    /// fails silently when a queue is full, and joining while the
    /// sender is still alive would then deadlock (the worker blocks in
    /// `recv` forever). Workers treat channel closure as shutdown and
    /// still drain (and answer) every job buffered before the close.
    /// All senders drop before the first join so that fan-in topologies
    /// (the PJRT backend) cannot wedge on a sibling's queue either.
    pub fn shutdown(&mut self) {
        let mut handles = Vec::new();
        for (_, mut w) in self.workers.drain() {
            // best-effort nudge for an idle worker; the sender drop at
            // the end of this iteration is what guarantees progress
            let _ = w.tx.try_send(Job::Shutdown);
            if let Some(h) = w.handle.take() {
                handles.push(h);
            }
        }
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Engine worker: drains the queue (micro-batching: everything already
/// queued is processed back-to-back and reported as one batch). A
/// `Shutdown` drained mid-batch does not abort the batch: every eval
/// job drained alongside it is still answered before the worker exits,
/// and `batch_size` counts eval jobs only. Channel closure (all senders
/// dropped) is treated as shutdown too.
fn engine_worker(name: String, entry: EngineEntry, rx: Receiver<Job>, metrics: Arc<Metrics>) {
    while let Ok(first) = rx.recv() {
        let mut jobs = vec![first];
        while let Ok(j) = rx.try_recv() {
            jobs.push(j);
        }
        let batch = jobs.iter().filter(|j| matches!(j, Job::Eval { .. })).count();
        let mut shutdown = false;
        for job in jobs {
            match job {
                Job::Shutdown => shutdown = true,
                Job::Eval { inputs, reply } => {
                    let t0 = Instant::now();
                    let res = run_engine(&entry, inputs).map(|outputs| Response {
                        outputs,
                        latency: t0.elapsed().as_secs_f64(),
                        batch_size: batch,
                    });
                    metrics.completed(&name, t0.elapsed().as_secs_f64(), res.is_err());
                    let _ = reply.send(res);
                }
            }
        }
        if shutdown {
            return;
        }
    }
}

fn run_engine(entry: &EngineEntry, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
    if inputs.len() != entry.inputs.len() {
        bail!("expected {} inputs, got {}", entry.inputs.len(), inputs.len());
    }
    let mut env = Env::new();
    for ((name, shape), t) in entry.inputs.iter().zip(inputs) {
        if t.shape() != &shape[..] {
            bail!("input {} shape {:?}, expected {:?}", name, t.shape(), shape);
        }
        env.insert(name, t);
    }
    Ok(entry.plan.run(&env))
}

/// PJRT worker: owns the runtime, routes jobs by artifact name.
fn pjrt_worker(mut runtime: Runtime, rx: Receiver<(String, Job)>, metrics: Arc<Metrics>) {
    while let Ok((name, job)) = rx.recv() {
        match job {
            Job::Shutdown => return,
            Job::Eval { inputs, reply } => {
                let t0 = Instant::now();
                let res = runtime.execute(&name, &inputs).map(|outputs| Response {
                    outputs,
                    latency: t0.elapsed().as_secs_f64(),
                    batch_size: 1,
                });
                metrics.completed(&name, t0.elapsed().as_secs_f64(), res.is_err());
                let _ = reply.send(res);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::reverse::reverse_gradient;
    use crate::simplify::simplify_one;

    fn logreg_grad_entry(m: usize, n: usize) -> EngineEntry {
        logreg_grad_entry_mem(m, n, crate::exec::ExecMemory::default())
    }

    fn logreg_grad_entry_mem(
        m: usize,
        n: usize,
        memory: crate::exec::ExecMemory,
    ) -> EngineEntry {
        let mut g = Graph::new();
        let x = g.var("X", &[m, n]);
        let y = g.var("y", &[m]);
        let w = g.var("w", &[n]);
        let xw = g.matvec(x, w);
        let yxw = g.hadamard(y, xw);
        let t = g.neg(yxw);
        let e = g.elem(crate::ir::Elem::Exp, t);
        let one = g.constant(1.0, &[m]);
        let s = g.add(e, one);
        let l = g.elem(crate::ir::Elem::Log, s);
        let loss = g.sum_all(l);
        let grad = reverse_gradient(&mut g, loss, w);
        let grad = simplify_one(&mut g, grad);
        EngineEntry::compiled_with(
            &g,
            &[loss, grad],
            vec![
                ("X".into(), vec![m, n]),
                ("y".into(), vec![m]),
                ("w".into(), vec![n]),
            ],
            crate::opt::OptLevel::default(),
            memory,
        )
    }

    #[test]
    fn engine_entry_roundtrip() {
        let mut c = Coordinator::new(16);
        c.register_engine("logreg_grad", logreg_grad_entry(8, 3));
        let x = Tensor::randn(&[8, 3], 1);
        let y = Tensor::randn(&[8], 2).map(f64::signum);
        let w = Tensor::randn(&[3], 3);
        let resp = c.eval("logreg_grad", vec![x, y, w]).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        assert_eq!(resp.outputs[1].shape(), &[3]);
        assert!(resp.latency >= 0.0);
    }

    #[test]
    fn planned_and_pooled_entries_agree() {
        use crate::exec::ExecMemory;
        let mut c = Coordinator::new(16);
        c.register_engine("planned", logreg_grad_entry_mem(8, 3, ExecMemory::Planned));
        c.register_engine("pooled", logreg_grad_entry_mem(8, 3, ExecMemory::Pooled));
        let x = Tensor::randn(&[8, 3], 1);
        let y = Tensor::randn(&[8], 2).map(f64::signum);
        let w = Tensor::randn(&[3], 3);
        let a = c.eval("planned", vec![x.clone(), y.clone(), w.clone()]).unwrap();
        let b = c.eval("pooled", vec![x, y, w]).unwrap();
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(ta.data(), tb.data(), "entry memory modes diverged");
        }
    }

    #[test]
    fn unknown_entry_errors() {
        let c = Coordinator::new(4);
        assert!(c.submit("nope", vec![]).is_err());
    }

    #[test]
    fn wrong_shape_is_reported_not_panicking() {
        let mut c = Coordinator::new(4);
        c.register_engine("e", logreg_grad_entry(8, 3));
        let bad = vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[8]), Tensor::zeros(&[3])];
        let resp = c.eval("e", bad);
        assert!(resp.is_err());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let mut c = Coordinator::new(64);
        c.register_engine("e", logreg_grad_entry(16, 4));
        let mut rxs = Vec::new();
        for i in 0..32 {
            let x = Tensor::randn(&[16, 4], i);
            let y = Tensor::randn(&[16], i + 100).map(f64::signum);
            let w = Tensor::randn(&[4], i + 200);
            rxs.push(c.submit("e", vec![x, y, w]).unwrap());
        }
        let mut max_batch = 0;
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            max_batch = max_batch.max(r.batch_size);
        }
        assert!(max_batch >= 1);
        let stats = c.metrics().snapshot();
        assert_eq!(stats.completed, 32);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn backpressure_queue_full() {
        let mut c = Coordinator::new(1);
        c.register_engine("e", logreg_grad_entry(64, 16));
        let mk = |i| {
            vec![
                Tensor::randn(&[64, 16], i),
                Tensor::randn(&[64], i + 1).map(f64::signum),
                Tensor::randn(&[16], i + 2),
            ]
        };
        let mut errs = 0;
        let mut oks = Vec::new();
        for i in 0..64 {
            match c.submit("e", mk(i)) {
                Ok(rx) => oks.push(rx),
                Err(_) => errs += 1,
            }
        }
        for rx in oks {
            let _ = rx.recv();
        }
        // with queue_cap=1 and 64 rapid submits, backpressure should trigger
        assert!(errs > 0, "expected backpressure with cap=1");
    }

    #[test]
    fn shutdown_with_saturated_cap1_queue_terminates() {
        let mut c = Coordinator::new(1);
        c.register_engine("e", logreg_grad_entry(64, 16));
        let mk = |i| {
            vec![
                Tensor::randn(&[64, 16], i),
                Tensor::randn(&[64], i + 1).map(f64::signum),
                Tensor::randn(&[16], i + 2),
            ]
        };
        // saturate the cap-1 queue so try_send(Shutdown) will fail
        let mut accepted = Vec::new();
        for i in 0..16 {
            if let Ok(rx) = c.submit("e", mk(i)) {
                accepted.push(rx);
            }
        }
        let (done_tx, done_rx) = sync_channel::<()>(1);
        let h = std::thread::spawn(move || {
            c.shutdown();
            drop(c);
            let _ = done_tx.send(());
        });
        assert!(
            done_rx.recv_timeout(std::time::Duration::from_secs(60)).is_ok(),
            "Coordinator::shutdown deadlocked on a full queue"
        );
        h.join().unwrap();
        // every accepted job was answered before the worker exited
        for rx in accepted {
            let resp = rx.recv().expect("reply dropped on shutdown");
            assert!(resp.is_ok());
        }
    }

    #[test]
    fn mid_batch_shutdown_answers_drained_jobs() {
        // Deterministic mid-batch shutdown: queue [Eval, Shutdown, Eval]
        // before the worker starts, so one drain sees all three.
        let entry = logreg_grad_entry(8, 3);
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Job>(8);
        let mk = |i: u64| {
            vec![
                Tensor::randn(&[8, 3], i),
                Tensor::randn(&[8], i + 1).map(f64::signum),
                Tensor::randn(&[3], i + 2),
            ]
        };
        let (r1tx, r1rx) = sync_channel(1);
        let (r2tx, r2rx) = sync_channel(1);
        tx.send(Job::Eval { inputs: mk(1), reply: r1tx }).unwrap();
        tx.send(Job::Shutdown).unwrap();
        tx.send(Job::Eval { inputs: mk(10), reply: r2tx }).unwrap();
        drop(tx);
        engine_worker("e".into(), entry, rx, metrics.clone());
        let a = r1rx.recv().expect("first reply dropped").unwrap();
        let b = r2rx.recv().expect("eval after mid-batch Shutdown dropped").unwrap();
        assert_eq!(a.batch_size, 2, "Shutdown must not count toward the eval batch");
        assert_eq!(b.batch_size, 2);
        assert_eq!(metrics.snapshot().completed, 2);
    }

    #[test]
    fn pjrt_backend_through_coordinator() {
        let Some(dir) = crate::runtime::artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let mut c = Coordinator::new(8);
        c.register_runtime(dir.clone(), &["logreg_val_grad".to_string()]).unwrap();
        let x = crate::runtime::read_f32_raw(dir.join("check/logreg_X.f32"), &[256, 128]).unwrap();
        let y = crate::runtime::read_f32_raw(dir.join("check/logreg_y.f32"), &[256]).unwrap();
        let w = crate::runtime::read_f32_raw(dir.join("check/logreg_w.f32"), &[128]).unwrap();
        let resp = c.eval("logreg_val_grad", vec![w, x, y]).unwrap();
        assert_eq!(resp.outputs.len(), 2);
        let grad =
            crate::runtime::read_f32_raw(dir.join("check/logreg_grad.f32"), &[128]).unwrap();
        assert!(resp.outputs[1].allclose(&grad, 1e-4, 1e-4));
    }
}
