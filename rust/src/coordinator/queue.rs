//! The bounded per-entry job queue behind [`Coordinator::submit`]
//! (replacing the former `std::sync::mpsc::sync_channel`): a
//! `Mutex<VecDeque>` with two condvars, so the coordinator controls the
//! *full-queue policy* ([`ShedPolicy`]) on the submit side and gets a
//! deterministic shutdown signal ([`JobQueue::close`]) on the worker
//! side — the mpsc channel could do neither (its only overload behavior
//! is reject, and its only close signal is dropping every sender, which
//! a `try_send(Shutdown)` nudge could silently fail to reinforce on a
//! full queue).
//!
//! Contract: every job accepted by [`JobQueue::push`] is either drained
//! by the worker (including after `close` — closing does not discard
//! queued jobs) or handed back to the submitter as the shed victim, so
//! the caller can answer it. Nothing is silently dropped.
//!
//! [`Coordinator::submit`]: super::Coordinator::submit

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// What [`JobQueue::push`] does when the queue is at capacity — the
/// per-entry backpressure policy (CLI: `serve --shed`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse the new job (the submitter sees a retryable
    /// `SubmitError::QueueFull`). The default: callers own their retry
    /// loop and the queue never lies about its capacity.
    Reject,
    /// Evict the oldest queued job to make room — the evicted job is
    /// answered `Err(ServeError::Shed)` by the submitter. Freshest-wins:
    /// right when stale work loses value fastest (deadline traffic).
    ShedOldest,
    /// Wait up to the given duration for the worker to drain, then
    /// reject. Smooths short bursts at the cost of submitter latency.
    Block(Duration),
}

impl Default for ShedPolicy {
    fn default() -> Self {
        ShedPolicy::Reject
    }
}

impl ShedPolicy {
    /// Parse the CLI / env spelling: `reject`, `oldest`, `block`
    /// (100 ms default), or `block:<ms>`.
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "reject" => Some(ShedPolicy::Reject),
            "oldest" | "shed-oldest" => Some(ShedPolicy::ShedOldest),
            _ => {
                let rest = s.strip_prefix("block")?;
                if rest.is_empty() {
                    return Some(ShedPolicy::Block(Duration::from_millis(100)));
                }
                let ms: u64 = rest.strip_prefix(':')?.parse().ok()?;
                Some(ShedPolicy::Block(Duration::from_millis(ms)))
            }
        }
    }
}

impl std::fmt::Display for ShedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedPolicy::Reject => write!(f, "reject"),
            ShedPolicy::ShedOldest => write!(f, "oldest"),
            ShedPolicy::Block(d) => write!(f, "block:{}", d.as_millis()),
        }
    }
}

/// Outcome of [`JobQueue::push`]. The shed victim rides back to the
/// submitter so *it* answers the evicted caller — the queue itself never
/// owns a reply channel.
#[derive(Debug)]
pub(crate) enum PushOutcome<T> {
    Accepted,
    /// Accepted after evicting the oldest queued item (returned).
    AcceptedShed(T),
    /// At capacity under `Reject`, or `Block` timed out.
    Full,
    /// The queue was closed (worker shutting down).
    Closed,
}

struct State<T> {
    jobs: VecDeque<T>,
    closed: bool,
}

/// Bounded MPSC job queue: many submitters, one draining worker.
pub(crate) struct JobQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> JobQueue<T> {
    pub fn new(cap: usize) -> Self {
        JobQueue {
            state: Mutex::new(State { jobs: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    #[cfg(test)]
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// Submit one job under the given full-queue policy.
    pub fn push(&self, item: T, policy: ShedPolicy) -> PushOutcome<T> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return PushOutcome::Closed;
        }
        if st.jobs.len() < self.cap {
            st.jobs.push_back(item);
            self.not_empty.notify_one();
            return PushOutcome::Accepted;
        }
        match policy {
            ShedPolicy::Reject => PushOutcome::Full,
            ShedPolicy::ShedOldest => {
                let victim = st.jobs.pop_front().expect("full queue has a head (cap >= 1)");
                st.jobs.push_back(item);
                self.not_empty.notify_one();
                PushOutcome::AcceptedShed(victim)
            }
            ShedPolicy::Block(timeout) => {
                let deadline = Instant::now() + timeout;
                while st.jobs.len() >= self.cap && !st.closed {
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        return PushOutcome::Full;
                    }
                    let (guard, _timed_out) =
                        self.not_full.wait_timeout(st, left).unwrap();
                    st = guard;
                }
                if st.closed {
                    return PushOutcome::Closed;
                }
                st.jobs.push_back(item);
                self.not_empty.notify_one();
                PushOutcome::Accepted
            }
        }
    }

    /// Worker side: block until at least one job is queued or the queue
    /// is closed, then take everything. Returns `(jobs, closed)` —
    /// `closed` with a non-empty batch means "serve these, then exit".
    pub fn drain_wait(&self) -> (Vec<T>, bool) {
        let mut st = self.state.lock().unwrap();
        while st.jobs.is_empty() && !st.closed {
            st = self.not_empty.wait(st).unwrap();
        }
        let jobs: Vec<T> = st.jobs.drain(..).collect();
        let closed = st.closed;
        drop(st);
        if !jobs.is_empty() {
            self.not_full.notify_all();
        }
        (jobs, closed)
    }

    /// The deterministic shutdown signal: wakes the worker (and any
    /// blocked submitters) unconditionally. Jobs already queued stay
    /// queued — the worker drains and answers them before exiting.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_then_drain_roundtrip_in_order() {
        let q = JobQueue::new(4);
        for i in 0..3 {
            assert!(matches!(q.push(i, ShedPolicy::Reject), PushOutcome::Accepted));
        }
        let (jobs, closed) = q.drain_wait();
        assert_eq!(jobs, vec![0, 1, 2]);
        assert!(!closed);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn reject_policy_refuses_at_capacity() {
        let q = JobQueue::new(2);
        assert!(matches!(q.push(1, ShedPolicy::Reject), PushOutcome::Accepted));
        assert!(matches!(q.push(2, ShedPolicy::Reject), PushOutcome::Accepted));
        assert!(matches!(q.push(3, ShedPolicy::Reject), PushOutcome::Full));
        // the rejected item was not enqueued
        assert_eq!(q.drain_wait().0, vec![1, 2]);
    }

    #[test]
    fn shed_oldest_evicts_head_and_returns_it() {
        let q = JobQueue::new(2);
        q.push(1, ShedPolicy::ShedOldest);
        q.push(2, ShedPolicy::ShedOldest);
        match q.push(3, ShedPolicy::ShedOldest) {
            PushOutcome::AcceptedShed(victim) => assert_eq!(victim, 1),
            other => panic!("expected AcceptedShed, got {:?}", other),
        }
        assert_eq!(q.drain_wait().0, vec![2, 3]);
    }

    #[test]
    fn block_policy_times_out_on_a_stuck_queue() {
        let q = JobQueue::new(1);
        q.push(1, ShedPolicy::Reject);
        let t0 = Instant::now();
        let out = q.push(2, ShedPolicy::Block(Duration::from_millis(20)));
        assert!(matches!(out, PushOutcome::Full));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn block_policy_succeeds_when_the_worker_drains() {
        let q = Arc::new(JobQueue::new(1));
        q.push(1, ShedPolicy::Reject);
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.drain_wait().0
        });
        let out = q.push(2, ShedPolicy::Block(Duration::from_secs(10)));
        assert!(matches!(out, PushOutcome::Accepted));
        let drained = h.join().unwrap();
        assert_eq!(drained, vec![1]);
        assert_eq!(q.drain_wait().0, vec![2]);
    }

    #[test]
    fn close_wakes_an_idle_drainer_and_rejects_new_pushes() {
        let q = Arc::new(JobQueue::<u32>::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.drain_wait());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        let (jobs, closed) = h.join().unwrap();
        assert!(jobs.is_empty());
        assert!(closed, "close must wake and flag the drainer");
        assert!(matches!(q.push(1, ShedPolicy::Reject), PushOutcome::Closed));
        assert!(matches!(
            q.push(1, ShedPolicy::Block(Duration::from_secs(10))),
            PushOutcome::Closed
        ));
    }

    #[test]
    fn close_preserves_queued_jobs_for_the_final_drain() {
        // the satellite-1 contract: closing does not discard accepted
        // jobs — the worker's final drain still sees them
        let q = JobQueue::new(4);
        q.push(7, ShedPolicy::Reject);
        q.push(8, ShedPolicy::Reject);
        q.close();
        let (jobs, closed) = q.drain_wait();
        assert_eq!(jobs, vec![7, 8]);
        assert!(closed);
        // subsequent drains terminate immediately and stay empty
        let (jobs, closed) = q.drain_wait();
        assert!(jobs.is_empty() && closed);
    }

    #[test]
    fn close_unblocks_a_blocked_submitter() {
        let q = Arc::new(JobQueue::new(1));
        q.push(1, ShedPolicy::Reject);
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.close();
        });
        let out = q.push(2, ShedPolicy::Block(Duration::from_secs(60)));
        assert!(matches!(out, PushOutcome::Closed), "close must unblock Block submitters");
        h.join().unwrap();
    }
}
