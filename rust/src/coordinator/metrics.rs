//! Lock-light service metrics: counters + a sampled latency reservoir.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics for the coordinator.
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    /// per-entry latency samples (seconds), capped reservoir
    latencies: Mutex<HashMap<String, Vec<f64>>>,
}

/// A point-in-time view.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    /// per-entry (count, p50, p99) in seconds
    pub per_entry: Vec<(String, usize, f64, f64)>,
}

const RESERVOIR: usize = 4096;

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latencies: Mutex::new(HashMap::new()),
        }
    }

    pub fn submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn completed(&self, entry: &str, latency: f64, is_err: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if is_err {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let mut map = self.latencies.lock().unwrap();
        let v = map.entry(entry.to_string()).or_default();
        if v.len() < RESERVOIR {
            v.push(latency);
        } else {
            // simple overwrite reservoir
            let i = (latency.to_bits() as usize) % RESERVOIR;
            v[i] = latency;
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let map = self.latencies.lock().unwrap();
        let mut per_entry = Vec::new();
        for (name, v) in map.iter() {
            let mut s = v.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let p = |q: f64| -> f64 {
                if s.is_empty() {
                    0.0
                } else {
                    s[((s.len() - 1) as f64 * q) as usize]
                }
            };
            per_entry.push((name.clone(), v.len(), p(0.5), p(0.99)));
        }
        per_entry.sort_by(|a, b| a.0.cmp(&b.0));
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            per_entry,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.submitted();
        m.submitted();
        m.completed("a", 0.001, false);
        m.completed("a", 0.002, true);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.per_entry.len(), 1);
        let (name, count, p50, p99) = &s.per_entry[0];
        assert_eq!(name, "a");
        assert_eq!(*count, 2);
        assert!(*p50 > 0.0 && *p99 >= *p50);
    }

    #[test]
    fn reservoir_caps_memory() {
        let m = Metrics::new();
        for i in 0..10_000 {
            m.completed("x", i as f64 * 1e-6, false);
        }
        let s = m.snapshot();
        assert_eq!(s.per_entry[0].1, RESERVOIR);
    }
}
