//! Lock-light service metrics: global counters, per-entry latency
//! reservoirs (uniform Algorithm R sampling), per-entry log₂ histograms
//! for queue-wait and service time, batch-size distributions, a live
//! queue-depth gauge, registered gauges (lease recycling, compile
//! counters, degrade level), and a Prometheus-style text exposition
//! ([`Metrics::render_prometheus`]).
//!
//! Accounting contract (pinned by `tests/chaos.rs`): every *admitted*
//! request resolves into exactly one of completed / errors / shed /
//! expired, so `submitted == completed + errors + shed + expired` once
//! the queues drain. Admission-time refusals (queue-full rejects,
//! already-expired deadlines) are counted separately in
//! `rejected_full` / `rejected_expired` and never enter the balance.

use crate::tensor::XorShift;
use std::collections::{BTreeMap, HashMap};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// A gauge read at render time (e.g. a closure over a plan's
/// `pool_stats`). Boxed so callers can register anything.
type GaugeFn = Box<dyn Fn() -> f64 + Send>;

/// How one admitted request resolved — the argument to
/// [`Metrics::observe`]. Exactly one per admitted request, which is
/// what makes the balance invariant checkable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Answered with a response.
    Ok,
    /// Answered with an error (panic, invalid input, backend failure).
    Error,
    /// Evicted under `ShedPolicy::ShedOldest`, answered `Err(Shed)`.
    Shed,
    /// Deadline passed before execution, answered `Err(Expired)`.
    Expired,
}

/// Shared metrics for the coordinator.
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    /// admission refusals: queue at capacity (retryable)
    rejected_full: AtomicU64,
    /// admission refusals: deadline already expired at submit
    rejected_expired: AtomicU64,
    /// chunks served under a nonzero degrade-ladder level
    degraded: AtomicU64,
    /// jobs sitting in worker channels right now: +1 at enqueue, −1 at
    /// drain (signed so a racy snapshot renders a transient −1 instead
    /// of wrapping)
    queue_depth: AtomicI64,
    /// per-entry streams (latency reservoir, histograms, batch sizes)
    entries: Mutex<HashMap<String, EntryMetrics>>,
    /// registered gauges keyed by `(metric name, label set)`; keyed
    /// replacement, so re-registering an entry updates in place instead
    /// of leaking a stale closure
    gauges: Mutex<BTreeMap<(String, String), GaugeFn>>,
}

/// A point-in-time view.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub submitted: u64,
    /// requests answered with a response (successes only)
    pub completed: u64,
    pub errors: u64,
    /// requests evicted under `ShedPolicy::ShedOldest`
    pub shed: u64,
    /// requests whose deadline passed before execution
    pub expired: u64,
    /// admission refusals: queue full
    pub rejected_full: u64,
    /// admission refusals: deadline already expired at submit
    pub rejected_expired: u64,
    /// chunks served under a nonzero degrade level
    pub degraded: u64,
    /// per-entry (samples held, p50, p99) in seconds
    pub per_entry: Vec<(String, usize, f64, f64)>,
}

const RESERVOIR: usize = 4096;

/// Histogram bucket count: upper bounds `1µs · 2^i` for `i = 0..25`
/// (1µs … ~16.8s) plus the +Inf overflow bucket — log₂ spacing covers
/// the full serving range in a fixed, allocation-free array.
const N_BUCKETS: usize = 25;

/// Upper bound (seconds) of bucket `i`.
fn bucket_le(i: usize) -> f64 {
    1e-6 * (1u64 << i) as f64
}

/// Fixed-bucket log₂ histogram (non-cumulative counts; the Prometheus
/// renderer cumulates).
#[derive(Clone)]
struct Histogram {
    counts: [u64; N_BUCKETS + 1],
    sum: f64,
    count: u64,
}

impl Histogram {
    fn new() -> Self {
        Histogram { counts: [0; N_BUCKETS + 1], sum: 0.0, count: 0 }
    }

    fn observe(&mut self, v: f64) {
        let mut idx = N_BUCKETS; // +Inf (also where NaN lands)
        for i in 0..N_BUCKETS {
            if v <= bucket_le(i) {
                idx = i;
                break;
            }
        }
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Append `<name>_bucket{...,le=...}` / `_sum` / `_count` lines.
    fn render(&self, out: &mut String, name: &str, labels: &str) {
        let mut cum = 0u64;
        for i in 0..N_BUCKETS {
            cum += self.counts[i];
            let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{}\"}} {cum}", bucket_le(i));
        }
        cum += self.counts[N_BUCKETS];
        let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum{{{labels}}} {}", self.sum);
        let _ = writeln!(out, "{name}_count{{{labels}}} {}", self.count);
    }
}

/// Everything tracked per coordinator entry.
struct EntryMetrics {
    /// end-to-end latency samples (queue wait + service), capped reservoir
    latency: Reservoir,
    queue_wait: Histogram,
    service: Histogram,
    /// batch size → occurrences (one count per *request*, so the
    /// distribution weights what requests experienced)
    batch_sizes: BTreeMap<usize, u64>,
    errors: u64,
    shed: u64,
    expired: u64,
}

impl EntryMetrics {
    fn new() -> Self {
        EntryMetrics {
            latency: Reservoir::new(),
            queue_wait: Histogram::new(),
            service: Histogram::new(),
            batch_sizes: BTreeMap::new(),
            errors: 0,
            shed: 0,
            expired: 0,
        }
    }
}

/// Uniform fixed-size sample of an unbounded latency stream (Vitter's
/// Algorithm R): after `seen` observations, every one of them is in the
/// reservoir with probability `RESERVOIR / seen`. The previous scheme
/// indexed by the latency's *bit pattern* (`to_bits() % RESERVOIR`) —
/// value-keyed, not random, so a steady-state service funneled all its
/// similar latencies into a handful of slots and p50/p99 stayed frozen
/// on warm-up samples.
struct Reservoir {
    samples: Vec<f64>,
    /// observations ever offered (≥ samples.len())
    seen: u64,
    rng: XorShift,
}

impl Reservoir {
    fn new() -> Self {
        Reservoir { samples: Vec::new(), seen: 0, rng: XorShift::new(0x5EED) }
    }

    fn offer(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR {
            self.samples.push(v);
        } else {
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < RESERVOIR {
                self.samples[j] = v;
            }
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_expired: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            queue_depth: AtomicI64::new(0),
            entries: Mutex::new(HashMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A job entered a worker channel.
    pub fn enqueued(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A job left a worker channel (drained into a batch).
    pub fn dequeued(&self) {
        self.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }

    /// An admission-time refusal because the entry's queue was full
    /// (the caller saw `SubmitError::QueueFull`). Pre-PR a full queue
    /// was invisible to the Prometheus surface.
    pub fn rejected_queue_full(&self) {
        self.rejected_full.fetch_add(1, Ordering::Relaxed);
    }

    /// An admission-time refusal because the deadline had already
    /// passed (the caller saw `SubmitError::Expired`).
    pub fn rejected_expired(&self) {
        self.rejected_expired.fetch_add(1, Ordering::Relaxed);
    }

    /// One chunk was served under a nonzero degrade-ladder level.
    pub fn degraded_run(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the resolution of one *admitted* request with its timing
    /// breakdown: `queue_secs` from enqueue to drain, `service_secs`
    /// from drain to reply, `batch` the fused batch it rode in. Exactly
    /// one call per admitted request — that is the balance invariant.
    /// Sheds and expiries record their queue wait (the time the system
    /// held the request) but contribute no latency/service/batch
    /// samples, which describe executed requests only.
    pub fn observe(
        &self,
        entry: &str,
        queue_secs: f64,
        service_secs: f64,
        batch: usize,
        outcome: Outcome,
    ) {
        match outcome {
            Outcome::Ok => self.completed.fetch_add(1, Ordering::Relaxed),
            Outcome::Error => self.errors.fetch_add(1, Ordering::Relaxed),
            Outcome::Shed => self.shed.fetch_add(1, Ordering::Relaxed),
            Outcome::Expired => self.expired.fetch_add(1, Ordering::Relaxed),
        };
        let mut map = self.entries.lock().unwrap();
        let e = map.entry(entry.to_string()).or_insert_with(EntryMetrics::new);
        e.queue_wait.observe(queue_secs);
        match outcome {
            Outcome::Ok | Outcome::Error => {
                e.latency.offer(queue_secs + service_secs);
                e.service.observe(service_secs);
                *e.batch_sizes.entry(batch).or_insert(0) += 1;
                if outcome == Outcome::Error {
                    e.errors += 1;
                }
            }
            Outcome::Shed => e.shed += 1,
            Outcome::Expired => e.expired += 1,
        }
    }

    /// Record one finished request with only its end-to-end latency
    /// (queue wait unknown, batch size 1) — the pre-breakdown entry
    /// point, kept for callers without an enqueue stamp.
    pub fn completed(&self, entry: &str, latency: f64, is_err: bool) {
        let outcome = if is_err { Outcome::Error } else { Outcome::Ok };
        self.observe(entry, 0.0, latency, 1, outcome);
    }

    /// Register (or replace) a gauge rendered by
    /// [`render_prometheus`](Self::render_prometheus). `labels` is the
    /// raw label body, e.g. `entry="grad"` — may be empty.
    pub fn register_gauge(
        &self,
        name: &str,
        labels: &str,
        f: impl Fn() -> f64 + Send + 'static,
    ) {
        self.gauges
            .lock()
            .unwrap()
            .insert((name.to_string(), labels.to_string()), Box::new(f));
    }

    pub fn snapshot(&self) -> Snapshot {
        let map = self.entries.lock().unwrap();
        let mut per_entry = Vec::new();
        for (name, e) in map.iter() {
            let mut s = e.latency.samples.clone();
            // total order: NaN sorts last instead of panicking the snapshot
            s.sort_by(f64::total_cmp);
            // nearest-rank percentile: the ⌈q·N⌉-th smallest sample. The
            // old truncating index `(N-1)·q as usize` rounded p99 down to
            // p50 for small N.
            let p = |q: f64| -> f64 {
                if s.is_empty() {
                    return 0.0;
                }
                let rank = (q * s.len() as f64).ceil() as usize;
                s[rank.clamp(1, s.len()) - 1]
            };
            per_entry.push((name.clone(), e.latency.samples.len(), p(0.5), p(0.99)));
        }
        per_entry.sort_by(|a, b| a.0.cmp(&b.0));
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            rejected_full: self.rejected_full.load(Ordering::Relaxed),
            rejected_expired: self.rejected_expired.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            per_entry,
        }
    }

    /// Render every counter, gauge and histogram in the Prometheus text
    /// exposition format (one metric family per `# HELP`/`# TYPE` pair).
    /// Zero dependencies: plain text, scrapeable or just readable.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let counter = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        counter(
            &mut out,
            "tensorcalc_submitted_total",
            "Requests accepted by submit().",
            self.submitted.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "tensorcalc_completed_total",
            "Requests answered with a response (successes only).",
            self.completed.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "tensorcalc_errors_total",
            "Requests answered with an error.",
            self.errors.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "tensorcalc_shed_total",
            "Admitted requests evicted under shed-oldest overload policy.",
            self.shed.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "tensorcalc_expired_total",
            "Admitted requests whose deadline passed before execution.",
            self.expired.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "tensorcalc_degraded_total",
            "Chunks served under a nonzero degrade-ladder level.",
            self.degraded.load(Ordering::Relaxed),
        );
        {
            let _ = writeln!(
                out,
                "# HELP tensorcalc_rejected_total Requests refused at admission, by reason."
            );
            let _ = writeln!(out, "# TYPE tensorcalc_rejected_total counter");
            let _ = writeln!(
                out,
                "tensorcalc_rejected_total{{reason=\"queue_full\"}} {}",
                self.rejected_full.load(Ordering::Relaxed)
            );
            let _ = writeln!(
                out,
                "tensorcalc_rejected_total{{reason=\"expired\"}} {}",
                self.rejected_expired.load(Ordering::Relaxed)
            );
        }
        let (hits, misses) = crate::exec::global_plan_cache().cache_stats();
        counter(
            &mut out,
            "tensorcalc_plan_cache_hits_total",
            "Plan-cache lookups served an existing compiled plan.",
            hits,
        );
        counter(
            &mut out,
            "tensorcalc_plan_cache_misses_total",
            "Plan-cache lookups that compiled a fresh plan.",
            misses,
        );

        let _ = writeln!(out, "# HELP tensorcalc_queue_depth Jobs waiting in worker channels.");
        let _ = writeln!(out, "# TYPE tensorcalc_queue_depth gauge");
        let _ = writeln!(
            out,
            "tensorcalc_queue_depth {}",
            self.queue_depth.load(Ordering::Relaxed)
        );

        {
            let map = self.entries.lock().unwrap();
            let mut names: Vec<&String> = map.keys().collect();
            names.sort();
            let _ = writeln!(
                out,
                "# HELP tensorcalc_queue_wait_seconds Enqueue-to-drain wait per request."
            );
            let _ = writeln!(out, "# TYPE tensorcalc_queue_wait_seconds histogram");
            for name in &names {
                map[*name].queue_wait.render(
                    &mut out,
                    "tensorcalc_queue_wait_seconds",
                    &format!("entry=\"{name}\""),
                );
            }
            let _ = writeln!(
                out,
                "# HELP tensorcalc_service_seconds Drain-to-reply service time per request."
            );
            let _ = writeln!(out, "# TYPE tensorcalc_service_seconds histogram");
            for name in &names {
                map[*name].service.render(
                    &mut out,
                    "tensorcalc_service_seconds",
                    &format!("entry=\"{name}\""),
                );
            }
            let _ = writeln!(
                out,
                "# HELP tensorcalc_batch_total Requests served per fused batch size."
            );
            let _ = writeln!(out, "# TYPE tensorcalc_batch_total counter");
            for name in &names {
                for (bsz, n) in &map[*name].batch_sizes {
                    let _ = writeln!(
                        out,
                        "tensorcalc_batch_total{{entry=\"{name}\",size=\"{bsz}\"}} {n}"
                    );
                }
            }
            let _ = writeln!(
                out,
                "# HELP tensorcalc_entry_errors_total Error replies per entry."
            );
            let _ = writeln!(out, "# TYPE tensorcalc_entry_errors_total counter");
            for name in &names {
                let _ = writeln!(
                    out,
                    "tensorcalc_entry_errors_total{{entry=\"{name}\"}} {}",
                    map[*name].errors
                );
            }
            let _ = writeln!(
                out,
                "# HELP tensorcalc_entry_shed_total Shed replies per entry."
            );
            let _ = writeln!(out, "# TYPE tensorcalc_entry_shed_total counter");
            for name in &names {
                let _ = writeln!(
                    out,
                    "tensorcalc_entry_shed_total{{entry=\"{name}\"}} {}",
                    map[*name].shed
                );
            }
            let _ = writeln!(
                out,
                "# HELP tensorcalc_entry_expired_total Expired replies per entry."
            );
            let _ = writeln!(out, "# TYPE tensorcalc_entry_expired_total counter");
            for name in &names {
                let _ = writeln!(
                    out,
                    "tensorcalc_entry_expired_total{{entry=\"{name}\"}} {}",
                    map[*name].expired
                );
            }
        }

        // registered gauges, grouped by family (the BTreeMap keeps one
        // family's label sets adjacent and the output deterministic)
        let gauges = self.gauges.lock().unwrap();
        let mut last_name: Option<&str> = None;
        for ((name, labels), f) in gauges.iter() {
            if last_name != Some(name.as_str()) {
                let _ = writeln!(out, "# TYPE {name} gauge");
                last_name = Some(name.as_str());
            }
            if labels.is_empty() {
                let _ = writeln!(out, "{name} {}", f());
            } else {
                let _ = writeln!(out, "{name}{{{labels}}} {}", f());
            }
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.submitted();
        m.submitted();
        m.completed("a", 0.001, false);
        m.completed("a", 0.002, true);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 1, "completed counts successes only");
        assert_eq!(s.errors, 1);
        assert_eq!(s.submitted, s.completed + s.errors + s.shed + s.expired);
        assert_eq!(s.per_entry.len(), 1);
        let (name, count, p50, p99) = &s.per_entry[0];
        assert_eq!(name, "a");
        assert_eq!(*count, 2);
        assert!(*p50 > 0.0 && *p99 >= *p50);
    }

    #[test]
    fn reservoir_caps_memory() {
        let m = Metrics::new();
        for i in 0..10_000 {
            m.completed("x", i as f64 * 1e-6, false);
        }
        let s = m.snapshot();
        assert_eq!(s.per_entry[0].1, RESERVOIR);
    }

    #[test]
    fn reservoir_is_not_value_keyed() {
        // Warm up with 1.0s, then shift the distribution to 2.0 for 8×
        // the reservoir size. A uniform reservoir is dominated by 2.0s;
        // the value-keyed overwrite funneled every 2.0 into ONE slot
        // (2.0f64.to_bits() % RESERVOIR is a single index), freezing
        // p50 and p99 at the warm-up value forever.
        let m = Metrics::new();
        for _ in 0..RESERVOIR {
            m.completed("x", 1.0, false);
        }
        for _ in 0..8 * RESERVOIR {
            m.completed("x", 2.0, false);
        }
        let s = m.snapshot();
        let (_, _, p50, p99) = &s.per_entry[0];
        assert_eq!(*p50, 2.0, "reservoir still dominated by warm-up samples");
        assert_eq!(*p99, 2.0);
    }

    #[test]
    fn percentiles_distinguish_p99_from_p50_on_small_samples() {
        let m = Metrics::new();
        m.completed("a", 0.001, false);
        m.completed("a", 0.002, false);
        let s = m.snapshot();
        let (_, _, p50, p99) = &s.per_entry[0];
        assert_eq!(*p50, 0.001);
        assert_eq!(*p99, 0.002, "truncating index collapses p99 onto p50");
    }

    #[test]
    fn nearest_rank_percentiles_are_exact() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.completed("a", i as f64 / 100.0, false);
        }
        let snap = m.snapshot();
        let (_, _, p50, p99) = &snap.per_entry[0];
        assert_eq!(*p50, 0.50);
        assert_eq!(*p99, 0.99);
    }

    #[test]
    fn snapshot_survives_nan_latency() {
        // a NaN sample (e.g. a zero-duration division upstream) must not
        // panic the sort inside snapshot()
        let m = Metrics::new();
        m.completed("a", f64::NAN, false);
        m.completed("a", 1.0, false);
        let s = m.snapshot();
        assert_eq!(s.per_entry[0].1, 2);
    }

    #[test]
    fn histogram_buckets_cumulate_and_overflow() {
        let mut h = Histogram::new();
        h.observe(0.5e-6); // first bucket (≤ 1µs)
        h.observe(3e-6); // ≤ 4µs bucket
        h.observe(1e9); // +Inf
        assert_eq!(h.count, 3);
        let mut out = String::new();
        h.render(&mut out, "m", "entry=\"e\"");
        assert!(out.contains("m_bucket{entry=\"e\",le=\"0.000001\"} 1"));
        assert!(out.contains("m_bucket{entry=\"e\",le=\"0.000004\"} 2"));
        assert!(out.contains("m_bucket{entry=\"e\",le=\"+Inf\"} 3"));
        assert!(out.contains("m_count{entry=\"e\"} 3"));
    }

    #[test]
    fn observe_breaks_out_queue_service_and_batch() {
        let m = Metrics::new();
        m.enqueued();
        m.enqueued();
        m.dequeued();
        m.observe("g", 0.002, 0.001, 4, Outcome::Ok);
        m.observe("g", 0.0, 0.005, 1, Outcome::Error);
        let s = m.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.errors, 1);
        // reservoir samples the sum the caller saw
        let (_, n, p50, _) = &s.per_entry[0];
        assert_eq!(*n, 2);
        assert!(*p50 > 0.0);
        let text = m.render_prometheus();
        assert!(text.contains("tensorcalc_queue_depth 1"));
        assert!(text.contains("tensorcalc_batch_total{entry=\"g\",size=\"4\"} 1"));
        assert!(text.contains("tensorcalc_batch_total{entry=\"g\",size=\"1\"} 1"));
        assert!(text.contains("tensorcalc_entry_errors_total{entry=\"g\"} 1"));
        assert!(text.contains("tensorcalc_service_seconds_count{entry=\"g\"} 2"));
    }

    #[test]
    fn registered_gauges_render_and_replace_in_place() {
        let m = Metrics::new();
        m.register_gauge("tensorcalc_test_gauge", "entry=\"a\"", || 1.0);
        // re-registering the same (name, labels) replaces — no leak, no
        // duplicate series
        m.register_gauge("tensorcalc_test_gauge", "entry=\"a\"", || 2.0);
        let text = m.render_prometheus();
        assert!(text.contains("tensorcalc_test_gauge{entry=\"a\"} 2"));
        assert!(!text.contains("tensorcalc_test_gauge{entry=\"a\"} 1"));
        assert_eq!(text.matches("# TYPE tensorcalc_test_gauge gauge").count(), 1);
    }

    #[test]
    fn prometheus_text_has_well_formed_families() {
        let m = Metrics::new();
        m.submitted();
        m.completed("a", 0.001, false);
        let text = m.render_prometheus();
        for family in [
            "tensorcalc_submitted_total",
            "tensorcalc_completed_total",
            "tensorcalc_errors_total",
            "tensorcalc_shed_total",
            "tensorcalc_expired_total",
            "tensorcalc_degraded_total",
            "tensorcalc_rejected_total",
            "tensorcalc_plan_cache_hits_total",
            "tensorcalc_plan_cache_misses_total",
            "tensorcalc_queue_depth",
            "tensorcalc_queue_wait_seconds",
            "tensorcalc_service_seconds",
        ] {
            assert!(
                text.contains(&format!("# TYPE {family} ")),
                "missing TYPE line for {family}:\n{text}"
            );
        }
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(
                value.parse::<f64>().is_ok(),
                "unparseable sample value in line: {line}"
            );
            assert!(parts.next().is_some(), "no metric name in line: {line}");
        }
    }

    #[test]
    fn outcomes_split_into_disjoint_counters_and_balance() {
        let m = Metrics::new();
        for _ in 0..6 {
            m.submitted();
        }
        m.observe("g", 0.001, 0.002, 2, Outcome::Ok);
        m.observe("g", 0.001, 0.002, 2, Outcome::Ok);
        m.observe("g", 0.001, 0.002, 2, Outcome::Error);
        m.observe("g", 0.010, 0.0, 0, Outcome::Shed);
        m.observe("g", 0.010, 0.0, 0, Outcome::Shed);
        m.observe("g", 0.050, 0.0, 0, Outcome::Expired);
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.expired, 1);
        assert_eq!(s.submitted, s.completed + s.errors + s.shed + s.expired);
        // sheds/expiries never pollute executed-request distributions:
        // only the 3 executed requests hold latency samples
        assert_eq!(s.per_entry[0].1, 3);
        let text = m.render_prometheus();
        assert!(text.contains("tensorcalc_shed_total 2"), "{text}");
        assert!(text.contains("tensorcalc_expired_total 1"), "{text}");
        assert!(text.contains("tensorcalc_entry_shed_total{entry=\"g\"} 2"), "{text}");
        assert!(text.contains("tensorcalc_entry_expired_total{entry=\"g\"} 1"), "{text}");
        // but their queue wait IS recorded (the system held them)
        assert!(text.contains("tensorcalc_queue_wait_seconds_count{entry=\"g\"} 6"), "{text}");
        assert!(text.contains("tensorcalc_service_seconds_count{entry=\"g\"} 3"), "{text}");
    }

    #[test]
    fn admission_rejections_and_degraded_runs_are_counted() {
        let m = Metrics::new();
        m.rejected_queue_full();
        m.rejected_queue_full();
        m.rejected_expired();
        m.degraded_run();
        let s = m.snapshot();
        assert_eq!(s.rejected_full, 2);
        assert_eq!(s.rejected_expired, 1);
        assert_eq!(s.degraded, 1);
        // rejections stay outside the admitted-request balance
        assert_eq!(s.submitted, 0);
        assert_eq!(s.completed + s.errors + s.shed + s.expired, 0);
        let text = m.render_prometheus();
        assert!(text.contains("tensorcalc_rejected_total{reason=\"queue_full\"} 2"), "{text}");
        assert!(text.contains("tensorcalc_rejected_total{reason=\"expired\"} 1"), "{text}");
        assert!(text.contains("tensorcalc_degraded_total 1"), "{text}");
    }
}
