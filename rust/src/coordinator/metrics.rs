//! Lock-light service metrics: counters + per-entry latency reservoirs
//! with uniform (Algorithm R) reservoir sampling.

use crate::tensor::XorShift;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics for the coordinator.
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    /// per-entry latency samples (seconds), capped reservoir
    latencies: Mutex<HashMap<String, Reservoir>>,
}

/// A point-in-time view.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    /// per-entry (samples held, p50, p99) in seconds
    pub per_entry: Vec<(String, usize, f64, f64)>,
}

const RESERVOIR: usize = 4096;

/// Uniform fixed-size sample of an unbounded latency stream (Vitter's
/// Algorithm R): after `seen` observations, every one of them is in the
/// reservoir with probability `RESERVOIR / seen`. The previous scheme
/// indexed by the latency's *bit pattern* (`to_bits() % RESERVOIR`) —
/// value-keyed, not random, so a steady-state service funneled all its
/// similar latencies into a handful of slots and p50/p99 stayed frozen
/// on warm-up samples.
struct Reservoir {
    samples: Vec<f64>,
    /// observations ever offered (≥ samples.len())
    seen: u64,
    rng: XorShift,
}

impl Reservoir {
    fn new() -> Self {
        Reservoir { samples: Vec::new(), seen: 0, rng: XorShift::new(0x5EED) }
    }

    fn offer(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR {
            self.samples.push(v);
        } else {
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < RESERVOIR {
                self.samples[j] = v;
            }
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latencies: Mutex::new(HashMap::new()),
        }
    }

    pub fn submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn completed(&self, entry: &str, latency: f64, is_err: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if is_err {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let mut map = self.latencies.lock().unwrap();
        map.entry(entry.to_string()).or_insert_with(Reservoir::new).offer(latency);
    }

    pub fn snapshot(&self) -> Snapshot {
        let map = self.latencies.lock().unwrap();
        let mut per_entry = Vec::new();
        for (name, r) in map.iter() {
            let mut s = r.samples.clone();
            // total order: NaN sorts last instead of panicking the snapshot
            s.sort_by(f64::total_cmp);
            // nearest-rank percentile: the ⌈q·N⌉-th smallest sample. The
            // old truncating index `(N-1)·q as usize` rounded p99 down to
            // p50 for small N.
            let p = |q: f64| -> f64 {
                if s.is_empty() {
                    return 0.0;
                }
                let rank = (q * s.len() as f64).ceil() as usize;
                s[rank.clamp(1, s.len()) - 1]
            };
            per_entry.push((name.clone(), r.samples.len(), p(0.5), p(0.99)));
        }
        per_entry.sort_by(|a, b| a.0.cmp(&b.0));
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            per_entry,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.submitted();
        m.submitted();
        m.completed("a", 0.001, false);
        m.completed("a", 0.002, true);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.per_entry.len(), 1);
        let (name, count, p50, p99) = &s.per_entry[0];
        assert_eq!(name, "a");
        assert_eq!(*count, 2);
        assert!(*p50 > 0.0 && *p99 >= *p50);
    }

    #[test]
    fn reservoir_caps_memory() {
        let m = Metrics::new();
        for i in 0..10_000 {
            m.completed("x", i as f64 * 1e-6, false);
        }
        let s = m.snapshot();
        assert_eq!(s.per_entry[0].1, RESERVOIR);
    }

    #[test]
    fn reservoir_is_not_value_keyed() {
        // Warm up with 1.0s, then shift the distribution to 2.0 for 8×
        // the reservoir size. A uniform reservoir is dominated by 2.0s;
        // the value-keyed overwrite funneled every 2.0 into ONE slot
        // (2.0f64.to_bits() % RESERVOIR is a single index), freezing
        // p50 and p99 at the warm-up value forever.
        let m = Metrics::new();
        for _ in 0..RESERVOIR {
            m.completed("x", 1.0, false);
        }
        for _ in 0..8 * RESERVOIR {
            m.completed("x", 2.0, false);
        }
        let s = m.snapshot();
        let (_, _, p50, p99) = &s.per_entry[0];
        assert_eq!(*p50, 2.0, "reservoir still dominated by warm-up samples");
        assert_eq!(*p99, 2.0);
    }

    #[test]
    fn percentiles_distinguish_p99_from_p50_on_small_samples() {
        let m = Metrics::new();
        m.completed("a", 0.001, false);
        m.completed("a", 0.002, false);
        let s = m.snapshot();
        let (_, _, p50, p99) = &s.per_entry[0];
        assert_eq!(*p50, 0.001);
        assert_eq!(*p99, 0.002, "truncating index collapses p99 onto p50");
    }

    #[test]
    fn nearest_rank_percentiles_are_exact() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.completed("a", i as f64 / 100.0, false);
        }
        let snap = m.snapshot();
        let (_, _, p50, p99) = &snap.per_entry[0];
        assert_eq!(*p50, 0.50);
        assert_eq!(*p99, 0.99);
    }

    #[test]
    fn snapshot_survives_nan_latency() {
        // a NaN sample (e.g. a zero-duration division upstream) must not
        // panic the sort inside snapshot()
        let m = Metrics::new();
        m.completed("a", f64::NAN, false);
        m.completed("a", 1.0, false);
        let s = m.snapshot();
        assert_eq!(s.per_entry[0].1, 2);
    }
}
