//! Deterministic, zero-dependency fault injection for the serving
//! layer. A [`FaultPlan`] is a seeded RNG plus per-site firing rates;
//! the coordinator consults it at four named sites in the request path:
//!
//! * [`FaultSite::QueueFull`] — `submit` pretends the entry queue is at
//!   capacity (the caller sees a retryable `SubmitError::QueueFull`).
//! * [`FaultSite::ServiceLatency`] — the worker sleeps before executing
//!   a chunk, simulating a slow plan (drives deadline expiry and queue
//!   buildup deterministically in tests).
//! * [`FaultSite::ExecPanic`] — the worker panics inside the
//!   `catch_unwind` that guards plan execution.
//! * [`FaultSite::ReplyDrop`] — the worker drops a reply channel
//!   without sending (the caller sees `RecvError`, never a hang).
//!
//! Faults never corrupt the metrics contract: a dropped reply is still
//! *counted* by the worker before the drop, so the balance invariant
//! `submitted == completed + errors + shed + expired` pinned by
//! `tests/chaos.rs` holds under every plan.
//!
//! The draw sequence is a single seeded [`XorShift`] stream, so a given
//! (seed, request schedule) replays the same faults — that is what lets
//! the chaos suite assert exact behavior instead of "usually works".
//! Enable in production-shaped runs via the `TC_FAULT` env var, e.g.
//! `TC_FAULT="seed=42,exec_panic=0.05,latency=0.2,latency_ms=5"`.

use crate::tensor::XorShift;
use std::sync::Mutex;
use std::time::Duration;

/// A named injection point in the serving path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// `Coordinator::submit`: reject as if the queue were full.
    QueueFull,
    /// Worker, before plan execution: sleep for the plan's latency.
    ServiceLatency,
    /// Worker, inside the execution `catch_unwind`: panic.
    ExecPanic,
    /// Worker, at reply time: drop the channel without sending.
    ReplyDrop,
}

/// Seeded per-site fault rates. `FaultPlan::none()` is the always-off
/// fast path (no lock taken); a plan built by [`FaultPlan::seeded`] or
/// [`FaultPlan::from_env`] draws one RNG value per consulted site.
#[derive(Debug)]
pub struct FaultPlan {
    enabled: bool,
    queue_full: f64,
    exec_panic: f64,
    latency: f64,
    latency_dur: Duration,
    reply_drop: f64,
    rng: Mutex<XorShift>,
}

impl FaultPlan {
    /// No faults, ever. The coordinator default.
    pub fn none() -> Self {
        FaultPlan {
            enabled: false,
            queue_full: 0.0,
            exec_panic: 0.0,
            latency: 0.0,
            latency_dur: Duration::from_millis(1),
            reply_drop: 0.0,
            rng: Mutex::new(XorShift::new(1)),
        }
    }

    /// An active plan with every rate at zero; compose with
    /// [`FaultPlan::with_rate`] / [`FaultPlan::with_latency`].
    pub fn seeded(seed: u64) -> Self {
        FaultPlan { enabled: true, rng: Mutex::new(XorShift::new(seed)), ..Self::none() }
    }

    /// Set one site's firing probability (clamped to `[0, 1]`).
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> Self {
        let rate = rate.clamp(0.0, 1.0);
        match site {
            FaultSite::QueueFull => self.queue_full = rate,
            FaultSite::ExecPanic => self.exec_panic = rate,
            FaultSite::ServiceLatency => self.latency = rate,
            FaultSite::ReplyDrop => self.reply_drop = rate,
        }
        self
    }

    /// Set the sleep injected when [`FaultSite::ServiceLatency`] fires.
    pub fn with_latency(mut self, dur: Duration) -> Self {
        self.latency_dur = dur;
        self
    }

    /// Whether any site can fire at all.
    pub fn is_active(&self) -> bool {
        self.enabled
            && (self.queue_full > 0.0
                || self.exec_panic > 0.0
                || self.latency > 0.0
                || self.reply_drop > 0.0)
    }

    /// Parse `TC_FAULT` (comma-separated `key=value`: `seed`,
    /// `queue_full`, `exec_panic`, `latency`, `latency_ms`,
    /// `reply_drop`). `None` when unset or empty; malformed specs panic
    /// loudly — a typo silently disabling chaos is worse than a crash.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("TC_FAULT").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        Some(Self::parse(&spec))
    }

    fn parse(spec: &str) -> FaultPlan {
        let mut plan = FaultPlan::seeded(1);
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once('=')
                .unwrap_or_else(|| panic!("TC_FAULT: expected key=value, got {:?}", part));
            let rate = |what: &str| -> f64 {
                val.parse::<f64>()
                    .unwrap_or_else(|_| panic!("TC_FAULT: bad {} value {:?}", what, val))
                    .clamp(0.0, 1.0)
            };
            match key {
                "seed" => {
                    let s: u64 = val
                        .parse()
                        .unwrap_or_else(|_| panic!("TC_FAULT: bad seed value {:?}", val));
                    plan.rng = Mutex::new(XorShift::new(s));
                }
                "queue_full" => plan.queue_full = rate("queue_full"),
                "exec_panic" => plan.exec_panic = rate("exec_panic"),
                "latency" => plan.latency = rate("latency"),
                "latency_ms" => {
                    let ms: u64 = val
                        .parse()
                        .unwrap_or_else(|_| panic!("TC_FAULT: bad latency_ms value {:?}", val));
                    plan.latency_dur = Duration::from_millis(ms);
                }
                "reply_drop" => plan.reply_drop = rate("reply_drop"),
                other => panic!("TC_FAULT: unknown key {:?}", other),
            }
        }
        plan
    }

    fn rate(&self, site: FaultSite) -> f64 {
        match site {
            FaultSite::QueueFull => self.queue_full,
            FaultSite::ExecPanic => self.exec_panic,
            FaultSite::ServiceLatency => self.latency,
            FaultSite::ReplyDrop => self.reply_drop,
        }
    }

    /// Draw: does `site` fire now? Rate-0 sites draw nothing, so adding
    /// a rate to one site never shifts another site's replay sequence.
    pub fn fire(&self, site: FaultSite) -> bool {
        if !self.enabled {
            return false;
        }
        let rate = self.rate(site);
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        let x = self.rng.lock().unwrap().next_u64();
        (x as f64) < rate * (u64::MAX as f64)
    }

    /// Sleep if [`FaultSite::ServiceLatency`] fires.
    pub fn maybe_delay(&self) {
        if self.fire(FaultSite::ServiceLatency) {
            std::thread::sleep(self.latency_dur);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fires() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        for _ in 0..100 {
            assert!(!p.fire(FaultSite::ExecPanic));
            assert!(!p.fire(FaultSite::QueueFull));
        }
    }

    #[test]
    fn rate_bounds_are_exact() {
        let p = FaultPlan::seeded(7).with_rate(FaultSite::ExecPanic, 1.0);
        for _ in 0..100 {
            assert!(p.fire(FaultSite::ExecPanic));
        }
        let p = FaultPlan::seeded(7).with_rate(FaultSite::ExecPanic, 0.0);
        for _ in 0..100 {
            assert!(!p.fire(FaultSite::ExecPanic));
        }
    }

    #[test]
    fn same_seed_replays_the_same_firing_sequence() {
        let draw = |seed: u64| -> Vec<bool> {
            let p = FaultPlan::seeded(seed).with_rate(FaultSite::ReplyDrop, 0.5);
            (0..64).map(|_| p.fire(FaultSite::ReplyDrop)).collect()
        };
        assert_eq!(draw(42), draw(42), "a seed must replay deterministically");
        assert_ne!(draw(42), draw(43), "different seeds must diverge");
        let seq = draw(42);
        assert!(seq.iter().any(|&b| b) && seq.iter().any(|&b| !b), "rate 0.5 mixes outcomes");
    }

    #[test]
    fn env_spec_parses_every_key() {
        let p = FaultPlan::parse(
            "seed=9,queue_full=0.25,exec_panic=0.5,latency=1.0,latency_ms=7,reply_drop=0.1",
        );
        assert!(p.is_active());
        assert_eq!(p.queue_full, 0.25);
        assert_eq!(p.exec_panic, 0.5);
        assert_eq!(p.latency, 1.0);
        assert_eq!(p.latency_dur, Duration::from_millis(7));
        assert_eq!(p.reply_drop, 0.1);
    }

    #[test]
    #[should_panic(expected = "unknown key")]
    fn env_spec_rejects_unknown_keys() {
        let _ = FaultPlan::parse("seed=1,typo_rate=0.5");
    }
}
