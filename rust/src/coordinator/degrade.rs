//! The overload degradation ladder: trade per-request latency headroom
//! for availability when an entry's queue stays hot.
//!
//! Each engine worker feeds the ladder one observation per drain — how
//! many jobs the drain pulled, relative to the queue capacity. Sustained
//! hot drains escalate the level; sustained cool drains walk it back
//! (with hysteresis on both edges so one burst cannot flap the ladder):
//!
//! * **Level 0** — normal: chunks up to `max_batch`, partial buckets
//!   padded to the next power of two, batch variants compiled lazily if
//!   missing.
//! * **Level 1** — capped: chunks snap to the largest *already-compiled*
//!   power-of-two bucket that fits exactly. No pad slots are computed
//!   and wasted, and the serving path never compiles — throughput is
//!   spent only on live work.
//! * **Level 2** — base plan only: every request runs the entry's cached
//!   `OptLevel::None` canonical plan (batch 1). Maximum availability,
//!   zero batching wait.
//!
//! Degraded output equals normal output bit-for-bit: every level serves
//! from the same frozen canonical graph through bucket variants that are
//! already pinned bit-identical per slice (`tests/serve_batch.rs`), so
//! the ladder changes *scheduling*, never numerics — asserted again
//! end-to-end in `tests/chaos.rs`.

/// Per-worker escalation state. Deterministic: level transitions depend
/// only on the sequence of drain sizes fed in.
#[derive(Debug)]
pub struct DegradeLadder {
    level: u8,
    hot: u32,
    cool: u32,
    /// a drain pulling at least this many jobs is "hot"
    high_fill: usize,
    /// a drain pulling at most this many jobs is "cool"
    low_fill: usize,
    escalate_after: u32,
    deescalate_after: u32,
}

/// Highest ladder level (base-plan-only serving).
pub const MAX_DEGRADE_LEVEL: u8 = 2;

impl DegradeLadder {
    /// Thresholds derive from the queue capacity: hot at half-full
    /// drains, cool at one-eighth. Escalation needs 3 consecutive hot
    /// drains; de-escalation needs 8 consecutive cool ones — recovering
    /// is deliberately slower than degrading.
    pub fn new(queue_cap: usize) -> Self {
        DegradeLadder {
            level: 0,
            hot: 0,
            cool: 0,
            high_fill: (queue_cap / 2).max(2),
            low_fill: (queue_cap / 8).max(1),
            escalate_after: 3,
            deescalate_after: 8,
        }
    }

    pub fn level(&self) -> u8 {
        self.level
    }

    /// Feed one drain's job count. Returns `(level, escalated)` —
    /// `escalated` is true exactly when this observation raised the
    /// level (the metrics hook counts those transitions).
    pub fn observe_drain(&mut self, drained: usize) -> (u8, bool) {
        if drained >= self.high_fill {
            self.hot += 1;
            self.cool = 0;
            if self.hot >= self.escalate_after && self.level < MAX_DEGRADE_LEVEL {
                self.level += 1;
                self.hot = 0;
                return (self.level, true);
            }
        } else if drained <= self.low_fill {
            self.cool += 1;
            self.hot = 0;
            if self.cool >= self.deescalate_after && self.level > 0 {
                self.level -= 1;
                self.cool = 0;
            }
        } else {
            // mid-band drains reset both streaks: hysteresis
            self.hot = 0;
            self.cool = 0;
        }
        (self.level, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sustained_hot_drains_escalate_stepwise() {
        let mut l = DegradeLadder::new(16); // hot ≥ 8, cool ≤ 2
        assert_eq!(l.observe_drain(8), (0, false));
        assert_eq!(l.observe_drain(8), (0, false));
        assert_eq!(l.observe_drain(8), (1, true), "third hot drain escalates");
        assert_eq!(l.observe_drain(16), (1, false));
        assert_eq!(l.observe_drain(16), (1, false));
        assert_eq!(l.observe_drain(16), (2, true));
        // the ladder tops out at MAX_DEGRADE_LEVEL
        for _ in 0..10 {
            assert_eq!(l.observe_drain(16).0, MAX_DEGRADE_LEVEL);
        }
    }

    #[test]
    fn recovery_needs_a_longer_cool_streak() {
        let mut l = DegradeLadder::new(16);
        for _ in 0..3 {
            l.observe_drain(16);
        }
        assert_eq!(l.level(), 1);
        // 7 cool drains are not enough
        for _ in 0..7 {
            assert_eq!(l.observe_drain(1).0, 1);
        }
        assert_eq!(l.observe_drain(1), (0, false), "eighth cool drain de-escalates");
    }

    #[test]
    fn mid_band_drains_break_both_streaks() {
        let mut l = DegradeLadder::new(16);
        l.observe_drain(8);
        l.observe_drain(8);
        l.observe_drain(4); // mid-band: resets the hot streak
        assert_eq!(l.observe_drain(8), (0, false));
        assert_eq!(l.observe_drain(8), (0, false));
        assert_eq!(l.observe_drain(8), (1, true));
        // and on the way down: a mid-band drain resets the cool streak
        for _ in 0..7 {
            l.observe_drain(1);
        }
        l.observe_drain(4);
        for _ in 0..7 {
            assert_eq!(l.observe_drain(1).0, 1);
        }
        assert_eq!(l.observe_drain(1).0, 0);
    }

    #[test]
    fn tiny_queues_still_have_a_working_band() {
        let mut l = DegradeLadder::new(1); // hot ≥ 2, cool ≤ 1
        for _ in 0..3 {
            l.observe_drain(5);
        }
        assert_eq!(l.level(), 1, "cap-1 queues must still be able to degrade");
        for _ in 0..8 {
            l.observe_drain(0);
        }
        assert_eq!(l.level(), 0);
    }
}
