//! Observability: plan-level tracing and profiling.
//!
//! Zero-dependency instrumentation for the compiled executor. Both
//! execution backends record per-instruction (and, in [`TraceMode::Trace`],
//! per-level and epilogue) spans into pre-sized per-lane ring buffers
//! owned by the plan's run state ([`TraceSink`]); the drained [`Trace`]
//! aggregates into a [`Profile`] (top-k instructions by time, achieved
//! GFLOP/s against the `opt::cost` flop estimate, level occupancy) or
//! exports as Chrome trace-event JSON loadable in Perfetto /
//! `chrome://tracing` ([`chrome_trace_json`]).
//!
//! The overhead contract: with [`TraceMode::Off`] (the default) the hot
//! path pays exactly one predictable branch per instruction — no
//! allocation, no lock, no clock read — and plans stay bit-identical to
//! pre-instrumentation builds (counter-asserted in
//! `tests/obs_trace.rs`, like PR 5's zero-alloc arena contract). With
//! tracing on, each span costs two monotonic clock reads and one write
//! into a lane-private ring buffer; buffers never grow mid-run, and
//! overflow increments a drop counter instead of allocating.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Trace modes
// ---------------------------------------------------------------------------

/// How much a compiled plan records while executing.
///
/// Threads through `CompiledPlan::with_options`, the lowering artifact,
/// the plan-cache key, `eval_many_opts` and the `--trace` CLI flag.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TraceMode {
    /// No instrumentation: the steady-state contract (zero allocations,
    /// no locks, bit-identical output) is unchanged.
    #[default]
    Off,
    /// Per-instruction spans only — enough for the [`Profile`] table.
    Profile,
    /// Instruction + level + two-pass-epilogue spans — the full
    /// timeline for Chrome-trace export.
    Trace,
}

impl TraceMode {
    /// Canonical lower-case name, as accepted by [`TraceMode::parse`].
    pub fn name(self) -> &'static str {
        match self {
            TraceMode::Off => "off",
            TraceMode::Profile => "profile",
            TraceMode::Trace => "trace",
        }
    }

    /// Parse a CLI-style mode name.
    pub fn parse(s: &str) -> Option<TraceMode> {
        match s {
            "off" => Some(TraceMode::Off),
            "profile" => Some(TraceMode::Profile),
            "trace" => Some(TraceMode::Trace),
            _ => None,
        }
    }
}

impl std::fmt::Display for TraceMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

/// What a [`Span`] measured.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SpanKind {
    /// One executed instruction (`id` = instruction position).
    #[default]
    Instr,
    /// One DAG level, fork to join (`id` = level index, lane 0).
    Level,
    /// The second pass of a two-pass epilogue (`id` = the carrying
    /// instruction's position).
    Epilogue,
}

/// One timed interval, in nanoseconds since the run's epoch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    pub kind: SpanKind,
    /// Instruction position or level index, per [`SpanKind`].
    pub id: u32,
    /// Worker lane (scope participant index; 0 is the calling thread).
    pub lane: u32,
    pub t0_ns: u64,
    pub t1_ns: u64,
}

impl Span {
    /// Span duration in seconds.
    pub fn secs(&self) -> f64 {
        self.t1_ns.saturating_sub(self.t0_ns) as f64 * 1e-9
    }
}

// ---------------------------------------------------------------------------
// The sink: pre-sized per-lane ring buffers
// ---------------------------------------------------------------------------

/// One lane's ring: a fixed, pre-sized span array plus a monotone write
/// counter. Writes past capacity wrap (oldest spans are overwritten and
/// counted as dropped at drain time); the buffer never grows mid-run.
struct LaneBuf {
    spans: Vec<Span>,
    written: u64,
}

impl LaneBuf {
    fn new(cap: usize) -> LaneBuf {
        LaneBuf { spans: vec![Span::default(); cap.max(1)], written: 0 }
    }

    #[inline]
    fn push(&mut self, span: Span) {
        let cap = self.spans.len();
        self.spans[(self.written % cap as u64) as usize] = span;
        self.written += 1;
    }
}

/// A lane slot. Each lane is written only by the single scope
/// participant running as that lane (the same disjointness argument as
/// the executor's arena slots), so handing `&TraceSink` to all
/// participants is safe.
struct LaneSlot(UnsafeCell<LaneBuf>);

// SAFETY: see `LaneSlot` — lane i is touched only by participant i
// while the scope is live, and only by the owner (`&mut`) otherwise.
unsafe impl Sync for LaneSlot {}

/// Per-run span recorder owned by a plan's run state: one pre-sized
/// ring buffer per worker lane plus the run's clock epoch. Allocated
/// once per run state on the first traced run and reused (reset)
/// afterwards, so traced steady state allocates nothing either.
pub struct TraceSink {
    mode: TraceMode,
    epoch: Instant,
    lanes: Box<[LaneSlot]>,
    /// Spans aimed at a lane index beyond the sink's width (never
    /// expected; counted instead of written to keep `record` race-free).
    overflow: AtomicU64,
}

impl TraceSink {
    /// A sink with `lanes` ring buffers of `cap` spans each.
    pub fn new(mode: TraceMode, lanes: usize, cap: usize) -> TraceSink {
        let lanes = lanes.max(1);
        TraceSink {
            mode,
            epoch: Instant::now(),
            lanes: (0..lanes).map(|_| LaneSlot(UnsafeCell::new(LaneBuf::new(cap)))).collect(),
            overflow: AtomicU64::new(0),
        }
    }

    pub fn mode(&self) -> TraceMode {
        self.mode
    }

    /// Nanoseconds since the current run's epoch.
    #[inline]
    pub fn now(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Rewind every lane and restart the clock for a new run.
    pub fn reset(&mut self) {
        for slot in self.lanes.iter_mut() {
            slot.0.get_mut().written = 0;
        }
        *self.overflow.get_mut() = 0;
        self.epoch = Instant::now();
    }

    #[inline]
    fn record(&self, lane: u32, kind: SpanKind, id: u32, t0_ns: u64) {
        let t1_ns = self.now();
        match self.lanes.get(lane as usize) {
            // SAFETY: each lane is written only by its own participant.
            Some(slot) => unsafe {
                (*slot.0.get()).push(Span { kind, id, lane, t0_ns, t1_ns });
            },
            None => {
                self.overflow.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Record one executed instruction, closing at the current clock.
    #[inline]
    pub fn record_instr(&self, lane: u32, pos: u32, t0_ns: u64) {
        self.record(lane, SpanKind::Instr, pos, t0_ns);
    }

    /// Record one level (fork to join). Level spans are part of the
    /// full timeline only — [`TraceMode::Profile`] skips them.
    #[inline]
    pub fn record_level(&self, level: u32, t0_ns: u64) {
        if self.mode == TraceMode::Trace {
            self.record(0, SpanKind::Level, level, t0_ns);
        }
    }

    /// Record a two-pass epilogue's second pass (full timeline only).
    #[inline]
    pub fn record_epilogue(&self, lane: u32, pos: u32, t0_ns: u64) {
        if self.mode == TraceMode::Trace {
            self.record(lane, SpanKind::Epilogue, pos, t0_ns);
        }
    }

    /// Collect the run's spans, sorted by start time.
    pub fn drain(&mut self) -> Trace {
        let mut spans = Vec::new();
        let mut dropped = *self.overflow.get_mut();
        let lanes = self.lanes.len();
        for slot in self.lanes.iter_mut() {
            let buf = slot.0.get_mut();
            let cap = buf.spans.len() as u64;
            if buf.written <= cap {
                spans.extend_from_slice(&buf.spans[..buf.written as usize]);
            } else {
                // the ring wrapped: the oldest `written - cap` spans are
                // gone; what's left starts at the wrap cursor
                dropped += buf.written - cap;
                let at = (buf.written % cap) as usize;
                spans.extend_from_slice(&buf.spans[at..]);
                spans.extend_from_slice(&buf.spans[..at]);
            }
        }
        spans.sort_by_key(|s| (s.t0_ns, s.t1_ns));
        Trace { mode: self.mode, spans, lanes, dropped }
    }
}

/// The drained spans of one plan run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub mode: TraceMode,
    /// All spans, sorted by start time.
    pub spans: Vec<Span>,
    /// Ring buffers the sink carried (one per potential worker lane).
    pub lanes: usize,
    /// Spans lost to ring wrap-around (0 unless a plan re-executes an
    /// instruction stream larger than the pre-sized rings).
    pub dropped: u64,
}

impl Trace {
    /// Spans of one kind, in start order.
    pub fn spans_of(&self, kind: SpanKind) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(move |s| s.kind == kind)
    }
}

// ---------------------------------------------------------------------------
// Static plan description (built by `exec`, consumed by the exporters)
// ---------------------------------------------------------------------------

/// What the lowering knows statically about one executed instruction.
#[derive(Clone, Debug)]
pub struct InstrInfo {
    /// Position in the lowered instruction stream.
    pub pos: u32,
    /// Human-readable kernel label (`mul`, `fused[4]`, `elem tanh`, …).
    pub name: String,
    /// DAG level the instruction executes in.
    pub level: u32,
    /// The `opt::cost`-model flop estimate baked in at lowering.
    pub flops: u64,
    /// Output bytes written.
    pub bytes: u64,
}

/// Static description of a compiled plan, paired with a [`Trace`] by
/// the exporters. Built by `CompiledPlan::plan_info`.
#[derive(Clone, Debug, Default)]
pub struct PlanInfo {
    /// Executed instructions only (`Var`/`Static` never run and are
    /// never traced).
    pub instrs: Vec<InstrInfo>,
    /// Number of DAG levels in the schedule.
    pub levels: usize,
    /// Executing backend name (`cpu` / `direct`).
    pub backend: &'static str,
}

impl PlanInfo {
    fn instr(&self, pos: u32) -> Option<&InstrInfo> {
        self.instrs.iter().find(|i| i.pos == pos)
    }
}

// ---------------------------------------------------------------------------
// Profile aggregation
// ---------------------------------------------------------------------------

/// Aggregated cost of one instruction across a trace.
#[derive(Clone, Debug)]
pub struct InstrProfile {
    pub pos: u32,
    pub name: String,
    pub level: u32,
    /// Spans observed (1 per run for a single-run trace).
    pub calls: u64,
    /// Total wall time across all spans.
    pub secs: f64,
    /// The cost model's flop estimate (per call).
    pub flops: u64,
    /// Achieved GFLOP/s: `calls · flops / secs / 1e9`.
    pub gflops: f64,
}

/// Aggregated occupancy of one DAG level.
#[derive(Clone, Debug)]
pub struct LevelProfile {
    pub level: u32,
    /// Executed instructions scheduled in this level.
    pub instrs: usize,
    /// Level envelope: last span end minus first span start.
    pub wall_secs: f64,
    /// Sum of instruction span durations inside the level.
    pub busy_secs: f64,
    /// Distinct worker lanes that recorded spans in the level.
    pub lanes_used: usize,
    /// `busy / (wall · lanes_used)` — the steal-balance figure.
    pub occupancy: f64,
}

/// Per-plan profile: the [`Trace`] rolled up against the plan's static
/// [`PlanInfo`].
#[derive(Clone, Debug, Default)]
pub struct Profile {
    pub mode: TraceMode,
    /// Envelope of all instruction spans.
    pub wall_secs: f64,
    /// Cost-model flops summed over all recorded calls.
    pub total_flops: u64,
    /// Per-instruction rows, sorted by total time, descending.
    pub instrs: Vec<InstrProfile>,
    /// Per-level rows, in level order.
    pub levels: Vec<LevelProfile>,
    /// Distinct instructions that recorded at least one span.
    pub covered: usize,
    /// Executed instructions the plan carries.
    pub expected: usize,
    /// Spans lost to ring wrap-around.
    pub dropped: u64,
}

impl Profile {
    /// Roll a trace up against its plan description.
    pub fn build(trace: &Trace, info: &PlanInfo) -> Profile {
        let mut per_instr: Vec<(u64, u64)> = Vec::new(); // (calls, ns) by info index
        per_instr.resize(info.instrs.len(), (0, 0));
        let mut t_lo = u64::MAX;
        let mut t_hi = 0u64;
        for s in trace.spans_of(SpanKind::Instr) {
            t_lo = t_lo.min(s.t0_ns);
            t_hi = t_hi.max(s.t1_ns);
            if let Some(ix) = info.instrs.iter().position(|i| i.pos == s.id) {
                per_instr[ix].0 += 1;
                per_instr[ix].1 += s.t1_ns.saturating_sub(s.t0_ns);
            }
        }
        let mut instrs: Vec<InstrProfile> = info
            .instrs
            .iter()
            .zip(&per_instr)
            .filter(|(_, (calls, _))| *calls > 0)
            .map(|(i, &(calls, ns))| {
                let secs = ns as f64 * 1e-9;
                InstrProfile {
                    pos: i.pos,
                    name: i.name.clone(),
                    level: i.level,
                    calls,
                    secs,
                    flops: i.flops,
                    gflops: if secs > 0.0 {
                        (calls as f64 * i.flops as f64) / secs / 1e9
                    } else {
                        0.0
                    },
                }
            })
            .collect();
        instrs.sort_by(|a, b| b.secs.total_cmp(&a.secs));
        let total_flops: u64 = instrs.iter().map(|i| i.calls * i.flops).sum();

        let mut levels = Vec::new();
        for lv in 0..info.levels as u32 {
            let members: Vec<u32> =
                info.instrs.iter().filter(|i| i.level == lv).map(|i| i.pos).collect();
            if members.is_empty() {
                continue;
            }
            let mut lo = u64::MAX;
            let mut hi = 0u64;
            let mut busy = 0u64;
            let mut lanes: Vec<u32> = Vec::new();
            let mut seen = false;
            for s in trace.spans_of(SpanKind::Instr).filter(|s| members.contains(&s.id)) {
                seen = true;
                lo = lo.min(s.t0_ns);
                hi = hi.max(s.t1_ns);
                busy += s.t1_ns.saturating_sub(s.t0_ns);
                if !lanes.contains(&s.lane) {
                    lanes.push(s.lane);
                }
            }
            if !seen {
                continue;
            }
            let wall_secs = hi.saturating_sub(lo) as f64 * 1e-9;
            let busy_secs = busy as f64 * 1e-9;
            let denom = wall_secs * lanes.len().max(1) as f64;
            levels.push(LevelProfile {
                level: lv,
                instrs: members.len(),
                wall_secs,
                busy_secs,
                lanes_used: lanes.len(),
                occupancy: if denom > 0.0 { (busy_secs / denom).min(1.0) } else { 1.0 },
            });
        }

        Profile {
            mode: trace.mode,
            wall_secs: if t_hi > t_lo { (t_hi - t_lo) as f64 * 1e-9 } else { 0.0 },
            total_flops,
            covered: instrs.len(),
            expected: info.instrs.len(),
            instrs,
            levels,
            dropped: trace.dropped,
        }
    }

    /// Render the paper-bench-style profile table: a plan summary, the
    /// top-`k` instructions by time, and per-level occupancy.
    pub fn render_table(&self, k: usize) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let total_secs: f64 = self.instrs.iter().map(|i| i.secs).sum();
        let _ = writeln!(
            out,
            "profile: {} of {} instructions covered, wall {:.3} ms, {:.3} GFLOP total{}",
            self.covered,
            self.expected,
            self.wall_secs * 1e3,
            self.total_flops as f64 / 1e9,
            if self.dropped > 0 {
                format!(", {} spans dropped", self.dropped)
            } else {
                String::new()
            }
        );
        let _ = writeln!(
            out,
            "{:>4} {:<28} {:>5} {:>5} {:>10} {:>6} {:>12} {:>9}",
            "pos", "instr", "level", "calls", "time", "%time", "flops/call", "GFLOP/s"
        );
        for i in self.instrs.iter().take(k) {
            let _ = writeln!(
                out,
                "{:>4} {:<28} {:>5} {:>5} {:>9.1}us {:>5.1}% {:>12} {:>9.2}",
                i.pos,
                i.name,
                i.level,
                i.calls,
                i.secs * 1e6,
                if total_secs > 0.0 { 100.0 * i.secs / total_secs } else { 0.0 },
                i.flops,
                i.gflops
            );
        }
        if !self.levels.is_empty() {
            let _ = writeln!(
                out,
                "{:>5} {:>6} {:>10} {:>10} {:>5} {:>9}",
                "level", "instrs", "wall", "busy", "lanes", "occupancy"
            );
            for l in &self.levels {
                let _ = writeln!(
                    out,
                    "{:>5} {:>6} {:>9.1}us {:>9.1}us {:>5} {:>8.1}%",
                    l.level,
                    l.instrs,
                    l.wall_secs * 1e6,
                    l.busy_secs * 1e6,
                    l.lanes_used,
                    l.occupancy * 100.0
                );
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize a trace as Chrome trace-event JSON (the `traceEvents`
/// array format), loadable in Perfetto or `chrome://tracing`. Worker
/// lanes map to tids, instruction / level / epilogue spans become
/// complete (`"ph":"X"`) events, and each lane gets a `thread_name`
/// metadata record.
pub fn chrome_trace_json(trace: &Trace, info: &PlanInfo) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut sep = |out: &mut String, first: &mut bool| {
        if !*first {
            out.push(',');
        }
        *first = false;
        out.push('\n');
    };
    for lane in 0..trace.lanes {
        sep(&mut out, &mut first);
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"lane {}{}\"}}}}",
            lane,
            lane,
            if lane == 0 { " (caller)" } else { "" }
        );
    }
    for s in &trace.spans {
        sep(&mut out, &mut first);
        let (cat, name, flops, level) = match s.kind {
            SpanKind::Instr => match info.instr(s.id) {
                Some(i) => ("instr", i.name.clone(), i.flops, i.level),
                None => ("instr", format!("instr {}", s.id), 0, 0),
            },
            SpanKind::Level => ("level", format!("level {}", s.id), 0, s.id),
            SpanKind::Epilogue => {
                let name = match info.instr(s.id) {
                    Some(i) => format!("epilogue of {}", i.name),
                    None => format!("epilogue of instr {}", s.id),
                };
                ("epilogue", name, 0, s.id)
            }
        };
        let _ = write!(
            out,
            "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\"cat\":\"{}\",\
             \"name\":",
            s.lane,
            s.t0_ns as f64 / 1e3,
            s.t1_ns.saturating_sub(s.t0_ns) as f64 / 1e3,
            cat
        );
        push_json_str(&mut out, &name);
        let _ = write!(
            out,
            ",\"args\":{{\"pos\":{},\"level\":{},\"flops\":{}}}}}",
            s.id, level, flops
        );
    }
    let _ = write!(
        out,
        "\n],\"displayTimeUnit\":\"ns\",\"otherData\":{{\"backend\":\"{}\",\"mode\":\"{}\",\
         \"dropped\":{}}}}}",
        info.backend,
        trace.mode.name(),
        trace.dropped
    );
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, id: u32, lane: u32, t0: u64, t1: u64) -> Span {
        Span { kind, id, lane, t0_ns: t0, t1_ns: t1 }
    }

    fn info2() -> PlanInfo {
        PlanInfo {
            instrs: vec![
                InstrInfo { pos: 2, name: "mul".into(), level: 1, flops: 1000, bytes: 80 },
                InstrInfo { pos: 3, name: "elem tanh".into(), level: 2, flops: 10, bytes: 80 },
            ],
            levels: 3,
            backend: "cpu",
        }
    }

    #[test]
    fn sink_records_resets_and_drains_in_order() {
        let mut sink = TraceSink::new(TraceMode::Trace, 2, 8);
        let a = sink.now();
        sink.record_instr(1, 7, a);
        let b = sink.now();
        sink.record_instr(0, 3, b);
        sink.record_level(0, a);
        let t = sink.drain();
        assert_eq!(t.spans.len(), 3);
        assert_eq!(t.lanes, 2);
        assert_eq!(t.dropped, 0);
        assert!(t.spans.windows(2).all(|w| w[0].t0_ns <= w[1].t0_ns));
        // reset rewinds everything
        sink.reset();
        let t = sink.drain();
        assert!(t.spans.is_empty());
    }

    #[test]
    fn profile_mode_skips_level_and_epilogue_spans() {
        let mut sink = TraceSink::new(TraceMode::Profile, 1, 8);
        let t0 = sink.now();
        sink.record_instr(0, 1, t0);
        sink.record_level(0, t0);
        sink.record_epilogue(0, 1, t0);
        let t = sink.drain();
        assert_eq!(t.spans.len(), 1);
        assert_eq!(t.spans[0].kind, SpanKind::Instr);
    }

    #[test]
    fn ring_wraps_and_counts_drops_instead_of_growing() {
        let mut sink = TraceSink::new(TraceMode::Profile, 1, 4);
        for i in 0..10u32 {
            let t0 = sink.now();
            sink.record_instr(0, i, t0);
        }
        let t = sink.drain();
        assert_eq!(t.spans.len(), 4, "ring must stay at capacity");
        assert_eq!(t.dropped, 6);
        // the survivors are the newest writes, still in order
        let ids: Vec<u32> = t.spans.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
    }

    #[test]
    fn out_of_range_lane_counts_overflow() {
        let mut sink = TraceSink::new(TraceMode::Profile, 1, 4);
        let t0 = sink.now();
        sink.record_instr(5, 0, t0);
        let t = sink.drain();
        assert!(t.spans.is_empty());
        assert_eq!(t.dropped, 1);
    }

    #[test]
    fn profile_aggregates_time_flops_and_occupancy() {
        let trace = Trace {
            mode: TraceMode::Trace,
            spans: vec![
                span(SpanKind::Instr, 2, 0, 0, 2_000),
                span(SpanKind::Instr, 2, 0, 2_000, 4_000),
                span(SpanKind::Instr, 3, 1, 4_000, 5_000),
            ],
            lanes: 2,
            dropped: 0,
        };
        let p = Profile::build(&trace, &info2());
        assert_eq!(p.covered, 2);
        assert_eq!(p.expected, 2);
        assert_eq!(p.total_flops, 2 * 1000 + 10);
        // sorted by time: the mul (4µs) leads
        assert_eq!(p.instrs[0].pos, 2);
        assert_eq!(p.instrs[0].calls, 2);
        assert!((p.instrs[0].secs - 4e-6).abs() < 1e-12);
        assert!((p.instrs[0].gflops - 2000.0 / 4e-6 / 1e9).abs() < 1e-9);
        // one level row per level with executed members + spans
        assert_eq!(p.levels.len(), 2);
        assert_eq!(p.levels[0].level, 1);
        assert_eq!(p.levels[0].lanes_used, 1);
        assert!((p.levels[0].occupancy - 1.0).abs() < 1e-9);
        let table = p.render_table(10);
        assert!(table.contains("mul"));
        assert!(table.contains("elem tanh"));
    }

    #[test]
    fn chrome_json_has_events_metadata_and_escaping() {
        let mut info = info2();
        info.instrs[0].name = "mul \"ij,jk->ik\"".into();
        let trace = Trace {
            mode: TraceMode::Trace,
            spans: vec![
                span(SpanKind::Level, 1, 0, 0, 5_000),
                span(SpanKind::Instr, 2, 1, 100, 4_900),
                span(SpanKind::Epilogue, 2, 1, 4_000, 4_800),
            ],
            lanes: 2,
            dropped: 0,
        };
        let js = chrome_trace_json(&trace, &info);
        assert!(js.starts_with("{\"traceEvents\":["));
        assert!(js.contains("\"ph\":\"X\""));
        assert!(js.contains("\"ph\":\"M\""));
        assert!(js.contains("\"cat\":\"level\""));
        assert!(js.contains("\"cat\":\"epilogue\""));
        assert!(js.contains("mul \\\"ij,jk->ik\\\""));
        assert_eq!(js.matches('{').count(), js.matches('}').count());
    }

    #[test]
    fn trace_mode_names_round_trip() {
        for m in [TraceMode::Off, TraceMode::Profile, TraceMode::Trace] {
            assert_eq!(TraceMode::parse(m.name()), Some(m));
        }
        assert_eq!(TraceMode::parse("bogus"), None);
        assert_eq!(TraceMode::default(), TraceMode::Off);
    }
}
