//! The static memory planner: buffer lifetimes compiled to arena offsets.
//!
//! A [`CompiledPlan`](super::CompiledPlan) already knows, at compile
//! time, when every intermediate is born (its dependency level) and when
//! it dies (the last level that reads it — the same liveness the pooled
//! mode uses for recycling). This module turns that knowledge into a
//! *memory plan*: every instruction output — and every einsum
//! gather/presum scratch region — gets a fixed element offset into one
//! per-plan arena, so at run time a destination is just
//! `&arena[off..off + len]`. No mutex, no bucket lookup, no allocation
//! after the arena's first growth.
//!
//! ```text
//!   liveness            intervals                offsets
//!   (per level)         (def ..= last use)       (best-fit packing)
//!
//!   L0  a ──┐           a: [0, 2] ────┐          a: [0   .. 400)
//!   L1  b ──┼─ reads a   b: [1, 1] ──┐│          b: [400 .. 480)
//!   L2  c ──┘  reads a,b c: [2, 3]   ││ b dead   c: [400 .. 464)   ← reuses b's bytes
//!   L3  d  reads c       d: [3, 3] ──┘│ a dead   d: [0   .. 320)   ← reuses a's bytes
//!                                     └─────────────────────────────
//! ```
//!
//! Packing rules (all decided here, once per plan):
//!
//! * Two buffers may share bytes iff their level intervals are disjoint.
//!   A buffer read for the last time in level `L` frees its bytes for
//!   allocations from level `L + 1` on — never within `L`, because
//!   instructions inside one level run concurrently.
//! * Allocation is **best-fit** over a coalescing free list (smallest
//!   hole that fits; a top-adjacent hole is grown instead of leaving a
//!   permanent gap); only when nothing fits does the arena extend.
//! * **In-place reuse**: when an alias-safe instruction (element-wise
//!   map, add, fused pipeline) is the *sole* last-level consumer of an
//!   operand whose slot length equals the output length, the output
//!   simply takes over the dying operand's slot and the instruction runs
//!   in place — the chain `x → tanh → scale → …` costs one slot total.
//! * Einsum scratch regions live exactly for their instruction's level
//!   (`[L, L]`), so concurrent contractions in one level get disjoint
//!   scratch and consecutive levels reuse it.
//!
//! [`MemPlan::check_no_overlap`] is the debug-mode checker the
//! differential test suite calls: it re-verifies, pairwise, that no two
//! live intervals share arena bytes (in-place transfers hand bytes over
//! with back-to-back intervals, which it models exactly).

use crate::einsum::ScratchSizes;

/// One arena region, in `f64` elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slot {
    pub off: usize,
    pub len: usize,
}

/// What the planner must know about one instruction.
pub struct PlanInput {
    /// output length in elements; `None` for instructions that do not
    /// own a buffer (`Var` bindings, compile-time statics)
    pub out_len: Option<usize>,
    /// einsum scratch sizes (contractions only)
    pub scratch: Option<ScratchSizes>,
    /// dependency level the instruction executes at
    pub def: usize,
    /// last level that reads the output (inclusive); `None` = lives to
    /// the end of the run (roots)
    pub last: Option<usize>,
    /// stream position of an operand whose slot the output may take over
    /// in place (the executor pre-checks alias safety, sole-last-level
    /// consumption and length equality; the planner confirms and commits)
    pub inplace_from: Option<usize>,
}

/// The compiled memory plan of one instruction stream.
pub struct MemPlan {
    /// per instruction: the arena slot of its output
    pub out: Vec<Option<Slot>>,
    /// per instruction: einsum scratch slots `[a, b, c]`
    pub scratch: Vec<Option<[Slot; 3]>>,
    /// per instruction: confirmed in-place source (stream position)
    pub inplace: Vec<Option<usize>>,
    /// total arena length in elements
    pub arena_len: usize,
    /// slots packed into bytes a dead buffer freed earlier
    pub planned_reuse: u64,
    /// outputs that took over a dying operand's slot in place
    pub inplace_reuse: u64,
    /// `(slot, first level, last level)` of every placed buffer — the
    /// overlap checker's ground truth (in-place donors end one level
    /// before their taker starts)
    intervals: Vec<(Slot, usize, usize)>,
}

impl MemPlan {
    /// Pack the instruction stream's buffers into arena offsets.
    /// `n_levels` is the number of dependency levels; inputs are indexed
    /// by stream position.
    pub fn build(inputs: &[PlanInput], n_levels: usize) -> MemPlan {
        let m = inputs.len();
        let mut out: Vec<Option<Slot>> = vec![None; m];
        let mut scratch: Vec<Option<[Slot; 3]>> = vec![None; m];
        let mut inplace: Vec<Option<usize>> = vec![None; m];
        let mut free: Vec<Slot> = Vec::new();
        let mut arena_len = 0usize;
        let mut planned_reuse = 0u64;
        let mut inplace_reuse = 0u64;
        let last_level = n_levels.saturating_sub(1);
        let end_of = |i: usize| inputs[i].last.unwrap_or(last_level);

        // buffers whose bytes become free *after* level L sit in
        // expiring[L]; they are released when level L + 1 starts
        let mut expiring: Vec<Vec<Slot>> = vec![Vec::new(); n_levels.max(1)];
        let mut defs: Vec<Vec<usize>> = vec![Vec::new(); n_levels.max(1)];
        for (i, inp) in inputs.iter().enumerate() {
            if inp.out_len.is_some() || inp.scratch.is_some() {
                defs[inp.def].push(i);
            }
        }

        for lv in 0..n_levels {
            // 1. bytes whose last reader ran in the previous level are free
            if lv > 0 {
                let expired = std::mem::take(&mut expiring[lv - 1]);
                for s in expired {
                    free_slot(&mut free, s);
                }
            }
            // 2. place this level's outputs, then scratch
            for &i in &defs[lv] {
                let inp = &inputs[i];
                if let (Some(len), Some(o)) = (inp.out_len, inp.inplace_from) {
                    // in-place transfer: take over the dying operand's
                    // slot (its expiry at this level is cancelled — the
                    // bytes now live until *this* buffer dies)
                    if len > 0 {
                        if let Some(oslot) = out[o] {
                            let donor_end = end_of(o);
                            if oslot.len == len && donor_end == lv {
                                if let Some(pos) =
                                    expiring[donor_end].iter().position(|s| *s == oslot)
                                {
                                    expiring[donor_end].remove(pos);
                                    out[i] = Some(oslot);
                                    inplace[i] = Some(o);
                                    inplace_reuse += 1;
                                    if let Some(e) = inp.last {
                                        expiring[e].push(oslot);
                                    }
                                }
                            }
                        }
                    }
                }
                if out[i].is_none() {
                    if let Some(len) = inp.out_len {
                        let (slot, reused) = alloc(&mut free, &mut arena_len, len);
                        if reused {
                            planned_reuse += 1;
                        }
                        out[i] = Some(slot);
                        if let Some(e) = inp.last {
                            if len > 0 {
                                expiring[e].push(slot);
                            }
                        }
                    }
                }
                if let Some(ss) = inp.scratch {
                    // scratch is live only while instruction i runs
                    let mut slots = [Slot { off: 0, len: 0 }; 3];
                    for (j, len) in [ss.a, ss.b, ss.c].into_iter().enumerate() {
                        let (slot, reused) = alloc(&mut free, &mut arena_len, len);
                        if reused {
                            planned_reuse += 1;
                        }
                        slots[j] = slot;
                        if len > 0 {
                            expiring[lv].push(slot);
                        }
                    }
                    scratch[i] = Some(slots);
                }
            }
        }

        // record intervals for the overlap checker: an in-place donor's
        // bytes are handed over at the taker's level, so its interval
        // ends one level earlier
        let mut donated_until: Vec<Option<usize>> = vec![None; m];
        for (i, &src) in inplace.iter().enumerate() {
            if let Some(o) = src {
                donated_until[o] = Some(inputs[i].def - 1);
            }
        }
        let mut intervals = Vec::new();
        for (i, inp) in inputs.iter().enumerate() {
            if let Some(slot) = out[i] {
                if slot.len > 0 {
                    let end = donated_until[i].unwrap_or_else(|| end_of(i));
                    intervals.push((slot, inp.def, end));
                }
            }
            if let Some(slots) = scratch[i] {
                for s in slots.iter().filter(|s| s.len > 0) {
                    intervals.push((*s, inp.def, inp.def));
                }
            }
        }

        let plan = MemPlan {
            out,
            scratch,
            inplace,
            arena_len,
            planned_reuse,
            inplace_reuse,
            intervals,
        };
        #[cfg(debug_assertions)]
        plan.check_no_overlap();
        plan
    }

    /// Assert that no two live intervals share arena bytes (O(n²); run at
    /// compile time under `debug_assertions` and by the differential test
    /// suite). Panics with the offending pair on violation.
    pub fn check_no_overlap(&self) {
        for (x, &(sa, da, ea)) in self.intervals.iter().enumerate() {
            assert!(
                sa.off + sa.len <= self.arena_len,
                "slot {:?} exceeds the arena ({} elements)",
                sa,
                self.arena_len
            );
            for &(sb, db, eb) in &self.intervals[x + 1..] {
                let time_overlap = da <= eb && db <= ea;
                let byte_overlap = sa.off < sb.off + sb.len && sb.off < sa.off + sa.len;
                assert!(
                    !(time_overlap && byte_overlap),
                    "memory plan overlap: {:?} live [{}, {}] vs {:?} live [{}, {}]",
                    sa,
                    da,
                    ea,
                    sb,
                    db,
                    eb
                );
            }
        }
    }
}

/// Best-fit allocation: the smallest free hole that fits; a hole ending
/// at the arena top is grown in place rather than left as a permanent
/// gap; otherwise the arena extends. The returned flag is true only for
/// a genuine best-fit hit — growing the top hole still extends the
/// arena, so it does not count as packing reuse.
fn alloc(free: &mut Vec<Slot>, arena_len: &mut usize, len: usize) -> (Slot, bool) {
    if len == 0 {
        return (Slot { off: 0, len: 0 }, false);
    }
    let mut best: Option<usize> = None;
    for (k, h) in free.iter().enumerate() {
        let better = match best {
            None => h.len >= len,
            Some(b) => h.len >= len && free[b].len > h.len,
        };
        if better {
            best = Some(k);
        }
    }
    if let Some(k) = best {
        let h = free[k];
        let slot = Slot { off: h.off, len };
        if h.len == len {
            free.remove(k);
        } else {
            free[k] = Slot { off: h.off + len, len: h.len - len };
        }
        return (slot, true);
    }
    // grow a top-adjacent hole instead of stranding it below a fresh
    // slot (not counted as reuse: the arena still extends)
    if let Some(last) = free.last().copied() {
        if last.off + last.len == *arena_len {
            free.pop();
            let slot = Slot { off: last.off, len };
            *arena_len = last.off + len;
            return (slot, false);
        }
    }
    let slot = Slot { off: *arena_len, len };
    *arena_len += len;
    (slot, false)
}

/// Return a slot to the free list, coalescing with adjacent holes.
fn free_slot(free: &mut Vec<Slot>, s: Slot) {
    if s.len == 0 {
        return;
    }
    let mut pos = free.partition_point(|h| h.off < s.off);
    let mut slot = s;
    if pos > 0 && free[pos - 1].off + free[pos - 1].len == slot.off {
        slot = Slot { off: free[pos - 1].off, len: free[pos - 1].len + slot.len };
        free.remove(pos - 1);
        pos -= 1;
    }
    if pos < free.len() && slot.off + slot.len == free[pos].off {
        slot.len += free[pos].len;
        free.remove(pos);
    }
    free.insert(pos, slot);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(
        out_len: Option<usize>,
        def: usize,
        last: Option<usize>,
        inplace_from: Option<usize>,
    ) -> PlanInput {
        PlanInput { out_len, scratch: None, def, last, inplace_from }
    }

    #[test]
    fn disjoint_lifetimes_share_bytes() {
        // a[0,1] feeds b[1,2]; c at level 2 can take a's bytes
        let inputs = vec![
            input(Some(100), 0, Some(1), None),
            input(Some(100), 1, Some(2), None),
            input(Some(100), 2, None, None),
        ];
        let mp = MemPlan::build(&inputs, 3);
        mp.check_no_overlap();
        assert_eq!(mp.arena_len, 200, "c must reuse a's bytes");
        assert_eq!(mp.planned_reuse, 1);
        assert_eq!(mp.out[2].unwrap().off, mp.out[0].unwrap().off);
    }

    #[test]
    fn same_level_buffers_never_share() {
        // two level-1 consumers of a level-0 value run concurrently
        let inputs = vec![
            input(Some(10), 0, Some(1), None),
            input(Some(10), 1, None, None),
            input(Some(10), 1, None, None),
        ];
        let mp = MemPlan::build(&inputs, 2);
        mp.check_no_overlap();
        assert_eq!(mp.arena_len, 30);
        assert_ne!(mp.out[1].unwrap().off, mp.out[2].unwrap().off);
    }

    #[test]
    fn inplace_transfer_hands_over_the_slot() {
        let inputs = vec![
            input(Some(64), 0, Some(1), None),
            input(Some(64), 1, None, Some(0)),
        ];
        let mp = MemPlan::build(&inputs, 2);
        mp.check_no_overlap();
        assert_eq!(mp.arena_len, 64, "in-place chain must cost one slot");
        assert_eq!(mp.inplace[1], Some(0));
        assert_eq!(mp.inplace_reuse, 1);
        assert_eq!(mp.out[1], mp.out[0]);
    }

    #[test]
    fn inplace_rejected_on_length_mismatch() {
        let inputs = vec![
            input(Some(64), 0, Some(1), None),
            input(Some(32), 1, None, Some(0)),
        ];
        let mp = MemPlan::build(&inputs, 2);
        mp.check_no_overlap();
        assert_eq!(mp.inplace[1], None);
        assert_ne!(mp.out[1].unwrap().off, mp.out[0].unwrap().off);
    }

    #[test]
    fn scratch_is_disjoint_within_a_level_and_reused_across() {
        let scr = ScratchSizes { a: 16, b: 16, c: 32 };
        let mk = |def: usize, last: Option<usize>| PlanInput {
            out_len: Some(8),
            scratch: Some(scr),
            def,
            last,
            inplace_from: None,
        };
        // two contractions in level 0, one in level 1
        let inputs = vec![mk(0, Some(1)), mk(0, Some(1)), mk(1, None)];
        let mp = MemPlan::build(&inputs, 2);
        mp.check_no_overlap();
        // every level-0 region (2 outputs + 6 scratch slots) is pairwise
        // disjoint — the two contractions run concurrently
        let mut regions: Vec<Slot> = vec![mp.out[0].unwrap(), mp.out[1].unwrap()];
        regions.extend(mp.scratch[0].unwrap());
        regions.extend(mp.scratch[1].unwrap());
        let regions: Vec<Slot> = regions.into_iter().filter(|s| s.len > 0).collect();
        for (x, a) in regions.iter().enumerate() {
            for b in &regions[x + 1..] {
                assert!(
                    a.off + a.len <= b.off || b.off + b.len <= a.off,
                    "level-0 regions overlap: {:?} vs {:?}",
                    a,
                    b
                );
            }
        }
        // the level-1 contraction reuses freed level-0 scratch bytes
        assert!(mp.planned_reuse > 0, "level-1 scratch must reuse freed bytes");
        // arena is bounded by one level's worst case plus live outputs
        assert!(mp.arena_len < 2 * (8 + 64) + (8 + 64), "packing too loose: {}", mp.arena_len);
    }

    #[test]
    fn free_list_coalesces() {
        let mut free = Vec::new();
        free_slot(&mut free, Slot { off: 0, len: 10 });
        free_slot(&mut free, Slot { off: 20, len: 10 });
        free_slot(&mut free, Slot { off: 10, len: 10 });
        assert_eq!(free, vec![Slot { off: 0, len: 30 }]);
        let mut arena = 30usize;
        let (s, reused) = alloc(&mut free, &mut arena, 30);
        assert!(reused);
        assert_eq!(s, Slot { off: 0, len: 30 });
        assert!(free.is_empty());
    }

    #[test]
    fn top_adjacent_hole_grows_instead_of_stranding() {
        let mut free = Vec::new();
        let mut arena = 100usize;
        free_slot(&mut free, Slot { off: 60, len: 40 });
        let (s, reused) = alloc(&mut free, &mut arena, 80);
        assert!(!reused, "growing the top hole extends the arena — not a packing win");
        assert_eq!(s.off, 60);
        assert_eq!(arena, 140, "the top hole must grow, not strand");
    }
}
