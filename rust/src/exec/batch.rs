//! Batched-variant graph rewrite for the serving layer.
//!
//! In Einstein notation a batch axis is just one more free index on
//! every operand: to evaluate one expression DAG for `B` independent
//! requests at once, prepend a size-`B` axis to every variable and
//! thread a fresh label through every `Mul` spec on a batched path —
//! the label is kept by the output and never summed, so slot `b` of the
//! batched result is computed from exactly the same operand values, by
//! exactly the same sequence of floating-point operations, as request
//! `b` evaluated alone. That makes the rewrite *bit-identical* per
//! slice, which is what lets the coordinator pin its batched serving
//! path against N sequential runs (`tests/serve_batch.rs`).
//!
//! Nodes that do not depend on any variable (constants, deltas, and
//! anything computed from them alone) stay unbatched and are computed
//! once for the whole batch. They re-acquire the batch axis only where
//! a batched path needs them:
//!
//! * an `Add` with one batched operand broadcast-lifts the other,
//! * every root is lifted so all outputs carry the leading axis.
//!
//! The lift of a constant materialises a bigger constant (same value in
//! every slot — trivially bit-identical); the lift of a computed node
//! is an outer product with a ones vector, and `1.0 * v` is bitwise `v`.

use crate::einsum::{EinSpec, Label};
use crate::ir::{Graph, NodeId, Op};
use std::collections::HashMap;

/// Rewrite the sub-DAG of `g` reachable from `roots` into a batched
/// variant: every variable gains a leading axis of size `bsz` and every
/// root returns with that axis prepended to its shape. Returns the new
/// graph and the mapped roots (in the same order as `roots`).
///
/// The rewrite is structure-preserving — node for node, with the same
/// operand order and the same einsum contraction structure — so a plan
/// compiled from the result at [`crate::opt::OptLevel::None`] executes
/// each batch slice bit-identically to the unbatched plan.
pub fn batch_graph(g: &Graph, roots: &[NodeId], bsz: usize) -> (Graph, Vec<NodeId>) {
    assert!(bsz >= 1, "batch size must be at least 1");
    let mut out = Graph::new();
    // old id → (new id, does it carry the batch axis?)
    let mut map: HashMap<NodeId, (NodeId, bool)> = HashMap::new();
    for id in g.topo(roots) {
        let mapped = match g.op(id) {
            Op::Var(name) => {
                let mut shape = vec![bsz];
                shape.extend_from_slice(g.shape(id));
                (out.var(name, &shape), true)
            }
            Op::Const(bits) => (out.constant(f64::from_bits(*bits), g.shape(id)), false),
            Op::Delta { dims } => (out.delta(dims), false),
            Op::Add(a, b) => {
                let (mut na, ba) = map[a];
                let (mut nb, bb) = map[b];
                let batched = ba || bb;
                // Add demands identical shapes: broadcast-lift the
                // unbatched side of a mixed pair
                if batched && !ba {
                    na = lift(&mut out, na, bsz);
                }
                if batched && !bb {
                    nb = lift(&mut out, nb, bsz);
                }
                (out.add(na, nb), batched)
            }
            Op::Mul(a, b, spec) => {
                let (na, ba) = map[a];
                let (nb, bb) = map[b];
                if !ba && !bb {
                    (out.mul(na, nb, spec.clone()), false)
                } else {
                    // thread a fresh batch label through the spec: kept
                    // on every batched operand and on the output, never
                    // summed — each slice contracts exactly as before
                    let l: Label = spec.max_label() + 1;
                    let mut s1 = spec.s1.clone();
                    let mut s2 = spec.s2.clone();
                    let mut s3 = spec.s3.clone();
                    if ba {
                        s1.insert(0, l);
                    }
                    if bb {
                        s2.insert(0, l);
                    }
                    s3.insert(0, l);
                    (out.mul(na, nb, EinSpec::new(s1, s2, s3)), true)
                }
            }
            Op::Elem(f, a) => {
                let (na, ba) = map[a];
                (out.elem(*f, na), ba)
            }
            Op::GenUnary(f, a) => {
                // general unary functions act on the trailing axis, so a
                // leading batch axis just multiplies the row count
                let (na, ba) = map[a];
                (out.gen_unary(*f, na), ba)
            }
        };
        map.insert(id, mapped);
    }
    let broots = roots
        .iter()
        .map(|r| {
            let (nid, batched) = map[r];
            if batched {
                nid
            } else {
                lift(&mut out, nid, bsz)
            }
        })
        .collect();
    (out, broots)
}

/// Broadcast an unbatched node along a new leading axis of size `bsz`.
/// A constant stays a constant (the bigger fill holds the same value);
/// anything else becomes `ones[B] ⊗ v`, bit-identical per element since
/// `1.0 * v == v` in IEEE arithmetic.
fn lift(out: &mut Graph, n: NodeId, bsz: usize) -> NodeId {
    if let Some(v) = out.const_value(n) {
        let mut shape = vec![bsz];
        shape.extend_from_slice(out.shape(n));
        return out.constant(v, &shape);
    }
    let ones = out.constant(1.0, &[bsz]);
    let rank = out.order(n) as Label;
    let s2: Vec<Label> = (1..=rank).collect();
    let mut s3: Vec<Label> = vec![0];
    s3.extend_from_slice(&s2);
    out.mul(ones, n, EinSpec::new(vec![0], s2, s3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_many_with, Env};
    use crate::ir::Elem;
    use crate::opt::OptLevel;
    use crate::tensor::Tensor;

    #[test]
    fn batched_shapes_gain_leading_axis() {
        let mut g = Graph::new();
        let x = g.var("X", &[4, 3]);
        let w = g.var("w", &[3]);
        let xw = g.mul(x, w, EinSpec::parse("ij,j->i"));
        let e = g.elem(Elem::Exp, xw);
        let (bg, broots) = batch_graph(&g, &[e], 5);
        assert_eq!(bg.shape(broots[0]), &[5, 4]);
        assert_eq!(bg.shape(bg.var_id("X").unwrap()), &[5, 4, 3]);
    }

    #[test]
    fn unbatched_const_root_is_lifted() {
        let mut g = Graph::new();
        let _x = g.var("x", &[2]);
        let c = g.constant(3.0, &[2]);
        let (bg, broots) = batch_graph(&g, &[c], 4);
        assert_eq!(bg.shape(broots[0]), &[4, 2]);
        let mut env = Env::new();
        env.insert("x", Tensor::zeros(&[2]));
        let out = eval_many_with(&bg, &broots, &env, OptLevel::None);
        assert!(out[0].data().iter().all(|&v| v == 3.0));
    }

    #[test]
    fn batched_slices_match_per_request_eval_bitwise() {
        // mixed Add (batched + const), contraction, elementwise chain
        let mut g = Graph::new();
        let x = g.var("X", &[3, 2]);
        let w = g.var("w", &[2]);
        let xw = g.mul(x, w, EinSpec::parse("ij,j->i"));
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[3]);
        let s = g.add(e, one);
        let l = g.elem(Elem::Log, s);
        let bsz = 3;
        let (bg, broots) = batch_graph(&g, &[l, xw], bsz);

        let mut xs = Vec::new();
        let mut ws = Vec::new();
        for b in 0..bsz {
            xs.push(Tensor::randn(&[3, 2], 7 + b as u64));
            ws.push(Tensor::randn(&[2], 70 + b as u64));
        }
        let stack = |ts: &[Tensor], shape: &[usize]| {
            let mut data = Vec::new();
            for t in ts {
                data.extend_from_slice(t.data());
            }
            let mut bshape = vec![ts.len()];
            bshape.extend_from_slice(shape);
            Tensor::new(&bshape, data)
        };
        let mut benv = Env::new();
        benv.insert("X", stack(&xs, &[3, 2]));
        benv.insert("w", stack(&ws, &[2]));
        let batched = eval_many_with(&bg, &broots, &benv, OptLevel::None);
        for b in 0..bsz {
            let mut env = Env::new();
            env.insert("X", xs[b].clone());
            env.insert("w", ws[b].clone());
            let seq = eval_many_with(&g, &[l, xw], &env, OptLevel::None);
            for (r, s) in seq.iter().enumerate() {
                let len = s.len();
                assert_eq!(
                    &batched[r].data()[b * len..(b + 1) * len],
                    s.data(),
                    "slice {} of root {} diverged",
                    b,
                    r
                );
            }
        }
    }
}
