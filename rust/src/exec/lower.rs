//! Backend-neutral lowering: expression DAG → dense [`Instr`] stream.
//!
//! Everything that happens **before** "how instructions run" lives here:
//! descriptor lowering, the cross-node fusion pass (element-wise
//! chains/trees collapse into [`FusedKernel`] postfix programs or ride
//! contractions as in-place epilogues), dependency levelling with the
//! flop estimates the schedulers gate on, buffer liveness, and the
//! static memory plan. The result — a [`Lowered`] — is the artifact the
//! [`backend`](super::backend) layer compiles into an executable: the
//! same `Lowered` drives the work-stealing CPU executor and the
//! direct-threaded closure backend, which is what makes the backends
//! bit-identical by construction (same stream, same kernels, same
//! accumulation order).

use crate::einsum::{EinSpec, EinsumPlan, Label};
use crate::ir::{Elem, GenFn, Graph, NodeId, Op};
use crate::obs::TraceMode;
use crate::tensor::Tensor;
use crate::util::{num_threads, PAR_BATCH_TOTAL_MIN_FLOP, PAR_LEVEL_MIN_FLOP, STEAL_CHUNKS_PER_THREAD};
use std::collections::HashMap;
use std::sync::Arc;

use super::memplan::{MemPlan, PlanInput};
use super::{EpilogueMode, ExecMemory};

/// Maximum value-stack depth of a [`FusedKernel`] postfix program; the
/// group builder stops inlining before a kernel could exceed it.
pub(crate) const FUSED_MAX_STACK: usize = 16;

/// Maximum number of operand slots of a [`FusedKernel`]. The group
/// builder enforces it (pending-leaf accounting in
/// [`GroupBuilder::operand`]), which lets the executors resolve operands
/// into a fixed-size stack array per instruction — no heap allocation on
/// the steady-state hot path.
pub(crate) const FUSED_MAX_ARGS: usize = 16;

/// One step of a fused single-pass pipeline (postfix form).
#[derive(Clone, Copy)]
pub(crate) enum FusedOp {
    /// Push element `i` (or the broadcast scalar) of operand slot `k`.
    Load(u32),
    /// Apply an element-wise function to the top of the stack.
    Un(Elem),
    /// Pop two values, push their sum.
    Add,
    /// Pop two values, push their product.
    Mul,
}

/// A collapsed chain/tree of `Elem` / `Add` / Hadamard- and
/// scalar-`Mul` nodes evaluated in one pass over the data: for every
/// element index the postfix program runs over a fixed-size value
/// stack, reading operand slots and producing one output value — zero
/// intermediate buffers regardless of the chain depth. `Clone` so the
/// direct-threaded backend can bake a kernel into its closure chain.
#[derive(Clone)]
pub(crate) struct FusedKernel {
    pub(crate) ops: Vec<FusedOp>,
}

/// An operand slot resolved for one execution: same-shape operands are
/// read per element, rank-0 operands broadcast one value. `Copy` so a
/// whole slot array can live on the stack.
#[derive(Clone, Copy)]
pub(crate) enum FusedSrc<'s> {
    Slice(&'s [f64]),
    Scalar(f64),
}

impl FusedSrc<'_> {
    /// Per-element read — the reference the chunked interpreter's tests
    /// pin against (the hot paths read whole lane blocks instead).
    #[cfg(test)]
    #[inline]
    pub(crate) fn at(&self, i: usize) -> f64 {
        match self {
            FusedSrc::Slice(s) => s[i],
            FusedSrc::Scalar(v) => *v,
        }
    }
}

/// Lane-block width of the chunked fused interpreter: each postfix step
/// runs over this many elements at once (a full AVX-512 f64 vector, two
/// AVX2 vectors, four NEON vectors).
pub(crate) const FUSED_LANES: usize = 8;

/// Resolve `Load` lanes `[off, off + dst.len())` from one operand slot.
#[inline(always)]
fn fill_src(src: &FusedSrc, off: usize, dst: &mut [f64]) {
    match src {
        FusedSrc::Slice(s) => dst.copy_from_slice(&s[off..off + dst.len()]),
        FusedSrc::Scalar(v) => dst.fill(*v),
    }
}

impl FusedKernel {
    /// `out[i] = program(srcs, i)`; `Load(k)` reads `srcs[k]`.
    pub(crate) fn run(&self, srcs: &[FusedSrc], out: &mut [f64]) {
        self.eval_chunks(out, |k, off, dst, _carrier| fill_src(&srcs[k as usize], off, dst));
    }

    /// In-place epilogue on a producer's output: `Load(0)` reads the
    /// buffer value being replaced, `Load(k ≥ 1)` reads `rest[k-1]`.
    pub(crate) fn run_inplace(&self, buf: &mut [f64], rest: &[FusedSrc]) {
        self.run_inplace_at(buf, 0, rest);
    }

    /// [`FusedKernel::run_inplace`] on a tile: `buf[j]` is global flat
    /// output element `base + j`, so operand slots resolve correctly
    /// from inside GEMM tiles, row bands and batch slices.
    pub(crate) fn run_inplace_at(&self, buf: &mut [f64], base: usize, rest: &[FusedSrc]) {
        self.eval_chunks(buf, |k, off, dst, carrier| {
            if k == 0 {
                dst.copy_from_slice(&carrier[..dst.len()]);
            } else {
                fill_src(&rest[k as usize - 1], base + off, dst);
            }
        });
    }

    /// The planned executor's in-place form: operand slot `arg` aliases
    /// the output buffer, so `Load(arg)` reads the value being replaced
    /// while every other slot reads `srcs` at its *original* position
    /// (`srcs[arg]` is a dummy, never touched). Bit-identical to
    /// [`FusedKernel::run`] with the aliased operand materialised.
    pub(crate) fn run_inplace_arg(&self, buf: &mut [f64], arg: u32, srcs: &[FusedSrc]) {
        self.eval_chunks(buf, |k, off, dst, carrier| {
            if k == arg {
                dst.copy_from_slice(&carrier[..dst.len()]);
            } else {
                fill_src(&srcs[k as usize], off, dst);
            }
        });
    }

    /// Dispatch wrapper around [`FusedKernel::eval_chunks_body`]: on
    /// x86-64 with AVX2 active, run the identical body compiled with
    /// AVX2 enabled (the lane loops are pure per-lane maps, so the wider
    /// codegen is bit-identical to the portable build — dispatch only
    /// changes speed).
    #[inline]
    fn eval_chunks<F: Fn(u32, usize, &mut [f64], &[f64])>(&self, out: &mut [f64], fill: F) {
        #[cfg(target_arch = "x86_64")]
        if matches!(
            crate::util::simd::active_isa(),
            crate::util::simd::Isa::Avx2 | crate::util::simd::Isa::Avx512
        ) {
            // SAFETY: the dispatch tier guarantees AVX2 is present.
            unsafe { self.eval_chunks_avx2(out, fill) };
            return;
        }
        self.eval_chunks_body(out, fill);
    }

    /// # Safety
    /// Requires AVX2; only called when the active ISA tier implies it.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_chunks_avx2<F: Fn(u32, usize, &mut [f64], &[f64])>(
        &self,
        out: &mut [f64],
        fill: F,
    ) {
        self.eval_chunks_body(out, fill)
    }

    /// The one postfix interpreter every execution form shares, blocked
    /// over [`FUSED_LANES`]-wide chunks: `fill(k, off, dst, carrier)`
    /// resolves `Load(k)` for lanes `[off, off + dst.len())` (slice
    /// block, broadcast scalar, or the in-place carrier lanes, depending
    /// on the caller's slot convention). `Add`/`Mul` run full
    /// constant-trip lane loops — on a ragged tail chunk the stale lanes
    /// past `dst.len()` compute garbage that is never stored back, which
    /// is harmless for IEEE arithmetic. `Un` applies the *same* scalar
    /// function per lane as the per-element reference, so lane blocking
    /// never changes results bitwise.
    #[inline(always)]
    fn eval_chunks_body<F: Fn(u32, usize, &mut [f64], &[f64])>(&self, out: &mut [f64], fill: F) {
        let mut stack = [[0.0f64; FUSED_LANES]; FUSED_MAX_STACK];
        let mut carrier = [0.0f64; FUSED_LANES];
        let n = out.len();
        let mut off = 0usize;
        while off < n {
            let l = FUSED_LANES.min(n - off);
            carrier[..l].copy_from_slice(&out[off..off + l]);
            let mut sp = 0usize;
            for op in &self.ops {
                match op {
                    FusedOp::Load(k) => {
                        fill(*k, off, &mut stack[sp][..l], &carrier);
                        sp += 1;
                    }
                    FusedOp::Un(f) => {
                        for v in stack[sp - 1][..l].iter_mut() {
                            *v = f.apply(*v);
                        }
                    }
                    FusedOp::Add => {
                        sp -= 1;
                        let (lo, hi) = stack.split_at_mut(sp);
                        for (a, &b) in lo[sp - 1].iter_mut().zip(hi[0].iter()) {
                            *a += b;
                        }
                    }
                    FusedOp::Mul => {
                        sp -= 1;
                        let (lo, hi) = stack.split_at_mut(sp);
                        for (a, &b) in lo[sp - 1].iter_mut().zip(hi[0].iter()) {
                            *a *= b;
                        }
                    }
                }
            }
            debug_assert_eq!(sp, 1, "fused program must leave exactly one value");
            out[off..off + l].copy_from_slice(&stack[0][..l]);
            off += l;
        }
    }

    /// Per-element reference interpreter — the oracle the chunked tests
    /// pin [`FusedKernel::eval_chunks_body`] against bitwise.
    #[cfg(test)]
    fn eval_one<L: Fn(usize) -> f64>(&self, stack: &mut [f64; FUSED_MAX_STACK], load: L) -> f64 {
        let mut sp = 0usize;
        for op in &self.ops {
            match op {
                FusedOp::Load(k) => {
                    stack[sp] = load(*k as usize);
                    sp += 1;
                }
                FusedOp::Un(f) => stack[sp - 1] = f.apply(stack[sp - 1]),
                FusedOp::Add => {
                    sp -= 1;
                    stack[sp - 1] += stack[sp];
                }
                FusedOp::Mul => {
                    sp -= 1;
                    stack[sp - 1] *= stack[sp];
                }
            }
        }
        debug_assert_eq!(sp, 1, "fused program must leave exactly one value");
        stack[0]
    }
}

/// A fused chain applied in place on a producer's freshly written
/// output (slot 0 of the kernel is the produced value itself).
pub(crate) struct Epilogue {
    pub(crate) kernel: FusedKernel,
    /// operand positions for kernel slots `1..` (slot 0 is the carrier)
    pub(crate) args: Vec<usize>,
}

/// One lowered node. Operands are dense positions into the instruction
/// stream (not `NodeId`s), so execution never touches the `Graph`. The
/// stream is backend-neutral: every backend consumes exactly this.
pub(crate) enum Instr {
    /// Bind the named input from the `Env` (shape-checked, zero-copy).
    Var { name: String, shape: Vec<usize> },
    /// A `Const`/`Delta` tensor materialised once at compile time.
    Static(usize),
    Add(usize, usize),
    /// Pre-compiled contraction (strides/pre-sums/permutation resolved),
    /// optionally with a fused element-wise epilogue applied in place.
    /// `Arc` so a backend can bake the plan into its own artifact.
    Mul(usize, usize, Arc<EinsumPlan>, Option<Epilogue>),
    Elem(Elem, usize),
    GenUnary(GenFn, usize, Option<Epilogue>),
    /// A collapsed element-wise chain/tree evaluated in one pass.
    Fused { kernel: FusedKernel, args: Vec<usize> },
}

/// Intermediate lowering of one node, before the fusion pass decides
/// which nodes survive as instructions.
enum DescKind {
    Var(String),
    Static(usize),
    Add(usize, usize),
    Mul(usize, usize, EinsumPlan),
    Elem(Elem, usize),
    GenUnary(GenFn, usize),
}

fn desc_operands(d: &DescKind) -> Vec<usize> {
    match d {
        DescKind::Add(a, b) | DescKind::Mul(a, b, _) => vec![*a, *b],
        DescKind::Elem(_, a) | DescKind::GenUnary(_, a) => vec![*a],
        DescKind::Var(_) | DescKind::Static(_) => Vec::new(),
    }
}

/// Fusion-pass classification of a node: how it reads its operands when
/// evaluated element by element.
#[derive(Clone, Copy)]
enum FuseNode {
    Un(Elem, usize),
    Add2(usize, usize),
    /// element-wise product of two same-shape operands
    Had(usize, usize),
    /// `(tensor, scalar)`: tensor scaled by a broadcast rank-0 operand
    Scale(usize, usize),
}

fn all_distinct(ls: &[Label]) -> bool {
    ls.iter().enumerate().all(|(i, l)| !ls[i + 1..].contains(l))
}

/// Classify a `Mul` node as element-wise fusable: a Hadamard product of
/// same-shape operands, or a scalar broadcast scale. Anything with
/// summed labels, diagonals or permuted outputs stays a contraction.
fn classify_mul(
    spec: &EinSpec,
    a_shape: &[usize],
    b_shape: &[usize],
    pa: usize,
    pb: usize,
) -> Option<FuseNode> {
    if spec.is_elementwise() && all_distinct(&spec.s1) {
        return Some(FuseNode::Had(pa, pb));
    }
    if b_shape.is_empty() && spec.s2.is_empty() && spec.s3 == spec.s1 && all_distinct(&spec.s1) {
        return Some(FuseNode::Scale(pa, pb));
    }
    if a_shape.is_empty() && spec.s1.is_empty() && spec.s3 == spec.s2 && all_distinct(&spec.s2) {
        return Some(FuseNode::Scale(pb, pa));
    }
    None
}

/// A fused group under construction: the postfix program, its leaf
/// operands (pre-fusion stream positions, slot order) and how many
/// loads each leaf received — the epilogue-carrier check needs the
/// latter to prove all of a producer's uses live inside the group.
#[derive(Default)]
struct Group {
    ops: Vec<FusedOp>,
    leaves: Vec<usize>,
    leaf_loads: Vec<usize>,
    n_nodes: usize,
    /// melted producer applied in place (pre-fusion position)
    carrier: Option<usize>,
}

impl Group {
    fn push_leaf(&mut self, o: usize) {
        let slot = match self.leaves.iter().position(|&q| q == o) {
            Some(s) => s,
            None => {
                self.leaves.push(o);
                self.leaf_loads.push(0);
                self.leaves.len() - 1
            }
        };
        self.leaf_loads[slot] += 1;
        self.ops.push(FusedOp::Load(slot as u32));
    }

    /// Re-number slots for epilogue form: the carrier slot becomes
    /// `Load(0)`, remaining leaves shift to slots `1..` in order.
    fn rewrite_for_carrier(&mut self, slot: usize) {
        for op in self.ops.iter_mut() {
            if let FusedOp::Load(k) = op {
                let k0 = *k as usize;
                *k = if k0 == slot {
                    0
                } else if k0 < slot {
                    (k0 + 1) as u32
                } else {
                    k0 as u32
                };
            }
        }
        self.carrier = Some(self.leaves.remove(slot));
        self.leaf_loads.remove(slot);
    }
}

/// Shared context of one group build (the fusion pass working over the
/// pre-fusion descriptor stream).
struct GroupBuilder<'c> {
    fusable: &'c [Option<FuseNode>],
    uses: &'c [usize],
    is_root: &'c [bool],
    shapes: &'c [Vec<usize>],
    group_shape: &'c [usize],
}

impl GroupBuilder<'_> {
    /// Emit the postfix program of member `p`; the value stack already
    /// holds `held` entries when the member starts executing, and
    /// enclosing members will still load `pending` more leaves after
    /// this member returns (the operand-slot budget mirrors how `held`
    /// budgets the value stack).
    fn member(&self, p: usize, held: usize, pending: usize, melted: &mut [bool], grp: &mut Group) {
        grp.n_nodes += 1;
        match self.fusable[p].expect("group member must be fusable") {
            FuseNode::Un(f, a) => {
                self.operand(a, held, pending, melted, grp);
                grp.ops.push(FusedOp::Un(f));
            }
            FuseNode::Add2(a, b) => {
                self.operand(a, held, pending + 1, melted, grp);
                self.operand(b, held + 1, pending, melted, grp);
                grp.ops.push(FusedOp::Add);
            }
            FuseNode::Had(a, b) => {
                self.operand(a, held, pending + 1, melted, grp);
                self.operand(b, held + 1, pending, melted, grp);
                grp.ops.push(FusedOp::Mul);
            }
            FuseNode::Scale(t, s) => {
                self.operand(t, held, pending + 1, melted, grp);
                // the rank-0 operand broadcasts per run, not per
                // element: always a leaf
                grp.push_leaf(s);
                grp.ops.push(FusedOp::Mul);
            }
        }
    }

    /// Inline operand `o` when it is fusable, consumed only here, not a
    /// plan root, shape-preserving, and both the value stack and the
    /// operand-slot array have headroom (an inlined member adds at most
    /// two direct leaves, and `pending` siblings still follow);
    /// otherwise record it as a leaf.
    fn operand(
        &self,
        o: usize,
        held: usize,
        pending: usize,
        melted: &mut [bool],
        grp: &mut Group,
    ) {
        let inline = held + 2 <= FUSED_MAX_STACK
            && grp.leaves.len() + pending + 2 <= FUSED_MAX_ARGS
            && !self.is_root[o]
            && self.uses[o] == 1
            && self.fusable[o].is_some()
            && self.shapes[o].as_slice() == self.group_shape;
        if inline {
            melted[o] = true;
            self.member(o, held, pending, melted, grp);
        } else {
            grp.push_leaf(o);
        }
    }
}

/// Operand positions of one instruction (epilogue arguments included).
pub(crate) fn operands(instr: &Instr) -> Vec<usize> {
    let mut v = match instr {
        Instr::Add(a, b) | Instr::Mul(a, b, _, _) => vec![*a, *b],
        Instr::Elem(_, a) | Instr::GenUnary(_, a, _) => vec![*a],
        Instr::Fused { args, .. } => args.clone(),
        Instr::Var { .. } | Instr::Static(_) => Vec::new(),
    };
    match instr {
        Instr::Mul(_, _, _, Some(e)) | Instr::GenUnary(_, _, Some(e)) => v.extend(&e.args),
        _ => {}
    }
    v
}

/// The backend-neutral compilation artifact: the fused instruction
/// stream plus every compile-time decision a backend needs to execute
/// it — dependency levels with their flop estimates, buffer lifetimes,
/// the static memory plan, and the ablation toggles the plan was
/// compiled under. A [`Backend`](super::backend::Backend) turns a
/// `Lowered` into something runnable; the lowering itself never says
/// *how* instructions run.
pub struct Lowered {
    pub(crate) instrs: Vec<Instr>,
    pub(crate) shapes: Vec<Vec<usize>>,
    pub(crate) statics: Vec<Tensor>,
    /// instruction positions grouped by dependency depth (level 0 first);
    /// nodes within one level are independent and may run in parallel
    pub(crate) levels: Vec<Vec<usize>>,
    /// estimated flops per level — gates the worker-pool fork
    pub(crate) level_flops: Vec<usize>,
    /// largest *internally parallel* (GEMM) flop estimate per level —
    /// levels whose contractions parallelise internally (row bands /
    /// batch splits) run serially at the level layer to avoid
    /// nested-fork oversubscription
    pub(crate) level_max_flops: Vec<usize>,
    /// positions whose value dies after each level (returned to the pool;
    /// pooled mode only — the planner bakes lifetimes into offsets)
    pub(crate) free_at_level: Vec<Vec<usize>>,
    pub(crate) root_pos: Vec<usize>,
    /// where contraction epilogues run (in-tile vs two-pass ablation)
    pub(crate) epilogue_mode: EpilogueMode,
    /// where intermediates live (planned arena vs pooled ablation)
    pub(crate) memory: ExecMemory,
    /// the static memory plan (`Some` whenever the plan executes
    /// in-arena: planned mode, or any backend that requires an arena)
    pub(crate) memplan: Option<MemPlan>,
    /// per instruction: operand index *within the instruction* whose
    /// dying slot the output takes over in place (in-arena only; for
    /// `Fused` this is the kernel's operand slot)
    pub(crate) inplace_arg: Vec<Option<usize>>,
    /// estimated flops per instruction (the same cost-model figures the
    /// level aggregates fold over) — the profiler's GFLOP/s denominator
    pub(crate) instr_flops: Vec<usize>,
    /// how much the backends record while executing this plan
    pub(crate) trace: TraceMode,
}

impl Lowered {
    /// The level fork gate shared by **all** level-parallel execution:
    /// fork only for many-small-node levels — a node whose contraction
    /// exceeds `PAR_BATCH_TOTAL_MIN_FLOP` forks its own row bands /
    /// batch splits inside the GEMM, and nesting both layers would
    /// oversubscribe the cores. Returns `(participants, steal-chunk
    /// size)` when the level should fork, `None` to run it serially.
    /// Keeping the gate and the chunk formula in one place is part of
    /// the bit-identical contract between memory modes: they must
    /// schedule identically.
    pub(crate) fn level_fork(&self, lv: usize, level_len: usize) -> Option<(usize, usize)> {
        let nt = num_threads().min(level_len);
        if nt > 1
            && self.level_flops[lv] >= PAR_LEVEL_MIN_FLOP
            && self.level_max_flops[lv] <= PAR_BATCH_TOTAL_MIN_FLOP
        {
            Some((nt, (level_len / (nt * STEAL_CHUNKS_PER_THREAD)).max(1)))
        } else {
            None
        }
    }
}

/// Lower the sub-DAG of `g` reachable from `roots` to a backend-neutral
/// [`Lowered`]: descriptors → fusion → dense stream → levels/liveness →
/// memory plan. `force_arena` builds the static memory plan even under
/// [`ExecMemory::Pooled`] — for backends (like the direct-threaded one)
/// that only execute in-arena, and for traced plans (span recording is
/// wired through the arena executor, so any `trace != Off` forces one
/// too).
pub(crate) fn lower(
    g: &Graph,
    roots: &[NodeId],
    fuse: bool,
    epilogue_mode: EpilogueMode,
    memory: ExecMemory,
    force_arena: bool,
    trace: TraceMode,
) -> Lowered {
    let force_arena = force_arena || trace != TraceMode::Off;
    let order = g.topo(roots);
    let n = order.len();
    let mut pos_of: HashMap<NodeId, usize> = HashMap::with_capacity(n);
    for (i, &id) in order.iter().enumerate() {
        pos_of.insert(id, i);
    }

    // -- lower every reachable node to a descriptor --
    let mut descs: Vec<Option<DescKind>> = Vec::with_capacity(n);
    let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(n);
    let mut statics: Vec<Tensor> = Vec::new();
    let mut base_flops: Vec<usize> = vec![0; n];
    let mut fusable: Vec<Option<FuseNode>> = Vec::with_capacity(n);
    for (i, &id) in order.iter().enumerate() {
        let shape = g.shape(id).to_vec();
        let out_len: usize = shape.iter().product();
        let (kind, fnode) = match g.op(id) {
            Op::Var(name) => (DescKind::Var(name.clone()), None),
            Op::Const(bits) => {
                statics.push(Tensor::fill(&shape, f64::from_bits(*bits)));
                (DescKind::Static(statics.len() - 1), None)
            }
            Op::Delta { dims } => {
                statics.push(Tensor::delta(dims));
                (DescKind::Static(statics.len() - 1), None)
            }
            Op::Add(a, b) => {
                let (pa, pb) = (pos_of[a], pos_of[b]);
                (DescKind::Add(pa, pb), Some(FuseNode::Add2(pa, pb)))
            }
            Op::Mul(a, b, spec) => {
                let plan = EinsumPlan::new(spec, g.shape(*a), g.shape(*b));
                base_flops[i] = plan.iteration_space();
                let (pa, pb) = (pos_of[a], pos_of[b]);
                let f = classify_mul(spec, g.shape(*a), g.shape(*b), pa, pb);
                (DescKind::Mul(pa, pb, plan), f)
            }
            Op::Elem(f, a) => {
                let pa = pos_of[a];
                (DescKind::Elem(*f, pa), Some(FuseNode::Un(*f, pa)))
            }
            Op::GenUnary(f, a) => {
                // the interpreter's contract, enforced at *compile*
                // time — a mid-run panic in gen_unary_into would
                // poison pooled buffers
                assert!(
                    !g.shape(*a).is_empty(),
                    "GenUnary({}) needs a rank ≥ 1 operand (got rank 0)",
                    f.name()
                );
                (DescKind::GenUnary(*f, pos_of[a]), None)
            }
        };
        if base_flops[i] == 0 && !matches!(kind, DescKind::Var(_) | DescKind::Static(_)) {
            base_flops[i] = out_len;
        }
        descs.push(Some(kind));
        shapes.push(shape);
        fusable.push(if fuse { fnode } else { None });
    }

    // -- consumer counts over the pre-fusion stream (roots count) --
    let root_old: Vec<usize> = roots.iter().map(|r| pos_of[r]).collect();
    let mut uses = vec![0usize; n];
    for d in &descs {
        for o in desc_operands(d.as_ref().expect("desc present")) {
            uses[o] += 1;
        }
    }
    let mut is_root = vec![false; n];
    for &r in &root_old {
        uses[r] += 1;
        is_root[r] = true;
    }

    // -- fusion pass: greedy maximal groups, processed root-down --
    let mut melted = vec![false; n];
    let mut groups: Vec<Option<Group>> = Vec::with_capacity(n);
    groups.resize_with(n, || None);
    for p in (0..n).rev() {
        if melted[p] || fusable[p].is_none() {
            continue;
        }
        let builder = GroupBuilder {
            fusable: &fusable,
            uses: &uses,
            is_root: &is_root,
            shapes: &shapes,
            group_shape: &shapes[p],
        };
        let mut grp = Group::default();
        builder.member(p, 0, 0, &mut melted, &mut grp);
        // epilogue carrier: a contraction / general unary consumed
        // only by this group, producing exactly the group shape
        let carrier_slot = grp.leaves.iter().enumerate().find_map(|(slot, &l)| {
            let eligible = !is_root[l]
                && shapes[l].as_slice() == shapes[p].as_slice()
                && grp.leaf_loads[slot] == uses[l]
                && matches!(
                    descs[l].as_ref().expect("desc present"),
                    DescKind::Mul(..) | DescKind::GenUnary(..)
                );
            eligible.then_some(slot)
        });
        if let Some(slot) = carrier_slot {
            let l = grp.leaves[slot];
            melted[l] = true;
            grp.rewrite_for_carrier(slot);
            groups[p] = Some(grp);
        } else if grp.n_nodes >= 2 {
            groups[p] = Some(grp);
        }
        // n_nodes == 1 without a carrier: nothing was melted — the
        // original single instruction is kept as-is
    }

    // -- emit the fused instruction stream (dense re-map) --
    let mut remap = vec![usize::MAX; n];
    let mut instrs: Vec<Instr> = Vec::new();
    let mut out_shapes: Vec<Vec<usize>> = Vec::new();
    let mut flops: Vec<usize> = Vec::new();
    let mut internal_flops: Vec<usize> = Vec::new();
    for p in 0..n {
        if melted[p] {
            continue;
        }
        let out_len: usize = shapes[p].iter().product();
        let (instr, fl, ifl) = if let Some(grp) = groups[p].take() {
            let args: Vec<usize> = grp.leaves.iter().map(|&q| remap[q]).collect();
            let kernel = FusedKernel { ops: grp.ops };
            let chain_fl = grp.n_nodes.saturating_mul(out_len);
            match grp.carrier {
                Some(l) => {
                    let epi = Some(Epilogue { kernel, args });
                    match descs[l].take().expect("carrier desc present") {
                        DescKind::Mul(a, b, plan) => {
                            let gemm_fl = plan.iteration_space();
                            (
                                Instr::Mul(remap[a], remap[b], Arc::new(plan), epi),
                                gemm_fl.saturating_add(chain_fl),
                                gemm_fl,
                            )
                        }
                        DescKind::GenUnary(f, a) => (
                            Instr::GenUnary(f, remap[a], epi),
                            out_len.saturating_add(chain_fl),
                            0,
                        ),
                        _ => unreachable!("carrier must be Mul or GenUnary"),
                    }
                }
                None => (Instr::Fused { kernel, args }, chain_fl, 0),
            }
        } else {
            let instr = match descs[p].take().expect("desc present") {
                DescKind::Var(name) => Instr::Var { name, shape: shapes[p].clone() },
                DescKind::Static(i) => Instr::Static(i),
                DescKind::Add(a, b) => Instr::Add(remap[a], remap[b]),
                DescKind::Mul(a, b, plan) => {
                    Instr::Mul(remap[a], remap[b], Arc::new(plan), None)
                }
                DescKind::Elem(f, a) => Instr::Elem(f, remap[a]),
                DescKind::GenUnary(f, a) => Instr::GenUnary(f, remap[a], None),
            };
            let ifl = match &instr {
                Instr::Mul(_, _, plan, _) => plan.iteration_space(),
                _ => 0,
            };
            (instr, base_flops[p], ifl)
        };
        remap[p] = instrs.len();
        instrs.push(instr);
        out_shapes.push(shapes[p].clone());
        flops.push(fl);
        internal_flops.push(ifl);
    }

    // -- levels / lifetimes over the fused stream --
    let m = instrs.len();
    let mut depth: Vec<usize> = vec![0; m];
    for (i, instr) in instrs.iter().enumerate() {
        let d = operands(instr)
            .iter()
            .map(|&c| depth[c] + 1)
            .max()
            .unwrap_or(0);
        depth[i] = d;
    }
    let n_levels = depth.iter().copied().max().map(|d| d + 1).unwrap_or(0);
    let mut levels: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
    let mut level_flops: Vec<usize> = vec![0; n_levels];
    let mut level_max_flops: Vec<usize> = vec![0; n_levels];
    for (i, &d) in depth.iter().enumerate() {
        levels[d].push(i);
        level_flops[d] = level_flops[d].saturating_add(flops[i]);
        level_max_flops[d] = level_max_flops[d].max(internal_flops[i]);
    }

    // Buffer lifetimes: a value is released to the pool after the
    // last level that consumes it. Roots are never released.
    let mut last_level: Vec<Option<usize>> = vec![None; m];
    for (i, instr) in instrs.iter().enumerate() {
        for &c in operands(instr).iter() {
            let lvl = depth[i];
            last_level[c] = Some(last_level[c].map_or(lvl, |p| p.max(lvl)));
        }
    }
    let root_pos: Vec<usize> = root_old.iter().map(|&r| remap[r]).collect();
    for &r in &root_pos {
        last_level[r] = None;
    }
    let mut free_at_level: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
    for (i, ll) in last_level.iter().enumerate() {
        if let Some(lvl) = ll {
            free_at_level[*lvl].push(i);
        }
    }

    // -- static memory plan: liveness → intervals → arena offsets, with
    //    in-place reuse of dying inputs. Built whenever the plan will
    //    execute in-arena (planned mode, or a backend that forces it) --
    let (plan_mem, inplace_arg) = if memory == ExecMemory::Planned || force_arena {
        // consumers of each value at its last-use level: in-place
        // transfer requires the taker to be the *sole* reader
        // there (anything else in that level runs concurrently)
        let mut last_consumers: Vec<Vec<usize>> = vec![Vec::new(); m];
        for (i, instr) in instrs.iter().enumerate() {
            for &c in operands(instr).iter() {
                if last_level[c] == Some(depth[i]) {
                    last_consumers[c].push(i);
                }
            }
        }
        // alias-safe in-place candidates: (operand stream
        // position, operand index within the instruction)
        let mut cand: Vec<Option<(usize, usize)>> = vec![None; m];
        for (i, instr) in instrs.iter().enumerate() {
            let out_len: usize = out_shapes[i].iter().product();
            let eligible = |o: usize| -> bool {
                out_len > 0
                    && !matches!(instrs[o], Instr::Var { .. } | Instr::Static(_))
                    && last_level[o] == Some(depth[i])
                    && last_consumers[o].len() == 1
                    && out_shapes[o].iter().product::<usize>() == out_len
            };
            cand[i] = match instr {
                // streaming element-wise reads of index j happen
                // strictly before the write of index j, so the
                // output may overwrite the dying operand
                Instr::Elem(_, a) if eligible(*a) => Some((*a, 0)),
                Instr::Add(a, b) => {
                    if eligible(*a) {
                        Some((*a, 0))
                    } else if eligible(*b) && a != b {
                        Some((*b, 1))
                    } else {
                        None
                    }
                }
                Instr::Fused { args, .. } => args
                    .iter()
                    .enumerate()
                    .find(|(_, &q)| eligible(q))
                    .map(|(slot, &q)| (q, slot)),
                // contractions and general unaries read arbitrary
                // indices (gather/GEMM/row reductions): never
                // in-place
                _ => None,
            };
        }
        let inputs: Vec<PlanInput> = instrs
            .iter()
            .enumerate()
            .map(|(i, instr)| PlanInput {
                out_len: match instr {
                    Instr::Var { .. } | Instr::Static(_) => None,
                    _ => Some(out_shapes[i].iter().product()),
                },
                scratch: match instr {
                    Instr::Mul(_, _, plan, _) => Some(plan.scratch_sizes()),
                    _ => None,
                },
                def: depth[i],
                last: last_level[i],
                inplace_from: cand[i].map(|(o, _)| o),
            })
            .collect();
        let mp = MemPlan::build(&inputs, n_levels);
        // keep only the transfers the planner actually committed
        let inplace_arg: Vec<Option<usize>> = (0..m)
            .map(|i| match mp.inplace[i] {
                Some(_) => cand[i].map(|(_, arg)| arg),
                None => None,
            })
            .collect();
        (Some(mp), inplace_arg)
    } else {
        (None, vec![None; m])
    };

    Lowered {
        instrs,
        shapes: out_shapes,
        statics,
        levels,
        level_flops,
        level_max_flops,
        free_at_level,
        root_pos,
        epilogue_mode,
        memory,
        memplan: plan_mem,
        inplace_arg,
        instr_flops: flops,
        trace,
    }
}

#[cfg(test)]
mod fused_tests {
    use super::*;
    use crate::tensor::XorShift;

    /// Random postfix programs (always stack-valid, ending with one
    /// value) over `n_args` operand slots.
    fn random_program(rng: &mut XorShift, n_args: usize) -> FusedKernel {
        let elems = [Elem::Exp, Elem::Tanh, Elem::Relu, Elem::Neg, Elem::Square];
        let mut ops = vec![FusedOp::Load(rng.below(n_args) as u32)];
        let mut depth = 1usize;
        for _ in 0..(2 + rng.below(12)) {
            match rng.below(4) {
                0 if depth < FUSED_MAX_STACK - 1 => {
                    ops.push(FusedOp::Load(rng.below(n_args) as u32));
                    depth += 1;
                }
                1 if depth >= 2 => {
                    ops.push(FusedOp::Add);
                    depth -= 1;
                }
                2 if depth >= 2 => {
                    ops.push(FusedOp::Mul);
                    depth -= 1;
                }
                _ => ops.push(FusedOp::Un(elems[rng.below(elems.len())])),
            }
        }
        while depth > 1 {
            ops.push(if rng.below(2) == 0 { FusedOp::Add } else { FusedOp::Mul });
            depth -= 1;
        }
        FusedKernel { ops }
    }

    fn rand_vec(rng: &mut XorShift, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
    }

    /// The chunked lane interpreter (whichever tier is dispatched) must
    /// reproduce the per-element reference bitwise, across all three
    /// execution forms, including ragged tails and broadcast scalars.
    #[test]
    fn chunked_interpreter_bit_identical_to_reference() {
        let mut rng = XorShift::new(42);
        for case in 0..60u64 {
            let n_args = 1 + (case % 3) as usize;
            let kernel = random_program(&mut rng, n_args);
            // lengths straddling FUSED_LANES boundaries, incl. 0 and 1
            let len = [0usize, 1, 7, 8, 9, 16, 61][(case % 7) as usize];
            let slices: Vec<Vec<f64>> = (0..n_args).map(|_| rand_vec(&mut rng, len)).collect();
            let scalar = rng.next_f64();
            let srcs: Vec<FusedSrc> = slices
                .iter()
                .enumerate()
                .map(|(k, s)| {
                    if k == n_args - 1 && case % 2 == 0 {
                        FusedSrc::Scalar(scalar)
                    } else {
                        FusedSrc::Slice(s)
                    }
                })
                .collect();

            // run(): fresh output
            let mut want = vec![0.0f64; len];
            let mut stack = [0.0f64; FUSED_MAX_STACK];
            for (i, w) in want.iter_mut().enumerate() {
                *w = kernel.eval_one(&mut stack, |k| srcs[k].at(i));
            }
            let mut got = vec![0.0f64; len];
            kernel.run(&srcs, &mut got);
            assert_eq!(got, want, "run() diverged (case {case}, len {len})");

            // run_inplace_at(): slot 0 is the carrier, offset base
            let base = 3usize;
            let rest = &srcs[..n_args.saturating_sub(1)];
            let carrier0 = rand_vec(&mut rng, len);
            // rest slots index from `base`, so back them with longer data
            let long: Vec<Vec<f64>> =
                (0..rest.len()).map(|_| rand_vec(&mut rng, len + base)).collect();
            let rest_srcs: Vec<FusedSrc> =
                long.iter().map(|s| FusedSrc::Slice(s)).collect();
            let mut want_ip = carrier0.clone();
            for (j, w) in want_ip.iter_mut().enumerate() {
                let carrier = *w;
                *w = kernel.eval_one(&mut stack, |k| {
                    if k == 0 {
                        carrier
                    } else if k - 1 < rest_srcs.len() {
                        rest_srcs[k - 1].at(base + j)
                    } else {
                        carrier
                    }
                });
            }
            // only valid when the program touches existing slots
            if kernel.ops.iter().all(|op| match op {
                FusedOp::Load(k) => (*k as usize) <= rest_srcs.len(),
                _ => true,
            }) {
                let mut got_ip = carrier0.clone();
                kernel.run_inplace_at(&mut got_ip, base, &rest_srcs);
                if rest_srcs.len() + 1 >= n_args {
                    assert_eq!(got_ip, want_ip, "run_inplace_at diverged (case {case})");
                }
            }

            // run_inplace_arg(): slot `arg` aliases the output
            let arg = (case % n_args as u64) as u32;
            let carrier1 = rand_vec(&mut rng, len);
            let mut want_arg = carrier1.clone();
            for (i, w) in want_arg.iter_mut().enumerate() {
                let carrier = *w;
                *w = kernel.eval_one(&mut stack, |k| {
                    if k == arg as usize {
                        carrier
                    } else {
                        srcs[k].at(i)
                    }
                });
            }
            let mut got_arg = carrier1.clone();
            kernel.run_inplace_arg(&mut got_arg, arg, &srcs);
            assert_eq!(got_arg, want_arg, "run_inplace_arg diverged (case {case})");
        }
    }
}
