//! The compiled execution engine: [`CompiledPlan`] lowers an expression
//! DAG into a dense instruction stream executed with pooled buffers,
//! pre-compiled write-into einsums and level-parallel scheduling.
//!
//! ## Architecture (interpreter = oracle, compiled plan = hot path)
//!
//! The crate keeps **two** executors on purpose:
//!
//! * [`crate::eval::Plan`] — the *interpreter*: simple, allocating, and
//!   independently validated against brute-force and finite-difference
//!   oracles. It is the reference semantics.
//! * [`CompiledPlan`] (this module) — the *hot path*: every `Mul` is
//!   pre-compiled into an [`EinsumPlan`](crate::einsum::EinsumPlan)
//!   (strides, pre-sums and permutations resolved at compile time),
//!   constants and δ tensors are materialised once, intermediate buffers
//!   come from a shape-bucketed [`BufferPool`] and are recycled at their
//!   last use, and independent DAG levels run on scoped worker threads.
//!
//! `tests/exec_equivalence.rs` pins the two against each other (and
//! against `einsum_naive`) over randomized specs and DAGs.
//!
//! ## Plan-cache key contract
//!
//! [`PlanCache`] memoises compiled plans for the coordinator's
//! repeated-request hot path. The key is
//! `(graph fingerprint, root node ids)` where the fingerprint hashes
//! every node of the graph **in id order** — operator, einsum spec,
//! constant bits, δ dims *and node shape*. Because `Var` nodes carry
//! their declared shape, the fingerprint covers the input-shape
//! signature; two graphs with equal fingerprints therefore compile to
//! identical instruction streams (modulo 64-bit hash collision). The
//! cache never evicts: it is bounded by the number of distinct
//! `(graph, roots)` pairs a process registers, which is the number of
//! distinct service entries. Cached plans are `Arc`-shared, so every
//! worker that serves the same graph also shares one warm buffer pool.

use crate::einsum::{EinScratch, EinsumPlan};
use crate::eval::Env;
use crate::ir::{Elem, GenFn, Graph, NodeId, Op};
use crate::tensor::Tensor;
use crate::util::{num_threads, PAR_BATCH_TOTAL_MIN_FLOP, PAR_LEVEL_MIN_FLOP};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex, OnceLock};

/// A shape-bucketed free list of `f64` buffers. Buffers are bucketed by
/// exact element count; `acquire` pops a warm buffer (contents arbitrary
/// — every instruction fully overwrites its output) or allocates a fresh
/// one.
#[derive(Default)]
pub struct BufferPool {
    buckets: HashMap<usize, Vec<Vec<f64>>>,
    fresh: u64,
    reused: u64,
}

/// Allocation counters of a [`BufferPool`] — the executor's "near-zero
/// allocations after warm-up" invariant is asserted through these.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolStats {
    /// buffers allocated anew (cold misses)
    pub fresh: u64,
    /// buffers served from the pool (warm hits)
    pub reused: u64,
}

impl BufferPool {
    fn acquire(&mut self, len: usize) -> Vec<f64> {
        if let Some(list) = self.buckets.get_mut(&len) {
            if let Some(buf) = list.pop() {
                self.reused += 1;
                debug_assert_eq!(buf.len(), len);
                return buf;
            }
        }
        self.fresh += 1;
        vec![0.0; len]
    }

    fn release(&mut self, buf: Vec<f64>) {
        self.buckets.entry(buf.len()).or_default().push(buf);
    }

    fn stats(&self) -> PoolStats {
        PoolStats { fresh: self.fresh, reused: self.reused }
    }
}

/// One lowered node. Operands are dense positions into the instruction
/// stream (not `NodeId`s), so execution never touches the `Graph`.
enum Instr {
    /// Bind the named input from the `Env` (shape-checked, zero-copy).
    Var { name: String, shape: Vec<usize> },
    /// A `Const`/`Delta` tensor materialised once at compile time.
    Static(usize),
    Add(usize, usize),
    /// Pre-compiled contraction (strides/pre-sums/permutation resolved).
    Mul(usize, usize, EinsumPlan),
    Elem(Elem, usize),
    GenUnary(GenFn, usize),
}

/// A value slot during execution: intermediates own pooled buffers,
/// inputs and compile-time constants are borrowed.
enum Val<'a> {
    Owned(Tensor),
    Ref(&'a Tensor),
}

impl<'a> Val<'a> {
    fn tensor(&self) -> &Tensor {
        match self {
            Val::Owned(t) => t,
            Val::Ref(t) => t,
        }
    }
}

/// An expression DAG compiled for repeated execution: dense instruction
/// stream in topological order, per-level scheduling, buffer lifetimes
/// resolved to pool-release points, and all contractions pre-compiled.
pub struct CompiledPlan {
    instrs: Vec<Instr>,
    shapes: Vec<Vec<usize>>,
    statics: Vec<Tensor>,
    /// instruction positions grouped by dependency depth (level 0 first);
    /// nodes within one level are independent and may run in parallel
    levels: Vec<Vec<usize>>,
    /// estimated flops per level — gates the scoped-thread fork
    level_flops: Vec<usize>,
    /// largest single-node flop estimate per level — levels whose nodes
    /// parallelise *internally* (GEMM row bands / batch splits) are run
    /// serially at this layer to avoid nested-fork oversubscription
    level_max_flops: Vec<usize>,
    /// positions whose value dies after each level (returned to the pool)
    free_at_level: Vec<Vec<usize>>,
    root_pos: Vec<usize>,
    pool: Mutex<BufferPool>,
    /// einsum scratch buffers, checked out once per run (serial) or once
    /// per band (parallel) — never per node, to keep lock traffic low
    scratches: Mutex<Vec<EinScratch>>,
}

impl CompiledPlan {
    /// Compile the sub-DAG of `g` reachable from `roots`.
    pub fn new(g: &Graph, roots: &[NodeId]) -> Self {
        let order = g.topo(roots);
        let mut pos_of: HashMap<NodeId, usize> = HashMap::with_capacity(order.len());
        for (i, &id) in order.iter().enumerate() {
            pos_of.insert(id, i);
        }

        let mut instrs: Vec<Instr> = Vec::with_capacity(order.len());
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(order.len());
        let mut statics: Vec<Tensor> = Vec::new();
        let mut depth: Vec<usize> = vec![0; order.len()];
        let mut flops: Vec<usize> = vec![0; order.len()];

        for (i, &id) in order.iter().enumerate() {
            let shape = g.shape(id).to_vec();
            let out_len: usize = shape.iter().product();
            let instr = match g.op(id) {
                Op::Var(name) => Instr::Var { name: name.clone(), shape: shape.clone() },
                Op::Const(bits) => {
                    statics.push(Tensor::fill(&shape, f64::from_bits(*bits)));
                    Instr::Static(statics.len() - 1)
                }
                Op::Delta { dims } => {
                    statics.push(Tensor::delta(dims));
                    Instr::Static(statics.len() - 1)
                }
                Op::Add(a, b) => Instr::Add(pos_of[a], pos_of[b]),
                Op::Mul(a, b, spec) => {
                    let plan = EinsumPlan::new(spec, g.shape(*a), g.shape(*b));
                    flops[i] = plan.iteration_space();
                    Instr::Mul(pos_of[a], pos_of[b], plan)
                }
                Op::Elem(f, a) => Instr::Elem(*f, pos_of[a]),
                Op::GenUnary(f, a) => Instr::GenUnary(*f, pos_of[a]),
            };
            if flops[i] == 0 {
                flops[i] = match &instr {
                    Instr::Var { .. } | Instr::Static(_) => 0,
                    _ => out_len,
                };
            }
            let d = operands(&instr)
                .iter()
                .map(|&c| depth[c] + 1)
                .max()
                .unwrap_or(0);
            depth[i] = d;
            instrs.push(instr);
            shapes.push(shape);
        }

        let n_levels = depth.iter().copied().max().map(|d| d + 1).unwrap_or(0);
        let mut levels: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
        let mut level_flops: Vec<usize> = vec![0; n_levels];
        let mut level_max_flops: Vec<usize> = vec![0; n_levels];
        for (i, &d) in depth.iter().enumerate() {
            levels[d].push(i);
            level_flops[d] = level_flops[d].saturating_add(flops[i]);
            level_max_flops[d] = level_max_flops[d].max(flops[i]);
        }

        // Buffer lifetimes: a value is released to the pool after the
        // last level that consumes it. Roots are never released.
        let mut last_level: Vec<Option<usize>> = vec![None; instrs.len()];
        for (i, instr) in instrs.iter().enumerate() {
            for &c in operands(instr).iter() {
                let lvl = depth[i];
                last_level[c] = Some(last_level[c].map_or(lvl, |p| p.max(lvl)));
            }
        }
        let root_pos: Vec<usize> = roots.iter().map(|r| pos_of[r]).collect();
        for &r in &root_pos {
            last_level[r] = None;
        }
        let mut free_at_level: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
        for (i, ll) in last_level.iter().enumerate() {
            if let Some(lvl) = ll {
                free_at_level[*lvl].push(i);
            }
        }

        CompiledPlan {
            instrs,
            shapes,
            statics,
            levels,
            level_flops,
            level_max_flops,
            free_at_level,
            root_pos,
            pool: Mutex::new(BufferPool::default()),
            scratches: Mutex::new(Vec::new()),
        }
    }

    /// Number of instructions (reachable nodes) the plan executes.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Number of dependency levels (the critical-path length).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Buffer-pool counters (cold allocations vs warm reuses) — after
    /// one warm-up run, repeated executions should add reuses only.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.lock().unwrap().stats()
    }

    /// Execute the plan against `env`. Panics on unbound or wrongly
    /// shaped variables (same contract as the interpreter).
    pub fn run(&self, env: &Env) -> Vec<Tensor> {
        let n = self.instrs.len();
        let mut values: Vec<Option<Val>> = Vec::with_capacity(n);
        values.resize_with(n, || None);
        let mut scratch = self.scratches.lock().unwrap().pop().unwrap_or_default();

        for (lv, level) in self.levels.iter().enumerate() {
            let nt = num_threads().min(level.len());
            // Fork at the level layer only for many-small-node levels:
            // a node above PAR_BATCH_TOTAL_MIN_FLOP may fork its own row
            // bands / batch splits inside the GEMM, and nesting both
            // layers would oversubscribe the cores num_threads-fold.
            if nt > 1
                && self.level_flops[lv] >= PAR_LEVEL_MIN_FLOP
                && self.level_max_flops[lv] <= PAR_BATCH_TOTAL_MIN_FLOP
            {
                // band-split the level across scoped worker threads; each
                // thread writes its own slice of `results`
                let mut results: Vec<Option<Val>> = Vec::with_capacity(level.len());
                results.resize_with(level.len(), || None);
                let per = level.len().div_ceil(nt);
                std::thread::scope(|s| {
                    let values_ref = &values;
                    let mut rest: &mut [Option<Val>] = &mut results;
                    let mut nodes: &[usize] = level;
                    while !rest.is_empty() {
                        let take = per.min(rest.len());
                        let (band, tail) = rest.split_at_mut(take);
                        let (nb, ntail) = nodes.split_at(take);
                        s.spawn(move || {
                            let mut band_scratch =
                                self.scratches.lock().unwrap().pop().unwrap_or_default();
                            for (slot, &p) in band.iter_mut().zip(nb) {
                                *slot =
                                    Some(self.exec_node(p, values_ref, env, &mut band_scratch));
                            }
                            self.scratches.lock().unwrap().push(band_scratch);
                        });
                        rest = tail;
                        nodes = ntail;
                    }
                });
                for (r, &p) in results.into_iter().zip(level) {
                    values[p] = r;
                }
            } else {
                for &p in level {
                    let v = self.exec_node(p, &values, env, &mut scratch);
                    values[p] = Some(v);
                }
            }
            // recycle buffers whose last consumer ran in this level
            // (one pool lock per level, not per buffer)
            if !self.free_at_level[lv].is_empty() {
                let mut pool = self.pool.lock().unwrap();
                for &p in &self.free_at_level[lv] {
                    if let Some(Val::Owned(t)) = values[p].take() {
                        pool.release(t.into_data());
                    }
                }
            }
        }
        self.scratches.lock().unwrap().push(scratch);

        let mut out = Vec::with_capacity(self.root_pos.len());
        for i in 0..self.root_pos.len() {
            let p = self.root_pos[i];
            let used_again = self.root_pos[i + 1..].contains(&p);
            let t = if used_again {
                values[p].as_ref().expect("root not computed").tensor().clone()
            } else {
                match values[p].take().expect("root not computed") {
                    Val::Owned(t) => t,
                    Val::Ref(t) => t.clone(),
                }
            };
            out.push(t);
        }
        out
    }

    fn exec_node<'a>(
        &'a self,
        p: usize,
        values: &[Option<Val<'a>>],
        env: &'a Env,
        scratch: &mut EinScratch,
    ) -> Val<'a> {
        let shape = &self.shapes[p];
        match &self.instrs[p] {
            Instr::Var { name, shape } => {
                let t = env
                    .get(name)
                    .unwrap_or_else(|| panic!("unbound variable {}", name));
                assert_eq!(
                    t.shape(),
                    &shape[..],
                    "variable {} bound with wrong shape",
                    name
                );
                Val::Ref(t)
            }
            Instr::Static(i) => Val::Ref(&self.statics[*i]),
            Instr::Add(a, b) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let tb = values[*b].as_ref().expect("operand not computed").tensor();
                let mut buf = self.pool.lock().unwrap().acquire(ta.len());
                for ((o, &x), &y) in buf.iter_mut().zip(ta.data()).zip(tb.data()) {
                    *o = x + y;
                }
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::Mul(a, b, plan) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let tb = values[*b].as_ref().expect("operand not computed").tensor();
                let out_len: usize = shape.iter().product();
                let buf = self.pool.lock().unwrap().acquire(out_len);
                let mut out = Tensor::new(shape, buf);
                plan.run(ta, tb, &mut out, scratch);
                Val::Owned(out)
            }
            Instr::Elem(f, a) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let mut buf = self.pool.lock().unwrap().acquire(ta.len());
                for (o, &x) in buf.iter_mut().zip(ta.data()) {
                    *o = f.apply(x);
                }
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::GenUnary(f, a) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let out_len: usize = shape.iter().product();
                let mut buf = self.pool.lock().unwrap().acquire(out_len);
                gen_unary_into(*f, ta, &mut buf);
                Val::Owned(Tensor::new(shape, buf))
            }
        }
    }
}

/// Operand positions of one instruction.
fn operands(instr: &Instr) -> Vec<usize> {
    match instr {
        Instr::Add(a, b) | Instr::Mul(a, b, _) => vec![*a, *b],
        Instr::Elem(_, a) | Instr::GenUnary(_, a) => vec![*a],
        Instr::Var { .. } | Instr::Static(_) => Vec::new(),
    }
}

/// Write-into evaluation of the general unary functions (mirrors
/// [`GenFn::eval`] but targets a pooled buffer).
fn gen_unary_into(f: GenFn, t: &Tensor, out: &mut [f64]) {
    let n = *t.shape().last().expect("GenFn needs rank ≥ 1");
    match f {
        GenFn::Softmax => {
            out.copy_from_slice(t.data());
            for row in out.chunks_mut(n) {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut z = 0.0;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    z += *v;
                }
                for v in row.iter_mut() {
                    *v /= z;
                }
            }
        }
        GenFn::LogSumExp => {
            for (o, row) in out.iter_mut().zip(t.data().chunks(n)) {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                *o = m + row.iter().map(|v| (v - m).exp()).sum::<f64>().ln();
            }
        }
    }
}

/// Fingerprint of a graph: hashes every node (op + shape) in id order.
/// See the module docs for the key contract this participates in.
pub fn graph_fingerprint(g: &Graph) -> u64 {
    let mut h = DefaultHasher::new();
    g.len().hash(&mut h);
    for node in g.nodes() {
        node.hash(&mut h);
    }
    h.finish()
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    fingerprint: u64,
    roots: Vec<u32>,
}

/// Memoised compiled plans keyed by `(graph fingerprint, roots)` — the
/// coordinator's repeated-request hot path compiles each entry once and
/// shares it (plan + warm buffer pool) across workers.
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, Arc<CompiledPlan>>>,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::default()
    }

    /// Fetch the compiled plan for `(g, roots)`, compiling on first use.
    pub fn get_or_compile(&self, g: &Graph, roots: &[NodeId]) -> Arc<CompiledPlan> {
        let key = PlanKey {
            fingerprint: graph_fingerprint(g),
            roots: roots.iter().map(|r| r.0).collect(),
        };
        let mut map = self.map.lock().unwrap();
        if let Some(plan) = map.get(&key) {
            return plan.clone();
        }
        let plan = Arc::new(CompiledPlan::new(g, roots));
        map.insert(key, plan.clone());
        plan
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide plan cache used by the coordinator.
pub fn global_plan_cache() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(PlanCache::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Plan;
    use crate::ir::Elem;

    fn expr1() -> (Graph, NodeId, Env) {
        // Xᵀ((exp(Xw)+1)⁻¹ ⊙ exp(Xw)) — paper Expression (1)
        let mut g = Graph::new();
        let x = g.var("X", &[4, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[4]);
        let e1 = g.add(e, one);
        let inv = g.elem(Elem::Recip, e1);
        let prod = g.hadamard(inv, e);
        let y = g.tmatvec(x, prod);
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[4, 3], 1));
        env.insert("w", Tensor::randn(&[3], 2));
        (g, y, env)
    }

    #[test]
    fn compiled_matches_interpreter_on_expression1() {
        let (g, y, env) = expr1();
        let compiled = CompiledPlan::new(&g, &[y]);
        let interp = Plan::new(&g, &[y]);
        let a = compiled.run(&env);
        let b = interp.run(&g, &env);
        assert!(a[0].allclose(&b[0], 1e-12, 1e-14), "diff {}", a[0].max_abs_diff(&b[0]));
    }

    #[test]
    fn pool_warm_after_first_run() {
        let (g, y, env) = expr1();
        let plan = CompiledPlan::new(&g, &[y]);
        let first = plan.run(&env);
        let cold = plan.pool_stats();
        for _ in 0..5 {
            let again = plan.run(&env);
            assert_eq!(again[0].data(), first[0].data());
        }
        let warm = plan.pool_stats();
        // Root buffers leave the pool each run, so one fresh alloc per
        // run for the root is expected; intermediates must all be reused.
        let runs = 5;
        assert!(
            warm.fresh <= cold.fresh + runs,
            "pool still allocating after warm-up: {:?} -> {:?}",
            cold,
            warm
        );
        assert!(warm.reused > cold.reused, "pool never reused a buffer");
    }

    #[test]
    fn duplicate_roots_are_returned_twice() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let e = g.elem(Elem::Exp, x);
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[3], 3));
        let plan = CompiledPlan::new(&g, &[e, e, x]);
        let vals = plan.run(&env);
        assert_eq!(vals.len(), 3);
        assert_eq!(vals[0], vals[1]);
        assert_eq!(vals[2], *env.get("x").unwrap());
    }

    #[test]
    #[should_panic(expected = "unbound variable")]
    fn unbound_variable_panics_compiled() {
        let mut g = Graph::new();
        let x = g.var("x", &[2]);
        CompiledPlan::new(&g, &[x]).run(&Env::new());
    }

    #[test]
    fn statics_are_precomputed_and_shared() {
        let mut g = Graph::new();
        let d = g.delta(&[3]);
        let c = g.constant(2.5, &[3, 3]);
        let s = g.hadamard(d, c);
        let plan = CompiledPlan::new(&g, &[s]);
        let vals = plan.run(&Env::new());
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 2.5 } else { 0.0 };
                assert_eq!(vals[0].at(&[i, j]), want);
            }
        }
    }

    #[test]
    fn plan_cache_hits_on_identical_graphs() {
        let cache = PlanCache::new();
        let (g, y, _) = expr1();
        let p1 = cache.get_or_compile(&g, &[y]);
        let p2 = cache.get_or_compile(&g, &[y]);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.len(), 1);
        // a structurally identical but separately built graph hits too
        let (g2, y2, _) = expr1();
        let p3 = cache.get_or_compile(&g2, &[y2]);
        assert!(Arc::ptr_eq(&p1, &p3));
        // different roots miss
        let _ = cache.get_or_compile(&g, &[y, y]);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn fingerprint_distinguishes_shapes() {
        let mut g1 = Graph::new();
        g1.var("x", &[3]);
        let mut g2 = Graph::new();
        g2.var("x", &[4]);
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&g2));
    }

    #[test]
    fn levels_partition_instructions() {
        let (g, y, _) = expr1();
        let plan = CompiledPlan::new(&g, &[y]);
        let total: usize = plan.levels.iter().map(|l| l.len()).sum();
        assert_eq!(total, plan.len());
        assert!(plan.depth() >= 4, "expression 1 has a chain of depth ≥ 4");
    }
}
