//! The compiled execution engine: [`CompiledPlan`] lowers an expression
//! DAG into a dense instruction stream executed over a statically
//! planned arena (or, as the ablation baseline, pooled buffers), with
//! pre-compiled write-into einsums, cross-node fusion of element-wise
//! chains and work-stealing level scheduling on a persistent worker
//! pool.
//!
//! ## Architecture (interpreter = oracle, compiled plan = hot path)
//!
//! The crate keeps **two** executors on purpose:
//!
//! * [`crate::eval::Plan`] — the *interpreter*: simple, allocating, and
//!   independently validated against brute-force and finite-difference
//!   oracles. It is the reference semantics and deliberately stays
//!   un-fused — it is the oracle the fused executor is pinned against.
//! * [`CompiledPlan`] (this module) — the *hot path*: every `Mul` is
//!   pre-compiled into an [`EinsumPlan`](crate::einsum::EinsumPlan)
//!   (strides, pre-sums and permutations resolved at compile time),
//!   constants and δ tensors are materialised once, intermediate buffers
//!   live at planner-assigned fixed offsets of a per-plan arena (the
//!   shape-bucketed [`BufferPool`] survives as the
//!   [`ExecMemory::Pooled`] ablation), and independent DAG levels run on
//!   the persistent worker pool.
//!
//! `tests/exec_equivalence.rs` pins the two against each other (and
//! against `einsum_naive`) over randomized specs and DAGs, including
//! deep element-wise chains that exercise the fusion pass.
//!
//! ## Fusion pass
//!
//! At compile time, maximal single-consumer chains/trees of `Elem`,
//! `Add`, Hadamard- and scalar-`Mul` nodes collapse into one
//! `FusedKernel`: a tiny postfix program evaluated in a single pass over
//! the data — one output buffer, zero intermediates, regardless of the
//! chain depth. Where the chain rides on the output of a contraction or
//! general unary whose value is not needed elsewhere, the kernel is
//! instead applied *in place* as an epilogue on the producer's buffer,
//! so the whole chain costs no buffer at all. Kernels are capped at
//! `FUSED_MAX_ARGS` operand slots (a chain that would exceed it splits
//! into two kernels), which lets execution resolve operands into a stack
//! array — the hot path performs no heap allocation at all once the pool
//! is warm.
//!
//! ## Epilogue placement ([`EpilogueMode`])
//!
//! A contraction epilogue can run two ways, selected at compile time:
//!
//! * [`EpilogueMode::InTile`] (default) — the kernel is pushed down into
//!   the GEMM tile loop
//!   ([`EinsumPlan::run_with_epilogue_in_tile`](crate::einsum::EinsumPlan::run_with_epilogue_in_tile)):
//!   each output tile is post-processed right after its final
//!   k-accumulation, while it is cache-hot, so the fused chain costs no
//!   extra pass over the output buffer at all.
//! * [`EpilogueMode::TwoPass`] — the pre-tiling behaviour, kept as the
//!   reference and ablation baseline: the contraction finishes, then the
//!   kernel sweeps the whole output buffer once more
//!   ([`EinsumPlan::run_with_epilogue`]).
//!
//! The two are bit-identical (same GEMM accumulation order, same
//! per-element epilogue program); `tests/tile_epilogue.rs` pins them
//! against each other and against the interpreter.
//!
//! ## Memory discipline ([`ExecMemory`])
//!
//! Where an instruction's output lives is a compile-time choice:
//!
//! * [`ExecMemory::Planned`] (default) — the `memplan` pass runs a
//!   liveness analysis over the instruction stream (the same last-use
//!   levels the pooled mode recycles on), builds the interference
//!   intervals of every intermediate and einsum scratch region, and
//!   packs them into fixed offsets of a single per-plan arena
//!   (best-fit, with in-place reuse when a dying input's slot fits the
//!   output). At run time a destination is `&arena[off..off + len]`:
//!   after the arena's first growth, the steady-state hot path performs
//!   **zero** heap allocations and acquires **no** pool mutex — one
//!   run-state checkout per call is the only synchronization.
//! * [`ExecMemory::Pooled`] — the PR 1 executor, kept as the
//!   ablation/reference mode: intermediates come from a shape-bucketed
//!   [`BufferPool`] behind a mutex and are recycled at their last use.
//!
//! The two modes are bit-identical (same instruction stream, same
//! kernels, same accumulation order); `tests/memory_plan.rs` pins them
//! against each other and against the interpreter, checks the planner's
//! no-overlap invariant, and asserts the steady-state zero-alloc /
//! no-lock counters.
//!
//! ## Work-stealing level scheduling on a persistent pool
//!
//! Within a parallel level, worker threads claim chunks of the level's
//! instruction list from a shared atomic cursor instead of pre-sliced
//! static bands, so one oversized node delays only the thread that
//! claimed it — not an entire band scheduled behind it. The workers
//! themselves come from the process-wide
//! [`util::worker_pool`](crate::util::worker_pool): parked threads that
//! survive across runs, plans and coordinator entries, so the level
//! scheduler spawns no threads and every worker keeps its GEMM packing
//! scratch and einsum odometer warm. (Serial levels containing a large
//! contraction still fork scoped row-band threads *inside* the GEMM
//! kernel — that layer is gated by `PAR_GEMM_MIN_FLOP` and is the one
//! remaining spawn site.)
//!
//! ## Plan-cache key contract
//!
//! [`PlanCache`] memoises compiled plans for the coordinator's
//! repeated-request hot path. Unless the caller opts out with
//! [`OptLevel::None`](crate::opt::OptLevel), the graph first runs
//! through the [`crate::opt`] pipeline (global CSE + contraction
//! reassociation) and a dead-node sweep; the key is
//! `(graph fingerprint, root node ids)` **of the optimized, compacted
//! graph**, where the fingerprint hashes every node **in id order** —
//! operator, einsum spec, constant bits, δ dims *and node shape*.
//! Because `Var` nodes carry their declared shape, the fingerprint
//! covers the input-shape signature, and because the optimizer
//! canonicalises specs and operand orders, differently-built but
//! equivalent graphs converge on the same key; two graphs with equal
//! fingerprints compile to identical instruction streams (modulo 64-bit
//! hash collision). The cache never evicts: it is bounded by the number
//! of distinct `(graph, roots)` pairs a process registers, which is the
//! number of distinct service entries. Cached plans are `Arc`-shared,
//! so every worker that serves the same graph also shares one warm set
//! of run arenas (or, under the pooled ablation mode, one warm buffer
//! pool).

mod batch;
mod memplan;

pub use batch::batch_graph;

use crate::einsum::{EinScratch, EinSpec, EinsumPlan, EpiFn, Label, NoEpilogue};
use crate::eval::Env;
use crate::ir::{Elem, GenFn, Graph, NodeId, Op};
use crate::opt::OptLevel;
use crate::tensor::Tensor;
use crate::util::{
    num_threads, worker_pool, PAR_BATCH_TOTAL_MIN_FLOP, PAR_LEVEL_MIN_FLOP,
    STEAL_CHUNKS_PER_THREAD,
};
use memplan::{MemPlan, PlanInput, Slot};
use std::cell::RefCell;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// A shape-bucketed free list of `f64` buffers. Buffers are bucketed by
/// exact element count; `acquire` pops a warm buffer (contents arbitrary
/// — every instruction fully overwrites its output) or allocates a fresh
/// one.
#[derive(Default)]
pub struct BufferPool {
    buckets: HashMap<usize, Vec<Vec<f64>>>,
    fresh: u64,
    reused: u64,
}

/// Memory counters of a [`CompiledPlan`] — the executor's "zero
/// steady-state allocation" invariant is asserted through these, in the
/// units of whichever [`ExecMemory`] mode the plan compiled with.
///
/// Under [`ExecMemory::Pooled`] the meaningful fields are the bucket
/// counters `fresh`/`reused` (and `pool_locks`). Under
/// [`ExecMemory::Planned`] the pool is never touched — those stay zero —
/// and the plan reports its arena instead: `arena_bytes` (the packed
/// footprint), the planner's compile-time `planned_reuse`/`inplace_reuse`
/// packing wins, and `arena_allocs`, the number of run-state arenas that
/// had to grow at run time (one per concurrent caller, then constant —
/// the steady-state zero-allocation assertion in `tests/memory_plan.rs`
/// checks exactly this counter and `pool_locks == 0`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// which discipline the plan compiled with (selects the meaningful
    /// counters, and the `Display` format)
    pub memory: ExecMemory,
    /// pooled mode: buffers allocated anew (cold misses)
    pub fresh: u64,
    /// pooled mode: buffers served from the pool (warm hits)
    pub reused: u64,
    /// planned mode: bytes of one run arena (all intermediates + scratch)
    pub arena_bytes: u64,
    /// planned mode: slots packed into bytes freed by dead buffers
    pub planned_reuse: u64,
    /// planned mode: outputs reusing a dying input's slot in place
    pub inplace_reuse: u64,
    /// planned mode: run-state arenas grown at run time (cold starts)
    pub arena_allocs: u64,
    /// times the buffer-pool mutex was acquired (zero under `Planned`)
    pub pool_locks: u64,
}

impl fmt::Display for PoolStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.memory {
            ExecMemory::Planned => write!(
                f,
                "arena {:.1} KiB, packed-reuse {}, in-place {}, arena allocs {}, pool locks {}",
                self.arena_bytes as f64 / 1024.0,
                self.planned_reuse,
                self.inplace_reuse,
                self.arena_allocs,
                self.pool_locks
            ),
            ExecMemory::Pooled => write!(
                f,
                "pool fresh {}, reused {}, locks {}",
                self.fresh, self.reused, self.pool_locks
            ),
        }
    }
}

/// Where a plan's intermediates live — the memory-discipline ablation
/// toggle next to [`EpilogueMode`]. See the module docs ("Memory
/// discipline") for the contract; the two modes are bit-identical.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default, Hash)]
pub enum ExecMemory {
    /// Buffer lifetimes compiled to fixed offsets in one per-plan arena
    /// (liveness → interference intervals → best-fit packing, in-place
    /// reuse of dying inputs, einsum scratch planned alongside). The
    /// steady-state hot path allocates nothing and takes no pool mutex.
    /// The default.
    #[default]
    Planned,
    /// The PR 1 executor: a shape-bucketed [`BufferPool`] behind a mutex,
    /// buffers recycled at their last use. Kept as the ablation/reference
    /// mode.
    Pooled,
}

impl BufferPool {
    fn acquire(&mut self, len: usize) -> Vec<f64> {
        if let Some(list) = self.buckets.get_mut(&len) {
            if let Some(buf) = list.pop() {
                self.reused += 1;
                debug_assert_eq!(buf.len(), len);
                return buf;
            }
        }
        self.fresh += 1;
        vec![0.0; len]
    }

    fn release(&mut self, buf: Vec<f64>) {
        self.buckets.entry(buf.len()).or_default().push(buf);
    }

    fn stats(&self) -> PoolStats {
        PoolStats { fresh: self.fresh, reused: self.reused, ..PoolStats::default() }
    }
}

/// Maximum value-stack depth of a [`FusedKernel`] postfix program; the
/// group builder stops inlining before a kernel could exceed it.
const FUSED_MAX_STACK: usize = 16;

/// Maximum number of operand slots of a [`FusedKernel`]. The group
/// builder enforces it (pending-leaf accounting in
/// [`GroupBuilder::operand`]), which lets the executor resolve operands
/// into a fixed-size stack array per instruction — no heap allocation on
/// the steady-state hot path.
const FUSED_MAX_ARGS: usize = 16;

/// One step of a fused single-pass pipeline (postfix form).
#[derive(Clone, Copy)]
enum FusedOp {
    /// Push element `i` (or the broadcast scalar) of operand slot `k`.
    Load(u32),
    /// Apply an element-wise function to the top of the stack.
    Un(Elem),
    /// Pop two values, push their sum.
    Add,
    /// Pop two values, push their product.
    Mul,
}

/// A collapsed chain/tree of `Elem` / `Add` / Hadamard- and
/// scalar-`Mul` nodes evaluated in one pass over the data: for every
/// element index the postfix program runs over a fixed-size value
/// stack, reading operand slots and producing one output value — zero
/// intermediate buffers regardless of the chain depth.
struct FusedKernel {
    ops: Vec<FusedOp>,
    /// number of graph nodes collapsed into this kernel
    n_nodes: usize,
}

/// An operand slot resolved for one execution: same-shape operands are
/// read per element, rank-0 operands broadcast one value. `Copy` so a
/// whole slot array can live on the stack (see [`fused_srcs`]).
#[derive(Clone, Copy)]
enum FusedSrc<'s> {
    Slice(&'s [f64]),
    Scalar(f64),
}

impl FusedSrc<'_> {
    #[inline]
    fn at(&self, i: usize) -> f64 {
        match self {
            FusedSrc::Slice(s) => s[i],
            FusedSrc::Scalar(v) => *v,
        }
    }
}

impl FusedKernel {
    /// `out[i] = program(srcs, i)`; `Load(k)` reads `srcs[k]`.
    fn run(&self, srcs: &[FusedSrc], out: &mut [f64]) {
        let mut stack = [0.0f64; FUSED_MAX_STACK];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = self.eval_one(&mut stack, |k| srcs[k].at(i));
        }
    }

    /// In-place epilogue on a producer's output: `Load(0)` reads the
    /// buffer value being replaced, `Load(k ≥ 1)` reads `rest[k-1]`.
    fn run_inplace(&self, buf: &mut [f64], rest: &[FusedSrc]) {
        self.run_inplace_at(buf, 0, rest);
    }

    /// [`FusedKernel::run_inplace`] on a tile: `buf[j]` is global flat
    /// output element `base + j`, so operand slots resolve correctly
    /// from inside GEMM tiles, row bands and batch slices.
    fn run_inplace_at(&self, buf: &mut [f64], base: usize, rest: &[FusedSrc]) {
        let mut stack = [0.0f64; FUSED_MAX_STACK];
        for (j, slot) in buf.iter_mut().enumerate() {
            let carrier = *slot;
            *slot = self.eval_one(&mut stack, |k| {
                if k == 0 {
                    carrier
                } else {
                    rest[k - 1].at(base + j)
                }
            });
        }
    }

    /// The planned executor's in-place form: operand slot `arg` aliases
    /// the output buffer, so `Load(arg)` reads the value being replaced
    /// while every other slot reads `srcs` at its *original* position
    /// (`srcs[arg]` is a dummy, never touched). Bit-identical to
    /// [`FusedKernel::run`] with the aliased operand materialised.
    fn run_inplace_arg(&self, buf: &mut [f64], arg: u32, srcs: &[FusedSrc]) {
        let arg = arg as usize;
        let mut stack = [0.0f64; FUSED_MAX_STACK];
        for (i, out) in buf.iter_mut().enumerate() {
            let carrier = *out;
            *out = self.eval_one(&mut stack, |k| {
                if k == arg {
                    carrier
                } else {
                    srcs[k].at(i)
                }
            });
        }
    }

    /// The one postfix interpreter every execution form shares: `load`
    /// resolves `Load(k)` (per-element slice read, broadcast scalar, or
    /// the in-place carrier value, depending on the caller's slot
    /// convention).
    #[inline]
    fn eval_one<L: Fn(usize) -> f64>(
        &self,
        stack: &mut [f64; FUSED_MAX_STACK],
        load: L,
    ) -> f64 {
        let mut sp = 0usize;
        for op in &self.ops {
            match op {
                FusedOp::Load(k) => {
                    stack[sp] = load(*k as usize);
                    sp += 1;
                }
                FusedOp::Un(f) => stack[sp - 1] = f.apply(stack[sp - 1]),
                FusedOp::Add => {
                    sp -= 1;
                    stack[sp - 1] += stack[sp];
                }
                FusedOp::Mul => {
                    sp -= 1;
                    stack[sp - 1] *= stack[sp];
                }
            }
        }
        debug_assert_eq!(sp, 1, "fused program must leave exactly one value");
        stack[0]
    }
}

/// A fused chain applied in place on a producer's freshly written
/// output (slot 0 of the kernel is the produced value itself).
struct Epilogue {
    kernel: FusedKernel,
    /// operand positions for kernel slots `1..` (slot 0 is the carrier)
    args: Vec<usize>,
}

/// One lowered node. Operands are dense positions into the instruction
/// stream (not `NodeId`s), so execution never touches the `Graph`.
enum Instr {
    /// Bind the named input from the `Env` (shape-checked, zero-copy).
    Var { name: String, shape: Vec<usize> },
    /// A `Const`/`Delta` tensor materialised once at compile time.
    Static(usize),
    Add(usize, usize),
    /// Pre-compiled contraction (strides/pre-sums/permutation resolved),
    /// optionally with a fused element-wise epilogue applied in place.
    Mul(usize, usize, EinsumPlan, Option<Epilogue>),
    Elem(Elem, usize),
    GenUnary(GenFn, usize, Option<Epilogue>),
    /// A collapsed element-wise chain/tree evaluated in one pass.
    Fused { kernel: FusedKernel, args: Vec<usize> },
}

/// A value slot during execution: intermediates own pooled buffers,
/// inputs and compile-time constants are borrowed.
enum Val<'a> {
    Owned(Tensor),
    Ref(&'a Tensor),
}

impl<'a> Val<'a> {
    fn tensor(&self) -> &Tensor {
        match self {
            Val::Owned(t) => t,
            Val::Ref(t) => t,
        }
    }
}

/// Intermediate lowering of one node, before the fusion pass decides
/// which nodes survive as instructions.
enum DescKind {
    Var(String),
    Static(usize),
    Add(usize, usize),
    Mul(usize, usize, EinsumPlan),
    Elem(Elem, usize),
    GenUnary(GenFn, usize),
}

fn desc_operands(d: &DescKind) -> Vec<usize> {
    match d {
        DescKind::Add(a, b) | DescKind::Mul(a, b, _) => vec![*a, *b],
        DescKind::Elem(_, a) | DescKind::GenUnary(_, a) => vec![*a],
        DescKind::Var(_) | DescKind::Static(_) => Vec::new(),
    }
}

/// Fusion-pass classification of a node: how it reads its operands when
/// evaluated element by element.
#[derive(Clone, Copy)]
enum FuseNode {
    Un(Elem, usize),
    Add2(usize, usize),
    /// element-wise product of two same-shape operands
    Had(usize, usize),
    /// `(tensor, scalar)`: tensor scaled by a broadcast rank-0 operand
    Scale(usize, usize),
}

fn all_distinct(ls: &[Label]) -> bool {
    ls.iter().enumerate().all(|(i, l)| !ls[i + 1..].contains(l))
}

/// Classify a `Mul` node as element-wise fusable: a Hadamard product of
/// same-shape operands, or a scalar broadcast scale. Anything with
/// summed labels, diagonals or permuted outputs stays a contraction.
fn classify_mul(
    spec: &EinSpec,
    a_shape: &[usize],
    b_shape: &[usize],
    pa: usize,
    pb: usize,
) -> Option<FuseNode> {
    if spec.is_elementwise() && all_distinct(&spec.s1) {
        return Some(FuseNode::Had(pa, pb));
    }
    if b_shape.is_empty() && spec.s2.is_empty() && spec.s3 == spec.s1 && all_distinct(&spec.s1) {
        return Some(FuseNode::Scale(pa, pb));
    }
    if a_shape.is_empty() && spec.s1.is_empty() && spec.s3 == spec.s2 && all_distinct(&spec.s2) {
        return Some(FuseNode::Scale(pb, pa));
    }
    None
}

/// A fused group under construction: the postfix program, its leaf
/// operands (pre-fusion stream positions, slot order) and how many
/// loads each leaf received — the epilogue-carrier check needs the
/// latter to prove all of a producer's uses live inside the group.
#[derive(Default)]
struct Group {
    ops: Vec<FusedOp>,
    leaves: Vec<usize>,
    leaf_loads: Vec<usize>,
    n_nodes: usize,
    /// melted producer applied in place (pre-fusion position)
    carrier: Option<usize>,
}

impl Group {
    fn push_leaf(&mut self, o: usize) {
        let slot = match self.leaves.iter().position(|&q| q == o) {
            Some(s) => s,
            None => {
                self.leaves.push(o);
                self.leaf_loads.push(0);
                self.leaves.len() - 1
            }
        };
        self.leaf_loads[slot] += 1;
        self.ops.push(FusedOp::Load(slot as u32));
    }

    /// Re-number slots for epilogue form: the carrier slot becomes
    /// `Load(0)`, remaining leaves shift to slots `1..` in order.
    fn rewrite_for_carrier(&mut self, slot: usize) {
        for op in self.ops.iter_mut() {
            if let FusedOp::Load(k) = op {
                let k0 = *k as usize;
                *k = if k0 == slot {
                    0
                } else if k0 < slot {
                    (k0 + 1) as u32
                } else {
                    k0 as u32
                };
            }
        }
        self.carrier = Some(self.leaves.remove(slot));
        self.leaf_loads.remove(slot);
    }
}

/// Shared context of one group build (the fusion pass working over the
/// pre-fusion descriptor stream).
struct GroupBuilder<'c> {
    fusable: &'c [Option<FuseNode>],
    uses: &'c [usize],
    is_root: &'c [bool],
    shapes: &'c [Vec<usize>],
    group_shape: &'c [usize],
}

impl GroupBuilder<'_> {
    /// Emit the postfix program of member `p`; the value stack already
    /// holds `held` entries when the member starts executing, and
    /// enclosing members will still load `pending` more leaves after
    /// this member returns (the operand-slot budget mirrors how `held`
    /// budgets the value stack).
    fn member(&self, p: usize, held: usize, pending: usize, melted: &mut [bool], grp: &mut Group) {
        grp.n_nodes += 1;
        match self.fusable[p].expect("group member must be fusable") {
            FuseNode::Un(f, a) => {
                self.operand(a, held, pending, melted, grp);
                grp.ops.push(FusedOp::Un(f));
            }
            FuseNode::Add2(a, b) => {
                self.operand(a, held, pending + 1, melted, grp);
                self.operand(b, held + 1, pending, melted, grp);
                grp.ops.push(FusedOp::Add);
            }
            FuseNode::Had(a, b) => {
                self.operand(a, held, pending + 1, melted, grp);
                self.operand(b, held + 1, pending, melted, grp);
                grp.ops.push(FusedOp::Mul);
            }
            FuseNode::Scale(t, s) => {
                self.operand(t, held, pending + 1, melted, grp);
                // the rank-0 operand broadcasts per run, not per
                // element: always a leaf
                grp.push_leaf(s);
                grp.ops.push(FusedOp::Mul);
            }
        }
    }

    /// Inline operand `o` when it is fusable, consumed only here, not a
    /// plan root, shape-preserving, and both the value stack and the
    /// operand-slot array have headroom (an inlined member adds at most
    /// two direct leaves, and `pending` siblings still follow);
    /// otherwise record it as a leaf.
    fn operand(
        &self,
        o: usize,
        held: usize,
        pending: usize,
        melted: &mut [bool],
        grp: &mut Group,
    ) {
        let inline = held + 2 <= FUSED_MAX_STACK
            && grp.leaves.len() + pending + 2 <= FUSED_MAX_ARGS
            && !self.is_root[o]
            && self.uses[o] == 1
            && self.fusable[o].is_some()
            && self.shapes[o].as_slice() == self.group_shape;
        if inline {
            melted[o] = true;
            self.member(o, held, pending, melted, grp);
        } else {
            grp.push_leaf(o);
        }
    }
}

/// Where a contraction's fused epilogue runs — the ablation toggle next
/// to `CompiledPlan::with_fusion`. See the module docs ("Epilogue
/// placement") for the contract; the two modes are bit-identical.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum EpilogueMode {
    /// Inside the GEMM tile loop, while each output tile is cache-hot
    /// (no second sweep over the output buffer). The default.
    #[default]
    InTile,
    /// As a second full sweep over the finished contraction output —
    /// the pre-tiling behaviour, kept as reference/ablation baseline.
    TwoPass,
}

/// Per-run state of a planned-memory execution, checked out once per
/// call (one lock) and returned warm: the arena plus the resolved
/// per-instruction source table. A plan keeps one `RunState` per
/// concurrent caller; each grows its arena once and never again.
#[derive(Default)]
struct RunState {
    arena: Vec<f64>,
    srcs: SrcTable,
}

/// Resolved value source of every instruction for one run: a pointer and
/// element count into the env's tensors, the plan's statics, or the
/// checked-out arena.
#[derive(Default)]
struct SrcTable(Vec<(*const f64, usize)>);

// SAFETY: the raw pointers are inert between runs (rewritten at the
// start of every run) and only dereferenced while the borrows they were
// derived from — env tensors, plan statics, the checked-out arena — are
// live within that run.
unsafe impl Send for SrcTable {}

/// Shared view of one planned run handed to the level workers: the
/// arena base plus the per-instruction source table.
///
/// SAFETY (for the `Sync` impl): each worker writes only its own
/// instructions' output slots, and the memory planner guarantees that a
/// slot written in level `L` overlaps no slot read or written by any
/// other instruction live in `L` (`MemPlan::check_no_overlap`).
struct ArenaExec<'r> {
    base: *mut f64,
    srcs: &'r [(*const f64, usize)],
}

unsafe impl Sync for ArenaExec<'_> {}

/// Operand slice of instruction `q` (env tensor, static, or arena slot).
#[inline]
fn src_slice<'r>(ex: &ArenaExec<'r>, q: usize) -> &'r [f64] {
    let (ptr, len) = ex.srcs[q];
    // SAFETY: see ArenaExec — the pointee outlives the run and no &mut
    // to the same region exists while this borrow is used.
    unsafe { std::slice::from_raw_parts(ptr, len) }
}

/// Mutable view of an arena slot.
///
/// SAFETY: caller must be the (sole) instruction that owns `slot` in the
/// current level — guaranteed by the memory plan.
#[inline]
#[allow(clippy::mut_from_ref)] // disjointness is the planner's invariant
unsafe fn slot_mut<'r>(ex: &ArenaExec<'r>, slot: Slot) -> &'r mut [f64] {
    std::slice::from_raw_parts_mut(ex.base.add(slot.off), slot.len)
}

thread_local! {
    /// Per-thread odometer scratch for planned-mode einsum gathers — the
    /// one scratch that cannot live in the `f64` arena. Persistent pool
    /// workers keep it warm across scopes, plans and coordinator entries.
    static IDX_SCRATCH: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
}

/// A checked-out run state kept alive past the end of its run so root
/// outputs can be served as views straight out of the arena — the
/// zero-copy response path. Dropping the last reference returns the
/// state (arena and all) to the plan's warm pool.
pub struct RunLease {
    /// `Some` until `Drop` takes it back to `plan.run_states`
    state: Option<RunState>,
    plan: Arc<CompiledPlan>,
}

// SAFETY: the lease only ever *reads* the arena `Vec<f64>` (through
// `PlanOutput::data`), and only after the run that wrote it completed on
// the leasing thread. The contained `SrcTable` pointers are inert while
// leased — they are rewritten at the start of the next run and never
// dereferenced through the lease.
unsafe impl Send for RunLease {}
unsafe impl Sync for RunLease {}

impl Drop for RunLease {
    fn drop(&mut self) {
        if let Some(st) = self.state.take() {
            self.plan.run_states.lock().unwrap().push(st);
        }
    }
}

impl RunLease {
    fn arena(&self) -> &[f64] {
        &self.state.as_ref().expect("lease taken before drop").arena
    }
}

/// A root output of [`CompiledPlan::run_leased`]: either an owned
/// [`Tensor`] or a zero-copy view into a leased run arena. Views borrow
/// nothing from the caller — the `Arc`-owned lease keeps the arena alive
/// — so a `PlanOutput` can cross threads and outlive the `Env` it was
/// computed from. Cloning a view clones the `Arc`, not the data.
#[derive(Clone)]
pub struct PlanOutput {
    shape: Vec<usize>,
    repr: OutRepr,
}

#[derive(Clone)]
enum OutRepr {
    Owned(Tensor),
    View { lease: Arc<RunLease>, off: usize, len: usize },
}

impl PlanOutput {
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// The value, row-major — a borrow of the leased arena for views.
    pub fn data(&self) -> &[f64] {
        match &self.repr {
            OutRepr::Owned(t) => t.data(),
            OutRepr::View { lease, off, len } => &lease.arena()[*off..*off + *len],
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Scalar value; panics unless the output holds exactly one element.
    pub fn item(&self) -> f64 {
        let d = self.data();
        assert_eq!(d.len(), 1, "item() on non-scalar output");
        d[0]
    }

    /// Materialise an owned [`Tensor`] (copies a view's slice; this is
    /// the moment a zero-copy response pays for its bytes).
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(&self.shape, self.data().to_vec())
    }

    /// Element-wise `|a - b| <= atol + rtol * |b|` against a tensor,
    /// shapes included — mirrors [`Tensor::allclose`].
    pub fn allclose(&self, other: &Tensor, rtol: f64, atol: f64) -> bool {
        self.shape() == other.shape()
            && self
                .data()
                .iter()
                .zip(other.data())
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// View of slice `i` of a leading-axis-batched output: the first
    /// axis (which must have size `bucket`) is dropped and the data
    /// narrows to that slice. For a view this is pointer arithmetic on
    /// the shared lease; for an owned tensor it copies the slice.
    pub fn batch_slice(&self, i: usize, bucket: usize) -> PlanOutput {
        assert!(
            self.shape.first() == Some(&bucket) && i < bucket,
            "batch_slice({}, {}) on output of shape {:?}",
            i,
            bucket,
            self.shape
        );
        let inner: Vec<usize> = self.shape[1..].to_vec();
        let len: usize = inner.iter().product();
        let repr = match &self.repr {
            OutRepr::Owned(t) => OutRepr::Owned(Tensor::new(
                &inner,
                t.data()[i * len..(i + 1) * len].to_vec(),
            )),
            OutRepr::View { lease, off, .. } => {
                OutRepr::View { lease: lease.clone(), off: off + i * len, len }
            }
        };
        PlanOutput { shape: inner, repr }
    }
}

impl From<Tensor> for PlanOutput {
    fn from(t: Tensor) -> Self {
        PlanOutput { shape: t.shape().to_vec(), repr: OutRepr::Owned(t) }
    }
}

impl fmt::Debug for PlanOutput {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = match &self.repr {
            OutRepr::Owned(_) => "owned",
            OutRepr::View { .. } => "leased",
        };
        f.debug_struct("PlanOutput")
            .field("shape", &self.shape)
            .field("kind", &kind)
            .finish()
    }
}

/// An expression DAG compiled for repeated execution: dense instruction
/// stream in topological order (element-wise chains fused), per-level
/// scheduling on the persistent worker pool, buffer lifetimes compiled
/// to arena offsets (or pool-release points under the pooled ablation
/// mode), and all contractions pre-compiled.
pub struct CompiledPlan {
    instrs: Vec<Instr>,
    shapes: Vec<Vec<usize>>,
    statics: Vec<Tensor>,
    /// instruction positions grouped by dependency depth (level 0 first);
    /// nodes within one level are independent and may run in parallel
    levels: Vec<Vec<usize>>,
    /// estimated flops per level — gates the worker-pool fork
    level_flops: Vec<usize>,
    /// largest *internally parallel* (GEMM) flop estimate per level —
    /// levels whose contractions parallelise internally (row bands /
    /// batch splits) run serially at this layer to avoid nested-fork
    /// oversubscription
    level_max_flops: Vec<usize>,
    /// positions whose value dies after each level (returned to the pool;
    /// pooled mode only — the planner bakes lifetimes into offsets)
    free_at_level: Vec<Vec<usize>>,
    root_pos: Vec<usize>,
    pool: Mutex<BufferPool>,
    /// einsum scratch buffers, checked out once per run (serial) or once
    /// per worker (parallel) — never per node, to keep lock traffic low
    /// (pooled mode only)
    scratches: Mutex<Vec<EinScratch>>,
    /// where contraction epilogues run (in-tile vs two-pass ablation)
    epilogue_mode: EpilogueMode,
    /// where intermediates live (planned arena vs pooled ablation)
    memory: ExecMemory,
    /// the static memory plan (planned mode only)
    memplan: Option<MemPlan>,
    /// per instruction: operand index *within the instruction* whose
    /// dying slot the output takes over in place (planned mode only; for
    /// `Fused` this is the kernel's operand slot)
    inplace_arg: Vec<Option<usize>>,
    /// warm per-caller run states (arena + source table), planned mode
    run_states: Mutex<Vec<RunState>>,
    /// run-state arenas grown at run time (cold starts; then constant)
    arena_allocs: AtomicU64,
    /// buffer-pool mutex acquisitions (the no-lock assertion's counter)
    pool_locks: AtomicU64,
}

impl CompiledPlan {
    /// Compile the sub-DAG of `g` reachable from `roots`.
    pub fn new(g: &Graph, roots: &[NodeId]) -> Self {
        Self::with_options(g, roots, true, EpilogueMode::default(), ExecMemory::default())
    }

    /// Compile with or without the cross-node fusion pass. `false`
    /// reproduces the PR 1 lowering (one buffer per node) and is kept as
    /// the ablation baseline for benches and differential tests.
    pub fn with_fusion(g: &Graph, roots: &[NodeId], fuse: bool) -> Self {
        Self::with_options(g, roots, fuse, EpilogueMode::default(), ExecMemory::default())
    }

    /// Compile with every ablation toggle explicit: the fusion pass
    /// on/off, where contraction epilogues run ([`EpilogueMode`]), and
    /// where intermediates live ([`ExecMemory`]).
    pub fn with_options(
        g: &Graph,
        roots: &[NodeId],
        fuse: bool,
        epilogue_mode: EpilogueMode,
        memory: ExecMemory,
    ) -> Self {
        let order = g.topo(roots);
        let n = order.len();
        let mut pos_of: HashMap<NodeId, usize> = HashMap::with_capacity(n);
        for (i, &id) in order.iter().enumerate() {
            pos_of.insert(id, i);
        }

        // -- lower every reachable node to a descriptor --
        let mut descs: Vec<Option<DescKind>> = Vec::with_capacity(n);
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(n);
        let mut statics: Vec<Tensor> = Vec::new();
        let mut base_flops: Vec<usize> = vec![0; n];
        let mut fusable: Vec<Option<FuseNode>> = Vec::with_capacity(n);
        for (i, &id) in order.iter().enumerate() {
            let shape = g.shape(id).to_vec();
            let out_len: usize = shape.iter().product();
            let (kind, fnode) = match g.op(id) {
                Op::Var(name) => (DescKind::Var(name.clone()), None),
                Op::Const(bits) => {
                    statics.push(Tensor::fill(&shape, f64::from_bits(*bits)));
                    (DescKind::Static(statics.len() - 1), None)
                }
                Op::Delta { dims } => {
                    statics.push(Tensor::delta(dims));
                    (DescKind::Static(statics.len() - 1), None)
                }
                Op::Add(a, b) => {
                    let (pa, pb) = (pos_of[a], pos_of[b]);
                    (DescKind::Add(pa, pb), Some(FuseNode::Add2(pa, pb)))
                }
                Op::Mul(a, b, spec) => {
                    let plan = EinsumPlan::new(spec, g.shape(*a), g.shape(*b));
                    base_flops[i] = plan.iteration_space();
                    let (pa, pb) = (pos_of[a], pos_of[b]);
                    let f = classify_mul(spec, g.shape(*a), g.shape(*b), pa, pb);
                    (DescKind::Mul(pa, pb, plan), f)
                }
                Op::Elem(f, a) => {
                    let pa = pos_of[a];
                    (DescKind::Elem(*f, pa), Some(FuseNode::Un(*f, pa)))
                }
                Op::GenUnary(f, a) => {
                    // the interpreter's contract, enforced at *compile*
                    // time — a mid-run panic in gen_unary_into would
                    // poison pooled buffers
                    assert!(
                        !g.shape(*a).is_empty(),
                        "GenUnary({}) needs a rank ≥ 1 operand (got rank 0)",
                        f.name()
                    );
                    (DescKind::GenUnary(*f, pos_of[a]), None)
                }
            };
            if base_flops[i] == 0 && !matches!(kind, DescKind::Var(_) | DescKind::Static(_)) {
                base_flops[i] = out_len;
            }
            descs.push(Some(kind));
            shapes.push(shape);
            fusable.push(if fuse { fnode } else { None });
        }

        // -- consumer counts over the pre-fusion stream (roots count) --
        let root_old: Vec<usize> = roots.iter().map(|r| pos_of[r]).collect();
        let mut uses = vec![0usize; n];
        for d in &descs {
            for o in desc_operands(d.as_ref().expect("desc present")) {
                uses[o] += 1;
            }
        }
        let mut is_root = vec![false; n];
        for &r in &root_old {
            uses[r] += 1;
            is_root[r] = true;
        }

        // -- fusion pass: greedy maximal groups, processed root-down --
        let mut melted = vec![false; n];
        let mut groups: Vec<Option<Group>> = Vec::with_capacity(n);
        groups.resize_with(n, || None);
        for p in (0..n).rev() {
            if melted[p] || fusable[p].is_none() {
                continue;
            }
            let builder = GroupBuilder {
                fusable: &fusable,
                uses: &uses,
                is_root: &is_root,
                shapes: &shapes,
                group_shape: &shapes[p],
            };
            let mut grp = Group::default();
            builder.member(p, 0, 0, &mut melted, &mut grp);
            // epilogue carrier: a contraction / general unary consumed
            // only by this group, producing exactly the group shape
            let carrier_slot = grp.leaves.iter().enumerate().find_map(|(slot, &l)| {
                let eligible = !is_root[l]
                    && shapes[l].as_slice() == shapes[p].as_slice()
                    && grp.leaf_loads[slot] == uses[l]
                    && matches!(
                        descs[l].as_ref().expect("desc present"),
                        DescKind::Mul(..) | DescKind::GenUnary(..)
                    );
                eligible.then_some(slot)
            });
            if let Some(slot) = carrier_slot {
                let l = grp.leaves[slot];
                melted[l] = true;
                grp.rewrite_for_carrier(slot);
                groups[p] = Some(grp);
            } else if grp.n_nodes >= 2 {
                groups[p] = Some(grp);
            }
            // n_nodes == 1 without a carrier: nothing was melted — the
            // original single instruction is kept as-is
        }

        // -- emit the fused instruction stream (dense re-map) --
        let mut remap = vec![usize::MAX; n];
        let mut instrs: Vec<Instr> = Vec::new();
        let mut out_shapes: Vec<Vec<usize>> = Vec::new();
        let mut flops: Vec<usize> = Vec::new();
        let mut internal_flops: Vec<usize> = Vec::new();
        for p in 0..n {
            if melted[p] {
                continue;
            }
            let out_len: usize = shapes[p].iter().product();
            let (instr, fl, ifl) = if let Some(grp) = groups[p].take() {
                let args: Vec<usize> = grp.leaves.iter().map(|&q| remap[q]).collect();
                let kernel = FusedKernel { ops: grp.ops, n_nodes: grp.n_nodes };
                let chain_fl = grp.n_nodes.saturating_mul(out_len);
                match grp.carrier {
                    Some(l) => {
                        let epi = Some(Epilogue { kernel, args });
                        match descs[l].take().expect("carrier desc present") {
                            DescKind::Mul(a, b, plan) => {
                                let gemm_fl = plan.iteration_space();
                                (
                                    Instr::Mul(remap[a], remap[b], plan, epi),
                                    gemm_fl.saturating_add(chain_fl),
                                    gemm_fl,
                                )
                            }
                            DescKind::GenUnary(f, a) => (
                                Instr::GenUnary(f, remap[a], epi),
                                out_len.saturating_add(chain_fl),
                                0,
                            ),
                            _ => unreachable!("carrier must be Mul or GenUnary"),
                        }
                    }
                    None => (Instr::Fused { kernel, args }, chain_fl, 0),
                }
            } else {
                let instr = match descs[p].take().expect("desc present") {
                    DescKind::Var(name) => Instr::Var { name, shape: shapes[p].clone() },
                    DescKind::Static(i) => Instr::Static(i),
                    DescKind::Add(a, b) => Instr::Add(remap[a], remap[b]),
                    DescKind::Mul(a, b, plan) => Instr::Mul(remap[a], remap[b], plan, None),
                    DescKind::Elem(f, a) => Instr::Elem(f, remap[a]),
                    DescKind::GenUnary(f, a) => Instr::GenUnary(f, remap[a], None),
                };
                let ifl = match &instr {
                    Instr::Mul(_, _, plan, _) => plan.iteration_space(),
                    _ => 0,
                };
                (instr, base_flops[p], ifl)
            };
            remap[p] = instrs.len();
            instrs.push(instr);
            out_shapes.push(shapes[p].clone());
            flops.push(fl);
            internal_flops.push(ifl);
        }

        // -- levels / lifetimes over the fused stream --
        let m = instrs.len();
        let mut depth: Vec<usize> = vec![0; m];
        for (i, instr) in instrs.iter().enumerate() {
            let d = operands(instr)
                .iter()
                .map(|&c| depth[c] + 1)
                .max()
                .unwrap_or(0);
            depth[i] = d;
        }
        let n_levels = depth.iter().copied().max().map(|d| d + 1).unwrap_or(0);
        let mut levels: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
        let mut level_flops: Vec<usize> = vec![0; n_levels];
        let mut level_max_flops: Vec<usize> = vec![0; n_levels];
        for (i, &d) in depth.iter().enumerate() {
            levels[d].push(i);
            level_flops[d] = level_flops[d].saturating_add(flops[i]);
            level_max_flops[d] = level_max_flops[d].max(internal_flops[i]);
        }

        // Buffer lifetimes: a value is released to the pool after the
        // last level that consumes it. Roots are never released.
        let mut last_level: Vec<Option<usize>> = vec![None; m];
        for (i, instr) in instrs.iter().enumerate() {
            for &c in operands(instr).iter() {
                let lvl = depth[i];
                last_level[c] = Some(last_level[c].map_or(lvl, |p| p.max(lvl)));
            }
        }
        let root_pos: Vec<usize> = root_old.iter().map(|&r| remap[r]).collect();
        for &r in &root_pos {
            last_level[r] = None;
        }
        let mut free_at_level: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
        for (i, ll) in last_level.iter().enumerate() {
            if let Some(lvl) = ll {
                free_at_level[*lvl].push(i);
            }
        }

        // -- static memory plan (planned mode): liveness → intervals →
        //    arena offsets, with in-place reuse of dying inputs --
        let (plan_mem, inplace_arg) = match memory {
            ExecMemory::Pooled => (None, vec![None; m]),
            ExecMemory::Planned => {
                // consumers of each value at its last-use level: in-place
                // transfer requires the taker to be the *sole* reader
                // there (anything else in that level runs concurrently)
                let mut last_consumers: Vec<Vec<usize>> = vec![Vec::new(); m];
                for (i, instr) in instrs.iter().enumerate() {
                    for &c in operands(instr).iter() {
                        if last_level[c] == Some(depth[i]) {
                            last_consumers[c].push(i);
                        }
                    }
                }
                // alias-safe in-place candidates: (operand stream
                // position, operand index within the instruction)
                let mut cand: Vec<Option<(usize, usize)>> = vec![None; m];
                for (i, instr) in instrs.iter().enumerate() {
                    let out_len: usize = out_shapes[i].iter().product();
                    let eligible = |o: usize| -> bool {
                        out_len > 0
                            && !matches!(instrs[o], Instr::Var { .. } | Instr::Static(_))
                            && last_level[o] == Some(depth[i])
                            && last_consumers[o].len() == 1
                            && out_shapes[o].iter().product::<usize>() == out_len
                    };
                    cand[i] = match instr {
                        // streaming element-wise reads of index j happen
                        // strictly before the write of index j, so the
                        // output may overwrite the dying operand
                        Instr::Elem(_, a) if eligible(*a) => Some((*a, 0)),
                        Instr::Add(a, b) => {
                            if eligible(*a) {
                                Some((*a, 0))
                            } else if eligible(*b) && a != b {
                                Some((*b, 1))
                            } else {
                                None
                            }
                        }
                        Instr::Fused { args, .. } => args
                            .iter()
                            .enumerate()
                            .find(|(_, &q)| eligible(q))
                            .map(|(slot, &q)| (q, slot)),
                        // contractions and general unaries read arbitrary
                        // indices (gather/GEMM/row reductions): never
                        // in-place
                        _ => None,
                    };
                }
                let inputs: Vec<PlanInput> = instrs
                    .iter()
                    .enumerate()
                    .map(|(i, instr)| PlanInput {
                        out_len: match instr {
                            Instr::Var { .. } | Instr::Static(_) => None,
                            _ => Some(out_shapes[i].iter().product()),
                        },
                        scratch: match instr {
                            Instr::Mul(_, _, plan, _) => Some(plan.scratch_sizes()),
                            _ => None,
                        },
                        def: depth[i],
                        last: last_level[i],
                        inplace_from: cand[i].map(|(o, _)| o),
                    })
                    .collect();
                let mp = MemPlan::build(&inputs, n_levels);
                // keep only the transfers the planner actually committed
                let inplace_arg: Vec<Option<usize>> = (0..m)
                    .map(|i| match mp.inplace[i] {
                        Some(_) => cand[i].map(|(_, arg)| arg),
                        None => None,
                    })
                    .collect();
                (Some(mp), inplace_arg)
            }
        };

        CompiledPlan {
            instrs,
            shapes: out_shapes,
            statics,
            levels,
            level_flops,
            level_max_flops,
            free_at_level,
            root_pos,
            pool: Mutex::new(BufferPool::default()),
            scratches: Mutex::new(Vec::new()),
            epilogue_mode,
            memory,
            memplan: plan_mem,
            inplace_arg,
            run_states: Mutex::new(Vec::new()),
            arena_allocs: AtomicU64::new(0),
            pool_locks: AtomicU64::new(0),
        }
    }

    /// Number of instructions the plan executes (after fusion this is
    /// smaller than the reachable node count).
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Number of dependency levels (the critical-path length).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Number of fused pipelines in the stream — standalone `Fused`
    /// instructions plus contraction/unary epilogues.
    pub fn fused_count(&self) -> usize {
        self.instrs
            .iter()
            .filter(|i| {
                matches!(
                    i,
                    Instr::Fused { .. }
                        | Instr::Mul(_, _, _, Some(_))
                        | Instr::GenUnary(_, _, Some(_))
                )
            })
            .count()
    }

    /// Memory counters — pooled bucket hits or planned arena figures,
    /// depending on the compile-time [`ExecMemory`]. After one warm-up
    /// run, repeated executions must not move the allocation counters.
    pub fn pool_stats(&self) -> PoolStats {
        // diagnostic read: bypasses lock_pool so it never perturbs the
        // pool_locks counter the tests assert on
        let base = self.pool.lock().unwrap().stats();
        PoolStats {
            memory: self.memory,
            arena_bytes: self
                .memplan
                .as_ref()
                .map_or(0, |mp| (mp.arena_len * std::mem::size_of::<f64>()) as u64),
            planned_reuse: self.memplan.as_ref().map_or(0, |mp| mp.planned_reuse),
            inplace_reuse: self.memplan.as_ref().map_or(0, |mp| mp.inplace_reuse),
            arena_allocs: self.arena_allocs.load(Ordering::Relaxed),
            pool_locks: self.pool_locks.load(Ordering::Relaxed),
            ..base
        }
    }

    /// The memory discipline this plan compiled with.
    pub fn memory(&self) -> ExecMemory {
        self.memory
    }

    /// Re-verify the memory plan's no-overlap invariant (no two live
    /// intervals share arena bytes). Panics on violation; no-op for
    /// pooled plans. The differential suite calls this on every plan it
    /// builds; compile already asserts it under `debug_assertions`.
    pub fn validate_memory_plan(&self) {
        if let Some(mp) = &self.memplan {
            mp.check_no_overlap();
        }
    }

    /// Acquire the buffer pool, counting the acquisition (the planned
    /// mode's "no pool mutex on the hot path" assertion reads this).
    fn lock_pool(&self) -> MutexGuard<'_, BufferPool> {
        self.pool_locks.fetch_add(1, Ordering::Relaxed);
        self.pool.lock().unwrap()
    }

    /// The level fork gate shared by **both** memory modes: fork only
    /// for many-small-node levels — a node whose contraction exceeds
    /// `PAR_BATCH_TOTAL_MIN_FLOP` forks its own row bands / batch splits
    /// inside the GEMM, and nesting both layers would oversubscribe the
    /// cores. Returns `(participants, steal-chunk size)` when the level
    /// should fork, `None` to run it serially. Keeping the gate and the
    /// chunk formula in one place is part of the Planned/Pooled
    /// bit-identical contract: the two modes must schedule identically.
    fn level_fork(&self, lv: usize, level_len: usize) -> Option<(usize, usize)> {
        let nt = num_threads().min(level_len);
        if nt > 1
            && self.level_flops[lv] >= PAR_LEVEL_MIN_FLOP
            && self.level_max_flops[lv] <= PAR_BATCH_TOTAL_MIN_FLOP
        {
            Some((nt, (level_len / (nt * STEAL_CHUNKS_PER_THREAD)).max(1)))
        } else {
            None
        }
    }

    /// Execute the plan against `env`. Panics on unbound or wrongly
    /// shaped variables (same contract as the interpreter).
    pub fn run(&self, env: &Env) -> Vec<Tensor> {
        match self.memory {
            ExecMemory::Planned => self.run_planned(env),
            ExecMemory::Pooled => self.run_pooled(env),
        }
    }

    /// Planned-memory execution: one run-state checkout (a single lock),
    /// then every instruction reads and writes fixed arena offsets. No
    /// allocation after the arena's first growth, no pool mutex, no
    /// thread spawn (parallel levels run on the persistent worker pool).
    fn run_planned(&self, env: &Env) -> Vec<Tensor> {
        let st = self.exec_planned_state(env);
        // materialise the roots (the only per-run allocations: the
        // caller owns the returned tensors)
        let mut out = Vec::with_capacity(self.root_pos.len());
        for &p in &self.root_pos {
            let (ptr, len) = st.srcs.0[p];
            // SAFETY: the pointee — env tensor, plan static, or st's own
            // arena — is still live here (env outlives the call, st is
            // owned by this frame).
            let data = unsafe { std::slice::from_raw_parts(ptr, len) }.to_vec();
            out.push(Tensor::new(&self.shapes[p], data));
        }
        self.run_states.lock().unwrap().push(st);
        out
    }

    /// Execute the plan against `env` and return the roots as
    /// [`PlanOutput`]s: arena-backed zero-copy views under an `Arc`-owned
    /// [`RunLease`] instead of `Tensor` clones — the serving hot path.
    /// The leased run state (arena included) returns to the plan's warm
    /// pool when the last output referencing it drops, so long-held
    /// responses hold their arena with them.
    ///
    /// Roots whose bytes live outside the arena (a root that *is* a
    /// variable or a compiled-in constant) are deep-copied, since the env
    /// they borrow from dies with the call. Pooled-mode plans have no
    /// arena and fall back to owned outputs wholesale.
    ///
    /// Takes the `Arc` by value (clone it to keep a handle — an `Arc`
    /// clone, not a plan copy): the lease must own the plan to return
    /// the run state on drop.
    pub fn run_leased(self: Arc<Self>, env: &Env) -> Vec<PlanOutput> {
        if self.memory == ExecMemory::Pooled {
            return self.run_pooled(env).into_iter().map(PlanOutput::from).collect();
        }
        let mp = self.memplan.as_ref().expect("planned plan carries a memory plan");
        let st = self.exec_planned_state(env);
        enum Pending {
            Owned(Tensor),
            Slot { off: usize, len: usize },
        }
        let mut pend = Vec::with_capacity(self.root_pos.len());
        for &p in &self.root_pos {
            match &self.instrs[p] {
                Instr::Var { .. } | Instr::Static(_) => {
                    let (ptr, len) = st.srcs.0[p];
                    // SAFETY: env and statics are live within this call.
                    let data = unsafe { std::slice::from_raw_parts(ptr, len) }.to_vec();
                    pend.push(Pending::Owned(Tensor::new(&self.shapes[p], data)));
                }
                _ => {
                    let slot = mp.out[p].expect("planned instruction output");
                    pend.push(Pending::Slot { off: slot.off, len: slot.len });
                }
            }
        }
        // moving `st` into the lease moves the Vec header, not the heap
        // buffer, so the slot offsets recorded above stay valid
        let plan = self;
        let lease = Arc::new(RunLease { state: Some(st), plan: plan.clone() });
        pend.into_iter()
            .zip(&plan.root_pos)
            .map(|(pd, &p)| match pd {
                Pending::Owned(t) => PlanOutput::from(t),
                Pending::Slot { off, len } => PlanOutput {
                    shape: plan.shapes[p].clone(),
                    repr: OutRepr::View { lease: lease.clone(), off, len },
                },
            })
            .collect()
    }

    /// The shared body of [`run_planned`](Self::run_planned) and
    /// [`run_leased`](Self::run_leased): check out a run state, resolve
    /// every instruction's value source, execute all levels, and hand the
    /// state (holding the results in its arena) back to the caller.
    fn exec_planned_state(&self, env: &Env) -> RunState {
        let mp = self.memplan.as_ref().expect("planned plan carries a memory plan");
        let mut st = self.run_states.lock().unwrap().pop().unwrap_or_default();
        if st.arena.len() < mp.arena_len {
            self.arena_allocs.fetch_add(1, Ordering::Relaxed);
            st.arena.resize(mp.arena_len, 0.0);
        }

        // resolve every instruction's value source up front: env lookups
        // and shape checks happen once per run, on the calling thread
        let base = st.arena.as_mut_ptr();
        st.srcs.0.clear();
        for (i, instr) in self.instrs.iter().enumerate() {
            let entry = match instr {
                Instr::Var { name, shape } => {
                    let t = env
                        .get(name)
                        .unwrap_or_else(|| panic!("unbound variable {}", name));
                    assert_eq!(
                        t.shape(),
                        &shape[..],
                        "variable {} bound with wrong shape",
                        name
                    );
                    (t.data().as_ptr(), t.len())
                }
                Instr::Static(s) => {
                    let t = &self.statics[*s];
                    (t.data().as_ptr(), t.len())
                }
                _ => {
                    let slot = mp.out[i].expect("planned instruction output");
                    // SAFETY: in-bounds by construction (checked against
                    // arena_len by the planner's validator)
                    (unsafe { base.add(slot.off) } as *const f64, slot.len)
                }
            };
            st.srcs.0.push(entry);
        }
        let ex = ArenaExec { base, srcs: &st.srcs.0 };

        for (lv, level) in self.levels.iter().enumerate() {
            if let Some((nt, chunk)) = self.level_fork(lv, level.len()) {
                let cursor = AtomicUsize::new(0);
                let ex_ref = &ex;
                let cursor_ref = &cursor;
                worker_pool().scope(nt, move |_| loop {
                    let start = cursor_ref.fetch_add(chunk, Ordering::Relaxed);
                    if start >= level.len() {
                        break;
                    }
                    let end = (start + chunk).min(level.len());
                    for &p in &level[start..end] {
                        self.exec_node_planned(p, ex_ref);
                    }
                });
            } else {
                for &p in level {
                    self.exec_node_planned(p, &ex);
                }
            }
        }
        drop(ex);
        st
    }

    /// Pooled-memory execution (the PR 1 ablation baseline): buffers
    /// from the mutex-guarded pool, recycled at their last-use level.
    fn run_pooled(&self, env: &Env) -> Vec<Tensor> {
        let n = self.instrs.len();
        let mut values: Vec<Option<Val>> = Vec::with_capacity(n);
        values.resize_with(n, || None);
        let mut scratch = self.scratches.lock().unwrap().pop().unwrap_or_default();

        for (lv, level) in self.levels.iter().enumerate() {
            if let Some((nt, chunk)) = self.level_fork(lv, level.len()) {
                // Work stealing: workers claim chunks of the level from
                // a shared cursor, so one oversized node delays only the
                // thread that claimed it — not a whole static band.
                let results: Vec<Mutex<Option<Val>>> =
                    level.iter().map(|_| Mutex::new(None)).collect();
                let cursor = AtomicUsize::new(0);
                {
                    let values_ref = &values;
                    let results_ref = &results;
                    let cursor_ref = &cursor;
                    worker_pool().scope(nt, move |_| {
                        let mut band_scratch =
                            self.scratches.lock().unwrap().pop().unwrap_or_default();
                        loop {
                            let start = cursor_ref.fetch_add(chunk, Ordering::Relaxed);
                            if start >= level.len() {
                                break;
                            }
                            let end = (start + chunk).min(level.len());
                            for k in start..end {
                                let v = self.exec_node(
                                    level[k],
                                    values_ref,
                                    env,
                                    &mut band_scratch,
                                );
                                *results_ref[k].lock().unwrap() = Some(v);
                            }
                        }
                        self.scratches.lock().unwrap().push(band_scratch);
                    });
                }
                for (r, &p) in results.into_iter().zip(level) {
                    values[p] = r.into_inner().unwrap();
                }
            } else {
                for &p in level {
                    let v = self.exec_node(p, &values, env, &mut scratch);
                    values[p] = Some(v);
                }
            }
            // recycle buffers whose last consumer ran in this level
            // (one pool lock per level, not per buffer)
            if !self.free_at_level[lv].is_empty() {
                let mut pool = self.lock_pool();
                for &p in &self.free_at_level[lv] {
                    if let Some(Val::Owned(t)) = values[p].take() {
                        pool.release(t.into_data());
                    }
                }
            }
        }
        self.scratches.lock().unwrap().push(scratch);

        let mut out = Vec::with_capacity(self.root_pos.len());
        for i in 0..self.root_pos.len() {
            let p = self.root_pos[i];
            let used_again = self.root_pos[i + 1..].contains(&p);
            let t = if used_again {
                values[p].as_ref().expect("root not computed").tensor().clone()
            } else {
                match values[p].take().expect("root not computed") {
                    Val::Owned(t) => t,
                    Val::Ref(t) => t.clone(),
                }
            };
            out.push(t);
        }
        out
    }

    /// Execute one instruction of a planned run: operands and the
    /// destination are fixed arena offsets (or pre-resolved env/static
    /// pointers); nothing here allocates, locks, or touches a `Tensor`.
    fn exec_node_planned(&self, p: usize, ex: &ArenaExec<'_>) {
        let mp = self.memplan.as_ref().expect("planned plan carries a memory plan");
        let instr = &self.instrs[p];
        let slot = match instr {
            Instr::Var { .. } | Instr::Static(_) => return, // resolved up front
            _ => mp.out[p].expect("planned instruction output"),
        };
        // SAFETY: this instruction is the sole writer of `slot` in its
        // level, and no concurrently live buffer overlaps it (planner
        // invariant, re-checked by validate_memory_plan / debug builds).
        let out: &mut [f64] = unsafe { slot_mut(ex, slot) };
        match instr {
            Instr::Var { .. } | Instr::Static(_) => unreachable!(),
            Instr::Add(a, b) => match self.inplace_arg[p] {
                // out aliases operand a: its values are already in place
                Some(0) => {
                    for (o, &y) in out.iter_mut().zip(src_slice(ex, *b)) {
                        *o += y;
                    }
                }
                // out aliases operand b
                Some(_) => {
                    for (o, &x) in out.iter_mut().zip(src_slice(ex, *a)) {
                        *o += x;
                    }
                }
                None => {
                    let ta = src_slice(ex, *a);
                    let tb = src_slice(ex, *b);
                    for ((o, &x), &y) in out.iter_mut().zip(ta).zip(tb) {
                        *o = x + y;
                    }
                }
            },
            Instr::Elem(f, a) => match self.inplace_arg[p] {
                Some(_) => {
                    for o in out.iter_mut() {
                        *o = f.apply(*o);
                    }
                }
                None => {
                    for (o, &x) in out.iter_mut().zip(src_slice(ex, *a)) {
                        *o = f.apply(x);
                    }
                }
            },
            Instr::Mul(a, b, plan, epi) => {
                let ta = src_slice(ex, *a);
                let tb = src_slice(ex, *b);
                let scr = mp.scratch[p].expect("contraction scratch planned");
                // SAFETY: scratch slots are exclusive to this instruction
                // for the duration of its level (planner invariant).
                let (sa, sb, sc) = unsafe {
                    (slot_mut(ex, scr[0]), slot_mut(ex, scr[1]), slot_mut(ex, scr[2]))
                };
                IDX_SCRATCH.with(|idx_cell| {
                    let mut guard = idx_cell.borrow_mut();
                    let idx: &mut Vec<usize> = &mut guard;
                    match epi {
                        None => plan.run_planned(ta, tb, out, sa, sb, sc, idx, &NoEpilogue),
                        Some(e) => {
                            let srcs = fused_srcs_planned(&e.args, ex, out.len());
                            let rest = &srcs[..e.args.len()];
                            match self.epilogue_mode {
                                EpilogueMode::InTile => {
                                    let tile_epi = EpiFn(|base: usize, seg: &mut [f64]| {
                                        e.kernel.run_inplace_at(seg, base, rest)
                                    });
                                    plan.run_planned(ta, tb, out, sa, sb, sc, idx, &tile_epi);
                                }
                                EpilogueMode::TwoPass => {
                                    plan.run_planned(
                                        ta,
                                        tb,
                                        out,
                                        sa,
                                        sb,
                                        sc,
                                        idx,
                                        &NoEpilogue,
                                    );
                                    e.kernel.run_inplace(out, rest);
                                }
                            }
                        }
                    }
                });
            }
            Instr::GenUnary(f, a, epi) => {
                let ta = src_slice(ex, *a);
                let last_dim = *self.shapes[*a].last().expect("GenFn needs rank ≥ 1");
                gen_unary_into(*f, ta, last_dim, out);
                if let Some(e) = epi {
                    let srcs = fused_srcs_planned(&e.args, ex, out.len());
                    e.kernel.run_inplace(out, &srcs[..e.args.len()]);
                }
            }
            Instr::Fused { kernel, args } => match self.inplace_arg[p] {
                Some(arg) => {
                    // slot `arg` aliases the output; resolve the others
                    let srcs = fused_srcs_planned_except(args, ex, out.len(), arg);
                    kernel.run_inplace_arg(out, arg as u32, &srcs[..args.len()]);
                }
                None => {
                    let srcs = fused_srcs_planned(args, ex, out.len());
                    kernel.run(&srcs[..args.len()], out);
                }
            },
        }
    }

    fn exec_node<'a>(
        &'a self,
        p: usize,
        values: &[Option<Val<'a>>],
        env: &'a Env,
        scratch: &mut EinScratch,
    ) -> Val<'a> {
        let shape = &self.shapes[p];
        match &self.instrs[p] {
            Instr::Var { name, shape } => {
                let t = env
                    .get(name)
                    .unwrap_or_else(|| panic!("unbound variable {}", name));
                assert_eq!(
                    t.shape(),
                    &shape[..],
                    "variable {} bound with wrong shape",
                    name
                );
                Val::Ref(t)
            }
            Instr::Static(i) => Val::Ref(&self.statics[*i]),
            Instr::Add(a, b) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let tb = values[*b].as_ref().expect("operand not computed").tensor();
                let mut buf = self.lock_pool().acquire(ta.len());
                for ((o, &x), &y) in buf.iter_mut().zip(ta.data()).zip(tb.data()) {
                    *o = x + y;
                }
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::Mul(a, b, plan, epi) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let tb = values[*b].as_ref().expect("operand not computed").tensor();
                let out_len: usize = shape.iter().product();
                let buf = self.lock_pool().acquire(out_len);
                let mut out = Tensor::new(shape, buf);
                match epi {
                    None => plan.run(ta, tb, &mut out, scratch),
                    Some(e) => {
                        let srcs = fused_srcs(&e.args, values, out_len);
                        let rest = &srcs[..e.args.len()];
                        match self.epilogue_mode {
                            EpilogueMode::InTile => {
                                // the fused chain runs on each output
                                // tile right after its final
                                // k-accumulation, cache-hot
                                let tile_epi = EpiFn(|base: usize, seg: &mut [f64]| {
                                    e.kernel.run_inplace_at(seg, base, rest)
                                });
                                plan.run_with_epilogue_in_tile(ta, tb, &mut out, scratch, &tile_epi);
                            }
                            EpilogueMode::TwoPass => {
                                plan.run_with_epilogue(ta, tb, &mut out, scratch, |data| {
                                    e.kernel.run_inplace(data, rest)
                                });
                            }
                        }
                    }
                }
                Val::Owned(out)
            }
            Instr::Elem(f, a) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let mut buf = self.lock_pool().acquire(ta.len());
                for (o, &x) in buf.iter_mut().zip(ta.data()) {
                    *o = f.apply(x);
                }
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::GenUnary(f, a, epi) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let out_len: usize = shape.iter().product();
                let mut buf = self.lock_pool().acquire(out_len);
                let last_dim = *ta.shape().last().expect("GenFn needs rank ≥ 1");
                gen_unary_into(*f, ta.data(), last_dim, &mut buf);
                if let Some(e) = epi {
                    let srcs = fused_srcs(&e.args, values, out_len);
                    e.kernel.run_inplace(&mut buf, &srcs[..e.args.len()]);
                }
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::Fused { kernel, args } => {
                let out_len: usize = shape.iter().product();
                let srcs = fused_srcs(args, values, out_len);
                let mut buf = self.lock_pool().acquire(out_len);
                kernel.run(&srcs[..args.len()], &mut buf);
                Val::Owned(Tensor::new(shape, buf))
            }
        }
    }
}

/// Resolve fused-kernel operand slots against computed values: operands
/// matching the output length stream per element, rank-0 operands
/// broadcast. (Group construction guarantees every slot is one of the
/// two.)
///
/// Returns a fixed-size stack array — the group builder caps kernels at
/// [`FUSED_MAX_ARGS`] operand slots, so resolution costs zero heap
/// allocations and the executor's steady-state hot path is strictly
/// alloc-free (callers slice the array to `args.len()`).
fn fused_srcs<'v>(
    args: &[usize],
    values: &'v [Option<Val<'_>>],
    out_len: usize,
) -> [FusedSrc<'v>; FUSED_MAX_ARGS] {
    debug_assert!(args.len() <= FUSED_MAX_ARGS, "group builder must cap operand slots");
    let mut srcs = [FusedSrc::Scalar(0.0); FUSED_MAX_ARGS];
    for (slot, &q) in args.iter().enumerate() {
        let t = values[q].as_ref().expect("operand not computed").tensor();
        srcs[slot] = if t.len() == out_len {
            FusedSrc::Slice(t.data())
        } else {
            FusedSrc::Scalar(t.data()[0])
        };
    }
    srcs
}

/// [`fused_srcs`] for the planned path: operand slots resolve through
/// the run's source table instead of `Val`s. Same contract, same
/// fixed-size zero-allocation array.
fn fused_srcs_planned<'r>(
    args: &[usize],
    ex: &ArenaExec<'r>,
    out_len: usize,
) -> [FusedSrc<'r>; FUSED_MAX_ARGS] {
    debug_assert!(args.len() <= FUSED_MAX_ARGS, "group builder must cap operand slots");
    let mut srcs = [FusedSrc::Scalar(0.0); FUSED_MAX_ARGS];
    for (slot, &q) in args.iter().enumerate() {
        let s = src_slice(ex, q);
        srcs[slot] = if s.len() == out_len {
            FusedSrc::Slice(s)
        } else {
            FusedSrc::Scalar(s[0])
        };
    }
    srcs
}

/// [`fused_srcs_planned`] minus the slot that aliases the output of an
/// in-place fused instruction: that operand's bytes *are* the output
/// buffer, so no shared slice to it may exist — the kernel reads it as
/// the carrier instead ([`FusedKernel::run_inplace_arg`]).
fn fused_srcs_planned_except<'r>(
    args: &[usize],
    ex: &ArenaExec<'r>,
    out_len: usize,
    skip: usize,
) -> [FusedSrc<'r>; FUSED_MAX_ARGS] {
    debug_assert!(args.len() <= FUSED_MAX_ARGS, "group builder must cap operand slots");
    let mut srcs = [FusedSrc::Scalar(0.0); FUSED_MAX_ARGS];
    for (slot, &q) in args.iter().enumerate() {
        if slot == skip {
            continue; // dummy: Load(skip) reads the carrier value
        }
        let s = src_slice(ex, q);
        srcs[slot] = if s.len() == out_len {
            FusedSrc::Slice(s)
        } else {
            FusedSrc::Scalar(s[0])
        };
    }
    srcs
}

/// Operand positions of one instruction (epilogue arguments included).
fn operands(instr: &Instr) -> Vec<usize> {
    let mut v = match instr {
        Instr::Add(a, b) | Instr::Mul(a, b, _, _) => vec![*a, *b],
        Instr::Elem(_, a) | Instr::GenUnary(_, a, _) => vec![*a],
        Instr::Fused { args, .. } => args.clone(),
        Instr::Var { .. } | Instr::Static(_) => Vec::new(),
    };
    match instr {
        Instr::Mul(_, _, _, Some(e)) | Instr::GenUnary(_, _, Some(e)) => v.extend(&e.args),
        _ => {}
    }
    v
}

/// Write-into evaluation of the general unary functions (mirrors
/// [`GenFn::eval`] but targets a raw buffer — pooled or arena-planned).
/// `n` is the operand's trailing dimension; rank-0 inputs are rejected
/// at compile time.
fn gen_unary_into(f: GenFn, data: &[f64], n: usize, out: &mut [f64]) {
    match f {
        GenFn::Softmax => {
            out.copy_from_slice(data);
            for row in out.chunks_mut(n) {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut z = 0.0;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    z += *v;
                }
                for v in row.iter_mut() {
                    *v /= z;
                }
            }
        }
        GenFn::LogSumExp => {
            for (o, row) in out.iter_mut().zip(data.chunks(n)) {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                *o = m + row.iter().map(|v| (v - m).exp()).sum::<f64>().ln();
            }
        }
    }
}

/// Fingerprint of a graph: hashes every node (op + shape) in id order.
/// See the module docs for the key contract this participates in.
pub fn graph_fingerprint(g: &Graph) -> u64 {
    let mut h = DefaultHasher::new();
    g.len().hash(&mut h);
    for node in g.nodes() {
        node.hash(&mut h);
    }
    h.finish()
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    fingerprint: u64,
    roots: Vec<u32>,
    /// plans compiled under different memory disciplines are distinct
    /// artifacts (offsets vs pool), so the key separates them
    memory: ExecMemory,
}

/// Memoised compiled plans keyed by `(graph fingerprint, roots)` — the
/// coordinator's repeated-request hot path compiles each entry once and
/// shares it (plan + warm buffer pool) across workers.
#[derive(Default)]
pub struct PlanCache {
    /// canonical plans, keyed by the fingerprint of the graph actually
    /// compiled (the optimized + compacted graph unless `OptLevel::None`)
    map: Mutex<HashMap<PlanKey, Arc<CompiledPlan>>>,
    /// fast path: `(raw input fingerprint, roots, level)` → plan, so a
    /// repeated request skips the optimizer entirely — only first-time
    /// graphs pay for canonicalization
    by_input: Mutex<HashMap<(PlanKey, OptLevel), Arc<CompiledPlan>>>,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::default()
    }

    /// Fetch the compiled plan for `(g, roots)` at the default optimizer
    /// level, compiling on first use.
    pub fn get_or_compile(&self, g: &Graph, roots: &[NodeId]) -> Arc<CompiledPlan> {
        self.get_or_compile_with(g, roots, OptLevel::default())
    }

    /// Fetch the compiled plan for `(g, roots)` with an explicit
    /// optimizer level (default memory discipline). See
    /// [`PlanCache::get_or_compile_opts`].
    pub fn get_or_compile_with(
        &self,
        g: &Graph,
        roots: &[NodeId],
        level: OptLevel,
    ) -> Arc<CompiledPlan> {
        self.get_or_compile_opts(g, roots, level, ExecMemory::default())
    }

    /// Fetch the compiled plan for `(g, roots)` with an explicit
    /// optimizer level and memory discipline. For `OptLevel::None` the
    /// graph is fingerprinted and compiled exactly as given (the pre-PR 3
    /// behaviour, kept as the ablation escape hatch); otherwise the graph
    /// is optimized and dead-node-swept first and the *optimized,
    /// compacted* graph is what the key fingerprints — so
    /// differently-built but equivalent graphs converge on one cached
    /// plan (one warm arena set or buffer pool). Plans compiled under
    /// different [`ExecMemory`] modes are cached separately.
    pub fn get_or_compile_opts(
        &self,
        g: &Graph,
        roots: &[NodeId],
        level: OptLevel,
        memory: ExecMemory,
    ) -> Arc<CompiledPlan> {
        let input_key = PlanKey {
            fingerprint: graph_fingerprint(g),
            roots: roots.iter().map(|r| r.0).collect(),
            memory,
        };
        if level == OptLevel::None {
            let mut map = self.map.lock().unwrap();
            if let Some(plan) = map.get(&input_key) {
                return plan.clone();
            }
            let plan = Arc::new(CompiledPlan::with_options(
                g,
                roots,
                true,
                EpilogueMode::default(),
                memory,
            ));
            map.insert(input_key, plan.clone());
            return plan;
        }
        // fast path: this exact graph was optimized before — one hash
        // pass of the raw graph, no clone, no optimizer
        let input_key = (input_key, level);
        if let Some(plan) = self.by_input.lock().unwrap().get(&input_key) {
            return plan.clone();
        }
        let mut g2 = g.clone();
        let o = crate::opt::optimize(&mut g2, roots, level);
        let (gc, croots) = crate::opt::compact(&g2, &o.roots);
        let canon_key = PlanKey {
            fingerprint: graph_fingerprint(&gc),
            roots: croots.iter().map(|r| r.0).collect(),
            memory,
        };
        let plan = {
            let mut map = self.map.lock().unwrap();
            if let Some(plan) = map.get(&canon_key) {
                plan.clone()
            } else {
                let plan = Arc::new(CompiledPlan::with_options(
                    &gc,
                    &croots,
                    true,
                    EpilogueMode::default(),
                    memory,
                ));
                map.insert(canon_key, plan.clone());
                plan
            }
        };
        self.by_input.lock().unwrap().insert(input_key, plan.clone());
        plan
    }

    /// Number of cached plans (distinct compiled artifacts, not raw-graph
    /// aliases).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide plan cache used by the coordinator.
pub fn global_plan_cache() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(PlanCache::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Plan;
    use crate::ir::Elem;

    fn expr1() -> (Graph, NodeId, Env) {
        // Xᵀ((exp(Xw)+1)⁻¹ ⊙ exp(Xw)) — paper Expression (1)
        let mut g = Graph::new();
        let x = g.var("X", &[4, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[4]);
        let e1 = g.add(e, one);
        let inv = g.elem(Elem::Recip, e1);
        let prod = g.hadamard(inv, e);
        let y = g.tmatvec(x, prod);
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[4, 3], 1));
        env.insert("w", Tensor::randn(&[3], 2));
        (g, y, env)
    }

    #[test]
    fn compiled_matches_interpreter_on_expression1() {
        let (g, y, env) = expr1();
        let compiled = CompiledPlan::new(&g, &[y]);
        let interp = Plan::new(&g, &[y]);
        let a = compiled.run(&env);
        let b = interp.run(&g, &env);
        assert!(a[0].allclose(&b[0], 1e-12, 1e-14), "diff {}", a[0].max_abs_diff(&b[0]));
    }

    #[test]
    fn leased_run_matches_owned_and_recycles_state() {
        let (g, y, env) = expr1();
        let plan = Arc::new(CompiledPlan::new(&g, &[y]));
        let owned = plan.run(&env);
        let leased = plan.clone().run_leased(&env);
        assert_eq!(leased.len(), owned.len());
        for (l, o) in leased.iter().zip(&owned) {
            assert_eq!(l.shape(), o.shape());
            assert_eq!(l.data(), o.data(), "leased view diverged from owned run");
        }
        drop(leased);
        // a dropped lease returns its run state: later runs must not
        // grow fresh arenas
        let a0 = plan.pool_stats().arena_allocs;
        for _ in 0..4 {
            drop(plan.clone().run_leased(&env));
        }
        assert_eq!(
            plan.pool_stats().arena_allocs,
            a0,
            "dropped leases must recycle their run state"
        );
    }

    #[test]
    fn leased_var_root_outlives_env() {
        // a root that *is* a variable borrows the env — the lease path
        // must deep-copy it so the output survives the env
        let mut g = Graph::new();
        let x = g.var("x", &[4]);
        let e = g.elem(Elem::Exp, x);
        let plan = Arc::new(CompiledPlan::new(&g, &[x, e]));
        let xt = Tensor::randn(&[4], 9);
        let out = {
            let mut env = Env::new();
            env.insert("x", xt.clone());
            plan.clone().run_leased(&env)
        };
        assert_eq!(out[0].data(), xt.data());
        assert_eq!(out[1].shape(), &[4]);
    }

    #[test]
    fn batch_slices_of_leased_outputs_share_one_lease() {
        let (g, y, _) = expr1();
        let (bg, broots) = batch_graph(&g, &[y], 2);
        let plan = global_plan_cache().get_or_compile_opts(
            &bg,
            &broots,
            OptLevel::None,
            ExecMemory::Planned,
        );
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[2, 4, 3], 1));
        env.insert("w", Tensor::randn(&[2, 3], 2));
        let out = plan.run_leased(&env);
        let full = out[0].to_tensor();
        let (a, b) = (out[0].batch_slice(0, 2), out[0].batch_slice(1, 2));
        drop(out); // slices alone must keep the lease alive
        assert_eq!(a.data(), &full.data()[..3]);
        assert_eq!(b.data(), &full.data()[3..]);
    }

    #[test]
    fn expression1_fuses_chain_and_epilogue() {
        let (g, y, env) = expr1();
        let fused = CompiledPlan::new(&g, &[y]);
        let unfused = CompiledPlan::with_fusion(&g, &[y], false);
        assert!(fused.len() < unfused.len(), "fusion must shrink the stream");
        assert!(fused.fused_count() >= 1, "expression 1 has a fusable chain");
        let a = fused.run(&env);
        let b = unfused.run(&env);
        assert_eq!(a[0].data(), b[0].data(), "fusion changed the numerics");
    }

    #[test]
    fn deep_chain_fuses_to_single_instruction() {
        let mut g = Graph::new();
        let x = g.var("x", &[8]);
        let mut v = x;
        for _ in 0..6 {
            v = g.elem(Elem::Tanh, v);
            v = g.scale(v, 0.5);
        }
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[8], 5));
        let plan = CompiledPlan::new(&g, &[v]);
        // stream: Var x, the shared 0.5 Static, one Fused pipeline
        assert_eq!(plan.fused_count(), 1);
        assert_eq!(plan.len(), 3);
        let unfused = CompiledPlan::with_fusion(&g, &[v], false);
        let a = plan.run(&env);
        let b = unfused.run(&env);
        assert_eq!(a[0].data(), b[0].data());
    }

    #[test]
    fn epilogue_modes_are_bit_identical() {
        let (g, y, env) = expr1();
        let in_tile = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::InTile,
            ExecMemory::default(),
        );
        let two_pass = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::TwoPass,
            ExecMemory::default(),
        );
        assert!(in_tile.fused_count() >= 1, "expression 1 must produce an epilogue");
        let a = in_tile.run(&env);
        let b = two_pass.run(&env);
        assert_eq!(
            a[0].data(),
            b[0].data(),
            "in-tile epilogue must be bit-identical to the two-pass reference"
        );
    }

    #[test]
    #[should_panic(expected = "rank ≥ 1")]
    fn rank0_gen_unary_rejected_at_compile_time() {
        let mut g = Graph::new();
        let x = g.var("x", &[]);
        let s = g.gen_unary(GenFn::Softmax, x);
        let _ = CompiledPlan::new(&g, &[s]);
    }

    #[test]
    fn pool_warm_after_first_run() {
        let (g, y, env) = expr1();
        let plan = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::default(),
            ExecMemory::Pooled,
        );
        let first = plan.run(&env);
        let cold = plan.pool_stats();
        for _ in 0..5 {
            let again = plan.run(&env);
            assert_eq!(again[0].data(), first[0].data());
        }
        let warm = plan.pool_stats();
        // Root buffers leave the pool each run, so one fresh alloc per
        // run for the root is expected; intermediates must all be reused.
        let runs = 5;
        assert!(
            warm.fresh <= cold.fresh + runs,
            "pool still allocating after warm-up: {:?} -> {:?}",
            cold,
            warm
        );
        assert!(warm.reused > cold.reused, "pool never reused a buffer");
    }

    #[test]
    fn planned_matches_pooled_and_takes_no_pool_lock() {
        let (g, y, env) = expr1();
        let planned = CompiledPlan::new(&g, &[y]);
        assert_eq!(planned.memory(), ExecMemory::Planned);
        planned.validate_memory_plan();
        let pooled = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::default(),
            ExecMemory::Pooled,
        );
        let a = planned.run(&env);
        let b = pooled.run(&env);
        assert_eq!(a[0].data(), b[0].data(), "memory modes must be bit-identical");
        // warm-up done: further runs must not grow the arena, touch the
        // pool, or acquire its mutex
        let cold = planned.pool_stats();
        assert!(cold.arena_bytes > 0, "expression 1 has intermediates to plan");
        for _ in 0..5 {
            let again = planned.run(&env);
            assert_eq!(again[0].data(), a[0].data());
        }
        let warm = planned.pool_stats();
        assert_eq!(warm.arena_allocs, cold.arena_allocs, "arena grew after warm-up");
        assert_eq!(warm.pool_locks, 0, "planned mode must not touch the pool mutex");
        assert_eq!(warm.fresh, 0);
        assert_eq!(warm.reused, 0);
    }

    #[test]
    fn duplicate_roots_are_returned_twice() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let e = g.elem(Elem::Exp, x);
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[3], 3));
        let plan = CompiledPlan::new(&g, &[e, e, x]);
        let vals = plan.run(&env);
        assert_eq!(vals.len(), 3);
        assert_eq!(vals[0], vals[1]);
        assert_eq!(vals[2], *env.get("x").unwrap());
    }

    #[test]
    #[should_panic(expected = "unbound variable")]
    fn unbound_variable_panics_compiled() {
        let mut g = Graph::new();
        let x = g.var("x", &[2]);
        CompiledPlan::new(&g, &[x]).run(&Env::new());
    }

    #[test]
    fn statics_are_precomputed_and_shared() {
        let mut g = Graph::new();
        let d = g.delta(&[3]);
        let c = g.constant(2.5, &[3, 3]);
        let s = g.hadamard(d, c);
        let plan = CompiledPlan::new(&g, &[s]);
        let vals = plan.run(&Env::new());
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 2.5 } else { 0.0 };
                assert_eq!(vals[0].at(&[i, j]), want);
            }
        }
    }

    #[test]
    fn plan_cache_hits_on_identical_graphs() {
        let cache = PlanCache::new();
        let (g, y, _) = expr1();
        let p1 = cache.get_or_compile(&g, &[y]);
        let p2 = cache.get_or_compile(&g, &[y]);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.len(), 1);
        // a structurally identical but separately built graph hits too
        let (g2, y2, _) = expr1();
        let p3 = cache.get_or_compile(&g2, &[y2]);
        assert!(Arc::ptr_eq(&p1, &p3));
        // different roots miss
        let _ = cache.get_or_compile(&g, &[y, y]);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn plan_cache_canonicalizes_equivalent_graphs() {
        // the same contraction written with different labels / operand
        // order must converge on ONE cached plan via the optimizer...
        let build = |swap: bool| {
            let mut g = Graph::new();
            let a = g.var("A", &[4, 5]);
            let x = g.var("x", &[5]);
            let m = if swap {
                g.mul(x, a, EinSpec::parse("j,ij->i"))
            } else {
                g.mul(a, x, EinSpec::new(vec![30, 31], vec![31], vec![30]))
            };
            (g, m)
        };
        let cache = PlanCache::new();
        let (g1, r1) = build(false);
        let (g2, r2) = build(true);
        let p1 = cache.get_or_compile(&g1, &[r1]);
        let p2 = cache.get_or_compile(&g2, &[r2]);
        assert!(Arc::ptr_eq(&p1, &p2), "canonicalisation must unify equivalent graphs");
        assert_eq!(cache.len(), 1);
        // ...while the OptLevel::None escape hatch keeps them distinct
        let p3 = cache.get_or_compile_with(&g1, &[r1], OptLevel::None);
        let p4 = cache.get_or_compile_with(&g2, &[r2], OptLevel::None);
        assert!(!Arc::ptr_eq(&p3, &p4));
        assert_eq!(cache.len(), 3);
        // and both lowerings agree numerically
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[4, 5], 1));
        env.insert("x", Tensor::randn(&[5], 2));
        let a = p1.run(&env);
        let b = p3.run(&env);
        assert!(a[0].allclose(&b[0], 1e-12, 1e-13));
    }

    #[test]
    fn wide_add_tree_splits_at_operand_cap() {
        // 24 distinct leaves exceed FUSED_MAX_ARGS: the builder must
        // split the chain into several kernels, bit-identically
        let mut g = Graph::new();
        let vars: Vec<NodeId> = (0..24).map(|i| g.var(&format!("x{}", i), &[32])).collect();
        let mut v = vars[0];
        for &x in &vars[1..] {
            v = g.add(v, x);
        }
        let mut env = Env::new();
        for (i, _) in vars.iter().enumerate() {
            env.insert(&format!("x{}", i), Tensor::randn(&[32], 50 + i as u64));
        }
        let fused = CompiledPlan::new(&g, &[v]);
        let unfused = CompiledPlan::with_fusion(&g, &[v], false);
        assert!(fused.len() < unfused.len(), "the chain must still fuse partially");
        let a = fused.run(&env);
        let b = unfused.run(&env);
        assert_eq!(a[0].data(), b[0].data(), "splitting must not change the association");
        let want = Plan::new(&g, &[v]).run(&g, &env);
        assert!(a[0].allclose(&want[0], 1e-12, 1e-13));
    }

    #[test]
    fn fingerprint_distinguishes_shapes() {
        let mut g1 = Graph::new();
        g1.var("x", &[3]);
        let mut g2 = Graph::new();
        g2.var("x", &[4]);
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&g2));
    }

    #[test]
    fn levels_partition_instructions() {
        let (g, y, _) = expr1();
        let plan = CompiledPlan::new(&g, &[y]);
        let total: usize = plan.levels.iter().map(|l| l.len()).sum();
        assert_eq!(total, plan.len());
        assert!(plan.depth() >= 4, "expression 1 has a chain of depth ≥ 4");
    }
}
