//! The compiled execution engine: [`CompiledPlan`] lowers an expression
//! DAG into a dense instruction stream and hands it to an execution
//! [`Backend`], with pre-compiled write-into einsums, cross-node fusion
//! of element-wise chains, and buffer lifetimes compiled to fixed arena
//! offsets (or, as the ablation baseline, pooled buffers).
//!
//! ## Architecture (interpreter = oracle, compiled plan = hot path)
//!
//! The crate keeps **two** executors on purpose:
//!
//! * [`crate::eval::Plan`] — the *interpreter*: simple, allocating, and
//!   independently validated against brute-force and finite-difference
//!   oracles. It is the reference semantics and deliberately stays
//!   un-fused — it is the oracle the fused executor is pinned against.
//! * [`CompiledPlan`] (this module) — the *hot path*: every `Mul` is
//!   pre-compiled into an [`EinsumPlan`](crate::einsum::EinsumPlan)
//!   (strides, pre-sums and permutations resolved at compile time),
//!   constants and δ tensors are materialised once, intermediate buffers
//!   live at planner-assigned fixed offsets of a per-plan arena (the
//!   shape-bucketed [`BufferPool`] survives as the
//!   [`ExecMemory::Pooled`] ablation), and execution is delegated to a
//!   pluggable [`Backend`].
//!
//! `tests/exec_equivalence.rs` pins the two against each other (and
//! against `einsum_naive`) over randomized specs and DAGs, including
//! deep element-wise chains that exercise the fusion pass.
//!
//! ## The backend seam
//!
//! Compilation is split in two layers:
//!
//! 1. **Lowering** (`exec::lower`, backend-neutral): DAG → fused
//!    [`Lowered`] instruction stream, dependency levels with flop
//!    estimates, buffer liveness, and the static arena memory plan —
//!    everything up to but excluding *how* instructions run.
//! 2. **Backend** ([`backend`]): compiles the `Lowered` into an
//!    executable artifact. [`BackendKind::Cpu`] is the work-stealing,
//!    level-parallel executor on the persistent worker pool;
//!    [`BackendKind::Direct`] is a direct-threaded closure chain that
//!    resolves offsets, operands and epilogues at compile time and runs
//!    sequentially in-arena — lowest dispatch overhead for the
//!    small/skinny plans the serving path sees at low batch sizes.
//!
//! All backends are bit-identical on every workload (same stream, same
//! kernels, same accumulation order) and differentially pinned against
//! the interpreter in `tests/backend_equivalence.rs`. The facade in
//! this module owns what every backend shares: run-state checkout,
//! source-table resolution, root extraction, leasing, and the plan
//! cache.
//!
//! ## Fusion pass
//!
//! At compile time, maximal single-consumer chains/trees of `Elem`,
//! `Add`, Hadamard- and scalar-`Mul` nodes collapse into one
//! `FusedKernel`: a tiny postfix program evaluated in a single pass over
//! the data — one output buffer, zero intermediates, regardless of the
//! chain depth. Where the chain rides on the output of a contraction or
//! general unary whose value is not needed elsewhere, the kernel is
//! instead applied *in place* as an epilogue on the producer's buffer,
//! so the whole chain costs no buffer at all. Kernels are capped at
//! `FUSED_MAX_ARGS` operand slots (a chain that would exceed it splits
//! into two kernels), which lets execution resolve operands into a stack
//! array — the hot path performs no heap allocation at all once the pool
//! is warm.
//!
//! ## Epilogue placement ([`EpilogueMode`])
//!
//! A contraction epilogue can run two ways, selected at compile time:
//!
//! * [`EpilogueMode::InTile`] (default) — the kernel is pushed down into
//!   the GEMM tile loop
//!   ([`EinsumPlan::run_with_epilogue_in_tile`](crate::einsum::EinsumPlan::run_with_epilogue_in_tile)):
//!   each output tile is post-processed right after its final
//!   k-accumulation, while it is cache-hot, so the fused chain costs no
//!   extra pass over the output buffer at all.
//! * [`EpilogueMode::TwoPass`] — the pre-tiling behaviour, kept as the
//!   reference and ablation baseline: the contraction finishes, then the
//!   kernel sweeps the whole output buffer once more
//!   ([`EinsumPlan::run_with_epilogue`](crate::einsum::EinsumPlan::run_with_epilogue)).
//!
//! The two are bit-identical (same GEMM accumulation order, same
//! per-element epilogue program); `tests/tile_epilogue.rs` pins them
//! against each other and against the interpreter.
//!
//! ## Memory discipline ([`ExecMemory`])
//!
//! Where an instruction's output lives is a compile-time choice:
//!
//! * [`ExecMemory::Planned`] (default) — the `memplan` pass runs a
//!   liveness analysis over the instruction stream (the same last-use
//!   levels the pooled mode recycles on), builds the interference
//!   intervals of every intermediate and einsum scratch region, and
//!   packs them into fixed offsets of a single per-plan arena
//!   (best-fit, with in-place reuse when a dying input's slot fits the
//!   output). At run time a destination is `&arena[off..off + len]`:
//!   after the arena's first growth, the steady-state hot path performs
//!   **zero** heap allocations and acquires **no** pool mutex — one
//!   run-state checkout per call is the only synchronization.
//! * [`ExecMemory::Pooled`] — the PR 1 executor, kept as the
//!   ablation/reference mode: intermediates come from a shape-bucketed
//!   [`BufferPool`] behind a mutex and are recycled at their last use.
//!   (The direct backend executes in-arena only, so it force-builds the
//!   memory plan even under this mode.)
//!
//! The two modes are bit-identical (same instruction stream, same
//! kernels, same accumulation order); `tests/memory_plan.rs` pins them
//! against each other and against the interpreter, checks the planner's
//! no-overlap invariant, and asserts the steady-state zero-alloc /
//! no-lock counters.
//!
//! ## Plan-cache key contract
//!
//! [`PlanCache`] memoises compiled plans for the coordinator's
//! repeated-request hot path. Unless the caller opts out with
//! [`OptLevel::None`](crate::opt::OptLevel), the graph first runs
//! through the [`crate::opt`] pipeline (global CSE + contraction
//! reassociation) and a dead-node sweep; the key is
//! `(graph fingerprint, root node ids, memory mode, backend, trace
//! mode)` **of the optimized, compacted graph**, where the fingerprint hashes every node
//! **in id order** — operator, einsum spec, constant bits, δ dims *and
//! node shape*. Because `Var` nodes carry their declared shape, the
//! fingerprint covers the input-shape signature, and because the
//! optimizer canonicalises specs and operand orders, differently-built
//! but equivalent graphs converge on the same key; two graphs with equal
//! fingerprints compile to identical instruction streams (modulo 64-bit
//! hash collision). Plans compiled under different [`ExecMemory`] modes,
//! [`BackendKind`]s or [`TraceMode`]s are distinct artifacts and cached
//! separately (an instrumented plan must never be served where the
//! zero-overhead default was requested, and vice versa).
//! The cache never evicts: it is bounded by the number of distinct
//! `(graph, roots)` configurations a process registers, which is the
//! number of distinct service entries. Cached plans are `Arc`-shared,
//! so every worker that serves the same graph also shares one warm set
//! of run arenas (or, under the pooled ablation mode, one warm buffer
//! pool).

pub mod backend;
mod batch;
mod lower;
pub(crate) mod memplan;

pub use backend::cpu::BufferPool;
pub use backend::{Backend, BackendKind};
pub use batch::batch_graph;
pub use lower::Lowered;

use crate::eval::Env;
use crate::ir::{Graph, NodeId};
use crate::obs::{self, TraceMode};
use crate::opt::OptLevel;
use crate::tensor::Tensor;
use backend::ArenaExec;
use lower::Instr;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Memory counters of a [`CompiledPlan`] — the executor's "zero
/// steady-state allocation" invariant is asserted through these, in the
/// units of whichever [`ExecMemory`] mode the plan compiled with.
///
/// Under [`ExecMemory::Pooled`] the meaningful fields are the bucket
/// counters `fresh`/`reused` (and `pool_locks`). Under
/// [`ExecMemory::Planned`] the pool is never touched — those stay zero —
/// and the plan reports its arena instead: `arena_bytes` (the packed
/// footprint), the planner's compile-time `planned_reuse`/`inplace_reuse`
/// packing wins, and `arena_allocs`, the number of run-state arenas that
/// had to grow at run time (one per concurrent caller, then constant —
/// the steady-state zero-allocation assertion in `tests/memory_plan.rs`
/// checks exactly this counter and `pool_locks == 0`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// which discipline the plan compiled with (selects the meaningful
    /// counters, and the `Display` format)
    pub memory: ExecMemory,
    /// pooled mode: buffers allocated anew (cold misses)
    pub fresh: u64,
    /// pooled mode: buffers served from the pool (warm hits)
    pub reused: u64,
    /// planned mode: bytes of one run arena (all intermediates + scratch)
    pub arena_bytes: u64,
    /// planned mode: slots packed into bytes freed by dead buffers
    pub planned_reuse: u64,
    /// planned mode: outputs reusing a dying input's slot in place
    pub inplace_reuse: u64,
    /// planned mode: run-state arenas grown at run time (cold starts)
    pub arena_allocs: u64,
    /// times the buffer-pool mutex was acquired (zero under `Planned`)
    pub pool_locks: u64,
    /// trace sinks allocated at run time (zero under [`TraceMode::Off`];
    /// otherwise one per run state, then constant — the observability
    /// twin of `arena_allocs`)
    pub trace_allocs: u64,
    /// in-arena runs that recycled a warm run state from the lease pool
    /// instead of starting a fresh one
    pub state_reuse: u64,
}

impl fmt::Display for PoolStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.memory {
            ExecMemory::Planned => write!(
                f,
                "arena {:.1} KiB, packed-reuse {}, in-place {}, arena allocs {}, pool locks {}",
                self.arena_bytes as f64 / 1024.0,
                self.planned_reuse,
                self.inplace_reuse,
                self.arena_allocs,
                self.pool_locks
            ),
            ExecMemory::Pooled => write!(
                f,
                "pool fresh {}, reused {}, locks {}",
                self.fresh, self.reused, self.pool_locks
            ),
        }
    }
}

/// Where a plan's intermediates live — the memory-discipline ablation
/// toggle next to [`EpilogueMode`]. See the module docs ("Memory
/// discipline") for the contract; the two modes are bit-identical.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default, Hash)]
pub enum ExecMemory {
    /// Buffer lifetimes compiled to fixed offsets in one per-plan arena
    /// (liveness → interference intervals → best-fit packing, in-place
    /// reuse of dying inputs, einsum scratch planned alongside). The
    /// steady-state hot path allocates nothing and takes no pool mutex.
    /// The default.
    #[default]
    Planned,
    /// The PR 1 executor: a shape-bucketed [`BufferPool`] behind a mutex,
    /// buffers recycled at their last use. Kept as the ablation/reference
    /// mode.
    Pooled,
}

/// Where a contraction's fused epilogue runs — the ablation toggle next
/// to `CompiledPlan::with_fusion`. See the module docs ("Epilogue
/// placement") for the contract; the two modes are bit-identical.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum EpilogueMode {
    /// Inside the GEMM tile loop, while each output tile is cache-hot
    /// (no second sweep over the output buffer). The default.
    #[default]
    InTile,
    /// As a second full sweep over the finished contraction output —
    /// the pre-tiling behaviour, kept as reference/ablation baseline.
    TwoPass,
}

/// Per-run state of an in-arena execution, checked out once per call
/// (one lock) and returned warm: the arena plus the resolved
/// per-instruction source table. A plan keeps one `RunState` per
/// concurrent caller; each grows its arena once and never again.
#[derive(Default)]
struct RunState {
    arena: Vec<f64>,
    srcs: SrcTable,
    /// the span recorder, allocated on the first traced run of this
    /// state and reset (not reallocated) on every run after — `None`
    /// forever under [`TraceMode::Off`]
    trace: Option<Box<obs::TraceSink>>,
}

/// Resolved value source of every instruction for one run: a pointer and
/// element count into the env's tensors, the plan's statics, or the
/// checked-out arena.
#[derive(Default)]
struct SrcTable(Vec<(*const f64, usize)>);

// SAFETY: the raw pointers are inert between runs (rewritten at the
// start of every run) and only dereferenced while the borrows they were
// derived from — env tensors, plan statics, the checked-out arena — are
// live within that run.
unsafe impl Send for SrcTable {}

/// A checked-out run state kept alive past the end of its run so root
/// outputs can be served as views straight out of the arena — the
/// zero-copy response path. Dropping the last reference returns the
/// state (arena and all) to the plan's warm pool.
pub struct RunLease {
    /// `Some` until `Drop` takes it back to `plan.run_states`
    state: Option<RunState>,
    plan: Arc<CompiledPlan>,
}

// SAFETY: the lease only ever *reads* the arena `Vec<f64>` (through
// `PlanOutput::data`), and only after the run that wrote it completed on
// the leasing thread. The contained `SrcTable` pointers are inert while
// leased — they are rewritten at the start of the next run and never
// dereferenced through the lease.
unsafe impl Send for RunLease {}
unsafe impl Sync for RunLease {}

impl Drop for RunLease {
    fn drop(&mut self) {
        if let Some(st) = self.state.take() {
            self.plan.run_states.lock().unwrap().push(st);
        }
    }
}

impl RunLease {
    fn arena(&self) -> &[f64] {
        &self.state.as_ref().expect("lease taken before drop").arena
    }
}

/// A root output of [`CompiledPlan::run_leased`]: either an owned
/// [`Tensor`] or a zero-copy view into a leased run arena. Views borrow
/// nothing from the caller — the `Arc`-owned lease keeps the arena alive
/// — so a `PlanOutput` can cross threads and outlive the `Env` it was
/// computed from. Cloning a view clones the `Arc`, not the data.
#[derive(Clone)]
pub struct PlanOutput {
    shape: Vec<usize>,
    repr: OutRepr,
}

#[derive(Clone)]
enum OutRepr {
    Owned(Tensor),
    View { lease: Arc<RunLease>, off: usize, len: usize },
}

impl PlanOutput {
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// The value, row-major — a borrow of the leased arena for views.
    pub fn data(&self) -> &[f64] {
        match &self.repr {
            OutRepr::Owned(t) => t.data(),
            OutRepr::View { lease, off, len } => &lease.arena()[*off..*off + *len],
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Scalar value; panics unless the output holds exactly one element.
    pub fn item(&self) -> f64 {
        let d = self.data();
        assert_eq!(d.len(), 1, "item() on non-scalar output");
        d[0]
    }

    /// Materialise an owned [`Tensor`] (copies a view's slice; this is
    /// the moment a zero-copy response pays for its bytes).
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(&self.shape, self.data().to_vec())
    }

    /// Element-wise `|a - b| <= atol + rtol * |b|` against a tensor,
    /// shapes included — mirrors [`Tensor::allclose`].
    pub fn allclose(&self, other: &Tensor, rtol: f64, atol: f64) -> bool {
        self.shape() == other.shape()
            && self
                .data()
                .iter()
                .zip(other.data())
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// View of slice `i` of a leading-axis-batched output: the first
    /// axis (which must have size `bucket`) is dropped and the data
    /// narrows to that slice. For a view this is pointer arithmetic on
    /// the shared lease; for an owned tensor it copies the slice.
    pub fn batch_slice(&self, i: usize, bucket: usize) -> PlanOutput {
        assert!(
            self.shape.first() == Some(&bucket) && i < bucket,
            "batch_slice({}, {}) on output of shape {:?}",
            i,
            bucket,
            self.shape
        );
        let inner: Vec<usize> = self.shape[1..].to_vec();
        let len: usize = inner.iter().product();
        let repr = match &self.repr {
            OutRepr::Owned(t) => OutRepr::Owned(Tensor::new(
                &inner,
                t.data()[i * len..(i + 1) * len].to_vec(),
            )),
            OutRepr::View { lease, off, .. } => {
                OutRepr::View { lease: lease.clone(), off: off + i * len, len }
            }
        };
        PlanOutput { shape: inner, repr }
    }
}

impl From<Tensor> for PlanOutput {
    fn from(t: Tensor) -> Self {
        PlanOutput { shape: t.shape().to_vec(), repr: OutRepr::Owned(t) }
    }
}

impl fmt::Debug for PlanOutput {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = match &self.repr {
            OutRepr::Owned(_) => "owned",
            OutRepr::View { .. } => "leased",
        };
        f.debug_struct("PlanOutput")
            .field("shape", &self.shape)
            .field("kind", &kind)
            .finish()
    }
}

/// An expression DAG compiled for repeated execution: the facade over
/// the backend seam. Holds the backend-neutral [`Lowered`] artifact,
/// the [`Backend`] executable compiled from it, and the run-time state
/// every backend shares (warm run states, the arena-growth counter).
/// The facade owns source-table resolution, root extraction and
/// leasing; the backend owns only instruction execution.
pub struct CompiledPlan {
    lowered: Lowered,
    backend: BackendKind,
    exec: Box<dyn Backend>,
    /// warm per-caller run states (arena + source table), in-arena mode
    run_states: Mutex<Vec<RunState>>,
    /// run-state arenas grown at run time (cold starts; then constant)
    arena_allocs: AtomicU64,
    /// trace sinks allocated at run time (always zero under `Off`)
    trace_allocs: AtomicU64,
    /// in-arena runs served by a recycled warm run state
    state_reuse: AtomicU64,
}

impl CompiledPlan {
    /// Compile the sub-DAG of `g` reachable from `roots`.
    pub fn new(g: &Graph, roots: &[NodeId]) -> Self {
        Self::with_options(
            g,
            roots,
            true,
            EpilogueMode::default(),
            ExecMemory::default(),
            BackendKind::default(),
            TraceMode::default(),
        )
    }

    /// Compile with or without the cross-node fusion pass. `false`
    /// reproduces the PR 1 lowering (one buffer per node) and is kept as
    /// the ablation baseline for benches and differential tests.
    pub fn with_fusion(g: &Graph, roots: &[NodeId], fuse: bool) -> Self {
        Self::with_options(
            g,
            roots,
            fuse,
            EpilogueMode::default(),
            ExecMemory::default(),
            BackendKind::default(),
            TraceMode::default(),
        )
    }

    /// Compile for an explicit execution backend, every other toggle at
    /// its default.
    pub fn with_backend(g: &Graph, roots: &[NodeId], backend: BackendKind) -> Self {
        Self::with_options(
            g,
            roots,
            true,
            EpilogueMode::default(),
            ExecMemory::default(),
            backend,
            TraceMode::default(),
        )
    }

    /// Compile with every ablation toggle explicit: the fusion pass
    /// on/off, where contraction epilogues run ([`EpilogueMode`]), where
    /// intermediates live ([`ExecMemory`]), which [`BackendKind`]
    /// executes the stream, and how much the run records ([`TraceMode`]).
    /// Lowering is backend-neutral; the backend only changes *how* the
    /// same instructions run (the direct backend additionally
    /// force-builds the arena plan, since it executes in-arena even
    /// under the pooled ablation mode — and so does any `trace != Off`,
    /// since span recording is wired through the arena executor).
    pub fn with_options(
        g: &Graph,
        roots: &[NodeId],
        fuse: bool,
        epilogue_mode: EpilogueMode,
        memory: ExecMemory,
        backend: BackendKind,
        trace: TraceMode,
    ) -> Self {
        let lowered = lower::lower(
            g,
            roots,
            fuse,
            epilogue_mode,
            memory,
            backend == BackendKind::Direct,
            trace,
        );
        let exec = backend::compile(backend, &lowered);
        CompiledPlan {
            lowered,
            backend,
            exec,
            run_states: Mutex::new(Vec::new()),
            arena_allocs: AtomicU64::new(0),
            trace_allocs: AtomicU64::new(0),
            state_reuse: AtomicU64::new(0),
        }
    }

    /// Number of instructions the plan executes (after fusion this is
    /// smaller than the reachable node count).
    pub fn len(&self) -> usize {
        self.lowered.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lowered.instrs.is_empty()
    }

    /// Number of dependency levels (the critical-path length).
    pub fn depth(&self) -> usize {
        self.lowered.levels.len()
    }

    /// Number of fused pipelines in the stream — standalone `Fused`
    /// instructions plus contraction/unary epilogues.
    pub fn fused_count(&self) -> usize {
        self.lowered
            .instrs
            .iter()
            .filter(|i| {
                matches!(
                    i,
                    Instr::Fused { .. }
                        | Instr::Mul(_, _, _, Some(_))
                        | Instr::GenUnary(_, _, Some(_))
                )
            })
            .count()
    }

    /// Memory counters — pooled bucket hits or planned arena figures,
    /// depending on the compile-time [`ExecMemory`]. After one warm-up
    /// run, repeated executions must not move the allocation counters.
    pub fn pool_stats(&self) -> PoolStats {
        let mut st = PoolStats {
            memory: self.lowered.memory,
            arena_bytes: self
                .lowered
                .memplan
                .as_ref()
                .map_or(0, |mp| (mp.arena_len * std::mem::size_of::<f64>()) as u64),
            planned_reuse: self.lowered.memplan.as_ref().map_or(0, |mp| mp.planned_reuse),
            inplace_reuse: self.lowered.memplan.as_ref().map_or(0, |mp| mp.inplace_reuse),
            arena_allocs: self.arena_allocs.load(Ordering::Relaxed),
            trace_allocs: self.trace_allocs.load(Ordering::Relaxed),
            state_reuse: self.state_reuse.load(Ordering::Relaxed),
            ..PoolStats::default()
        };
        // diagnostic read: the backend merges its own counters (pool
        // hits, lock counts) without perturbing them
        self.exec.fold_stats(&mut st);
        st
    }

    /// The memory discipline this plan compiled with.
    pub fn memory(&self) -> ExecMemory {
        self.lowered.memory
    }

    /// The execution backend this plan compiled for.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// The [`TraceMode`] this plan compiled with.
    pub fn trace_mode(&self) -> TraceMode {
        self.lowered.trace
    }

    /// Number of instructions that actually execute: the stream minus
    /// `Var` bindings and compile-time statics, which never run and are
    /// never traced. This is the span count a Profile-mode trace must
    /// cover exactly once per run.
    pub fn executed_instrs(&self) -> usize {
        self.lowered
            .instrs
            .iter()
            .filter(|i| !matches!(i, Instr::Var { .. } | Instr::Static(_)))
            .count()
    }

    /// The backend-neutral lowering artifact (crate-internal: the
    /// benches and the obs exporters read levels and flop estimates).
    pub(crate) fn lowered(&self) -> &Lowered {
        &self.lowered
    }

    /// Static plan description for the obs exporters: one
    /// [`obs::InstrInfo`] per executed instruction (kernel label, level,
    /// cost-model flops, output bytes).
    pub fn plan_info(&self) -> obs::PlanInfo {
        let lw = &self.lowered;
        let mut level_of = vec![0u32; lw.instrs.len()];
        for (lv, level) in lw.levels.iter().enumerate() {
            for &p in level {
                level_of[p] = lv as u32;
            }
        }
        let instrs = lw
            .instrs
            .iter()
            .enumerate()
            .filter_map(|(p, instr)| {
                let name = match instr {
                    Instr::Var { .. } | Instr::Static(_) => return None,
                    Instr::Add(..) => "add".to_string(),
                    Instr::Mul(_, _, _, None) => "mul".to_string(),
                    Instr::Mul(_, _, _, Some(_)) => "mul+epilogue".to_string(),
                    Instr::Elem(f, _) => format!("elem {}", f.name()),
                    Instr::GenUnary(f, _, None) => format!("gen {}", f.name()),
                    Instr::GenUnary(f, _, Some(_)) => format!("gen {}+epilogue", f.name()),
                    Instr::Fused { kernel, .. } => format!("fused[{}]", kernel.ops.len()),
                };
                Some(obs::InstrInfo {
                    pos: p as u32,
                    name,
                    level: level_of[p],
                    flops: lw.instr_flops[p] as u64,
                    bytes: (lw.shapes[p].iter().product::<usize>() * std::mem::size_of::<f64>())
                        as u64,
                })
            })
            .collect();
        obs::PlanInfo { instrs, levels: lw.levels.len(), backend: self.backend.name() }
    }

    /// Re-verify the memory plan's no-overlap invariant (no two live
    /// intervals share arena bytes). Panics on violation; no-op for
    /// pooled plans. The differential suite calls this on every plan it
    /// builds; compile already asserts it under `debug_assertions`.
    pub fn validate_memory_plan(&self) {
        if let Some(mp) = &self.lowered.memplan {
            mp.check_no_overlap();
        }
    }

    /// Execute the plan against `env`. Panics on unbound or wrongly
    /// shaped variables (same contract as the interpreter). Dispatch is
    /// on the plan's artifact, not the requested mode: any plan carrying
    /// an arena layout runs in-arena (the direct backend does even under
    /// the pooled ablation mode).
    pub fn run(&self, env: &Env) -> Vec<Tensor> {
        if self.lowered.memplan.is_some() {
            self.run_planned(env)
        } else {
            self.exec.run_pooled(&self.lowered, env)
        }
    }

    /// In-arena execution: one run-state checkout (a single lock), then
    /// the backend reads and writes fixed arena offsets. No allocation
    /// after the arena's first growth, no pool mutex.
    fn run_planned(&self, env: &Env) -> Vec<Tensor> {
        let st = self.exec_planned_state(env);
        // materialise the roots (the only per-run allocations: the
        // caller owns the returned tensors)
        let mut out = Vec::with_capacity(self.lowered.root_pos.len());
        for &p in &self.lowered.root_pos {
            let (ptr, len) = st.srcs.0[p];
            // SAFETY: the pointee — env tensor, plan static, or st's own
            // arena — is still live here (env outlives the call, st is
            // owned by this frame).
            let data = unsafe { std::slice::from_raw_parts(ptr, len) }.to_vec();
            out.push(Tensor::new(&self.lowered.shapes[p], data));
        }
        self.run_states.lock().unwrap().push(st);
        out
    }

    /// Execute the plan against `env` and return the roots as
    /// [`PlanOutput`]s: arena-backed zero-copy views under an `Arc`-owned
    /// [`RunLease`] instead of `Tensor` clones — the serving hot path.
    /// The leased run state (arena included) returns to the plan's warm
    /// pool when the last output referencing it drops, so long-held
    /// responses hold their arena with them.
    ///
    /// Roots whose bytes live outside the arena (a root that *is* a
    /// variable or a compiled-in constant) are deep-copied, since the env
    /// they borrow from dies with the call. Plans without an arena (the
    /// CPU backend under pooled mode) fall back to owned outputs
    /// wholesale.
    ///
    /// Takes the `Arc` by value (clone it to keep a handle — an `Arc`
    /// clone, not a plan copy): the lease must own the plan to return
    /// the run state on drop.
    pub fn run_leased(self: Arc<Self>, env: &Env) -> Vec<PlanOutput> {
        if self.lowered.memplan.is_none() {
            return self
                .exec
                .run_pooled(&self.lowered, env)
                .into_iter()
                .map(PlanOutput::from)
                .collect();
        }
        let mp = self.lowered.memplan.as_ref().expect("in-arena plan carries a memory plan");
        let st = self.exec_planned_state(env);
        enum Pending {
            Owned(Tensor),
            Slot { off: usize, len: usize },
        }
        let mut pend = Vec::with_capacity(self.lowered.root_pos.len());
        for &p in &self.lowered.root_pos {
            match &self.lowered.instrs[p] {
                Instr::Var { .. } | Instr::Static(_) => {
                    let (ptr, len) = st.srcs.0[p];
                    // SAFETY: env and statics are live within this call.
                    let data = unsafe { std::slice::from_raw_parts(ptr, len) }.to_vec();
                    pend.push(Pending::Owned(Tensor::new(&self.lowered.shapes[p], data)));
                }
                _ => {
                    let slot = mp.out[p].expect("planned instruction output");
                    pend.push(Pending::Slot { off: slot.off, len: slot.len });
                }
            }
        }
        // moving `st` into the lease moves the Vec header, not the heap
        // buffer, so the slot offsets recorded above stay valid
        let plan = self;
        let lease = Arc::new(RunLease { state: Some(st), plan: plan.clone() });
        pend.into_iter()
            .zip(&plan.lowered.root_pos)
            .map(|(pd, &p)| match pd {
                Pending::Owned(t) => PlanOutput::from(t),
                Pending::Slot { off, len } => PlanOutput {
                    shape: plan.lowered.shapes[p].clone(),
                    repr: OutRepr::View { lease: lease.clone(), off, len },
                },
            })
            .collect()
    }

    /// The shared body of [`run_planned`](Self::run_planned) and
    /// [`run_leased`](Self::run_leased): check out a run state, resolve
    /// every instruction's value source, hand the backend the arena
    /// view to execute, and return the state (holding the results in
    /// its arena) to the caller.
    fn exec_planned_state(&self, env: &Env) -> RunState {
        let mp = self.lowered.memplan.as_ref().expect("in-arena plan carries a memory plan");
        let mut st = match self.run_states.lock().unwrap().pop() {
            Some(st) => {
                self.state_reuse.fetch_add(1, Ordering::Relaxed);
                st
            }
            None => RunState::default(),
        };
        if st.arena.len() < mp.arena_len {
            self.arena_allocs.fetch_add(1, Ordering::Relaxed);
            st.arena.resize(mp.arena_len, 0.0);
        }
        if self.lowered.trace != TraceMode::Off {
            if st.trace.is_none() {
                // capacity: every instruction can span twice per run
                // (instr + epilogue), plus one span per level, plus slack
                let cap = 2 * self.lowered.instrs.len() + self.lowered.levels.len() + 16;
                self.trace_allocs.fetch_add(1, Ordering::Relaxed);
                st.trace = Some(Box::new(obs::TraceSink::new(
                    self.lowered.trace,
                    crate::util::num_threads(),
                    cap,
                )));
            }
            if let Some(t) = st.trace.as_mut() {
                t.reset();
            }
        }

        // resolve every instruction's value source up front: env lookups
        // and shape checks happen once per run, on the calling thread
        let base = st.arena.as_mut_ptr();
        st.srcs.0.clear();
        for (i, instr) in self.lowered.instrs.iter().enumerate() {
            let entry = match instr {
                Instr::Var { name, shape } => {
                    let t = env
                        .get(name)
                        .unwrap_or_else(|| panic!("unbound variable {}", name));
                    assert_eq!(
                        t.shape(),
                        &shape[..],
                        "variable {} bound with wrong shape",
                        name
                    );
                    (t.data().as_ptr(), t.len())
                }
                Instr::Static(s) => {
                    let t = &self.lowered.statics[*s];
                    (t.data().as_ptr(), t.len())
                }
                _ => {
                    let slot = mp.out[i].expect("planned instruction output");
                    // SAFETY: in-bounds by construction (checked against
                    // arena_len by the planner's validator)
                    (unsafe { base.add(slot.off) } as *const f64, slot.len)
                }
            };
            st.srcs.0.push(entry);
        }
        let ex = ArenaExec { base, srcs: &st.srcs.0, trace: st.trace.as_deref() };
        self.exec.exec_arena(&self.lowered, &ex);
        drop(ex);
        st
    }

    /// Execute the plan and return the recorded [`obs::Trace`] alongside
    /// the outputs. On a plan compiled with [`TraceMode::Off`] this is
    /// just [`run`](Self::run) plus an empty trace — the instrumented
    /// path only exists on plans whose cache key asked for it.
    pub fn run_traced(&self, env: &Env) -> (Vec<Tensor>, obs::Trace) {
        if self.lowered.trace == TraceMode::Off {
            return (self.run(env), obs::Trace::default());
        }
        // trace != Off forced an arena at lowering time, so the planned
        // path is the only one that can run here
        let mut st = self.exec_planned_state(env);
        let mut out = Vec::with_capacity(self.lowered.root_pos.len());
        for &p in &self.lowered.root_pos {
            let (ptr, len) = st.srcs.0[p];
            // SAFETY: same as `run_planned` — env, statics, and st's own
            // arena are all live here.
            let data = unsafe { std::slice::from_raw_parts(ptr, len) }.to_vec();
            out.push(Tensor::new(&self.lowered.shapes[p], data));
        }
        let trace = st.trace.as_mut().map(|t| t.drain()).unwrap_or_default();
        self.run_states.lock().unwrap().push(st);
        (out, trace)
    }
}

/// Fingerprint of a graph: hashes every node (op + shape) in id order.
/// See the module docs for the key contract this participates in.
pub fn graph_fingerprint(g: &Graph) -> u64 {
    let mut h = DefaultHasher::new();
    g.len().hash(&mut h);
    for node in g.nodes() {
        node.hash(&mut h);
    }
    h.finish()
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    fingerprint: u64,
    roots: Vec<u32>,
    /// plans compiled under different memory disciplines are distinct
    /// artifacts (offsets vs pool), so the key separates them
    memory: ExecMemory,
    /// likewise for the execution backend: a direct-threaded closure
    /// chain and a level-parallel plan are different compiled artifacts
    backend: BackendKind,
    /// and for the trace mode: an instrumented plan must never be
    /// served where the zero-overhead default was requested
    trace: TraceMode,
}

/// Memoised compiled plans keyed by `(graph fingerprint, roots, memory,
/// backend, trace mode)` — the coordinator's repeated-request hot path compiles
/// each entry once and shares it (plan + warm arenas or buffer pool)
/// across workers.
#[derive(Default)]
pub struct PlanCache {
    /// canonical plans, keyed by the fingerprint of the graph actually
    /// compiled (the optimized + compacted graph unless `OptLevel::None`)
    map: Mutex<HashMap<PlanKey, Arc<CompiledPlan>>>,
    /// fast path: `(raw input key, level)` → plan, so a repeated request
    /// skips the optimizer entirely — only first-time graphs pay for
    /// canonicalization. The raw key carries the full configuration
    /// (memory mode and backend included), so a repeated graph requested
    /// under a different configuration can never be served the other
    /// configuration's plan.
    by_input: Mutex<HashMap<(PlanKey, OptLevel), Arc<CompiledPlan>>>,
    /// lookups that found an existing plan (either table)
    hits: AtomicU64,
    /// lookups that compiled a fresh plan
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::default()
    }

    /// Fetch the compiled plan for `(g, roots)` at the default optimizer
    /// level, compiling on first use.
    pub fn get_or_compile(&self, g: &Graph, roots: &[NodeId]) -> Arc<CompiledPlan> {
        self.get_or_compile_with(g, roots, OptLevel::default())
    }

    /// Fetch the compiled plan for `(g, roots)` with an explicit
    /// optimizer level (default memory discipline and backend). See
    /// [`PlanCache::get_or_compile_opts`].
    pub fn get_or_compile_with(
        &self,
        g: &Graph,
        roots: &[NodeId],
        level: OptLevel,
    ) -> Arc<CompiledPlan> {
        self.get_or_compile_opts(
            g,
            roots,
            level,
            ExecMemory::default(),
            BackendKind::default(),
            TraceMode::default(),
        )
    }

    /// Fetch the compiled plan for `(g, roots)` with an explicit
    /// optimizer level, memory discipline and execution backend. For
    /// `OptLevel::None` the graph is fingerprinted and compiled exactly
    /// as given (the pre-PR 3 behaviour, kept as the ablation escape
    /// hatch); otherwise the graph is optimized and dead-node-swept
    /// first and the *optimized, compacted* graph is what the key
    /// fingerprints — so differently-built but equivalent graphs
    /// converge on one cached plan (one warm arena set or buffer pool).
    /// Plans compiled under different [`ExecMemory`] modes,
    /// [`BackendKind`]s or [`TraceMode`]s are cached separately.
    pub fn get_or_compile_opts(
        &self,
        g: &Graph,
        roots: &[NodeId],
        level: OptLevel,
        memory: ExecMemory,
        backend: BackendKind,
        trace: TraceMode,
    ) -> Arc<CompiledPlan> {
        let input_key = PlanKey {
            fingerprint: graph_fingerprint(g),
            roots: roots.iter().map(|r| r.0).collect(),
            memory,
            backend,
            trace,
        };
        if level == OptLevel::None {
            let mut map = self.map.lock().unwrap();
            if let Some(plan) = map.get(&input_key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return plan.clone();
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            let plan = Arc::new(CompiledPlan::with_options(
                g,
                roots,
                true,
                EpilogueMode::default(),
                memory,
                backend,
                trace,
            ));
            map.insert(input_key, plan.clone());
            return plan;
        }
        // fast path: this exact graph was optimized before — one hash
        // pass of the raw graph, no clone, no optimizer
        let input_key = (input_key, level);
        if let Some(plan) = self.by_input.lock().unwrap().get(&input_key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return plan.clone();
        }
        let mut g2 = g.clone();
        let o = crate::opt::optimize(&mut g2, roots, level);
        let (gc, croots) = crate::opt::compact(&g2, &o.roots);
        let canon_key = PlanKey {
            fingerprint: graph_fingerprint(&gc),
            roots: croots.iter().map(|r| r.0).collect(),
            memory,
            backend,
            trace,
        };
        let plan = {
            let mut map = self.map.lock().unwrap();
            if let Some(plan) = map.get(&canon_key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                plan.clone()
            } else {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let plan = Arc::new(CompiledPlan::with_options(
                    &gc,
                    &croots,
                    true,
                    EpilogueMode::default(),
                    memory,
                    backend,
                    trace,
                ));
                map.insert(canon_key, plan.clone());
                plan
            }
        };
        self.by_input.lock().unwrap().insert(input_key, plan.clone());
        plan
    }

    /// `(hits, misses)` across both lookup tables since process start —
    /// the serving metrics surface reads this off the global cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Number of cached plans (distinct compiled artifacts, not raw-graph
    /// aliases).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide plan cache used by the coordinator.
pub fn global_plan_cache() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(PlanCache::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::EinSpec;
    use crate::eval::Plan;
    use crate::ir::{Elem, GenFn};

    fn expr1() -> (Graph, NodeId, Env) {
        // Xᵀ((exp(Xw)+1)⁻¹ ⊙ exp(Xw)) — paper Expression (1)
        let mut g = Graph::new();
        let x = g.var("X", &[4, 3]);
        let w = g.var("w", &[3]);
        let xw = g.matvec(x, w);
        let e = g.elem(Elem::Exp, xw);
        let one = g.constant(1.0, &[4]);
        let e1 = g.add(e, one);
        let inv = g.elem(Elem::Recip, e1);
        let prod = g.hadamard(inv, e);
        let y = g.tmatvec(x, prod);
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[4, 3], 1));
        env.insert("w", Tensor::randn(&[3], 2));
        (g, y, env)
    }

    #[test]
    fn compiled_matches_interpreter_on_expression1() {
        let (g, y, env) = expr1();
        let compiled = CompiledPlan::new(&g, &[y]);
        let interp = Plan::new(&g, &[y]);
        let a = compiled.run(&env);
        let b = interp.run(&g, &env);
        assert!(a[0].allclose(&b[0], 1e-12, 1e-14), "diff {}", a[0].max_abs_diff(&b[0]));
    }

    #[test]
    fn direct_backend_is_bit_identical_to_cpu() {
        let (g, y, env) = expr1();
        let cpu = CompiledPlan::new(&g, &[y]);
        let direct = CompiledPlan::with_backend(&g, &[y], BackendKind::Direct);
        assert_eq!(cpu.backend(), BackendKind::Cpu);
        assert_eq!(direct.backend(), BackendKind::Direct);
        direct.validate_memory_plan();
        let a = cpu.run(&env);
        let b = direct.run(&env);
        assert_eq!(a[0].data(), b[0].data(), "backends must be bit-identical");
        // the direct backend leases arena views exactly like the cpu one
        let direct = Arc::new(direct);
        let leased = direct.clone().run_leased(&env);
        assert_eq!(leased[0].data(), a[0].data());
    }

    #[test]
    fn direct_backend_executes_in_arena_under_pooled_mode() {
        // the direct backend force-builds the arena plan even under the
        // pooled ablation mode, and never touches a pool mutex
        let (g, y, env) = expr1();
        let plan = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::default(),
            ExecMemory::Pooled,
            BackendKind::Direct,
            TraceMode::Off,
        );
        let want = CompiledPlan::new(&g, &[y]).run(&env);
        let got = plan.run(&env);
        assert_eq!(got[0].data(), want[0].data());
        let st = plan.pool_stats();
        assert_eq!(st.pool_locks, 0, "direct backend must not touch the pool");
        assert!(st.arena_bytes > 0, "direct backend must carry an arena plan");
    }

    #[test]
    fn leased_run_matches_owned_and_recycles_state() {
        let (g, y, env) = expr1();
        let plan = Arc::new(CompiledPlan::new(&g, &[y]));
        let owned = plan.run(&env);
        let leased = plan.clone().run_leased(&env);
        assert_eq!(leased.len(), owned.len());
        for (l, o) in leased.iter().zip(&owned) {
            assert_eq!(l.shape(), o.shape());
            assert_eq!(l.data(), o.data(), "leased view diverged from owned run");
        }
        drop(leased);
        // a dropped lease returns its run state: later runs must not
        // grow fresh arenas
        let a0 = plan.pool_stats().arena_allocs;
        for _ in 0..4 {
            drop(plan.clone().run_leased(&env));
        }
        assert_eq!(
            plan.pool_stats().arena_allocs,
            a0,
            "dropped leases must recycle their run state"
        );
    }

    #[test]
    fn leased_var_root_outlives_env() {
        // a root that *is* a variable borrows the env — the lease path
        // must deep-copy it so the output survives the env
        let mut g = Graph::new();
        let x = g.var("x", &[4]);
        let e = g.elem(Elem::Exp, x);
        let plan = Arc::new(CompiledPlan::new(&g, &[x, e]));
        let xt = Tensor::randn(&[4], 9);
        let out = {
            let mut env = Env::new();
            env.insert("x", xt.clone());
            plan.clone().run_leased(&env)
        };
        assert_eq!(out[0].data(), xt.data());
        assert_eq!(out[1].shape(), &[4]);
    }

    #[test]
    fn batch_slices_of_leased_outputs_share_one_lease() {
        let (g, y, _) = expr1();
        let (bg, broots) = batch_graph(&g, &[y], 2);
        let plan = global_plan_cache().get_or_compile_opts(
            &bg,
            &broots,
            OptLevel::None,
            ExecMemory::Planned,
            BackendKind::Cpu,
            TraceMode::Off,
        );
        let mut env = Env::new();
        env.insert("X", Tensor::randn(&[2, 4, 3], 1));
        env.insert("w", Tensor::randn(&[2, 3], 2));
        let out = plan.run_leased(&env);
        let full = out[0].to_tensor();
        let (a, b) = (out[0].batch_slice(0, 2), out[0].batch_slice(1, 2));
        drop(out); // slices alone must keep the lease alive
        assert_eq!(a.data(), &full.data()[..3]);
        assert_eq!(b.data(), &full.data()[3..]);
    }

    #[test]
    fn expression1_fuses_chain_and_epilogue() {
        let (g, y, env) = expr1();
        let fused = CompiledPlan::new(&g, &[y]);
        let unfused = CompiledPlan::with_fusion(&g, &[y], false);
        assert!(fused.len() < unfused.len(), "fusion must shrink the stream");
        assert!(fused.fused_count() >= 1, "expression 1 has a fusable chain");
        let a = fused.run(&env);
        let b = unfused.run(&env);
        assert_eq!(a[0].data(), b[0].data(), "fusion changed the numerics");
    }

    #[test]
    fn deep_chain_fuses_to_single_instruction() {
        let mut g = Graph::new();
        let x = g.var("x", &[8]);
        let mut v = x;
        for _ in 0..6 {
            v = g.elem(Elem::Tanh, v);
            v = g.scale(v, 0.5);
        }
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[8], 5));
        let plan = CompiledPlan::new(&g, &[v]);
        // stream: Var x, the shared 0.5 Static, one Fused pipeline
        assert_eq!(plan.fused_count(), 1);
        assert_eq!(plan.len(), 3);
        let unfused = CompiledPlan::with_fusion(&g, &[v], false);
        let a = plan.run(&env);
        let b = unfused.run(&env);
        assert_eq!(a[0].data(), b[0].data());
    }

    #[test]
    fn epilogue_modes_are_bit_identical() {
        let (g, y, env) = expr1();
        let in_tile = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::InTile,
            ExecMemory::default(),
            BackendKind::default(),
            TraceMode::Off,
        );
        let two_pass = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::TwoPass,
            ExecMemory::default(),
            BackendKind::default(),
            TraceMode::Off,
        );
        assert!(in_tile.fused_count() >= 1, "expression 1 must produce an epilogue");
        let a = in_tile.run(&env);
        let b = two_pass.run(&env);
        assert_eq!(
            a[0].data(),
            b[0].data(),
            "in-tile epilogue must be bit-identical to the two-pass reference"
        );
    }

    #[test]
    #[should_panic(expected = "rank ≥ 1")]
    fn rank0_gen_unary_rejected_at_compile_time() {
        let mut g = Graph::new();
        let x = g.var("x", &[]);
        let s = g.gen_unary(GenFn::Softmax, x);
        let _ = CompiledPlan::new(&g, &[s]);
    }

    #[test]
    fn pool_warm_after_first_run() {
        let (g, y, env) = expr1();
        let plan = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::default(),
            ExecMemory::Pooled,
            BackendKind::Cpu,
            TraceMode::Off,
        );
        let first = plan.run(&env);
        let cold = plan.pool_stats();
        for _ in 0..5 {
            let again = plan.run(&env);
            assert_eq!(again[0].data(), first[0].data());
        }
        let warm = plan.pool_stats();
        // Root buffers leave the pool each run, so one fresh alloc per
        // run for the root is expected; intermediates must all be reused.
        let runs = 5;
        assert!(
            warm.fresh <= cold.fresh + runs,
            "pool still allocating after warm-up: {:?} -> {:?}",
            cold,
            warm
        );
        assert!(warm.reused > cold.reused, "pool never reused a buffer");
    }

    #[test]
    fn planned_matches_pooled_and_takes_no_pool_lock() {
        let (g, y, env) = expr1();
        let planned = CompiledPlan::new(&g, &[y]);
        assert_eq!(planned.memory(), ExecMemory::Planned);
        planned.validate_memory_plan();
        let pooled = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::default(),
            ExecMemory::Pooled,
            BackendKind::Cpu,
            TraceMode::Off,
        );
        let a = planned.run(&env);
        let b = pooled.run(&env);
        assert_eq!(a[0].data(), b[0].data(), "memory modes must be bit-identical");
        // warm-up done: further runs must not grow the arena, touch the
        // pool, or acquire its mutex
        let cold = planned.pool_stats();
        assert!(cold.arena_bytes > 0, "expression 1 has intermediates to plan");
        for _ in 0..5 {
            let again = planned.run(&env);
            assert_eq!(again[0].data(), a[0].data());
        }
        let warm = planned.pool_stats();
        assert_eq!(warm.arena_allocs, cold.arena_allocs, "arena grew after warm-up");
        assert_eq!(warm.pool_locks, 0, "planned mode must not touch the pool mutex");
        assert_eq!(warm.fresh, 0);
        assert_eq!(warm.reused, 0);
    }

    #[test]
    fn duplicate_roots_are_returned_twice() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let e = g.elem(Elem::Exp, x);
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[3], 3));
        let plan = CompiledPlan::new(&g, &[e, e, x]);
        let vals = plan.run(&env);
        assert_eq!(vals.len(), 3);
        assert_eq!(vals[0], vals[1]);
        assert_eq!(vals[2], *env.get("x").unwrap());
    }

    #[test]
    #[should_panic(expected = "unbound variable")]
    fn unbound_variable_panics_compiled() {
        let mut g = Graph::new();
        let x = g.var("x", &[2]);
        CompiledPlan::new(&g, &[x]).run(&Env::new());
    }

    #[test]
    fn statics_are_precomputed_and_shared() {
        let mut g = Graph::new();
        let d = g.delta(&[3]);
        let c = g.constant(2.5, &[3, 3]);
        let s = g.hadamard(d, c);
        let plan = CompiledPlan::new(&g, &[s]);
        let vals = plan.run(&Env::new());
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 2.5 } else { 0.0 };
                assert_eq!(vals[0].at(&[i, j]), want);
            }
        }
    }

    #[test]
    fn plan_cache_hits_on_identical_graphs() {
        let cache = PlanCache::new();
        let (g, y, _) = expr1();
        let p1 = cache.get_or_compile(&g, &[y]);
        let p2 = cache.get_or_compile(&g, &[y]);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.len(), 1);
        // a structurally identical but separately built graph hits too
        let (g2, y2, _) = expr1();
        let p3 = cache.get_or_compile(&g2, &[y2]);
        assert!(Arc::ptr_eq(&p1, &p3));
        // different roots miss
        let _ = cache.get_or_compile(&g, &[y, y]);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn plan_cache_separates_memory_modes_and_backends() {
        // regression for the by_input fast-path key: a repeated graph
        // requested under a different memory mode or backend must never
        // be served the other configuration's plan
        let cache = PlanCache::new();
        let (g, y, env) = expr1();
        let level = OptLevel::default();
        let get = |mem, be| cache.get_or_compile_opts(&g, &[y], level, mem, be, TraceMode::Off);
        let planned = get(ExecMemory::Planned, BackendKind::Cpu);
        let pooled = get(ExecMemory::Pooled, BackendKind::Cpu);
        let direct = get(ExecMemory::Planned, BackendKind::Direct);
        assert!(
            !Arc::ptr_eq(&planned, &pooled),
            "memory modes must compile distinct plans"
        );
        assert!(
            !Arc::ptr_eq(&planned, &direct),
            "backends must compile distinct plans"
        );
        assert_eq!(planned.memory(), ExecMemory::Planned);
        assert_eq!(pooled.memory(), ExecMemory::Pooled);
        assert_eq!(direct.backend(), BackendKind::Direct);
        assert_eq!(cache.len(), 3);
        // repeated requests hit their own artifact (the fast path
        // includes the full configuration in its key)
        let planned2 = get(ExecMemory::Planned, BackendKind::Cpu);
        let pooled2 = get(ExecMemory::Pooled, BackendKind::Cpu);
        let direct2 = get(ExecMemory::Planned, BackendKind::Direct);
        assert!(Arc::ptr_eq(&planned, &planned2));
        assert!(Arc::ptr_eq(&pooled, &pooled2));
        assert!(Arc::ptr_eq(&direct, &direct2));
        assert_eq!(cache.len(), 3);
        // and all three agree bitwise
        let a = planned.run(&env);
        let b = pooled.run(&env);
        let c = direct.run(&env);
        assert_eq!(a[0].data(), b[0].data());
        assert_eq!(a[0].data(), c[0].data());
    }

    #[test]
    fn plan_cache_canonicalizes_equivalent_graphs() {
        // the same contraction written with different labels / operand
        // order must converge on ONE cached plan via the optimizer...
        let build = |swap: bool| {
            let mut g = Graph::new();
            let a = g.var("A", &[4, 5]);
            let x = g.var("x", &[5]);
            let m = if swap {
                g.mul(x, a, EinSpec::parse("j,ij->i"))
            } else {
                g.mul(a, x, EinSpec::new(vec![30, 31], vec![31], vec![30]))
            };
            (g, m)
        };
        let cache = PlanCache::new();
        let (g1, r1) = build(false);
        let (g2, r2) = build(true);
        let p1 = cache.get_or_compile(&g1, &[r1]);
        let p2 = cache.get_or_compile(&g2, &[r2]);
        assert!(Arc::ptr_eq(&p1, &p2), "canonicalisation must unify equivalent graphs");
        assert_eq!(cache.len(), 1);
        // ...while the OptLevel::None escape hatch keeps them distinct
        let p3 = cache.get_or_compile_with(&g1, &[r1], OptLevel::None);
        let p4 = cache.get_or_compile_with(&g2, &[r2], OptLevel::None);
        assert!(!Arc::ptr_eq(&p3, &p4));
        assert_eq!(cache.len(), 3);
        // and both lowerings agree numerically
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[4, 5], 1));
        env.insert("x", Tensor::randn(&[5], 2));
        let a = p1.run(&env);
        let b = p3.run(&env);
        assert!(a[0].allclose(&b[0], 1e-12, 1e-13));
    }

    #[test]
    fn wide_add_tree_splits_at_operand_cap() {
        // 24 distinct leaves exceed FUSED_MAX_ARGS: the builder must
        // split the chain into several kernels, bit-identically
        let mut g = Graph::new();
        let vars: Vec<NodeId> = (0..24).map(|i| g.var(&format!("x{}", i), &[32])).collect();
        let mut v = vars[0];
        for &x in &vars[1..] {
            v = g.add(v, x);
        }
        let mut env = Env::new();
        for (i, _) in vars.iter().enumerate() {
            env.insert(&format!("x{}", i), Tensor::randn(&[32], 50 + i as u64));
        }
        let fused = CompiledPlan::new(&g, &[v]);
        let unfused = CompiledPlan::with_fusion(&g, &[v], false);
        assert!(fused.len() < unfused.len(), "the chain must still fuse partially");
        let a = fused.run(&env);
        let b = unfused.run(&env);
        assert_eq!(a[0].data(), b[0].data(), "splitting must not change the association");
        let want = Plan::new(&g, &[v]).run(&g, &env);
        assert!(a[0].allclose(&want[0], 1e-12, 1e-13));
    }

    #[test]
    fn fingerprint_distinguishes_shapes() {
        let mut g1 = Graph::new();
        g1.var("x", &[3]);
        let mut g2 = Graph::new();
        g2.var("x", &[4]);
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&g2));
    }

    #[test]
    fn levels_partition_instructions() {
        let (g, y, _) = expr1();
        let plan = CompiledPlan::new(&g, &[y]);
        let total: usize = plan.lowered.levels.iter().map(|l| l.len()).sum();
        assert_eq!(total, plan.len());
        assert!(plan.depth() >= 4, "expression 1 has a chain of depth ≥ 4");
    }
}
