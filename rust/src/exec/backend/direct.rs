//! The direct-threaded backend: a second, genuinely different lowering
//! of the same instruction stream — proof that the backend seam is
//! real, and a latency play for small/skinny serving plans.
//!
//! Backend compilation walks the [`Lowered`] stream once (in level
//! order, the sequential schedule the memory plan's liveness intervals
//! are valid for) and emits **one monomorphized boxed closure per
//! instruction**: output arena offsets, scratch slots, operand
//! positions, the element-wise function pointer, the fused kernel and
//! the epilogue placement are all resolved *here*, at compile time. A
//! run is then a straight sequential walk of the closure chain — no
//! instruction dispatch, no level bookkeeping, no atomics, no worker
//! handoff. For the small plans the coordinator serves at low batch
//! sizes, that per-node overhead is the dominant cost the
//! work-stealing executor pays and this backend does not.
//!
//! The closures reuse exactly the kernels the CPU backend runs —
//! `EinsumPlan::run_planned`, `FusedKernel`, `gen_unary_into` — with
//! the same operand resolution and the same epilogue placement, so the
//! two backends are bit-identical by construction
//! (`tests/backend_equivalence.rs` pins it, and pins both against the
//! interpreter oracle).
//!
//! This backend executes **in-arena only**: lowering force-builds the
//! memory plan for it even under the pooled ablation mode, so
//! [`Backend::run_pooled`]'s default (unreachable) body is never hit.

use crate::einsum::{EpiFn, NoEpilogue};
use crate::ir::Elem;
use crate::util::simd::{add_assign, add_into};

use super::super::lower::{Instr, Lowered};
use super::super::EpilogueMode;
use super::{
    fused_srcs_planned, fused_srcs_planned_except, gen_unary_into, src_slice, slot_mut,
    ArenaExec, Backend, BackendKind, IDX_SCRATCH,
};

/// One compiled instruction: everything but the run's arena pointer is
/// baked into the closure's captures.
type DirectOp = Box<dyn Fn(&ArenaExec<'_>) + Send + Sync>;

/// Coerce a closure to the higher-ranked [`DirectOp`] signature.
fn boxed<F: for<'r> Fn(&ArenaExec<'r>) + Send + Sync + 'static>(f: F) -> DirectOp {
    Box::new(f)
}

/// Monomorphize an element-wise function to a plain `fn` pointer. The
/// bodies mirror [`Elem::apply`] exactly — bit-identical results are
/// part of the backend contract.
fn elem_fn(f: Elem) -> fn(f64) -> f64 {
    match f {
        Elem::Exp => |x| x.exp(),
        Elem::Log => |x| x.ln(),
        Elem::Relu => |x| x.max(0.0),
        Elem::Step => |x| if x > 0.0 { 1.0 } else { 0.0 },
        Elem::Sigmoid => |x| 1.0 / (1.0 + (-x).exp()),
        Elem::Tanh => |x| x.tanh(),
        Elem::Sqrt => |x| x.sqrt(),
        Elem::Neg => |x| -x,
        Elem::Recip => |x| 1.0 / x,
        Elem::Square => |x| x * x,
        Elem::Sign => |x| {
            if x > 0.0 {
                1.0
            } else if x < 0.0 {
                -1.0
            } else {
                0.0
            }
        },
        Elem::Abs => |x| x.abs(),
    }
}

/// The compiled closure chain. `Var`/`Static` instructions emit no
/// closure at all — the facade resolves them into the source table
/// before the backend runs. Each closure carries its instruction
/// position so a traced run can attribute spans; epilogues are baked
/// *inside* the closures here, so unlike the CPU backend a direct trace
/// has no separate epilogue sub-spans (their time is inside the
/// instruction span).
pub struct DirectBackend {
    ops: Vec<(u32, DirectOp)>,
    /// cumulative closure count at the end of each level — a traced run
    /// replays the level structure from this, the untraced run ignores it
    level_ends: Vec<usize>,
}

impl DirectBackend {
    /// Compile the stream into the closure chain. Closures are emitted
    /// in **level order** (not stream order): the memory plan's slot
    /// reuse is proven safe against level-based liveness, and level
    /// order is the canonical sequential schedule consistent with it.
    pub(crate) fn compile(lw: &Lowered) -> DirectBackend {
        let mut ops = Vec::with_capacity(lw.instrs.len());
        let mut level_ends = Vec::with_capacity(lw.levels.len());
        for level in &lw.levels {
            for &p in level {
                if let Some(op) = compile_instr(lw, p) {
                    ops.push((p as u32, op));
                }
            }
            level_ends.push(ops.len());
        }
        DirectBackend { ops, level_ends }
    }
}

impl Backend for DirectBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Direct
    }

    fn exec_arena(&self, _lw: &Lowered, ex: &ArenaExec<'_>) {
        match ex.trace {
            None => {
                for (_, op) in &self.ops {
                    op(ex);
                }
            }
            Some(sink) => {
                // sequential executor: everything runs on lane 0
                let mut start = 0;
                for (lv, &end) in self.level_ends.iter().enumerate() {
                    let l0 = sink.now();
                    for (pos, op) in &self.ops[start..end] {
                        let t0 = sink.now();
                        op(ex);
                        sink.record_instr(0, *pos, t0);
                    }
                    sink.record_level(lv as u32, l0);
                    start = end;
                }
            }
        }
    }
}

/// Compile one instruction into its closure, resolving every
/// compile-time-known quantity now (slots, operand positions, function
/// pointers, epilogue placement, in-place aliasing).
fn compile_instr(lw: &Lowered, p: usize) -> Option<DirectOp> {
    let mp = lw.memplan.as_ref().expect("direct backend requires an arena plan");
    let instr = &lw.instrs[p];
    let slot = match instr {
        Instr::Var { .. } | Instr::Static(_) => return None, // source table
        _ => mp.out[p].expect("planned instruction output"),
    };
    let op = match instr {
        Instr::Var { .. } | Instr::Static(_) => unreachable!(),
        Instr::Add(a, b) => {
            let (a, b) = (*a, *b);
            match lw.inplace_arg[p] {
                // out aliases operand a: its values are already in place
                Some(0) => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    add_assign(out, src_slice(ex, b));
                }),
                // out aliases operand b
                Some(_) => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    add_assign(out, src_slice(ex, a));
                }),
                None => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    add_into(out, src_slice(ex, a), src_slice(ex, b));
                }),
            }
        }
        Instr::Elem(f, a) => {
            let f = elem_fn(*f);
            let a = *a;
            match lw.inplace_arg[p] {
                Some(_) => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    for o in out.iter_mut() {
                        *o = f(*o);
                    }
                }),
                None => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    for (o, &x) in out.iter_mut().zip(src_slice(ex, a)) {
                        *o = f(x);
                    }
                }),
            }
        }
        Instr::Mul(a, b, plan, epi) => {
            let (a, b) = (*a, *b);
            let plan = plan.clone();
            let scr = mp.scratch[p].expect("contraction scratch planned");
            match epi {
                None => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    let ta = src_slice(ex, a);
                    let tb = src_slice(ex, b);
                    // SAFETY: scratch slots are exclusive to this
                    // instruction while it runs (planner invariant).
                    let (sa, sb, sc) = unsafe {
                        (slot_mut(ex, scr[0]), slot_mut(ex, scr[1]), slot_mut(ex, scr[2]))
                    };
                    IDX_SCRATCH.with(|idx_cell| {
                        let mut idx = idx_cell.borrow_mut();
                        plan.run_planned(ta, tb, out, sa, sb, sc, &mut idx, &NoEpilogue);
                    });
                }),
                Some(e) => {
                    let kernel = e.kernel.clone();
                    let args = e.args.clone();
                    let mode = lw.epilogue_mode;
                    boxed(move |ex| {
                        let out = unsafe { slot_mut(ex, slot) };
                        let ta = src_slice(ex, a);
                        let tb = src_slice(ex, b);
                        // SAFETY: planner invariant, as above.
                        let (sa, sb, sc) = unsafe {
                            (slot_mut(ex, scr[0]), slot_mut(ex, scr[1]), slot_mut(ex, scr[2]))
                        };
                        let srcs = fused_srcs_planned(&args, ex, out.len());
                        let rest = &srcs[..args.len()];
                        IDX_SCRATCH.with(|idx_cell| {
                            let mut idx = idx_cell.borrow_mut();
                            match mode {
                                EpilogueMode::InTile => {
                                    let tile_epi = EpiFn(|base: usize, seg: &mut [f64]| {
                                        kernel.run_inplace_at(seg, base, rest)
                                    });
                                    plan.run_planned(
                                        ta, tb, out, sa, sb, sc, &mut idx, &tile_epi,
                                    );
                                }
                                EpilogueMode::TwoPass => {
                                    plan.run_planned(
                                        ta,
                                        tb,
                                        out,
                                        sa,
                                        sb,
                                        sc,
                                        &mut idx,
                                        &NoEpilogue,
                                    );
                                    kernel.run_inplace(out, rest);
                                }
                            }
                        });
                    })
                }
            }
        }
        Instr::GenUnary(f, a, epi) => {
            let (gf, a) = (*f, *a);
            let last_dim = *lw.shapes[a].last().expect("GenFn needs rank ≥ 1");
            match epi {
                None => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    gen_unary_into(gf, src_slice(ex, a), last_dim, out);
                }),
                Some(e) => {
                    let kernel = e.kernel.clone();
                    let args = e.args.clone();
                    boxed(move |ex| {
                        let out = unsafe { slot_mut(ex, slot) };
                        gen_unary_into(gf, src_slice(ex, a), last_dim, out);
                        let srcs = fused_srcs_planned(&args, ex, out.len());
                        kernel.run_inplace(out, &srcs[..args.len()]);
                    })
                }
            }
        }
        Instr::Fused { kernel, args } => {
            let kernel = kernel.clone();
            let args = args.clone();
            match lw.inplace_arg[p] {
                Some(arg) => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    // slot `arg` aliases the output; resolve the others
                    let srcs = fused_srcs_planned_except(&args, ex, out.len(), arg);
                    kernel.run_inplace_arg(out, arg as u32, &srcs[..args.len()]);
                }),
                None => boxed(move |ex| {
                    let out = unsafe { slot_mut(ex, slot) };
                    let srcs = fused_srcs_planned(&args, ex, out.len());
                    kernel.run(&srcs[..args.len()], out);
                }),
            }
        }
    };
    Some(op)
}
