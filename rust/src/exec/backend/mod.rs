//! The backend seam: a [`Lowered`] instruction stream is turned into an
//! executable artifact by a [`Backend`] implementation.
//!
//! The contract is deliberately narrow. Lowering (`exec::lower`) decides
//! *what* runs — the fused instruction stream, dependency levels,
//! buffer lifetimes, the static arena layout. A backend decides only
//! *how* it runs:
//!
//! * [`cpu`] — the work-stealing, level-parallel executor on the
//!   persistent worker pool: the production CPU path, extracted from the
//!   pre-seam `CompiledPlan` by code motion. It is also the only
//!   backend implementing the pooled-memory ablation mode.
//! * [`direct`] — a direct-threaded second lowering: every instruction
//!   is compiled into one monomorphized boxed closure (arena offsets,
//!   scratch slots, operand kinds and epilogue placement resolved at
//!   backend-compile time), and a run is a sequential walk of the
//!   closure chain. A latency play for the small/skinny plans the
//!   serving path sees at low batch sizes — and the proof that the seam
//!   is real: it shares no executor code with [`cpu`], only the
//!   kernels.
//!
//! Both backends execute **in-arena** through [`Backend::exec_arena`]:
//! the facade (`exec::CompiledPlan`) checks out a run state, resolves
//! every instruction's value source into an [`ArenaExec`], and hands it
//! to the backend; root extraction, leasing and run-state recycling stay
//! in the facade so every backend gets them for free. Pooled-mode
//! execution ([`Backend::run_pooled`]) is optional — backends that only
//! execute in-arena (the direct one) simply force the memory plan to be
//! built at lowering time.
//!
//! Every backend is pinned bit-identical to every other **and**
//! differentially against the interpreter oracle
//! (`tests/backend_equivalence.rs`); a future PJRT/GPU backend slots in
//! as a third implementation of the same trait, with the same tests.

pub mod cpu;
pub mod direct;

use crate::eval::Env;
use crate::ir::GenFn;
use crate::tensor::Tensor;
use std::cell::RefCell;

use super::lower::{FusedSrc, Lowered, FUSED_MAX_ARGS};
use super::memplan::Slot;
use super::PoolStats;

/// Which executor a plan compiles its instruction stream for. Part of
/// the plan-cache key: plans for different backends are distinct
/// artifacts.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub enum BackendKind {
    /// The work-stealing, level-parallel executor on the persistent
    /// worker pool — the production CPU path and the default.
    #[default]
    Cpu,
    /// The direct-threaded executor: one monomorphized closure per
    /// instruction, run sequentially in-arena. Lowest dispatch overhead;
    /// best for small/skinny serving plans.
    Direct,
}

impl BackendKind {
    /// Stable name used by the CLI flag and the bench mode labels.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::Direct => "direct",
        }
    }

    /// Parse a CLI/bench name. Inverse of [`BackendKind::name`].
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "cpu" => Some(BackendKind::Cpu),
            "direct" => Some(BackendKind::Direct),
            _ => None,
        }
    }
}

/// An executable compiled from a [`Lowered`] stream. See the module
/// docs for the split of responsibilities between lowering, the facade
/// and the backend.
pub trait Backend: Send + Sync {
    /// Which kind this executable is (mirrors the compile request).
    fn kind(&self) -> BackendKind;

    /// Execute every instruction of an in-arena run. `ex` carries the
    /// arena base and the per-instruction source table the facade
    /// resolved; on return every root's slot holds its value.
    fn exec_arena(&self, lw: &Lowered, ex: &ArenaExec<'_>);

    /// Execute a pooled-memory run (the [`ExecMemory::Pooled`]
    /// ablation). Only the CPU backend implements this; in-arena-only
    /// backends never reach it because they force the memory plan at
    /// lowering time.
    ///
    /// [`ExecMemory::Pooled`]: super::ExecMemory::Pooled
    fn run_pooled(&self, _lw: &Lowered, _env: &Env) -> Vec<Tensor> {
        unreachable!("this backend executes in-arena only")
    }

    /// Merge the backend's own counters (pool hits, lock counts) into a
    /// stats snapshot. Backends without a pool report nothing.
    fn fold_stats(&self, _stats: &mut PoolStats) {}
}

/// Compile a [`Lowered`] stream for `kind`. The CPU backend is a thin
/// runtime over the stream; the direct backend walks the stream once
/// here and emits its closure chain.
pub(crate) fn compile(kind: BackendKind, lw: &Lowered) -> Box<dyn Backend> {
    match kind {
        BackendKind::Cpu => Box::new(cpu::CpuBackend::default()),
        BackendKind::Direct => Box::new(direct::DirectBackend::compile(lw)),
    }
}

/// Shared view of one in-arena run handed to a backend: the arena base
/// plus the per-instruction source table.
///
/// SAFETY (for the `Sync` impl): each executor writes only its own
/// instructions' output slots, and the memory planner guarantees that a
/// slot written in level `L` overlaps no slot read or written by any
/// other instruction live in `L` (`MemPlan::check_no_overlap`).
pub struct ArenaExec<'r> {
    pub(crate) base: *mut f64,
    pub(crate) srcs: &'r [(*const f64, usize)],
    /// span recorder for this run, `None` under `TraceMode::Off` — the
    /// executors branch on it once per instruction/level, so the
    /// untraced hot path pays a predicted-not-taken branch and nothing
    /// else (no allocation, no lock; counter-asserted in
    /// `tests/obs_trace.rs`)
    pub(crate) trace: Option<&'r crate::obs::TraceSink>,
}

unsafe impl Sync for ArenaExec<'_> {}

/// Operand slice of instruction `q` (env tensor, static, or arena slot).
#[inline]
pub(crate) fn src_slice<'r>(ex: &ArenaExec<'r>, q: usize) -> &'r [f64] {
    let (ptr, len) = ex.srcs[q];
    // SAFETY: see ArenaExec — the pointee outlives the run and no &mut
    // to the same region exists while this borrow is used.
    unsafe { std::slice::from_raw_parts(ptr, len) }
}

/// Mutable view of an arena slot.
///
/// SAFETY: caller must be the (sole) instruction that owns `slot` in the
/// current level — guaranteed by the memory plan.
#[inline]
#[allow(clippy::mut_from_ref)] // disjointness is the planner's invariant
pub(crate) unsafe fn slot_mut<'r>(ex: &ArenaExec<'r>, slot: Slot) -> &'r mut [f64] {
    std::slice::from_raw_parts_mut(ex.base.add(slot.off), slot.len)
}

thread_local! {
    /// Per-thread odometer scratch for in-arena einsum gathers — the
    /// one scratch that cannot live in the `f64` arena. Persistent pool
    /// workers keep it warm across scopes, plans and coordinator
    /// entries.
    pub(crate) static IDX_SCRATCH: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
}

/// Resolve fused-kernel operand slots through an in-arena run's source
/// table: operands matching the output length stream per element,
/// rank-0 operands broadcast. (Group construction guarantees every slot
/// is one of the two.)
///
/// Returns a fixed-size stack array — the group builder caps kernels at
/// `FUSED_MAX_ARGS` operand slots, so resolution costs zero heap
/// allocations and the steady-state hot path is strictly alloc-free
/// (callers slice the array to `args.len()`).
pub(crate) fn fused_srcs_planned<'r>(
    args: &[usize],
    ex: &ArenaExec<'r>,
    out_len: usize,
) -> [FusedSrc<'r>; FUSED_MAX_ARGS] {
    debug_assert!(args.len() <= FUSED_MAX_ARGS, "group builder must cap operand slots");
    let mut srcs = [FusedSrc::Scalar(0.0); FUSED_MAX_ARGS];
    for (slot, &q) in args.iter().enumerate() {
        let s = src_slice(ex, q);
        srcs[slot] = if s.len() == out_len {
            FusedSrc::Slice(s)
        } else {
            FusedSrc::Scalar(s[0])
        };
    }
    srcs
}

/// [`fused_srcs_planned`] minus the slot that aliases the output of an
/// in-place fused instruction: that operand's bytes *are* the output
/// buffer, so no shared slice to it may exist — the kernel reads it as
/// the carrier instead (`FusedKernel::run_inplace_arg`).
pub(crate) fn fused_srcs_planned_except<'r>(
    args: &[usize],
    ex: &ArenaExec<'r>,
    out_len: usize,
    skip: usize,
) -> [FusedSrc<'r>; FUSED_MAX_ARGS] {
    debug_assert!(args.len() <= FUSED_MAX_ARGS, "group builder must cap operand slots");
    let mut srcs = [FusedSrc::Scalar(0.0); FUSED_MAX_ARGS];
    for (slot, &q) in args.iter().enumerate() {
        if slot == skip {
            continue; // dummy: Load(skip) reads the carrier value
        }
        let s = src_slice(ex, q);
        srcs[slot] = if s.len() == out_len {
            FusedSrc::Slice(s)
        } else {
            FusedSrc::Scalar(s[0])
        };
    }
    srcs
}

/// Write-into evaluation of the general unary functions (mirrors
/// `GenFn::eval` but targets a raw buffer — pooled or arena-planned).
/// `n` is the operand's trailing dimension; rank-0 inputs are rejected
/// at lowering time.
pub(crate) fn gen_unary_into(f: GenFn, data: &[f64], n: usize, out: &mut [f64]) {
    match f {
        GenFn::Softmax => {
            out.copy_from_slice(data);
            for row in out.chunks_mut(n) {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut z = 0.0;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    z += *v;
                }
                for v in row.iter_mut() {
                    *v /= z;
                }
            }
        }
        GenFn::LogSumExp => {
            for (o, row) in out.iter_mut().zip(data.chunks(n)) {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                *o = m + row.iter().map(|v| (v - m).exp()).sum::<f64>().ln();
            }
        }
    }
}
