//! The work-stealing, level-parallel CPU backend — the production
//! executor, extracted from the pre-seam `CompiledPlan` by code motion.
//!
//! In-arena runs walk the dependency levels; a level that passes the
//! fork gate (`Lowered::level_fork`) is claimed in chunks from a shared
//! atomic cursor by workers of the persistent pool
//! ([`worker_pool`](crate::util::worker_pool)), so one oversized node
//! delays only the thread that claimed it. This backend also owns the
//! [`ExecMemory::Pooled`](crate::exec::ExecMemory::Pooled) ablation
//! path and its shape-bucketed [`BufferPool`].

use crate::einsum::{EinScratch, EpiFn, NoEpilogue};
use crate::eval::Env;
use crate::tensor::Tensor;
use crate::util::simd::{add_assign, add_into};
use crate::util::worker_pool;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use super::super::lower::{FusedSrc, Instr, Lowered, FUSED_MAX_ARGS};
use super::super::{EpilogueMode, PoolStats};
use super::{
    fused_srcs_planned, fused_srcs_planned_except, gen_unary_into, src_slice, slot_mut,
    ArenaExec, Backend, BackendKind, IDX_SCRATCH,
};

/// A shape-bucketed free list of `f64` buffers. Buffers are bucketed by
/// exact element count; `acquire` pops a warm buffer (contents arbitrary
/// — every instruction fully overwrites its output) or allocates a fresh
/// one. Pooled-mode ablation only; planned runs never touch it.
#[derive(Default)]
pub struct BufferPool {
    buckets: HashMap<usize, Vec<Vec<f64>>>,
    fresh: u64,
    reused: u64,
}

impl BufferPool {
    fn acquire(&mut self, len: usize) -> Vec<f64> {
        if let Some(list) = self.buckets.get_mut(&len) {
            if let Some(buf) = list.pop() {
                self.reused += 1;
                debug_assert_eq!(buf.len(), len);
                return buf;
            }
        }
        self.fresh += 1;
        vec![0.0; len]
    }

    fn release(&mut self, buf: Vec<f64>) {
        self.buckets.entry(buf.len()).or_default().push(buf);
    }

    fn stats(&self) -> PoolStats {
        PoolStats { fresh: self.fresh, reused: self.reused, ..PoolStats::default() }
    }
}

/// A value slot during a pooled execution: intermediates own pooled
/// buffers, inputs and compile-time constants are borrowed.
enum Val<'a> {
    Owned(Tensor),
    Ref(&'a Tensor),
}

impl<'a> Val<'a> {
    fn tensor(&self) -> &Tensor {
        match self {
            Val::Owned(t) => t,
            Val::Ref(t) => t,
        }
    }
}

/// The work-stealing level executor plus the pooled-mode runtime state
/// (buffer pool, einsum scratches, the lock counter the no-lock
/// assertion reads).
#[derive(Default)]
pub struct CpuBackend {
    pool: Mutex<BufferPool>,
    /// einsum scratch buffers, checked out once per run (serial) or once
    /// per worker (parallel) — never per node, to keep lock traffic low
    /// (pooled mode only)
    scratches: Mutex<Vec<EinScratch>>,
    /// buffer-pool mutex acquisitions (the no-lock assertion's counter)
    pool_locks: AtomicU64,
}

impl CpuBackend {
    /// Acquire the buffer pool, counting the acquisition (the planned
    /// mode's "no pool mutex on the hot path" assertion reads this).
    fn lock_pool(&self) -> MutexGuard<'_, BufferPool> {
        self.pool_locks.fetch_add(1, Ordering::Relaxed);
        self.pool.lock().unwrap()
    }

    fn exec_node<'a>(
        &self,
        lw: &'a Lowered,
        p: usize,
        values: &[Option<Val<'a>>],
        env: &'a Env,
        scratch: &mut EinScratch,
    ) -> Val<'a> {
        let shape = &lw.shapes[p];
        match &lw.instrs[p] {
            Instr::Var { name, shape } => {
                let t = env
                    .get(name)
                    .unwrap_or_else(|| panic!("unbound variable {}", name));
                assert_eq!(
                    t.shape(),
                    &shape[..],
                    "variable {} bound with wrong shape",
                    name
                );
                Val::Ref(t)
            }
            Instr::Static(i) => Val::Ref(&lw.statics[*i]),
            Instr::Add(a, b) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let tb = values[*b].as_ref().expect("operand not computed").tensor();
                let mut buf = self.lock_pool().acquire(ta.len());
                add_into(&mut buf, ta.data(), tb.data());
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::Mul(a, b, plan, epi) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let tb = values[*b].as_ref().expect("operand not computed").tensor();
                let out_len: usize = shape.iter().product();
                let buf = self.lock_pool().acquire(out_len);
                let mut out = Tensor::new(shape, buf);
                match epi {
                    None => plan.run(ta, tb, &mut out, scratch),
                    Some(e) => {
                        let srcs = fused_srcs(&e.args, values, out_len);
                        let rest = &srcs[..e.args.len()];
                        match lw.epilogue_mode {
                            EpilogueMode::InTile => {
                                // the fused chain runs on each output
                                // tile right after its final
                                // k-accumulation, cache-hot
                                let tile_epi = EpiFn(|base: usize, seg: &mut [f64]| {
                                    e.kernel.run_inplace_at(seg, base, rest)
                                });
                                plan.run_with_epilogue_in_tile(ta, tb, &mut out, scratch, &tile_epi);
                            }
                            EpilogueMode::TwoPass => {
                                plan.run_with_epilogue(ta, tb, &mut out, scratch, |data| {
                                    e.kernel.run_inplace(data, rest)
                                });
                            }
                        }
                    }
                }
                Val::Owned(out)
            }
            Instr::Elem(f, a) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let mut buf = self.lock_pool().acquire(ta.len());
                for (o, &x) in buf.iter_mut().zip(ta.data()) {
                    *o = f.apply(x);
                }
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::GenUnary(f, a, epi) => {
                let ta = values[*a].as_ref().expect("operand not computed").tensor();
                let out_len: usize = shape.iter().product();
                let mut buf = self.lock_pool().acquire(out_len);
                let last_dim = *ta.shape().last().expect("GenFn needs rank ≥ 1");
                gen_unary_into(*f, ta.data(), last_dim, &mut buf);
                if let Some(e) = epi {
                    let srcs = fused_srcs(&e.args, values, out_len);
                    e.kernel.run_inplace(&mut buf, &srcs[..e.args.len()]);
                }
                Val::Owned(Tensor::new(shape, buf))
            }
            Instr::Fused { kernel, args } => {
                let out_len: usize = shape.iter().product();
                let srcs = fused_srcs(args, values, out_len);
                let mut buf = self.lock_pool().acquire(out_len);
                kernel.run(&srcs[..args.len()], &mut buf);
                Val::Owned(Tensor::new(shape, buf))
            }
        }
    }
}

impl Backend for CpuBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Cpu
    }

    /// In-arena execution: walk the levels, forking a level onto the
    /// persistent worker pool when the gate passes. Nothing here
    /// allocates, locks, or touches a `Tensor` — with tracing off the
    /// only addition is one untaken branch per instruction.
    fn exec_arena(&self, lw: &Lowered, ex: &ArenaExec<'_>) {
        for (lv, level) in lw.levels.iter().enumerate() {
            let l0 = ex.trace.map(|s| s.now());
            if let Some((nt, chunk)) = lw.level_fork(lv, level.len()) {
                let cursor = AtomicUsize::new(0);
                let cursor_ref = &cursor;
                worker_pool().scope(nt, move |lane| loop {
                    let start = cursor_ref.fetch_add(chunk, Ordering::Relaxed);
                    if start >= level.len() {
                        break;
                    }
                    let end = (start + chunk).min(level.len());
                    for &p in &level[start..end] {
                        exec_node_traced(lw, p, ex, lane as u32);
                    }
                });
            } else {
                for &p in level {
                    exec_node_traced(lw, p, ex, 0);
                }
            }
            if let (Some(s), Some(t0)) = (ex.trace, l0) {
                s.record_level(lv as u32, t0);
            }
        }
    }

    /// Pooled-memory execution (the PR 1 ablation baseline): buffers
    /// from the mutex-guarded pool, recycled at their last-use level.
    fn run_pooled(&self, lw: &Lowered, env: &Env) -> Vec<Tensor> {
        let n = lw.instrs.len();
        let mut values: Vec<Option<Val>> = Vec::with_capacity(n);
        values.resize_with(n, || None);
        let mut scratch = self.scratches.lock().unwrap().pop().unwrap_or_default();

        for (lv, level) in lw.levels.iter().enumerate() {
            if let Some((nt, chunk)) = lw.level_fork(lv, level.len()) {
                // Work stealing: workers claim chunks of the level from
                // a shared cursor, so one oversized node delays only the
                // thread that claimed it — not a whole static band.
                let results: Vec<Mutex<Option<Val>>> =
                    level.iter().map(|_| Mutex::new(None)).collect();
                let cursor = AtomicUsize::new(0);
                {
                    let values_ref = &values;
                    let results_ref = &results;
                    let cursor_ref = &cursor;
                    worker_pool().scope(nt, move |_| {
                        let mut band_scratch =
                            self.scratches.lock().unwrap().pop().unwrap_or_default();
                        loop {
                            let start = cursor_ref.fetch_add(chunk, Ordering::Relaxed);
                            if start >= level.len() {
                                break;
                            }
                            let end = (start + chunk).min(level.len());
                            for k in start..end {
                                let v = self.exec_node(
                                    lw,
                                    level[k],
                                    values_ref,
                                    env,
                                    &mut band_scratch,
                                );
                                *results_ref[k].lock().unwrap() = Some(v);
                            }
                        }
                        self.scratches.lock().unwrap().push(band_scratch);
                    });
                }
                for (r, &p) in results.into_iter().zip(level) {
                    values[p] = r.into_inner().unwrap();
                }
            } else {
                for &p in level {
                    let v = self.exec_node(lw, p, &values, env, &mut scratch);
                    values[p] = Some(v);
                }
            }
            // recycle buffers whose last consumer ran in this level
            // (one pool lock per level, not per buffer)
            if !lw.free_at_level[lv].is_empty() {
                let mut pool = self.lock_pool();
                for &p in &lw.free_at_level[lv] {
                    if let Some(Val::Owned(t)) = values[p].take() {
                        pool.release(t.into_data());
                    }
                }
            }
        }
        self.scratches.lock().unwrap().push(scratch);

        let mut out = Vec::with_capacity(lw.root_pos.len());
        for i in 0..lw.root_pos.len() {
            let p = lw.root_pos[i];
            let used_again = lw.root_pos[i + 1..].contains(&p);
            let t = if used_again {
                values[p].as_ref().expect("root not computed").tensor().clone()
            } else {
                match values[p].take().expect("root not computed") {
                    Val::Owned(t) => t,
                    Val::Ref(t) => t.clone(),
                }
            };
            out.push(t);
        }
        out
    }

    fn fold_stats(&self, stats: &mut PoolStats) {
        let p = self.pool.lock().unwrap().stats();
        stats.fresh = p.fresh;
        stats.reused = p.reused;
        stats.pool_locks = self.pool_locks.load(Ordering::Relaxed);
    }
}

/// [`exec_node_planned`] wrapped in a span when the run carries a trace
/// sink. The untraced path is the `None` arm — one branch, no clock
/// read. `Var`/`Static` never execute, so they are skipped before the
/// clock starts (a traced run records exactly the executed instructions).
#[inline]
fn exec_node_traced(lw: &Lowered, p: usize, ex: &ArenaExec<'_>, lane: u32) {
    match ex.trace {
        None => exec_node_planned(lw, p, ex, lane),
        Some(sink) => {
            if matches!(lw.instrs[p], Instr::Var { .. } | Instr::Static(_)) {
                return;
            }
            let t0 = sink.now();
            exec_node_planned(lw, p, ex, lane);
            sink.record_instr(lane, p as u32, t0);
        }
    }
}

/// Execute one instruction of an in-arena run: operands and the
/// destination are fixed arena offsets (or pre-resolved env/static
/// pointers); nothing here allocates, locks, or touches a `Tensor`.
/// `lane` is only read when the run is traced (the two-pass epilogue
/// sweep records its own sub-span).
fn exec_node_planned(lw: &Lowered, p: usize, ex: &ArenaExec<'_>, lane: u32) {
    let mp = lw.memplan.as_ref().expect("in-arena plan carries a memory plan");
    let instr = &lw.instrs[p];
    let slot = match instr {
        Instr::Var { .. } | Instr::Static(_) => return, // resolved up front
        _ => mp.out[p].expect("planned instruction output"),
    };
    // SAFETY: this instruction is the sole writer of `slot` in its
    // level, and no concurrently live buffer overlaps it (planner
    // invariant, re-checked by validate_memory_plan / debug builds).
    let out: &mut [f64] = unsafe { slot_mut(ex, slot) };
    match instr {
        Instr::Var { .. } | Instr::Static(_) => unreachable!(),
        Instr::Add(a, b) => match lw.inplace_arg[p] {
            // out aliases operand a: its values are already in place
            Some(0) => add_assign(out, src_slice(ex, *b)),
            // out aliases operand b
            Some(_) => add_assign(out, src_slice(ex, *a)),
            None => add_into(out, src_slice(ex, *a), src_slice(ex, *b)),
        },
        Instr::Elem(f, a) => match lw.inplace_arg[p] {
            Some(_) => {
                for o in out.iter_mut() {
                    *o = f.apply(*o);
                }
            }
            None => {
                for (o, &x) in out.iter_mut().zip(src_slice(ex, *a)) {
                    *o = f.apply(x);
                }
            }
        },
        Instr::Mul(a, b, plan, epi) => {
            let ta = src_slice(ex, *a);
            let tb = src_slice(ex, *b);
            let scr = mp.scratch[p].expect("contraction scratch planned");
            // SAFETY: scratch slots are exclusive to this instruction
            // for the duration of its level (planner invariant).
            let (sa, sb, sc) = unsafe {
                (slot_mut(ex, scr[0]), slot_mut(ex, scr[1]), slot_mut(ex, scr[2]))
            };
            IDX_SCRATCH.with(|idx_cell| {
                let mut guard = idx_cell.borrow_mut();
                let idx: &mut Vec<usize> = &mut guard;
                match epi {
                    None => plan.run_planned(ta, tb, out, sa, sb, sc, idx, &NoEpilogue),
                    Some(e) => {
                        let srcs = fused_srcs_planned(&e.args, ex, out.len());
                        let rest = &srcs[..e.args.len()];
                        match lw.epilogue_mode {
                            EpilogueMode::InTile => {
                                let tile_epi = EpiFn(|base: usize, seg: &mut [f64]| {
                                    e.kernel.run_inplace_at(seg, base, rest)
                                });
                                plan.run_planned(ta, tb, out, sa, sb, sc, idx, &tile_epi);
                            }
                            EpilogueMode::TwoPass => {
                                plan.run_planned(
                                    ta,
                                    tb,
                                    out,
                                    sa,
                                    sb,
                                    sc,
                                    idx,
                                    &NoEpilogue,
                                );
                                let t0 = ex.trace.map(|s| s.now());
                                e.kernel.run_inplace(out, rest);
                                if let (Some(s), Some(t0)) = (ex.trace, t0) {
                                    s.record_epilogue(lane, p as u32, t0);
                                }
                            }
                        }
                    }
                }
            });
        }
        Instr::GenUnary(f, a, epi) => {
            let ta = src_slice(ex, *a);
            let last_dim = *lw.shapes[*a].last().expect("GenFn needs rank ≥ 1");
            gen_unary_into(*f, ta, last_dim, out);
            if let Some(e) = epi {
                let srcs = fused_srcs_planned(&e.args, ex, out.len());
                e.kernel.run_inplace(out, &srcs[..e.args.len()]);
            }
        }
        Instr::Fused { kernel, args } => match lw.inplace_arg[p] {
            Some(arg) => {
                // slot `arg` aliases the output; resolve the others
                let srcs = fused_srcs_planned_except(args, ex, out.len(), arg);
                kernel.run_inplace_arg(out, arg as u32, &srcs[..args.len()]);
            }
            None => {
                let srcs = fused_srcs_planned(args, ex, out.len());
                kernel.run(&srcs[..args.len()], out);
            }
        },
    }
}

/// Resolve fused-kernel operand slots against computed pooled values:
/// same contract as [`fused_srcs_planned`], resolving through `Val`s
/// instead of the source table.
fn fused_srcs<'v>(
    args: &[usize],
    values: &'v [Option<Val<'_>>],
    out_len: usize,
) -> [FusedSrc<'v>; FUSED_MAX_ARGS] {
    debug_assert!(args.len() <= FUSED_MAX_ARGS, "group builder must cap operand slots");
    let mut srcs = [FusedSrc::Scalar(0.0); FUSED_MAX_ARGS];
    for (slot, &q) in args.iter().enumerate() {
        let t = values[q].as_ref().expect("operand not computed").tensor();
        srcs[slot] = if t.len() == out_len {
            FusedSrc::Slice(t.data())
        } else {
            FusedSrc::Scalar(t.data()[0])
        };
    }
    srcs
}
