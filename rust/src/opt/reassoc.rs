//! Contraction reassociation: rewrite chains/trees of generic
//! multiplications into the cheapest pairwise association order (the
//! §3.3 cross-country strategy, generalised to whole root *sets*).
//!
//! Each maximal multiplication tree whose interior nodes are consumed
//! nowhere else is flattened into one n-ary contraction with globally
//! unified labels; the flattened terms are then contracted pairwise.
//! Two search strategies pick the order:
//!
//! * **optimal (DP)** — chains of at most [`DP_MAX_TERMS`] terms run an
//!   exact Held–Karp-style search over term subsets (the classic
//!   matrix-chain/einsum-ordering dynamic program, generalised to
//!   arbitrary label structure including outer products), so short
//!   chains — which is nearly all chains autodiff emits — get the
//!   provably cheapest association;
//! * **greedy** — longer chains contract cheapest-pair-first (result
//!   order as the tie-break — the paper's vectors-before-matrices rule),
//!   which is O(t³) instead of O(3ᵗ).
//!
//! Shared subexpressions stay atomic, so no work is ever duplicated
//! across roots. Re-association is justified by Lemmas 1–3: labels are
//! unified globally and summed labels stay internal to the chain.
//!
//! A cost guard makes the pass monotone: the original association
//! (rebuilt over the same optimised leaves) is restored whenever the
//! [`cost`](crate::opt::cost) model says the chosen order would cost
//! *more*; on ties the greedy order wins — even against an equal-cost DP
//! plan — because its expensive-factors-last property is what the §3.3
//! compression scheme builds on. So `(A·B)·v` becomes `A·(B·v)`, and no
//! chain ever gets costlier than it started.

use crate::einsum::{EinSpec, Label};
use crate::ir::{Graph, NodeId, Op};
use crate::opt::cost;
use std::collections::HashMap;

/// Global label space for flattened chains (disjoint from the per-spec
/// local labels).
type GLabel = u64;

/// Chains of at most this many terms run the exact subset-DP association
/// search; longer chains fall back to the greedy order. At 12 terms the
/// DP visits 3¹² ≈ 531k subset splits — well under a millisecond, and
/// comfortably above the chain lengths autodiff emits in practice.
pub const DP_MAX_TERMS: usize = 12;

/// A planned sequence of pairwise merges, as indices into the *current*
/// (shrinking) term list: step `(i, j)` merges the terms at positions
/// `i < j`, stores the result at `i` and removes `j` — exactly what the
/// emitter replays.
type Schedule = Vec<(usize, usize)>;

/// Re-associate all multiplication chains reachable from `roots`,
/// jointly. Returns the new roots (same order) and the number of chains
/// whose association actually changed. Semantics are preserved exactly;
/// only the association order (and label names) of `*` change.
pub fn reassociate(g: &mut Graph, roots: &[NodeId]) -> (Vec<NodeId>, usize) {
    let uses = g.use_counts(roots);
    let mut r = Reassoc { uses, memo: HashMap::new(), counter: 0, rewritten: 0 };
    let new_roots = roots.iter().map(|&root| r.rewrite(g, root)).collect();
    (new_roots, r.rewritten)
}

struct Reassoc {
    /// use counts over the *joint* pre-rewrite root set: a node consumed
    /// more than once stays atomic (never inlined into a chain)
    uses: Vec<u32>,
    memo: HashMap<NodeId, NodeId>,
    counter: GLabel,
    rewritten: usize,
}

/// One operand of a flattened n-ary contraction: the (original-graph)
/// node plus the global labels of its axes.
struct Term {
    node: NodeId,
    labels: Vec<GLabel>,
}

impl Reassoc {
    fn fresh(&mut self) -> GLabel {
        self.counter += 1;
        self.counter
    }

    fn rewrite(&mut self, g: &mut Graph, id: NodeId) -> NodeId {
        if let Some(&m) = self.memo.get(&id) {
            return m;
        }
        let res = match g.op(id).clone() {
            Op::Mul(..) => {
                // flatten the chain rooted here
                let out: Vec<GLabel> = (0..g.order(id)).map(|_| self.fresh()).collect();
                let mut terms: Vec<Term> = Vec::new();
                let mut dims: HashMap<GLabel, usize> = HashMap::new();
                for (gl, &d) in out.iter().zip(g.shape(id)) {
                    dims.insert(*gl, d);
                }
                self.flatten(g, id, &out, true, &mut terms, &mut dims);
                // rewrite the atomic operands themselves
                for t in &mut terms {
                    t.node = self.rewrite(g, t.node);
                }
                // Pick the association: exact DP for short chains, greedy
                // otherwise, with the cost guard comparing against the
                // chain's original association — all measured as the sum
                // of interior-contraction iteration spaces (the flattened
                // region is a tree of single-use Muls, so the sums are
                // exact region costs — leaves cancel out). A DP plan is
                // taken only when *strictly* cheaper than greedy, and the
                // original association is restored whenever the chosen
                // order would cost *more* than it; ties keep greedy,
                // whose expensive-factors-last property the §3.3
                // compression scheme builds on.
                let plain_cost = self.plain_region_cost(g, id, true);
                let label_sets: Vec<Vec<GLabel>> =
                    terms.iter().map(|t| t.labels.clone()).collect();
                let (greedy_sched, greedy_cost) =
                    schedule_greedy(label_sets.clone(), &out, &dims);
                let (sched, best_cost) = match schedule_optimal(&label_sets, &out, &dims) {
                    Some((s, c)) if c < greedy_cost => (s, c),
                    _ => (greedy_sched, greedy_cost),
                };
                if best_cost <= plain_cost {
                    if best_cost < plain_cost {
                        self.rewritten += 1;
                    }
                    emit_schedule(g, terms, &sched, &out, &dims)
                } else {
                    self.rebuild_plain(g, id, true)
                }
            }
            Op::Add(a, b) => {
                let a = self.rewrite(g, a);
                let b = self.rewrite(g, b);
                g.add(a, b)
            }
            Op::Elem(f, a) => {
                let a = self.rewrite(g, a);
                g.elem(f, a)
            }
            Op::GenUnary(f, a) => {
                let a = self.rewrite(g, a);
                g.gen_unary(f, a)
            }
            _ => id,
        };
        self.memo.insert(id, res);
        res
    }

    /// Collect the operands of the multiplication tree at `id`, whose
    /// axes carry the global labels `labels`. Only exclusively-owned Mul
    /// children are inlined — shared subexpressions stay atomic so no
    /// work is duplicated.
    fn flatten(
        &mut self,
        g: &Graph,
        id: NodeId,
        labels: &[GLabel],
        is_root: bool,
        terms: &mut Vec<Term>,
        dims: &mut HashMap<GLabel, usize>,
    ) {
        let inline = is_root || self.uses[id.index()] <= 1;
        if let Op::Mul(a, b, spec) = g.op(id).clone() {
            if inline {
                // map the spec's local labels to global ones: output labels
                // through `labels`, summed labels fresh
                let mut map: HashMap<Label, GLabel> = HashMap::new();
                for (l, &gl) in spec.s3.iter().zip(labels) {
                    map.insert(*l, gl);
                }
                let bind = |this: &mut Self,
                            map: &mut HashMap<Label, GLabel>,
                            ls: &[Label],
                            shape: &[usize],
                            dims: &mut HashMap<GLabel, usize>|
                 -> Vec<GLabel> {
                    ls.iter()
                        .zip(shape)
                        .map(|(l, &d)| {
                            let gl = *map.entry(*l).or_insert_with(|| this.fresh());
                            dims.insert(gl, d);
                            gl
                        })
                        .collect()
                };
                let la = bind(self, &mut map, &spec.s1, g.shape(a), dims);
                let lb = bind(self, &mut map, &spec.s2, g.shape(b), dims);
                self.flatten(g, a, &la, false, terms, dims);
                self.flatten(g, b, &lb, false, terms, dims);
                return;
            }
        }
        terms.push(Term { node: id, labels: labels.to_vec() });
    }

    /// Estimated flops of the chain's *original* association: the sum of
    /// the iteration spaces of the interior (inlined) `Mul` nodes. The
    /// leaves' own sub-DAG costs are identical for every association of
    /// the chain, so they are excluded from the comparison.
    fn plain_region_cost(&self, g: &Graph, id: NodeId, is_root: bool) -> u128 {
        if let Op::Mul(a, b, _) = g.op(id) {
            if is_root || self.uses[id.index()] <= 1 {
                return cost::node_flops(g, id)
                    + self.plain_region_cost(g, *a, false)
                    + self.plain_region_cost(g, *b, false);
            }
        }
        0
    }

    /// Rebuild the chain at `id` keeping its *original* association, with
    /// the atomic leaves rewritten through the normal path. Only invoked
    /// when the cost guard rejects the greedy order.
    fn rebuild_plain(&mut self, g: &mut Graph, id: NodeId, is_root: bool) -> NodeId {
        if let Op::Mul(a, b, spec) = g.op(id).clone() {
            if is_root || self.uses[id.index()] <= 1 {
                let ra = self.rebuild_plain(g, a, false);
                let rb = self.rebuild_plain(g, b, false);
                return g.mul(ra, rb, spec);
            }
        }
        self.rewrite(g, id)
    }
}

/// Plan the greedy association: contract cheapest pair first
/// (iteration-space size; ties broken by the *order* of the result
/// tensor — the paper's vectors-before-matrices rule). Pure label-level
/// simulation: returns the merge schedule plus its summed cost (the
/// greedy region cost the guard in [`Reassoc::rewrite`] compares);
/// [`emit_schedule`] replays the winner into the graph.
fn schedule_greedy(
    mut labels: Vec<Vec<GLabel>>,
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> (Schedule, u128) {
    assert!(!labels.is_empty());
    let mut sched = Schedule::new();
    let mut total: u128 = 0;
    while labels.len() > 1 {
        let mut best: Option<(usize, usize, u128, usize)> = None; // (i, j, cost, result order)
        for i in 0..labels.len() {
            for j in (i + 1)..labels.len() {
                let (cost, res) = pair_result(&labels, i, j, out, dims);
                let order = res.len();
                let better = match best {
                    None => true,
                    Some((_, _, bc, bo)) => cost < bc || (cost == bc && order < bo),
                };
                if better {
                    best = Some((i, j, cost, order));
                }
            }
        }
        let (i, j, step_cost, _) = best.unwrap();
        let (_, res) = pair_result(&labels, i, j, out, dims);
        labels[i] = res;
        labels.remove(j);
        sched.push((i, j));
        total = total.saturating_add(step_cost);
    }
    // a single term that is not already in output order pays one
    // transpose pass (the emitter adds the same node)
    if sched.is_empty() && labels[0] != out {
        let n: u128 = labels[0].iter().map(|l| dims[l] as u128).product();
        total = total.saturating_add(n);
    }
    (sched, total)
}

/// Plan the *optimal* association of a short chain: Held–Karp dynamic
/// programming over term subsets. `dp[S]` is the cheapest cost of
/// contracting subset `S` down to one tensor; a merge of `T` and `S \ T`
/// costs the iteration space of the union of their reduced label sets
/// (identical to the greedy step cost, so the two plans are compared in
/// the same currency). Returns `None` for chains outside `3..=DP_MAX_TERMS`
/// (2 terms have a unique association; longer chains stay greedy).
fn schedule_optimal(
    labels: &[Vec<GLabel>],
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> Option<(Schedule, u128)> {
    let t = labels.len();
    if !(3..=DP_MAX_TERMS).contains(&t) {
        return None;
    }
    let full: u32 = (1u32 << t) - 1;
    // reduced label set of every subset: the union of its members'
    // labels, keeping only labels still needed outside the subset (by
    // another term or by the output) — order-independent, which is what
    // makes the subset DP well-defined
    let mut set_labels: Vec<Vec<GLabel>> = vec![Vec::new(); (full as usize) + 1];
    for s in 1..=full {
        let mut ls: Vec<GLabel> = Vec::new();
        for (k, term) in labels.iter().enumerate() {
            if s & (1 << k) != 0 {
                for &l in term {
                    if !ls.contains(&l) {
                        ls.push(l);
                    }
                }
            }
        }
        if s.count_ones() > 1 {
            ls.retain(|l| {
                out.contains(l)
                    || labels
                        .iter()
                        .enumerate()
                        .any(|(k, term)| s & (1 << k) == 0 && term.contains(l))
            });
        }
        set_labels[s as usize] = ls;
    }

    const INF: u128 = u128::MAX;
    let mut best: Vec<u128> = vec![INF; (full as usize) + 1];
    let mut split: Vec<u32> = vec![0; (full as usize) + 1];
    for k in 0..t {
        best[1usize << k] = 0;
    }
    for s in 1..=full {
        if s.count_ones() < 2 {
            continue;
        }
        // enumerate splits (T, S \ T) with the lowest set bit pinned to T
        // so each unordered split is visited once
        let low = s & s.wrapping_neg();
        let rest = s ^ low;
        let mut sub = rest;
        loop {
            let t1 = sub | low;
            let t2 = s ^ t1;
            if t2 != 0 {
                let (c1, c2) = (best[t1 as usize], best[t2 as usize]);
                if c1 != INF && c2 != INF {
                    let mut union: Vec<GLabel> = set_labels[t1 as usize].clone();
                    for &l in &set_labels[t2 as usize] {
                        if !union.contains(&l) {
                            union.push(l);
                        }
                    }
                    let mc: u128 = union.iter().map(|l| dims[l] as u128).product();
                    let cost = c1.saturating_add(c2).saturating_add(mc);
                    if cost < best[s as usize] {
                        best[s as usize] = cost;
                        split[s as usize] = t1;
                    }
                }
            }
            if sub == 0 {
                break;
            }
            sub = (sub - 1) & rest;
        }
    }
    if best[full as usize] == INF {
        return None;
    }

    // flatten the winning binary tree into a shrinking-list schedule
    // (post-order), mirroring the emitter's replay semantics
    let mut live: Vec<u32> = (0..t).map(|k| 1u32 << k).collect();
    let mut sched = Schedule::new();
    fn flatten_tree(s: u32, split: &[u32], live: &mut Vec<u32>, sched: &mut Schedule) {
        if s.count_ones() == 1 {
            return;
        }
        let t1 = split[s as usize];
        let t2 = s ^ t1;
        flatten_tree(t1, split, live, sched);
        flatten_tree(t2, split, live, sched);
        let a = live.iter().position(|&x| x == t1).expect("live subset");
        let b = live.iter().position(|&x| x == t2).expect("live subset");
        let (i, j) = if a < b { (a, b) } else { (b, a) };
        live[i] = s;
        live.remove(j);
        sched.push((i, j));
    }
    flatten_tree(full, &split, &mut live, &mut sched);
    Some((sched, best[full as usize]))
}

/// Replay a merge schedule into the graph: each step contracts two live
/// terms into a fresh `Mul` (the final step emits directly in the
/// requested output order, so no trailing transpose is ever needed for
/// multi-term chains).
fn emit_schedule(
    g: &mut Graph,
    mut terms: Vec<Term>,
    sched: &Schedule,
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> NodeId {
    for &(i, j) in sched {
        let labels_view: Vec<Vec<GLabel>> = terms.iter().map(|t| t.labels.clone()).collect();
        let (_, mut res_labels) = pair_result(&labels_view, i, j, out, dims);
        if terms.len() == 2 {
            // final contraction: emit directly in the requested order
            res_labels = out.to_vec();
        }
        let merged = build_mul(g, &terms[i], &terms[j], &res_labels);
        terms[i] = Term { node: merged, labels: res_labels };
        terms.remove(j);
    }
    let last = terms.pop().expect("chain has at least one term");
    // final axis order must match `out`
    if last.labels == out {
        last.node
    } else {
        let perm: Vec<usize> = out
            .iter()
            .map(|gl| last.labels.iter().position(|x| x == gl).unwrap())
            .collect();
        g.transpose(last.node, &perm)
    }
}

/// Cost (iteration-space size) and surviving labels of contracting the
/// pair `(i, j)`: a label survives if some other term or the output still
/// needs it.
fn pair_result(
    labels: &[Vec<GLabel>],
    i: usize,
    j: usize,
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> (u128, Vec<GLabel>) {
    let mut union: Vec<GLabel> = Vec::new();
    for &l in labels[i].iter().chain(&labels[j]) {
        if !union.contains(&l) {
            union.push(l);
        }
    }
    let cost: u128 = union.iter().map(|l| dims[l] as u128).product();
    let needed = |l: &GLabel| {
        out.contains(l)
            || labels
                .iter()
                .enumerate()
                .any(|(t, ls)| t != i && t != j && ls.contains(l))
    };
    let res: Vec<GLabel> = union.into_iter().filter(needed).collect();
    (cost, res)
}

/// Emit the binary Mul node for one greedy step, relabelling the global
/// labels into a compact local space.
fn build_mul(g: &mut Graph, a: &Term, b: &Term, res: &[GLabel]) -> NodeId {
    let mut local: HashMap<GLabel, Label> = HashMap::new();
    let mut next: Label = 0;
    let mut conv = |gl: GLabel, local: &mut HashMap<GLabel, Label>| -> Label {
        *local.entry(gl).or_insert_with(|| {
            let l = next;
            next += 1;
            l
        })
    };
    let s1: Vec<Label> = a.labels.iter().map(|&gl| conv(gl, &mut local)).collect();
    let s2: Vec<Label> = b.labels.iter().map(|&gl| conv(gl, &mut local)).collect();
    let s3: Vec<Label> = res.iter().map(|&gl| conv(gl, &mut local)).collect();
    g.mul(a.node, b.node, EinSpec::new(s1, s2, s3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Env, Plan};
    use crate::simplify::flop_estimate;
    use crate::tensor::Tensor;

    fn eval1(g: &Graph, root: NodeId, env: &Env) -> Tensor {
        Plan::new(g, &[root]).run(g, env).pop().unwrap()
    }

    #[test]
    fn matrix_chain_reassociates_to_matvec_first() {
        // (A·B)·x costs n³ + n²; A·(B·x) costs 2n² — greedy must switch
        let mut g = Graph::new();
        let a = g.var("A", &[20, 20]);
        let b = g.var("B", &[20, 20]);
        let x = g.var("x", &[20]);
        let ab = g.matmul(a, b);
        let y = g.matvec(ab, x);
        let (roots, changed) = reassociate(&mut g, &[y]);
        assert_eq!(changed, 1);
        assert!(
            flop_estimate(&g, roots[0]) < flop_estimate(&g, y),
            "association must get cheaper: {} vs {}",
            flop_estimate(&g, roots[0]),
            flop_estimate(&g, y)
        );
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[20, 20], 1));
        env.insert("B", Tensor::randn(&[20, 20], 2));
        env.insert("x", Tensor::randn(&[20], 3));
        let want = eval1(&g, y, &env);
        let got = eval1(&g, roots[0], &env);
        assert!(got.allclose(&want, 1e-9, 1e-11), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn cost_guard_never_regresses() {
        // a lone matvec has nothing to improve: the rewrite may relabel
        // but must neither count as a reassociation nor change the cost
        let mut g = Graph::new();
        let a = g.var("A", &[8, 6]);
        let x = g.var("x", &[6]);
        let y = g.matvec(a, x);
        let before = flop_estimate(&g, y);
        let (roots, changed) = reassociate(&mut g, &[y]);
        assert_eq!(changed, 0);
        assert_eq!(flop_estimate(&g, roots[0]), before);
    }

    #[test]
    fn shared_chain_interior_stays_atomic_across_roots() {
        // A·B feeds two different chains; reassociating both roots must
        // keep one shared A·B (or cheaper), never duplicate the work
        let mut g = Graph::new();
        let a = g.var("A", &[10, 10]);
        let b = g.var("B", &[10, 10]);
        let x = g.var("x", &[10]);
        let z = g.var("z", &[10]);
        let ab = g.matmul(a, b);
        let r1 = g.matvec(ab, x);
        let r2 = g.matvec(ab, z);
        let joint_before = cost::dag_flops(&g, &[r1, r2]);
        let (roots, _) = reassociate(&mut g, &[r1, r2]);
        let joint_after = cost::dag_flops(&g, &roots);
        assert!(
            joint_after <= joint_before,
            "joint cost must not regress: {} vs {}",
            joint_after,
            joint_before
        );
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[10, 10], 1));
        env.insert("B", Tensor::randn(&[10, 10], 2));
        env.insert("x", Tensor::randn(&[10], 3));
        env.insert("z", Tensor::randn(&[10], 4));
        let want = Plan::new(&g, &[r1, r2]).run(&g, &env);
        let got = Plan::new(&g, &roots).run(&g, &env);
        for (w, v) in want.iter().zip(&got) {
            assert!(v.allclose(w, 1e-9, 1e-11));
        }
    }

    #[test]
    fn dp_beats_greedy_where_cheapest_first_misleads() {
        // M1: 1×1, M2: 1×100, M3: 100×2, out 1×2.
        // Greedy grabs the cheapest pair first — M1·M2 at 1·1·100 = 100 —
        // and then pays 1·100·2 = 200 for the rest: 300 total.
        // The optimal order is M2·M3 (1·100·2 = 200) then M1·(M2·M3)
        // (1·1·2 = 2): 202 total. Only the exact DP finds it.
        let mut g = Graph::new();
        let m1 = g.var("M1", &[1, 1]);
        let m2 = g.var("M2", &[1, 100]);
        let m3 = g.var("M3", &[100, 2]);
        let m12 = g.matmul(m1, m2);
        let y = g.matmul(m12, m3);
        assert_eq!(flop_estimate(&g, y), 300, "plain association costs 300");
        let (roots, changed) = reassociate(&mut g, &[y]);
        assert_eq!(changed, 1);
        assert_eq!(
            flop_estimate(&g, roots[0]),
            202,
            "DP must find the 202-flop association (greedy stops at 300)"
        );
        let mut env = Env::new();
        env.insert("M1", Tensor::randn(&[1, 1], 1));
        env.insert("M2", Tensor::randn(&[1, 100], 2));
        env.insert("M3", Tensor::randn(&[100, 2], 3));
        let want = eval1(&g, y, &env);
        let got = eval1(&g, roots[0], &env);
        assert!(got.allclose(&want, 1e-9, 1e-11), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn long_chains_fall_back_to_greedy() {
        // 14 terms exceed DP_MAX_TERMS: the pass must stay on the greedy
        // path and still preserve semantics
        let mut g = Graph::new();
        let vars: Vec<_> = (0..14).map(|i| g.var(&format!("v{}", i), &[6])).collect();
        let mut y = vars[0];
        for &v in &vars[1..] {
            y = g.hadamard(y, v);
        }
        let before = flop_estimate(&g, y);
        let (roots, _) = reassociate(&mut g, &[y]);
        assert!(flop_estimate(&g, roots[0]) <= before);
        let mut env = Env::new();
        for i in 0..14 {
            env.insert(&format!("v{}", i), Tensor::randn(&[6], 10 + i as u64));
        }
        let want = eval1(&g, y, &env);
        let got = eval1(&g, roots[0], &env);
        assert!(got.allclose(&want, 1e-9, 1e-11), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn dp_ties_keep_the_greedy_order() {
        // square matrix chain where greedy already finds the optimum:
        // the DP must not displace it (fingerprint-stable graphs)
        let build = || {
            let mut g = Graph::new();
            let a = g.var("A", &[20, 20]);
            let b = g.var("B", &[20, 20]);
            let x = g.var("x", &[20]);
            let ab = g.matmul(a, b);
            let y = g.matvec(ab, x);
            let (roots, _) = reassociate(&mut g, &[y]);
            // both searches land on A·(B·x): two 20²-space matvecs
            assert_eq!(flop_estimate(&g, roots[0]), 800);
            let (gc, croots) = crate::opt::compact(&g, &roots);
            (gc, croots)
        };
        let (g1, r1) = build();
        let (g2, r2) = build();
        assert_eq!(r1, r2);
        assert_eq!(
            crate::exec::graph_fingerprint(&g1),
            crate::exec::graph_fingerprint(&g2),
            "tie-handling must stay deterministic"
        );
    }

    #[test]
    fn permuted_outputs_preserved() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.mul(a, b, EinSpec::parse("ij,jk->ki"));
        let (roots, _) = reassociate(&mut g, &[c]);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 1));
        env.insert("B", Tensor::randn(&[4, 5], 2));
        let want = eval1(&g, c, &env);
        let got = eval1(&g, roots[0], &env);
        assert!(got.allclose(&want, 1e-10, 1e-12));
    }
}
