//! Contraction reassociation: rewrite chains/trees of generic
//! multiplications into the cheapest pairwise association order found by
//! a greedy dimension-aware search (the §3.3 cross-country strategy,
//! generalised to whole root *sets*).
//!
//! Each maximal multiplication tree whose interior nodes are consumed
//! nowhere else is flattened into one n-ary contraction with globally
//! unified labels; the flattened terms are then contracted pairwise,
//! cheapest iteration space first (result order as the tie-break — the
//! paper's vectors-before-matrices rule). Shared subexpressions stay
//! atomic, so no work is ever duplicated across roots. Re-association is
//! justified by Lemmas 1–3: labels are unified globally and summed
//! labels stay internal to the chain.
//!
//! A cost guard makes the pass monotone: the original association
//! (rebuilt over the same optimised leaves) is restored whenever the
//! [`cost`](crate::opt::cost) model says the greedy order would cost
//! *more*; on ties the greedy order wins, because its
//! expensive-factors-last property is what the §3.3 compression scheme
//! builds on. So `(A·B)·v` becomes `A·(B·v)`, and no chain ever gets
//! costlier than it started.

use crate::einsum::{EinSpec, Label};
use crate::ir::{Graph, NodeId, Op};
use crate::opt::cost;
use std::collections::HashMap;

/// Global label space for flattened chains (disjoint from the per-spec
/// local labels).
type GLabel = u64;

/// Re-associate all multiplication chains reachable from `roots`,
/// jointly. Returns the new roots (same order) and the number of chains
/// whose association actually changed. Semantics are preserved exactly;
/// only the association order (and label names) of `*` change.
pub fn reassociate(g: &mut Graph, roots: &[NodeId]) -> (Vec<NodeId>, usize) {
    let uses = g.use_counts(roots);
    let mut r = Reassoc { uses, memo: HashMap::new(), counter: 0, rewritten: 0 };
    let new_roots = roots.iter().map(|&root| r.rewrite(g, root)).collect();
    (new_roots, r.rewritten)
}

struct Reassoc {
    /// use counts over the *joint* pre-rewrite root set: a node consumed
    /// more than once stays atomic (never inlined into a chain)
    uses: Vec<u32>,
    memo: HashMap<NodeId, NodeId>,
    counter: GLabel,
    rewritten: usize,
}

/// One operand of a flattened n-ary contraction: the (original-graph)
/// node plus the global labels of its axes.
struct Term {
    node: NodeId,
    labels: Vec<GLabel>,
}

impl Reassoc {
    fn fresh(&mut self) -> GLabel {
        self.counter += 1;
        self.counter
    }

    fn rewrite(&mut self, g: &mut Graph, id: NodeId) -> NodeId {
        if let Some(&m) = self.memo.get(&id) {
            return m;
        }
        let res = match g.op(id).clone() {
            Op::Mul(..) => {
                // flatten the chain rooted here
                let out: Vec<GLabel> = (0..g.order(id)).map(|_| self.fresh()).collect();
                let mut terms: Vec<Term> = Vec::new();
                let mut dims: HashMap<GLabel, usize> = HashMap::new();
                for (gl, &d) in out.iter().zip(g.shape(id)) {
                    dims.insert(*gl, d);
                }
                self.flatten(g, id, &out, true, &mut terms, &mut dims);
                // rewrite the atomic operands themselves
                for t in &mut terms {
                    t.node = self.rewrite(g, t.node);
                }
                // cost guard: compare the greedy merge sequence against
                // the chain's original association, both measured as the
                // sum of interior-contraction iteration spaces (the
                // flattened region is a tree of single-use Muls, so both
                // sums are exact region costs — leaves cancel out). Fall
                // back to the original association only when greedy would
                // actually cost *more*; ties keep the greedy order, whose
                // expensive-factors-last property the §3.3 compression
                // scheme builds on.
                let plain_cost = self.plain_region_cost(g, id, true);
                let (greedy, greedy_cost) = contract_greedy(g, terms, &out, &dims);
                if greedy_cost <= plain_cost {
                    if greedy_cost < plain_cost {
                        self.rewritten += 1;
                    }
                    greedy
                } else {
                    self.rebuild_plain(g, id, true)
                }
            }
            Op::Add(a, b) => {
                let a = self.rewrite(g, a);
                let b = self.rewrite(g, b);
                g.add(a, b)
            }
            Op::Elem(f, a) => {
                let a = self.rewrite(g, a);
                g.elem(f, a)
            }
            Op::GenUnary(f, a) => {
                let a = self.rewrite(g, a);
                g.gen_unary(f, a)
            }
            _ => id,
        };
        self.memo.insert(id, res);
        res
    }

    /// Collect the operands of the multiplication tree at `id`, whose
    /// axes carry the global labels `labels`. Only exclusively-owned Mul
    /// children are inlined — shared subexpressions stay atomic so no
    /// work is duplicated.
    fn flatten(
        &mut self,
        g: &Graph,
        id: NodeId,
        labels: &[GLabel],
        is_root: bool,
        terms: &mut Vec<Term>,
        dims: &mut HashMap<GLabel, usize>,
    ) {
        let inline = is_root || self.uses[id.index()] <= 1;
        if let Op::Mul(a, b, spec) = g.op(id).clone() {
            if inline {
                // map the spec's local labels to global ones: output labels
                // through `labels`, summed labels fresh
                let mut map: HashMap<Label, GLabel> = HashMap::new();
                for (l, &gl) in spec.s3.iter().zip(labels) {
                    map.insert(*l, gl);
                }
                let bind = |this: &mut Self,
                            map: &mut HashMap<Label, GLabel>,
                            ls: &[Label],
                            shape: &[usize],
                            dims: &mut HashMap<GLabel, usize>|
                 -> Vec<GLabel> {
                    ls.iter()
                        .zip(shape)
                        .map(|(l, &d)| {
                            let gl = *map.entry(*l).or_insert_with(|| this.fresh());
                            dims.insert(gl, d);
                            gl
                        })
                        .collect()
                };
                let la = bind(self, &mut map, &spec.s1, g.shape(a), dims);
                let lb = bind(self, &mut map, &spec.s2, g.shape(b), dims);
                self.flatten(g, a, &la, false, terms, dims);
                self.flatten(g, b, &lb, false, terms, dims);
                return;
            }
        }
        terms.push(Term { node: id, labels: labels.to_vec() });
    }

    /// Estimated flops of the chain's *original* association: the sum of
    /// the iteration spaces of the interior (inlined) `Mul` nodes. The
    /// leaves' own sub-DAG costs are identical for every association of
    /// the chain, so they are excluded from the comparison.
    fn plain_region_cost(&self, g: &Graph, id: NodeId, is_root: bool) -> u128 {
        if let Op::Mul(a, b, _) = g.op(id) {
            if is_root || self.uses[id.index()] <= 1 {
                return cost::node_flops(g, id)
                    + self.plain_region_cost(g, *a, false)
                    + self.plain_region_cost(g, *b, false);
            }
        }
        0
    }

    /// Rebuild the chain at `id` keeping its *original* association, with
    /// the atomic leaves rewritten through the normal path. Only invoked
    /// when the cost guard rejects the greedy order.
    fn rebuild_plain(&mut self, g: &mut Graph, id: NodeId, is_root: bool) -> NodeId {
        if let Op::Mul(a, b, spec) = g.op(id).clone() {
            if is_root || self.uses[id.index()] <= 1 {
                let ra = self.rebuild_plain(g, a, false);
                let rb = self.rebuild_plain(g, b, false);
                return g.mul(ra, rb, spec);
            }
        }
        self.rewrite(g, id)
    }
}

/// Greedily contract the flattened terms pairwise: cheapest contraction
/// first (iteration-space size; ties broken by the *order* of the result
/// tensor — the paper's vectors-before-matrices rule). Returns the chain
/// root plus the summed cost of the merges it performed (the greedy
/// region cost the guard in [`Reassoc::rewrite`] compares).
fn contract_greedy(
    g: &mut Graph,
    mut terms: Vec<Term>,
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> (NodeId, u128) {
    assert!(!terms.is_empty());
    let mut total: u128 = 0;
    while terms.len() > 1 {
        let mut best: Option<(usize, usize, u128, usize)> = None; // (i, j, cost, result order)
        for i in 0..terms.len() {
            for j in (i + 1)..terms.len() {
                let (cost, res) = pair_result(&terms, i, j, out, dims);
                let order = res.len();
                let better = match best {
                    None => true,
                    Some((_, _, bc, bo)) => cost < bc || (cost == bc && order < bo),
                };
                if better {
                    best = Some((i, j, cost, order));
                }
            }
        }
        let (i, j, step_cost, _) = best.unwrap();
        let (_, mut res_labels) = pair_result(&terms, i, j, out, dims);
        if terms.len() == 2 {
            // final contraction: emit directly in the requested output order
            res_labels = out.to_vec();
        }
        let merged = build_mul(g, &terms[i], &terms[j], &res_labels);
        terms[i] = Term { node: merged, labels: res_labels };
        terms.remove(j);
        total = total.saturating_add(step_cost);
    }
    let last = terms.pop().unwrap();
    // final axis order must match `out`
    if last.labels == out {
        (last.node, total)
    } else {
        let perm: Vec<usize> = out
            .iter()
            .map(|gl| last.labels.iter().position(|x| x == gl).unwrap())
            .collect();
        let n: u128 = g.shape(last.node).iter().map(|&d| d as u128).product();
        (g.transpose(last.node, &perm), total.saturating_add(n))
    }
}

/// Cost (iteration-space size) and surviving labels of contracting the
/// pair `(i, j)`: a label survives if some other term or the output still
/// needs it.
fn pair_result(
    terms: &[Term],
    i: usize,
    j: usize,
    out: &[GLabel],
    dims: &HashMap<GLabel, usize>,
) -> (u128, Vec<GLabel>) {
    let mut union: Vec<GLabel> = Vec::new();
    for &l in terms[i].labels.iter().chain(&terms[j].labels) {
        if !union.contains(&l) {
            union.push(l);
        }
    }
    let cost: u128 = union.iter().map(|l| dims[l] as u128).product();
    let needed = |l: &GLabel| {
        out.contains(l)
            || terms
                .iter()
                .enumerate()
                .any(|(t, term)| t != i && t != j && term.labels.contains(l))
    };
    let res: Vec<GLabel> = union.into_iter().filter(needed).collect();
    (cost, res)
}

/// Emit the binary Mul node for one greedy step, relabelling the global
/// labels into a compact local space.
fn build_mul(g: &mut Graph, a: &Term, b: &Term, res: &[GLabel]) -> NodeId {
    let mut local: HashMap<GLabel, Label> = HashMap::new();
    let mut next: Label = 0;
    let mut conv = |gl: GLabel, local: &mut HashMap<GLabel, Label>| -> Label {
        *local.entry(gl).or_insert_with(|| {
            let l = next;
            next += 1;
            l
        })
    };
    let s1: Vec<Label> = a.labels.iter().map(|&gl| conv(gl, &mut local)).collect();
    let s2: Vec<Label> = b.labels.iter().map(|&gl| conv(gl, &mut local)).collect();
    let s3: Vec<Label> = res.iter().map(|&gl| conv(gl, &mut local)).collect();
    g.mul(a.node, b.node, EinSpec::new(s1, s2, s3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Env, Plan};
    use crate::simplify::flop_estimate;
    use crate::tensor::Tensor;

    fn eval1(g: &Graph, root: NodeId, env: &Env) -> Tensor {
        Plan::new(g, &[root]).run(g, env).pop().unwrap()
    }

    #[test]
    fn matrix_chain_reassociates_to_matvec_first() {
        // (A·B)·x costs n³ + n²; A·(B·x) costs 2n² — greedy must switch
        let mut g = Graph::new();
        let a = g.var("A", &[20, 20]);
        let b = g.var("B", &[20, 20]);
        let x = g.var("x", &[20]);
        let ab = g.matmul(a, b);
        let y = g.matvec(ab, x);
        let (roots, changed) = reassociate(&mut g, &[y]);
        assert_eq!(changed, 1);
        assert!(
            flop_estimate(&g, roots[0]) < flop_estimate(&g, y),
            "association must get cheaper: {} vs {}",
            flop_estimate(&g, roots[0]),
            flop_estimate(&g, y)
        );
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[20, 20], 1));
        env.insert("B", Tensor::randn(&[20, 20], 2));
        env.insert("x", Tensor::randn(&[20], 3));
        let want = eval1(&g, y, &env);
        let got = eval1(&g, roots[0], &env);
        assert!(got.allclose(&want, 1e-9, 1e-11), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn cost_guard_never_regresses() {
        // a lone matvec has nothing to improve: the rewrite may relabel
        // but must neither count as a reassociation nor change the cost
        let mut g = Graph::new();
        let a = g.var("A", &[8, 6]);
        let x = g.var("x", &[6]);
        let y = g.matvec(a, x);
        let before = flop_estimate(&g, y);
        let (roots, changed) = reassociate(&mut g, &[y]);
        assert_eq!(changed, 0);
        assert_eq!(flop_estimate(&g, roots[0]), before);
    }

    #[test]
    fn shared_chain_interior_stays_atomic_across_roots() {
        // A·B feeds two different chains; reassociating both roots must
        // keep one shared A·B (or cheaper), never duplicate the work
        let mut g = Graph::new();
        let a = g.var("A", &[10, 10]);
        let b = g.var("B", &[10, 10]);
        let x = g.var("x", &[10]);
        let z = g.var("z", &[10]);
        let ab = g.matmul(a, b);
        let r1 = g.matvec(ab, x);
        let r2 = g.matvec(ab, z);
        let joint_before = cost::dag_flops(&g, &[r1, r2]);
        let (roots, _) = reassociate(&mut g, &[r1, r2]);
        let joint_after = cost::dag_flops(&g, &roots);
        assert!(
            joint_after <= joint_before,
            "joint cost must not regress: {} vs {}",
            joint_after,
            joint_before
        );
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[10, 10], 1));
        env.insert("B", Tensor::randn(&[10, 10], 2));
        env.insert("x", Tensor::randn(&[10], 3));
        env.insert("z", Tensor::randn(&[10], 4));
        let want = Plan::new(&g, &[r1, r2]).run(&g, &env);
        let got = Plan::new(&g, &roots).run(&g, &env);
        for (w, v) in want.iter().zip(&got) {
            assert!(v.allclose(w, 1e-9, 1e-11));
        }
    }

    #[test]
    fn permuted_outputs_preserved() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.mul(a, b, EinSpec::parse("ij,jk->ki"));
        let (roots, _) = reassociate(&mut g, &[c]);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 1));
        env.insert("B", Tensor::randn(&[4, 5], 2));
        let want = eval1(&g, c, &env);
        let got = eval1(&g, roots[0], &env);
        assert!(got.allclose(&want, 1e-10, 1e-12));
    }
}
