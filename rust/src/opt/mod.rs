//! The graph optimizer: a pass pipeline that runs on `(Graph, roots)`
//! *after* autodiff/simplify and *before* plan compilation.
//!
//! The paper's efficiency claim "hinges on the representation of the
//! expressions": loss, gradient and Hessian DAGs share large common
//! subexpressions, and the association order of contraction chains
//! decides the constant factors. The local rewrites of
//! [`crate::simplify`] cannot see either — this subsystem adds the two
//! graph-level passes where those constants hide:
//!
//! 1. **Global CSE** ([`cse`]) — hash-consing with einsum-spec
//!    canonicalization (commutative `Add`, Lemma-2 swapped `Mul`,
//!    relabel-equivalent specs all dedupe to one node), run jointly over
//!    *all* roots so the whole root set shares one sub-DAG. Exact up to
//!    operand order (swapping commutes elementwise; only accumulation
//!    order inside the lowered contraction can move the last bits).
//! 2. **Contraction reassociation** ([`reassoc`]) — maximal
//!    multiplication chains are flattened and re-associated greedily
//!    under the dimension-aware cost model of [`cost`] (the classic
//!    `(A·B)·v → A·(B·v)` win on every Hessian-vector workload), with a
//!    guard that restores the original association whenever the greedy
//!    order would cost more (ties keep greedy — compression relies on
//!    its factor ordering). Changes only the association (and rounding
//!    at the last bits), never the semantics.
//! 3. **CSE again + dead-node sweep** — reassociation emits canonically
//!    labelled nodes, so a second (cheap) CSE merges newly identical
//!    chains; [`compact`] then rebuilds the live sub-DAG into a fresh
//!    graph for consumers that key on the whole graph (the plan cache
//!    fingerprints the *optimized, compacted* graph).
//!
//! Pass ordering matters: CSE first maximises sharing so reassociation
//! sees true use counts (a shared product must stay atomic); reassociation
//! then mints relabelled nodes that only a second CSE can merge.
//!
//! Invariants, relied on by the tests and the wiring in
//! [`crate::eval::eval_many`] / [`crate::exec::PlanCache`]:
//!
//! * optimisation never *adds* reachable nodes or estimated flops
//!   (`nodes_after ≤ nodes_before`, `flops_after ≤ flops_before`),
//! * root order (and duplicates) are preserved, roots only ever merge,
//! * the pipeline is deterministic: equal input graphs give equal
//!   optimized graphs (the plan-cache key contract),
//! * [`OptLevel::None`] is a true no-op escape hatch, kept as the
//!   ablation baseline alongside `CompiledPlan::with_fusion(.., false)`.

pub mod cost;
pub mod cse;
pub mod reassoc;

use crate::ir::{Graph, NodeId, Op};
use std::collections::HashMap;
use std::fmt;

/// How hard the optimizer works. Levels are cumulative.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Hash, Default)]
pub enum OptLevel {
    /// No optimisation — compile the graph exactly as given.
    None,
    /// Global CSE only (exact up to operand order).
    Cse,
    /// CSE + contraction reassociation + final CSE. The default.
    #[default]
    Full,
}

/// What the optimizer did, in the units the paper argues in: DAG nodes
/// and estimated flops, before and after.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptStats {
    pub nodes_before: usize,
    pub nodes_after: usize,
    pub flops_before: u128,
    pub flops_after: u128,
    /// distinct nodes merged away by the CSE passes
    pub cse_merged: usize,
    /// multiplication chains whose association order changed
    pub reassoc_rewritten: usize,
}

impl fmt::Display for OptStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "nodes {} -> {}, est. flops {} -> {}, cse merged {}, chains reassociated {}",
            self.nodes_before,
            self.nodes_after,
            self.flops_before,
            self.flops_after,
            self.cse_merged,
            self.reassoc_rewritten
        )
    }
}

/// Result of one optimizer run: the rewritten roots plus statistics.
pub struct Optimized {
    pub roots: Vec<NodeId>,
    pub stats: OptStats,
}

/// Run the pass pipeline on the sub-DAG of `roots` at the given level.
/// New nodes are appended to `g`; dead originals simply become
/// unreachable (use [`compact`] to sweep them into a fresh graph).
///
/// # Example
///
/// The classic reassociation win — `(A·B)·x` is rewritten to `A·(B·x)`,
/// and [`OptStats`] reports the flop change the paper argues in:
///
/// ```
/// use tensorcalc::ir::Graph;
/// use tensorcalc::opt::{optimize, OptLevel};
///
/// let mut g = Graph::new();
/// let a = g.var("A", &[64, 64]);
/// let b = g.var("B", &[64, 64]);
/// let x = g.var("x", &[64]);
/// let ab = g.matmul(a, b);       // 64³ flops if evaluated this way
/// let y = g.matvec(ab, x);
///
/// let o = optimize(&mut g, &[y], OptLevel::Full);
/// assert_eq!(o.roots.len(), 1);          // roots map 1:1, in order
/// assert!(o.stats.reassoc_rewritten >= 1);
/// assert!(o.stats.flops_after < o.stats.flops_before); // two matvecs now
/// ```
pub fn optimize(g: &mut Graph, roots: &[NodeId], level: OptLevel) -> Optimized {
    let nodes_before = g.topo(roots).len();
    let flops_before = cost::dag_flops(g, roots);
    let mut cur = roots.to_vec();
    let mut cse_merged = 0;
    let mut reassoc_rewritten = 0;
    if level >= OptLevel::Cse {
        let (r, m) = cse::cse(g, &cur);
        cur = r;
        cse_merged += m;
    }
    if level >= OptLevel::Full {
        let (r, n) = reassoc::reassociate(g, &cur);
        cur = r;
        reassoc_rewritten = n;
        let (r, m) = cse::cse(g, &cur);
        cur = r;
        cse_merged += m;
    }
    let stats = OptStats {
        nodes_before,
        nodes_after: g.topo(&cur).len(),
        flops_before,
        flops_after: cost::dag_flops(g, &cur),
        cse_merged,
        reassoc_rewritten,
    };
    Optimized { roots: cur, stats }
}

/// What [`optimize`] *would* do to `(g, roots)` at `level`, without
/// mutating the caller's graph — the reporting entry point used by the
/// CLI, the figures and the examples.
pub fn report(g: &Graph, roots: &[NodeId], level: OptLevel) -> OptStats {
    let mut g2 = g.clone();
    optimize(&mut g2, roots, level).stats
}

/// Dead-node sweep: rebuild only the nodes reachable from `roots` into a
/// fresh graph (variable names and declaration shapes preserved).
/// Returns the new graph and the remapped roots. Node ids stay in
/// topological order, so the compiled instruction stream — and therefore
/// the numerics — are identical to compiling the original graph.
pub fn compact(g: &Graph, roots: &[NodeId]) -> (Graph, Vec<NodeId>) {
    let mut g2 = Graph::new();
    let mut map: HashMap<NodeId, NodeId> = HashMap::new();
    for id in g.topo(roots) {
        let new = match g.op(id) {
            Op::Var(name) => {
                let name = name.clone();
                let shape = g.shape(id).to_vec();
                g2.var(&name, &shape)
            }
            Op::Const(bits) => {
                let v = f64::from_bits(*bits);
                let shape = g.shape(id).to_vec();
                g2.constant(v, &shape)
            }
            Op::Delta { dims } => {
                let dims = dims.clone();
                g2.delta(&dims)
            }
            Op::Add(a, b) => {
                let (a, b) = (map[a], map[b]);
                g2.add(a, b)
            }
            Op::Mul(a, b, spec) => {
                let (a, b, spec) = (map[a], map[b], spec.clone());
                g2.mul(a, b, spec)
            }
            Op::Elem(f, a) => {
                let (f, a) = (*f, map[a]);
                g2.elem(f, a)
            }
            Op::GenUnary(f, a) => {
                let (f, a) = (*f, map[a]);
                g2.gen_unary(f, a)
            }
        };
        map.insert(id, new);
    }
    let new_roots = roots.iter().map(|r| map[r]).collect();
    (g2, new_roots)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Env, Plan};
    use crate::ir::Elem;
    use crate::tensor::Tensor;

    fn chain_graph() -> (Graph, NodeId, Env) {
        let mut g = Graph::new();
        let a = g.var("A", &[16, 16]);
        let b = g.var("B", &[16, 16]);
        let x = g.var("x", &[16]);
        let ab = g.matmul(a, b);
        let abx = g.matvec(ab, x);
        let y = g.elem(Elem::Tanh, abx);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[16, 16], 1));
        env.insert("B", Tensor::randn(&[16, 16], 2));
        env.insert("x", Tensor::randn(&[16], 3));
        (g, y, env)
    }

    #[test]
    fn levels_are_monotone_and_none_is_identity() {
        let (mut g, y, _) = chain_graph();
        let o = optimize(&mut g, &[y], OptLevel::None);
        assert_eq!(o.roots, vec![y]);
        assert_eq!(o.stats.nodes_after, o.stats.nodes_before);
        assert_eq!(o.stats.flops_after, o.stats.flops_before);

        let o = optimize(&mut g, &[y], OptLevel::Full);
        assert!(o.stats.nodes_after <= o.stats.nodes_before);
        assert!(
            o.stats.flops_after < o.stats.flops_before,
            "the matrix chain must reassociate: {}",
            o.stats
        );
        assert!(o.stats.reassoc_rewritten >= 1);
    }

    #[test]
    fn optimize_preserves_values() {
        let (mut g, y, env) = chain_graph();
        let want = Plan::new(&g, &[y]).run(&g, &env);
        for level in [OptLevel::None, OptLevel::Cse, OptLevel::Full] {
            let o = optimize(&mut g, &[y], level);
            let got = Plan::new(&g, &o.roots).run(&g, &env);
            assert!(
                got[0].allclose(&want[0], 1e-10, 1e-12),
                "{:?}: diff {}",
                level,
                got[0].max_abs_diff(&want[0])
            );
        }
    }

    #[test]
    fn duplicate_roots_survive() {
        let (mut g, y, _) = chain_graph();
        let o = optimize(&mut g, &[y, y], OptLevel::Full);
        assert_eq!(o.roots.len(), 2);
        assert_eq!(o.roots[0], o.roots[1]);
    }

    #[test]
    fn compact_drops_dead_nodes_and_preserves_values() {
        let (mut g, y, env) = chain_graph();
        // grow some garbage that is unreachable from y
        let dead = g.var("dead", &[7]);
        let _ = g.elem(Elem::Exp, dead);
        let o = optimize(&mut g, &[y], OptLevel::Full);
        let (g2, roots2) = compact(&g, &o.roots);
        assert_eq!(g2.len(), g.topo(&o.roots).len(), "compacted graph must be exactly the live set");
        assert!(g2.len() < g.len());
        assert!(g2.var_id("dead").is_none());
        let want = Plan::new(&g, &o.roots).run(&g, &env);
        let got = Plan::new(&g2, &roots2).run(&g2, &env);
        assert_eq!(got[0], want[0], "compaction must not change numerics");
    }

    #[test]
    fn optimize_is_deterministic() {
        let build = || {
            let (mut g, y, _) = chain_graph();
            let o = optimize(&mut g, &[y], OptLevel::Full);
            compact(&g, &o.roots)
        };
        let (g1, r1) = build();
        let (g2, r2) = build();
        assert_eq!(r1, r2);
        assert_eq!(crate::exec::graph_fingerprint(&g1), crate::exec::graph_fingerprint(&g2));
    }

    #[test]
    fn raw_delta_seeded_derivatives_survive_optimization() {
        // the optimizer must digest *unsimplified* autodiff output
        // (delta seeds, broadcast pullbacks) without panicking
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let y = g.elem(Elem::Exp, ax);
        let jac = crate::autodiff::reverse::reverse_derivative(&mut g, y, &[x])[0];
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 4));
        env.insert("x", Tensor::randn(&[4], 5));
        let want = Plan::new(&g, &[jac]).run(&g, &env);
        let o = optimize(&mut g, &[jac], OptLevel::Full);
        assert!(o.stats.nodes_after <= o.stats.nodes_before);
        assert!(o.stats.flops_after <= o.stats.flops_before);
        let got = Plan::new(&g, &o.roots).run(&g, &env);
        assert!(got[0].allclose(&want[0], 1e-10, 1e-12));
    }
}
