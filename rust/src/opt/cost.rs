//! The dimension-aware cost model shared by the optimizer passes.
//!
//! The unit is an *estimated flop*: for a multiplication node the size of
//! its iteration space (the product of the dimensions of all distinct
//! labels of the spec — exactly the number of multiply-adds a naive
//! evaluation performs), for element-wise nodes the element count of the
//! result, and zero for inputs and compile-time constants. This
//! generalises the old per-root `simplify::flop_estimate` (which now
//! delegates here) to *joint* root sets: a node shared by several roots
//! is counted once, which is what the executor actually pays.

use crate::einsum::Label;
use crate::ir::{Graph, NodeId, Op};

/// Estimated flops of evaluating node `id` once.
pub fn node_flops(g: &Graph, id: NodeId) -> u128 {
    match g.op(id) {
        Op::Mul(a, b, spec) => {
            let mut dims: Vec<(Label, usize)> = Vec::new();
            for (&l, &d) in spec
                .s1
                .iter()
                .zip(g.shape(*a))
                .chain(spec.s2.iter().zip(g.shape(*b)))
            {
                if !dims.iter().any(|(ll, _)| *ll == l) {
                    dims.push((l, d));
                }
            }
            dims.iter().map(|(_, d)| *d as u128).product()
        }
        Op::Elem(..) | Op::GenUnary(..) | Op::Add(..) => {
            g.shape(id).iter().map(|&d| d as u128).product()
        }
        _ => 0,
    }
}

/// Estimated flops of evaluating the sub-DAG reachable from `roots`
/// once, counting every shared node exactly once.
pub fn dag_flops(g: &Graph, roots: &[NodeId]) -> u128 {
    g.topo(roots).iter().map(|&id| node_flops(g, id)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::EinSpec;

    #[test]
    fn mul_cost_is_iteration_space() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let b = g.var("B", &[4, 5]);
        let c = g.mul(a, b, EinSpec::parse("ij,jk->ik"));
        assert_eq!(node_flops(&g, c), 3 * 4 * 5);
        assert_eq!(dag_flops(&g, &[c]), 3 * 4 * 5);
    }

    #[test]
    fn shared_nodes_count_once_across_roots() {
        let mut g = Graph::new();
        let a = g.var("A", &[6, 6]);
        let x = g.var("x", &[6]);
        let ax = g.matvec(a, x); // 36 flops
        let r1 = g.elem(crate::ir::Elem::Exp, ax); // 6
        let r2 = g.elem(crate::ir::Elem::Tanh, ax); // 6
        assert_eq!(dag_flops(&g, &[r1, r2]), 36 + 6 + 6);
        // and each root alone still pays for the shared product
        assert_eq!(dag_flops(&g, &[r1]), 36 + 6);
    }

    #[test]
    fn matches_simplify_flop_estimate_on_single_roots() {
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let e = g.elem(crate::ir::Elem::Exp, ax);
        let f = g.sum_all(e);
        assert_eq!(dag_flops(&g, &[f]), crate::simplify::flop_estimate(&g, f));
    }
}
